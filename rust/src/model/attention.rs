//! Attention numerics: dense softmax attention and FNet (2D-FFT) mixing.
//!
//! Used by the functional examples to cross-check the PJRT-executed
//! artifacts and by the workload generators to produce realistic traffic.

use super::butterfly::BpmmFactors;
use super::fft::fft2d_real;

/// Row-major (rows, cols) matrix helper.
#[derive(Debug, Clone)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// self (r x k) @ other (k x c).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.at(k, j);
                }
            }
        }
        out
    }

    /// self (r x k) @ other^T (c x k).
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            for j in 0..other.rows {
                let mut acc = 0.0f32;
                for k in 0..self.cols {
                    acc += self.at(i, k) * other.at(j, k);
                }
                out.data[i * other.rows + j] = acc;
            }
        }
        out
    }
}

/// Numerically-stable softmax over each row, in place.
pub fn softmax_rows(m: &mut Mat) {
    for i in 0..m.rows {
        let row = m.row_mut(i);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Dense softmax(Q K^T / sqrt(d)) V for a single head.
pub fn softmax_attention(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    let d = q.cols as f32;
    let mut scores = q.matmul_t(k);
    for s in scores.data.iter_mut() {
        *s /= d.sqrt();
    }
    softmax_rows(&mut scores);
    scores.matmul(v)
}

/// FNet token mixing: Re(FFT2(x)) over a (seq, hidden) matrix.
pub fn fnet_mixing(x: &Mat) -> Mat {
    let spec = fft2d_real(&x.data, x.rows, x.cols);
    Mat::from_vec(
        x.rows,
        x.cols,
        spec.into_iter().map(|c| c.re as f32).collect(),
    )
}

/// Apply a BPMM linear layer to every row of `x` (square case).
pub fn bpmm_linear(x: &Mat, factors: &BpmmFactors) -> Mat {
    assert_eq!(x.cols, factors.n);
    let mut out = x.clone();
    for i in 0..out.rows {
        factors.apply(out.row_mut(i));
    }
    out
}

/// LayerNorm over rows (eps 1e-5), in place.
pub fn layer_norm_rows(m: &mut Mat) {
    for i in 0..m.rows {
        let row = m.row_mut(i);
        let n = row.len() as f32;
        let mean: f32 = row.iter().sum::<f32>() / n;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(r, c, rng.normal_vec(r * c))
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut m = rand_mat(5, 9, 1);
        softmax_rows(&mut m);
        for i in 0..5 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(i).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn attention_of_identical_tokens_is_average() {
        // If all value rows are equal, attention returns that row.
        let q = rand_mat(4, 8, 2);
        let k = rand_mat(4, 8, 3);
        let mut v = Mat::zeros(4, 8);
        for i in 0..4 {
            for j in 0..8 {
                v.data[i * 8 + j] = j as f32;
            }
        }
        let o = softmax_attention(&q, &k, &v);
        for i in 0..4 {
            for j in 0..8 {
                assert!((o.at(i, j) - j as f32).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn fnet_mixing_of_zero_is_zero() {
        let x = Mat::zeros(8, 16);
        let y = fnet_mixing(&x);
        assert!(y.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn fnet_dc_term() {
        // Mixing output at (0,0) equals the sum of all elements.
        let x = rand_mat(8, 8, 5);
        let y = fnet_mixing(&x);
        let sum: f32 = x.data.iter().sum();
        assert!((y.at(0, 0) - sum).abs() < 1e-2);
    }

    #[test]
    fn bpmm_linear_identity() {
        let x = rand_mat(3, 16, 6);
        let f = BpmmFactors::identity(16);
        let y = bpmm_linear(&x, &f);
        assert_eq!(x.data, y.data);
    }

    #[test]
    fn layer_norm_moments() {
        let mut m = rand_mat(4, 64, 7);
        layer_norm_rows(&mut m);
        for i in 0..4 {
            let row = m.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn matmul_t_matches_matmul() {
        let a = rand_mat(3, 5, 8);
        let b = rand_mat(4, 5, 9);
        // a @ b^T via matmul with explicit transpose.
        let mut bt = Mat::zeros(5, 4);
        for i in 0..4 {
            for j in 0..5 {
                bt.data[j * 4 + i] = b.at(i, j);
            }
        }
        let want = a.matmul(&bt);
        let got = a.matmul_t(&b);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
