//! Butterfly matrices and BPMM (real-valued), matching ref.py layouts.

use crate::util::rng::Rng;

use super::log2_int;

/// Index arrays (i, j) of the `n/2` pairs of a butterfly stage.
pub fn stage_pair_indices(n: usize, stage: usize) -> Vec<(usize, usize)> {
    let stride = 1usize << stage;
    let blocks = n / (2 * stride);
    let mut out = Vec::with_capacity(n / 2);
    for blk in 0..blocks {
        for off in 0..stride {
            let i = blk * 2 * stride + off;
            out.push((i, i + stride));
        }
    }
    out
}

/// A full BPMM factor set: `log2(n)` stages of `(n/2, 4)` weights.
#[derive(Debug, Clone)]
pub struct BpmmFactors {
    pub n: usize,
    /// `stages[s][p*4..p*4+4]` = 2x2 block of pair `p` at stage `s`.
    pub stages: Vec<Vec<f32>>,
}

impl BpmmFactors {
    /// Identity factors (each stage is the identity matrix).
    pub fn identity(n: usize) -> Self {
        let stages = log2_int(n);
        let mut sv = Vec::with_capacity(stages);
        for _ in 0..stages {
            let mut w = vec![0.0f32; n / 2 * 4];
            for p in 0..n / 2 {
                w[p * 4] = 1.0;
                w[p * 4 + 3] = 1.0;
            }
            sv.push(w);
        }
        BpmmFactors { n, stages: sv }
    }

    /// Random factors biased toward identity (well-conditioned product),
    /// mirroring `ref.random_bpmm_factors`.
    pub fn random(n: usize, rng: &mut Rng) -> Self {
        let stages = log2_int(n);
        let mut sv = Vec::with_capacity(stages);
        for _ in 0..stages {
            let mut w = vec![0.0f32; n / 2 * 4];
            for p in 0..n / 2 {
                for k in 0..4 {
                    let ident = if k == 0 || k == 3 { 0.5 } else { 0.0 };
                    w[p * 4 + k] = (rng.normal() * 0.5) as f32 + ident;
                }
            }
            sv.push(w);
        }
        BpmmFactors { n, stages: sv }
    }

    /// Number of stages (log2 n).
    pub fn depth(&self) -> usize {
        self.stages.len()
    }

    /// Non-zero parameter count: 2 n log2 n.
    pub fn param_count(&self) -> usize {
        self.stages.len() * self.n * 2
    }

    /// Apply one stage in place to a single vector.
    pub fn apply_stage(&self, x: &mut [f32], stage: usize) {
        debug_assert_eq!(x.len(), self.n);
        let w = &self.stages[stage];
        for (p, (i, j)) in stage_pair_indices(self.n, stage).into_iter().enumerate() {
            let (a, b) = (x[i], x[j]);
            x[i] = w[p * 4] * a + w[p * 4 + 1] * b;
            x[j] = w[p * 4 + 2] * a + w[p * 4 + 3] * b;
        }
    }

    /// Apply the full BPMM to a single vector in place.
    pub fn apply(&self, x: &mut [f32]) {
        for s in 0..self.stages.len() {
            self.apply_stage(x, s);
        }
    }

    /// Apply to a batch laid out row-major `(batch, n)`.
    pub fn apply_batch(&self, x: &mut [f32]) {
        assert_eq!(x.len() % self.n, 0);
        for row in x.chunks_mut(self.n) {
            self.apply(row);
        }
    }

    /// Materialize one stage as a dense row-major `(n, n)` matrix.
    pub fn stage_dense(&self, stage: usize) -> Vec<f32> {
        let n = self.n;
        let w = &self.stages[stage];
        let mut m = vec![0.0f32; n * n];
        for (p, (i, j)) in stage_pair_indices(n, stage).into_iter().enumerate() {
            m[i * n + i] = w[p * 4];
            m[i * n + j] = w[p * 4 + 1];
            m[j * n + i] = w[p * 4 + 2];
            m[j * n + j] = w[p * 4 + 3];
        }
        m
    }

    /// Materialize the whole product as a dense matrix (tests only).
    pub fn dense(&self) -> Vec<f32> {
        let n = self.n;
        let mut acc = vec![0.0f32; n * n];
        for i in 0..n {
            acc[i * n + i] = 1.0;
        }
        for s in 0..self.stages.len() {
            let b = self.stage_dense(s);
            acc = matmul(&b, &acc, n);
        }
        acc
    }
}

/// Row-major square matmul (test helper).
pub fn matmul(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..n {
                c[i * n + j] += aik * b[k * n + j];
            }
        }
    }
    c
}

/// Dense mat-vec y = M x (row-major).
pub fn matvec(m: &[f32], x: &[f32], n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let row = &m[i * n..(i + 1) * n];
        y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
    }
    y
}

/// Two-stage (Fig. 9 / Monarch-like) BPMM for scales beyond the single-DFG
/// limit: per-column scale-r factor sets, then per-row scale-c sets.
#[derive(Debug, Clone)]
pub struct StagedBpmm {
    pub r: usize,
    pub c: usize,
    pub col: Vec<BpmmFactors>, // len c, each scale r
    pub row: Vec<BpmmFactors>, // len r, each scale c
}

impl StagedBpmm {
    pub fn random(n: usize, division: (usize, usize), rng: &mut Rng) -> Self {
        let (r, c) = division;
        assert_eq!(r * c, n, "division {r}x{c} != {n}");
        StagedBpmm {
            r,
            c,
            col: (0..c).map(|_| BpmmFactors::random(r, rng)).collect(),
            row: (0..r).map(|_| BpmmFactors::random(c, rng)).collect(),
        }
    }

    pub fn n(&self) -> usize {
        self.r * self.c
    }

    /// Apply to a single vector of length r*c (viewed as A[r][c] row-major).
    pub fn apply(&self, x: &mut [f32]) {
        let (r, c) = (self.r, self.c);
        assert_eq!(x.len(), r * c);
        // Column stage.
        let mut colbuf = vec![0.0f32; r];
        for j in 0..c {
            for i in 0..r {
                colbuf[i] = x[i * c + j];
            }
            self.col[j].apply(&mut colbuf);
            for i in 0..r {
                x[i * c + j] = colbuf[i];
            }
        }
        // Row stage.
        for i in 0..r {
            self.row[i].apply(&mut x[i * c..(i + 1) * c]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_factors_are_identity() {
        let f = BpmmFactors::identity(16);
        let mut x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let orig = x.clone();
        f.apply(&mut x);
        assert_eq!(x, orig);
    }

    #[test]
    fn stage_pairs_partition_elements() {
        for n in [4usize, 16, 64] {
            for s in 0..log2_int_local(n) {
                let pairs = stage_pair_indices(n, s);
                let mut seen = vec![false; n];
                for (i, j) in pairs {
                    assert_eq!(j - i, 1 << s);
                    assert!(!seen[i] && !seen[j]);
                    seen[i] = true;
                    seen[j] = true;
                }
                assert!(seen.into_iter().all(|b| b));
            }
        }
    }

    fn log2_int_local(n: usize) -> usize {
        n.trailing_zeros() as usize
    }

    #[test]
    fn apply_matches_dense() {
        let mut rng = Rng::new(3);
        let f = BpmmFactors::random(32, &mut rng);
        let x: Vec<f32> = rng.normal_vec(32);
        let mut got = x.clone();
        f.apply(&mut got);
        let want = matvec(&f.dense(), &x, 32);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
    }

    #[test]
    fn stage_dense_has_two_nnz_per_row() {
        let mut rng = Rng::new(5);
        let f = BpmmFactors::random(16, &mut rng);
        for s in 0..f.depth() {
            let m = f.stage_dense(s);
            for i in 0..16 {
                let nnz = m[i * 16..(i + 1) * 16].iter().filter(|v| **v != 0.0).count();
                assert_eq!(nnz, 2);
            }
        }
    }

    #[test]
    fn param_count_is_nlogn() {
        let f = BpmmFactors::identity(256);
        assert_eq!(f.param_count(), 2 * 256 * 8);
    }

    #[test]
    fn staged_matches_naive_composition() {
        let mut rng = Rng::new(7);
        let st = StagedBpmm::random(64, (8, 8), &mut rng);
        let x = rng.normal_vec(64);
        let mut got = x.clone();
        st.apply(&mut got);
        // Naive: columns then rows via copies.
        let mut a = x.clone();
        for j in 0..8 {
            let mut col: Vec<f32> = (0..8).map(|i| a[i * 8 + j]).collect();
            st.col[j].apply(&mut col);
            for i in 0..8 {
                a[i * 8 + j] = col[i];
            }
        }
        for i in 0..8 {
            st.row[i].apply(&mut a[i * 8..(i + 1) * 8]);
        }
        assert_eq!(got, a);
    }
}
