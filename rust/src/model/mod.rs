//! Exact numeric reference implementations (Rust-side oracle).
//!
//! These mirror `python/compile/kernels/ref.py` with the *same layout
//! conventions* (stage `s` pairs `i` with `i + 2^s`; stage weights are
//! `(n/2, 4)` blocks `[w0 w1; w2 w3]`), so the simulator's functional
//! checks, the runtime's golden tests and the Python oracles all agree.

pub mod attention;
pub mod butterfly;
pub mod fft;

pub use butterfly::{BpmmFactors, StagedBpmm};
pub use fft::Complex;

/// log2 of an exact power of two.
pub fn log2_int(n: usize) -> usize {
    assert!(n.is_power_of_two() && n > 0, "{n} is not a positive power of two");
    n.trailing_zeros() as usize
}
