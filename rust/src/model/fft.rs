//! Radix-2 Cooley-Tukey FFT (reference numerics + the four-step
//! decomposition the Fig. 9 stage division executes).

use super::log2_int;

/// Minimal complex number (the vendor set has no `num-complex`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };

    pub fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }

    pub fn from_polar(r: f64, theta: f64) -> Self {
        Complex { re: r * theta.cos(), im: r * theta.sin() }
    }

    pub fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }

    pub fn sub(self, o: Complex) -> Complex {
        Complex::new(self.re - o.re, self.im - o.im)
    }

    pub fn mul(self, o: Complex) -> Complex {
        Complex::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    pub fn scale(self, s: f64) -> Complex {
        Complex::new(self.re * s, self.im * s)
    }

    pub fn conj(self) -> Complex {
        Complex::new(self.re, -self.im)
    }

    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

/// Bit-reversal permutation: `perm[k] = bitrev(k, log2 n)`.
pub fn bit_reversal_permutation(n: usize) -> Vec<usize> {
    let bits = log2_int(n);
    (0..n)
        .map(|k| {
            let mut r = 0usize;
            for b in 0..bits {
                if k & (1 << b) != 0 {
                    r |= 1 << (bits - 1 - b);
                }
            }
            r
        })
        .collect()
}

/// In-place DIT radix-2 FFT over `x` (length power of two).
pub fn fft_in_place(x: &mut [Complex]) {
    let n = x.len();
    let stages = log2_int(n);
    // Bit-reversal reorder.
    let perm = bit_reversal_permutation(n);
    for k in 0..n {
        if perm[k] > k {
            x.swap(k, perm[k]);
        }
    }
    // Butterfly stages: stage s pairs i with i + 2^s.
    for s in 0..stages {
        let stride = 1usize << s;
        let blocks = n / (2 * stride);
        for blk in 0..blocks {
            for off in 0..stride {
                let i = blk * 2 * stride + off;
                let j = i + stride;
                let w = Complex::from_polar(
                    1.0,
                    -std::f64::consts::PI * off as f64 / stride as f64,
                );
                let wb = w.mul(x[j]);
                let t = x[i];
                x[i] = t.add(wb);
                x[j] = t.sub(wb);
            }
        }
    }
}

/// Forward DFT of a real slice; returns complex spectrum.
pub fn fft_real(x: &[f32]) -> Vec<Complex> {
    let mut buf: Vec<Complex> =
        x.iter().map(|&v| Complex::new(v as f64, 0.0)).collect();
    fft_in_place(&mut buf);
    buf
}

/// Inverse FFT (in place).
pub fn ifft_in_place(x: &mut [Complex]) {
    let n = x.len() as f64;
    for v in x.iter_mut() {
        *v = v.conj();
    }
    fft_in_place(x);
    for v in x.iter_mut() {
        *v = v.conj().scale(1.0 / n);
    }
}

/// Naive O(n^2) DFT (ground truth in tests).
pub fn dft_naive(x: &[Complex]) -> Vec<Complex> {
    let n = x.len();
    (0..n)
        .map(|k| {
            let mut acc = Complex::ZERO;
            for (j, &v) in x.iter().enumerate() {
                let w = Complex::from_polar(
                    1.0,
                    -2.0 * std::f64::consts::PI * (k * j) as f64 / n as f64,
                );
                acc = acc.add(w.mul(v));
            }
            acc
        })
        .collect()
}

/// Four-step Cooley-Tukey FFT with an explicit (n1, n2) division —
/// numerically identical to `fft_in_place` but structured exactly like the
/// paper's Fig. 9 execution (row FFTs, twiddle layer, column FFTs).
///
/// Decomposition (matches `model.fft_staged` in Python):
///   A[a][b] = x[a + n1*b];  Y[a] = FFT_n2(A[a]);  Y[a][k2] *= w_n^(a*k2);
///   Z[:,k2] = FFT_n1(Y[:,k2]);  X[n2*k1 + k2] = Z[k1][k2].
pub fn fft_four_step(x: &[Complex], n1: usize, n2: usize) -> Vec<Complex> {
    let n = x.len();
    assert_eq!(n1 * n2, n, "division {n1}x{n2} != {n}");
    // A[a][b] = x[a + n1*b], row-major (n1, n2).
    let mut a = vec![Complex::ZERO; n];
    for ai in 0..n1 {
        for b in 0..n2 {
            a[ai * n2 + b] = x[ai + n1 * b];
        }
    }
    // Row FFTs (length n2) — the paper's DFG1 iterations.
    for row in a.chunks_mut(n2) {
        fft_in_place(row);
    }
    // Twiddle layer (element-wise, the Fig. 9 step 3).
    for ai in 0..n1 {
        for k2 in 0..n2 {
            let w = Complex::from_polar(
                1.0,
                -2.0 * std::f64::consts::PI * (ai * k2) as f64 / n as f64,
            );
            a[ai * n2 + k2] = a[ai * n2 + k2].mul(w);
        }
    }
    // Column FFTs (length n1) — DFG2.
    let mut col = vec![Complex::ZERO; n1];
    for k2 in 0..n2 {
        for ai in 0..n1 {
            col[ai] = a[ai * n2 + k2];
        }
        fft_in_place(&mut col);
        for k1 in 0..n1 {
            a[k1 * n2 + k2] = col[k1];
        }
    }
    // Row-major flatten is already X[n2*k1 + k2].
    a
}

/// 2D FFT over a (rows, cols) real matrix — FNet mixing spectrum.
pub fn fft2d_real(x: &[f32], rows: usize, cols: usize) -> Vec<Complex> {
    assert_eq!(x.len(), rows * cols);
    let mut buf: Vec<Complex> =
        x.iter().map(|&v| Complex::new(v as f64, 0.0)).collect();
    for row in buf.chunks_mut(cols) {
        fft_in_place(row);
    }
    let mut col = vec![Complex::ZERO; rows];
    for j in 0..cols {
        for i in 0..rows {
            col[i] = buf[i * cols + j];
        }
        fft_in_place(&mut col);
        for i in 0..rows {
            buf[i * cols + j] = col[i];
        }
    }
    buf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_complex(n: usize, seed: u64) -> Vec<Complex> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect()
    }

    fn assert_close(a: &[Complex], b: &[Complex], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!(
                x.sub(*y).abs() < tol,
                "{x:?} vs {y:?} (tol {tol})"
            );
        }
    }

    #[test]
    fn fft_matches_naive_dft() {
        for n in [2usize, 8, 32, 128] {
            let x = rand_complex(n, n as u64);
            let mut got = x.clone();
            fft_in_place(&mut got);
            let want = dft_naive(&x);
            assert_close(&got, &want, 1e-8 * n as f64);
        }
    }

    #[test]
    fn ifft_roundtrip() {
        let x = rand_complex(64, 9);
        let mut y = x.clone();
        fft_in_place(&mut y);
        ifft_in_place(&mut y);
        assert_close(&y, &x, 1e-10);
    }

    #[test]
    fn four_step_matches_direct() {
        for (n1, n2) in [(4usize, 8usize), (8, 8), (16, 4), (2, 64)] {
            let n = n1 * n2;
            let x = rand_complex(n, (n1 * 1000 + n2) as u64);
            let got = fft_four_step(&x, n1, n2);
            let mut want = x.clone();
            fft_in_place(&mut want);
            assert_close(&got, &want, 1e-8 * n as f64);
        }
    }

    #[test]
    fn parseval() {
        let x = rand_complex(128, 11);
        let mut y = x.clone();
        fft_in_place(&mut y);
        let et: f64 = x.iter().map(|c| c.abs() * c.abs()).sum();
        let ef: f64 = y.iter().map(|c| c.abs() * c.abs()).sum::<f64>() / 128.0;
        assert!((et - ef).abs() / et < 1e-10);
    }

    #[test]
    fn dc_bin_is_sum() {
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let spec = fft_real(&x);
        let sum: f64 = x.iter().map(|&v| v as f64).sum();
        assert!((spec[0].re - sum).abs() < 1e-9);
        assert!(spec[0].im.abs() < 1e-9);
    }

    #[test]
    fn fft2d_separable() {
        // FFT2 of an outer product is the outer product of FFTs.
        let r: Vec<f32> = vec![1.0, -2.0, 0.5, 3.0];
        let c: Vec<f32> = vec![2.0, 1.0, -1.0, 0.0, 4.0, -0.5, 1.5, 2.5];
        let mut m = vec![0.0f32; 4 * 8];
        for i in 0..4 {
            for j in 0..8 {
                m[i * 8 + j] = r[i] * c[j];
            }
        }
        let got = fft2d_real(&m, 4, 8);
        let fr = fft_real(&r);
        let fc = fft_real(&c);
        for i in 0..4 {
            for j in 0..8 {
                let want = fr[i].mul(fc[j]);
                assert!(got[i * 8 + j].sub(want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn bit_reversal_involution() {
        for n in [2usize, 16, 256] {
            let p = bit_reversal_permutation(n);
            for k in 0..n {
                assert_eq!(p[p[k]], k);
            }
        }
    }
}
