//! Seeded randomized property-test harness (proptest substitute).
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libstdc++ rpath the xla crate
//! // needs; the same property runs as a unit test below.)
//! use butterfly_dataflow::util::prop::check;
//! check("addition commutes", 200, |rng| {
//!     let (a, b) = (rng.below(1000) as i64, rng.below(1000) as i64);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets an independent, deterministic RNG derived from the
//! property name and the case index, so a failing case is replayable by
//! name+index without shrinking machinery.  On panic the harness reports
//! the case index and reraises.

use super::rng::Rng;

/// Derive a per-case seed from the property name and case index.
fn case_seed(name: &str, case: u64) -> u64 {
    // FNV-1a over the name, mixed with the case index.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Run `f` for `cases` independent seeded cases.  Panics (with the case
/// index in the message) on the first failing case.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u64, f: F) {
    for case in 0..cases {
        let mut rng = Rng::new(case_seed(name, case));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property '{name}' failed at case {case}/{cases}: {msg}");
        }
    }
}

/// Like [`check`] but the closure returns `Result`, for properties that
/// want `?`-style plumbing instead of asserts.
pub fn check_result<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut Rng) -> anyhow::Result<()>,
{
    check(name, cases, |rng| {
        if let Err(e) = f(rng) {
            panic!("{e:#}");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |rng| {
            let v = rng.below(10);
            assert!(v < 10);
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_case() {
        check("always-false", 5, |_| {
            assert!(false, "nope");
        });
    }

    #[test]
    fn case_seeds_differ() {
        assert_ne!(case_seed("a", 0), case_seed("a", 1));
        assert_ne!(case_seed("a", 0), case_seed("b", 0));
    }
}
