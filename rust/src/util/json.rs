//! Minimal JSON: a writer for result dumps and a parser for the artifact
//! metadata emitted by `python/compile/aot.py` (serde is not in the
//! offline vendor set).
//!
//! The parser supports the full JSON grammar minus exotic escapes
//! (`\uXXXX` is decoded for the BMP only), which covers everything the
//! AOT manifest uses.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing JSON key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("JSON key '{key}' is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow!("JSON key '{key}' is not a number"))
    }

    /// Serialize (compact).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Build helpers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(v: f64) -> Json {
    Json::Num(v)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!("expected '{}' at byte {}, got '{}'", b as char, self.pos - 1,
                  got as char);
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        for expected in word.bytes() {
            let got = self.bump()?;
            if got != expected {
                bail!("bad literal near byte {}", self.pos);
            }
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of JSON"))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow!("bad \\u codepoint"))?,
                        );
                    }
                    other => bail!("bad escape '\\{}'", other as char),
                },
                _ => {
                    // Re-decode UTF-8 multibyte sequences faithfully.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            bail!("truncated UTF-8 in string");
                        }
                        let slice = &self.bytes[start..end];
                        out.push_str(
                            std::str::from_utf8(slice)
                                .map_err(|_| anyhow!("invalid UTF-8 in string"))?,
                        );
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| anyhow!("bad number '{text}' at byte {start}"))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                other => bail!("expected ',' or ']', got '{}'", other as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                other => bail!("expected ',' or '}}', got '{}'", other as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"name":"bpmm","shape":[64,256],"mean":-0.5,"ok":true}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req_str("name").unwrap(), "bpmm");
        assert_eq!(v.get("shape").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.req_f64("mean").unwrap(), -0.5);
        let re = parse(&v.render()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_nested_and_ws() {
        let v = parse(" { \"a\" : [ 1 , { \"b\" : null } ] } ").unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nbA""#).unwrap();
        assert_eq!(v, Json::Str("a\nbA".to_string()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123abc").is_err());
        assert!(parse("{} {}").is_err());
    }

    #[test]
    fn scientific_numbers() {
        let v = parse("[1e3, -2.5E-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1000.0));
        assert!((a[1].as_f64().unwrap() + 0.025).abs() < 1e-12);
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v, Json::Str("héllo → 世界".to_string()));
    }

    #[test]
    fn writer_escapes_controls() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(parse(&j.render()).unwrap(), j);
    }
}
