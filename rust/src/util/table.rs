//! Fixed-width text tables for figure/table reports.
//!
//! Every bench target prints the rows the paper's corresponding table or
//! figure reports; this module keeps that output aligned and parseable.

/// A simple aligned text table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for string-literal rows.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with column alignment and a title rule.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row_str(&["a", "1"]).row_str(&["longer", "22"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
        let lines: Vec<&str> = s.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row_str(&["only-one"]);
    }
}
