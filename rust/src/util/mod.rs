//! Self-contained infrastructure.
//!
//! The offline crate set has no clap/serde/criterion/proptest, so this
//! module provides the minimal equivalents the rest of the crate needs:
//! [`cli`] (declarative argument parsing), [`json`] (writer + small
//! parser), [`prop`] (seeded randomized property harness), [`rng`]
//! (xorshift64*), [`stats`] (summary statistics) and [`table`]
//! (fixed-width text tables for the figure/table reports).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
