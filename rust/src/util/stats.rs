//! Summary statistics for bench harnesses and simulator reports.

/// Online summary of a sample set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { values: Vec::new() }
    }

    pub fn from_values(values: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for v in values {
            s.push(v);
        }
        s
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (self.values.len() - 1) as f64;
        var.sqrt()
    }

    /// Percentile by nearest-rank on the sorted sample (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_of_sorted(&sorted, p)
    }

    /// Several percentiles of one sample in a single pass: the sample is
    /// sorted once and every requested point is read off it, so batch
    /// consumers (the serving report asks for p50/p95/p99 of thousands
    /// of latencies) don't pay one sort per point.  Returns values in
    /// the order the points were requested; empty samples yield NaNs
    /// exactly like [`Summary::percentile`].
    pub fn percentiles(&self, points: &[f64]) -> Vec<f64> {
        if self.values.is_empty() {
            return points.iter().map(|_| f64::NAN).collect();
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        points.iter().map(|&p| percentile_of_sorted(&sorted, p)).collect()
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Nearest-rank percentile lookup on an already-sorted sample.  The
/// single implementation both [`Summary::percentile`] and
/// [`Summary::percentiles`] call, so the two can never disagree.
fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Geometric mean of positive values (speedup aggregation).
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let s: f64 = values.iter().map(|v| v.ln()).sum();
    (s / values.len() as f64).exp()
}

/// Format a quantity with an SI suffix (1.2 k, 3.4 M, ...).
pub fn si(v: f64) -> String {
    let (scaled, suffix) = if v.abs() >= 1e12 {
        (v / 1e12, "T")
    } else if v.abs() >= 1e9 {
        (v / 1e9, "G")
    } else if v.abs() >= 1e6 {
        (v / 1e6, "M")
    } else if v.abs() >= 1e3 {
        (v / 1e3, "k")
    } else {
        (v, "")
    };
    format!("{scaled:.2}{suffix}")
}

/// Format seconds human-readably (ns/us/ms/s).
pub fn fmt_time(seconds: f64) -> String {
    if seconds < 1e-6 {
        format!("{:.1}ns", seconds * 1e9)
    } else if seconds < 1e-3 {
        format!("{:.2}us", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.3}ms", seconds * 1e3)
    } else {
        format!("{seconds:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_values([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.len(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.median() - 2.0).abs() <= 1.0);
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let s = Summary::from_values([5.0; 10]);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn percentile_bounds() {
        let s = Summary::from_values((1..=100).map(|i| i as f64));
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn percentile_single_sample_is_that_sample() {
        let s = Summary::from_values([42.0]);
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 42.0, "p{p}");
        }
    }

    #[test]
    fn percentile_with_duplicated_values() {
        // Heavy duplication must not confuse the nearest-rank lookup:
        // the p99 of 99 ones and a single hundred is the outlier.
        let mut vals = vec![1.0; 99];
        vals.push(100.0);
        let s = Summary::from_values(vals);
        assert_eq!(s.percentile(50.0), 1.0);
        assert_eq!(s.percentile(98.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        let all_same = Summary::from_values([7.0; 10]);
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(all_same.percentile(p), 7.0);
        }
    }

    #[test]
    fn percentile_is_insertion_order_invariant() {
        let a = Summary::from_values([5.0, 1.0, 4.0, 2.0, 3.0]);
        let b = Summary::from_values([1.0, 2.0, 3.0, 4.0, 5.0]);
        for p in [0.0, 25.0, 50.0, 75.0, 100.0] {
            assert_eq!(a.percentile(p), b.percentile(p), "p{p}");
        }
        assert_eq!(a.percentile(0.0), 1.0);
        assert_eq!(a.percentile(100.0), 5.0);
    }

    #[test]
    fn percentiles_batch_matches_single_calls() {
        let s = Summary::from_values((1..=1000).rev().map(|i| i as f64));
        let pts = [50.0, 95.0, 99.0, 0.0, 100.0];
        let batch = s.percentiles(&pts);
        assert_eq!(batch.len(), pts.len());
        for (p, v) in pts.iter().zip(&batch) {
            assert_eq!(*v, s.percentile(*p), "p{p}");
        }
        // Empty sample: NaNs, same as the single-point path.
        let empty = Summary::new();
        let nan = empty.percentiles(&[50.0, 99.0]);
        assert_eq!(nan.len(), 2);
        assert!(nan.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(si(1_500.0), "1.50k");
        assert_eq!(si(2.5e9), "2.50G");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(0.002), "2.000ms");
        assert!(fmt_time(3e-9).ends_with("ns"));
    }
}
