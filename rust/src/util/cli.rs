//! Declarative command-line parsing (clap substitute).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, and positional arguments, plus generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// One option specification.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: String,
    pub help: String,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// A subcommand specification.
#[derive(Debug, Clone, Default)]
pub struct Command {
    pub name: String,
    pub about: String,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(String, String)>, // (name, help)
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command { name: name.to_string(), about: about.to_string(), ..Default::default() }
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn req_opt(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    fn usage(&self, prog: &str) -> String {
        let mut out = format!("{} {} — {}\n\nOptions:\n", prog, self.name, self.about);
        for p in &self.positionals {
            out.push_str(&format!("  <{}>  {}\n", p.0, p.1));
        }
        for o in &self.opts {
            let default = match (&o.default, o.is_flag) {
                (_, true) => " (flag)".to_string(),
                (Some(d), _) => format!(" (default: {d})"),
                (None, _) => " (required)".to_string(),
            };
            out.push_str(&format!("  --{}  {}{}\n", o.name, o.help, default));
        }
        out
    }

    /// Parse this command's arguments.
    pub fn parse(&self, prog: &str, args: &[String]) -> Result<Matches> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        let mut positionals: Vec<String> = Vec::new();
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.clone(), false);
            } else if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage(prog));
            }
            if let Some(name) = a.strip_prefix("--") {
                let (key, inline) = match name.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (name.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow!("unknown option --{key}\n\n{}", self.usage(prog)))?;
                if spec.is_flag {
                    if inline.is_some() {
                        bail!("flag --{key} takes no value");
                    }
                    flags.insert(key, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow!("option --{key} needs a value"))?
                        }
                    };
                    values.insert(key, v);
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if !o.is_flag && !values.contains_key(&o.name) {
                bail!("missing required option --{}\n\n{}", o.name, self.usage(prog));
            }
        }
        if positionals.len() > self.positionals.len() {
            bail!("unexpected positional arguments: {positionals:?}");
        }
        Ok(Matches { values, flags, positionals })
    }
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow!("--{name} expects an integer, got '{}'", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|_| anyhow!("--{name} expects a number, got '{}'", self.get(name)))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

/// A multi-command CLI application.
pub struct App {
    pub prog: String,
    pub about: String,
    pub commands: Vec<Command>,
}

impl App {
    pub fn new(prog: &str, about: &str) -> Self {
        App { prog: prog.to_string(), about: about.to_string(), commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nCommands:\n", self.prog, self.about);
        for c in &self.commands {
            out.push_str(&format!("  {:<16} {}\n", c.name, c.about));
        }
        out.push_str("\nRun with '<command> --help' for command options.\n");
        out
    }

    /// Dispatch on argv; returns (command name, matches).
    pub fn parse(&self, argv: &[String]) -> Result<(String, Matches)> {
        let cmd_name = argv
            .first()
            .filter(|a| !a.starts_with('-'))
            .ok_or_else(|| anyhow!("{}", self.usage()))?;
        let cmd = self
            .commands
            .iter()
            .find(|c| &c.name == cmd_name)
            .ok_or_else(|| anyhow!("unknown command '{cmd_name}'\n\n{}", self.usage()))?;
        let m = cmd.parse(&self.prog, &argv[1..])?;
        Ok((cmd_name.clone(), m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let c = Command::new("run", "run a thing")
            .opt("n", "256", "points")
            .flag("verbose", "chatty");
        let m = c.parse("prog", &args(&["--n", "512"])).unwrap();
        assert_eq!(m.get_usize("n").unwrap(), 512);
        assert!(!m.flag("verbose"));
        let m = c.parse("prog", &args(&["--verbose"])).unwrap();
        assert_eq!(m.get_usize("n").unwrap(), 256);
        assert!(m.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let c = Command::new("run", "").opt("scale", "1", "");
        let m = c.parse("prog", &args(&["--scale=8"])).unwrap();
        assert_eq!(m.get_usize("scale").unwrap(), 8);
    }

    #[test]
    fn missing_required() {
        let c = Command::new("run", "").req_opt("input", "path");
        assert!(c.parse("prog", &args(&[])).is_err());
        let m = c.parse("prog", &args(&["--input", "x.txt"])).unwrap();
        assert_eq!(m.get("input"), "x.txt");
    }

    #[test]
    fn unknown_option_errors() {
        let c = Command::new("run", "");
        assert!(c.parse("prog", &args(&["--wat"])).is_err());
    }

    #[test]
    fn app_dispatch() {
        let app = App::new("bfdf", "test app")
            .command(Command::new("sim", "simulate").opt("n", "64", ""))
            .command(Command::new("bench", "benchmark"));
        let (name, m) = app.parse(&args(&["sim", "--n", "128"])).unwrap();
        assert_eq!(name, "sim");
        assert_eq!(m.get_usize("n").unwrap(), 128);
        assert!(app.parse(&args(&["nope"])).is_err());
    }

    #[test]
    fn positionals() {
        let c = Command::new("load", "").positional("path", "artifact");
        let m = c.parse("prog", &args(&["a.hlo.txt"])).unwrap();
        assert_eq!(m.positional(0), Some("a.hlo.txt"));
    }
}
