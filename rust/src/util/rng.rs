//! Deterministic xorshift64* PRNG.
//!
//! Used by the property harness, workload generators and the numeric
//! reference models.  Deliberately tiny and fully reproducible: the same
//! seed yields the same stream on every platform, which keeps simulator
//! runs and test failures replayable.

/// xorshift64* generator (Vigna 2016).  Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a non-zero seed (0 is remapped).
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, bound)`; `bound` must be positive.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Modulo bias is negligible for the bounds used here (<< 2^32).
        self.next_u64() % bound
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32_sym(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Boolean with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential sample with the given `rate` (events per unit time):
    /// the inter-arrival time of a Poisson process, via inverse-CDF
    /// transform of one uniform draw.  Deterministic: a fixed seed
    /// yields a fixed sequence (golden-tested), and because exactly one
    /// uniform is consumed per sample, streams drawn at different rates
    /// from the same seed are time-scaled copies of each other —
    /// the property the serving-simulation rate sweeps rely on for
    /// monotone load curves.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = self.f64();
        // u < 1 by construction, so 1 - u > 0 and ln is finite.
        -(1.0 - u).ln() / rate
    }

    /// Poisson count sample with mean `lambda` (Knuth's product
    /// method; O(lambda) draws, fine for the small per-tick means the
    /// traffic models use).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        let limit = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0f64;
        loop {
            p *= self.f64();
            if p <= limit {
                return k;
            }
            k += 1;
        }
    }

    /// Random power of two in `[lo, hi]` (both powers of two).
    pub fn pow2(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
        let lo_bits = lo.trailing_zeros();
        let hi_bits = hi.trailing_zeros();
        1usize << self.range(lo_bits as usize, hi_bits as usize + 1)
    }

    /// Fill a vector with standard-normal f32 values.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn pow2_in_range() {
        let mut r = Rng::new(11);
        for _ in 0..200 {
            let v = r.pow2(4, 256);
            assert!(v.is_power_of_two() && (4..=256).contains(&v));
        }
    }

    #[test]
    fn exp_golden_sequence() {
        // Golden values: xorshift64* from seed 42, one uniform per
        // sample, -(1-u).ln()/rate at rate 100.  A fixed seed must
        // reproduce this exact sequence on every platform (bit-identical
        // uniforms; the ln is allowed one ulp of libm slack).
        let golden = [
            0.00414130439889302,
            0.015244345197292121,
            0.015613005578164578,
            0.028831652172335145,
            0.014455929936554264,
            0.01806303881790749,
        ];
        let mut r = Rng::new(42);
        for (i, g) in golden.iter().enumerate() {
            let v = r.exp(100.0);
            assert!((v - g).abs() <= 1e-12 * g.max(1.0), "sample {i}: {v} != {g}");
        }
        let mut r = Rng::new(7);
        let golden7 = [
            0.8580848687902343,
            1.3175636267765252,
            0.04679810076569491,
            0.05693577518691387,
        ];
        for (i, g) in golden7.iter().enumerate() {
            let v = r.exp(2.0);
            assert!((v - g).abs() <= 1e-12 * g.max(1.0), "sample {i}: {v} != {g}");
        }
    }

    #[test]
    fn exp_streams_scale_exactly_with_rate() {
        // Same seed at different rates must yield the same uniforms, so
        // samples differ by exactly the rate ratio — the time-scaling
        // property the serve-sim rate sweep depends on.
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..200 {
            let x = a.exp(50.0);
            let y = b.exp(200.0);
            assert!((x - 4.0 * y).abs() <= 1e-15 * x.max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn exp_is_positive_with_sane_mean() {
        let mut r = Rng::new(99);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.exp(4.0)).sum();
        let mean = sum / n as f64;
        assert!(mean > 0.0);
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn poisson_deterministic_and_sane() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let xs: Vec<u64> = (0..500).map(|_| a.poisson(3.0)).collect();
        let ys: Vec<u64> = (0..500).map(|_| b.poisson(3.0)).collect();
        assert_eq!(xs, ys);
        let mean = xs.iter().sum::<u64>() as f64 / xs.len() as f64;
        assert!((mean - 3.0).abs() < 0.3, "mean {mean}");
        let mut r = Rng::new(6);
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
