//! Workload descriptions: declarative [`ModelSpec`] networks and the
//! registered benchmark suites.
//!
//! The unit of execution is a [`KernelSpec`] — one butterfly kernel
//! instance (BPMM linear or FFT attention mixing) with its transform
//! length, vector population and original dense shape.  Networks are
//! described declaratively with [`spec::ModelSpec`]: typed blocks
//! (`Attention { Dense | Bpmm | Fft2d }`, `Ffn { Dense | Bpmm }`)
//! stacked into layers, validated, and lowered to ordered kernels with
//! per-layer provenance.  A compact grammar
//! (`att:fft2d,ffn:bpmm*x4;att:dense,ffn:bpmm*x2`) and a JSON
//! model-file format make arbitrary hybrid butterfly-sparsity networks
//! (§IV) addressable from the CLI — see the [`spec`] module docs.
//!
//! The paper's benchmark families (Table I bottom) are registered in
//! [`SUITES`] as `ModelSpec`-backed [`WorkloadSuite`] entries:
//!
//! * **ViT / BERT attention kernels** (Fig. 2/15/16): BPMM `AT-to_qkv`
//!   and `FFN` linears plus the 2D-FFT `AT-all` pair, across sequence
//!   scales.
//! * **FABNet-Base transformer** (Fig. 17): 2D-FFT attention + BPMM FFN
//!   blocks at sequence scales 128..1K.
//! * **One-layer vanilla transformer** (Table IV): 1K sequence, 1K
//!   hidden, 2D-FFT attention + two BPMM FFN layers, batch-256
//!   streamed.
//!
//! The seed's hand-written kernel enumerations survive as frozen golden
//! fixtures in `rust/tests/modelspec.rs`, which pins every registered
//! suite's `ModelSpec` lowering to them field-for-field.

pub mod platforms;
pub mod spec;

pub use spec::{AttnSparsity, Block, BlockSpec, FfnForm, ModelSpec, NetworkBuilder};

use crate::dfg::graph::KernelKind;

/// One attention kernel instance to run (sparse, on our design) or its
/// dense original (on the GPU baseline).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelSpec {
    /// Display name, e.g. "VIT-AT-to_qkv".
    pub name: String,
    pub kind: KernelKind,
    /// Transform length per vector (hidden size for BPMM; the FFT runs
    /// of `AT-all` are enumerated as separate specs per axis).
    pub points: usize,
    /// Independent vectors: batch × heads × rows.
    pub vectors: usize,
    /// Input/output hidden sizes of the original dense layer (for the
    /// dense-GPU comparison and the Fig. 10 slicing factor).
    pub d_in: usize,
    pub d_out: usize,
    /// Sequence length (drives the GPU cache model working set).
    pub seq: usize,
}

impl KernelSpec {
    /// Dense FLOPs of the original kernel this sparse kernel replaces
    /// (matmul: 2 × rows × d_in × d_out).
    pub fn dense_flops(&self) -> f64 {
        2.0 * self.vectors as f64 * self.d_in as f64 * self.d_out as f64
    }

    /// Sparse butterfly FLOPs (2 ops per MAC slot; see KernelKind).
    pub fn sparse_flops(&self) -> f64 {
        let n = self.points as f64;
        let stages = (self.points as f64).log2();
        let slices = (self.d_in.max(self.d_out) / self.d_in.min(self.d_out)) as f64;
        self.vectors as f64
            * slices
            * (n / 2.0)
            * stages
            * self.kind.ops_per_node() as f64
            * 2.0
    }

    /// Bytes touched per vector on a cache-based machine (input + output
    /// + weights once per vector re-walk).
    pub fn sparse_bytes(&self, elem_bytes: usize) -> f64 {
        let n = self.points as f64;
        let stages = n.log2();
        // Each stage rewrites the whole vector; weights are 2-per-row.
        self.vectors as f64 * (stages + 2.0) * n * elem_bytes as f64
    }
}

/// The paper's model families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelFamily {
    Vit,
    Bert,
    FabNet,
    Vanilla,
}

impl ModelFamily {
    pub fn name(self) -> &'static str {
        match self {
            ModelFamily::Vit => "VIT",
            ModelFamily::Bert => "BERT",
            ModelFamily::FabNet => "FABNet",
            ModelFamily::Vanilla => "Vanilla",
        }
    }
}

/// A named, CLI-addressable workload scenario, backed by a
/// [`ModelSpec`] (see [`WorkloadSuite::model`]).
///
/// Every benchmark family instance of the paper is registered here so
/// the CLI (`bfdf run --workload <name>`), the examples and the benches
/// can all address a scenario by string — see [`SUITES`] /
/// [`find_suite`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkloadSuite {
    /// Registry name, e.g. `"vanilla"`, `"bert-64k"`, `"fabnet-512"`.
    pub name: &'static str,
    pub family: ModelFamily,
    /// Sequence length of the scenario.
    pub seq: usize,
    /// Batch used when the caller does not override it.
    pub default_batch: usize,
}

impl WorkloadSuite {
    /// The suite's declarative network definition.  Lowering it
    /// reproduces the seed kernel enumeration exactly (name, kind,
    /// points, vectors, d_in, d_out, seq) — golden-tested in
    /// `rust/tests/modelspec.rs`.
    pub fn model(&self) -> ModelSpec {
        let att = |sparsity: AttnSparsity| Block::Attention { sparsity };
        let ffn = |expand: usize, contract: bool| Block::Ffn {
            form: FfnForm::Bpmm,
            expand,
            contract,
        };
        let b = NetworkBuilder::new(self.name)
            .seq(self.seq)
            .batch(self.default_batch);
        let built = match self.family {
            ModelFamily::Vit => b
                .hidden(512)
                .named_block(att(AttnSparsity::Bpmm), vec!["VIT-AT-to_qkv".into()])
                .named_block(
                    ffn(4, true),
                    vec!["VIT-FFN-L1".into(), "VIT-FFN-L2".into()],
                )
                .named_block(
                    att(AttnSparsity::Fft2d),
                    vec!["VIT-AT-all-hidden".into(), "VIT-AT-all-seq".into()],
                ),
            ModelFamily::Bert => {
                let sc = scale_name(self.seq);
                b.hidden(1024)
                    .named_block(
                        att(AttnSparsity::Bpmm),
                        vec![format!("BERT-AT-to_qkv-{sc}")],
                    )
                    .named_block(ffn(4, false), vec![format!("BERT-FFN-L1-{sc}")])
                    .named_block(
                        att(AttnSparsity::Fft2d),
                        vec![
                            format!("BERT-AT-all-hidden-{sc}"),
                            format!("BERT-AT-all-seq-{sc}"),
                        ],
                    )
            }
            ModelFamily::FabNet => b
                .hidden(256)
                .named_block(
                    att(AttnSparsity::Fft2d),
                    vec![
                        format!("FABNet-{}-ATT-hidden", self.seq),
                        format!("FABNet-{}-ATT-seq", self.seq),
                    ],
                )
                .named_block(
                    ffn(2, true),
                    vec![
                        format!("FABNet-{}-FFN-L1", self.seq),
                        format!("FABNet-{}-FFN-L2", self.seq),
                    ],
                ),
            ModelFamily::Vanilla => b
                .hidden(1024)
                .named_block(
                    att(AttnSparsity::Fft2d),
                    vec!["Vanilla-ATT-hidden".into(), "Vanilla-ATT-seq".into()],
                )
                .named_block(
                    ffn(2, true),
                    vec!["Vanilla-FFN-L1".into(), "Vanilla-FFN-L2".into()],
                ),
        };
        built
            .build()
            .expect("registry suite models are statically valid")
    }

    /// The suite's kernel enumeration at `batch` (`None` = the suite's
    /// default batch).
    pub fn kernels_at(&self, batch: Option<usize>) -> Vec<KernelSpec> {
        self.model().kernels(batch)
    }

    /// Kernels at the suite's default batch.
    pub fn default_kernels(&self) -> Vec<KernelSpec> {
        self.kernels_at(None)
    }
}

/// The registered workload suites (Table I bottom: ViT/BERT attention
/// kernels, FABNet-Base blocks across Fig. 17's sequence scales, and the
/// Table-IV one-layer vanilla transformer).
pub const SUITES: &[WorkloadSuite] = &[
    WorkloadSuite { name: "vanilla", family: ModelFamily::Vanilla, seq: 1024, default_batch: 256 },
    WorkloadSuite { name: "vit-256", family: ModelFamily::Vit, seq: 256, default_batch: 8 },
    WorkloadSuite { name: "bert-1k", family: ModelFamily::Bert, seq: 1024, default_batch: 1 },
    WorkloadSuite { name: "bert-4k", family: ModelFamily::Bert, seq: 4096, default_batch: 1 },
    WorkloadSuite { name: "bert-16k", family: ModelFamily::Bert, seq: 16 * 1024, default_batch: 1 },
    WorkloadSuite { name: "bert-64k", family: ModelFamily::Bert, seq: 64 * 1024, default_batch: 1 },
    WorkloadSuite { name: "fabnet-128", family: ModelFamily::FabNet, seq: 128, default_batch: 128 },
    WorkloadSuite { name: "fabnet-256", family: ModelFamily::FabNet, seq: 256, default_batch: 128 },
    WorkloadSuite { name: "fabnet-512", family: ModelFamily::FabNet, seq: 512, default_batch: 128 },
    WorkloadSuite { name: "fabnet-1k", family: ModelFamily::FabNet, seq: 1024, default_batch: 128 },
];

/// Look up a registered suite by name (case-insensitive).
pub fn find_suite(name: &str) -> anyhow::Result<&'static WorkloadSuite> {
    SUITES
        .iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown workload '{name}'; registered suites: {}; or pass a \
                 spec string like 'att:fft2d,ffn:bpmm*x2'",
                suite_names().join(", ")
            )
        })
}

/// Resolve a workload key the way `bfdf serve-sim` request classes do:
/// a registered suite name first (case-insensitive, returning the
/// suite's [`ModelSpec`] at its default shape), falling back to the
/// spec grammar (`att:fft2d,ffn:bpmm*x2`, at the builder's default
/// hidden/seq/heads) when the key contains a `:`.  Unknown plain names
/// keep [`find_suite`]'s registry-enumerating error.
pub fn resolve_model(key: &str) -> anyhow::Result<ModelSpec> {
    match find_suite(key) {
        Ok(suite) => Ok(suite.model()),
        Err(e) => {
            if key.contains(':') {
                NetworkBuilder::from_spec(key, key)
                    .and_then(|b| b.build())
                    .map_err(|spec_err| {
                        anyhow::anyhow!("workload spec '{key}' is invalid: {spec_err}")
                    })
            } else {
                Err(e)
            }
        }
    }
}

/// Names of all registered suites, registry order.
pub fn suite_names() -> Vec<&'static str> {
    SUITES.iter().map(|s| s.name).collect()
}

/// Short scale label (512, 1k, 64k ...).
pub fn scale_name(n: usize) -> String {
    if n >= 1024 && n % 1024 == 0 {
        format!("{}k", n / 1024)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vit_kernel_set_shape() {
        let ks = find_suite("vit-256").unwrap().kernels_at(Some(8));
        assert_eq!(ks.len(), 5);
        assert!(ks.iter().any(|k| k.name.contains("to_qkv")));
        assert!(ks.iter().any(|k| k.kind == KernelKind::Fft));
    }

    #[test]
    fn sparse_flops_below_dense() {
        let mut ks = find_suite("vit-256").unwrap().kernels_at(Some(8));
        ks.extend(find_suite("bert-4k").unwrap().kernels_at(Some(1)));
        for k in &ks {
            assert!(
                k.sparse_flops() < k.dense_flops(),
                "{}: sparse {} !< dense {}",
                k.name,
                k.sparse_flops(),
                k.dense_flops()
            );
        }
    }

    #[test]
    fn bert_64k_uses_long_sequence() {
        let ks = find_suite("bert-64k").unwrap().kernels_at(Some(1));
        let at_seq = ks.iter().find(|k| k.name.contains("AT-all-seq")).unwrap();
        assert_eq!(at_seq.points, 64 * 1024);
    }

    #[test]
    fn scale_names() {
        assert_eq!(scale_name(512), "512");
        assert_eq!(scale_name(1024), "1k");
        assert_eq!(scale_name(65536), "64k");
    }

    #[test]
    fn vanilla_matches_table4_shape() {
        let ks = find_suite("vanilla").unwrap().kernels_at(Some(256));
        assert_eq!(ks.len(), 4);
        assert!(ks.iter().all(|k| k.seq == 1024));
    }

    #[test]
    fn suite_registry_resolves_every_name() {
        for suite in SUITES {
            let found = find_suite(suite.name).unwrap();
            assert_eq!(found.name, suite.name);
            let ks = suite.default_kernels();
            assert!(!ks.is_empty(), "{} has no kernels", suite.name);
            // Suites must be addressable case-insensitively.
            assert!(find_suite(&suite.name.to_uppercase()).is_ok());
        }
    }

    #[test]
    fn suite_seq_matches_generated_kernels() {
        // The registry's `seq` is the source of truth: every kernel a
        // suite generates must carry it (mislabeled suites would emit
        // wrong metadata in reports).
        for suite in SUITES {
            for k in suite.default_kernels() {
                assert_eq!(k.seq, suite.seq, "{}: kernel {}", suite.name, k.name);
            }
        }
    }

    #[test]
    fn suite_names_are_unique() {
        let mut names = suite_names();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len());
    }

    #[test]
    fn unknown_suite_error_lists_alternatives() {
        // The message is pinned: it must enumerate the whole registry
        // (every name, registry order) and hint at the spec-string
        // fallback `serve-sim` accepts.
        let err = find_suite("resnet").unwrap_err().to_string();
        let expected = format!(
            "unknown workload 'resnet'; registered suites: {}; or pass a spec \
             string like 'att:fft2d,ffn:bpmm*x2'",
            suite_names().join(", ")
        );
        assert_eq!(err, expected);
        for suite in SUITES {
            assert!(err.contains(suite.name), "missing {} in: {err}", suite.name);
        }
    }

    #[test]
    fn resolve_model_accepts_suites_and_spec_strings() {
        // Suite names resolve to the registry model (case-insensitive).
        let vanilla = resolve_model("VANILLA").unwrap();
        assert_eq!(vanilla.name(), "vanilla");
        assert_eq!(vanilla.spec_string(), find_suite("vanilla").unwrap().model().spec_string());
        // Spec strings resolve through the grammar at default shapes.
        let hybrid = resolve_model("att:fft2d,ffn:bpmm*x2").unwrap();
        assert_eq!(hybrid.spec_string(), "att:fft2d,ffn:bpmm*x2");
        assert_eq!(hybrid.hidden(), 512);
        // Unknown plain names keep the registry-enumerating error.
        let err = resolve_model("resnet").unwrap_err().to_string();
        assert!(err.contains("registered suites") && err.contains("vanilla"), "{err}");
        // Invalid spec strings surface the grammar error, not the
        // registry message.
        let err = resolve_model("att:wat").unwrap_err().to_string();
        assert!(!err.contains("registered suites"), "{err}");
    }

    #[test]
    fn suite_batch_override_scales_vectors() {
        let suite = find_suite("fabnet-256").unwrap();
        let small = suite.kernels_at(Some(1));
        let big = suite.kernels_at(Some(8));
        assert_eq!(small.len(), big.len());
        assert_eq!(small[0].vectors * 8, big[0].vectors);
    }

    #[test]
    fn suite_models_describe_hybrid_structure() {
        // The registry is ModelSpec-backed: suite definitions are
        // inspectable as block structures, not frozen kernel lists.
        let fabnet = find_suite("fabnet-256").unwrap().model();
        assert_eq!(fabnet.spec_string(), "att:fft2d,ffn:bpmm*x2");
        assert_eq!(fabnet.hidden(), 256);
        let bert = find_suite("bert-4k").unwrap().model();
        assert_eq!(bert.spec_string(), "att:bpmm,ffn1:bpmm*x4,att:fft2d");
        let vit = find_suite("vit-256").unwrap().model();
        assert_eq!(vit.spec_string(), "att:bpmm,ffn:bpmm*x4,att:fft2d");
    }
}
