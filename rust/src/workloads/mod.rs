//! The paper's benchmark suite as kernel enumerations.
//!
//! Three benchmark families (Table I bottom):
//!
//! * **ViT / BERT attention kernels** (Fig. 2/15/16): the BPMM-sparse
//!   linear kernels `AT-to_qkv` and `FFN-L1/L2`, and the 2D-FFT-sparse
//!   whole-attention kernel `AT-all`, across sequence scales.
//! * **FABNet-Base transformer** (Fig. 17): 2D-FFT attention + BPMM FFN
//!   blocks at sequence scales 128..1K.
//! * **One-layer vanilla transformer** (Table IV): 1K sequence, 1K
//!   hidden, 2D-FFT attention + two BPMM FFN layers, batch-256 streamed.

pub mod platforms;

use crate::dfg::graph::KernelKind;

/// One attention kernel instance to run (sparse, on our design) or its
/// dense original (on the GPU baseline).
#[derive(Debug, Clone)]
pub struct KernelSpec {
    /// Display name, e.g. "VIT-AT-to_qkv".
    pub name: String,
    pub kind: KernelKind,
    /// Transform length per vector (hidden size for BPMM; the FFT runs
    /// of `AT-all` are enumerated as separate specs per axis).
    pub points: usize,
    /// Independent vectors: batch × heads × rows.
    pub vectors: usize,
    /// Input/output hidden sizes of the original dense layer (for the
    /// dense-GPU comparison and the Fig. 10 slicing factor).
    pub d_in: usize,
    pub d_out: usize,
    /// Sequence length (drives the GPU cache model working set).
    pub seq: usize,
}

impl KernelSpec {
    /// Dense FLOPs of the original kernel this sparse kernel replaces
    /// (matmul: 2 × rows × d_in × d_out).
    pub fn dense_flops(&self) -> f64 {
        2.0 * self.vectors as f64 * self.d_in as f64 * self.d_out as f64
    }

    /// Sparse butterfly FLOPs (2 ops per MAC slot; see KernelKind).
    pub fn sparse_flops(&self) -> f64 {
        let n = self.points as f64;
        let stages = (self.points as f64).log2();
        let slices = (self.d_in.max(self.d_out) / self.d_in.min(self.d_out)) as f64;
        self.vectors as f64
            * slices
            * (n / 2.0)
            * stages
            * self.kind.ops_per_node() as f64
            * 2.0
    }

    /// Bytes touched per vector on a cache-based machine (input + output
    /// + weights once per vector re-walk).
    pub fn sparse_bytes(&self, elem_bytes: usize) -> f64 {
        let n = self.points as f64;
        let stages = n.log2();
        // Each stage rewrites the whole vector; weights are 2-per-row.
        self.vectors as f64 * (stages + 2.0) * n * elem_bytes as f64
    }
}

/// The paper's model families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelFamily {
    Vit,
    Bert,
    FabNet,
    Vanilla,
}

impl ModelFamily {
    pub fn name(self) -> &'static str {
        match self {
            ModelFamily::Vit => "VIT",
            ModelFamily::Bert => "BERT",
            ModelFamily::FabNet => "FABNet",
            ModelFamily::Vanilla => "Vanilla",
        }
    }
}

/// ViT kernels at the paper's scales (Fig. 15a: seq 256, hidden 768-ish;
/// we use the power-of-two 1024/256/512 the butterfly requires).
pub fn vit_kernels(batch: usize) -> Vec<KernelSpec> {
    vit_kernels_seq(batch, 256)
}

/// ViT kernels at an explicit (power-of-two) sequence length — the
/// registry entry's `seq` drives this, so suite metadata and kernels
/// cannot drift apart.
pub fn vit_kernels_seq(batch: usize, seq: usize) -> Vec<KernelSpec> {
    let hidden = 512;
    let mut v = Vec::new();
    // AT-to_qkv: three hidden→hidden BPMM projections folded into one spec
    // (3× vectors).
    v.push(KernelSpec {
        name: "VIT-AT-to_qkv".into(),
        kind: KernelKind::Bpmm,
        points: hidden,
        vectors: 3 * batch * seq,
        d_in: hidden,
        d_out: hidden,
        seq,
    });
    // FFN-L1 (expand 4x) and FFN-L2 (shrink 4x).
    v.push(KernelSpec {
        name: "VIT-FFN-L1".into(),
        kind: KernelKind::Bpmm,
        points: hidden,
        vectors: 4 * batch * seq,
        d_in: hidden,
        d_out: 4 * hidden,
        seq,
    });
    v.push(KernelSpec {
        name: "VIT-FFN-L2".into(),
        kind: KernelKind::Bpmm,
        points: hidden,
        vectors: 4 * batch * seq,
        d_in: 4 * hidden,
        d_out: hidden,
        seq,
    });
    // AT-all: 2D FFT = seq-axis FFTs (hidden of them) + hidden-axis FFTs
    // (seq of them) per batch item; enumerate as one spec per axis.
    v.push(KernelSpec {
        name: "VIT-AT-all-hidden".into(),
        kind: KernelKind::Fft,
        points: hidden,
        vectors: batch * seq,
        d_in: hidden,
        d_out: hidden,
        seq,
    });
    v.push(KernelSpec {
        name: "VIT-AT-all-seq".into(),
        kind: KernelKind::Fft,
        points: seq,
        vectors: batch * hidden,
        d_in: seq,
        d_out: seq,
        seq,
    });
    v
}

/// BERT kernels across the paper's large sequence scales (§VI-F runs up
/// to 64K sequences at 1K hidden).
pub fn bert_kernels(batch: usize, seq: usize) -> Vec<KernelSpec> {
    let hidden = 1024;
    vec![
        KernelSpec {
            name: format!("BERT-AT-to_qkv-{}", scale_name(seq)),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 3 * batch * seq,
            d_in: hidden,
            d_out: hidden,
            seq,
        },
        KernelSpec {
            name: format!("BERT-FFN-L1-{}", scale_name(seq)),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 4 * batch * seq,
            d_in: hidden,
            d_out: 4 * hidden,
            seq,
        },
        KernelSpec {
            name: format!("BERT-AT-all-hidden-{}", scale_name(seq)),
            kind: KernelKind::Fft,
            points: hidden,
            vectors: batch * seq,
            d_in: hidden,
            d_out: hidden,
            seq,
        },
        KernelSpec {
            name: format!("BERT-AT-all-seq-{}", scale_name(seq)),
            kind: KernelKind::Fft,
            points: seq,
            vectors: batch * hidden,
            d_in: seq,
            d_out: seq,
            seq,
        },
    ]
}

/// FABNet-Base block kernels at one sequence scale (Fig. 17): 2D-FFT
/// attention + BPMM FFN (hidden 256, expand 2x per [8]).
pub fn fabnet_kernels(batch: usize, seq: usize) -> Vec<KernelSpec> {
    let hidden = 256;
    vec![
        KernelSpec {
            name: format!("FABNet-{}-ATT-hidden", seq),
            kind: KernelKind::Fft,
            points: hidden,
            vectors: batch * seq,
            d_in: hidden,
            d_out: hidden,
            seq,
        },
        KernelSpec {
            name: format!("FABNet-{}-ATT-seq", seq),
            kind: KernelKind::Fft,
            points: seq,
            vectors: batch * hidden,
            d_in: seq,
            d_out: seq,
            seq,
        },
        KernelSpec {
            name: format!("FABNet-{}-FFN-L1", seq),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 2 * batch * seq,
            d_in: hidden,
            d_out: 2 * hidden,
            seq,
        },
        KernelSpec {
            name: format!("FABNet-{}-FFN-L2", seq),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 2 * batch * seq,
            d_in: 2 * hidden,
            d_out: hidden,
            seq,
        },
    ]
}

/// Table-IV one-layer vanilla transformer: 1K seq, 1K hidden, 2D-FFT
/// attention + two BPMM FFN layers.
pub fn vanilla_kernels(batch: usize) -> Vec<KernelSpec> {
    vanilla_kernels_seq(batch, 1024)
}

/// Vanilla-transformer kernels at an explicit (power-of-two) sequence
/// length, 1K hidden — the registry entry's `seq` drives this.
pub fn vanilla_kernels_seq(batch: usize, seq: usize) -> Vec<KernelSpec> {
    let hidden = 1024;
    vec![
        KernelSpec {
            name: "Vanilla-ATT-hidden".into(),
            kind: KernelKind::Fft,
            points: hidden,
            vectors: batch * seq,
            d_in: hidden,
            d_out: hidden,
            seq,
        },
        KernelSpec {
            name: "Vanilla-ATT-seq".into(),
            kind: KernelKind::Fft,
            points: seq,
            vectors: batch * hidden,
            d_in: seq,
            d_out: seq,
            seq,
        },
        KernelSpec {
            name: "Vanilla-FFN-L1".into(),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 2 * batch * seq,
            d_in: hidden,
            d_out: 2 * hidden,
            seq,
        },
        KernelSpec {
            name: "Vanilla-FFN-L2".into(),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 2 * batch * seq,
            d_in: 2 * hidden,
            d_out: hidden,
            seq,
        },
    ]
}

/// A named, CLI-addressable workload scenario.
///
/// Every benchmark family instance of the paper is registered here so
/// the CLI (`bfdf run --workload <name>`), the examples and the benches
/// can all address a scenario by string — see [`SUITES`] /
/// [`find_suite`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkloadSuite {
    /// Registry name, e.g. `"vanilla"`, `"bert-64k"`, `"fabnet-512"`.
    pub name: &'static str,
    pub family: ModelFamily,
    /// Sequence length of the scenario.
    pub seq: usize,
    /// Batch used when the caller does not override it.
    pub default_batch: usize,
}

impl WorkloadSuite {
    /// The suite's kernel enumeration at `batch` (0 = the suite's
    /// default batch).
    pub fn kernels(&self, batch: usize) -> Vec<KernelSpec> {
        let batch = if batch == 0 { self.default_batch } else { batch };
        match self.family {
            ModelFamily::Vit => vit_kernels_seq(batch, self.seq),
            ModelFamily::Bert => bert_kernels(batch, self.seq),
            ModelFamily::FabNet => fabnet_kernels(batch, self.seq),
            ModelFamily::Vanilla => vanilla_kernels_seq(batch, self.seq),
        }
    }

    /// Kernels at the suite's default batch.
    pub fn default_kernels(&self) -> Vec<KernelSpec> {
        self.kernels(0)
    }
}

/// The registered workload suites (Table I bottom: ViT/BERT attention
/// kernels, FABNet-Base blocks across Fig. 17's sequence scales, and the
/// Table-IV one-layer vanilla transformer).
pub const SUITES: &[WorkloadSuite] = &[
    WorkloadSuite { name: "vanilla", family: ModelFamily::Vanilla, seq: 1024, default_batch: 256 },
    WorkloadSuite { name: "vit-256", family: ModelFamily::Vit, seq: 256, default_batch: 8 },
    WorkloadSuite { name: "bert-1k", family: ModelFamily::Bert, seq: 1024, default_batch: 1 },
    WorkloadSuite { name: "bert-4k", family: ModelFamily::Bert, seq: 4096, default_batch: 1 },
    WorkloadSuite { name: "bert-16k", family: ModelFamily::Bert, seq: 16 * 1024, default_batch: 1 },
    WorkloadSuite { name: "bert-64k", family: ModelFamily::Bert, seq: 64 * 1024, default_batch: 1 },
    WorkloadSuite { name: "fabnet-128", family: ModelFamily::FabNet, seq: 128, default_batch: 128 },
    WorkloadSuite { name: "fabnet-256", family: ModelFamily::FabNet, seq: 256, default_batch: 128 },
    WorkloadSuite { name: "fabnet-512", family: ModelFamily::FabNet, seq: 512, default_batch: 128 },
    WorkloadSuite { name: "fabnet-1k", family: ModelFamily::FabNet, seq: 1024, default_batch: 128 },
];

/// Look up a registered suite by name (case-insensitive).
pub fn find_suite(name: &str) -> anyhow::Result<&'static WorkloadSuite> {
    SUITES
        .iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown workload '{name}'; available: {}",
                suite_names().join(", ")
            )
        })
}

/// Names of all registered suites, registry order.
pub fn suite_names() -> Vec<&'static str> {
    SUITES.iter().map(|s| s.name).collect()
}

/// Short scale label (512, 1k, 64k ...).
pub fn scale_name(n: usize) -> String {
    if n >= 1024 && n % 1024 == 0 {
        format!("{}k", n / 1024)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vit_kernel_set_shape() {
        let ks = vit_kernels(8);
        assert_eq!(ks.len(), 5);
        assert!(ks.iter().any(|k| k.name.contains("to_qkv")));
        assert!(ks.iter().any(|k| k.kind == KernelKind::Fft));
    }

    #[test]
    fn sparse_flops_below_dense() {
        for k in vit_kernels(8).iter().chain(bert_kernels(1, 4096).iter()) {
            assert!(
                k.sparse_flops() < k.dense_flops(),
                "{}: sparse {} !< dense {}",
                k.name,
                k.sparse_flops(),
                k.dense_flops()
            );
        }
    }

    #[test]
    fn bert_64k_uses_long_sequence() {
        let ks = bert_kernels(1, 64 * 1024);
        let at_seq = ks.iter().find(|k| k.name.contains("AT-all-seq")).unwrap();
        assert_eq!(at_seq.points, 64 * 1024);
    }

    #[test]
    fn scale_names() {
        assert_eq!(scale_name(512), "512");
        assert_eq!(scale_name(1024), "1k");
        assert_eq!(scale_name(65536), "64k");
    }

    #[test]
    fn vanilla_matches_table4_shape() {
        let ks = vanilla_kernels(256);
        assert_eq!(ks.len(), 4);
        assert!(ks.iter().all(|k| k.seq == 1024));
    }

    #[test]
    fn suite_registry_resolves_every_name() {
        for suite in SUITES {
            let found = find_suite(suite.name).unwrap();
            assert_eq!(found.name, suite.name);
            let ks = suite.default_kernels();
            assert!(!ks.is_empty(), "{} has no kernels", suite.name);
            // Suites must be addressable case-insensitively.
            assert!(find_suite(&suite.name.to_uppercase()).is_ok());
        }
    }

    #[test]
    fn suite_seq_matches_generated_kernels() {
        // The registry's `seq` is the source of truth: every kernel a
        // suite generates must carry it (mislabeled suites would emit
        // wrong metadata in reports).
        for suite in SUITES {
            for k in suite.default_kernels() {
                assert_eq!(k.seq, suite.seq, "{}: kernel {}", suite.name, k.name);
            }
        }
    }

    #[test]
    fn suite_names_are_unique() {
        let mut names = suite_names();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len());
    }

    #[test]
    fn unknown_suite_error_lists_alternatives() {
        let err = find_suite("resnet").unwrap_err().to_string();
        assert!(err.contains("vanilla") && err.contains("bert-64k"), "{err}");
    }

    #[test]
    fn suite_batch_override_scales_vectors() {
        let suite = find_suite("fabnet-256").unwrap();
        let small = suite.kernels(1);
        let big = suite.kernels(8);
        assert_eq!(small.len(), big.len());
        assert_eq!(small[0].vectors * 8, big[0].vectors);
    }
}
