//! The paper's benchmark suite as kernel enumerations.
//!
//! Three benchmark families (Table I bottom):
//!
//! * **ViT / BERT attention kernels** (Fig. 2/15/16): the BPMM-sparse
//!   linear kernels `AT-to_qkv` and `FFN-L1/L2`, and the 2D-FFT-sparse
//!   whole-attention kernel `AT-all`, across sequence scales.
//! * **FABNet-Base transformer** (Fig. 17): 2D-FFT attention + BPMM FFN
//!   blocks at sequence scales 128..1K.
//! * **One-layer vanilla transformer** (Table IV): 1K sequence, 1K
//!   hidden, 2D-FFT attention + two BPMM FFN layers, batch-256 streamed.

pub mod platforms;

use crate::dfg::graph::KernelKind;

/// One attention kernel instance to run (sparse, on our design) or its
/// dense original (on the GPU baseline).
#[derive(Debug, Clone)]
pub struct KernelSpec {
    /// Display name, e.g. "VIT-AT-to_qkv".
    pub name: String,
    pub kind: KernelKind,
    /// Transform length per vector (hidden size for BPMM; the FFT runs
    /// of `AT-all` are enumerated as separate specs per axis).
    pub points: usize,
    /// Independent vectors: batch × heads × rows.
    pub vectors: usize,
    /// Input/output hidden sizes of the original dense layer (for the
    /// dense-GPU comparison and the Fig. 10 slicing factor).
    pub d_in: usize,
    pub d_out: usize,
    /// Sequence length (drives the GPU cache model working set).
    pub seq: usize,
}

impl KernelSpec {
    /// Dense FLOPs of the original kernel this sparse kernel replaces
    /// (matmul: 2 × rows × d_in × d_out).
    pub fn dense_flops(&self) -> f64 {
        2.0 * self.vectors as f64 * self.d_in as f64 * self.d_out as f64
    }

    /// Sparse butterfly FLOPs (2 ops per MAC slot; see KernelKind).
    pub fn sparse_flops(&self) -> f64 {
        let n = self.points as f64;
        let stages = (self.points as f64).log2();
        let slices = (self.d_in.max(self.d_out) / self.d_in.min(self.d_out)) as f64;
        self.vectors as f64
            * slices
            * (n / 2.0)
            * stages
            * self.kind.ops_per_node() as f64
            * 2.0
    }

    /// Bytes touched per vector on a cache-based machine (input + output
    /// + weights once per vector re-walk).
    pub fn sparse_bytes(&self, elem_bytes: usize) -> f64 {
        let n = self.points as f64;
        let stages = n.log2();
        // Each stage rewrites the whole vector; weights are 2-per-row.
        self.vectors as f64 * (stages + 2.0) * n * elem_bytes as f64
    }
}

/// The paper's model families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelFamily {
    Vit,
    Bert,
    FabNet,
    Vanilla,
}

impl ModelFamily {
    pub fn name(self) -> &'static str {
        match self {
            ModelFamily::Vit => "VIT",
            ModelFamily::Bert => "BERT",
            ModelFamily::FabNet => "FABNet",
            ModelFamily::Vanilla => "Vanilla",
        }
    }
}

/// ViT kernels at the paper's scales (Fig. 15a: seq 256, hidden 768-ish;
/// we use the power-of-two 1024/256/512 the butterfly requires).
pub fn vit_kernels(batch: usize) -> Vec<KernelSpec> {
    let seq = 256;
    let hidden = 512;
    let mut v = Vec::new();
    // AT-to_qkv: three hidden→hidden BPMM projections folded into one spec
    // (3× vectors).
    v.push(KernelSpec {
        name: "VIT-AT-to_qkv".into(),
        kind: KernelKind::Bpmm,
        points: hidden,
        vectors: 3 * batch * seq,
        d_in: hidden,
        d_out: hidden,
        seq,
    });
    // FFN-L1 (expand 4x) and FFN-L2 (shrink 4x).
    v.push(KernelSpec {
        name: "VIT-FFN-L1".into(),
        kind: KernelKind::Bpmm,
        points: hidden,
        vectors: 4 * batch * seq,
        d_in: hidden,
        d_out: 4 * hidden,
        seq,
    });
    v.push(KernelSpec {
        name: "VIT-FFN-L2".into(),
        kind: KernelKind::Bpmm,
        points: hidden,
        vectors: 4 * batch * seq,
        d_in: 4 * hidden,
        d_out: hidden,
        seq,
    });
    // AT-all: 2D FFT = seq-axis FFTs (hidden of them) + hidden-axis FFTs
    // (seq of them) per batch item; enumerate as one spec per axis.
    v.push(KernelSpec {
        name: "VIT-AT-all-hidden".into(),
        kind: KernelKind::Fft,
        points: hidden,
        vectors: batch * seq,
        d_in: hidden,
        d_out: hidden,
        seq,
    });
    v.push(KernelSpec {
        name: "VIT-AT-all-seq".into(),
        kind: KernelKind::Fft,
        points: seq,
        vectors: batch * hidden,
        d_in: seq,
        d_out: seq,
        seq,
    });
    v
}

/// BERT kernels across the paper's large sequence scales (§VI-F runs up
/// to 64K sequences at 1K hidden).
pub fn bert_kernels(batch: usize, seq: usize) -> Vec<KernelSpec> {
    let hidden = 1024;
    vec![
        KernelSpec {
            name: format!("BERT-AT-to_qkv-{}", scale_name(seq)),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 3 * batch * seq,
            d_in: hidden,
            d_out: hidden,
            seq,
        },
        KernelSpec {
            name: format!("BERT-FFN-L1-{}", scale_name(seq)),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 4 * batch * seq,
            d_in: hidden,
            d_out: 4 * hidden,
            seq,
        },
        KernelSpec {
            name: format!("BERT-AT-all-hidden-{}", scale_name(seq)),
            kind: KernelKind::Fft,
            points: hidden,
            vectors: batch * seq,
            d_in: hidden,
            d_out: hidden,
            seq,
        },
        KernelSpec {
            name: format!("BERT-AT-all-seq-{}", scale_name(seq)),
            kind: KernelKind::Fft,
            points: seq,
            vectors: batch * hidden,
            d_in: seq,
            d_out: seq,
            seq,
        },
    ]
}

/// FABNet-Base block kernels at one sequence scale (Fig. 17): 2D-FFT
/// attention + BPMM FFN (hidden 256, expand 2x per [8]).
pub fn fabnet_kernels(batch: usize, seq: usize) -> Vec<KernelSpec> {
    let hidden = 256;
    vec![
        KernelSpec {
            name: format!("FABNet-{}-ATT-hidden", seq),
            kind: KernelKind::Fft,
            points: hidden,
            vectors: batch * seq,
            d_in: hidden,
            d_out: hidden,
            seq,
        },
        KernelSpec {
            name: format!("FABNet-{}-ATT-seq", seq),
            kind: KernelKind::Fft,
            points: seq,
            vectors: batch * hidden,
            d_in: seq,
            d_out: seq,
            seq,
        },
        KernelSpec {
            name: format!("FABNet-{}-FFN-L1", seq),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 2 * batch * seq,
            d_in: hidden,
            d_out: 2 * hidden,
            seq,
        },
        KernelSpec {
            name: format!("FABNet-{}-FFN-L2", seq),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 2 * batch * seq,
            d_in: 2 * hidden,
            d_out: hidden,
            seq,
        },
    ]
}

/// Table-IV one-layer vanilla transformer: 1K seq, 1K hidden, 2D-FFT
/// attention + two BPMM FFN layers.
pub fn vanilla_kernels(batch: usize) -> Vec<KernelSpec> {
    let (seq, hidden) = (1024, 1024);
    vec![
        KernelSpec {
            name: "Vanilla-ATT-hidden".into(),
            kind: KernelKind::Fft,
            points: hidden,
            vectors: batch * seq,
            d_in: hidden,
            d_out: hidden,
            seq,
        },
        KernelSpec {
            name: "Vanilla-ATT-seq".into(),
            kind: KernelKind::Fft,
            points: seq,
            vectors: batch * hidden,
            d_in: seq,
            d_out: seq,
            seq,
        },
        KernelSpec {
            name: "Vanilla-FFN-L1".into(),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 2 * batch * seq,
            d_in: hidden,
            d_out: 2 * hidden,
            seq,
        },
        KernelSpec {
            name: "Vanilla-FFN-L2".into(),
            kind: KernelKind::Bpmm,
            points: hidden,
            vectors: 2 * batch * seq,
            d_in: 2 * hidden,
            d_out: hidden,
            seq,
        },
    ]
}

/// Short scale label (512, 1k, 64k ...).
pub fn scale_name(n: usize) -> String {
    if n >= 1024 && n % 1024 == 0 {
        format!("{}k", n / 1024)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vit_kernel_set_shape() {
        let ks = vit_kernels(8);
        assert_eq!(ks.len(), 5);
        assert!(ks.iter().any(|k| k.name.contains("to_qkv")));
        assert!(ks.iter().any(|k| k.kind == KernelKind::Fft));
    }

    #[test]
    fn sparse_flops_below_dense() {
        for k in vit_kernels(8).iter().chain(bert_kernels(1, 4096).iter()) {
            assert!(
                k.sparse_flops() < k.dense_flops(),
                "{}: sparse {} !< dense {}",
                k.name,
                k.sparse_flops(),
                k.dense_flops()
            );
        }
    }

    #[test]
    fn bert_64k_uses_long_sequence() {
        let ks = bert_kernels(1, 64 * 1024);
        let at_seq = ks.iter().find(|k| k.name.contains("AT-all-seq")).unwrap();
        assert_eq!(at_seq.points, 64 * 1024);
    }

    #[test]
    fn scale_names() {
        assert_eq!(scale_name(512), "512");
        assert_eq!(scale_name(1024), "1k");
        assert_eq!(scale_name(65536), "64k");
    }

    #[test]
    fn vanilla_matches_table4_shape() {
        let ks = vanilla_kernels(256);
        assert_eq!(ks.len(), 4);
        assert!(ks.iter().all(|k| k.seq == 1024));
    }
}
