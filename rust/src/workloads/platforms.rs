//! Comparison platform configurations (Table I).

/// A comparison platform's headline parameters.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: &'static str,
    pub freq_hz: f64,
    /// Peak fp16 FLOPS of the unit used in each comparison.
    pub peak_flops: f64,
    /// Secondary peak (tensor cores on NX), if any.
    pub peak_flops_tensor: Option<f64>,
    pub bandwidth: f64,
    pub technology_nm: u32,
    pub power_w: f64,
    /// L1 / L2 cache sizes (bytes) for the cache model (GPU platforms).
    pub l1_bytes: Option<usize>,
    pub l2_bytes: Option<usize>,
}

/// NVIDIA Jetson Xavier NX (Table I): 1.69 TFLOPS CUDA, 11 TFLOPS tensor,
/// 59.71 GB/s, 15 W.  Volta iGPU: 48 KiB L1 per SM (6 SMs), 512 KiB L2.
pub fn jetson_xavier_nx() -> Platform {
    Platform {
        name: "Jetson Xavier NX",
        freq_hz: 1.1e9,
        peak_flops: 1.69e12,
        peak_flops_tensor: Some(11.0e12),
        bandwidth: 59.71e9,
        technology_nm: 12,
        power_w: 15.0,
        l1_bytes: Some(6 * 48 * 1024),
        l2_bytes: Some(512 * 1024),
    }
}

/// NVIDIA Jetson Nano (Table I): 471.6 GFLOPS fp16, 25.6 GB/s, 10 W.
/// Maxwell iGPU: 64 KiB L1-ish per SM (1 SM pair), 256 KiB L2.
pub fn jetson_nano() -> Platform {
    Platform {
        name: "Jetson Nano",
        freq_hz: 0.921e9,
        peak_flops: 471.6e9,
        peak_flops_tensor: None,
        bandwidth: 25.6e9,
        technology_nm: 20,
        power_w: 10.0,
        l1_bytes: Some(64 * 1024),
        l2_bytes: Some(256 * 1024),
    }
}

/// SOTA butterfly accelerator [8] (FPGA): 204.8 GFLOPS (512 MACs @
/// 200 MHz), 21.3 GB/s, 11.355 W.
pub fn sota_butterfly_accel() -> Platform {
    Platform {
        name: "SOTA Butterfly Acc (FPGA)",
        freq_hz: 200e6,
        peak_flops: 204.8e9,
        peak_flops_tensor: None,
        bandwidth: 21.3e9,
        technology_nm: 28,
        power_w: 11.355,
        l1_bytes: None,
        l2_bytes: None,
    }
}

/// SpAtten (Table IV): ASIC 40 nm, 1 GHz, 128 MACs, 1.06 W.
pub fn spatten() -> Platform {
    Platform {
        name: "SpAtten",
        freq_hz: 1e9,
        peak_flops: 128.0 * 2.0 * 1e9,
        peak_flops_tensor: None,
        bandwidth: 64e9,
        technology_nm: 40,
        power_w: 1.06,
        l1_bytes: None,
        l2_bytes: None,
    }
}

/// DOTA (Table IV): ASIC 22 nm, 0.858 W.
pub fn dota() -> Platform {
    Platform {
        name: "DOTA",
        freq_hz: 1e9,
        peak_flops: 128.0 * 2.0 * 1e9,
        peak_flops_tensor: None,
        bandwidth: 64e9,
        technology_nm: 22,
        power_w: 0.858,
        l1_bytes: None,
        l2_bytes: None,
    }
}

/// Published Table-IV end-to-end numbers quoted for the baselines (the
/// paper itself quotes them from [8]).
#[derive(Debug, Clone)]
pub struct PublishedTable4 {
    pub name: &'static str,
    pub latency_ms: f64,
    pub throughput_pred_s: f64,
    pub power_w: f64,
    pub energy_eff_pred_j: f64,
}

pub fn table4_published() -> Vec<PublishedTable4> {
    vec![
        PublishedTable4 {
            name: "SpAtten",
            latency_ms: 48.8,
            throughput_pred_s: 20.49,
            power_w: 1.06,
            energy_eff_pred_j: 19.33,
        },
        PublishedTable4 {
            name: "DOTA",
            latency_ms: 34.1,
            throughput_pred_s: 29.32,
            power_w: 0.858,
            energy_eff_pred_j: 34.18,
        },
        PublishedTable4 {
            name: "SOTA Acc",
            latency_ms: 2.4,
            throughput_pred_s: 416.66,
            power_w: 11.355,
            energy_eff_pred_j: 36.69,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_parameters() {
        let nx = jetson_xavier_nx();
        assert!((nx.peak_flops - 1.69e12).abs() < 1e9);
        assert_eq!(nx.peak_flops_tensor, Some(11.0e12));
        let nano = jetson_nano();
        assert!((nano.peak_flops - 471.6e9).abs() < 1e6);
        let sota = sota_butterfly_accel();
        assert!((sota.peak_flops - 204.8e9).abs() < 1e6);
        assert!((sota.power_w - 11.355).abs() < 1e-9);
    }

    #[test]
    fn published_table4_rows() {
        let rows = table4_published();
        assert_eq!(rows.len(), 3);
        // Throughput ≈ 1000/latency (batch-1 predictions/s).
        for r in &rows {
            let implied = 1000.0 / r.latency_ms;
            assert!((implied - r.throughput_pred_s).abs() / implied < 0.05, "{}", r.name);
        }
    }
}
