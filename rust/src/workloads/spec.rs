//! Declarative, composable network descriptions (`ModelSpec`).
//!
//! The paper's first contribution is the *hybrid butterfly-sparsity
//! network* (§IV): per layer, attention is computed either densely, with
//! butterfly-sparse BPMM projections, or as 2D-FFT whole-attention
//! mixing, and the FFN is dense or BPMM-sparse — trading accuracy
//! against performance.  The seed repo could only replay four frozen
//! kernel enumerations; this module makes the whole design space
//! addressable:
//!
//! * [`NetworkBuilder`] stacks typed blocks ([`Block::Attention`],
//!   [`Block::Ffn`]) into layers with network-wide hidden/seq/heads/
//!   batch parameters and per-block kernel-name overrides, then
//!   validates shapes (powers of two, expand ratios, FFT scale minima).
//! * [`ModelSpec::lower`] turns a network into ordered
//!   [`LoweredBlock`]s — each carrying its layer index, its grammar
//!   label and either butterfly [`KernelSpec`]s or an analytic
//!   [`DenseCost`] — and [`ModelSpec::kernels`] flattens the sparse
//!   kernels for suite-compatible consumers.
//! * A compact spec grammar (see below) and a JSON model-file format
//!   make arbitrary hybrids addressable from the CLI without
//!   recompiling.
//!
//! # Spec grammar
//!
//! ```text
//! network := group (';' group)*
//! group   := [INT '*'] block (',' block)*         -- repeat prefix = depth
//! block   := 'att:'  ('dense' | 'bpmm' | 'fft2d')
//!          | 'ffn:'  ('dense' | 'bpmm') ['*x' INT]  -- expand+contract pair
//!          | 'ffn1:' ('dense' | 'bpmm') ['*x' INT]  -- expand layer only
//! ```
//!
//! `att:fft2d,ffn:bpmm*x4;att:dense,ffn:bpmm*x2` is a two-layer hybrid:
//! FFT attention with a 4x BPMM FFN, then dense attention with a 2x
//! BPMM FFN.  [`ModelSpec::spec_string`] renders the canonical form and
//! round-trips through [`parse_spec_layers`].
//!
//! # Validation guarantees
//!
//! `build()` rejects networks whose "sparse" blocks would not actually
//! save work: 2D-FFT attention needs `hidden >= 32` and `seq >= 32`
//! (below that the complex butterfly chain costs more FLOPs than dense
//! mixing), and every valid BPMM block satisfies
//! `sparse_flops < dense_flops` by construction — a property test in
//! `rust/tests/modelspec.rs` holds the module to this.

use anyhow::{bail, ensure, Result};

use crate::dfg::graph::KernelKind;
use crate::util::json::Json;

use super::KernelSpec;

/// Per-layer attention computation choice (§IV design space).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnSparsity {
    /// Exact softmax(QK^T)V with dense projections — the accuracy
    /// anchor; costed analytically, not run on the butterfly array.
    Dense,
    /// Butterfly-sparse BPMM QKV projections (the `AT-to_qkv` kernel).
    /// The attention core (scores, softmax, AV) and the output
    /// projection stay dense and are priced analytically alongside the
    /// kernel, so network totals are comparable with [`Self::Dense`].
    Bpmm,
    /// 2D-FFT whole-attention mixing (the `AT-all` kernel pair).
    Fft2d,
}

impl AttnSparsity {
    pub fn token(self) -> &'static str {
        match self {
            AttnSparsity::Dense => "dense",
            AttnSparsity::Bpmm => "bpmm",
            AttnSparsity::Fft2d => "fft2d",
        }
    }
}

/// FFN linear-layer form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfnForm {
    /// Dense matmuls — costed analytically.
    Dense,
    /// Butterfly-sparse BPMM layers.
    Bpmm,
}

impl FfnForm {
    pub fn token(self) -> &'static str {
        match self {
            FfnForm::Dense => "dense",
            FfnForm::Bpmm => "bpmm",
        }
    }
}

/// One typed block of a network layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Block {
    /// Attention with a per-layer sparsity choice.
    Attention { sparsity: AttnSparsity },
    /// Feed-forward pair: expand to `expand * hidden`, and (unless
    /// `contract` is off, the paper's FFN-L1 benchmark slice) contract
    /// back to `hidden`.
    Ffn { form: FfnForm, expand: usize, contract: bool },
}

impl Block {
    /// Canonical grammar token, e.g. `att:fft2d` or `ffn:bpmm*x4`.
    pub fn token(&self) -> String {
        match *self {
            Block::Attention { sparsity } => format!("att:{}", sparsity.token()),
            Block::Ffn { form, expand, contract } => {
                let key = if contract { "ffn" } else { "ffn1" };
                format!("{key}:{}*x{expand}", form.token())
            }
        }
    }

    /// Butterfly kernels this block lowers to (0 for dense blocks).
    pub fn kernel_count(&self) -> usize {
        match *self {
            Block::Attention { sparsity: AttnSparsity::Dense } => 0,
            Block::Attention { sparsity: AttnSparsity::Bpmm } => 1,
            Block::Attention { sparsity: AttnSparsity::Fft2d } => 2,
            Block::Ffn { form: FfnForm::Dense, .. } => 0,
            Block::Ffn { form: FfnForm::Bpmm, contract, .. } => {
                if contract {
                    2
                } else {
                    1
                }
            }
        }
    }
}

/// A block plus its optional kernel-name overrides (how the registry
/// suites reproduce the seed enumeration names exactly).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockSpec {
    pub block: Block,
    /// Explicit kernel names; empty = derive `{net}-L{layer}-{role}`
    /// names.  Length must be `kernel_count()` (or 1 for dense blocks).
    pub names: Vec<String>,
}

impl BlockSpec {
    pub fn new(block: Block) -> Self {
        BlockSpec { block, names: Vec::new() }
    }
}

/// Analytic cost of a dense block (the accuracy anchor of a hybrid
/// network).  Dense layers do not lower to butterfly kernels; the
/// coordinator prices them with a first-order roofline over the array's
/// peak MACs and DDR bandwidth (`coordinator::network`).
#[derive(Debug, Clone, PartialEq)]
pub struct DenseCost {
    pub name: String,
    /// Dense FLOPs of the block at the lowered batch.
    pub flops: f64,
    /// Scalar elements touched (weights + activations + score matrix);
    /// multiply by the architecture's element size for bytes.
    pub elems: f64,
}

/// One lowered block: layer provenance plus either butterfly kernels or
/// an analytic dense cost.
#[derive(Debug, Clone, PartialEq)]
pub struct LoweredBlock {
    /// 0-based layer index within the network.
    pub layer: usize,
    /// Canonical grammar token of the originating block.
    pub label: String,
    /// Butterfly kernels (empty for dense blocks).
    pub kernels: Vec<KernelSpec>,
    /// Analytic cost (dense blocks only).
    pub dense: Option<DenseCost>,
}

/// A validated, immutable network description.
///
/// Construct through [`NetworkBuilder`] (or [`ModelSpec::from_json`] /
/// the spec grammar); fields are private so every instance in the
/// program has passed validation.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    name: String,
    hidden: usize,
    seq: usize,
    heads: usize,
    default_batch: usize,
    layers: Vec<Vec<BlockSpec>>,
}

impl ModelSpec {
    pub fn builder(name: &str) -> NetworkBuilder {
        NetworkBuilder::new(name)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn default_batch(&self) -> usize {
        self.default_batch
    }

    /// Number of layers.
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    pub fn layers(&self) -> &[Vec<BlockSpec>] {
        &self.layers
    }

    /// Canonical spec-grammar rendering (drops name overrides).
    pub fn spec_string(&self) -> String {
        format_spec_layers(&self.layers)
    }

    /// Lower the network at `batch` (`None` = the model's default) into
    /// ordered blocks with per-layer provenance.
    ///
    /// # Panics
    ///
    /// Panics on an explicit `Some(0)` — batch 0 is a caller bug, not a
    /// silent default (the CLI and [`run_network`] reject it with a
    /// descriptive error first).
    ///
    /// [`run_network`]: crate::coordinator::Session::run_network
    pub fn lower(&self, batch: Option<usize>) -> Vec<LoweredBlock> {
        let batch = batch.unwrap_or(self.default_batch);
        assert!(batch >= 1, "lowering batch must be >= 1 (got 0)");
        let mut out = Vec::new();
        for (layer, blocks) in self.layers.iter().enumerate() {
            for bs in blocks {
                out.push(self.lower_block(layer, bs, batch));
            }
        }
        out
    }

    /// Flattened butterfly kernels of the network (dense blocks carry
    /// no kernels) — the suite-compatible view.
    pub fn kernels(&self, batch: Option<usize>) -> Vec<KernelSpec> {
        self.lower(batch)
            .into_iter()
            .flat_map(|b| b.kernels)
            .collect()
    }

    fn lower_block(&self, layer: usize, bs: &BlockSpec, batch: usize) -> LoweredBlock {
        let h = self.hidden;
        let s = self.seq;
        let prefix = format!("{}-L{layer}", self.name);
        let name = |idx: usize, fallback: String| -> String {
            bs.names.get(idx).cloned().unwrap_or(fallback)
        };
        let (b, hf, sf) = (batch as f64, h as f64, s as f64);
        let mut kernels = Vec::new();
        let mut dense = None;
        match bs.block {
            Block::Attention { sparsity: AttnSparsity::Bpmm } => {
                kernels.push(KernelSpec {
                    name: name(0, format!("{prefix}-AT-to_qkv")),
                    kind: KernelKind::Bpmm,
                    points: h,
                    vectors: 3 * batch * s,
                    d_in: h,
                    d_out: h,
                    seq: s,
                });
                // The BPMM kernel replaces only the QKV projections (the
                // paper's AT-to_qkv benchmark slice).  The attention core
                // — QK^T scores, softmax, AV — and the output projection
                // still run densely; price them so whole-network totals
                // stay comparable with `att:dense` instead of silently
                // dropping O(b·s²·h) work.
                let heads = self.heads as f64;
                let flops = 2.0 * b * sf * hf * hf
                    + 2.0 * 2.0 * b * sf * sf * hf
                    + 10.0 * b * heads * sf * sf;
                let elems = hf * hf + 2.0 * b * sf * hf + b * heads * sf * sf;
                dense = Some(DenseCost {
                    name: format!("{prefix}-AT-core"),
                    flops,
                    elems,
                });
            }
            Block::Attention { sparsity: AttnSparsity::Fft2d } => {
                kernels.push(KernelSpec {
                    name: name(0, format!("{prefix}-AT-all-hidden")),
                    kind: KernelKind::Fft,
                    points: h,
                    vectors: batch * s,
                    d_in: h,
                    d_out: h,
                    seq: s,
                });
                kernels.push(KernelSpec {
                    name: name(1, format!("{prefix}-AT-all-seq")),
                    kind: KernelKind::Fft,
                    points: s,
                    vectors: batch * h,
                    d_in: s,
                    d_out: s,
                    seq: s,
                });
            }
            Block::Attention { sparsity: AttnSparsity::Dense } => {
                // QKV + output projections, QK^T + AV matmuls, and a
                // softmax pass over the per-head score matrix.
                let heads = self.heads as f64;
                let flops = 2.0 * 4.0 * b * sf * hf * hf
                    + 2.0 * 2.0 * b * sf * sf * hf
                    + 10.0 * b * heads * sf * sf;
                let elems = 4.0 * hf * hf + 2.0 * b * sf * hf + b * heads * sf * sf;
                dense = Some(DenseCost {
                    name: name(0, format!("{prefix}-AT-dense")),
                    flops,
                    elems,
                });
            }
            Block::Ffn { form: FfnForm::Bpmm, expand, contract } => {
                kernels.push(KernelSpec {
                    name: name(0, format!("{prefix}-FFN-L1")),
                    kind: KernelKind::Bpmm,
                    points: h,
                    vectors: expand * batch * s,
                    d_in: h,
                    d_out: expand * h,
                    seq: s,
                });
                if contract {
                    kernels.push(KernelSpec {
                        name: name(1, format!("{prefix}-FFN-L2")),
                        kind: KernelKind::Bpmm,
                        points: h,
                        vectors: expand * batch * s,
                        d_in: expand * h,
                        d_out: h,
                        seq: s,
                    });
                }
            }
            Block::Ffn { form: FfnForm::Dense, expand, contract } => {
                let e = expand as f64;
                let pair = if contract { 2.0 } else { 1.0 };
                let flops = 2.0 * b * sf * hf * (e * hf) * pair;
                let elems = hf * e * hf * pair
                    + b * sf * (hf + e * hf + if contract { hf } else { 0.0 });
                dense = Some(DenseCost {
                    name: name(0, format!("{prefix}-FFN-dense")),
                    flops,
                    elems,
                });
            }
        }
        LoweredBlock { layer, label: bs.block.token(), kernels, dense }
    }

    /// Parse a JSON model file.  Two equivalent layer encodings:
    ///
    /// ```json
    /// { "name": "hybrid", "hidden": 512, "seq": 256,
    ///   "heads": 4, "batch": 8,
    ///   "spec": "att:fft2d,ffn:bpmm*x4;att:dense,ffn:bpmm*x2" }
    /// ```
    ///
    /// or structured:
    ///
    /// ```json
    /// { "name": "hybrid", "hidden": 512, "seq": 256,
    ///   "layers": [
    ///     { "repeat": 2,
    ///       "blocks": [ { "att": "fft2d" },
    ///                   { "ffn": "bpmm", "expand": 4 } ] },
    ///     { "blocks": [ { "att": "dense" },
    ///                   { "ffn": "bpmm", "expand": 2,
    ///                     "contract": false } ] } ] }
    /// ```
    pub fn from_json(v: &Json) -> Result<ModelSpec> {
        let name = v.req_str("name")?;
        let hidden = v.req_f64("hidden")? as usize;
        let seq = v.req_f64("seq")? as usize;
        let heads = v.get("heads").and_then(Json::as_usize).unwrap_or(1);
        let batch = v.get("batch").and_then(Json::as_usize).unwrap_or(1);
        let mut b = NetworkBuilder::new(name)
            .hidden(hidden)
            .seq(seq)
            .heads(heads)
            .batch(batch);
        match (v.get("spec"), v.get("layers")) {
            (Some(_), Some(_)) => {
                bail!("model file must use either \"spec\" or \"layers\", not both")
            }
            (Some(spec), None) => {
                let spec = spec
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("\"spec\" must be a string"))?;
                b.layers = parse_spec_layers(spec)?;
            }
            (None, Some(layers)) => {
                b.layers = parse_json_layers(layers)?;
            }
            (None, None) => bail!("model file needs a \"spec\" string or a \"layers\" array"),
        }
        b.build()
    }

    /// Parse a JSON model-file document from text.
    pub fn from_json_str(text: &str) -> Result<ModelSpec> {
        let v = crate::util::json::parse(text)?;
        Self::from_json(&v)
    }
}

/// Builder for [`ModelSpec`]: stack blocks, close layers, replicate for
/// depth, then `build()` to validate.
///
/// ```no_run
/// // (no_run: doctest binaries miss the libstdc++ rpath — see util::prop;
/// // the same flow runs as unit tests below.)
/// use butterfly_dataflow::workloads::spec::{AttnSparsity, FfnForm, ModelSpec};
///
/// let net = ModelSpec::builder("hybrid")
///     .hidden(512)
///     .seq(256)
///     .batch(8)
///     .attention(AttnSparsity::Fft2d)
///     .ffn(FfnForm::Bpmm, 4)
///     .next_layer()
///     .attention(AttnSparsity::Bpmm)
///     .ffn(FfnForm::Bpmm, 2)
///     .build()
///     .unwrap();
/// assert_eq!(net.depth(), 2);
/// assert_eq!(net.spec_string(), "att:fft2d,ffn:bpmm*x4;att:bpmm,ffn:bpmm*x2");
/// ```
#[derive(Debug, Clone)]
pub struct NetworkBuilder {
    name: String,
    hidden: usize,
    seq: usize,
    heads: usize,
    batch: usize,
    layers: Vec<Vec<BlockSpec>>,
    current: Vec<BlockSpec>,
}

impl NetworkBuilder {
    pub fn new(name: &str) -> Self {
        NetworkBuilder {
            name: name.to_string(),
            hidden: 512,
            seq: 256,
            heads: 1,
            batch: 1,
            layers: Vec::new(),
            current: Vec::new(),
        }
    }

    /// Preload layers from a spec-grammar string (shapes still come
    /// from the builder's `hidden`/`seq`/`heads`/`batch`).
    pub fn from_spec(name: &str, spec: &str) -> Result<Self> {
        let mut b = NetworkBuilder::new(name);
        b.layers = parse_spec_layers(spec)?;
        Ok(b)
    }

    pub fn hidden(mut self, hidden: usize) -> Self {
        self.hidden = hidden;
        self
    }

    pub fn seq(mut self, seq: usize) -> Self {
        self.seq = seq;
        self
    }

    pub fn heads(mut self, heads: usize) -> Self {
        self.heads = heads;
        self
    }

    /// Default batch used when the caller does not override it at
    /// lowering/run time.
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Append an attention block to the current layer.
    pub fn attention(self, sparsity: AttnSparsity) -> Self {
        self.block(Block::Attention { sparsity })
    }

    /// Append an expand+contract FFN block to the current layer.
    pub fn ffn(self, form: FfnForm, expand: usize) -> Self {
        self.block(Block::Ffn { form, expand, contract: true })
    }

    /// Append an expand-only FFN block (the paper's FFN-L1 benchmark
    /// slice) to the current layer.
    pub fn ffn_expand_only(self, form: FfnForm, expand: usize) -> Self {
        self.block(Block::Ffn { form, expand, contract: false })
    }

    /// Append a block to the current layer.
    pub fn block(mut self, block: Block) -> Self {
        self.current.push(BlockSpec::new(block));
        self
    }

    /// Append a block with explicit kernel names (registry-suite
    /// compatibility; length checked at `build()`).
    pub fn named_block(mut self, block: Block, names: Vec<String>) -> Self {
        self.current.push(BlockSpec { block, names });
        self
    }

    /// Close the current layer and start the next one.
    pub fn next_layer(mut self) -> Self {
        if !self.current.is_empty() {
            self.layers.push(std::mem::take(&mut self.current));
        }
        self
    }

    /// Close the current layer, then replicate the whole layer stack
    /// `depth` times: a stack of N defined layers becomes
    /// `depth.max(1) × N` layers (so on a single-layer stack,
    /// `repeat(d)` yields a d-layer network).
    pub fn repeat(mut self, depth: usize) -> Self {
        self = self.next_layer();
        let base = self.layers.clone();
        while self.layers.len() < depth.max(1) * base.len().max(1) && !base.is_empty() {
            let i = self.layers.len() % base.len();
            self.layers.push(base[i].clone());
        }
        self
    }

    /// Validate and freeze into a [`ModelSpec`].
    pub fn build(mut self) -> Result<ModelSpec> {
        if !self.current.is_empty() {
            self.layers.push(std::mem::take(&mut self.current));
        }
        let spec = ModelSpec {
            name: self.name,
            hidden: self.hidden,
            seq: self.seq,
            heads: self.heads,
            default_batch: self.batch,
            layers: self.layers,
        };
        validate(&spec)?;
        Ok(spec)
    }
}

fn validate(m: &ModelSpec) -> Result<()> {
    ensure!(!m.name.is_empty(), "network needs a non-empty name");
    ensure!(
        m.hidden.is_power_of_two() && m.hidden >= 8,
        "hidden size must be a power of two >= 8 (got {})",
        m.hidden
    );
    ensure!(
        m.seq.is_power_of_two() && m.seq >= 8,
        "sequence length must be a power of two >= 8 (got {})",
        m.seq
    );
    ensure!(
        m.heads >= 1 && m.hidden % m.heads == 0,
        "heads ({}) must divide hidden ({})",
        m.heads,
        m.hidden
    );
    ensure!(m.default_batch >= 1, "default batch must be >= 1");
    ensure!(!m.layers.is_empty(), "network needs at least one layer");
    for (li, layer) in m.layers.iter().enumerate() {
        ensure!(!layer.is_empty(), "layer {li} has no blocks");
        for bs in layer {
            match bs.block {
                Block::Attention { sparsity: AttnSparsity::Fft2d } => {
                    // Below 32 points the complex FFT butterfly chain
                    // (10 ops/node) costs more FLOPs than dense mixing;
                    // the sparse_flops < dense_flops property would
                    // break, so such networks are rejected outright.
                    ensure!(
                        m.hidden >= 32 && m.seq >= 32,
                        "layer {li}: fft2d attention needs hidden >= 32 and seq >= 32 \
                         (got hidden {}, seq {})",
                        m.hidden,
                        m.seq
                    );
                }
                Block::Ffn { expand, .. } => {
                    ensure!(
                        expand >= 1 && expand.is_power_of_two(),
                        "layer {li}: ffn expand ratio must be a power of two >= 1 (got {expand})"
                    );
                }
                Block::Attention { .. } => {}
            }
            let want = bs.block.kernel_count().max(1);
            ensure!(
                bs.names.is_empty() || bs.names.len() == want,
                "layer {li}: block {} takes {} name override(s), got {}",
                bs.block.token(),
                want,
                bs.names.len()
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Spec grammar
// ---------------------------------------------------------------------------

/// Parse the layer structure of a spec string (see the module docs for
/// the grammar).
pub fn parse_spec_layers(spec: &str) -> Result<Vec<Vec<BlockSpec>>> {
    let mut layers = Vec::new();
    for group in spec.split(';') {
        let group = group.trim();
        ensure!(!group.is_empty(), "empty layer group in spec '{spec}'");
        let (repeat, body) = match group.split_once('*') {
            Some((n, rest)) if !n.is_empty() && n.bytes().all(|b| b.is_ascii_digit()) => {
                (n.parse::<usize>()?, rest)
            }
            _ => (1, group),
        };
        ensure!(repeat >= 1, "layer repeat count must be >= 1 in '{group}'");
        let mut blocks = Vec::new();
        for token in body.split(',') {
            blocks.push(BlockSpec::new(parse_block(token.trim())?));
        }
        for _ in 0..repeat {
            layers.push(blocks.clone());
        }
    }
    Ok(layers)
}

fn parse_block(token: &str) -> Result<Block> {
    let (key, val) = token
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("block '{token}' is not 'att:...' or 'ffn:...'"))?;
    match key {
        "att" => {
            let sparsity = match val {
                "dense" => AttnSparsity::Dense,
                "bpmm" => AttnSparsity::Bpmm,
                "fft2d" => AttnSparsity::Fft2d,
                other => bail!("unknown attention sparsity '{other}' (dense | bpmm | fft2d)"),
            };
            Ok(Block::Attention { sparsity })
        }
        "ffn" | "ffn1" => {
            let (form_s, expand) = match val.split_once("*x") {
                Some((f, e)) => {
                    let expand: usize = e
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad expand ratio in '{token}'"))?;
                    (f, expand)
                }
                None => (val, 4),
            };
            let form = match form_s {
                "dense" => FfnForm::Dense,
                "bpmm" => FfnForm::Bpmm,
                other => bail!("unknown ffn form '{other}' (dense | bpmm)"),
            };
            Ok(Block::Ffn { form, expand, contract: key == "ffn" })
        }
        other => bail!("unknown block kind '{other}' in '{token}' (att | ffn | ffn1)"),
    }
}

/// Render layers in canonical grammar form (no repeat compression, no
/// name overrides).
pub fn format_spec_layers(layers: &[Vec<BlockSpec>]) -> String {
    layers
        .iter()
        .map(|blocks| {
            blocks
                .iter()
                .map(|b| b.block.token())
                .collect::<Vec<_>>()
                .join(",")
        })
        .collect::<Vec<_>>()
        .join(";")
}

fn parse_json_layers(layers: &Json) -> Result<Vec<Vec<BlockSpec>>> {
    let items = layers
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("\"layers\" must be an array"))?;
    let mut out = Vec::new();
    for item in items {
        let repeat = item.get("repeat").and_then(Json::as_usize).unwrap_or(1);
        ensure!(repeat >= 1, "layer \"repeat\" must be >= 1");
        let blocks_v = item
            .req("blocks")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("layer \"blocks\" must be an array"))?;
        let mut blocks = Vec::new();
        for bv in blocks_v {
            blocks.push(parse_json_block(bv)?);
        }
        ensure!(!blocks.is_empty(), "layer with empty \"blocks\" array");
        for _ in 0..repeat {
            out.push(blocks.clone());
        }
    }
    Ok(out)
}

fn parse_json_block(v: &Json) -> Result<BlockSpec> {
    let names = match v.get("names") {
        Some(ns) => ns
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("block \"names\" must be an array"))?
            .iter()
            .map(|n| {
                n.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("block \"names\" entries must be strings"))
            })
            .collect::<Result<Vec<_>>>()?,
        None => Vec::new(),
    };
    let block = match (v.get("att"), v.get("ffn")) {
        (Some(att), None) => {
            let tok = att
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("\"att\" must be a sparsity string"))?;
            parse_block(&format!("att:{tok}"))?
        }
        (None, Some(ffn)) => {
            let form = ffn
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("\"ffn\" must be a form string"))?;
            let expand = v.get("expand").and_then(Json::as_usize).unwrap_or(4);
            let contract = !matches!(v.get("contract"), Some(Json::Bool(false)));
            let key = if contract { "ffn" } else { "ffn1" };
            parse_block(&format!("{key}:{form}*x{expand}"))?
        }
        _ => bail!("each block needs exactly one of \"att\" or \"ffn\""),
    };
    Ok(BlockSpec { block, names })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hybrid() -> ModelSpec {
        ModelSpec::builder("h")
            .hidden(512)
            .seq(256)
            .batch(4)
            .attention(AttnSparsity::Fft2d)
            .ffn(FfnForm::Bpmm, 4)
            .next_layer()
            .attention(AttnSparsity::Dense)
            .ffn(FfnForm::Bpmm, 2)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_produces_ordered_lowering() {
        let m = hybrid();
        let lowered = m.lower(None);
        assert_eq!(lowered.len(), 4);
        assert_eq!(lowered[0].layer, 0);
        assert_eq!(lowered[0].label, "att:fft2d");
        assert_eq!(lowered[0].kernels.len(), 2);
        assert_eq!(lowered[2].layer, 1);
        assert!(lowered[2].dense.is_some(), "dense attention carries a cost");
        assert!(lowered[2].kernels.is_empty());
        // FFN expand drives vectors and d_out.
        let l1 = &lowered[3].kernels[0];
        assert_eq!(l1.vectors, 2 * 4 * 256);
        assert_eq!(l1.d_out, 2 * 512);
    }

    #[test]
    fn kernels_flatten_sparse_only() {
        let m = hybrid();
        let ks = m.kernels(Some(2));
        // fft2d (2) + ffn (2) + dense att (0) + ffn (2).
        assert_eq!(ks.len(), 6);
        assert!(ks.iter().all(|k| k.seq == 256));
        assert!(ks[0].name.contains("AT-all-hidden"));
    }

    #[test]
    fn batch_override_scales_vectors() {
        let m = hybrid();
        let a = m.kernels(Some(1));
        let b = m.kernels(Some(8));
        assert_eq!(a[0].vectors * 8, b[0].vectors);
    }

    #[test]
    fn spec_string_round_trips() {
        let m = hybrid();
        let s = m.spec_string();
        assert_eq!(s, "att:fft2d,ffn:bpmm*x4;att:dense,ffn:bpmm*x2");
        let reparsed = parse_spec_layers(&s).unwrap();
        assert_eq!(&reparsed, m.layers());
    }

    #[test]
    fn grammar_repeat_prefix_expands_layers() {
        let layers = parse_spec_layers("3*att:fft2d,ffn:bpmm*x2;att:bpmm").unwrap();
        assert_eq!(layers.len(), 4);
        assert_eq!(layers[0], layers[2]);
        assert_eq!(layers[3][0].block, Block::Attention { sparsity: AttnSparsity::Bpmm });
    }

    #[test]
    fn grammar_rejects_malformed_blocks() {
        assert!(parse_spec_layers("").is_err());
        assert!(parse_spec_layers("att:sparse").is_err());
        assert!(parse_spec_layers("ffn:bpmm*xq").is_err());
        assert!(parse_spec_layers("mlp:dense").is_err());
        assert!(parse_spec_layers("att:fft2d;;att:bpmm").is_err());
    }

    #[test]
    fn ffn1_parses_as_expand_only() {
        let layers = parse_spec_layers("ffn1:bpmm*x4").unwrap();
        assert_eq!(
            layers[0][0].block,
            Block::Ffn { form: FfnForm::Bpmm, expand: 4, contract: false }
        );
        // And formats back to the same token.
        assert_eq!(format_spec_layers(&layers), "ffn1:bpmm*x4");
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let base = || ModelSpec::builder("bad").attention(AttnSparsity::Bpmm);
        assert!(base().hidden(100).build().is_err(), "non power of two hidden");
        assert!(base().seq(3).build().is_err(), "non power of two seq");
        assert!(base().heads(3).build().is_err(), "heads must divide hidden");
        assert!(base().batch(0).build().is_err(), "zero default batch");
        assert!(ModelSpec::builder("bad").build().is_err(), "empty network");
        assert!(
            ModelSpec::builder("bad")
                .hidden(16)
                .attention(AttnSparsity::Fft2d)
                .build()
                .is_err(),
            "fft2d below the 32-point floor"
        );
        assert!(
            ModelSpec::builder("bad")
                .block(Block::Ffn { form: FfnForm::Bpmm, expand: 3, contract: true })
                .build()
                .is_err(),
            "non power-of-two expand"
        );
        assert!(
            ModelSpec::builder("bad")
                .named_block(
                    Block::Attention { sparsity: AttnSparsity::Fft2d },
                    vec!["only-one".into()],
                )
                .build()
                .is_err(),
            "name override count mismatch"
        );
    }

    #[test]
    fn repeat_builds_depth() {
        let m = ModelSpec::builder("deep")
            .attention(AttnSparsity::Fft2d)
            .ffn(FfnForm::Bpmm, 2)
            .repeat(6)
            .build()
            .unwrap();
        assert_eq!(m.depth(), 6);
        let ks = m.kernels(None);
        assert_eq!(ks.len(), 6 * 4);
        // Derived names carry the layer index.
        assert!(ks[0].name.starts_with("deep-L0-"));
        assert!(ks[23].name.starts_with("deep-L5-"));
    }

    #[test]
    fn repeat_multiplies_a_multi_layer_stack() {
        let m = ModelSpec::builder("deep2")
            .attention(AttnSparsity::Bpmm)
            .next_layer()
            .ffn(FfnForm::Bpmm, 2)
            .repeat(3)
            .build()
            .unwrap();
        assert_eq!(m.depth(), 6, "repeat multiplies the whole stack");
        assert_eq!(m.layers()[0], m.layers()[2]);
        assert_eq!(m.layers()[1], m.layers()[3]);
    }

    #[test]
    #[should_panic(expected = "batch must be >= 1")]
    fn lowering_explicit_zero_batch_panics() {
        hybrid().lower(Some(0));
    }

    #[test]
    fn bpmm_attention_prices_the_dense_core() {
        let m = ModelSpec::builder("b")
            .hidden(256)
            .seq(128)
            .attention(AttnSparsity::Bpmm)
            .build()
            .unwrap();
        let lowered = m.lower(Some(2));
        assert_eq!(lowered[0].kernels.len(), 1);
        let core = lowered[0].dense.as_ref().expect("attention core is priced");
        assert!(core.name.ends_with("AT-core"), "{}", core.name);
        // The core carries the O(b·s²·h) score/AV work the butterfly
        // projections do not eliminate.
        assert!(core.flops > 2.0 * 2.0 * 2.0 * 128.0 * 128.0 * 256.0);
    }

    #[test]
    fn json_spec_and_structured_layers_agree() {
        let a = ModelSpec::from_json_str(
            r#"{"name":"j","hidden":512,"seq":256,"heads":4,"batch":8,
                "spec":"att:fft2d,ffn:bpmm*x4;att:dense,ffn1:bpmm*x2"}"#,
        )
        .unwrap();
        let b = ModelSpec::from_json_str(
            r#"{"name":"j","hidden":512,"seq":256,"heads":4,"batch":8,
                "layers":[
                  {"blocks":[{"att":"fft2d"},{"ffn":"bpmm","expand":4}]},
                  {"blocks":[{"att":"dense"},
                             {"ffn":"bpmm","expand":2,"contract":false}]}]}"#,
        )
        .unwrap();
        assert_eq!(a, b);
        assert_eq!(a.default_batch(), 8);
        assert_eq!(a.heads(), 4);
    }

    #[test]
    fn json_rejects_ambiguous_or_missing_layers() {
        assert!(ModelSpec::from_json_str(
            r#"{"name":"j","hidden":512,"seq":256}"#
        )
        .is_err());
        assert!(ModelSpec::from_json_str(
            r#"{"name":"j","hidden":512,"seq":256,"spec":"att:bpmm",
                "layers":[{"blocks":[{"att":"bpmm"}]}]}"#
        )
        .is_err());
    }

    #[test]
    fn sparse_blocks_always_beat_dense_flops() {
        let m = hybrid();
        for k in m.kernels(Some(8)) {
            assert!(
                k.sparse_flops() < k.dense_flops(),
                "{}: sparse {} !< dense {}",
                k.name,
                k.sparse_flops(),
                k.dense_flops()
            );
        }
    }
}
