//! Hardware configuration of the dataflow substrate (Table I / Table III).
//!
//! Two presets matter for the paper's evaluation:
//!
//! * [`ArchConfig::full`] — the headline design: 4×4 PE mesh, SIMD32 per
//!   PE (16 × 32 = 512 MACs, 1.02 TFLOPS fp16 at 1 GHz), 4 MB SPM,
//!   dual-channel 25.6 GB/s DDR.
//! * [`ArchConfig::scaled_128`] — the fair-comparison configuration of
//!   §VI-H: MACs scaled to 128 (SIMD8), one DDR channel halved, matching
//!   the SOTA butterfly FPGA accelerator's 204.8 GFLOPS peak.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

pub mod fault;

pub use fault::FaultModel;

/// Function-unit kinds inside a PE (Fig. 8 decoupled units).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum UnitKind {
    Load,
    Flow,
    Cal,
    Store,
}

impl UnitKind {
    pub const ALL: [UnitKind; 4] =
        [UnitKind::Load, UnitKind::Flow, UnitKind::Cal, UnitKind::Store];

    pub fn index(self) -> usize {
        match self {
            UnitKind::Load => 0,
            UnitKind::Flow => 1,
            UnitKind::Cal => 2,
            UnitKind::Store => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            UnitKind::Load => "Load",
            UnitKind::Flow => "Flow",
            UnitKind::Cal => "Cal",
            UnitKind::Store => "Store",
        }
    }
}

/// Complete architecture configuration.
#[derive(Debug, Clone)]
pub struct ArchConfig {
    /// PE mesh dimensions (paper: 4×4).
    pub mesh_rows: usize,
    pub mesh_cols: usize,
    /// SIMD lanes per PE (32 in the full design → 512 MACs total).
    pub simd_width: usize,
    /// Clock frequency in Hz (1 GHz).
    pub freq_hz: f64,
    /// Element size in bytes (fp16 per Table I).
    pub elem_bytes: usize,

    // --- SPM (Fig. 9 multi-line design) ---
    /// Total SPM capacity in bytes (4 MB).
    pub spm_bytes: usize,
    /// Interleaved banks (4).
    pub spm_banks: usize,
    /// Lines per bank (8).
    pub spm_lines_per_bank: usize,
    /// SRAM entry width in elements (SIMD16).
    pub spm_entry_width: usize,
    /// SPM access latency in cycles.
    pub spm_latency: u64,

    // --- NoC ---
    /// Per-hop router latency in cycles.
    pub noc_hop_latency: u64,
    /// Link width in bytes/cycle.
    pub noc_link_bytes: usize,

    // --- DDR/DMA ---
    /// Number of DDR channels (2 full, 1 scaled).
    pub ddr_channels: usize,
    /// Bandwidth per channel in bytes/s (25.6 GB/s).
    pub ddr_chan_bw: f64,
    /// DMA burst setup latency in cycles.
    pub dma_setup: u64,

    // --- Scheduling (Fig. 8) ---
    /// Fixed issue overhead per micro-code block, cycles (arbitration +
    /// context fetch in the controlUnit).
    pub block_issue_overhead: u64,
    /// Iteration contexts resident per PE (SIMD-RAM double buffering);
    /// bounds how many DFG iterations stream concurrently.
    pub inflight_iters: usize,

    // --- Single-DFG capacity limits (§V-B) ---
    pub max_fft_points: usize,
    pub max_bpmm_points: usize,
}

impl ArchConfig {
    /// The paper's full design (Table I rightmost column, 512 MACs).
    pub fn full() -> Self {
        ArchConfig {
            mesh_rows: 4,
            mesh_cols: 4,
            simd_width: 32,
            freq_hz: 1.0e9,
            elem_bytes: 2,
            spm_bytes: 4 << 20,
            spm_banks: 4,
            spm_lines_per_bank: 8,
            spm_entry_width: 16,
            spm_latency: 2,
            noc_hop_latency: 1,
            noc_link_bytes: 32,
            ddr_channels: 2,
            ddr_chan_bw: 25.6e9,
            dma_setup: 16,
            block_issue_overhead: 4,
            inflight_iters: 4,
            max_fft_points: 256,
            max_bpmm_points: 512,
        }
    }

    /// §VI-H fair-comparison scale-down: 128 MACs (SIMD8), half DDR.
    pub fn scaled_128() -> Self {
        ArchConfig {
            simd_width: 8,
            ddr_channels: 1,
            ..Self::full()
        }
    }

    /// Table IV configuration: SIMD8 PE16 (128 MACs), power 3.94 W.
    pub fn table4() -> Self {
        Self::scaled_128()
    }

    /// Number of PEs in the mesh.
    pub fn num_pes(&self) -> usize {
        self.mesh_rows * self.mesh_cols
    }

    /// Total MAC units.
    pub fn total_macs(&self) -> usize {
        self.num_pes() * self.simd_width
    }

    /// Peak fp16 FLOPS (MAC = 2 flops).
    pub fn peak_flops(&self) -> f64 {
        self.total_macs() as f64 * 2.0 * self.freq_hz
    }

    /// Aggregate DDR bandwidth (bytes/s).
    pub fn ddr_bw(&self) -> f64 {
        self.ddr_channels as f64 * self.ddr_chan_bw
    }

    /// DDR bytes per cycle.
    pub fn ddr_bytes_per_cycle(&self) -> f64 {
        self.ddr_bw() / self.freq_hz
    }

    /// Manhattan distance between two PEs on the mesh.
    pub fn hop_distance(&self, a: usize, b: usize) -> usize {
        let (ar, ac) = (a / self.mesh_cols, a % self.mesh_cols);
        let (br, bc) = (b / self.mesh_cols, b % self.mesh_cols);
        ar.abs_diff(br) + ac.abs_diff(bc)
    }

    /// Seconds for a cycle count at this clock.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / self.freq_hz
    }

    /// Deterministic configuration signature covering every field; two
    /// configs with equal signatures plan, lower and simulate
    /// identically, so the coordinator's plan cache keys on it.
    pub fn signature(&self) -> String {
        format!("{self:?}")
    }

    /// Reject configurations the compiler/simulator cannot execute.
    ///
    /// The design-space enumerator (`coordinator::autotune`) builds
    /// `ArchConfig`s from user-supplied grids; every candidate passes
    /// through here before it can reach lowering or simulation, so a
    /// malformed grid fails with a message naming the knob instead of a
    /// divide-by-zero panic deep in the engine.  Error messages are
    /// pinned by unit tests — treat them as API.
    pub fn validate(&self) -> crate::Result<()> {
        use anyhow::bail;
        if self.mesh_rows == 0 || self.mesh_cols == 0 {
            bail!(
                "invalid arch: PE mesh must be non-empty (got {}x{} rows x cols)",
                self.mesh_rows,
                self.mesh_cols
            );
        }
        if self.simd_width == 0 {
            bail!("invalid arch: simd_width must be >= 1 lane (got 0)");
        }
        if self.spm_banks == 0 {
            bail!("invalid arch: SPM must expose at least one bank/port (got 0 banks)");
        }
        if self.spm_lines_per_bank == 0 {
            bail!("invalid arch: SPM banks need at least one line (got 0 lines per bank)");
        }
        if self.spm_bytes == 0 {
            bail!("invalid arch: SPM capacity must be positive (got 0 bytes)");
        }
        if self.spm_entry_width == 0 {
            bail!("invalid arch: SPM entry width must be >= 1 element (got 0)");
        }
        if self.ddr_channels == 0 {
            bail!("invalid arch: at least one DDR channel is required (got 0)");
        }
        if !(self.ddr_chan_bw > 0.0) {
            bail!(
                "invalid arch: DMA bandwidth per DDR channel must be positive (got {} B/s)",
                self.ddr_chan_bw
            );
        }
        if !(self.freq_hz > 0.0) {
            bail!("invalid arch: clock frequency must be positive (got {} Hz)", self.freq_hz);
        }
        if self.elem_bytes == 0 {
            bail!("invalid arch: element size must be >= 1 byte (got 0)");
        }
        if self.noc_link_bytes == 0 {
            bail!("invalid arch: NoC link width must be >= 1 byte/cycle (got 0)");
        }
        if self.inflight_iters == 0 {
            bail!("invalid arch: inflight_iters must be >= 1 (got 0)");
        }
        if self.max_fft_points < 2 || self.max_bpmm_points < 2 {
            bail!(
                "invalid arch: single-DFG capacity limits must be >= 2 points (got fft {} / bpmm {})",
                self.max_fft_points,
                self.max_bpmm_points
            );
        }
        Ok(())
    }
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self::full()
    }
}

/// Precomputed XY routes for every (src, dst) PE pair of a mesh.
///
/// Routing is dimension-ordered (columns first, then rows) over directed
/// links owned by the *upstream* PE, encoded `pe * 4 + dir` with
/// dir 0 = E, 1 = W, 2 = S, 3 = N — the exact walk the simulator's
/// legacy `xy_path` performed per FLOW block.  Routes depend only on the
/// mesh geometry (`mesh_rows`/`mesh_cols`), so [`RouteTable::for_arch`]
/// memoizes one shared table per geometry process-wide and lowering
/// copies per-block route slices out of it once, killing the per-block
/// path allocation in the simulator hot loop.
#[derive(Debug)]
pub struct RouteTable {
    num_pes: usize,
    /// CSR offsets: route of (src, dst) is
    /// `links[offsets[src * num_pes + dst]..offsets[src * num_pes + dst + 1]]`.
    offsets: Vec<u32>,
    /// Directed link ids, hop by hop.
    links: Vec<u32>,
}

impl RouteTable {
    /// Build the table for a `rows × cols` mesh.
    pub fn new(mesh_rows: usize, mesh_cols: usize) -> Self {
        let cols = mesh_cols.max(1);
        let num_pes = mesh_rows.max(1) * cols;
        let mut offsets = Vec::with_capacity(num_pes * num_pes + 1);
        let mut links = Vec::new();
        offsets.push(0u32);
        for src in 0..num_pes {
            for dst in 0..num_pes {
                let (mut r, mut c) = (src / cols, src % cols);
                let (dr, dc) = (dst / cols, dst % cols);
                while c != dc {
                    let pe = r * cols + c;
                    if dc > c {
                        links.push((pe * 4) as u32);
                        c += 1;
                    } else {
                        links.push((pe * 4 + 1) as u32);
                        c -= 1;
                    }
                }
                while r != dr {
                    let pe = r * cols + c;
                    if dr > r {
                        links.push((pe * 4 + 2) as u32);
                        r += 1;
                    } else {
                        links.push((pe * 4 + 3) as u32);
                        r -= 1;
                    }
                }
                offsets.push(links.len() as u32);
            }
        }
        RouteTable { num_pes, offsets, links }
    }

    /// The shared table for `arch`'s mesh geometry (built once per
    /// distinct `(mesh_rows, mesh_cols)` process-wide).
    pub fn for_arch(arch: &ArchConfig) -> Arc<RouteTable> {
        static TABLES: OnceLock<Mutex<HashMap<(usize, usize), Arc<RouteTable>>>> =
            OnceLock::new();
        let tables = TABLES.get_or_init(|| Mutex::new(HashMap::new()));
        tables
            .lock()
            .unwrap()
            .entry((arch.mesh_rows, arch.mesh_cols))
            .or_insert_with(|| Arc::new(RouteTable::new(arch.mesh_rows, arch.mesh_cols)))
            .clone()
    }

    /// Directed link ids along the XY route from `src` to `dst`.
    pub fn route(&self, src: usize, dst: usize) -> &[u32] {
        let i = src * self.num_pes + dst;
        &self.links[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// PEs covered by this table.
    pub fn num_pes(&self) -> usize {
        self.num_pes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_matches_table1() {
        let c = ArchConfig::full();
        assert_eq!(c.num_pes(), 16);
        assert_eq!(c.total_macs(), 512);
        // 1.02 TFLOPS fp16 (Table I): 512 MACs * 2 * 1 GHz = 1.024e12.
        assert!((c.peak_flops() - 1.024e12).abs() < 1e9);
        // 25.6x2 GB/s DDR.
        assert!((c.ddr_bw() - 51.2e9).abs() < 1e6);
        assert_eq!(c.spm_bytes, 4 * 1024 * 1024);
    }

    #[test]
    fn scaled_config_matches_section6h() {
        let c = ArchConfig::scaled_128();
        assert_eq!(c.total_macs(), 128);
        // 256 GFLOPS at 128 MACs (Table I bottom entry).
        assert!((c.peak_flops() - 256e9).abs() < 1e6);
        assert!((c.ddr_bw() - 25.6e9).abs() < 1e6);
    }

    #[test]
    fn hop_distance_mesh() {
        let c = ArchConfig::full();
        assert_eq!(c.hop_distance(0, 0), 0);
        assert_eq!(c.hop_distance(0, 3), 3); // same row
        assert_eq!(c.hop_distance(0, 15), 6); // opposite corner 4x4
        assert_eq!(c.hop_distance(5, 6), 1);
    }

    #[test]
    fn unit_kind_indexing() {
        for (i, k) in UnitKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
        }
    }

    #[test]
    fn route_table_lengths_match_manhattan() {
        for (rows, cols) in [(4, 4), (2, 8), (1, 16), (3, 5)] {
            let t = RouteTable::new(rows, cols);
            let arch = ArchConfig { mesh_rows: rows, mesh_cols: cols, ..ArchConfig::full() };
            for src in 0..t.num_pes() {
                for dst in 0..t.num_pes() {
                    assert_eq!(
                        t.route(src, dst).len(),
                        arch.hop_distance(src, dst),
                        "{rows}x{cols} {src}->{dst}"
                    );
                }
            }
        }
    }

    #[test]
    fn route_table_links_are_contiguous() {
        // Each consecutive link must leave the PE the previous link
        // entered, and the walk must end at the destination.
        let cols = 4;
        let t = RouteTable::new(4, cols);
        let step = |pe: usize, dir: usize| match dir {
            0 => pe + 1,
            1 => pe - 1,
            2 => pe + cols,
            _ => pe - cols,
        };
        for src in 0..16 {
            for dst in 0..16 {
                let mut at = src;
                for &l in t.route(src, dst) {
                    let (pe, dir) = (l as usize / 4, l as usize % 4);
                    assert_eq!(pe, at, "link leaves wrong PE on {src}->{dst}");
                    at = step(pe, dir);
                }
                assert_eq!(at, dst);
            }
        }
    }

    #[test]
    fn presets_validate() {
        ArchConfig::full().validate().unwrap();
        ArchConfig::scaled_128().validate().unwrap();
        ArchConfig::table4().validate().unwrap();
    }

    #[test]
    fn validate_pins_error_messages() {
        // The autotune enumerator surfaces these verbatim; pin them.
        let cases: &[(ArchConfig, &str)] = &[
            (
                ArchConfig { mesh_rows: 0, ..ArchConfig::full() },
                "invalid arch: PE mesh must be non-empty (got 0x4 rows x cols)",
            ),
            (
                ArchConfig { mesh_cols: 0, ..ArchConfig::full() },
                "invalid arch: PE mesh must be non-empty (got 4x0 rows x cols)",
            ),
            (
                ArchConfig { simd_width: 0, ..ArchConfig::full() },
                "invalid arch: simd_width must be >= 1 lane (got 0)",
            ),
            (
                ArchConfig { spm_banks: 0, ..ArchConfig::full() },
                "invalid arch: SPM must expose at least one bank/port (got 0 banks)",
            ),
            (
                ArchConfig { spm_lines_per_bank: 0, ..ArchConfig::full() },
                "invalid arch: SPM banks need at least one line (got 0 lines per bank)",
            ),
            (
                ArchConfig { spm_bytes: 0, ..ArchConfig::full() },
                "invalid arch: SPM capacity must be positive (got 0 bytes)",
            ),
            (
                ArchConfig { ddr_channels: 0, ..ArchConfig::full() },
                "invalid arch: at least one DDR channel is required (got 0)",
            ),
            (
                ArchConfig { ddr_chan_bw: 0.0, ..ArchConfig::full() },
                "invalid arch: DMA bandwidth per DDR channel must be positive (got 0 B/s)",
            ),
            (
                ArchConfig { ddr_chan_bw: -1.0, ..ArchConfig::full() },
                "invalid arch: DMA bandwidth per DDR channel must be positive (got -1 B/s)",
            ),
            (
                ArchConfig { freq_hz: 0.0, ..ArchConfig::full() },
                "invalid arch: clock frequency must be positive (got 0 Hz)",
            ),
        ];
        for (arch, want) in cases {
            let err = arch.validate().expect_err("must reject");
            assert_eq!(err.to_string(), *want);
        }
    }

    #[test]
    fn route_table_memo_shares_per_geometry() {
        let a = RouteTable::for_arch(&ArchConfig::full());
        let b = RouteTable::for_arch(&ArchConfig::scaled_128());
        assert!(std::sync::Arc::ptr_eq(&a, &b), "same mesh must share one table");
    }
}
