//! Hardware fault injection: dead PEs, degraded NoC links, downed DDR
//! channels.
//!
//! A [`FaultModel`] is a validated description of *which* hardware is
//! broken on one concrete [`super::ArchConfig`] geometry.  It carries no
//! policy: the lowering layer reacts by remapping butterfly nodes around
//! dead PEs ([`crate::dfg::Mapping::fault_aware`]), and the simulator
//! reacts by pricing degraded links and the reduced DDR bandwidth
//! ([`crate::sim::SimOptions::faults`]).  Everything is default-off —
//! a session without a fault model simulates the perfect machine
//! bit-for-bit identically to before this module existed.
//!
//! Construction is validating: a model that kills every PE or downs
//! every DDR channel is rejected up front with a structured error, so
//! later layers never have to panic on an unmappable machine.  Models
//! are geometry-bound; [`FaultModel::validate`] re-checks the binding
//! when a model meets a session built for a different preset.

use anyhow::{ensure, Result};

use crate::util::rng::Rng;

use super::ArchConfig;

/// A validated set of injected hardware faults for one arch geometry.
///
/// Invariants (enforced by every constructor and mutator):
///
/// * at least one PE is alive;
/// * at least one DDR channel is up;
/// * every degraded-link multiplier is `>= 1` (1 = healthy);
/// * indices are in range for the bound geometry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultModel {
    num_pes: usize,
    ddr_channels: usize,
    dead: Vec<bool>,
    /// Per directed-link latency/occupancy multiplier (`pe * 4 + dir`
    /// encoding, matching the simulator's link table); 1 = healthy.
    link_mult: Vec<u32>,
    ddr_down: usize,
}

impl FaultModel {
    /// An all-healthy model bound to `arch`'s geometry.
    pub fn for_arch(arch: &ArchConfig) -> Self {
        FaultModel {
            num_pes: arch.num_pes(),
            ddr_channels: arch.ddr_channels,
            dead: vec![false; arch.num_pes()],
            link_mult: vec![1; arch.num_pes() * 4],
            ddr_down: 0,
        }
    }

    /// Seeded random fault set: `dead_pes` distinct dead PEs,
    /// `degraded_links` distinct links slowed by `link_mult`, and
    /// `ddr_down` downed DDR channels.  The same `(arch, seed, counts)`
    /// always produces the same model.
    pub fn seeded(
        arch: &ArchConfig,
        seed: u64,
        dead_pes: usize,
        degraded_links: usize,
        link_mult: u32,
        ddr_down: usize,
    ) -> Result<Self> {
        let mut fm = Self::for_arch(arch);
        let mut rng = Rng::new(seed);
        ensure!(
            dead_pes < fm.num_pes,
            "fault set kills every PE ({dead_pes} dead of {} total)",
            fm.num_pes
        );
        let mut killed = 0;
        while killed < dead_pes {
            let p = rng.below(fm.num_pes as u64) as usize;
            if !fm.dead[p] {
                fm.kill_pe(p)?;
                killed += 1;
            }
        }
        let links = fm.link_mult.len();
        ensure!(
            degraded_links <= links,
            "cannot degrade {degraded_links} links: the mesh has only {links}"
        );
        let mut degraded = 0;
        while degraded < degraded_links {
            let l = rng.below(links as u64) as usize;
            if fm.link_mult[l] == 1 {
                fm.degrade_link(l, link_mult)?;
                degraded += 1;
            }
        }
        fm.down_ddr(ddr_down)?;
        Ok(fm)
    }

    /// Parse a fault spec string (the CLI `--faults` grammar when the
    /// value is not a file path): comma-separated `key=value` tokens.
    ///
    /// * `pe=<idx>` — kill one PE (repeatable);
    /// * `link=<idx>` — degrade one directed link (repeatable);
    /// * `mult=<m>` — multiplier for degraded links (default 4);
    /// * `ddr=<n>` — down `n` DDR channels;
    /// * `seed=<s>,pes=<n>,links=<n>` — seeded random selection of `n`
    ///   dead PEs / degraded links on top of any explicit entries.
    pub fn parse(spec: &str, arch: &ArchConfig) -> Result<Self> {
        let mut fm = Self::for_arch(arch);
        let mut seed: Option<u64> = None;
        let mut rand_pes = 0usize;
        let mut rand_links = 0usize;
        let mut mult = 4u32;
        let mut explicit_links: Vec<usize> = Vec::new();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (key, val) = tok.split_once('=').ok_or_else(|| {
                anyhow::anyhow!(
                    "fault spec token '{tok}' is not key=value \
                     (keys: pe, link, mult, ddr, seed, pes, links)"
                )
            })?;
            let uint = |name: &str| -> Result<usize> {
                val.parse().map_err(|_| {
                    anyhow::anyhow!("fault spec {name}= expects an integer, got '{val}'")
                })
            };
            match key {
                "pe" => fm.kill_pe(uint("pe")?)?,
                "link" => explicit_links.push(uint("link")?),
                "mult" => {
                    mult = uint("mult")? as u32;
                    ensure!(mult >= 1, "fault spec mult= must be >= 1 (got {mult})");
                }
                "ddr" => fm.down_ddr(uint("ddr")?)?,
                "seed" => seed = Some(uint("seed")? as u64),
                "pes" => rand_pes = uint("pes")?,
                "links" => rand_links = uint("links")?,
                other => anyhow::bail!(
                    "unknown fault spec key '{other}' \
                     (keys: pe, link, mult, ddr, seed, pes, links)"
                ),
            }
        }
        for l in explicit_links {
            fm.degrade_link(l, mult)?;
        }
        if rand_pes > 0 || rand_links > 0 {
            let seed = seed.ok_or_else(|| {
                anyhow::anyhow!("fault spec pes=/links= need seed=<s> for the random draw")
            })?;
            let rand =
                Self::seeded(arch, seed, rand_pes, rand_links, mult, 0)?;
            for p in 0..fm.num_pes {
                if rand.dead[p] {
                    fm.kill_pe(p)?;
                }
            }
            for l in 0..fm.link_mult.len() {
                if rand.link_mult[l] > 1 {
                    fm.degrade_link(l, rand.link_mult[l])?;
                }
            }
        }
        ensure!(
            !fm.is_healthy(),
            "fault spec '{spec}' injects no faults (use pe=, link=, ddr= or seed=/pes=/links=)"
        );
        Ok(fm)
    }

    /// Kill one PE.  Rejects out-of-range indices and the kill that
    /// would leave zero live PEs.
    pub fn kill_pe(&mut self, pe: usize) -> Result<()> {
        ensure!(
            pe < self.num_pes,
            "fault set names PE {pe} but the mesh has {} PEs",
            self.num_pes
        );
        if !self.dead[pe] {
            ensure!(
                self.live_count() > 1,
                "fault set kills every PE ({} of {})",
                self.num_pes,
                self.num_pes
            );
            self.dead[pe] = true;
        }
        Ok(())
    }

    /// Slow one directed link by `mult` (serialized transfer and hop
    /// latency both scale).
    pub fn degrade_link(&mut self, link: usize, mult: u32) -> Result<()> {
        ensure!(
            link < self.link_mult.len(),
            "fault set names link {link} but the mesh has {} directed links",
            self.link_mult.len()
        );
        ensure!(mult >= 1, "link multiplier must be >= 1 (got {mult})");
        self.link_mult[link] = self.link_mult[link].max(mult);
        Ok(())
    }

    /// Down `channels` DDR channels (aggregate bandwidth scales by the
    /// surviving fraction).  At least one channel must stay up.
    pub fn down_ddr(&mut self, channels: usize) -> Result<()> {
        let down = self.ddr_down.max(channels);
        ensure!(
            down < self.ddr_channels,
            "fault set downs every DDR channel ({down} of {})",
            self.ddr_channels
        );
        self.ddr_down = down;
        Ok(())
    }

    /// Re-check the geometry binding against a (possibly different)
    /// arch.  A model parsed for `full` must not silently misprice a
    /// `scaled128` session.
    pub fn validate(&self, arch: &ArchConfig) -> Result<()> {
        ensure!(
            self.num_pes == arch.num_pes() && self.ddr_channels == arch.ddr_channels,
            "fault model was built for {} PEs / {} DDR channels but this \
             architecture has {} / {}",
            self.num_pes,
            self.ddr_channels,
            arch.num_pes(),
            arch.ddr_channels
        );
        ensure!(
            self.live_count() >= 1,
            "fault set kills every PE ({} of {})",
            self.num_pes,
            self.num_pes
        );
        Ok(())
    }

    /// Is PE `pe` dead?
    pub fn pe_dead(&self, pe: usize) -> bool {
        self.dead.get(pe).copied().unwrap_or(false)
    }

    /// Number of live PEs.
    pub fn live_count(&self) -> usize {
        self.dead.iter().filter(|&&d| !d).count()
    }

    /// Live PE indices, ascending.
    pub fn live_pes(&self) -> Vec<u16> {
        (0..self.num_pes).filter(|&p| !self.dead[p]).map(|p| p as u16).collect()
    }

    /// Occupancy/latency multiplier of directed link `link` (1 = healthy).
    #[inline]
    pub fn link_multiplier(&self, link: usize) -> u64 {
        self.link_mult.get(link).copied().unwrap_or(1) as u64
    }

    /// Downed DDR channel count.
    pub fn ddr_down(&self) -> usize {
        self.ddr_down
    }

    /// Surviving fraction of DDR bandwidth, in `(0, 1]`.
    pub fn ddr_scale(&self) -> f64 {
        (self.ddr_channels - self.ddr_down) as f64 / self.ddr_channels as f64
    }

    /// True when the model injects nothing (equivalent to no model).
    pub fn is_healthy(&self) -> bool {
        self.ddr_down == 0
            && !self.dead.iter().any(|&d| d)
            && self.link_mult.iter().all(|&m| m == 1)
    }

    /// Stable, complete cache-key signature.  Everything that changes
    /// simulated numbers is spelled out field by field (the same
    /// contract as [`crate::sim::SimOptions::signature`]), so fault
    /// configurations can never alias in the plan cache, the structural
    /// store or the autotune journal.
    pub fn signature(&self) -> String {
        let dead: Vec<String> =
            (0..self.num_pes).filter(|&p| self.dead[p]).map(|p| p.to_string()).collect();
        let links: Vec<String> = self
            .link_mult
            .iter()
            .enumerate()
            .filter(|&(_, &m)| m > 1)
            .map(|(l, &m)| format!("{l}x{m}"))
            .collect();
        format!(
            "fault[pes{}|dead={}|links={}|ddr{}]",
            self.num_pes,
            dead.join(";"),
            links.join(";"),
            self.ddr_down
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_model_is_a_no_op() {
        let arch = ArchConfig::full();
        let fm = FaultModel::for_arch(&arch);
        assert!(fm.is_healthy());
        assert_eq!(fm.live_count(), 16);
        assert_eq!(fm.ddr_scale(), 1.0);
        assert_eq!(fm.link_multiplier(7), 1);
        fm.validate(&arch).unwrap();
    }

    #[test]
    fn constructors_enforce_invariants() {
        let arch = ArchConfig::full();
        let mut fm = FaultModel::for_arch(&arch);
        assert_eq!(
            fm.kill_pe(99).unwrap_err().to_string(),
            "fault set names PE 99 but the mesh has 16 PEs"
        );
        for p in 0..15 {
            fm.kill_pe(p).unwrap();
        }
        assert_eq!(
            fm.kill_pe(15).unwrap_err().to_string(),
            "fault set kills every PE (16 of 16)"
        );
        assert_eq!(fm.live_count(), 1);

        let mut fm = FaultModel::for_arch(&arch);
        assert!(fm.degrade_link(1000, 4).is_err());
        assert!(fm.degrade_link(3, 0).is_err());
        fm.degrade_link(3, 4).unwrap();
        assert_eq!(fm.link_multiplier(3), 4);

        // full() has 2 DDR channels: one may fail, both may not.
        fm.down_ddr(1).unwrap();
        assert_eq!(fm.ddr_scale(), 0.5);
        assert_eq!(
            fm.down_ddr(2).unwrap_err().to_string(),
            "fault set downs every DDR channel (2 of 2)"
        );
    }

    #[test]
    fn seeded_is_deterministic_and_counts_exact() {
        let arch = ArchConfig::full();
        let a = FaultModel::seeded(&arch, 42, 3, 5, 8, 0).unwrap();
        let b = FaultModel::seeded(&arch, 42, 3, 5, 8, 0).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.live_count(), 13);
        assert_eq!(a.link_mult.iter().filter(|&&m| m > 1).count(), 5);
        let c = FaultModel::seeded(&arch, 43, 3, 5, 8, 0).unwrap();
        assert_ne!(a, c, "different seed, different draw");
    }

    #[test]
    fn parse_grammar_round_trips_and_rejects_garbage() {
        let arch = ArchConfig::full();
        let fm = FaultModel::parse("pe=3,pe=7,link=12,mult=8,ddr=0", &arch).unwrap();
        assert!(fm.pe_dead(3) && fm.pe_dead(7) && !fm.pe_dead(0));
        assert_eq!(fm.link_multiplier(12), 8);
        let fm = FaultModel::parse("seed=9,pes=2,links=3", &arch).unwrap();
        assert_eq!(fm.live_count(), 14);

        let err = FaultModel::parse("pes=2", &arch).unwrap_err().to_string();
        assert_eq!(err, "fault spec pes=/links= need seed=<s> for the random draw");
        let err = FaultModel::parse("bogus=1", &arch).unwrap_err().to_string();
        assert_eq!(
            err,
            "unknown fault spec key 'bogus' (keys: pe, link, mult, ddr, seed, pes, links)"
        );
        let err = FaultModel::parse("pe", &arch).unwrap_err().to_string();
        assert_eq!(
            err,
            "fault spec token 'pe' is not key=value (keys: pe, link, mult, ddr, seed, pes, links)"
        );
        let err = FaultModel::parse("mult=4", &arch).unwrap_err().to_string();
        assert_eq!(
            err,
            "fault spec 'mult=4' injects no faults (use pe=, link=, ddr= or seed=/pes=/links=)"
        );
    }

    #[test]
    fn validate_catches_geometry_mismatch() {
        let full = ArchConfig::full();
        let scaled = ArchConfig::scaled_128();
        let fm = FaultModel::seeded(&full, 1, 2, 0, 1, 0).unwrap();
        fm.validate(&full).unwrap();
        let err = fm.validate(&scaled).unwrap_err().to_string();
        assert!(
            err.starts_with("fault model was built for 16 PEs"),
            "unexpected: {err}"
        );
    }

    #[test]
    fn signature_is_complete_and_order_stable() {
        let arch = ArchConfig::full();
        let mut fm = FaultModel::for_arch(&arch);
        fm.kill_pe(5).unwrap();
        fm.kill_pe(1).unwrap();
        fm.degrade_link(9, 4).unwrap();
        assert_eq!(fm.signature(), "fault[pes16|dead=1;5|links=9x4|ddr0]");
        let mut other = FaultModel::for_arch(&arch);
        other.kill_pe(1).unwrap();
        other.kill_pe(5).unwrap();
        other.degrade_link(9, 2).unwrap();
        assert_ne!(fm.signature(), other.signature(), "multiplier is part of the key");
    }
}
