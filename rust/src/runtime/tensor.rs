//! The `.f32t` tensor format shared with `python/compile/aot.py`:
//! `u32 ndim, u32 dims[ndim], f32 data[prod(dims)]`, little-endian.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {n} elements, got {}", shape, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn l2(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&v| v as f64).sum::<f64>() / self.data.len() as f64
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Read a `.f32t` file.
pub fn read_f32_tensor(path: &Path) -> Result<Tensor> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let ndim = u32::from_le_bytes(u32buf) as usize;
    if ndim > 8 {
        bail!("implausible ndim {ndim} in {path:?}");
    }
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        f.read_exact(&mut u32buf)?;
        shape.push(u32::from_le_bytes(u32buf) as usize);
    }
    let n: usize = shape.iter().product();
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)
        .with_context(|| format!("short data in {path:?} (want {n} f32)"))?;
    let data: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Tensor { shape, data })
}

/// Write a `.f32t` file (round-trip/testing).
pub fn write_f32_tensor(path: &Path, t: &Tensor) -> Result<()> {
    use std::io::Write;
    let mut out = Vec::with_capacity(4 + 4 * t.shape.len() + 4 * t.data.len());
    out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
    for &d in &t.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for &v in &t.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-9, 7.25]).unwrap();
        let dir = std::env::temp_dir().join("bfdf_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.f32t");
        write_f32_tensor(&p, &t).unwrap();
        let back = read_f32_tensor(&p).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(vec![2, 2], vec![1.0]).is_err());
    }

    #[test]
    fn stats() {
        let t = Tensor::new(vec![4], vec![3.0, 4.0, 0.0, 0.0]).unwrap();
        assert!((t.l2() - 5.0).abs() < 1e-12);
        assert!((t.mean() - 1.75).abs() < 1e-12);
    }
}
