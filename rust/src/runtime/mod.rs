//! PJRT runtime: load and execute the AOT artifacts.
//!
//! `python/compile/aot.py` lowers each model variant to HLO *text* (the
//! interchange format xla_extension 0.5.1 accepts; serialized protos from
//! jax ≥ 0.5 carry 64-bit ids it rejects).  This module loads the text,
//! compiles it once on the PJRT CPU client, caches the executable, and
//! runs it from the Rust hot path — Python never executes at runtime.
//!
//! The PJRT executor needs the `xla` crate, which is not part of the
//! offline vendor set, so it is gated behind the `pjrt` cargo feature
//! (enable it *and* add the `xla` dependency to Cargo.toml to use it).
//! Without the feature, manifest/metadata loading and the [`Tensor`]
//! utilities still work; [`Runtime::load`] returns a descriptive error.

pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

pub use tensor::Tensor;

/// Metadata of one artifact (from `<name>.meta.json`).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub output_mean: f64,
    pub output_l2: f64,
}

impl ArtifactMeta {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = |key: &str| -> Result<Vec<usize>> {
            Ok(j.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow!("{key} not an array"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect())
        };
        Ok(ArtifactMeta {
            name: j.req_str("name")?.to_string(),
            input_shape: shape("input_shape")?,
            output_shape: shape("output_shape")?,
            output_mean: j.req_f64("output_mean")?,
            output_l2: j.req_f64("output_l2")?,
        })
    }
}

/// Read and parse an artifact directory's `manifest.json`.
fn read_manifest(dir: &Path) -> Result<HashMap<String, ArtifactMeta>> {
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
    let parsed = json::parse(&text)?;
    let mut cache = HashMap::new();
    for item in parsed
        .as_arr()
        .ok_or_else(|| anyhow!("manifest is not an array"))?
    {
        let meta = ArtifactMeta::from_json(item)?;
        cache.insert(meta.name.clone(), meta);
    }
    Ok(cache)
}

/// A compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct LoadedModel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl LoadedModel {
    /// Execute on one input tensor; returns the output tensor.
    pub fn run(&self, input: &Tensor) -> Result<Tensor> {
        if input.shape != self.meta.input_shape {
            anyhow::bail!(
                "input shape {:?} != artifact '{}' expects {:?}",
                input.shape,
                self.meta.name,
                self.meta.input_shape
            );
        }
        let dims: Vec<i64> = input.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&input.data)
            .reshape(&dims)
            .context("reshape input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        Ok(Tensor { shape: self.meta.output_shape.clone(), data })
    }

    /// Validate against the golden input/output pair shipped with the
    /// artifact; returns the max abs error *relative to the golden RMS*
    /// (XLA fusion reorders f32 reductions, so bit-exactness is not the
    /// contract — scale-relative closeness is).
    pub fn validate_golden(&self, dir: &Path) -> Result<f32> {
        let input = tensor::read_f32_tensor(&dir.join(format!("{}.in.f32t", self.meta.name)))?;
        let want = tensor::read_f32_tensor(&dir.join(format!("{}.out.f32t", self.meta.name)))?;
        let got = self.run(&input)?;
        if got.shape != want.shape {
            anyhow::bail!("golden shape mismatch: {:?} vs {:?}", got.shape, want.shape);
        }
        let max_err = got.max_abs_diff(&want);
        let rms = (want.l2() / (want.len() as f64).sqrt()).max(1e-30) as f32;
        Ok(max_err / rms)
    }
}

/// Artifact directory: PJRT client + executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    pub dir: PathBuf,
    client: xla::PjRtClient,
    cache: HashMap<String, ArtifactMeta>,
    exes: HashMap<String, LoadedModel>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open an artifact directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let cache = read_manifest(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { dir, client, cache, exes: HashMap::new() })
    }

    /// Names of available artifacts.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.cache.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.cache.get(name)
    }

    /// Load (compile) an artifact, memoized.
    pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
        if !self.exes.contains_key(name) {
            let meta = self
                .cache
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
                .clone();
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.exes.insert(name.to_string(), LoadedModel { meta, exe });
        }
        Ok(&self.exes[name])
    }

    /// Platform name of the PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Stub of the compiled artifact handle (built without the `pjrt`
/// feature, which needs the `xla` crate): metadata is available, but
/// execution returns a descriptive error.
#[cfg(not(feature = "pjrt"))]
pub struct LoadedModel {
    pub meta: ArtifactMeta,
}

#[cfg(not(feature = "pjrt"))]
impl LoadedModel {
    pub fn run(&self, _input: &Tensor) -> Result<Tensor> {
        Err(no_pjrt_error(&self.meta.name))
    }

    pub fn validate_golden(&self, _dir: &Path) -> Result<f32> {
        Err(no_pjrt_error(&self.meta.name))
    }
}

/// Artifact directory: manifest metadata only (no PJRT backend).
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub dir: PathBuf,
    cache: HashMap<String, ArtifactMeta>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Open an artifact directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let cache = read_manifest(&dir)?;
        Ok(Runtime { dir, cache })
    }

    /// Names of available artifacts.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.cache.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.cache.get(name)
    }

    /// Always errors: executing artifacts needs the PJRT backend.
    pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
        let _ = self
            .cache
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        Err(no_pjrt_error(name))
    }

    /// Platform name of the PJRT client.
    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }
}

#[cfg(not(feature = "pjrt"))]
fn no_pjrt_error(name: &str) -> anyhow::Error {
    anyhow!(
        "cannot execute artifact '{name}': this build has no PJRT backend \
         (enable the `pjrt` cargo feature and add the `xla` crate dependency)"
    )
}
