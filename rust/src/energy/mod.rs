//! The Table III power/area model, activity-scaled.
//!
//! DC-synthesized per-unit active power of one PE (12 nm, 1 GHz):
//!
//! | unit              | area mm² | active mW |
//! |-------------------|---------|-----------|
//! | ContextRouter     | 0.018   | 6.37      |
//! | DataRouter        | 0.108   | 62.21     |
//! | ControlUnit       | 0.002   | 2.58      |
//! | InstBlocks        | 0.039   | 9.23      |
//! | SIMD RAM          | 0.106   | 32.13     |
//! | FuncUnits (SIMD32)| 0.316   | 322.16    |
//! | **total/PE**      | 0.985   | 434.68 (6.95 W for 16 PEs) |
//!
//! FuncUnits power scales with SIMD width; the remaining "uncore" is
//! width-independent.  The paper's two published operating points pin
//! the line: 6.95 W at SIMD32·PE16 and 3.94 W at SIMD8·PE16 — we use the
//! Table III breakdown for the SIMD32 point and a per-lane slope fitted
//! to both points for scaled configurations, then scale dynamic terms by
//! measured unit activity.

use crate::arch::{ArchConfig, UnitKind};
use crate::sim::SimStats;

/// Table III unit classes.  Power partitioning matches on this, never on
/// the display name, so renaming a row cannot silently misattribute its
/// power (see [`power_partition`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PowerClass {
    ContextRouter,
    DataRouter,
    ControlUnit,
    InstBlocks,
    SimdRam,
    FuncUnits,
}

impl PowerClass {
    pub const ALL: [PowerClass; 6] = [
        PowerClass::ContextRouter,
        PowerClass::DataRouter,
        PowerClass::ControlUnit,
        PowerClass::InstBlocks,
        PowerClass::SimdRam,
        PowerClass::FuncUnits,
    ];
}

/// One Table III row.
#[derive(Debug, Clone)]
pub struct UnitPower {
    pub class: PowerClass,
    pub name: &'static str,
    pub area_mm2: f64,
    pub active_mw: f64,
}

/// Total synthesized area of one PE including glue logic (the Table III
/// "total" row); the glue term is derived as `PE_AREA_MM2 - Σ row areas`
/// rather than hardcoded, so editing a row keeps the total honest.
const PE_AREA_MM2: f64 = 0.985;

/// Table III rows for the SIMD32 PE.
pub fn table3_rows() -> Vec<UnitPower> {
    use PowerClass as C;
    let row = |class, name, area_mm2, active_mw| UnitPower { class, name, area_mm2, active_mw };
    vec![
        row(C::ContextRouter, "ContextRouter", 0.018, 6.37),
        row(C::DataRouter, "DataRouter", 0.108, 62.21),
        row(C::ControlUnit, "ControlUnit", 0.002, 2.58),
        row(C::InstBlocks, "InstBlocks", 0.039, 9.23),
        row(C::SimdRam, "SIMD RAM", 0.106, 32.13),
        row(C::FuncUnits, "FuncUnits (SIMD32)", 0.316, 322.16),
    ]
}

/// Total active power of one SIMD32 PE (mW).
pub fn pe_active_mw() -> f64 {
    table3_rows().iter().map(|r| r.active_mw).sum()
}

/// Array active power (W) at a given SIMD width, from the two published
/// operating points (6.95 W @ SIMD32, 3.94 W @ SIMD8, both PE16).
pub fn array_power_w(arch: &ArchConfig) -> f64 {
    // P(S) = A + B·S per array of 16 PEs; scale by actual PE count.
    let b = (6.95 - 3.94) / (32.0 - 8.0);
    let a = 6.95 - 32.0 * b;
    let base16 = a + b * arch.simd_width as f64;
    base16 * arch.num_pes() as f64 / 16.0
}

/// Idle fraction of dynamic power (clock tree + leakage at 12 nm).
const IDLE_FRACTION: f64 = 0.35;

/// Partition of the array power (W) into the four activity-scaled
/// groups `(func, router, ram, ctrl)`, by the Table III breakdown.
///
/// Rows are looked up by [`PowerClass`], exhaustively: every class must
/// appear in [`table3_rows`] exactly once (panics otherwise), so a
/// renamed row can never silently fall out of its group.
fn power_partition(arch: &ArchConfig) -> (f64, f64, f64, f64) {
    let total = array_power_w(arch);
    let rows = table3_rows();
    let pe_total: f64 = rows.iter().map(|r| r.active_mw).sum();
    let frac = |class: PowerClass| -> f64 {
        let mut matches = rows.iter().filter(|r| r.class == class);
        let row = matches
            .next()
            .unwrap_or_else(|| panic!("table3_rows is missing the {class:?} row"));
        assert!(
            matches.next().is_none(),
            "table3_rows lists {class:?} more than once"
        );
        row.active_mw / pe_total
    };
    let p_func = total * frac(PowerClass::FuncUnits);
    let p_router = total * (frac(PowerClass::DataRouter) + frac(PowerClass::ContextRouter));
    let p_ram = total * frac(PowerClass::SimdRam);
    let p_ctrl = total * (frac(PowerClass::ControlUnit) + frac(PowerClass::InstBlocks));
    (p_func, p_router, p_ram, p_ctrl)
}

/// Power (W) of a powered-but-idle array: clock tree + leakage on the
/// dynamic units plus the always-on control plane.  This is what a
/// replicated dataflow array burns while another shard's longer
/// schedule keeps the batch in flight
/// (see [`crate::coordinator::pipeline`]).
pub fn idle_power_w(arch: &ArchConfig) -> f64 {
    let (p_func, p_router, p_ram, p_ctrl) = power_partition(arch);
    IDLE_FRACTION * (p_func + p_router + p_ram) + p_ctrl
}

/// Effective power (W) for a run with measured activity.
///
/// The width-dependent term (FuncUnits) scales with Cal activity and the
/// control plane is always on.  The data movers scale with *measured
/// traffic* when the stats carry it: SIMD RAM with the SPM scalar rate
/// over the banks' peak service rate, the routers with the NoC scalar
/// rate plus the DMA stream (which crosses the DataRouter to reach the
/// SPM banks) over the combined mover bandwidth.  Stats without traffic
/// counters (unit-level micro-runs) fall back to Flow/Load/Store busy
/// time as the activity proxy.
pub fn effective_power_w(arch: &ArchConfig, stats: &SimStats) -> f64 {
    let n = arch.num_pes();
    let cycles = stats.cycles.max(1) as f64;
    let cal = stats.utilization(UnitKind::Cal, n);
    let flow = stats.utilization(UnitKind::Flow, n);
    let ls = stats.utilization(UnitKind::Load, n) + stats.utilization(UnitKind::Store, n);
    // SIMD RAM activity: scalars the SPM served per cycle over the peak
    // service rate of all bank lines.
    let ram_act = if stats.spm_scalars > 0 {
        let spm_peak =
            (arch.spm_banks * arch.spm_lines_per_bank * arch.spm_entry_width) as f64;
        stats.spm_scalars as f64 / cycles / spm_peak
    } else {
        ls
    };
    // Router activity: NoC + DMA scalar traffic over the aggregate mover
    // bandwidth (mesh links plus the DDR interface).
    let router_act = if stats.noc_scalars > 0 || stats.dma_bytes > 0 {
        let elem = arch.elem_bytes as f64;
        let link_cap = (n * 4) as f64 * (arch.noc_link_bytes as f64 / elem);
        let dma_cap = arch.ddr_bytes_per_cycle() / elem;
        let moved = stats.noc_scalars as f64 + stats.dma_bytes as f64 / elem;
        moved / cycles / (link_cap + dma_cap)
    } else {
        flow
    };
    // Partition the array power by the Table III breakdown (by class,
    // not by name — see `power_partition`).
    let (p_func, p_router, p_ram, p_ctrl) = power_partition(arch);
    let act = |p: f64, u: f64| p * (IDLE_FRACTION + (1.0 - IDLE_FRACTION) * u.min(1.0));
    act(p_func, cal) + act(p_router, router_act) + act(p_ram, ram_act) + p_ctrl
}

/// Energy (J) for a run of `seconds` at the activity of `stats`.
pub fn energy_j(arch: &ArchConfig, stats: &SimStats, seconds: f64) -> f64 {
    effective_power_w(arch, stats) * seconds
}

/// Total synthesized area of the PE array (mm²).
pub fn array_area_mm2(arch: &ArchConfig) -> f64 {
    let units: f64 = table3_rows().iter().map(|r| r.area_mm2).sum();
    // Glue logic is whatever the Table III total row leaves after the
    // itemized units — derived, so a row edit cannot desync the total,
    // and a row edit that overflows the total is a model error.
    let glue = PE_AREA_MM2 - units;
    debug_assert!(glue >= 0.0, "Table III unit areas exceed the PE total: glue {glue}");
    (units + glue) * arch.num_pes() as f64
}

/// SPM SRAM density (mm² per MiB) at the Table III node.  Derived from
/// the SIMD RAM row: 0.106 mm² buys a PE's context RAM; scaled to the
/// shared 4 MiB SPM of the full design it puts the SPM at roughly the
/// same order as the 16-PE array, matching the die-photo proportions of
/// comparable 12 nm dataflow accelerators.
pub const SPM_MM2_PER_MIB: f64 = 0.55;

/// Synthesized area (mm²) of one complete design point: the PE array
/// (Table III per-PE total, with the width-dependent rows — FuncUnits
/// and SIMD RAM — scaled linearly from their SIMD32 reference) plus the
/// shared SPM at [`SPM_MM2_PER_MIB`].  DDR channels are off-chip PHY +
/// DIMMs and contribute no die area here; they still differentiate
/// designs through bandwidth (latency) and are reported alongside.
///
/// This is the area axis of the autotuner's Pareto frontier
/// (`coordinator::autotune`): unlike [`array_area_mm2`] it must *rank*
/// heterogeneous design points, so it cannot ignore SIMD width or SPM
/// capacity.
pub fn design_area_mm2(arch: &ArchConfig) -> f64 {
    let rows = table3_rows();
    let simd_scale = arch.simd_width as f64 / 32.0;
    let units: f64 = rows
        .iter()
        .map(|r| match r.class {
            PowerClass::FuncUnits | PowerClass::SimdRam => r.area_mm2 * simd_scale,
            _ => r.area_mm2,
        })
        .sum();
    let glue = PE_AREA_MM2 - rows.iter().map(|r| r.area_mm2).sum::<f64>();
    let pe_array = (units + glue) * arch.num_pes() as f64;
    let spm = SPM_MM2_PER_MIB * arch.spm_bytes as f64 / (1024.0 * 1024.0);
    pe_array + spm
}

/// Lower bound (J) on the *compute* energy of executing `flops` on this
/// array: the FuncUnits' dynamic power over the minimum Cal busy time
/// the roofline allows.  Every additional joule a real run spends —
/// idle fractions, data movers, control plane, utilization below peak —
/// only adds to this, so the autotuner may prune a design point whose
/// floor is already dominated without simulating it
/// (see `coordinator::autotune`).
pub fn compute_energy_floor_j(arch: &ArchConfig, flops: f64) -> f64 {
    let (p_func, _, _, _) = power_partition(arch);
    (1.0 - IDLE_FRACTION) * p_func * flops / arch.peak_flops()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_total_matches_paper() {
        // Rows sum to ~434.68 mW.
        let sum = pe_active_mw();
        assert!((sum - 434.68).abs() < 0.5, "{sum}");
        // 16 PEs → ~6.95 W.
        assert!((sum * 16.0 / 1000.0 - 6.95).abs() < 0.05);
    }

    #[test]
    fn power_line_hits_both_operating_points() {
        assert!((array_power_w(&ArchConfig::full()) - 6.95).abs() < 1e-9);
        assert!((array_power_w(&ArchConfig::scaled_128()) - 3.94).abs() < 1e-9);
    }

    #[test]
    fn effective_power_between_idle_and_peak() {
        let arch = ArchConfig::full();
        let idle = SimStats { cycles: 1000, ..Default::default() };
        let p_idle = effective_power_w(&arch, &idle);
        let mut busy = SimStats { cycles: 1000, ..Default::default() };
        busy.unit_busy = [16_000, 16_000, 16_000, 16_000]; // fully busy
        let p_busy = effective_power_w(&arch, &busy);
        assert!(p_idle < p_busy);
        assert!(p_busy <= 6.95 * 1.3 + 1e-9);
        assert!(p_idle > 0.3 * 6.95 * 0.3);
    }

    #[test]
    fn traffic_counters_raise_mover_power() {
        // The SPM/NoC/DMA activity threaded through the aggregate stats
        // must influence the estimate: same busy time, more data moved
        // ⇒ more effective power.
        let arch = ArchConfig::full();
        let mut quiet = SimStats { cycles: 10_000, ..Default::default() };
        quiet.unit_busy = [2_000, 2_000, 12_000, 2_000];
        let mut busy_traffic = quiet.clone();
        busy_traffic.spm_scalars = 10_000 * 256; // half the SPM peak rate
        busy_traffic.noc_scalars = 10_000 * 500; // ~half the mover bandwidth
        busy_traffic.dma_bytes = 10_000 * 25;
        let p_quiet = effective_power_w(&arch, &quiet);
        let p_traffic = effective_power_w(&arch, &busy_traffic);
        assert!(
            p_traffic > p_quiet,
            "traffic ignored: {p_traffic} <= {p_quiet}"
        );
        assert!(p_traffic <= array_power_w(&arch) + 1e-9);
    }

    #[test]
    fn area_scales_with_pes() {
        let full = array_area_mm2(&ArchConfig::full());
        assert!((full - 0.985 * 16.0).abs() < 1e-6);
    }

    #[test]
    fn design_area_ranks_knobs() {
        // Full design: simd scale 1 ⇒ PE array term equals
        // array_area_mm2; SPM adds its own term.
        let full = ArchConfig::full();
        let a_full = design_area_mm2(&full);
        let spm_mib = full.spm_bytes as f64 / (1024.0 * 1024.0);
        assert!(
            (a_full - (array_area_mm2(&full) + SPM_MM2_PER_MIB * spm_mib)).abs() < 1e-9,
            "{a_full}"
        );
        // Narrower SIMD shrinks the die but not below the uncore floor.
        let narrow = ArchConfig::scaled_128();
        assert!(design_area_mm2(&narrow) < a_full);
        assert!(design_area_mm2(&narrow) > SPM_MM2_PER_MIB * spm_mib);
        // Fewer PEs, less SPM, fewer DDR channels: only the first two
        // change the die area (DDR is off-chip by construction).
        let small_mesh = ArchConfig { mesh_rows: 2, mesh_cols: 2, ..full.clone() };
        assert!(design_area_mm2(&small_mesh) < a_full);
        let small_spm = ArchConfig { spm_bytes: 1 << 20, ..full.clone() };
        assert!(design_area_mm2(&small_spm) < a_full);
        let one_ddr = ArchConfig { ddr_channels: 1, ..full.clone() };
        assert!((design_area_mm2(&one_ddr) - a_full).abs() < 1e-12);
    }

    #[test]
    fn compute_energy_floor_is_a_floor() {
        // The floor at peak-rate execution must sit below the energy the
        // activity model charges for the same work: a fully-busy run of
        // exactly the roofline duration burns the FuncUnits dynamic term
        // *plus* idle fractions, movers and control.
        for arch in [ArchConfig::full(), ArchConfig::scaled_128()] {
            let flops = 1.0e9;
            let floor = compute_energy_floor_j(&arch, flops);
            assert!(floor > 0.0);
            let t = flops / arch.peak_flops();
            let mut busy = SimStats { cycles: 1000, ..Default::default() };
            busy.unit_busy = [16_000, 16_000, 16_000, 16_000];
            let modeled = effective_power_w(&arch, &busy) * t;
            assert!(floor < modeled, "floor {floor} >= modeled {modeled}");
        }
    }

    #[test]
    fn power_classes_cover_table3_exactly_once() {
        // The partition matches rows by class, so a renamed row cannot
        // silently misattribute power — but only if every class appears
        // exactly once.  This is the regression guard for that
        // invariant (power_partition itself panics on violations).
        let rows = table3_rows();
        for class in PowerClass::ALL {
            assert_eq!(
                rows.iter().filter(|r| r.class == class).count(),
                1,
                "{class:?} must appear exactly once"
            );
        }
        assert_eq!(rows.len(), PowerClass::ALL.len());
    }

    #[test]
    fn partition_accounts_for_all_array_power() {
        for arch in [ArchConfig::full(), ArchConfig::scaled_128()] {
            let (f, r, m, c) = super::power_partition(&arch);
            let total = array_power_w(&arch);
            assert!(((f + r + m + c) - total).abs() < 1e-9 * total);
            assert!(f > 0.0 && r > 0.0 && m > 0.0 && c > 0.0);
        }
    }

    #[test]
    fn idle_power_below_any_running_estimate() {
        let arch = ArchConfig::table4();
        let idle = idle_power_w(&arch);
        assert!(idle > 0.0);
        assert!(idle < array_power_w(&arch));
        // A fully-idle activity estimate differs from the replica idle
        // power only by the always-on control plane treatment; both sit
        // well below the busy estimate.
        let mut busy = SimStats { cycles: 1000, ..Default::default() };
        busy.unit_busy = [16_000, 16_000, 16_000, 16_000];
        assert!(idle <= effective_power_w(&arch, &busy));
    }
}
