//! # butterfly-dataflow
//!
//! Reproduction of *"Multilayer Dataflow: Orchestrate Butterfly Sparsity to
//! Accelerate Attention Computation"* (Wu et al., 2024): a reconfigurable
//! coarse-grained dataflow architecture (4×4 PE mesh, decoupled
//! {Load, Flow, Cal, Store} function units, multi-bank/multi-line SPM)
//! executing butterfly-sparse attention kernels (BPMM linear layers and
//! FFT attention mixing) as *multilayer dataflow graphs*.
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — self-contained infrastructure: CLI parsing, JSON, a
//!   property-test harness, statistics (the offline vendor set has no
//!   clap/serde/criterion/proptest — see DESIGN.md).
//! * [`model`] — exact numeric references for butterfly matrices, FFT and
//!   attention, used as oracles by tests and by the functional examples.
//! * [`arch`] — hardware configuration (Table I / Table III parameters)
//!   plus the fault layer ([`arch::FaultModel`]): a validated, seedable
//!   set of dead PEs, degraded NoC links and downed DDR channels that
//!   the mapping and the engine price instead of ignoring.
//! * [`dfg`] — the paper's compiler: multilayer butterfly DFG templates
//!   (Fig. 5b/7), multi-stage Cooley-Tukey division (Fig. 9), BPMM weight
//!   slicing (Fig. 10), PE-array mapping and micro-code block generation
//!   (Fig. 8).  The three lowering decisions (division plan, PE mapping,
//!   BPMM slicing) plus the stage schedule sit behind the
//!   [`dfg::strategy::DataflowStrategy`] trait: `PaperStrategy` is the
//!   paper's recipe verbatim (the default), `SpmAdaptiveStrategy` packs
//!   blocks deeper (SPM-residency bounded) and cost-models the division
//!   choice, and sessions built with
//!   [`dfg::strategy::Strategy::Auto`] simulate every registered
//!   strategy per kernel shape and keep the fastest.
//! * [`sim`] — deterministic cycle-level discrete-event simulator of the
//!   dataflow substrate: PEs with decoupled units and coarse-grained
//!   block scheduling, mesh NoC, multi-line SPM, DMA/DDR.  The engine
//!   core is throughput-tuned (bucketed event calendar, pending-wake
//!   flags, precomputed routes, reusable [`sim::SimWorkspace`]) and
//!   held bit-exact against the frozen [`sim::reference`] engine by
//!   golden tests.
//! * [`baselines`] — analytical models of the comparison platforms
//!   (Jetson Xavier NX / Nano roofline + cache hierarchy; SOTA butterfly
//!   FPGA accelerator; SpAtten; DOTA).
//! * [`energy`] — the Table III power/area model, activity-scaled.
//! * [`workloads`] — declarative network descriptions: the
//!   [`workloads::spec::ModelSpec`] API composes hybrid
//!   butterfly-sparsity networks (per-layer `Dense | Bpmm | Fft2d`
//!   attention, `Dense | Bpmm` FFNs) from typed blocks, a compact spec
//!   grammar and a JSON model-file format, and the paper's benchmark
//!   suites (ViT, BERT, FABNet, one-layer vanilla transformer) are
//!   registered as `ModelSpec`-backed [`workloads::SUITES`] entries.
//! * [`runtime`] — PJRT loader/executor for the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text via the `xla` crate; gated behind
//!   the `pjrt` cargo feature, metadata-only stub otherwise).
//! * [`coordinator`] — experiment orchestration around a long-lived
//!   [`coordinator::Session`]: a builder-configured session (arch
//!   preset, window, simulator options, division policy, dataflow
//!   strategy) owns a plan cache keyed on `(kind, points, division,
//!   strategy, arch signature)`, so
//!   repeated stage DFGs — the vanilla transformer's twin FFN layers,
//!   FABNet's repeated blocks — plan, lower and simulate exactly once;
//!   independent kernels fan out across threads via
//!   [`coordinator::Session::run_many`] with deterministic input-order
//!   results, [`coordinator::Session::stream`] is the Table-IV
//!   batch-streaming driver, and
//!   [`coordinator::Session::run_network`] executes a whole
//!   `ModelSpec` network end-to-end with per-layer latency/energy/
//!   utilization rollups ([`coordinator::NetworkResult`]).  Streamed
//!   schedules are post-processed by the coarse-grained overlap model
//!   ([`coordinator::pipeline`]: DMA double buffering, inter-layer
//!   pipelining, batch sharding across replicated arrays).  Results
//!   serialize to JSON through [`coordinator::Report`] for benches and
//!   CI.  On top sits the serving layer ([`coordinator::serve`]):
//!   deterministic Poisson or trace-file traffic over mixed request
//!   classes (suite names or spec strings), a dynamic batcher
//!   (max-batch / max-wait knobs) packing queued requests into
//!   plan-cached batch executions, and a discrete-event loop across
//!   replica arrays that reports p50/p95/p99 latency, goodput against
//!   the capacity bound and utilization
//!   ([`coordinator::Session::serve`], `Report::Serving`, the
//!   `bfdf serve-sim` subcommand).  The serving loop degrades
//!   gracefully under failures — seeded or scripted replica up/down
//!   schedules ([`coordinator::ReplicaFaults`]), capped-backoff
//!   retries for batches killed in flight, per-request deadlines, and
//!   pluggable admission ([`coordinator::Admission`], FIFO or
//!   SLO-aware slack shedding) — all default-off, so fault-free runs
//!   stay byte-identical.  Design-space autotuning
//!   ([`coordinator::autotune`]) closes the loop: a
//!   [`coordinator::SearchSpace`] grid over the `ArchConfig` knobs
//!   (mesh, SIMD width, SPM ports/capacity, DDR channels, replica
//!   arrays), sound equal-shard/roofline pruning with reported skip
//!   counts, a resumable journal-checkpointed parallel sweep through
//!   shared per-arch sessions, and a per-class latency/energy/area
//!   Pareto frontier ([`coordinator::autotune::sweep`],
//!   `Report::Pareto`, the `bfdf autotune` subcommand).  The search
//!   space also carries a `strategy=` axis, so the sweep can race
//!   dataflow strategies against architecture knobs in one grid.

pub mod arch;
pub mod baselines;
pub mod coordinator;
pub mod dfg;
pub mod energy;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
