//! Multilayer butterfly DFG template (Fig. 5b / Fig. 7a) and a functional
//! executor that proves the template's swap topology is correct.
//!
//! Structure for an `n`-point kernel (`s = log2 n` stages):
//!
//! * layer 0 — `n/2` LOAD nodes; node `k` fetches elements `2k, 2k+1`.
//! * layers `1..=s` — `n/2` butterfly nodes; node `k` of layer `l`
//!   computes pair `k` of stage `l-1`.
//! * layer `s+1` — `n/2` STORE nodes.
//!
//! Inter-layer producers: pair `k` at stage `t` consumes the outputs of
//! pairs `k & !2^t` and `k | 2^t` of the previous layer — one of which is
//! `k` itself (the kept half, `COPY_I`) and the other at node distance
//! `2^t` (the swapped half, `COPY_T`).  This is the "sequential distances
//! of 1, 2, 4, 8, …" flowing of §III-B.

use anyhow::Result;

use crate::model::log2_int;

use super::graph::{Dfg, Edge, EdgeKind, KernelKind, Node, NodeId, NodeOp};

/// Pair index of element `e` at stage `s`: `((e >> (s+1)) << s) | (e & (2^s - 1))`.
pub fn pair_of_element(e: usize, stage: usize) -> usize {
    ((e >> (stage + 1)) << stage) | (e & ((1 << stage) - 1))
}

/// The two elements of pair `p` at stage `s`.
pub fn elements_of_pair(p: usize, stage: usize) -> (usize, usize) {
    let stride = 1usize << stage;
    let blk = p >> stage;
    let off = p & (stride - 1);
    let i = blk * 2 * stride + off;
    (i, i + stride)
}

/// Build the multilayer DFG for an `n`-point butterfly kernel.
pub fn build_butterfly_dfg(kind: KernelKind, n: usize) -> Dfg {
    let stages = log2_int(n);
    let half = n / 2;
    let layers = stages as u32 + 2; // load + stages + store
    let mut nodes = Vec::with_capacity(half * layers as usize);
    let mut edges = Vec::new();

    let id_of = |layer: u32, index: usize| NodeId((layer * half as u32) + index as u32);

    // Load layer.
    for k in 0..half {
        nodes.push(Node { id: id_of(0, k), layer: 0, index: k as u32, op: NodeOp::Load });
    }
    // Butterfly layers.
    for s in 0..stages {
        let layer = s as u32 + 1;
        for k in 0..half {
            nodes.push(Node {
                id: id_of(layer, k),
                layer,
                index: k as u32,
                op: NodeOp::Butterfly { stage: s as u32 },
            });
            if s == 0 {
                // Stage 0 pairs are (2k, 2k+1): exactly load node k's fetch.
                edges.push(Edge {
                    from: id_of(0, k),
                    to: id_of(layer, k),
                    kind: EdgeKind::CopyI,
                });
            } else {
                let keep = k & !(1usize << (s - 1));
                let swap = k | (1usize << (s - 1));
                let (local, remote) = if keep == k { (keep, swap) } else { (swap, keep) };
                debug_assert_eq!(local, k);
                edges.push(Edge {
                    from: id_of(layer - 1, local),
                    to: id_of(layer, k),
                    kind: EdgeKind::CopyI,
                });
                edges.push(Edge {
                    from: id_of(layer - 1, remote),
                    to: id_of(layer, k),
                    kind: EdgeKind::CopyT { node_dist: 1 << (s - 1) },
                });
            }
        }
    }
    // Store layer: node k stores the outputs of the last stage's pair k.
    let last = stages as u32 + 1;
    for k in 0..half {
        nodes.push(Node { id: id_of(last, k), layer: last, index: k as u32, op: NodeOp::Store });
        edges.push(Edge { from: id_of(last - 1, k), to: id_of(last, k), kind: EdgeKind::CopyI });
    }

    Dfg { kind, points: n, nodes, edges, layers }
}

/// Per-stage swap distance in node indices (1, 2, 4, … between butterfly
/// layers; 0 between load/stage0 and lastStage/store).
pub fn swap_distance(stage: usize) -> usize {
    if stage == 0 {
        0
    } else {
        1 << (stage - 1)
    }
}

/// Functionally execute a BPMM DFG over a vector, walking nodes in layer
/// order and applying the stage weights — the structural proof that the
/// multilayer reconstruction computes the same thing as the textbook
/// in-place butterfly.
///
/// `weights[s][p*4..p*4+4]` is pair `p`'s 2x2 block at stage `s`.
pub fn execute_bpmm_dfg(dfg: &Dfg, weights: &[Vec<f32>], x: &[f32]) -> Result<Vec<f32>> {
    let n = dfg.points;
    assert_eq!(x.len(), n);
    let stages = log2_int(n);
    assert_eq!(weights.len(), stages);
    // Value state carried between layers, indexed by element position.
    let mut vals = x.to_vec();
    for s in 0..stages {
        let layer = s as u32 + 1;
        let mut next = vals.clone();
        for node in dfg.layer_nodes(layer) {
            let p = node.index as usize;
            let (i, j) = elements_of_pair(p, s);
            let w = &weights[s][p * 4..p * 4 + 4];
            next[i] = w[0] * vals[i] + w[1] * vals[j];
            next[j] = w[2] * vals[i] + w[3] * vals[j];
        }
        vals = next;
    }
    Ok(vals)
}

/// Functionally execute an FFT DFG: bit-reverse the input (the paper's
/// P_N permutations folded into SPM addressing), then walk the butterfly
/// layers applying the standard DIT twiddles.  Proves the *same* swap
/// topology serves the complex kernel.
pub fn execute_fft_dfg(dfg: &Dfg, x: &[crate::model::Complex]) -> Vec<crate::model::Complex> {
    use crate::model::fft::bit_reversal_permutation;
    use crate::model::Complex;
    let n = dfg.points;
    assert_eq!(x.len(), n);
    let stages = log2_int(n);
    let perm = bit_reversal_permutation(n);
    let mut vals: Vec<Complex> = (0..n).map(|k| x[perm[k]]).collect();
    for s in 0..stages {
        let layer = s as u32 + 1;
        let mut next = vals.clone();
        for node in dfg.layer_nodes(layer) {
            let (i, j) = elements_of_pair(node.index as usize, s);
            let off = i & ((1 << s) - 1);
            let w = Complex::from_polar(
                1.0,
                -std::f64::consts::PI * off as f64 / (1 << s) as f64,
            );
            let wb = w.mul(vals[j]);
            next[i] = vals[i].add(wb);
            next[j] = vals[i].sub(wb);
        }
        vals = next;
    }
    vals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::butterfly::BpmmFactors;
    use crate::model::fft::dft_naive;
    use crate::model::Complex;
    use crate::util::prop::check;

    #[test]
    fn pair_element_roundtrip() {
        for n in [4usize, 16, 64, 256] {
            for s in 0..log2_int(n) {
                for p in 0..n / 2 {
                    let (i, j) = elements_of_pair(p, s);
                    assert_eq!(j - i, 1 << s);
                    assert_eq!(pair_of_element(i, s), p);
                    assert_eq!(pair_of_element(j, s), p);
                }
            }
        }
    }

    #[test]
    fn dfg_structure() {
        let g = build_butterfly_dfg(KernelKind::Bpmm, 32);
        assert_eq!(g.layers, 7); // load + 5 stages + store
        for layer in 0..g.layers {
            assert_eq!(g.layer_width(layer), 16);
        }
        g.validate_partial_order().unwrap();
        g.validate_layer_indexing().unwrap();
    }

    #[test]
    fn swap_distances_are_powers_of_two() {
        let g = build_butterfly_dfg(KernelKind::Fft, 64);
        for s in 1..log2_int(64) {
            let layer = s as u32 + 1;
            let mut dists: Vec<u32> = g
                .nodes
                .iter()
                .filter(|n| n.layer == layer)
                .flat_map(|n| g.in_edges(n.id))
                .filter_map(|e| match e.kind {
                    EdgeKind::CopyT { node_dist } => Some(node_dist),
                    _ => None,
                })
                .collect();
            dists.dedup();
            assert_eq!(dists, vec![1 << (s - 1)]);
        }
    }

    #[test]
    fn every_butterfly_node_has_local_and_remote_input() {
        let g = build_butterfly_dfg(KernelKind::Bpmm, 64);
        for node in g.nodes.iter().filter(|n| {
            matches!(n.op, NodeOp::Butterfly { stage } if stage > 0)
        }) {
            let ins: Vec<_> = g.in_edges(node.id).collect();
            assert_eq!(ins.len(), 2);
            let locals = ins.iter().filter(|e| e.kind == EdgeKind::CopyI).count();
            assert_eq!(locals, 1, "node {:?}", node.id);
        }
    }

    #[test]
    fn functional_execution_matches_reference() {
        check("dfg-bpmm-functional", 30, |rng| {
            let n = rng.pow2(4, 128);
            let f = BpmmFactors::random(n, rng);
            let x = rng.normal_vec(n);
            let g = build_butterfly_dfg(KernelKind::Bpmm, n);
            let got = execute_bpmm_dfg(&g, &f.stages, &x).unwrap();
            let mut want = x.clone();
            f.apply(&mut want);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn fft_dfg_computes_the_dft() {
        check("dfg-fft-functional", 20, |rng| {
            let n = rng.pow2(4, 256);
            let x: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
            let g = build_butterfly_dfg(KernelKind::Fft, n);
            let got = execute_fft_dfg(&g, &x);
            let want = dft_naive(&x);
            for (a, b) in got.iter().zip(&want) {
                assert!(a.sub(*b).abs() < 1e-7 * n as f64, "{a:?} vs {b:?}");
            }
        });
    }

    #[test]
    fn node_and_edge_counts() {
        let n = 128;
        let g = build_butterfly_dfg(KernelKind::Bpmm, n);
        let s = log2_int(n);
        assert_eq!(g.nodes.len(), (n / 2) * (s + 2));
        // Edges: stage0 has 1 in-edge per node, stages 1..s have 2, store 1.
        let want_edges = (n / 2) * (1 + 2 * (s - 1) + 1);
        assert_eq!(g.edges.len(), want_edges);
        assert_eq!(g.butterfly_node_count(), (n / 2) * s);
    }
}
