//! Multi-stage Cooley-Tukey division planning (Fig. 9 / §V-B).
//!
//! A kernel over `n` points that exceeds the single-DFG capacity
//! (256 FFT / 512 BPMM) is reshaped into an `r × c` matrix and executed
//! as: column-stage DFG (scale `r`, `c` sub-iterations per vector), a
//! synchronization barrier, an element-wise twiddle layer (FFT only),
//! then a row-stage DFG (scale `c`, `r` sub-iterations).  For scales
//! whose working set exceeds the SPM (the 64K example), the division
//! recurses on the larger factor, producing a ≥3-stage plan like the
//! paper's BERT-AT-all execution (1K-hidden FFT + two 256-point stages).

use anyhow::{bail, Result};

use crate::arch::ArchConfig;
use crate::model::log2_int;

use super::graph::KernelKind;

/// One stage of a kernel plan: a single-DFG butterfly of `points`,
/// executed `sub_iters` times per logical vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageDfg {
    pub kind: KernelKind,
    pub points: usize,
    /// Sub-iterations of this stage per input vector (matrix columns or
    /// rows of the reshape).
    pub sub_iters: usize,
    /// Whether an element-wise twiddle layer precedes this stage (FFT
    /// inter-stage factors; never set for BPMM).
    pub twiddle_before: bool,
    /// Whether this stage's weights/twiddles must be re-streamed from DDR
    /// (working set exceeded SPM residency).
    pub weights_from_ddr: bool,
}

/// A full execution plan for one kernel invocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelPlan {
    pub kind: KernelKind,
    /// Total transform length.
    pub n: usize,
    pub stages: Vec<StageDfg>,
    /// Logical vectors per invocation (batch × heads × rows …).
    pub vectors: usize,
}

impl KernelPlan {
    /// Total butterfly stages across the plan (must equal log2 n).
    pub fn total_depth(&self) -> usize {
        self.stages.iter().map(|s| log2_int(s.points)).sum()
    }

    /// Total butterfly-node evaluations per vector: (n/2) log2 n.
    pub fn nodes_per_vector(&self) -> usize {
        self.stages
            .iter()
            .map(|s| s.sub_iters * (s.points / 2) * log2_int(s.points))
            .sum()
    }

    /// MAC-relevant FLOPs per vector (2 flops per MAC slot).
    pub fn flops_per_vector(&self) -> f64 {
        let per_node = self.kind.ops_per_node() as f64 * 2.0;
        self.nodes_per_vector() as f64 * per_node
    }

    /// Weight bytes of the whole plan (per the paper's 64K example: a 64K
    /// butterfly's sparsity weights occupy 8.4 MB at fp16).
    pub fn weight_bytes(&self, elem_bytes: usize) -> usize {
        self.stages
            .iter()
            .map(|s| {
                s.sub_iters
                    * (s.points / 2)
                    * log2_int(s.points)
                    * self.kind.weight_scalars_per_node() as usize
                    * elem_bytes
            })
            .sum()
    }
}

/// Single-DFG capacity for a kernel kind (§V-B).
pub fn max_points(kind: KernelKind, arch: &ArchConfig) -> usize {
    match kind {
        KernelKind::Fft => arch.max_fft_points,
        KernelKind::Bpmm => arch.max_bpmm_points,
    }
}

/// The balanced division the paper's Fig. 14 sweep converges to:
/// `r = 2^ceil(log2(n)/2)` clipped to the capacity limit.
pub fn balanced_division(n: usize, cap: usize) -> (usize, usize) {
    let stages = log2_int(n);
    let mut r = 1usize << ((stages + 1) / 2);
    let mut c = n / r;
    while r > cap {
        r /= 2;
        c *= 2;
    }
    while c > cap {
        c /= 2;
        r *= 2;
    }
    assert_eq!(r * c, n);
    (r, c)
}

/// Enumerate all power-of-two divisions of `n` with both factors within
/// `[min_factor, cap]` (the Fig. 14 sweep space).
pub fn enumerate_divisions(n: usize, min_factor: usize, cap: usize) -> Vec<(usize, usize)> {
    let stages = log2_int(n);
    let mut out = Vec::new();
    for rb in 1..stages {
        let r = 1usize << rb;
        let c = n >> rb;
        if r >= min_factor && c >= min_factor && r <= cap && c <= cap {
            out.push((r, c));
        }
    }
    out
}

/// Build a kernel plan for `n` points and `vectors` logical vectors.
///
/// `division`: optional explicit (r, c) split for two-stage plans (used
/// by the Fig. 14 sweep); `None` picks the balanced division and recurses
/// as needed.
pub fn plan_kernel(
    kind: KernelKind,
    n: usize,
    vectors: usize,
    arch: &ArchConfig,
    division: Option<(usize, usize)>,
) -> Result<KernelPlan> {
    if !n.is_power_of_two() || n < 2 {
        bail!("kernel points {n} must be a power of two >= 2");
    }
    let cap = max_points(kind, arch);
    let mut stages = Vec::new();
    build_stages(kind, n, 1, arch, cap, division, &mut stages)?;
    // Mark DDR-resident weights: if the total working set (weights +
    // one vector in/out) exceeds SPM, later stages stream from DDR.
    let plan = KernelPlan { kind, n, stages, vectors };
    let mut plan = plan;
    let ws = plan.weight_bytes(arch.elem_bytes)
        + 2 * n * kind.planes() * arch.elem_bytes;
    if ws > arch.spm_bytes {
        for s in plan.stages.iter_mut().skip(1) {
            s.weights_from_ddr = true;
        }
    }
    Ok(plan)
}

fn build_stages(
    kind: KernelKind,
    n: usize,
    outer_iters: usize,
    arch: &ArchConfig,
    cap: usize,
    division: Option<(usize, usize)>,
    out: &mut Vec<StageDfg>,
) -> Result<()> {
    if n <= cap && division.is_none() {
        out.push(StageDfg {
            kind,
            points: n,
            sub_iters: outer_iters,
            twiddle_before: false,
            weights_from_ddr: false,
        });
        return Ok(());
    }
    let (r, c) = match division {
        Some((r, c)) => {
            if r * c != n {
                bail!("division {r}x{c} != {n}");
            }
            (r, c)
        }
        None => balanced_division(n, cap),
    };
    if r > cap || c > cap {
        // Recurse on the oversized factor (the 64K→1K×(256×256) case).
        if r > cap {
            build_stages(kind, r, outer_iters * c, arch, cap, None, out)?;
        } else {
            out.push(StageDfg {
                kind,
                points: r,
                sub_iters: outer_iters * c,
                twiddle_before: false,
                weights_from_ddr: false,
            });
        }
        let twiddle = kind == KernelKind::Fft;
        if c > cap {
            let mark = out.len();
            build_stages(kind, c, outer_iters * r, arch, cap, None, out)?;
            if twiddle {
                out[mark].twiddle_before = true;
            }
        } else {
            out.push(StageDfg {
                kind,
                points: c,
                sub_iters: outer_iters * r,
                twiddle_before: twiddle,
                weights_from_ddr: false,
            });
        }
        return Ok(());
    }
    // Plain two-stage split: column DFG (scale r, c iters), row DFG.
    out.push(StageDfg {
        kind,
        points: r,
        sub_iters: outer_iters * c,
        twiddle_before: false,
        weights_from_ddr: false,
    });
    out.push(StageDfg {
        kind,
        points: c,
        sub_iters: outer_iters * r,
        twiddle_before: kind == KernelKind::Fft,
        weights_from_ddr: false,
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn arch() -> ArchConfig {
        ArchConfig::full()
    }

    #[test]
    fn small_kernel_is_single_stage() {
        let p = plan_kernel(KernelKind::Fft, 256, 10, &arch(), None).unwrap();
        assert_eq!(p.stages.len(), 1);
        assert_eq!(p.stages[0].points, 256);
        assert_eq!(p.total_depth(), 8);
    }

    #[test]
    fn paper_8192_example_division() {
        // Fig. 9: 8192 → 128 × 64 (BPMM capacity 512 ⇒ balanced 128x64).
        let p = plan_kernel(KernelKind::Bpmm, 8192, 1, &arch(), None).unwrap();
        assert_eq!(p.stages.len(), 2);
        assert_eq!((p.stages[0].points, p.stages[1].points), (128, 64));
        assert_eq!(p.stages[0].sub_iters, 64); // 64 columns of scale-128
        assert_eq!(p.stages[1].sub_iters, 128);
        assert!(!p.stages[0].twiddle_before);
        assert!(!p.stages[1].twiddle_before); // BPMM: no twiddle layer
        assert_eq!(p.total_depth(), 13);
    }

    #[test]
    fn fft_gets_twiddle_layer() {
        let p = plan_kernel(KernelKind::Fft, 1024, 1, &arch(), None).unwrap();
        assert_eq!(p.stages.len(), 2);
        assert!(p.stages[1].twiddle_before);
    }

    #[test]
    fn paper_64k_fft_division() {
        // §V-B: "the 64K vector can be reshaped as a 256 × 256 matrix",
        // both within the FFT cap, with weights/twiddles swapping between
        // SPM and DDR as needed.
        let p = plan_kernel(KernelKind::Fft, 64 * 1024, 1, &arch(), None).unwrap();
        assert_eq!(p.stages.len(), 2);
        assert!(p.stages.iter().all(|s| s.points == 256));
        assert_eq!(p.total_depth(), 16);
    }

    #[test]
    fn weight_bytes_64k_exceeds_spm() {
        // Paper: "a 64K vector whose sparsity weights occupy 8.4MB
        // storage, while the SPM capacity is 4MB" (full-depth butterfly:
        // (n/2)·16 stages·4 scalars·2 B = 8 MB).  Our two-stage Monarch
        // factoring halves the per-element depth (4 MB of weights), but
        // together with activations it still exceeds SPM, so the plan
        // must flag DDR weight streaming.
        let full_depth_bytes = (64 * 1024 / 2) * 16 * 4 * 4; // fp32 master weights
        assert!(full_depth_bytes > arch().spm_bytes);
        let p = plan_kernel(KernelKind::Bpmm, 64 * 1024, 1, &arch(), None).unwrap();
        let wb = p.weight_bytes(2);
        assert!(wb + 2 * 64 * 1024 * 2 > arch().spm_bytes);
        assert!(
            p.stages.iter().skip(1).any(|s| s.weights_from_ddr),
            "64K BPMM plan must stream weights from DDR"
        );
    }

    #[test]
    fn explicit_division_respected() {
        let p =
            plan_kernel(KernelKind::Bpmm, 2048, 1, &arch(), Some((32, 64))).unwrap();
        assert_eq!((p.stages[0].points, p.stages[1].points), (32, 64));
        assert!(plan_kernel(KernelKind::Bpmm, 2048, 1, &arch(), Some((32, 32))).is_err());
    }

    #[test]
    fn enumerate_divisions_covers_fig14_space() {
        let divs = enumerate_divisions(2048, 16, 512);
        assert!(divs.contains(&(32, 64)));
        assert!(divs.contains(&(64, 32)));
        assert!(divs.contains(&(16, 128)));
        for (r, c) in divs {
            assert_eq!(r * c, 2048);
        }
    }

    #[test]
    fn plan_depth_invariant() {
        check("plan-depth-is-log2n", 50, |rng| {
            let n = rng.pow2(2, 1 << 16);
            let kind = if rng.chance(0.5) { KernelKind::Fft } else { KernelKind::Bpmm };
            let p = plan_kernel(kind, n, 1, &ArchConfig::full(), None).unwrap();
            assert_eq!(p.total_depth(), log2_int(n));
            // Node count conservation: (n/2) log2 n butterflies per vector.
            assert_eq!(p.nodes_per_vector(), n / 2 * log2_int(n));
        });
    }

    #[test]
    fn balanced_division_examples() {
        // Fig. 14 best divisions: 2k→32x64, 4k→64x64, 8k→128x64.
        assert_eq!(balanced_division(2048, 512), (64, 32)); // or 32x64 mirror
        assert_eq!(balanced_division(4096, 512), (64, 64));
        assert_eq!(balanced_division(8192, 512), (128, 64));
    }
}
