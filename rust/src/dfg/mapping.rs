//! Node→PE mapping (Fig. 7b/c): balanced round-robin with wrap-back.
//!
//! Layer node `k` is assigned to PE `k mod P`.  Consequences the paper
//! relies on:
//!
//! * every layer spreads evenly over the array (workload balance);
//! * a stage with node swap distance `d = 2^t` becomes a PE exchange
//!   between `p` and `p XOR d` when `d < P` — using disjoint mesh links
//!   per stage in both directions ("all vertical and horizontal data
//!   paths in full throughput");
//! * when `d` is a multiple of `P` the partner wraps back to the same PE
//!   (`PE1 pairs with PE17 % 16 = PE1`) and the transfer is local — later
//!   stages need no NoC traffic at all.

use crate::arch::ArchConfig;

use super::butterfly::swap_distance;
use super::graph::Dfg;

/// A mapping of one DFG onto the PE array.
///
/// Which mapping a lowering uses is a [`crate::dfg::strategy::DataflowStrategy`]
/// decision (`DataflowStrategy::mapping`); the paper's recipe is
/// [`Mapping::for_points`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mapping {
    /// Number of PEs.
    pub num_pes: usize,
    /// Width of each layer in nodes (uniform for butterfly DFGs).
    pub layer_width: usize,
}

impl Mapping {
    /// Round-robin mapping of a butterfly DFG.
    pub fn round_robin(dfg: &Dfg, arch: &ArchConfig) -> Self {
        Mapping { num_pes: arch.num_pes(), layer_width: dfg.layer_width(0) }
    }

    /// Round-robin mapping of the `points`-point butterfly DFG *without*
    /// materializing the graph: every butterfly layer (and the load/store
    /// layers) of an `n`-point kernel is uniformly `n / 2` nodes wide, so
    /// the mapping is fully determined by `points` and the PE count.
    /// Identical to [`Mapping::round_robin`] over
    /// [`super::butterfly::build_butterfly_dfg`] — asserted by tests —
    /// but O(1); lowering uses it so the hot re-lowering path stops
    /// paying an O(n log n) graph build per call.
    pub fn for_points(points: usize, arch: &ArchConfig) -> Self {
        Mapping { num_pes: arch.num_pes(), layer_width: points / 2 }
    }

    /// Per-PE node counts for one layer, indexable without re-deriving
    /// the division/remainder per (iter, layer, pe) in lowering loops.
    pub fn nodes_per_pe(&self) -> Vec<usize> {
        (0..self.num_pes).map(|p| self.nodes_on_pe(p)).collect()
    }

    /// PE of layer-node `k`.
    pub fn pe_of(&self, node_index: usize) -> usize {
        node_index % self.num_pes
    }

    /// Nodes of a layer hosted by PE `p`.
    pub fn nodes_on_pe(&self, p: usize) -> usize {
        let full = self.layer_width / self.num_pes;
        let rem = self.layer_width % self.num_pes;
        full + usize::from(p < rem)
    }

    /// Max nodes across PEs (the per-layer block size).
    pub fn max_nodes_per_pe(&self) -> usize {
        self.layer_width.div_ceil(self.num_pes)
    }

    /// Number of PEs that host at least one node.
    pub fn active_pes(&self) -> usize {
        self.layer_width.min(self.num_pes)
    }

    /// Partner PE for the swap into butterfly stage `stage` (None if the
    /// exchange is PE-local: stage 0, or distance wraps to a multiple of
    /// P, or distance below the per-PE node block... with round-robin the
    /// rule is exact: partner = p XOR (d mod' P)).
    pub fn partner_pe(&self, p: usize, stage: usize) -> Option<usize> {
        let d = swap_distance(stage);
        if d == 0 {
            return None;
        }
        if d % self.num_pes == 0 {
            // Wrap-back: distance is a multiple of P → same PE.
            return None;
        }
        if d >= self.num_pes {
            // Power-of-two distance above P that is not a multiple of P
            // cannot happen (both are powers of two), but guard anyway.
            return None;
        }
        Some(p ^ d)
    }

    /// NoC hop count for the swap into `stage` from PE `p` (0 if local).
    pub fn swap_hops(&self, p: usize, stage: usize, arch: &ArchConfig) -> usize {
        match self.partner_pe(p, stage) {
            Some(q) => arch.hop_distance(p, q),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::butterfly::build_butterfly_dfg;
    use crate::dfg::graph::KernelKind;
    use crate::util::prop::check;

    fn mapping(n: usize) -> (Mapping, ArchConfig) {
        let arch = ArchConfig::full();
        let dfg = build_butterfly_dfg(KernelKind::Bpmm, n);
        (Mapping::round_robin(&dfg, &arch), arch)
    }

    #[test]
    fn paper_32_point_example() {
        // 32 points on 4x4: one node per PE per layer (Fig. 7b).
        let (m, _) = mapping(32);
        assert_eq!(m.layer_width, 16);
        for p in 0..16 {
            assert_eq!(m.nodes_on_pe(p), 1);
        }
        // Stage swap partners: distances 1,2,4,8 then wrap to local.
        assert_eq!(m.partner_pe(0, 1), Some(1));
        assert_eq!(m.partner_pe(0, 2), Some(2));
        assert_eq!(m.partner_pe(0, 3), Some(4));
        assert_eq!(m.partner_pe(0, 4), Some(8));
        assert_eq!(m.partner_pe(1, 5), None); // PE1 ↔ PE17 % 16 = PE1
    }

    #[test]
    fn for_points_matches_round_robin() {
        let arch = ArchConfig::full();
        for n in [4usize, 16, 32, 64, 256, 1024] {
            for kind in [KernelKind::Bpmm, KernelKind::Fft] {
                let dfg = build_butterfly_dfg(kind, n);
                let a = Mapping::round_robin(&dfg, &arch);
                let b = Mapping::for_points(n, &arch);
                assert_eq!(a.layer_width, b.layer_width, "{kind:?} n={n}");
                assert_eq!(a.num_pes, b.num_pes);
                assert_eq!(a.nodes_per_pe(), b.nodes_per_pe());
            }
        }
    }

    #[test]
    fn balance_invariant() {
        check("mapping-balance", 50, |rng| {
            let n = rng.pow2(4, 1 << 10);
            let (m, _) = mapping(n);
            let min = (0..16).map(|p| m.nodes_on_pe(p)).min().unwrap();
            let max = (0..16).map(|p| m.nodes_on_pe(p)).max().unwrap();
            assert!(max - min <= 1, "unbalanced: {min}..{max}");
            let total: usize = (0..16).map(|p| m.nodes_on_pe(p)).sum();
            assert_eq!(total, m.layer_width);
        });
    }

    #[test]
    fn partner_is_symmetric() {
        let (m, _) = mapping(256);
        for stage in 1..8 {
            for p in 0..16 {
                if let Some(q) = m.partner_pe(p, stage) {
                    assert_eq!(m.partner_pe(q, stage), Some(p), "stage {stage}");
                    assert_ne!(p, q);
                }
            }
        }
    }

    #[test]
    fn late_stages_are_local() {
        let (m, arch) = mapping(1 << 9); // 512 points, stages up to 8
        // Stage 5: d = 16 = P → local.  Stages 6+: d = 32, 64 → local.
        for stage in 5..9 {
            for p in 0..16 {
                assert_eq!(m.swap_hops(p, stage, &arch), 0, "stage {stage}");
            }
        }
        // Early stages are remote.
        assert!(m.swap_hops(0, 1, &arch) > 0);
    }

    #[test]
    fn stage_links_are_disjoint_across_pairs() {
        // Each stage's exchange partitions PEs into disjoint pairs.
        let (m, _) = mapping(512);
        for stage in 1..5 {
            let mut used = vec![false; 16];
            for p in 0..16 {
                if used[p] {
                    continue;
                }
                if let Some(q) = m.partner_pe(p, stage) {
                    assert!(!used[q]);
                    used[p] = true;
                    used[q] = true;
                }
            }
        }
    }

    #[test]
    fn small_dfg_leaves_pes_idle() {
        // 16-point kernel: 8 pairs < 16 PEs (the Fig. 14 shallow-stage
        // underutilization mechanism).
        let (m, _) = mapping(16);
        assert_eq!(m.active_pes(), 8);
        assert_eq!(m.nodes_on_pe(15), 0);
    }
}
