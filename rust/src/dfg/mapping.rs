//! Node→PE mapping (Fig. 7b/c): balanced round-robin with wrap-back.
//!
//! Layer node `k` is assigned to PE `k mod P`.  Consequences the paper
//! relies on:
//!
//! * every layer spreads evenly over the array (workload balance);
//! * a stage with node swap distance `d = 2^t` becomes a PE exchange
//!   between `p` and `p XOR d` when `d < P` — using disjoint mesh links
//!   per stage in both directions ("all vertical and horizontal data
//!   paths in full throughput");
//! * when `d` is a multiple of `P` the partner wraps back to the same PE
//!   (`PE1 pairs with PE17 % 16 = PE1`) and the transfer is local — later
//!   stages need no NoC traffic at all.

use anyhow::{ensure, Result};

use crate::arch::{ArchConfig, FaultModel};

use super::butterfly::swap_distance;
use super::graph::Dfg;

/// A mapping of one DFG onto the PE array.
///
/// Which mapping a lowering uses is a [`crate::dfg::strategy::DataflowStrategy`]
/// decision (`DataflowStrategy::mapping`); the paper's recipe is
/// [`Mapping::for_points`].
///
/// A mapping distributes layer nodes round-robin over *logical slots*
/// and the XOR partner rule runs in slot space.  On the healthy machine
/// (`live == None`) the slots are the physical PEs themselves — the
/// paper's Fig. 7b/c mapping, bit for bit.  Under a
/// [`FaultModel`] ([`Mapping::fault_aware`]) the slots are the largest
/// power-of-two subset of live PEs, so the XOR algebra (and with it the
/// partner-symmetry / disjoint-pairs properties the lowering relies on)
/// survives arbitrary dead-PE patterns; dead and surplus PEs simply
/// host zero nodes.  Swap hop counts use *physical* PE coordinates, so
/// remap detours across the hole left by a dead PE are priced
/// naturally.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mapping {
    /// Number of physical PEs (always `arch.num_pes()` — the lowering
    /// contract, even when some of them are dead).
    pub num_pes: usize,
    /// Width of each layer in nodes (uniform for butterfly DFGs).
    pub layer_width: usize,
    /// Logical-slot → physical-PE permutation for fault-aware mappings;
    /// `None` = identity over all PEs (the paper's round-robin).
    pub live: Option<Vec<u16>>,
}

impl Mapping {
    /// Round-robin mapping of a butterfly DFG.
    pub fn round_robin(dfg: &Dfg, arch: &ArchConfig) -> Self {
        Mapping { num_pes: arch.num_pes(), layer_width: dfg.layer_width(0), live: None }
    }

    /// Round-robin mapping of the `points`-point butterfly DFG *without*
    /// materializing the graph: every butterfly layer (and the load/store
    /// layers) of an `n`-point kernel is uniformly `n / 2` nodes wide, so
    /// the mapping is fully determined by `points` and the PE count.
    /// Identical to [`Mapping::round_robin`] over
    /// [`super::butterfly::build_butterfly_dfg`] — asserted by tests —
    /// but O(1); lowering uses it so the hot re-lowering path stops
    /// paying an O(n log n) graph build per call.
    pub fn for_points(points: usize, arch: &ArchConfig) -> Self {
        Mapping { num_pes: arch.num_pes(), layer_width: points / 2, live: None }
    }

    /// Round-robin mapping compacted onto the live PEs of a faulty mesh:
    /// the first `2^⌊log2(live)⌋` live PEs (ascending index) become the
    /// logical slots.  Keeping the slot count a power of two preserves
    /// the XOR partner rule exactly; the surviving-but-surplus PEs idle.
    /// Errors (no panic) when the fault set leaves no PE to map onto.
    pub fn fault_aware(points: usize, arch: &ArchConfig, faults: &FaultModel) -> Result<Self> {
        let live = faults.live_pes();
        ensure!(
            !live.is_empty(),
            "unmappable fault set: all {} PEs are dead",
            arch.num_pes()
        );
        // Largest power of two <= live.len().
        let slots = (live.len() + 1).next_power_of_two() / 2;
        if slots == arch.num_pes() {
            // No PE is dead: identical to the paper's mapping (and to
            // its cache entries).
            return Ok(Self::for_points(points, arch));
        }
        Ok(Mapping {
            num_pes: arch.num_pes(),
            layer_width: points / 2,
            live: Some(live[..slots].to_vec()),
        })
    }

    /// Number of logical slots nodes are distributed over (the PE count
    /// on the healthy machine).
    pub fn slots(&self) -> usize {
        self.live.as_ref().map_or(self.num_pes, Vec::len)
    }

    /// Physical PE of logical slot `s`.
    #[inline]
    fn phys(&self, s: usize) -> usize {
        match &self.live {
            Some(l) => l[s] as usize,
            None => s,
        }
    }

    /// Logical slot of physical PE `p` (`None` if `p` hosts no slot —
    /// dead, or surplus after power-of-two compaction).
    #[inline]
    fn slot_of(&self, p: usize) -> Option<usize> {
        match &self.live {
            Some(l) => l.iter().position(|&q| q as usize == p),
            None => (p < self.num_pes).then_some(p),
        }
    }

    /// Per-PE node counts for one layer, indexed by *physical* PE (dead
    /// and surplus PEs report zero), indexable without re-deriving the
    /// division/remainder per (iter, layer, pe) in lowering loops.
    pub fn nodes_per_pe(&self) -> Vec<usize> {
        (0..self.num_pes).map(|p| self.nodes_on_pe(p)).collect()
    }

    /// Physical PE of layer-node `k`.
    pub fn pe_of(&self, node_index: usize) -> usize {
        self.phys(node_index % self.slots())
    }

    /// Nodes of a layer hosted by physical PE `p`.
    pub fn nodes_on_pe(&self, p: usize) -> usize {
        let Some(slot) = self.slot_of(p) else {
            return 0;
        };
        let slots = self.slots();
        let full = self.layer_width / slots;
        let rem = self.layer_width % slots;
        full + usize::from(slot < rem)
    }

    /// Max nodes across PEs (the per-layer block size).
    pub fn max_nodes_per_pe(&self) -> usize {
        self.layer_width.div_ceil(self.slots())
    }

    /// Number of PEs that host at least one node.
    pub fn active_pes(&self) -> usize {
        self.layer_width.min(self.slots())
    }

    /// Partner PE for the swap into butterfly stage `stage` (None if the
    /// exchange is PE-local: stage 0, or distance wraps to a multiple of
    /// the slot count, or `p` hosts no slot; with round-robin the rule
    /// is exact in slot space: partner slot = slot XOR d, translated
    /// back to the physical PE).
    pub fn partner_pe(&self, p: usize, stage: usize) -> Option<usize> {
        let slots = self.slots();
        let slot = self.slot_of(p)?;
        let d = swap_distance(stage);
        if d == 0 {
            return None;
        }
        if d % slots == 0 {
            // Wrap-back: distance is a multiple of the slot count → same PE.
            return None;
        }
        if d >= slots {
            // Power-of-two distance above the slot count that is not a
            // multiple of it cannot happen (both are powers of two), but
            // guard anyway.
            return None;
        }
        Some(self.phys(slot ^ d))
    }

    /// NoC hop count for the swap into `stage` from PE `p` (0 if local).
    pub fn swap_hops(&self, p: usize, stage: usize, arch: &ArchConfig) -> usize {
        match self.partner_pe(p, stage) {
            Some(q) => arch.hop_distance(p, q),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::butterfly::build_butterfly_dfg;
    use crate::dfg::graph::KernelKind;
    use crate::util::prop::check;

    fn mapping(n: usize) -> (Mapping, ArchConfig) {
        let arch = ArchConfig::full();
        let dfg = build_butterfly_dfg(KernelKind::Bpmm, n);
        (Mapping::round_robin(&dfg, &arch), arch)
    }

    #[test]
    fn paper_32_point_example() {
        // 32 points on 4x4: one node per PE per layer (Fig. 7b).
        let (m, _) = mapping(32);
        assert_eq!(m.layer_width, 16);
        for p in 0..16 {
            assert_eq!(m.nodes_on_pe(p), 1);
        }
        // Stage swap partners: distances 1,2,4,8 then wrap to local.
        assert_eq!(m.partner_pe(0, 1), Some(1));
        assert_eq!(m.partner_pe(0, 2), Some(2));
        assert_eq!(m.partner_pe(0, 3), Some(4));
        assert_eq!(m.partner_pe(0, 4), Some(8));
        assert_eq!(m.partner_pe(1, 5), None); // PE1 ↔ PE17 % 16 = PE1
    }

    #[test]
    fn for_points_matches_round_robin() {
        let arch = ArchConfig::full();
        for n in [4usize, 16, 32, 64, 256, 1024] {
            for kind in [KernelKind::Bpmm, KernelKind::Fft] {
                let dfg = build_butterfly_dfg(kind, n);
                let a = Mapping::round_robin(&dfg, &arch);
                let b = Mapping::for_points(n, &arch);
                assert_eq!(a.layer_width, b.layer_width, "{kind:?} n={n}");
                assert_eq!(a.num_pes, b.num_pes);
                assert_eq!(a.nodes_per_pe(), b.nodes_per_pe());
            }
        }
    }

    #[test]
    fn balance_invariant() {
        check("mapping-balance", 50, |rng| {
            let n = rng.pow2(4, 1 << 10);
            let (m, _) = mapping(n);
            let min = (0..16).map(|p| m.nodes_on_pe(p)).min().unwrap();
            let max = (0..16).map(|p| m.nodes_on_pe(p)).max().unwrap();
            assert!(max - min <= 1, "unbalanced: {min}..{max}");
            let total: usize = (0..16).map(|p| m.nodes_on_pe(p)).sum();
            assert_eq!(total, m.layer_width);
        });
    }

    #[test]
    fn partner_is_symmetric() {
        let (m, _) = mapping(256);
        for stage in 1..8 {
            for p in 0..16 {
                if let Some(q) = m.partner_pe(p, stage) {
                    assert_eq!(m.partner_pe(q, stage), Some(p), "stage {stage}");
                    assert_ne!(p, q);
                }
            }
        }
    }

    #[test]
    fn late_stages_are_local() {
        let (m, arch) = mapping(1 << 9); // 512 points, stages up to 8
        // Stage 5: d = 16 = P → local.  Stages 6+: d = 32, 64 → local.
        for stage in 5..9 {
            for p in 0..16 {
                assert_eq!(m.swap_hops(p, stage, &arch), 0, "stage {stage}");
            }
        }
        // Early stages are remote.
        assert!(m.swap_hops(0, 1, &arch) > 0);
    }

    #[test]
    fn stage_links_are_disjoint_across_pairs() {
        // Each stage's exchange partitions PEs into disjoint pairs.
        let (m, _) = mapping(512);
        for stage in 1..5 {
            let mut used = vec![false; 16];
            for p in 0..16 {
                if used[p] {
                    continue;
                }
                if let Some(q) = m.partner_pe(p, stage) {
                    assert!(!used[q]);
                    used[p] = true;
                    used[q] = true;
                }
            }
        }
    }

    #[test]
    fn small_dfg_leaves_pes_idle() {
        // 16-point kernel: 8 pairs < 16 PEs (the Fig. 14 shallow-stage
        // underutilization mechanism).
        let (m, _) = mapping(16);
        assert_eq!(m.active_pes(), 8);
        assert_eq!(m.nodes_on_pe(15), 0);
    }

    fn faulty(dead: &[usize]) -> (Mapping, ArchConfig) {
        let arch = ArchConfig::full();
        let mut fm = FaultModel::for_arch(&arch);
        for &p in dead {
            fm.kill_pe(p).unwrap();
        }
        (Mapping::fault_aware(256, &arch, &fm).unwrap(), arch)
    }

    #[test]
    fn fault_aware_without_dead_pes_is_the_paper_mapping() {
        let arch = ArchConfig::full();
        let fm = FaultModel::for_arch(&arch);
        let m = Mapping::fault_aware(256, &arch, &fm).unwrap();
        assert_eq!(m, Mapping::for_points(256, &arch));
        assert!(m.live.is_none());
    }

    #[test]
    fn fault_aware_avoids_dead_pes_and_conserves_nodes() {
        // One dead PE → 15 live → 8 slots.
        let (m, _) = faulty(&[5]);
        assert_eq!(m.num_pes, 16, "lowering contract: physical PE count");
        assert_eq!(m.slots(), 8);
        assert_eq!(m.nodes_on_pe(5), 0, "dead PE hosts nothing");
        let per = m.nodes_per_pe();
        assert_eq!(per.len(), 16);
        assert_eq!(per.iter().sum::<usize>(), m.layer_width, "nodes conserved");
        let (lo, hi) = per
            .iter()
            .filter(|&&n| n > 0)
            .fold((usize::MAX, 0), |(lo, hi), &n| (lo.min(n), hi.max(n)));
        assert!(hi - lo <= 1, "balanced over live slots: {lo}..{hi}");
        for k in 0..m.layer_width {
            assert_ne!(m.pe_of(k), 5, "no node lands on the dead PE");
        }
    }

    #[test]
    fn fault_aware_partner_rule_stays_symmetric_and_disjoint() {
        let (m, _) = faulty(&[0, 3, 9]); // 13 live → 8 slots
        for stage in 1..6 {
            let mut used = vec![false; 16];
            for p in 0..16 {
                if let Some(q) = m.partner_pe(p, stage) {
                    assert_eq!(m.partner_pe(q, stage), Some(p), "stage {stage}");
                    assert_ne!(p, q);
                    assert!(m.nodes_on_pe(q) > 0, "partner must be a live slot");
                    assert!(!used[p] && !used[q], "pairs disjoint at stage {stage}");
                    used[p] = true;
                    used[q] = true;
                } else if m.nodes_on_pe(p) > 0 {
                    // A live slot with no partner means wrap-back: on 8
                    // slots that starts at stage 4 (d = 8).
                    assert!(stage >= 4, "unexpected local exchange at stage {stage}");
                }
            }
        }
        // Wrap-back now happens at the slot count (8), not the PE count.
        let live0 = (0..16).find(|&p| m.nodes_on_pe(p) > 0).unwrap();
        assert_eq!(m.partner_pe(live0, 4), None, "d=8 wraps back on 8 slots");
    }

    #[test]
    fn fault_aware_swap_hops_price_the_detour() {
        // Killing PE 1 forces slot 1 onto PE 2: slot pair (0,1) is now
        // PE0↔PE2, two mesh hops instead of one.
        let (m, arch) = faulty(&[1]);
        assert_eq!(m.partner_pe(0, 1), Some(2));
        assert_eq!(m.swap_hops(0, 1, &arch), 2);
    }

    #[test]
    fn fault_aware_rejects_the_all_dead_mesh() {
        // FaultModel itself refuses to kill the last PE, so exercise the
        // mapping-level guard through a model with every PE marked dead
        // via the seeded constructor's error path instead.
        let arch = ArchConfig::full();
        let err = FaultModel::seeded(&arch, 1, 16, 0, 1, 0).unwrap_err().to_string();
        assert_eq!(err, "fault set kills every PE (16 dead of 16 total)");
    }
}
