//! Layer-tagged dataflow-graph IR.
//!
//! The key structural idea of the paper (§III-B): the butterfly's mutual
//! element swap violates DFG partial ordering, so nodes are *extended into
//! layers* and every edge goes from layer `l` to layer `l+1` — either a
//! local `COPY_I` (producer and consumer land on the same PE) or a remote
//! `COPY_T` (they don't).  Locality is decided by the mapping, but the
//! *node distance* is a graph property recorded on the edge.

use anyhow::{bail, Result};

/// Kernel family a DFG implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Real-valued butterfly-pattern matrix multiply (linear layers).
    Bpmm,
    /// Complex radix-2 FFT stage chain (attention mixing).
    Fft,
}

impl KernelKind {
    /// Scalars per element (complex carries re+im planes).
    pub fn planes(self) -> usize {
        match self {
            KernelKind::Bpmm => 1,
            KernelKind::Fft => 2,
        }
    }

    /// Compute slots per butterfly node per lane (see DESIGN.md cost
    /// model): BPMM 2x2 block = 4 FMA; FFT complex butterfly = complex
    /// multiply (4 mul + 2 add) + two complex adds (4 add) = 10 slots.
    pub fn ops_per_node(self) -> u64 {
        match self {
            KernelKind::Bpmm => 4,
            KernelKind::Fft => 10,
        }
    }

    /// Weight scalars fetched per node per stage (BPMM: the 2x2 block;
    /// FFT: one complex twiddle).
    pub fn weight_scalars_per_node(self) -> u64 {
        match self {
            KernelKind::Bpmm => 4,
            KernelKind::Fft => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Bpmm => "BPMM",
            KernelKind::Fft => "FFT",
        }
    }
}

/// Node identifier (index into `Dfg::nodes`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// What a node does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeOp {
    /// Fetch two adjacent input elements from SPM (layer 0).
    Load,
    /// One 2x2 butterfly at `stage`, on pair index `pair`.
    Butterfly { stage: u32 },
    /// Element-wise twiddle multiply (between Fig. 9 stage DFGs).
    Twiddle,
    /// Write two result elements back to SPM (final layer).
    Store,
}

/// Edge kind after the layer reconstruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Local transfer inside a PE (kept half).
    CopyI,
    /// Remote transfer across the NoC (swapped half); `node_dist` is the
    /// distance in layer-node indices (1, 2, 4, ... for butterflies).
    CopyT { node_dist: u32 },
}

/// A DFG node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    /// Layer index (0 = load layer).
    pub layer: u32,
    /// Position within the layer (pair index for butterfly layers).
    pub index: u32,
    pub op: NodeOp,
}

/// An edge between consecutive layers.
#[derive(Debug, Clone)]
pub struct Edge {
    pub from: NodeId,
    pub to: NodeId,
    pub kind: EdgeKind,
}

/// A multilayer dataflow graph.
#[derive(Debug, Clone)]
pub struct Dfg {
    pub kind: KernelKind,
    /// Vector length this DFG transforms.
    pub points: usize,
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
    /// Number of layers (load + butterfly stages + store).
    pub layers: u32,
}

impl Dfg {
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    /// Nodes of one layer, ordered by index.
    pub fn layer_nodes(&self, layer: u32) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(move |n| n.layer == layer)
    }

    pub fn layer_width(&self, layer: u32) -> usize {
        self.layer_nodes(layer).count()
    }

    /// Incoming edges of a node.
    pub fn in_edges(&self, id: NodeId) -> impl Iterator<Item = &Edge> {
        self.edges.iter().filter(move |e| e.to == id)
    }

    /// Outgoing edges of a node.
    pub fn out_edges(&self, id: NodeId) -> impl Iterator<Item = &Edge> {
        self.edges.iter().filter(move |e| e.from == id)
    }

    /// Validate the partial-order invariant (Fig. 5b): every edge spans
    /// exactly one layer, forward.  This is the property the multilayer
    /// reconstruction exists to establish.
    pub fn validate_partial_order(&self) -> Result<()> {
        for e in &self.edges {
            let from = self.node(e.from);
            let to = self.node(e.to);
            if to.layer != from.layer + 1 {
                bail!(
                    "edge {:?}->{:?} spans layers {}->{} (must be +1)",
                    e.from,
                    e.to,
                    from.layer,
                    to.layer
                );
            }
        }
        Ok(())
    }

    /// Validate that node indices within each layer are dense [0, width).
    pub fn validate_layer_indexing(&self) -> Result<()> {
        for layer in 0..self.layers {
            let mut idx: Vec<u32> = self.layer_nodes(layer).map(|n| n.index).collect();
            idx.sort_unstable();
            for (want, got) in idx.iter().enumerate() {
                if *got != want as u32 {
                    bail!("layer {layer} indices not dense: {idx:?}");
                }
            }
        }
        Ok(())
    }

    /// Total butterfly compute nodes (excludes load/store/twiddle).
    pub fn butterfly_node_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, NodeOp::Butterfly { .. }))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Dfg {
        // load(0) -> bf(0) with one edge.
        let nodes = vec![
            Node { id: NodeId(0), layer: 0, index: 0, op: NodeOp::Load },
            Node {
                id: NodeId(1),
                layer: 1,
                index: 0,
                op: NodeOp::Butterfly { stage: 0 },
            },
        ];
        let edges = vec![Edge { from: NodeId(0), to: NodeId(1), kind: EdgeKind::CopyI }];
        Dfg { kind: KernelKind::Bpmm, points: 2, nodes, edges, layers: 2 }
    }

    #[test]
    fn partial_order_ok() {
        tiny_graph().validate_partial_order().unwrap();
    }

    #[test]
    fn partial_order_violation_detected() {
        let mut g = tiny_graph();
        // Same-layer edge (the Fig. 5a incoordination).
        g.edges.push(Edge { from: NodeId(1), to: NodeId(1), kind: EdgeKind::CopyI });
        assert!(g.validate_partial_order().is_err());
    }

    #[test]
    fn kernel_kind_parameters() {
        assert_eq!(KernelKind::Bpmm.planes(), 1);
        assert_eq!(KernelKind::Fft.planes(), 2);
        assert!(KernelKind::Fft.ops_per_node() > KernelKind::Bpmm.ops_per_node());
    }
}
