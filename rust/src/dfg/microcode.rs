//! Lowering a stage DFG to per-PE micro-code blocks (Fig. 8).
//!
//! Tensor workloads have "explicit computational certainty", so the
//! instructions of one DFG iteration on one PE are grouped into
//! sequential *Micro Code Blocks*, one per function unit episode:
//!
//! * `LOAD`  (layer 0)        — fetch the PE's input elements from SPM;
//! * `WLOAD` (per stage)      — fetch the stage's weights/twiddles
//!   (broadcast across SIMD lanes);
//! * `CAL`   (per stage)      — the PE's butterfly nodes of that layer;
//! * `FLOW`  (between stages) — the swapped halves travelling to the
//!   partner PE over the mesh (skipped when the wrap-back rule makes the
//!   exchange local);
//! * `STORE` (final layer)    — results back to SPM.
//!
//! Each block carries the `{layer, iter}` priority bit-string of the
//! paper's block scheduler and its dependence edges; the cycle-level
//! simulator turns the raw quantities into time.

use crate::arch::{ArchConfig, RouteTable, UnitKind};
use crate::model::log2_int;

use super::graph::KernelKind;
use super::mapping::Mapping;
use super::stages::StageDfg;

/// Block identifier (index into `Program::blocks`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

/// One coarse-grained micro-code block.
#[derive(Debug, Clone)]
pub struct Block {
    pub pe: u16,
    pub unit: UnitKind,
    /// Priority major: layer index within the stage DFG.
    pub layer: u16,
    /// Priority minor: DFG iteration index.
    pub iter: u32,
    /// Lane-scaled scalars moved (load/store inputs, flow payload): one
    /// per SIMD lane per element-plane.
    pub scalars_wide: u64,
    /// Broadcast scalars (weights/twiddles — lane-invariant).
    pub scalars_bcast: u64,
    /// Compute slots per lane (CAL blocks).
    pub ops: u64,
    /// Mesh hops to the destination (FLOW blocks).
    pub noc_hops: u16,
    /// Destination PE (FLOW blocks).
    pub dest_pe: Option<u16>,
    /// Blocks that must complete first.
    pub deps: Vec<BlockId>,
    /// Marks the last block of an iteration (iteration-completion probe).
    pub completes_iter: bool,
}

/// Metadata the simulator needs alongside the blocks.
#[derive(Debug, Clone)]
pub struct ProgramMeta {
    pub kind: KernelKind,
    pub points: usize,
    /// DFG iterations in this program (window).
    pub iters: usize,
    /// Input bytes DMA must deliver per iteration (gates its LOAD blocks).
    pub dma_in_bytes_per_iter: u64,
    /// Output bytes DMA drains per iteration.
    pub dma_out_bytes_per_iter: u64,
    /// One-time weight streaming before the stage starts (0 if resident).
    pub weight_dma_bytes: u64,
    /// PEs hosting at least one node.
    pub active_pes: usize,
    /// Butterfly layers in the DFG.
    pub stages: usize,
}

/// A lowered, simulatable program (one stage DFG × `iters` iterations).
///
/// `blocks` is the construction/inspection view (one struct per block,
/// explicit dependency lists); `exec` is the flat structure-of-arrays
/// view the discrete-event engine walks, derived once at lowering time
/// by [`Program::new`].  The two views describe the same program — the
/// engine never reads `blocks`.
#[derive(Debug, Clone)]
pub struct Program {
    pub meta: ProgramMeta,
    pub blocks: Vec<Block>,
    pub exec: ExecLayout,
}

/// Flat, execution-oriented layout of a program: one array per block
/// field (structure-of-arrays), dependents and NoC routes in CSR form,
/// scheduler priorities pre-packed.  Built once per lowering so the
/// simulator's hot loop does no per-call graph preprocessing, chases no
/// `&blocks[i]` struct loads and allocates no per-FLOW route vectors.
#[derive(Debug, Clone)]
pub struct ExecLayout {
    /// `UnitKind::index()` per block.
    pub unit: Vec<u8>,
    /// Function-unit queue index: `pe * 4 + unit` per block.
    pub unit_slot: Vec<u32>,
    /// Host PE per block.
    pub pe: Vec<u16>,
    /// Packed `{layer, iter}` scheduler priority: `(layer << 32) | iter`
    /// — orders identically to the paper's lexicographic bit string.
    pub prio: Vec<u64>,
    /// DFG iteration index per block.
    pub iter: Vec<u32>,
    /// Lane-scaled scalars moved per block.
    pub scalars_wide: Vec<u64>,
    /// Broadcast scalars per block.
    pub scalars_bcast: Vec<u64>,
    /// Compute slots per lane (CAL blocks).
    pub ops: Vec<u64>,
    /// Mesh hops to the destination (FLOW blocks).
    pub noc_hops: Vec<u16>,
    /// Per-block flag bits (`FLAG_*`).
    pub flags: Vec<u8>,
    /// Initial dependency counts, including the virtual DMA-delivery
    /// dependency of gated loads.
    pub n_deps: Vec<u32>,
    /// Dependents CSR offsets (`len = blocks + 1`): the blocks unlocked
    /// by block `i` are `dep_flat[dep_start[i]..dep_start[i + 1]]`.
    pub dep_start: Vec<u32>,
    pub dep_flat: Vec<u32>,
    /// Per-block NoC route CSR offsets (`len = blocks + 1`): directed
    /// link ids of block `i`'s XY path (empty for non-FLOW blocks),
    /// copied out of the shared per-geometry [`RouteTable`].
    pub route_start: Vec<u32>,
    pub route_flat: Vec<u32>,
    /// Whether any block gates on DMA delivery (cold-start fill exists).
    pub any_dma_gated: bool,
}

/// Whether a block gates on DMA delivery: input-bearing layer-0 loads
/// wait for their iteration's chunk.  Single source of truth for the
/// `FLAG_DMA_GATED` bit, the extra `n_deps` count and `any_dma_gated` —
/// the engine derives its `DmaArrive` seeding, virtual dependency and
/// `dma_fill_cycles` statistic from those, so they can never disagree.
fn dma_gated(b: &Block) -> bool {
    b.unit == UnitKind::Load && b.layer == 0 && b.scalars_wide > 0
}

impl ExecLayout {
    /// Block gates on a `DmaArrive` delivery event.
    pub const FLAG_DMA_GATED: u8 = 1 << 0;
    /// Block is the iteration-completion probe of its iteration.
    pub const FLAG_COMPLETES_ITER: u8 = 1 << 1;
    /// Block accesses the SPM column-wise (layer > 0): serialized under
    /// the `no_multiline_spm` ablation.
    pub const FLAG_COL_ACCESS: u8 = 1 << 2;

    /// Derive the flat layout from the block list (called once by
    /// [`Program::new`]).
    pub fn build(blocks: &[Block], arch: &ArchConfig) -> ExecLayout {
        let n = blocks.len();
        let routes = RouteTable::for_arch(arch);
        let mut dep_start = vec![0u32; n + 1];
        for b in blocks {
            for d in &b.deps {
                dep_start[d.0 as usize + 1] += 1;
            }
        }
        for i in 0..n {
            dep_start[i + 1] += dep_start[i];
        }
        let dep_flat = vec![0u32; dep_start[n] as usize];
        let mut cursor: Vec<u32> = dep_start[..n].to_vec();

        let mut out = ExecLayout {
            unit: Vec::with_capacity(n),
            unit_slot: Vec::with_capacity(n),
            pe: Vec::with_capacity(n),
            prio: Vec::with_capacity(n),
            iter: Vec::with_capacity(n),
            scalars_wide: Vec::with_capacity(n),
            scalars_bcast: Vec::with_capacity(n),
            ops: Vec::with_capacity(n),
            noc_hops: Vec::with_capacity(n),
            flags: Vec::with_capacity(n),
            n_deps: Vec::with_capacity(n),
            dep_start,
            dep_flat,
            route_start: Vec::with_capacity(n + 1),
            route_flat: Vec::new(),
            any_dma_gated: false,
        };
        out.route_start.push(0);
        for (i, b) in blocks.iter().enumerate() {
            for d in &b.deps {
                let c = &mut cursor[d.0 as usize];
                out.dep_flat[*c as usize] = i as u32;
                *c += 1;
            }
            let gated = dma_gated(b);
            out.any_dma_gated |= gated;
            let mut flags = 0u8;
            if gated {
                flags |= Self::FLAG_DMA_GATED;
            }
            if b.completes_iter {
                flags |= Self::FLAG_COMPLETES_ITER;
            }
            if b.layer > 0 {
                flags |= Self::FLAG_COL_ACCESS;
            }
            out.unit.push(b.unit.index() as u8);
            out.unit_slot.push(b.pe as u32 * 4 + b.unit.index() as u32);
            out.pe.push(b.pe);
            out.prio.push(((b.layer as u64) << 32) | b.iter as u64);
            out.iter.push(b.iter);
            out.scalars_wide.push(b.scalars_wide);
            out.scalars_bcast.push(b.scalars_bcast);
            out.ops.push(b.ops);
            out.noc_hops.push(b.noc_hops);
            out.flags.push(flags);
            out.n_deps.push(b.deps.len() as u32 + u32::from(gated));
            if b.unit == UnitKind::Flow {
                let dest = b.dest_pe.unwrap_or(b.pe) as usize;
                out.route_flat.extend_from_slice(routes.route(b.pe as usize, dest));
            }
            out.route_start.push(out.route_flat.len() as u32);
        }
        out
    }

    /// Number of blocks covered.
    pub fn len(&self) -> usize {
        self.unit.len()
    }

    pub fn is_empty(&self) -> bool {
        self.unit.is_empty()
    }
}

/// Per-PE slot layout used to wire dependencies.
#[derive(Clone, Copy)]
enum Slot {
    Load,
    WLoad(usize),
    Cal(usize),
    Flow(usize),
    Store,
}

/// Lower one stage DFG into a windowed program of `iters` iterations.
///
/// `stage.sub_iters` and batching are already folded into `iters` by the
/// coordinator (`iters = ceil(vectors × sub_iters / simd_width)`, clipped
/// to the simulation window).
pub fn lower_stage(stage: &StageDfg, arch: &ArchConfig, iters: usize) -> Program {
    lower_stage_packed(stage, arch, iters, 1)
}

/// Like [`lower_stage`] but packing `pack` independent DFG *instances*
/// into each iteration: every PE hosts `pack ×` the nodes per layer,
/// with identical swap-partner patterns (instances are element-wise
/// independent).  This is how shallow stage DFGs (a 32-point column
/// stage of a Fig. 9 division) amortize the per-block issue overheads —
/// the paper's "pour adequate graph iterations into the multilayer DFG".
pub fn lower_stage_packed(
    stage: &StageDfg,
    arch: &ArchConfig,
    iters: usize,
    pack: usize,
) -> Program {
    // The butterfly DFG's layers are uniformly n/2 nodes wide, so the
    // round-robin mapping is derivable without materializing the graph
    // (`for_points` == `round_robin(build_butterfly_dfg(..))`, tested).
    let map = Mapping::for_points(stage.points, arch);
    lower_stage_mapped(stage, arch, iters, pack, &map)
}

/// Like [`lower_stage_packed`] but with the node→PE assignment supplied
/// by the caller instead of derived internally — the lowering a
/// [`crate::dfg::strategy::DataflowStrategy`] drives when it owns the
/// mapping decision.  `map` must describe a `stage.points`-point DFG on
/// this architecture (`map.num_pes == arch.num_pes()`).
pub fn lower_stage_mapped(
    stage: &StageDfg,
    arch: &ArchConfig,
    iters: usize,
    pack: usize,
    map: &Mapping,
) -> Program {
    let pack = pack.max(1) as u64;
    let n = stage.points;
    let s = log2_int(n);
    let kind = stage.kind;
    let planes = kind.planes() as u64;
    // Per-PE node counts, hoisted out of the (iter × layer × pe) loops.
    let nodes_per_pe = map.nodes_per_pe();
    let num_pes = arch.num_pes();
    let w = arch.simd_width as u64;

    // Slot index layout per (iter, pe): Load, then per stage t in 0..s:
    // WLoad(t), Cal(t), Flow(t) [only t < s-1 and remote], then Store.
    let slots_per_pe = 1 + 3 * s + 1;
    let slot_index = |slot: Slot| -> usize {
        match slot {
            Slot::Load => 0,
            Slot::WLoad(t) => 1 + 3 * t,
            Slot::Cal(t) => 2 + 3 * t,
            Slot::Flow(t) => 3 + 3 * t,
            Slot::Store => 1 + 3 * s,
        }
    };
    // block id table: (iter, pe, slot) -> Option<BlockId>
    let mut table: Vec<Option<BlockId>> = vec![None; iters * num_pes * slots_per_pe];
    let t_idx = |iter: usize, pe: usize, slot: Slot| -> usize {
        (iter * num_pes + pe) * slots_per_pe + slot_index(slot)
    };

    let mut blocks: Vec<Block> = Vec::new();
    let mut push =
        |table: &mut Vec<Option<BlockId>>, iter: usize, pe: usize, slot: Slot, b: Block| {
            let id = BlockId(blocks.len() as u32);
            table[t_idx(iter, pe, slot)] = Some(id);
            blocks.push(b);
            id
        };

    let twiddle = stage.twiddle_before;
    let inflight = arch.inflight_iters.max(1);
    // Generation is layer-major within each iteration so that cross-PE
    // FLOW dependencies always reference already-created blocks.
    for iter in 0..iters {
        // LOAD layer: 2 input elements per node × planes (lane-scaled).
        // Buffer recycling bounds in-flight iterations: iteration i's
        // input buffers are freed by iteration i-inflight's STORE.
        for pe in 0..num_pes {
            let npe = nodes_per_pe[pe] as u64 * pack;
            if npe == 0 {
                continue;
            }
            let mut deps = Vec::new();
            if iter >= inflight {
                if let Some(sid) = table[t_idx(iter - inflight, pe, Slot::Store)] {
                    deps.push(sid);
                }
            }
            push(
                &mut table,
                iter,
                pe,
                Slot::Load,
                Block {
                    pe: pe as u16,
                    unit: UnitKind::Load,
                    layer: 0,
                    iter: iter as u32,
                    scalars_wide: 2 * npe * planes,
                    scalars_bcast: 0,
                    ops: 0,
                    noc_hops: 0,
                    dest_pe: None,
                    deps,
                    completes_iter: false,
                },
            );
        }
        for t in 0..s {
            let layer = t as u16 + 1;
            for pe in 0..num_pes {
                let npe = nodes_per_pe[pe] as u64 * pack;
                if npe == 0 {
                    continue;
                }
                // WLOAD: stage weights are *pre-stored* in the PE
                // (§III-B) — fetched once, on the first iteration only.
                // The first stage additionally carries the inter-stage
                // twiddle factors when present.
                if iter == 0 {
                    let mut wsc = kind.weight_scalars_per_node() * npe;
                    if t == 0 && twiddle {
                        wsc += 2 * 2 * npe; // one complex factor per element
                    }
                    push(
                        &mut table,
                        iter,
                        pe,
                        Slot::WLoad(t),
                        Block {
                            pe: pe as u16,
                            unit: UnitKind::Load,
                            layer,
                            iter: iter as u32,
                            scalars_wide: 0,
                            scalars_bcast: wsc,
                            ops: 0,
                            noc_hops: 0,
                            dest_pe: None,
                            deps: vec![],
                            completes_iter: false,
                        },
                    );
                }
                // CAL: the PE's butterflies of this stage (+ twiddle ewise).
                let mut ops = kind.ops_per_node() * npe;
                if t == 0 && twiddle {
                    ops += 6 * 2 * npe; // complex multiply per element
                }
                let mut deps = Vec::new();
                if let Some(wid) = table[t_idx(0, pe, Slot::WLoad(t))] {
                    if iter == 0 {
                        deps.push(wid);
                    }
                }
                if t == 0 {
                    deps.push(table[t_idx(iter, pe, Slot::Load)].unwrap());
                } else {
                    deps.push(table[t_idx(iter, pe, Slot::Cal(t - 1))].unwrap());
                    // Swapped half arrives from the partner's FLOW(t-1).
                    if let Some(q) = map.partner_pe(pe, t) {
                        if let Some(fid) = table[t_idx(iter, q, Slot::Flow(t - 1))] {
                            deps.push(fid);
                        }
                    }
                }
                push(
                    &mut table,
                    iter,
                    pe,
                    Slot::Cal(t),
                    Block {
                        pe: pe as u16,
                        unit: UnitKind::Cal,
                        layer,
                        iter: iter as u32,
                        scalars_wide: 0,
                        scalars_bcast: 0,
                        ops,
                        noc_hops: 0,
                        dest_pe: None,
                        deps,
                        completes_iter: false,
                    },
                );
            }
            // FLOW into stage t+1 (if the exchange is remote), after all
            // of this layer's CAL blocks exist.
            if t + 1 < s {
                for pe in 0..num_pes {
                    let npe = nodes_per_pe[pe] as u64 * pack;
                    if npe == 0 {
                        continue;
                    }
                    if let Some(q) = map.partner_pe(pe, t + 1) {
                        let hops = arch.hop_distance(pe, q) as u16;
                        let deps = vec![table[t_idx(iter, pe, Slot::Cal(t))].unwrap()];
                        push(
                            &mut table,
                            iter,
                            pe,
                            Slot::Flow(t),
                            Block {
                                pe: pe as u16,
                                unit: UnitKind::Flow,
                                layer,
                                iter: iter as u32,
                                scalars_wide: npe * planes,
                                scalars_bcast: 0,
                                ops: 0,
                                noc_hops: hops,
                                dest_pe: Some(q as u16),
                                deps,
                                completes_iter: false,
                            },
                        );
                    }
                }
            }
        }
        // STORE the final stage outputs.
        for pe in 0..num_pes {
            let npe = nodes_per_pe[pe] as u64 * pack;
            if npe == 0 {
                continue;
            }
            let store_deps = vec![table[t_idx(iter, pe, Slot::Cal(s - 1))].unwrap()];
            push(
                &mut table,
                iter,
                pe,
                Slot::Store,
                Block {
                    pe: pe as u16,
                    unit: UnitKind::Store,
                    layer: s as u16 + 1,
                    iter: iter as u32,
                    scalars_wide: 2 * npe * planes,
                    scalars_bcast: 0,
                    ops: 0,
                    noc_hops: 0,
                    dest_pe: None,
                    deps: store_deps,
                    completes_iter: true,
                },
            );
        }
    }

    let elem = arch.elem_bytes as u64;
    let vec_bytes = (n as u64) * planes * w * elem * pack;
    let weight_dma = if stage.weights_from_ddr {
        (n as u64 / 2)
            * s as u64
            * kind.weight_scalars_per_node()
            * elem
    } else {
        0
    };
    Program::new(
        ProgramMeta {
            kind,
            points: n,
            iters,
            dma_in_bytes_per_iter: vec_bytes,
            dma_out_bytes_per_iter: vec_bytes,
            weight_dma_bytes: weight_dma,
            active_pes: map.active_pes(),
            stages: s,
        },
        blocks,
        arch,
    )
}

impl Program {
    /// Assemble a program from its block list, deriving the flat
    /// [`ExecLayout`] the simulator walks.
    pub fn new(meta: ProgramMeta, blocks: Vec<Block>, arch: &ArchConfig) -> Program {
        let exec = ExecLayout::build(&blocks, arch);
        Program { meta, blocks, exec }
    }

    /// Sanity invariants: deps point backwards in priority space and the
    /// block set is an acyclic layered graph.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, b) in self.blocks.iter().enumerate() {
            for d in &b.deps {
                let dep = &self.blocks[d.0 as usize];
                anyhow::ensure!(
                    (dep.iter, dep.layer) <= (b.iter, b.layer),
                    "block {i} (iter {}, layer {}) depends on future block {:?} \
                     (iter {}, layer {})",
                    b.iter,
                    b.layer,
                    d,
                    dep.iter,
                    dep.layer
                );
            }
        }
        Ok(())
    }

    /// Aggregate compute ops (per lane) across all CAL blocks.
    pub fn total_ops(&self) -> u64 {
        self.blocks.iter().map(|b| b.ops).sum()
    }

    /// Aggregate lane-scaled SPM scalars.
    pub fn total_spm_scalars(&self) -> u64 {
        self.blocks
            .iter()
            .filter(|b| matches!(b.unit, UnitKind::Load | UnitKind::Store))
            .map(|b| b.scalars_wide)
            .sum()
    }

    /// Aggregate lane-scaled NoC scalars.
    pub fn total_noc_scalars(&self) -> u64 {
        self.blocks
            .iter()
            .filter(|b| b.unit == UnitKind::Flow)
            .map(|b| b.scalars_wide)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::stages::StageDfg;

    fn stage(kind: KernelKind, points: usize) -> StageDfg {
        StageDfg { kind, points, sub_iters: 1, twiddle_before: false, weights_from_ddr: false }
    }

    #[test]
    fn block_counts_32_points() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Bpmm, 32), &arch, 2);
        p.validate().unwrap();
        // 32 points, 16 PEs, 1 node/PE/layer, s=5 stages.
        // Iter 0: 1 LOAD + 5 WLOAD (weights pre-stored once) + 5 CAL +
        // 4 FLOW (stages 1..4, distances 1,2,4,8) + 1 STORE = 16.
        // Later iters skip WLOAD: 11.
        assert_eq!(p.blocks.len(), 16 * 16 + 16 * 11);
        assert_eq!(p.meta.active_pes, 16);
    }

    #[test]
    fn wrapback_suppresses_late_flows() {
        let arch = ArchConfig::full();
        // 512 points: s=9; flows into stages 1..8, but stages 5..=8 have
        // d ∈ {16,32,64,128} ≥ P → local (no FLOW blocks).
        let p = lower_stage(&stage(KernelKind::Bpmm, 512), &arch, 1);
        let flows = p.blocks.iter().filter(|b| b.unit == UnitKind::Flow).count();
        assert_eq!(flows, 16 * 4); // stages 1..4 remote only
    }

    #[test]
    fn fft_doubles_flow_payload() {
        let arch = ArchConfig::full();
        let pb = lower_stage(&stage(KernelKind::Bpmm, 64), &arch, 1);
        let pf = lower_stage(&stage(KernelKind::Fft, 64), &arch, 1);
        assert_eq!(pf.total_noc_scalars(), 2 * pb.total_noc_scalars());
        assert_eq!(pf.total_spm_scalars(), 2 * pb.total_spm_scalars());
    }

    #[test]
    fn twiddle_layer_adds_ops_and_factors() {
        let arch = ArchConfig::full();
        let mut st = stage(KernelKind::Fft, 64);
        let base = lower_stage(&st, &arch, 1);
        st.twiddle_before = true;
        let tw = lower_stage(&st, &arch, 1);
        assert!(tw.total_ops() > base.total_ops());
    }

    #[test]
    fn cal_deps_include_partner_flow() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Bpmm, 32), &arch, 1);
        p.validate().unwrap();
        // Find a CAL block at layer 2 (stage 1, remote swap distance 1):
        // it must depend on a FLOW block on the partner PE.
        let cal = p
            .blocks
            .iter()
            .find(|b| b.unit == UnitKind::Cal && b.layer == 2 && b.pe == 0)
            .unwrap();
        let has_flow_dep = cal.deps.iter().any(|d| {
            let dep = &p.blocks[d.0 as usize];
            dep.unit == UnitKind::Flow && dep.pe == 1
        });
        assert!(has_flow_dep);
    }

    #[test]
    fn store_completes_iteration() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Fft, 128), &arch, 3);
        let completers: Vec<_> =
            p.blocks.iter().filter(|b| b.completes_iter).collect();
        assert_eq!(completers.len(), 3 * 16);
        assert!(completers.iter().all(|b| b.unit == UnitKind::Store));
    }

    #[test]
    fn ddr_weights_flagged() {
        let arch = ArchConfig::full();
        let mut st = stage(KernelKind::Bpmm, 256);
        st.weights_from_ddr = true;
        let p = lower_stage(&st, &arch, 1);
        assert!(p.meta.weight_dma_bytes > 0);
    }

    #[test]
    fn exec_layout_mirrors_blocks() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Fft, 256), &arch, 4);
        let e = &p.exec;
        assert_eq!(e.len(), p.blocks.len());
        assert_eq!(*e.route_start.last().unwrap() as usize, e.route_flat.len());
        let mut dep_edges = 0usize;
        for (i, b) in p.blocks.iter().enumerate() {
            assert_eq!(e.unit[i] as usize, b.unit.index());
            assert_eq!(e.pe[i], b.pe);
            assert_eq!(e.unit_slot[i], b.pe as u32 * 4 + b.unit.index() as u32);
            assert_eq!(e.prio[i], ((b.layer as u64) << 32) | b.iter as u64);
            assert_eq!(e.iter[i], b.iter);
            assert_eq!(e.scalars_wide[i], b.scalars_wide);
            assert_eq!(e.scalars_bcast[i], b.scalars_bcast);
            assert_eq!(e.ops[i], b.ops);
            assert_eq!(e.noc_hops[i], b.noc_hops);
            assert_eq!(
                e.flags[i] & ExecLayout::FLAG_COMPLETES_ITER != 0,
                b.completes_iter
            );
            assert_eq!(e.flags[i] & ExecLayout::FLAG_COL_ACCESS != 0, b.layer > 0);
            let gated = e.flags[i] & ExecLayout::FLAG_DMA_GATED != 0;
            assert_eq!(e.n_deps[i] as usize, b.deps.len() + usize::from(gated));
            dep_edges += b.deps.len();
            // FLOW route length matches the recorded hop count; others
            // carry no route.
            let r = e.route_start[i + 1] - e.route_start[i];
            if b.unit == UnitKind::Flow {
                assert_eq!(r as usize, b.noc_hops as usize);
            } else {
                assert_eq!(r, 0);
            }
        }
        assert_eq!(e.dep_flat.len(), dep_edges);
        assert!(e.any_dma_gated);
        // Dependents CSR is the exact transpose of the deps lists.
        for (i, b) in p.blocks.iter().enumerate() {
            for d in &b.deps {
                let j = d.0 as usize;
                let deps_of_j =
                    &e.dep_flat[e.dep_start[j] as usize..e.dep_start[j + 1] as usize];
                assert!(deps_of_j.contains(&(i as u32)), "block {i} missing in {j}");
            }
        }
    }

    #[test]
    fn total_ops_matches_nodes() {
        let arch = ArchConfig::full();
        let n = 256;
        let p = lower_stage(&stage(KernelKind::Bpmm, n), &arch, 4);
        // 4 iters × (n/2 nodes × log2 n stages × 4 ops).
        assert_eq!(p.total_ops(), 4 * (n as u64 / 2) * 8 * 4);
    }
}
