//! Lowering a stage DFG to per-PE micro-code blocks (Fig. 8).
//!
//! Tensor workloads have "explicit computational certainty", so the
//! instructions of one DFG iteration on one PE are grouped into
//! sequential *Micro Code Blocks*, one per function unit episode:
//!
//! * `LOAD`  (layer 0)        — fetch the PE's input elements from SPM;
//! * `WLOAD` (per stage)      — fetch the stage's weights/twiddles
//!   (broadcast across SIMD lanes);
//! * `CAL`   (per stage)      — the PE's butterfly nodes of that layer;
//! * `FLOW`  (between stages) — the swapped halves travelling to the
//!   partner PE over the mesh (skipped when the wrap-back rule makes the
//!   exchange local);
//! * `STORE` (final layer)    — results back to SPM.
//!
//! Each block carries the `{layer, iter}` priority bit-string of the
//! paper's block scheduler and its dependence edges; the cycle-level
//! simulator turns the raw quantities into time.

use crate::arch::{ArchConfig, UnitKind};
use crate::model::log2_int;

use super::graph::KernelKind;
use super::mapping::Mapping;
use super::stages::StageDfg;

/// Block identifier (index into `Program::blocks`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

/// One coarse-grained micro-code block.
#[derive(Debug, Clone)]
pub struct Block {
    pub pe: u16,
    pub unit: UnitKind,
    /// Priority major: layer index within the stage DFG.
    pub layer: u16,
    /// Priority minor: DFG iteration index.
    pub iter: u32,
    /// Lane-scaled scalars moved (load/store inputs, flow payload): one
    /// per SIMD lane per element-plane.
    pub scalars_wide: u64,
    /// Broadcast scalars (weights/twiddles — lane-invariant).
    pub scalars_bcast: u64,
    /// Compute slots per lane (CAL blocks).
    pub ops: u64,
    /// Mesh hops to the destination (FLOW blocks).
    pub noc_hops: u16,
    /// Destination PE (FLOW blocks).
    pub dest_pe: Option<u16>,
    /// Blocks that must complete first.
    pub deps: Vec<BlockId>,
    /// Marks the last block of an iteration (iteration-completion probe).
    pub completes_iter: bool,
}

/// Metadata the simulator needs alongside the blocks.
#[derive(Debug, Clone)]
pub struct ProgramMeta {
    pub kind: KernelKind,
    pub points: usize,
    /// DFG iterations in this program (window).
    pub iters: usize,
    /// Input bytes DMA must deliver per iteration (gates its LOAD blocks).
    pub dma_in_bytes_per_iter: u64,
    /// Output bytes DMA drains per iteration.
    pub dma_out_bytes_per_iter: u64,
    /// One-time weight streaming before the stage starts (0 if resident).
    pub weight_dma_bytes: u64,
    /// PEs hosting at least one node.
    pub active_pes: usize,
    /// Butterfly layers in the DFG.
    pub stages: usize,
}

/// A lowered, simulatable program (one stage DFG × `iters` iterations).
#[derive(Debug, Clone)]
pub struct Program {
    pub meta: ProgramMeta,
    pub blocks: Vec<Block>,
}

/// Per-PE slot layout used to wire dependencies.
#[derive(Clone, Copy)]
enum Slot {
    Load,
    WLoad(usize),
    Cal(usize),
    Flow(usize),
    Store,
}

/// Lower one stage DFG into a windowed program of `iters` iterations.
///
/// `stage.sub_iters` and batching are already folded into `iters` by the
/// coordinator (`iters = ceil(vectors × sub_iters / simd_width)`, clipped
/// to the simulation window).
pub fn lower_stage(stage: &StageDfg, arch: &ArchConfig, iters: usize) -> Program {
    lower_stage_packed(stage, arch, iters, 1)
}

/// Like [`lower_stage`] but packing `pack` independent DFG *instances*
/// into each iteration: every PE hosts `pack ×` the nodes per layer,
/// with identical swap-partner patterns (instances are element-wise
/// independent).  This is how shallow stage DFGs (a 32-point column
/// stage of a Fig. 9 division) amortize the per-block issue overheads —
/// the paper's "pour adequate graph iterations into the multilayer DFG".
pub fn lower_stage_packed(
    stage: &StageDfg,
    arch: &ArchConfig,
    iters: usize,
    pack: usize,
) -> Program {
    let pack = pack.max(1) as u64;
    let n = stage.points;
    let s = log2_int(n);
    let kind = stage.kind;
    let planes = kind.planes() as u64;
    let dfg = super::butterfly::build_butterfly_dfg(kind, n);
    let map = Mapping::round_robin(&dfg, arch);
    let num_pes = arch.num_pes();
    let w = arch.simd_width as u64;

    // Slot index layout per (iter, pe): Load, then per stage t in 0..s:
    // WLoad(t), Cal(t), Flow(t) [only t < s-1 and remote], then Store.
    let slots_per_pe = 1 + 3 * s + 1;
    let slot_index = |slot: Slot| -> usize {
        match slot {
            Slot::Load => 0,
            Slot::WLoad(t) => 1 + 3 * t,
            Slot::Cal(t) => 2 + 3 * t,
            Slot::Flow(t) => 3 + 3 * t,
            Slot::Store => 1 + 3 * s,
        }
    };
    // block id table: (iter, pe, slot) -> Option<BlockId>
    let mut table: Vec<Option<BlockId>> = vec![None; iters * num_pes * slots_per_pe];
    let t_idx = |iter: usize, pe: usize, slot: Slot| -> usize {
        (iter * num_pes + pe) * slots_per_pe + slot_index(slot)
    };

    let mut blocks: Vec<Block> = Vec::new();
    let mut push =
        |table: &mut Vec<Option<BlockId>>, iter: usize, pe: usize, slot: Slot, b: Block| {
            let id = BlockId(blocks.len() as u32);
            table[t_idx(iter, pe, slot)] = Some(id);
            blocks.push(b);
            id
        };

    let twiddle = stage.twiddle_before;
    let inflight = arch.inflight_iters.max(1);
    // Generation is layer-major within each iteration so that cross-PE
    // FLOW dependencies always reference already-created blocks.
    for iter in 0..iters {
        // LOAD layer: 2 input elements per node × planes (lane-scaled).
        // Buffer recycling bounds in-flight iterations: iteration i's
        // input buffers are freed by iteration i-inflight's STORE.
        for pe in 0..num_pes {
            let npe = map.nodes_on_pe(pe) as u64 * pack;
            if npe == 0 {
                continue;
            }
            let mut deps = Vec::new();
            if iter >= inflight {
                if let Some(sid) = table[t_idx(iter - inflight, pe, Slot::Store)] {
                    deps.push(sid);
                }
            }
            push(
                &mut table,
                iter,
                pe,
                Slot::Load,
                Block {
                    pe: pe as u16,
                    unit: UnitKind::Load,
                    layer: 0,
                    iter: iter as u32,
                    scalars_wide: 2 * npe * planes,
                    scalars_bcast: 0,
                    ops: 0,
                    noc_hops: 0,
                    dest_pe: None,
                    deps,
                    completes_iter: false,
                },
            );
        }
        for t in 0..s {
            let layer = t as u16 + 1;
            for pe in 0..num_pes {
                let npe = map.nodes_on_pe(pe) as u64 * pack;
                if npe == 0 {
                    continue;
                }
                // WLOAD: stage weights are *pre-stored* in the PE
                // (§III-B) — fetched once, on the first iteration only.
                // The first stage additionally carries the inter-stage
                // twiddle factors when present.
                if iter == 0 {
                    let mut wsc = kind.weight_scalars_per_node() * npe;
                    if t == 0 && twiddle {
                        wsc += 2 * 2 * npe; // one complex factor per element
                    }
                    push(
                        &mut table,
                        iter,
                        pe,
                        Slot::WLoad(t),
                        Block {
                            pe: pe as u16,
                            unit: UnitKind::Load,
                            layer,
                            iter: iter as u32,
                            scalars_wide: 0,
                            scalars_bcast: wsc,
                            ops: 0,
                            noc_hops: 0,
                            dest_pe: None,
                            deps: vec![],
                            completes_iter: false,
                        },
                    );
                }
                // CAL: the PE's butterflies of this stage (+ twiddle ewise).
                let mut ops = kind.ops_per_node() * npe;
                if t == 0 && twiddle {
                    ops += 6 * 2 * npe; // complex multiply per element
                }
                let mut deps = Vec::new();
                if let Some(wid) = table[t_idx(0, pe, Slot::WLoad(t))] {
                    if iter == 0 {
                        deps.push(wid);
                    }
                }
                if t == 0 {
                    deps.push(table[t_idx(iter, pe, Slot::Load)].unwrap());
                } else {
                    deps.push(table[t_idx(iter, pe, Slot::Cal(t - 1))].unwrap());
                    // Swapped half arrives from the partner's FLOW(t-1).
                    if let Some(q) = map.partner_pe(pe, t) {
                        if let Some(fid) = table[t_idx(iter, q, Slot::Flow(t - 1))] {
                            deps.push(fid);
                        }
                    }
                }
                push(
                    &mut table,
                    iter,
                    pe,
                    Slot::Cal(t),
                    Block {
                        pe: pe as u16,
                        unit: UnitKind::Cal,
                        layer,
                        iter: iter as u32,
                        scalars_wide: 0,
                        scalars_bcast: 0,
                        ops,
                        noc_hops: 0,
                        dest_pe: None,
                        deps,
                        completes_iter: false,
                    },
                );
            }
            // FLOW into stage t+1 (if the exchange is remote), after all
            // of this layer's CAL blocks exist.
            if t + 1 < s {
                for pe in 0..num_pes {
                    let npe = map.nodes_on_pe(pe) as u64 * pack;
                    if npe == 0 {
                        continue;
                    }
                    if let Some(q) = map.partner_pe(pe, t + 1) {
                        let hops = arch.hop_distance(pe, q) as u16;
                        let deps = vec![table[t_idx(iter, pe, Slot::Cal(t))].unwrap()];
                        push(
                            &mut table,
                            iter,
                            pe,
                            Slot::Flow(t),
                            Block {
                                pe: pe as u16,
                                unit: UnitKind::Flow,
                                layer,
                                iter: iter as u32,
                                scalars_wide: npe * planes,
                                scalars_bcast: 0,
                                ops: 0,
                                noc_hops: hops,
                                dest_pe: Some(q as u16),
                                deps,
                                completes_iter: false,
                            },
                        );
                    }
                }
            }
        }
        // STORE the final stage outputs.
        for pe in 0..num_pes {
            let npe = map.nodes_on_pe(pe) as u64 * pack;
            if npe == 0 {
                continue;
            }
            let store_deps = vec![table[t_idx(iter, pe, Slot::Cal(s - 1))].unwrap()];
            push(
                &mut table,
                iter,
                pe,
                Slot::Store,
                Block {
                    pe: pe as u16,
                    unit: UnitKind::Store,
                    layer: s as u16 + 1,
                    iter: iter as u32,
                    scalars_wide: 2 * npe * planes,
                    scalars_bcast: 0,
                    ops: 0,
                    noc_hops: 0,
                    dest_pe: None,
                    deps: store_deps,
                    completes_iter: true,
                },
            );
        }
    }

    let elem = arch.elem_bytes as u64;
    let vec_bytes = (n as u64) * planes * w * elem * pack;
    let weight_dma = if stage.weights_from_ddr {
        (n as u64 / 2)
            * s as u64
            * kind.weight_scalars_per_node()
            * elem
    } else {
        0
    };
    Program {
        meta: ProgramMeta {
            kind,
            points: n,
            iters,
            dma_in_bytes_per_iter: vec_bytes,
            dma_out_bytes_per_iter: vec_bytes,
            weight_dma_bytes: weight_dma,
            active_pes: map.active_pes(),
            stages: s,
        },
        blocks,
    }
}

impl Program {
    /// Sanity invariants: deps point backwards in priority space and the
    /// block set is an acyclic layered graph.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, b) in self.blocks.iter().enumerate() {
            for d in &b.deps {
                let dep = &self.blocks[d.0 as usize];
                anyhow::ensure!(
                    (dep.iter, dep.layer) <= (b.iter, b.layer),
                    "block {i} (iter {}, layer {}) depends on future block {:?} \
                     (iter {}, layer {})",
                    b.iter,
                    b.layer,
                    d,
                    dep.iter,
                    dep.layer
                );
            }
        }
        Ok(())
    }

    /// Aggregate compute ops (per lane) across all CAL blocks.
    pub fn total_ops(&self) -> u64 {
        self.blocks.iter().map(|b| b.ops).sum()
    }

    /// Aggregate lane-scaled SPM scalars.
    pub fn total_spm_scalars(&self) -> u64 {
        self.blocks
            .iter()
            .filter(|b| matches!(b.unit, UnitKind::Load | UnitKind::Store))
            .map(|b| b.scalars_wide)
            .sum()
    }

    /// Aggregate lane-scaled NoC scalars.
    pub fn total_noc_scalars(&self) -> u64 {
        self.blocks
            .iter()
            .filter(|b| b.unit == UnitKind::Flow)
            .map(|b| b.scalars_wide)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::stages::StageDfg;

    fn stage(kind: KernelKind, points: usize) -> StageDfg {
        StageDfg { kind, points, sub_iters: 1, twiddle_before: false, weights_from_ddr: false }
    }

    #[test]
    fn block_counts_32_points() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Bpmm, 32), &arch, 2);
        p.validate().unwrap();
        // 32 points, 16 PEs, 1 node/PE/layer, s=5 stages.
        // Iter 0: 1 LOAD + 5 WLOAD (weights pre-stored once) + 5 CAL +
        // 4 FLOW (stages 1..4, distances 1,2,4,8) + 1 STORE = 16.
        // Later iters skip WLOAD: 11.
        assert_eq!(p.blocks.len(), 16 * 16 + 16 * 11);
        assert_eq!(p.meta.active_pes, 16);
    }

    #[test]
    fn wrapback_suppresses_late_flows() {
        let arch = ArchConfig::full();
        // 512 points: s=9; flows into stages 1..8, but stages 5..=8 have
        // d ∈ {16,32,64,128} ≥ P → local (no FLOW blocks).
        let p = lower_stage(&stage(KernelKind::Bpmm, 512), &arch, 1);
        let flows = p.blocks.iter().filter(|b| b.unit == UnitKind::Flow).count();
        assert_eq!(flows, 16 * 4); // stages 1..4 remote only
    }

    #[test]
    fn fft_doubles_flow_payload() {
        let arch = ArchConfig::full();
        let pb = lower_stage(&stage(KernelKind::Bpmm, 64), &arch, 1);
        let pf = lower_stage(&stage(KernelKind::Fft, 64), &arch, 1);
        assert_eq!(pf.total_noc_scalars(), 2 * pb.total_noc_scalars());
        assert_eq!(pf.total_spm_scalars(), 2 * pb.total_spm_scalars());
    }

    #[test]
    fn twiddle_layer_adds_ops_and_factors() {
        let arch = ArchConfig::full();
        let mut st = stage(KernelKind::Fft, 64);
        let base = lower_stage(&st, &arch, 1);
        st.twiddle_before = true;
        let tw = lower_stage(&st, &arch, 1);
        assert!(tw.total_ops() > base.total_ops());
    }

    #[test]
    fn cal_deps_include_partner_flow() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Bpmm, 32), &arch, 1);
        p.validate().unwrap();
        // Find a CAL block at layer 2 (stage 1, remote swap distance 1):
        // it must depend on a FLOW block on the partner PE.
        let cal = p
            .blocks
            .iter()
            .find(|b| b.unit == UnitKind::Cal && b.layer == 2 && b.pe == 0)
            .unwrap();
        let has_flow_dep = cal.deps.iter().any(|d| {
            let dep = &p.blocks[d.0 as usize];
            dep.unit == UnitKind::Flow && dep.pe == 1
        });
        assert!(has_flow_dep);
    }

    #[test]
    fn store_completes_iteration() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Fft, 128), &arch, 3);
        let completers: Vec<_> =
            p.blocks.iter().filter(|b| b.completes_iter).collect();
        assert_eq!(completers.len(), 3 * 16);
        assert!(completers.iter().all(|b| b.unit == UnitKind::Store));
    }

    #[test]
    fn ddr_weights_flagged() {
        let arch = ArchConfig::full();
        let mut st = stage(KernelKind::Bpmm, 256);
        st.weights_from_ddr = true;
        let p = lower_stage(&st, &arch, 1);
        assert!(p.meta.weight_dma_bytes > 0);
    }

    #[test]
    fn total_ops_matches_nodes() {
        let arch = ArchConfig::full();
        let n = 256;
        let p = lower_stage(&stage(KernelKind::Bpmm, n), &arch, 4);
        // 4 iters × (n/2 nodes × log2 n stages × 4 ops).
        assert_eq!(p.total_ops(), 4 * (n as u64 / 2) * 8 * 4);
    }
}
