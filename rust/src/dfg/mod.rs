//! The paper's compiler stack: butterfly kernels → multilayer DFGs →
//! PE-array mapping → micro-code blocks.
//!
//! * [`graph`] — layer-tagged DFG IR with the partial-order invariant of
//!   Fig. 5b (edges only cross consecutive layers).
//! * [`butterfly`] — the multilayer butterfly DFG template (Fig. 5b/7a):
//!   load layer, `log2 n` butterfly layers with swap distances 1, 2, 4,…
//!   and a store layer; plus a functional executor used to *prove* the
//!   template computes the right answer.
//! * [`stages`] — multi-stage Cooley-Tukey division planning (Fig. 9):
//!   splits scales beyond the single-DFG limit into column/twiddle/row
//!   stage DFGs with barriers, recursively for 64K-class vectors.
//! * [`slicing`] — BPMM weight slicing for unequal hidden sizes (Fig. 10).
//! * [`mapping`] — balanced round-robin node→PE assignment (Fig. 7b/c)
//!   with the wrap-back rule (distance ≥ #PEs stays local).
//! * [`microcode`] — lowering to per-PE coarse-grained code blocks
//!   {Load, Flow, Cal, Store} tagged with `{layer, iter}` priorities
//!   (Fig. 8), ready for the cycle-level simulator.
//! * [`strategy`] — the [`DataflowStrategy`] trait bundling the three
//!   lowering decisions (division, mapping, slicing + schedule) behind
//!   one pluggable interface: [`PaperStrategy`] is the verbatim paper
//!   recipe, alternatives trade the same invariants differently, and
//!   [`Strategy::Auto`] lets the coordinator simulate-and-pick.

pub mod butterfly;
pub mod graph;
pub mod mapping;
pub mod microcode;
pub mod slicing;
pub mod stages;
pub mod strategy;

pub use graph::{Dfg, EdgeKind, KernelKind, Node, NodeId, NodeOp};
pub use mapping::Mapping;
pub use microcode::{Block, BlockId, ExecLayout, Program, ProgramMeta};
pub use stages::{KernelPlan, StageDfg};
pub use strategy::{DataflowStrategy, PaperStrategy, SpmAdaptiveStrategy, Strategy};
