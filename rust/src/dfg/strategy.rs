//! Pluggable dataflow strategies (ROADMAP item 5, Flexagon-style).
//!
//! The paper fixes one lowering recipe: balanced Cooley-Tukey division
//! (Fig. 9), round-robin node→PE mapping (Fig. 7b/c), max/min BPMM
//! weight slicing (Fig. 10) and a fixed 8-nodes-per-PE instance packing
//! (§V-A streaming).  Flexagon's core observation (PAPERS.md) is that
//! no single dataflow wins across sparse workloads — so those decisions
//! live behind the [`DataflowStrategy`] trait:
//!
//! * [`PaperStrategy`] — the paper's recipe, extracted verbatim.  It is
//!   the default everywhere and is golden-pinned bit-exact against the
//!   pre-refactor lowering (`rust/tests/sim_golden.rs`).
//! * [`SpmAdaptiveStrategy`] — SPM-capacity-adaptive: packs DFG
//!   instances far deeper than the paper's fixed target (bounded so the
//!   in-flight working set stays SPM-resident) to amortize per-block
//!   issue overheads, and picks the r×c division by a static
//!   occupancy/NoC cost model instead of always splitting balanced.
//!
//! [`Strategy`] is the user-facing selector ([`Strategy::Auto`] makes
//! the coordinator simulate every registered concrete strategy per
//! kernel through the plan cache and memoize the winner); the concrete
//! implementations are enumerable via [`registry`].
//!
//! Contract for implementors: the *stage structure* returned by
//! [`DataflowStrategy::plan`] must not depend on `vectors` — the
//! coordinator's plan cache stores stage lists per `(kind, points,
//! division, strategy)` and re-attaches `vectors` per kernel.  The
//! schedule returned by [`DataflowStrategy::schedule`] must be a pure
//! function of `(stage, vectors, arch, window_cap)` so stage
//! measurements can be cached on `(stage, window, pack)`.

use anyhow::{bail, Result};

use crate::arch::ArchConfig;
use crate::model::log2_int;

use super::graph::KernelKind;
use super::mapping::Mapping;
use super::slicing::SlicePlan;
use super::stages::{enumerate_divisions, max_points, plan_kernel, KernelPlan, StageDfg};

/// The paper's packing target: keep at least this many butterfly nodes
/// per PE per layer so fixed block overheads stay amortized (§V-A
/// streaming).  Moved here from `coordinator::session`; the session's
/// `stage_schedule` delegates to [`paper_schedule`].
pub const TARGET_NODES_PER_PE: usize = 8;

/// The verbatim pre-refactor per-stage simulation schedule: shallow
/// stage DFGs (few nodes per PE) pack several independent instances per
/// iteration so block issue overheads amortize, the total iteration
/// count covers `vectors × sub_iters` instances, and the simulated
/// window is capped at `window_cap` (extrapolated beyond it).  Returns
/// `(iters_total, window, pack)`.
pub fn paper_schedule(
    stage: &StageDfg,
    vectors: usize,
    arch: &ArchConfig,
    window_cap: usize,
) -> (usize, usize, usize) {
    let w = arch.simd_width;
    let instances = vectors.saturating_mul(stage.sub_iters);
    let base_npe = (stage.points / 2).div_ceil(arch.num_pes()).max(1);
    let pack =
        (TARGET_NODES_PER_PE / base_npe).clamp(1, instances.div_ceil(w).max(1));
    let iters_total = instances.div_ceil(w * pack).max(1);
    let window = iters_total.min(window_cap.max(1));
    (iters_total, window, pack)
}

/// One complete lowering policy: the three decisions of the paper's
/// compiler (division planning, node→PE mapping, BPMM weight slicing)
/// plus the per-stage simulation schedule built on top of them.
///
/// Every method defaults to the paper's behavior, so [`PaperStrategy`]
/// is the empty impl and alternative strategies override only the
/// decisions they change.
pub trait DataflowStrategy: Send + Sync {
    /// Registry name (also the CLI `--strategy` value and the plan-cache
    /// discriminator — must be unique across registered strategies).
    fn name(&self) -> &'static str;

    /// One-line description for `bfdf strategies`.
    fn describe(&self) -> &'static str;

    /// Division planning (Fig. 9): decompose an `n`-point kernel into
    /// single-DFG stages.  An explicit `division` override (the Fig. 14
    /// sweep, `Session::run_with`) always wins over the strategy's own
    /// choice.  The stage structure must not depend on `vectors` (see
    /// module docs).
    fn plan(
        &self,
        kind: KernelKind,
        n: usize,
        vectors: usize,
        arch: &ArchConfig,
        division: Option<(usize, usize)>,
    ) -> Result<KernelPlan> {
        plan_kernel(kind, n, vectors, arch, division)
    }

    /// Node→PE mapping (Fig. 7b/c) for one stage DFG of `points`.
    /// Implementations must keep `Mapping::num_pes == arch.num_pes()`.
    fn mapping(&self, points: usize, arch: &ArchConfig) -> Mapping {
        Mapping::for_points(points, arch)
    }

    /// Node→PE mapping when hardware faults are present: the default
    /// compacts the butterfly onto the largest power-of-two subset of
    /// live PEs ([`Mapping::fault_aware`]), which keeps the XOR partner
    /// algebra (and the depth/node-conservation invariants) intact while
    /// dead PEs host zero nodes.  Errors — instead of panicking — when
    /// the fault set leaves nothing to map onto.  Cache aliasing is
    /// prevented structurally: the session's structural signature embeds
    /// the fault model's signature via
    /// [`crate::sim::SimOptions::signature`], so faulty and healthy
    /// measurements never share cache entries even though
    /// [`DataflowStrategy::mapping_id`] is unchanged.
    fn fault_mapping(
        &self,
        points: usize,
        arch: &ArchConfig,
        faults: &crate::arch::FaultModel,
    ) -> Result<Mapping> {
        Mapping::fault_aware(points, arch, faults)
    }

    /// Cache discriminator for [`DataflowStrategy::mapping`]: stage
    /// measurements are shared across strategies whose mapping ids (and
    /// schedules) agree, so a strategy that overrides `mapping` must
    /// return a distinct id here.
    fn mapping_id(&self) -> &'static str {
        "round-robin"
    }

    /// BPMM weight slicing (Fig. 10) for a `d_in → d_out` linear layer.
    fn slice(&self, d_in: usize, d_out: usize) -> Result<SlicePlan> {
        SlicePlan::new(d_in, d_out)
    }

    /// Per-stage simulation schedule `(iters_total, window, pack)`; see
    /// [`paper_schedule`].  Must be deterministic in its inputs.
    fn schedule(
        &self,
        stage: &StageDfg,
        vectors: usize,
        arch: &ArchConfig,
        window_cap: usize,
    ) -> (usize, usize, usize) {
        paper_schedule(stage, vectors, arch, window_cap)
    }
}

/// The paper's lowering recipe, verbatim: balanced division, round-robin
/// mapping, max/min slicing, 8-nodes-per-PE packing.
#[derive(Debug, Clone, Copy, Default)]
pub struct PaperStrategy;

impl DataflowStrategy for PaperStrategy {
    fn name(&self) -> &'static str {
        "paper"
    }

    fn describe(&self) -> &'static str {
        "the paper's recipe: balanced Fig. 9 division, round-robin mapping, \
         8-nodes/PE packing (bit-exact default)"
    }
}

/// SPM-capacity-adaptive strategy.
///
/// Two deliberate departures from the paper:
///
/// * **Deep packing** — instances are packed to
///   [`SpmAdaptiveStrategy::DEEP_NODES_PER_PE`] nodes per PE per layer
///   (4× the paper's target) so the fixed per-block issue overhead
///   (`ArchConfig::block_issue_overhead`) and per-access latencies
///   amortize over fatter blocks, bounded so `inflight_iters`
///   iterations of in+out vector slices stay resident in half the SPM.
/// * **Cost-modeled division** — instead of always taking the balanced
///   split, every `r × c` candidate (Fig. 14 space) is scored by a
///   static per-vector proxy of serialized unit time (PE occupancy,
///   NoC flow payload, SPM traffic) and the cheapest wins.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpmAdaptiveStrategy;

impl SpmAdaptiveStrategy {
    /// Deep packing target (nodes per PE per layer).
    pub const DEEP_NODES_PER_PE: usize = 32;

    /// Static per-vector cost proxy of one plan: for each stage,
    /// `sub_iters × (serialized CAL slots + NoC flow payload + SPM
    /// load/store scalars)` per worst-loaded PE.  Integer and
    /// deterministic; used only to rank divisions.
    pub fn division_cost(plan: &KernelPlan, arch: &ArchConfig) -> u64 {
        let pes = arch.num_pes().max(1);
        plan.stages
            .iter()
            .map(|s| {
                let depth = log2_int(s.points);
                let nppe = ((s.points / 2).div_ceil(pes)).max(1) as u64;
                let planes = plan.kind.planes() as u64;
                // Butterfly layers whose swap distance stays under the
                // PE count travel the NoC; the rest wrap back locally.
                let remote = (0..depth.saturating_sub(1))
                    .filter(|k| (1usize << k) < pes)
                    .count() as u64;
                let cal = nppe * depth as u64 * plan.kind.ops_per_node();
                let flow = nppe * planes * remote;
                let io = 2 * 2 * nppe * planes;
                s.sub_iters as u64 * (cal + flow + io)
            })
            .sum()
    }
}

impl DataflowStrategy for SpmAdaptiveStrategy {
    fn name(&self) -> &'static str {
        "spm-adaptive"
    }

    fn describe(&self) -> &'static str {
        "SPM-capacity-adaptive: deep instance packing bounded by SPM \
         residency, division picked by a static occupancy/NoC cost model"
    }

    fn plan(
        &self,
        kind: KernelKind,
        n: usize,
        vectors: usize,
        arch: &ArchConfig,
        division: Option<(usize, usize)>,
    ) -> Result<KernelPlan> {
        // Explicit overrides and single-stage kernels lower exactly as
        // the paper does (degenerate inputs keep plan_kernel's errors).
        if division.is_some() || !n.is_power_of_two() || n < 2 {
            return plan_kernel(kind, n, vectors, arch, division);
        }
        let cap = max_points(kind, arch);
        if n <= cap {
            return plan_kernel(kind, n, vectors, arch, None);
        }
        let mut best = plan_kernel(kind, n, vectors, arch, None)?;
        let mut best_cost = Self::division_cost(&best, arch);
        // Candidate splits need at least 4 points per factor — 2-point
        // stages collapse to one node per layer and starve the mesh.
        for (r, c) in enumerate_divisions(n, 4, cap) {
            let cand = plan_kernel(kind, n, vectors, arch, Some((r, c)))?;
            let cost = Self::division_cost(&cand, arch);
            if cost < best_cost {
                best = cand;
                best_cost = cost;
            }
        }
        Ok(best)
    }

    fn schedule(
        &self,
        stage: &StageDfg,
        vectors: usize,
        arch: &ArchConfig,
        window_cap: usize,
    ) -> (usize, usize, usize) {
        let w = arch.simd_width;
        let instances = vectors.saturating_mul(stage.sub_iters);
        let base_npe = (stage.points / 2).div_ceil(arch.num_pes()).max(1);
        // SPM residency bound: `inflight_iters` in-flight iterations of
        // in+out vector slices must fit in half the SPM (the other half
        // holds weights/twiddles).
        let iter_bytes = 2
            * stage.points
            * stage.kind.planes()
            * w
            * arch.elem_bytes
            * arch.inflight_iters.max(1);
        let spm_pack = ((arch.spm_bytes / 2) / iter_bytes.max(1)).max(1);
        let pack = (Self::DEEP_NODES_PER_PE / base_npe)
            .min(spm_pack)
            .clamp(1, instances.div_ceil(w).max(1));
        let iters_total = instances.div_ceil(w * pack).max(1);
        let window = iters_total.min(window_cap.max(1));
        (iters_total, window, pack)
    }
}

/// The paper strategy as a shared static (registry entry 0).
pub static PAPER: PaperStrategy = PaperStrategy;
/// The SPM-adaptive strategy as a shared static (registry entry 1).
pub static SPM_ADAPTIVE: SpmAdaptiveStrategy = SpmAdaptiveStrategy;

/// All registered concrete strategies, in probe order — [`PAPER`] first,
/// so `Strategy::Auto` ties resolve to the bit-exact default.
pub fn registry() -> &'static [&'static dyn DataflowStrategy] {
    static REGISTRY: [&dyn DataflowStrategy; 2] = [&PAPER, &SPM_ADAPTIVE];
    &REGISTRY
}

/// User-facing strategy selector: a registered concrete strategy, or
/// [`Strategy::Auto`] — the coordinator simulates every registry entry
/// per `(kind, points, vectors, division)` kernel shape through the plan
/// cache and memoizes the fastest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Strategy {
    /// The paper's recipe ([`PaperStrategy`], the bit-exact default).
    #[default]
    Paper,
    /// [`SpmAdaptiveStrategy`].
    SpmAdaptive,
    /// Simulate-and-pick across the registry.
    Auto,
}

impl Strategy {
    /// Every selectable strategy, concrete implementations first.
    pub const ALL: [Strategy; 3] = [Strategy::Paper, Strategy::SpmAdaptive, Strategy::Auto];

    /// Stable name (CLI value, cache/search-space token).
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Paper => "paper",
            Strategy::SpmAdaptive => "spm-adaptive",
            Strategy::Auto => "auto",
        }
    }

    /// One-line description for `bfdf strategies`.
    pub fn describe(self) -> &'static str {
        match self {
            Strategy::Paper => PAPER.describe(),
            Strategy::SpmAdaptive => SPM_ADAPTIVE.describe(),
            Strategy::Auto => {
                "simulate every registered strategy per kernel shape through \
                 the plan cache and pick the lowest-latency one"
            }
        }
    }

    /// Parse a CLI / search-space token.  Error message names the valid
    /// tokens and is pinned by tests.
    pub fn parse(s: &str) -> Result<Strategy> {
        match s.trim() {
            "paper" => Ok(Strategy::Paper),
            "spm-adaptive" => Ok(Strategy::SpmAdaptive),
            "auto" => Ok(Strategy::Auto),
            other => bail!(
                "unknown strategy '{other}' (available: paper, spm-adaptive, auto)"
            ),
        }
    }

    /// The concrete implementation, or `None` for [`Strategy::Auto`].
    pub fn implementation(self) -> Option<&'static dyn DataflowStrategy> {
        match self {
            Strategy::Paper => Some(&PAPER),
            Strategy::SpmAdaptive => Some(&SPM_ADAPTIVE),
            Strategy::Auto => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_paper_first_with_unique_names() {
        let reg = registry();
        assert_eq!(reg[0].name(), "paper");
        let mut names: Vec<_> = reg.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), reg.len(), "strategy names must be unique");
    }

    #[test]
    fn selector_round_trips_and_rejects_unknown() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::parse(s.name()).unwrap(), s);
        }
        assert_eq!(Strategy::default(), Strategy::Paper);
        let err = Strategy::parse("tiled").unwrap_err().to_string();
        assert_eq!(
            err,
            "unknown strategy 'tiled' (available: paper, spm-adaptive, auto)"
        );
        assert!(Strategy::Auto.implementation().is_none());
        assert_eq!(Strategy::Paper.implementation().unwrap().name(), "paper");
    }

    #[test]
    fn paper_strategy_is_verbatim() {
        let arch = ArchConfig::full();
        for (kind, n) in [
            (KernelKind::Bpmm, 1024),
            (KernelKind::Fft, 512),
            (KernelKind::Fft, 64 * 1024),
            (KernelKind::Bpmm, 256),
        ] {
            let a = PAPER.plan(kind, n, 7, &arch, None).unwrap();
            let b = plan_kernel(kind, n, 7, &arch, None).unwrap();
            assert_eq!(a, b, "{kind:?} {n}");
            for stage in &a.stages {
                assert_eq!(
                    PAPER.schedule(stage, 7, &arch, 48),
                    paper_schedule(stage, 7, &arch, 48)
                );
            }
        }
        assert_eq!(PAPER.mapping(64, &arch), Mapping::for_points(64, &arch));
        let s = PAPER.slice(1024, 256).unwrap();
        assert_eq!((s.pieces, s.piece_points), (4, 256));
    }

    #[test]
    fn all_strategies_conserve_depth_and_nodes() {
        let arch = ArchConfig::full();
        for strat in registry() {
            for kind in [KernelKind::Bpmm, KernelKind::Fft] {
                for exp in 1..=16 {
                    let n = 1usize << exp;
                    let p = strat.plan(kind, n, 3, &arch, None).unwrap();
                    assert_eq!(
                        p.total_depth(),
                        exp,
                        "{} {kind:?} {n}: depth",
                        strat.name()
                    );
                    assert_eq!(
                        p.nodes_per_vector(),
                        n / 2 * exp,
                        "{} {kind:?} {n}: nodes",
                        strat.name()
                    );
                    assert_eq!(p.vectors, 3);
                }
            }
        }
    }

    #[test]
    fn all_strategies_conserve_nodes_on_faulty_meshes() {
        // The faulty-mesh extension of the conservation invariant: for a
        // ladder of dead-PE counts, every strategy's fault mapping keeps
        // each stage's layer fully assigned to live PEs, balanced over
        // the compacted slots, with the plan structure untouched.
        let arch = ArchConfig::full();
        for dead in [1usize, 3, 7, 12, 15] {
            let fm = crate::arch::FaultModel::seeded(&arch, 11, dead, 0, 1, 0).unwrap();
            for strat in registry() {
                for kind in [KernelKind::Bpmm, KernelKind::Fft] {
                    let n = 1usize << 10;
                    let p = strat.plan(kind, n, 3, &arch, None).unwrap();
                    assert_eq!(p.total_depth(), 10, "{}: plan unchanged by faults", strat.name());
                    for stage in &p.stages {
                        let m = strat.fault_mapping(stage.points, &arch, &fm).unwrap();
                        assert_eq!(m.num_pes, arch.num_pes(), "lowering contract");
                        let per = m.nodes_per_pe();
                        assert_eq!(
                            per.iter().sum::<usize>(),
                            stage.points / 2,
                            "{} {kind:?} dead={dead}: nodes conserved",
                            strat.name()
                        );
                        for pe in 0..arch.num_pes() {
                            if fm.pe_dead(pe) {
                                assert_eq!(per[pe], 0, "dead PE {pe} hosts nodes");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn spm_adaptive_packs_deeper_on_shallow_stages() {
        let arch = ArchConfig::full();
        let stage = StageDfg {
            kind: KernelKind::Bpmm,
            points: 32,
            sub_iters: 32,
            twiddle_before: false,
            weights_from_ddr: false,
        };
        let (pi, pw, pp) = paper_schedule(&stage, 256, &arch, 48);
        let (ai, aw, ap) = SPM_ADAPTIVE.schedule(&stage, 256, &arch, 48);
        assert_eq!(pp, 8);
        assert_eq!(ap, 32, "deep packing target on a 1-node/PE stage");
        assert!(ai < pi, "deeper packs mean fewer iterations");
        assert!(aw <= pw);
        // Instance coverage is conserved: every schedule covers all
        // vectors × sub_iters instances.
        let w = arch.simd_width;
        assert!(ai * w * ap >= 256 * 32);
        assert!(pi * w * pp >= 256 * 32);
    }

    #[test]
    fn spm_bound_caps_pack_on_fat_stages() {
        // A 512-point FFT stage moves 512·2 planes·32 lanes·2 B ≈ 64 KiB
        // per in+out pair per packed instance; with 4 in-flight
        // iterations the SPM residency bound caps the pack.
        let arch = ArchConfig::full();
        let stage = StageDfg {
            kind: KernelKind::Fft,
            points: 256,
            sub_iters: 256,
            twiddle_before: false,
            weights_from_ddr: false,
        };
        let (_, _, pack) = SPM_ADAPTIVE.schedule(&stage, 4096, &arch, 48);
        let iter_bytes =
            2 * 256 * 2 * arch.simd_width * arch.elem_bytes * arch.inflight_iters;
        assert!(pack * iter_bytes <= arch.spm_bytes / 2);
        assert!(pack >= 1);
    }

    #[test]
    fn spm_adaptive_division_is_exact_and_scored() {
        let arch = ArchConfig::full();
        // 2048-point BPMM: candidates (16,128)..(128,16); whatever wins
        // must be a valid exact factorization at full depth.
        let p = SPM_ADAPTIVE.plan(KernelKind::Bpmm, 2048, 1, &arch, None).unwrap();
        assert_eq!(p.total_depth(), 11);
        assert_eq!(p.stages.iter().map(|s| s.points).product::<usize>(), 2048);
        // The balanced split is among the candidates, so the winner can
        // never score worse than it.
        let balanced = plan_kernel(KernelKind::Bpmm, 2048, 1, &arch, None).unwrap();
        assert!(
            SpmAdaptiveStrategy::division_cost(&p, &arch)
                <= SpmAdaptiveStrategy::division_cost(&balanced, &arch)
        );
        // Explicit division overrides the cost model.
        let forced =
            SPM_ADAPTIVE.plan(KernelKind::Bpmm, 2048, 1, &arch, Some((16, 128))).unwrap();
        assert_eq!((forced.stages[0].points, forced.stages[1].points), (16, 128));
    }
}
