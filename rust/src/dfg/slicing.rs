//! BPMM weight-matrix slicing for unequal input/output hidden sizes
//! (Fig. 10).
//!
//! A linear layer `d_in → d_out` whose sizes differ is sliced into
//! `k = max/min` square butterfly pieces of scale `m = min(d_in, d_out)`:
//! larger input ⇒ slice `x` and **sum** the piece products; larger output
//! ⇒ run `k` factor sets over the same `x` and **concatenate**.

use anyhow::{bail, Result};

use crate::model::log2_int;

/// How piece results combine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Combine {
    /// d_in > d_out: piece outputs are accumulated.
    Sum,
    /// d_in < d_out: piece outputs are concatenated.
    Concat,
    /// d_in == d_out: single piece.
    Single,
}

/// A slicing plan for one BPMM linear layer.
///
/// Slicing is one of the three lowering decisions a
/// [`crate::dfg::strategy::DataflowStrategy`] owns
/// (`DataflowStrategy::slice`); every current strategy delegates to
/// [`SlicePlan::new`], but the trait hook keeps the contract explicit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlicePlan {
    pub d_in: usize,
    pub d_out: usize,
    /// Butterfly scale of each piece.
    pub piece_points: usize,
    /// Number of pieces (factor sets).
    pub pieces: usize,
    pub combine: Combine,
}

impl SlicePlan {
    /// Build the plan; both sizes must be powers of two.
    pub fn new(d_in: usize, d_out: usize) -> Result<Self> {
        if !d_in.is_power_of_two() || !d_out.is_power_of_two() {
            bail!("hidden sizes must be powers of two: {d_in} -> {d_out}");
        }
        let m = d_in.min(d_out);
        let k = d_in.max(d_out) / m;
        let combine = if d_in == d_out {
            Combine::Single
        } else if d_in > d_out {
            Combine::Sum
        } else {
            Combine::Concat
        };
        Ok(SlicePlan { d_in, d_out, piece_points: m, pieces: k, combine })
    }

    /// Butterfly-node evaluations per input row: pieces × (m/2) log2 m.
    pub fn nodes_per_row(&self) -> usize {
        self.pieces * (self.piece_points / 2) * log2_int(self.piece_points)
    }

    /// Extra element-wise accumulate ops per row (Sum combine).
    pub fn reduce_ops_per_row(&self) -> usize {
        match self.combine {
            Combine::Sum => (self.pieces - 1) * self.d_out,
            _ => 0,
        }
    }

    /// Sparse parameter count (vs the dense d_in*d_out).
    pub fn param_count(&self) -> usize {
        self.pieces * 2 * self.piece_points * log2_int(self.piece_points)
    }

    /// Compression ratio against the dense layer.
    pub fn compression(&self) -> f64 {
        (self.d_in * self.d_out) as f64 / self.param_count() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn square_layer_single_piece() {
        let p = SlicePlan::new(256, 256).unwrap();
        assert_eq!(p.pieces, 1);
        assert_eq!(p.combine, Combine::Single);
        assert_eq!(p.piece_points, 256);
    }

    #[test]
    fn shrinking_layer_sums() {
        // Fig. 10 top: d_in 1024 > d_out 256 ⇒ 4 pieces summed.
        let p = SlicePlan::new(1024, 256).unwrap();
        assert_eq!(p.pieces, 4);
        assert_eq!(p.combine, Combine::Sum);
        assert_eq!(p.reduce_ops_per_row(), 3 * 256);
    }

    #[test]
    fn expanding_layer_concats() {
        // Fig. 10 bottom: FFN expansion 256 → 1024 ⇒ 4 pieces concat.
        let p = SlicePlan::new(256, 1024).unwrap();
        assert_eq!(p.pieces, 4);
        assert_eq!(p.combine, Combine::Concat);
        assert_eq!(p.reduce_ops_per_row(), 0);
    }

    #[test]
    fn rejects_non_power_of_two() {
        assert!(SlicePlan::new(100, 256).is_err());
    }

    #[test]
    fn params_always_compress() {
        check("slice-params-compress", 100, |rng| {
            let d_in = rng.pow2(64, 4096);
            let d_out = rng.pow2(64, 4096);
            let p = SlicePlan::new(d_in, d_out).unwrap();
            assert!(
                p.param_count() < d_in * d_out,
                "{d_in}x{d_out}: {} !< dense",
                p.param_count()
            );
            // Output coverage: concat pieces tile d_out exactly.
            match p.combine {
                Combine::Concat => assert_eq!(p.pieces * p.piece_points, d_out),
                Combine::Sum => assert_eq!(p.pieces * p.piece_points, d_in),
                Combine::Single => assert_eq!(p.piece_points, d_in),
            }
        });
    }
}
