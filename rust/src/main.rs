//! `bfdf` — the butterfly-dataflow command-line launcher.
//!
//! Subcommands cover interactive use of every layer: simulating kernels,
//! sweeping divisions, printing the platform/energy tables, validating
//! the AOT artifacts through PJRT, and streaming workloads end-to-end.
//! `run` addresses scenarios three ways: a registered suite
//! (`--workload vanilla`), an inline hybrid-network spec
//! (`--spec 'att:fft2d,ffn:bpmm*x4;att:dense,ffn:bpmm*x2'`), or a JSON
//! model file (`--model-file net.json`) — the latter two execute
//! arbitrary hybrid butterfly-sparsity networks with per-layer metrics.
//! All subcommands accept `--json` to emit a machine-readable [`Report`]
//! (or an equivalent JSON document) instead of the text tables, so
//! benches and CI can parse results without scraping.
//!
//! Simulation subcommands are backed by a [`Session`]: kernels sharing
//! stage DFGs (division sweeps, networks with repeated layers) lower
//! and simulate once, and independent kernels fan out across threads.

use anyhow::{Context as _, Result};

use butterfly_dataflow::arch::{ArchConfig, UnitKind};
use butterfly_dataflow::coordinator::autotune;
use butterfly_dataflow::coordinator::{
    Admission, AutotuneConfig, AutotuneResult, Journal, NetworkResult, Objective, Overlap,
    Report, ReplicaFaults, SearchSpace, ServeConfig, ServeResult, Session, StructuralStore,
    SweepRow, Traffic, WorkloadClass,
};
use butterfly_dataflow::dfg::graph::KernelKind;
use butterfly_dataflow::dfg::stages::enumerate_divisions;
use butterfly_dataflow::dfg::strategy::Strategy;
use butterfly_dataflow::energy;
use butterfly_dataflow::runtime::Runtime;
use butterfly_dataflow::sim::SimOptions;
use butterfly_dataflow::util::cli::{App, Command, Matches};
use butterfly_dataflow::util::json::{arr, num, obj, s, Json};
use butterfly_dataflow::util::stats::{fmt_time, si};
use butterfly_dataflow::util::table::Table;
use butterfly_dataflow::workloads::{self, KernelSpec, ModelSpec, NetworkBuilder, platforms};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn app() -> App {
    App::new("bfdf", "multilayer dataflow orchestration for butterfly sparsity")
        .command(
            Command::new("simulate", "simulate one butterfly kernel on the dataflow array")
                .opt("kind", "fft", "kernel kind: fft | bpmm")
                .opt("points", "256", "transform length (power of two)")
                .opt("vectors", "8192", "independent vectors (batch x rows)")
                .opt("window", "48", "simulation window (DFG iterations)")
                .opt("division", "auto", "stage division RxC, e.g. 64x32, or 'auto'")
                .opt("arch", "full", "architecture preset: full | scaled128")
                .flag("no-multiline-spm", "ablation: single-line SPM")
                .flag("fifo", "ablation: FIFO block scheduling")
                .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new("sweep-divisions", "Fig. 14 sweep: CalUnit utilization per division")
                .opt("kind", "bpmm", "kernel kind: fft | bpmm")
                .opt("points", "4096", "transform length")
                .opt("vectors", "8192", "independent vectors")
                .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new("run", "stream a workload suite or a declarative hybrid network")
                .opt("workload", "", "suite name (see the 'workloads' subcommand)")
                .opt(
                    "spec",
                    "",
                    "inline network spec, e.g. 'att:fft2d,ffn:bpmm*x4;att:dense,ffn:bpmm*x2'",
                )
                .opt("model-file", "", "path to a JSON model description")
                .opt("hidden", "default", "hidden size for --spec networks (default 512)")
                .opt("seq", "default", "sequence length for --spec networks (default 256)")
                .opt("heads", "default", "attention heads for --spec networks (default 1)")
                .opt("batch", "default", "streamed batch size ('default' = workload/model default)")
                .opt("arch", "scaled128", "architecture preset: full | scaled128")
                .opt("window", "48", "simulation window (DFG iterations)")
                .opt("overlap", "pipeline", "streaming overlap model: none | dma | pipeline")
                .opt("arrays", "1", "replicated dataflow arrays the batch shards across")
                .opt(
                    "strategy",
                    "paper",
                    "dataflow strategy: paper | spm-adaptive | auto (see 'strategies')",
                )
                .opt("threads", "auto", "simulation worker threads ('auto' = all cores)")
                .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new("workloads", "list the registered workload suites")
                .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new("strategies", "list the registered dataflow strategies")
                .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new("platforms", "print the Table I platform comparison")
                .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new("energy-model", "print the Table III power/area model")
                .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new("validate", "run every AOT artifact through PJRT against goldens")
                .opt("artifacts", "artifacts", "artifact directory")
                .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new("stream", "Table IV end-to-end vanilla-transformer streaming")
                .opt("batch", "256", "streamed batch size")
                .opt("arch", "scaled128", "architecture preset: full | scaled128")
                .opt("overlap", "pipeline", "streaming overlap model: none | dma | pipeline")
                .opt("arrays", "1", "replicated dataflow arrays the batch shards across")
                .opt(
                    "strategy",
                    "paper",
                    "dataflow strategy: paper | spm-adaptive | auto (see 'strategies')",
                )
                .opt("threads", "auto", "simulation worker threads ('auto' = all cores)")
                .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new(
                "serve-sim",
                "serving simulation: trace-driven traffic, dynamic batching, SLO percentiles",
            )
            .opt(
                "workloads",
                "vanilla",
                "space-separated request classes (quote the list): suite names and/or \
                 spec strings, e.g. 'vit-256 att:fft2d,ffn:bpmm*x2'",
            )
            .opt("rate", "500", "offered load in req/s; a comma-separated list sweeps rates")
            .opt("duration", "0.5", "arrival horizon in simulated seconds")
            .opt(
                "trace",
                "",
                "JSON arrival-trace file (overrides --workloads/--rate/--duration)",
            )
            .opt("max-batch", "8", "dynamic batcher: max requests packed per batch")
            .opt(
                "max-wait-ms",
                "2",
                "dynamic batcher: max wait before a partial batch dispatches (ms)",
            )
            .opt("arrays", "1", "replica dataflow arrays, each serving one batch at a time")
            .opt("queue-cap", "256", "bounded admission queue; overflow arrivals are rejected")
            .opt("seed", "42", "traffic seed (a fixed seed reproduces the run bit-for-bit)")
            .opt("arch", "scaled128", "architecture preset: full | scaled128")
            .opt("overlap", "pipeline", "per-batch overlap model: none | dma | pipeline")
            .opt(
                "faults",
                "",
                "replica fault-trace JSON file (scripted up/down events; conflicts with \
                 --mtbf/--mttr)",
            )
            .opt("mtbf", "", "seeded replica fault process: mean time between failures (s)")
            .opt("mttr", "", "seeded replica fault process: mean time to repair (s)")
            .opt("fault-seed", "7", "seed for the --mtbf/--mttr fault process")
            .opt("admission", "fifo", "admission policy: fifo | slo-aware")
            .opt(
                "deadline-ms",
                "",
                "per-request deadline (ms): stale queued requests are cancelled, and \
                 slo-aware admission sheds by slack",
            )
            .opt("retries", "3", "max re-enqueues for requests lost to a replica failure")
            .opt("out", "", "also write the JSON report to this path (e.g. BENCH_serving.json)")
            .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new(
                "autotune",
                "design-space sweep: latency/energy/area Pareto frontier per workload class",
            )
            .opt(
                "space",
                "default",
                "search-space grammar, e.g. 'mesh=2x2,4x4;simd=8,32;spm=2m,4m;ports=4;ddr=1,2;\
                 arrays=1,2;strategy=paper,auto', or 'default'",
            )
            .opt(
                "suites",
                "all",
                "space-separated workload classes (quote the list): suite names and/or spec \
                 strings, or 'all' for every registered suite",
            )
            .opt("batch", "default", "batch override for every class ('default' = per-class)")
            .opt(
                "objective",
                "edp",
                "best-point ranking: latency | energy | area | efficiency | edp",
            )
            .opt("arch", "scaled128", "base architecture preset: full | scaled128")
            .opt("window", "48", "simulation window (DFG iterations)")
            .opt("overlap", "pipeline", "per-batch overlap model: none | dma | pipeline")
            .opt(
                "strategy",
                "paper",
                "dataflow strategy for every point when --space has no strategy= axis: \
                 paper | spm-adaptive | auto",
            )
            .opt("journal", "", "checkpoint journal path (JSON lines); enables --resume")
            .opt(
                "store",
                "",
                "structural result store path (JSON lines); --resume also reloads it",
            )
            .opt("threads", "auto", "simulation worker threads ('auto' = all cores)")
            .flag("resume", "replay completed evaluations from --journal instead of re-running")
            .flag("no-prune", "disable the shard/roofline pruner (evaluate the full grid)")
            .opt("out", "", "also write the JSON report to this path (e.g. BENCH_pareto.json)")
            .flag("json", "emit a machine-readable report"),
        )
        .command(
            Command::new("gpu-model", "run the Jetson GPU baseline on a butterfly kernel")
                .opt("kind", "fft", "kernel kind: fft | bpmm")
                .opt("points", "1024", "transform length")
                .opt("vectors", "8192", "independent vectors")
                .opt("platform", "nx", "gpu platform: nx | nano")
                .flag("json", "emit a machine-readable report"),
        )
}

fn parse_kind(s: &str) -> Result<KernelKind> {
    match s {
        "fft" => Ok(KernelKind::Fft),
        "bpmm" => Ok(KernelKind::Bpmm),
        other => anyhow::bail!("unknown kernel kind '{other}' (fft | bpmm)"),
    }
}

fn parse_arch(s: &str) -> Result<ArchConfig> {
    match s {
        "full" => Ok(ArchConfig::full()),
        "scaled128" => Ok(ArchConfig::scaled_128()),
        other => anyhow::bail!("unknown arch preset '{other}' (full | scaled128)"),
    }
}

/// Parse the streaming-schedule knobs (`--overlap`, `--arrays`).
fn parse_pipeline(m: &Matches) -> Result<(Overlap, usize)> {
    let overlap = Overlap::parse(m.get("overlap"))?;
    let arrays = m.get_usize("arrays")?;
    anyhow::ensure!(arrays >= 1, "--arrays must be >= 1 (got {arrays})");
    Ok((overlap, arrays))
}

/// Parse `--strategy` (defaults to `paper`, the bit-exact recipe).
fn parse_strategy(m: &Matches) -> Result<Strategy> {
    Strategy::parse(m.get("strategy"))
}

/// Parse `--threads`: `auto` (0) lets the session use every core;
/// an explicit count pins the worker pool (1 = fully serial).
fn parse_threads(m: &Matches) -> Result<usize> {
    let s = m.get("threads");
    if s == "auto" {
        return Ok(0);
    }
    let n: usize = s
        .parse()
        .with_context(|| format!("--threads must be 'auto' or a count (got '{s}')"))?;
    anyhow::ensure!(n >= 1, "--threads must be >= 1 (got {n})");
    Ok(n)
}

/// One line per auto-selection a session made, for the text output
/// (empty unless the session ran with `--strategy auto`).
fn print_auto_selections(session: &Session) {
    for ((kind, points, vectors), winner) in session.auto_selections() {
        println!("auto strategy: {kind}-{points} x{vectors} -> {winner}");
    }
}

fn parse_division(s: &str) -> Result<Option<(usize, usize)>> {
    if s == "auto" {
        return Ok(None);
    }
    let (r, c) = s
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("division must be RxC, e.g. 64x32"))?;
    Ok(Some((r.parse()?, c.parse()?)))
}

fn point_spec(kind: KernelKind, points: usize, vectors: usize) -> KernelSpec {
    KernelSpec {
        name: format!("{}-{}", kind.name(), points),
        kind,
        points,
        vectors,
        d_in: points,
        d_out: points,
        seq: points,
    }
}

fn run(args: &[String]) -> Result<()> {
    let app = app();
    let (cmd, m) = app.parse(args)?;
    match cmd.as_str() {
        "simulate" => cmd_simulate(&m),
        "sweep-divisions" => cmd_sweep(&m),
        "run" => cmd_run(&m),
        "workloads" => cmd_workloads(&m),
        "strategies" => cmd_strategies(&m),
        "platforms" => cmd_platforms(&m),
        "energy-model" => cmd_energy_model(&m),
        "validate" => cmd_validate(&m),
        "stream" => cmd_stream(&m),
        "serve-sim" => cmd_serve_sim(&m),
        "autotune" => cmd_autotune(&m),
        "gpu-model" => cmd_gpu_model(&m),
        other => anyhow::bail!("unhandled command {other}"),
    }
}

fn cmd_simulate(m: &Matches) -> Result<()> {
    let kind = parse_kind(m.get("kind"))?;
    let points = m.get_usize("points")?;
    let vectors = m.get_usize("vectors")?;
    let spec = point_spec(kind, points, vectors);
    let session = Session::builder()
        .arch(parse_arch(m.get("arch"))?)
        .window(m.get_usize("window")?)
        .sim(SimOptions {
            no_multiline_spm: m.flag("no-multiline-spm"),
            fifo_scheduling: m.flag("fifo"),
            ..Default::default()
        })
        .build();
    let r = session.run_with(&spec, parse_division(m.get("division"))?)?;
    if m.flag("json") {
        let report = Report::Kernel {
            arch: session.arch_signature().to_string(),
            result: r,
        };
        println!("{}", report.render());
        return Ok(());
    }
    let mut t = Table::new(
        &format!("simulate {} ({} vectors)", r.name, vectors),
        &["metric", "value"],
    );
    t.row(&["cycles".into(), format!("{:.0}", r.cycles)]);
    t.row(&["time".into(), fmt_time(r.time_s)]);
    t.row(&["stages".into(), format!("{:?}",
        r.plan.stages.iter().map(|s| s.points).collect::<Vec<_>>())]);
    for k in UnitKind::ALL {
        t.row(&[format!("util.{}", k.name()), format!("{:.1}%", 100.0 * r.util_of(k))]);
    }
    t.row(&["spm requirement".into(), format!("{:.2}%", 100.0 * r.spm_requirement)]);
    t.row(&["flops".into(), si(r.flops)]);
    t.row(&["flops efficiency".into(), format!("{:.1}%", 100.0 * r.flops_efficiency)]);
    t.row(&["power".into(), format!("{:.2} W", r.power_w)]);
    t.row(&["energy".into(), format!("{:.4} J", r.energy_j)]);
    t.row(&["ddr traffic".into(), format!("{}B", si(r.dma_bytes))]);
    t.print();
    Ok(())
}

fn cmd_sweep(m: &Matches) -> Result<()> {
    let kind = parse_kind(m.get("kind"))?;
    let points = m.get_usize("points")?;
    let vectors = m.get_usize("vectors")?;
    let session = Session::builder().build();
    let cap = match kind {
        KernelKind::Fft => session.arch().max_fft_points,
        KernelKind::Bpmm => session.arch().max_bpmm_points,
    };
    let mut rows = Vec::new();
    for (r, c) in enumerate_divisions(points, 16, cap) {
        let spec = KernelSpec {
            name: format!("{}-{points}-{r}x{c}", kind.name()),
            ..point_spec(kind, points, vectors)
        };
        let res = session.run_with(&spec, Some((r, c)))?;
        rows.push(SweepRow { division: (r, c), cycles: res.cycles, util: res.util });
    }
    if m.flag("json") {
        let report = Report::Sweep {
            arch: session.arch_signature().to_string(),
            kernel: format!("{}-{points}", kind.name()),
            rows,
        };
        println!("{}", report.render());
        return Ok(());
    }
    let mut t = Table::new(
        &format!("Fig.14 division sweep: {} {}", kind.name(), points),
        &["division", "cycles", "cal util", "load util", "flow util"],
    );
    for row in &rows {
        t.row(&[
            format!("{}x{}", row.division.0, row.division.1),
            format!("{:.0}", row.cycles),
            format!("{:.2}%", 100.0 * row.util[UnitKind::Cal.index()]),
            format!("{:.2}%", 100.0 * row.util[UnitKind::Load.index()]),
            format!("{:.2}%", 100.0 * row.util[UnitKind::Flow.index()]),
        ]);
    }
    t.print();
    Ok(())
}

/// Parse an optional shape option: `'default'` means "not overridden".
fn opt_usize(m: &Matches, name: &str) -> Result<Option<usize>> {
    let raw = m.get(name);
    if raw == "default" {
        return Ok(None);
    }
    let v: usize = raw
        .parse()
        .map_err(|_| anyhow::anyhow!("--{name} expects an integer or 'default', got '{raw}'"))?;
    Ok(Some(v))
}

/// Parse `--batch`: `'default'` defers to the workload/model default;
/// an explicit `0` is rejected (it used to silently mean "default").
fn parse_batch(m: &Matches) -> Result<Option<usize>> {
    let raw = m.get("batch");
    if raw == "default" {
        return Ok(None);
    }
    let batch: usize = raw
        .parse()
        .map_err(|_| anyhow::anyhow!("--batch expects an integer or 'default', got '{raw}'"))?;
    anyhow::ensure!(
        batch > 0,
        "--batch 0 is invalid: batch must be >= 1 (omit --batch or pass 'default' \
         to use the workload's default batch)"
    );
    Ok(Some(batch))
}

fn cmd_run(m: &Matches) -> Result<()> {
    let workload = m.get("workload");
    let spec = m.get("spec");
    let model_file = m.get("model-file");
    let given = [workload, spec, model_file]
        .iter()
        .filter(|v| !v.is_empty())
        .count();
    anyhow::ensure!(
        given == 1,
        "pass exactly one of --workload <name>, --spec <grammar>, --model-file <path>"
    );
    let batch = parse_batch(m)?;
    let hidden = opt_usize(m, "hidden")?;
    let seq = opt_usize(m, "seq")?;
    let heads = opt_usize(m, "heads")?;
    // Shape overrides only make sense for --spec networks; anywhere
    // else they would be silently ignored, so reject them instead.
    if spec.is_empty() {
        anyhow::ensure!(
            hidden.is_none() && seq.is_none() && heads.is_none(),
            "--hidden/--seq/--heads apply only to --spec networks (workload suites and \
             model files carry their own shape parameters)"
        );
    }
    let (overlap, arrays) = parse_pipeline(m)?;
    let session = Session::builder()
        .arch(parse_arch(m.get("arch"))?)
        .window(m.get_usize("window")?)
        .overlap(overlap)
        .arrays(arrays)
        .strategy(parse_strategy(m)?)
        .threads(parse_threads(m)?)
        .build();
    if !workload.is_empty() {
        return run_suite(m, &session, workload, batch);
    }
    let model = if !spec.is_empty() {
        NetworkBuilder::from_spec("cli-spec", spec)?
            .hidden(hidden.unwrap_or(512))
            .seq(seq.unwrap_or(256))
            .heads(heads.unwrap_or(1))
            .build()?
    } else {
        let text = std::fs::read_to_string(model_file)
            .map_err(|e| anyhow::anyhow!("cannot read model file '{model_file}': {e}"))?;
        ModelSpec::from_json_str(&text)?
    };
    let r = session.run_network(&model, batch)?;
    let cache = session.cache_stats();
    if m.flag("json") {
        let report = Report::Network {
            arch: session.arch_signature().to_string(),
            strategy: session.strategy(),
            cache,
            result: r,
        };
        println!("{}", report.render());
        return Ok(());
    }
    print_network(&r);
    print_auto_selections(&session);
    println!(
        "plan cache: {} lowerings ({} stage hits, {} plan hits)",
        cache.lowerings, cache.stage_hits, cache.plan_hits
    );
    println!(
        "structural store: {} hits, {} misses",
        cache.structural_hits, cache.structural_misses
    );
    Ok(())
}

/// Stream a registered suite (the historical `run --workload` path).
fn run_suite(
    m: &Matches,
    session: &Session,
    name: &str,
    batch: Option<usize>,
) -> Result<()> {
    let suite = workloads::find_suite(name)?;
    let batch = batch.unwrap_or(suite.default_batch);
    let r = session.stream(&suite.kernels_at(Some(batch)), batch)?;
    let cache = session.cache_stats();
    if m.flag("json") {
        let report = Report::Stream {
            arch: session.arch_signature().to_string(),
            workload: suite.name.to_string(),
            strategy: session.strategy(),
            cache,
            result: r,
        };
        println!("{}", report.render());
        return Ok(());
    }
    let mut t = Table::new(
        &format!("workload {} (batch {batch}, {} kernels)", suite.name, r.kernels.len()),
        &["kernel", "time", "cal util", "power W"],
    );
    for k in &r.kernels {
        t.row(&[
            k.name.clone(),
            fmt_time(k.time_s),
            format!("{:.1}%", 100.0 * k.util_of(UnitKind::Cal)),
            format!("{:.2}", k.power_w),
        ]);
    }
    t.print();
    let mut t = Table::new("end-to-end", &["metric", "value"]);
    t.row(&["overlap".into(), format!("{} ({} arrays)", r.overlap.name(), r.arrays)]);
    t.row(&["serial time".into(), fmt_time(r.serial_time_s)]);
    t.row(&["batch time".into(), fmt_time(r.batch_time_s)]);
    t.row(&["speedup".into(), format!("{:.2}x", r.speedup())]);
    t.row(&["pipeline eff.".into(), format!("{:.1}%", 100.0 * r.pipeline_efficiency)]);
    t.row(&["latency".into(), format!("{:.3} ms", r.latency_ms)]);
    t.row(&["throughput".into(), format!("{:.1} pred/s", r.throughput)]);
    t.row(&["power".into(), format!("{:.2} W", r.power_w)]);
    t.row(&["energy eff.".into(), format!("{:.1} pred/J", r.energy_eff)]);
    t.print();
    if session.strategy() != Strategy::Paper {
        println!("strategy: {}", session.strategy().name());
    }
    print_auto_selections(session);
    println!(
        "plan cache: {} lowerings for {} kernels ({} stage hits, {} plan hits)",
        cache.lowerings,
        r.kernels.len(),
        cache.stage_hits,
        cache.plan_hits
    );
    println!(
        "structural store: {} hits, {} misses",
        cache.structural_hits, cache.structural_misses
    );
    Ok(())
}

/// Text tables for a hybrid-network run: per-block breakdown plus
/// end-to-end totals.
fn print_network(r: &NetworkResult) {
    let mut t = Table::new(
        &format!(
            "network {} (batch {}, {} layers): {}",
            r.network,
            r.batch,
            r.layers.len(),
            r.spec
        ),
        &["layer", "block", "time", "cal util", "energy J"],
    );
    for l in &r.layers {
        for b in &l.blocks {
            let cal = if b.kernels.is_empty() {
                "dense".into()
            } else {
                format!("{:.1}%", 100.0 * b.util[UnitKind::Cal.index()])
            };
            t.row(&[
                format!("{}", l.layer),
                b.label.clone(),
                fmt_time(b.time_s),
                cal,
                format!("{:.4}", b.energy_j),
            ]);
        }
    }
    t.print();
    let mut t = Table::new("end-to-end", &["metric", "value"]);
    t.row(&["overlap".into(), format!("{} ({} arrays)", r.overlap.name(), r.arrays)]);
    t.row(&["serial time".into(), fmt_time(r.serial_time_s)]);
    t.row(&["batch time".into(), fmt_time(r.batch_time_s)]);
    t.row(&["speedup".into(), format!("{:.2}x", r.speedup())]);
    t.row(&["pipeline eff.".into(), format!("{:.1}%", 100.0 * r.pipeline_efficiency)]);
    t.row(&["latency".into(), format!("{:.3} ms", r.latency_ms)]);
    t.row(&["throughput".into(), format!("{:.1} pred/s", r.throughput)]);
    t.row(&["power".into(), format!("{:.2} W", r.power_w)]);
    t.row(&["energy eff.".into(), format!("{:.1} pred/J", r.energy_eff)]);
    t.row(&[
        "cal util".into(),
        format!("{:.1}%", 100.0 * r.util[UnitKind::Cal.index()]),
    ]);
    t.print();
}

fn cmd_workloads(m: &Matches) -> Result<()> {
    if m.flag("json") {
        let items = workloads::SUITES
            .iter()
            .map(|w| {
                obj(vec![
                    ("name", s(w.name)),
                    ("family", s(w.family.name())),
                    ("seq", num(w.seq as f64)),
                    ("default_batch", num(w.default_batch as f64)),
                    ("kernels", num(w.default_kernels().len() as f64)),
                    ("spec", s(&w.model().spec_string())),
                ])
            })
            .collect();
        let report = obj(vec![("report", s("workloads")), ("suites", arr(items))]);
        println!("{}", report.render());
        return Ok(());
    }
    let mut t = Table::new(
        "registered workload suites",
        &["name", "family", "seq", "default batch", "kernels", "spec"],
    );
    for w in workloads::SUITES {
        t.row(&[
            w.name.to_string(),
            w.family.name().to_string(),
            format!("{}", w.seq),
            format!("{}", w.default_batch),
            format!("{}", w.default_kernels().len()),
            w.model().spec_string(),
        ]);
    }
    t.print();
    println!("run one with: bfdf run --workload <name>");
    println!("or compose a hybrid: bfdf run --spec 'att:fft2d,ffn:bpmm*x4;att:dense,ffn:bpmm*x2'");
    Ok(())
}

fn cmd_strategies(m: &Matches) -> Result<()> {
    if m.flag("json") {
        let items = Strategy::ALL
            .iter()
            .map(|st| obj(vec![("name", s(st.name())), ("description", s(st.describe()))]))
            .collect();
        let report = obj(vec![("report", s("strategies")), ("strategies", arr(items))]);
        println!("{}", report.render());
        return Ok(());
    }
    let mut t = Table::new("registered dataflow strategies", &["name", "description"]);
    for st in Strategy::ALL {
        t.row(&[st.name().to_string(), st.describe().to_string()]);
    }
    t.print();
    println!("pick one with: bfdf run|stream|autotune --strategy <name>");
    Ok(())
}

fn cmd_platforms(m: &Matches) -> Result<()> {
    let ours = ArchConfig::full();
    let rows = [
        platforms::jetson_nano(),
        platforms::sota_butterfly_accel(),
        platforms::jetson_xavier_nx(),
    ];
    if m.flag("json") {
        let mut items: Vec<Json> = rows
            .iter()
            .map(|p| {
                obj(vec![
                    ("platform", s(p.name)),
                    ("freq_hz", num(p.freq_hz)),
                    ("peak_flops", num(p.peak_flops)),
                    ("bandwidth", num(p.bandwidth)),
                    ("technology_nm", num(p.technology_nm as f64)),
                    ("power_w", num(p.power_w)),
                ])
            })
            .collect();
        items.push(obj(vec![
            ("platform", s("Multilayer Dataflow (ours)")),
            ("freq_hz", num(ours.freq_hz)),
            ("peak_flops", num(ours.peak_flops())),
            ("bandwidth", num(ours.ddr_bw())),
            ("technology_nm", num(12.0)),
            ("power_w", num(energy::array_power_w(&ours))),
        ]));
        let report = obj(vec![("report", s("platforms")), ("platforms", arr(items))]);
        println!("{}", report.render());
        return Ok(());
    }
    let mut t = Table::new(
        "Table I: platform comparison",
        &["platform", "freq", "peak fp16", "bandwidth", "tech", "power"],
    );
    for p in rows {
        t.row(&[
            p.name.to_string(),
            format!("{:.0} MHz", p.freq_hz / 1e6),
            format!("{}FLOPS", si(p.peak_flops)),
            format!("{}B/s", si(p.bandwidth)),
            format!("{} nm", p.technology_nm),
            format!("{:.2} W", p.power_w),
        ]);
    }
    t.row(&[
        "Multilayer Dataflow (ours)".into(),
        format!("{:.0} MHz", ours.freq_hz / 1e6),
        format!("{}FLOPS", si(ours.peak_flops())),
        format!("{}B/s", si(ours.ddr_bw())),
        "12 nm".into(),
        format!("{:.2} W", energy::array_power_w(&ours)),
    ]);
    t.print();
    Ok(())
}

fn cmd_energy_model(m: &Matches) -> Result<()> {
    let total = energy::pe_active_mw();
    if m.flag("json") {
        let units: Vec<Json> = energy::table3_rows()
            .iter()
            .map(|r| {
                obj(vec![
                    ("unit", s(r.name)),
                    ("area_mm2", num(r.area_mm2)),
                    ("active_mw", num(r.active_mw)),
                ])
            })
            .collect();
        let report = obj(vec![
            ("report", s("energy-model")),
            ("units", arr(units)),
            ("pe_active_mw", num(total)),
            ("array_power_w_full", num(energy::array_power_w(&ArchConfig::full()))),
            ("array_power_w_scaled128", num(energy::array_power_w(&ArchConfig::scaled_128()))),
        ]);
        println!("{}", report.render());
        return Ok(());
    }
    let mut t = Table::new(
        "Table III: synthesized area and power of PE unit",
        &["unit", "area mm^2", "active mW", "share"],
    );
    for r in energy::table3_rows() {
        t.row(&[
            r.name.to_string(),
            format!("{:.3}", r.area_mm2),
            format!("{:.2}", r.active_mw),
            format!("{:.2}%", 100.0 * r.active_mw / total),
        ]);
    }
    t.row(&[
        "Total (single PE)".into(),
        "0.985".into(),
        format!("{total:.2}"),
        "100%".into(),
    ]);
    t.print();
    println!(
        "array power: full {:.2} W, scaled128 {:.2} W",
        energy::array_power_w(&ArchConfig::full()),
        energy::array_power_w(&ArchConfig::scaled_128()),
    );
    Ok(())
}

fn cmd_validate(m: &Matches) -> Result<()> {
    let mut rt = Runtime::open(m.get("artifacts"))?;
    let names = rt.artifact_names();
    let json = m.flag("json");
    if !json {
        println!("PJRT platform: {}", rt.platform());
    }
    let mut t = Table::new(
        "artifact validation (PJRT vs python goldens)",
        &["artifact", "input", "output", "max |err|", "status"],
    );
    let mut items: Vec<Json> = Vec::new();
    let mut failed: Option<String> = None;
    let dir = rt.dir.clone();
    let shape_json = |shape: &[usize]| arr(shape.iter().map(|&d| num(d as f64)).collect());
    for name in names {
        let model = rt.load(&name)?;
        let err = model.validate_golden(&dir)?;
        let ok = err < 1e-3;
        if !ok && failed.is_none() {
            failed = Some(format!("artifact {name} exceeded tolerance: {err}"));
        }
        if json {
            items.push(obj(vec![
                ("artifact", s(&name)),
                ("input_shape", shape_json(&model.meta.input_shape)),
                ("output_shape", shape_json(&model.meta.output_shape)),
                ("max_rel_err", num(err as f64)),
                ("ok", Json::Bool(ok)),
            ]));
        } else {
            t.row(&[
                name.clone(),
                format!("{:?}", model.meta.input_shape),
                format!("{:?}", model.meta.output_shape),
                format!("{err:.2e}"),
                if ok { "OK" } else { "FAIL" }.to_string(),
            ]);
        }
    }
    if json {
        let report = obj(vec![("report", s("validate")), ("artifacts", arr(items))]);
        println!("{}", report.render());
    } else {
        t.print();
    }
    if let Some(msg) = failed {
        anyhow::bail!(msg);
    }
    Ok(())
}

fn cmd_stream(m: &Matches) -> Result<()> {
    let batch = m.get_usize("batch")?;
    anyhow::ensure!(
        batch > 0,
        "--batch 0 is invalid: batch must be >= 1 for the streamed Table-IV run"
    );
    let (overlap, arrays) = parse_pipeline(m)?;
    let suite = workloads::find_suite("vanilla")?;
    let session = Session::builder()
        .arch(parse_arch(m.get("arch"))?)
        .overlap(overlap)
        .arrays(arrays)
        .strategy(parse_strategy(m)?)
        .threads(parse_threads(m)?)
        .build();
    let r = session.stream(&suite.kernels_at(Some(batch)), batch)?;
    if m.flag("json") {
        let report = Report::Stream {
            arch: session.arch_signature().to_string(),
            workload: "vanilla".to_string(),
            strategy: session.strategy(),
            cache: session.cache_stats(),
            result: r,
        };
        println!("{}", report.render());
        return Ok(());
    }
    let mut t = Table::new(
        "Table IV (our side): 1-layer vanilla transformer, batch streamed",
        &["metric", "value"],
    );
    t.row(&["batch".into(), format!("{batch}")]);
    t.row(&["overlap".into(), format!("{} ({} arrays)", r.overlap.name(), r.arrays)]);
    t.row(&["serial time".into(), fmt_time(r.serial_time_s)]);
    t.row(&["batch time".into(), fmt_time(r.batch_time_s)]);
    t.row(&["speedup".into(), format!("{:.2}x", r.speedup())]);
    t.row(&["pipeline eff.".into(), format!("{:.1}%", 100.0 * r.pipeline_efficiency)]);
    t.row(&["latency".into(), format!("{:.2} ms", r.latency_ms)]);
    t.row(&["throughput".into(), format!("{:.1} pred/s", r.throughput)]);
    t.row(&["power".into(), format!("{:.2} W", r.power_w)]);
    t.row(&["energy eff.".into(), format!("{:.1} pred/J", r.energy_eff)]);
    t.print();
    if session.strategy() != Strategy::Paper {
        println!("strategy: {}", session.strategy().name());
    }
    print_auto_selections(&session);
    let cache = session.cache_stats();
    println!(
        "plan cache: {} lowerings for {} kernels ({} stage hits, {} plan hits)",
        cache.lowerings,
        r.kernels.len(),
        cache.stage_hits,
        cache.plan_hits
    );
    println!(
        "structural store: {} hits, {} misses",
        cache.structural_hits, cache.structural_misses
    );
    Ok(())
}

fn cmd_serve_sim(m: &Matches) -> Result<()> {
    let (overlap, arrays) = parse_pipeline(m)?;
    let max_batch = m.get_usize("max-batch")?;
    let max_wait_ms = m.get_f64("max-wait-ms")?;
    let queue_cap = m.get_usize("queue-cap")?;
    let seed: u64 = m
        .get("seed")
        .parse()
        .map_err(|_| anyhow::anyhow!("--seed expects an integer, got '{}'", m.get("seed")))?;
    let admission = Admission::parse(m.get("admission"))?;
    let deadline_s = match m.get("deadline-ms") {
        "" => None,
        raw => {
            let ms: f64 = raw.parse().map_err(|_| {
                anyhow::anyhow!("--deadline-ms expects a number, got '{raw}'")
            })?;
            Some(ms * 1e-3)
        }
    };
    let (fault_file, mtbf, mttr) = (m.get("faults"), m.get("mtbf"), m.get("mttr"));
    let faults = if !fault_file.is_empty() {
        anyhow::ensure!(
            mtbf.is_empty() && mttr.is_empty(),
            "--faults (a scripted trace) conflicts with --mtbf/--mttr (a seeded process); \
             pick one"
        );
        Some(ReplicaFaults::from_trace_file(fault_file)?)
    } else if !mtbf.is_empty() || !mttr.is_empty() {
        anyhow::ensure!(
            !mtbf.is_empty() && !mttr.is_empty(),
            "--mtbf and --mttr must be given together"
        );
        let mtbf_s: f64 = mtbf
            .parse()
            .map_err(|_| anyhow::anyhow!("--mtbf expects seconds, got '{mtbf}'"))?;
        let mttr_s: f64 = mttr
            .parse()
            .map_err(|_| anyhow::anyhow!("--mttr expects seconds, got '{mttr}'"))?;
        let fault_seed: u64 = m.get("fault-seed").parse().map_err(|_| {
            anyhow::anyhow!("--fault-seed expects an integer, got '{}'", m.get("fault-seed"))
        })?;
        Some(ReplicaFaults::Process { mtbf_s, mttr_s, seed: fault_seed })
    } else {
        None
    };
    let max_retries = m.get("retries").parse().map_err(|_| {
        anyhow::anyhow!("--retries expects an integer, got '{}'", m.get("retries"))
    })?;
    let cfg = ServeConfig {
        max_batch,
        max_wait_s: max_wait_ms * 1e-3,
        arrays,
        queue_cap,
        overlap,
        admission,
        deadline_s,
        faults,
        max_retries,
        ..ServeConfig::default()
    };
    let session = Session::builder().arch(parse_arch(m.get("arch"))?).build();
    let trace = m.get("trace");
    let mut points = Vec::new();
    if !trace.is_empty() {
        let traffic = Traffic::from_trace_file(trace)?;
        points.push(session.serve(&traffic, &cfg)?);
    } else {
        // Whitespace-separated, NOT comma-separated: spec strings use
        // commas internally ('att:fft2d,ffn:bpmm*x2' is one class).
        let keys: Vec<String> =
            m.get("workloads").split_whitespace().map(str::to_string).collect();
        anyhow::ensure!(!keys.is_empty(), "--workloads needs at least one class");
        let duration = m.get_f64("duration")?;
        for raw in m.get("rate").split(',') {
            let rate: f64 = raw
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--rate expects numbers, got '{raw}'"))?;
            let traffic = Traffic::poisson(&keys, rate, duration, seed)?;
            points.push(session.serve(&traffic, &cfg)?);
        }
    }
    let report = Report::Serving {
        arch: session.arch_signature().to_string(),
        cache: session.cache_stats(),
        points,
    };
    let out = m.get("out");
    if !out.is_empty() {
        std::fs::write(out, report.render() + "\n")
            .map_err(|e| anyhow::anyhow!("cannot write report to '{out}': {e}"))?;
    }
    if m.flag("json") {
        println!("{}", report.render());
        return Ok(());
    }
    if let Report::Serving { cache, points, .. } = &report {
        print_serving(points, cache);
    }
    Ok(())
}

/// Text tables for a serving run: the load/latency curve plus the
/// per-class breakdown of the heaviest point.
fn print_serving(points: &[ServeResult], cache: &butterfly_dataflow::coordinator::CacheStats) {
    // The fault-tolerance columns only appear when some point actually
    // configured faults, deadlines, or a non-FIFO policy — plain runs
    // keep the familiar narrow table.
    let robust = points.iter().any(|p| p.robustness_on());
    let mut head = vec![
        "rate r/s", "offered", "rej", "goodput r/s", "capacity r/s", "p50 ms", "p95 ms",
        "p99 ms", "util", "batch",
    ];
    if robust {
        head.extend(["timeout", "shed", "lost", "avail"]);
    }
    let mut t = Table::new("serve-sim load/latency curve", &head);
    for p in points {
        let mut row = vec![
            format!("{:.1}", p.offered_rate_rps),
            format!("{}", p.offered),
            format!("{}", p.rejected),
            format!("{:.1}", p.goodput_rps),
            format!("{:.1}", p.capacity_rps),
            format!("{:.3}", p.latency_p50_ms),
            format!("{:.3}", p.latency_p95_ms),
            format!("{:.3}", p.latency_p99_ms),
            format!("{:.1}%", 100.0 * p.utilization),
            format!("{:.2}", p.mean_batch),
        ];
        if robust {
            row.push(format!("{}", p.timed_out));
            row.push(format!("{}", p.shed));
            row.push(format!("{}", p.lost));
            row.push(format!("{:.1}%", 100.0 * p.availability));
        }
        t.row(&row);
    }
    t.print();
    if let Some(last) = points.last() {
        let mut title = format!(
            "per-class breakdown at {:.1} req/s ({} arrays, max batch {}, max wait {:.1} ms",
            last.offered_rate_rps,
            last.arrays,
            last.max_batch,
            last.max_wait_s * 1e3
        );
        if robust {
            title.push_str(&format!(", {} admission", last.admission.name()));
            if let Some(dl) = last.deadline_s {
                title.push_str(&format!(", deadline {:.1} ms", dl * 1e3));
            }
        }
        title.push(')');
        let mut head = vec!["class", "spec", "offered", "rej", "done"];
        if robust {
            head.extend(["timeout", "shed", "lost"]);
        }
        head.extend(["p50 ms", "p99 ms"]);
        let mut t = Table::new(&title, &head);
        for c in &last.classes {
            let mut row = vec![
                c.name.clone(),
                c.spec.clone(),
                format!("{}", c.offered),
                format!("{}", c.rejected),
                format!("{}", c.completed),
            ];
            if robust {
                row.push(format!("{}", c.timed_out));
                row.push(format!("{}", c.shed));
                row.push(format!("{}", c.lost));
            }
            row.push(format!("{:.3}", c.latency_p50_ms));
            row.push(format!("{:.3}", c.latency_p99_ms));
            t.row(&row);
        }
        t.print();
        if robust && last.faults_configured {
            println!(
                "replica availability {:.2}% -> degraded capacity bound {:.1} req/s \
                 (healthy {:.1}); {} retries",
                100.0 * last.availability,
                last.degraded_capacity_rps,
                last.capacity_rps,
                last.retries
            );
        }
    }
    println!(
        "plan cache (shared across all classes and batch sizes): {} lowerings, \
         {} stage hits, {} plan hits",
        cache.lowerings, cache.stage_hits, cache.plan_hits
    );
}

fn cmd_autotune(m: &Matches) -> Result<()> {
    let mut space = SearchSpace::parse(m.get("space"))?;
    let strategy = parse_strategy(m)?;
    if space.strategy.is_empty() {
        // --strategy pins every point when the space does not sweep the
        // axis itself (the default 'paper' keeps prior grids intact).
        space.strategy = vec![strategy];
    } else {
        anyhow::ensure!(
            strategy == Strategy::Paper,
            "--strategy conflicts with a 'strategy=' axis in --space; pick one"
        );
    }
    let base = parse_arch(m.get("arch"))?;
    // Whitespace-separated, NOT comma-separated: spec strings use
    // commas internally ('att:fft2d,ffn:bpmm*x2' is one class).
    let keys: Vec<String> = match m.get("suites") {
        "all" => workloads::suite_names().iter().map(|s| s.to_string()).collect(),
        list => list.split_whitespace().map(str::to_string).collect(),
    };
    anyhow::ensure!(!keys.is_empty(), "--suites needs at least one workload class");
    let batch = parse_batch(m)?;
    let classes = WorkloadClass::resolve(&keys, batch)?;
    let store_path = m.get("store");
    let store = if store_path.is_empty() {
        std::sync::Arc::new(StructuralStore::new())
    } else {
        std::sync::Arc::new(StructuralStore::open(store_path, m.flag("resume"))?)
    };
    let cfg = AutotuneConfig {
        objective: Objective::parse(m.get("objective"))?,
        overlap: Overlap::parse(m.get("overlap"))?,
        window: m.get_usize("window")?,
        batch,
        prune: !m.flag("no-prune"),
        store,
        threads: parse_threads(m)?,
    };
    let journal_path = m.get("journal");
    let journal = if journal_path.is_empty() {
        anyhow::ensure!(!m.flag("resume"), "--resume needs --journal to replay from");
        Journal::in_memory()
    } else {
        Journal::open(journal_path, m.flag("resume"))?
    };
    let result = autotune::sweep(&space, &base, &classes, &cfg, &journal)?;
    let report = Report::Pareto { result };
    let out = m.get("out");
    if !out.is_empty() {
        std::fs::write(out, report.render() + "\n")
            .map_err(|e| anyhow::anyhow!("cannot write report to '{out}': {e}"))?;
    }
    if m.flag("json") {
        println!("{}", report.render());
        return Ok(());
    }
    if let Report::Pareto { result } = &report {
        print_pareto(result);
    }
    Ok(())
}

/// Text tables for an autotune sweep: one Pareto-frontier table per
/// workload class, where the paper's default design point lands, and
/// the prune/journal/plan-cache accounting.
fn print_pareto(r: &AutotuneResult) {
    println!(
        "autotune: {} points x {} classes (base {}, objective {})",
        r.points.len(),
        r.classes.len(),
        r.base_arch,
        r.objective.name()
    );
    println!("space: {}", r.space);
    for c in &r.classes {
        let mut t = Table::new(
            &format!("{} (batch {}): Pareto frontier", c.name, c.batch),
            &[
                "point", "mesh", "simd", "spm KiB", "ports", "ddr", "arrays", "latency",
                "energy J", "area mm2", "pred/J", "best",
            ],
        );
        for &fi in &c.frontier {
            let e = &c.evals[fi];
            let p = &r.points[e.point];
            t.row(&[
                p.id.clone(),
                format!("{}x{}", p.arch.mesh_rows, p.arch.mesh_cols),
                format!("{}", p.arch.simd_width),
                format!("{}", p.arch.spm_bytes / 1024),
                format!("{}", p.arch.spm_banks),
                format!("{}", p.arch.ddr_channels),
                format!("{}", p.arrays),
                fmt_time(e.metrics.latency_s),
                format!("{:.3}", e.metrics.energy_j),
                format!("{:.1}", e.metrics.area_mm2),
                format!("{:.1}", e.metrics.efficiency),
                if fi == c.best_eval { r.objective.name().into() } else { String::new() },
            ]);
        }
        t.print();
        let d = &c.evals[c.default_eval];
        let place = if c.default_on_frontier() {
            "on the frontier".to_string()
        } else {
            let b = &c.evals[c.best_eval];
            format!(
                "dominated ({:.2}x latency, {:.2}x energy of the {} best)",
                d.metrics.latency_s / b.metrics.latency_s,
                d.metrics.energy_j / b.metrics.energy_j,
                r.objective.name()
            )
        };
        println!(
            "default design {}: {} -- pruned {} shard + {} roofline of {} points",
            r.points[d.point].id,
            place,
            c.pruned_shard,
            c.pruned_roofline,
            r.points.len()
        );
    }
    println!(
        "sweep: {} of {} evaluations run ({} shard-pruned, {} roofline-pruned, \
         {} journal hits); plan cache: {} lowerings, {} stage hits, {} plan hits",
        r.evaluated,
        r.units_total(),
        r.pruned_shard,
        r.pruned_roofline,
        r.journal_hits,
        r.cache.lowerings,
        r.cache.stage_hits,
        r.cache.plan_hits
    );
    println!(
        "structural store: {} hits, {} misses",
        r.cache.structural_hits, r.cache.structural_misses
    );
}

fn cmd_gpu_model(m: &Matches) -> Result<()> {
    let kind = parse_kind(m.get("kind"))?;
    let points = m.get_usize("points")?;
    let vectors = m.get_usize("vectors")?;
    let platform = match m.get("platform") {
        "nx" => platforms::jetson_xavier_nx(),
        "nano" => platforms::jetson_nano(),
        other => anyhow::bail!("unknown platform '{other}'"),
    };
    let gpu = butterfly_dataflow::baselines::gpu::GpuModel::new(platform);
    let spec = point_spec(kind, points, vectors);
    let r = gpu.butterfly(&spec);
    if m.flag("json") {
        let report = obj(vec![
            ("report", s("gpu-model")),
            ("name", s(&r.name)),
            ("time_s", num(r.time_s)),
            ("l1_hit", num(r.l1_hit)),
            ("l2_hit", num(r.l2_hit)),
            ("l1_requirement", num(r.l1_req)),
            ("l2_requirement", num(r.l2_req)),
            ("dram_bytes", num(r.dram_bytes)),
        ]);
        println!("{}", report.render());
        return Ok(());
    }
    let mut t = Table::new(&format!("GPU model: {}", r.name), &["metric", "value"]);
    t.row(&["time".into(), fmt_time(r.time_s)]);
    t.row(&["L1 hit".into(), format!("{:.1}%", 100.0 * r.l1_hit)]);
    t.row(&["L2 hit".into(), format!("{:.1}%", 100.0 * r.l2_hit)]);
    t.row(&["L1 requirement".into(), format!("{:.1}%", 100.0 * r.l1_req)]);
    t.row(&["L2 requirement".into(), format!("{:.1}%", 100.0 * r.l2_req)]);
    t.row(&["DRAM traffic".into(), format!("{}B", si(r.dram_bytes))]);
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    //! Pinned error messages for every class of bad user input the CLI
    //! can see: argv-level (unknown command/option), value-level (a
    //! word where a number belongs), domain-level (an unknown preset or
    //! policy), and file-level (an unreadable path).  Each test drives
    //! the real `run()` entry point, so a refactor that turns one of
    //! these structured errors back into a panic or an unhelpful
    //! message fails here, not in a user's terminal.

    use super::run;

    fn err_of(argv: &[&str]) -> String {
        let args: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
        format!("{:#}", run(&args).expect_err("argv must be rejected"))
    }

    #[test]
    fn unknown_command_and_option_are_structured_errors() {
        assert!(err_of(&["frobnicate"]).contains("unknown command 'frobnicate'"));
        let e = err_of(&["simulate", "--wat", "1"]);
        assert!(e.contains("unknown option --wat"), "{e}");
        let e = err_of(&["simulate", "--points"]);
        assert!(e.contains("option --points needs a value"), "{e}");
    }

    #[test]
    fn malformed_values_name_the_option_and_the_input() {
        let e = err_of(&["simulate", "--points", "abc"]);
        assert!(e.contains("--points expects an integer, got 'abc'"), "{e}");
        let e = err_of(&["serve-sim", "--seed", "1.5"]);
        assert!(e.contains("--seed expects an integer, got '1.5'"), "{e}");
        let e = err_of(&["serve-sim", "--deadline-ms", "soon"]);
        assert!(e.contains("--deadline-ms expects a number, got 'soon'"), "{e}");
        let e = err_of(&["serve-sim", "--retries", "many"]);
        assert!(e.contains("--retries expects an integer, got 'many'"), "{e}");
        let e = err_of(&["serve-sim", "--mtbf", "often", "--mttr", "0.1"]);
        assert!(e.contains("--mtbf expects seconds, got 'often'"), "{e}");
    }

    #[test]
    fn unknown_domain_values_list_the_choices() {
        let e = err_of(&["simulate", "--kind", "warp"]);
        assert!(e.contains("unknown kernel kind 'warp' (fft | bpmm)"), "{e}");
        let e = err_of(&["stream", "--arch", "weird"]);
        assert!(e.contains("unknown arch preset 'weird' (full | scaled128)"), "{e}");
        let e = err_of(&["serve-sim", "--admission", "lifo"]);
        assert!(e.contains("unknown admission policy 'lifo'"), "{e}");
        assert!(e.contains("fifo, slo-aware"), "{e}");
    }

    #[test]
    fn fault_knob_conflicts_are_reported_before_any_work() {
        let e = err_of(&["serve-sim", "--faults", "x.json", "--mtbf", "0.1", "--mttr", "0.01"]);
        assert!(e.contains("--faults") && e.contains("conflicts"), "{e}");
        let e = err_of(&["serve-sim", "--mtbf", "0.1"]);
        assert!(e.contains("--mtbf and --mttr must be given together"), "{e}");
        let e = err_of(&["serve-sim", "--mttr", "0.1"]);
        assert!(e.contains("--mtbf and --mttr must be given together"), "{e}");
    }

    #[test]
    fn unreadable_files_name_the_path() {
        let e = err_of(&["serve-sim", "--trace", "/nonexistent/bfdf-trace.json"]);
        assert!(e.contains("cannot read trace file '/nonexistent/bfdf-trace.json'"), "{e}");
        let e = err_of(&["serve-sim", "--faults", "/nonexistent/bfdf-faults.json"]);
        assert!(
            e.contains("cannot read fault trace file '/nonexistent/bfdf-faults.json'"),
            "{e}"
        );
    }
}
