//! `bfdf` — the butterfly-dataflow command-line launcher.
//!
//! Subcommands cover interactive use of every layer: simulating kernels,
//! sweeping divisions, printing the platform/energy tables, validating
//! the AOT artifacts through PJRT, and streaming the Table-IV workload.

use anyhow::Result;

use butterfly_dataflow::arch::{ArchConfig, UnitKind};
use butterfly_dataflow::coordinator::{
    run_kernel_with, stream_workload, ExperimentConfig,
};
use butterfly_dataflow::dfg::graph::KernelKind;
use butterfly_dataflow::dfg::stages::enumerate_divisions;
use butterfly_dataflow::energy;
use butterfly_dataflow::runtime::Runtime;
use butterfly_dataflow::util::cli::{App, Command};
use butterfly_dataflow::util::stats::{fmt_time, si};
use butterfly_dataflow::util::table::Table;
use butterfly_dataflow::workloads::{self, platforms, KernelSpec};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn app() -> App {
    App::new("bfdf", "multilayer dataflow orchestration for butterfly sparsity")
        .command(
            Command::new("simulate", "simulate one butterfly kernel on the dataflow array")
                .opt("kind", "fft", "kernel kind: fft | bpmm")
                .opt("points", "256", "transform length (power of two)")
                .opt("vectors", "8192", "independent vectors (batch x rows)")
                .opt("window", "48", "simulation window (DFG iterations)")
                .opt("division", "auto", "stage division RxC, e.g. 64x32, or 'auto'")
                .opt("arch", "full", "architecture preset: full | scaled128")
                .flag("no-multiline-spm", "ablation: single-line SPM")
                .flag("fifo", "ablation: FIFO block scheduling"),
        )
        .command(
            Command::new("sweep-divisions", "Fig. 14 sweep: CalUnit utilization per division")
                .opt("kind", "bpmm", "kernel kind: fft | bpmm")
                .opt("points", "4096", "transform length")
                .opt("vectors", "8192", "independent vectors"),
        )
        .command(Command::new("platforms", "print the Table I platform comparison"))
        .command(Command::new("energy-model", "print the Table III power/area model"))
        .command(
            Command::new("validate", "run every AOT artifact through PJRT against goldens")
                .opt("artifacts", "artifacts", "artifact directory"),
        )
        .command(
            Command::new("stream", "Table IV end-to-end vanilla-transformer streaming")
                .opt("batch", "256", "streamed batch size")
                .opt("arch", "scaled128", "architecture preset: full | scaled128"),
        )
        .command(
            Command::new("gpu-model", "run the Jetson GPU baseline on a butterfly kernel")
                .opt("kind", "fft", "kernel kind: fft | bpmm")
                .opt("points", "1024", "transform length")
                .opt("vectors", "8192", "independent vectors")
                .opt("platform", "nx", "gpu platform: nx | nano"),
        )
}

fn parse_kind(s: &str) -> Result<KernelKind> {
    match s {
        "fft" => Ok(KernelKind::Fft),
        "bpmm" => Ok(KernelKind::Bpmm),
        other => anyhow::bail!("unknown kernel kind '{other}' (fft | bpmm)"),
    }
}

fn parse_arch(s: &str) -> Result<ArchConfig> {
    match s {
        "full" => Ok(ArchConfig::full()),
        "scaled128" => Ok(ArchConfig::scaled_128()),
        other => anyhow::bail!("unknown arch preset '{other}' (full | scaled128)"),
    }
}

fn parse_division(s: &str) -> Result<Option<(usize, usize)>> {
    if s == "auto" {
        return Ok(None);
    }
    let (r, c) = s
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("division must be RxC, e.g. 64x32"))?;
    Ok(Some((r.parse()?, c.parse()?)))
}

fn run(args: &[String]) -> Result<()> {
    let app = app();
    let (cmd, m) = app.parse(args)?;
    match cmd.as_str() {
        "simulate" => {
            let kind = parse_kind(m.get("kind"))?;
            let points = m.get_usize("points")?;
            let vectors = m.get_usize("vectors")?;
            let spec = KernelSpec {
                name: format!("{}-{}", kind.name(), points),
                kind,
                points,
                vectors,
                d_in: points,
                d_out: points,
                seq: points,
            };
            let cfg = ExperimentConfig {
                arch: parse_arch(m.get("arch"))?,
                window: m.get_usize("window")?,
                sim: butterfly_dataflow::sim::SimOptions {
                    no_multiline_spm: m.flag("no-multiline-spm"),
                    fifo_scheduling: m.flag("fifo"),
                },
            };
            let r = run_kernel_with(&spec, &cfg, parse_division(m.get("division"))?)?;
            let mut t = Table::new(
                &format!("simulate {} ({} vectors)", r.name, vectors),
                &["metric", "value"],
            );
            t.row(&["cycles".into(), format!("{:.0}", r.cycles)]);
            t.row(&["time".into(), fmt_time(r.time_s)]);
            t.row(&["stages".into(), format!("{:?}",
                r.plan.stages.iter().map(|s| s.points).collect::<Vec<_>>())]);
            for k in UnitKind::ALL {
                t.row(&[format!("util.{}", k.name()), format!("{:.1}%", 100.0 * r.util_of(k))]);
            }
            t.row(&["spm requirement".into(), format!("{:.2}%", 100.0 * r.spm_requirement)]);
            t.row(&["flops".into(), si(r.flops)]);
            t.row(&["flops efficiency".into(), format!("{:.1}%", 100.0 * r.flops_efficiency)]);
            t.row(&["power".into(), format!("{:.2} W", r.power_w)]);
            t.row(&["energy".into(), format!("{:.4} J", r.energy_j)]);
            t.row(&["ddr traffic".into(), format!("{}B", si(r.dma_bytes))]);
            t.print();
        }
        "sweep-divisions" => {
            let kind = parse_kind(m.get("kind"))?;
            let points = m.get_usize("points")?;
            let vectors = m.get_usize("vectors")?;
            let cfg = ExperimentConfig::default();
            let cap = match kind {
                KernelKind::Fft => cfg.arch.max_fft_points,
                KernelKind::Bpmm => cfg.arch.max_bpmm_points,
            };
            let mut t = Table::new(
                &format!("Fig.14 division sweep: {} {}", kind.name(), points),
                &["division", "cycles", "cal util", "load util", "flow util"],
            );
            for (r, c) in enumerate_divisions(points, 16, cap) {
                let spec = KernelSpec {
                    name: format!("{}-{points}-{r}x{c}", kind.name()),
                    kind,
                    points,
                    vectors,
                    d_in: points,
                    d_out: points,
                    seq: points,
                };
                let res = run_kernel_with(&spec, &cfg, Some((r, c)))?;
                t.row(&[
                    format!("{r}x{c}"),
                    format!("{:.0}", res.cycles),
                    format!("{:.2}%", 100.0 * res.util_of(UnitKind::Cal)),
                    format!("{:.2}%", 100.0 * res.util_of(UnitKind::Load)),
                    format!("{:.2}%", 100.0 * res.util_of(UnitKind::Flow)),
                ]);
            }
            t.print();
        }
        "platforms" => {
            let mut t = Table::new(
                "Table I: platform comparison",
                &["platform", "freq", "peak fp16", "bandwidth", "tech", "power"],
            );
            let ours = ArchConfig::full();
            for p in [
                platforms::jetson_nano(),
                platforms::sota_butterfly_accel(),
                platforms::jetson_xavier_nx(),
            ] {
                t.row(&[
                    p.name.to_string(),
                    format!("{:.0} MHz", p.freq_hz / 1e6),
                    format!("{}FLOPS", si(p.peak_flops)),
                    format!("{}B/s", si(p.bandwidth)),
                    format!("{} nm", p.technology_nm),
                    format!("{:.2} W", p.power_w),
                ]);
            }
            t.row(&[
                "Multilayer Dataflow (ours)".into(),
                format!("{:.0} MHz", ours.freq_hz / 1e6),
                format!("{}FLOPS", si(ours.peak_flops())),
                format!("{}B/s", si(ours.ddr_bw())),
                "12 nm".into(),
                format!("{:.2} W", energy::array_power_w(&ours)),
            ]);
            t.print();
        }
        "energy-model" => {
            let mut t = Table::new(
                "Table III: synthesized area and power of PE unit",
                &["unit", "area mm^2", "active mW", "share"],
            );
            let total = energy::pe_active_mw();
            for r in energy::table3_rows() {
                t.row(&[
                    r.name.to_string(),
                    format!("{:.3}", r.area_mm2),
                    format!("{:.2}", r.active_mw),
                    format!("{:.2}%", 100.0 * r.active_mw / total),
                ]);
            }
            t.row(&[
                "Total (single PE)".into(),
                "0.985".into(),
                format!("{total:.2}"),
                "100%".into(),
            ]);
            t.print();
            println!(
                "array power: full {:.2} W, scaled128 {:.2} W",
                energy::array_power_w(&ArchConfig::full()),
                energy::array_power_w(&ArchConfig::scaled_128()),
            );
        }
        "validate" => {
            let mut rt = Runtime::open(m.get("artifacts"))?;
            println!("PJRT platform: {}", rt.platform());
            let names = rt.artifact_names();
            let mut t = Table::new(
                "artifact validation (PJRT vs python goldens)",
                &["artifact", "input", "output", "max |err|", "status"],
            );
            let dir = rt.dir.clone();
            for name in names {
                let model = rt.load(&name)?;
                let err = model.validate_golden(&dir)?;
                let ok = err < 1e-3;
                t.row(&[
                    name.clone(),
                    format!("{:?}", model.meta.input_shape),
                    format!("{:?}", model.meta.output_shape),
                    format!("{err:.2e}"),
                    if ok { "OK" } else { "FAIL" }.to_string(),
                ]);
                anyhow::ensure!(ok, "artifact {name} exceeded tolerance: {err}");
            }
            t.print();
        }
        "stream" => {
            let batch = m.get_usize("batch")?;
            let cfg = ExperimentConfig {
                arch: parse_arch(m.get("arch"))?,
                ..Default::default()
            };
            let r = stream_workload(&workloads::vanilla_kernels(batch), batch, &cfg)?;
            let mut t = Table::new(
                "Table IV (our side): 1-layer vanilla transformer, batch streamed",
                &["metric", "value"],
            );
            t.row(&["batch".into(), format!("{batch}")]);
            t.row(&["batch time".into(), fmt_time(r.batch_time_s)]);
            t.row(&["latency".into(), format!("{:.2} ms", r.latency_ms)]);
            t.row(&["throughput".into(), format!("{:.1} pred/s", r.throughput)]);
            t.row(&["power".into(), format!("{:.2} W", r.power_w)]);
            t.row(&["energy eff.".into(), format!("{:.1} pred/J", r.energy_eff)]);
            t.print();
        }
        "gpu-model" => {
            let kind = parse_kind(m.get("kind"))?;
            let points = m.get_usize("points")?;
            let vectors = m.get_usize("vectors")?;
            let platform = match m.get("platform") {
                "nx" => platforms::jetson_xavier_nx(),
                "nano" => platforms::jetson_nano(),
                other => anyhow::bail!("unknown platform '{other}'"),
            };
            let gpu = butterfly_dataflow::baselines::gpu::GpuModel::new(platform);
            let spec = KernelSpec {
                name: format!("{}-{}", kind.name(), points),
                kind,
                points,
                vectors,
                d_in: points,
                d_out: points,
                seq: points,
            };
            let r = gpu.butterfly(&spec);
            let mut t = Table::new(&format!("GPU model: {}", r.name), &["metric", "value"]);
            t.row(&["time".into(), fmt_time(r.time_s)]);
            t.row(&["L1 hit".into(), format!("{:.1}%", 100.0 * r.l1_hit)]);
            t.row(&["L2 hit".into(), format!("{:.1}%", 100.0 * r.l2_hit)]);
            t.row(&["L1 requirement".into(), format!("{:.1}%", 100.0 * r.l1_req)]);
            t.row(&["L2 requirement".into(), format!("{:.1}%", 100.0 * r.l2_req)]);
            t.row(&["DRAM traffic".into(), format!("{}B", si(r.dram_bytes))]);
            t.print();
        }
        other => anyhow::bail!("unhandled command {other}"),
    }
    Ok(())
}
