//! Analytical models of the accelerator baselines.
//!
//! The SOTA butterfly accelerator [8] (FABNet's FPGA co-design) is
//! modelled structurally: a *single-concatenation* butterfly pipeline —
//! one fixed chain of butterfly stages with stage-serial execution and
//! per-stage weight streaming, without the reconfigurable multilayer
//! data reuse our design gets from the mesh.  The paper attributes its
//! own 1.17×/1.44-1.59× advantage exactly to that difference (§VI-H),
//! so the model charges [8]:
//!
//! * MAC-array efficiency bounded by its published utilization profile
//!   (pipeline fill/drain per stage chain, stage-serial barriers);
//! * inter-stage intermediate traffic to on-chip buffers, with DDR
//!   re-streaming once the working set exceeds its BRAM budget.
//!
//! SpAtten and DOTA end-to-end numbers are *quoted* published values
//! (the paper quotes them too); see `workloads::platforms`.

use crate::dfg::graph::KernelKind;
use crate::workloads::platforms::Platform;
use crate::workloads::KernelSpec;

/// FPGA BRAM budget of the SOTA accelerator (Zynq-class part).
const SOTA_BRAM_BYTES: f64 = 2.0 * 1024.0 * 1024.0;
/// MAC efficiency of the fixed butterfly pipeline when streaming.
const SOTA_STREAM_EFF: f64 = 0.80;
/// Extra stage-serial overhead per butterfly stage (pipeline fill/drain
/// and buffer turnaround), as a fraction of the stage's compute time.
const SOTA_STAGE_OVERHEAD: f64 = 0.08;

/// Result of one modelled accelerator kernel.
#[derive(Debug, Clone)]
pub struct AccelKernelResult {
    pub name: String,
    pub time_s: f64,
    pub flops: f64,
    pub dram_bytes: f64,
    pub mac_utilization: f64,
}

/// The SOTA butterfly accelerator [8].
#[derive(Debug, Clone)]
pub struct SotaButterflyModel {
    pub platform: Platform,
}

impl SotaButterflyModel {
    pub fn new(platform: Platform) -> Self {
        SotaButterflyModel { platform }
    }

    /// Run one butterfly kernel through the fixed pipeline.
    pub fn run(&self, spec: &KernelSpec) -> AccelKernelResult {
        let n = spec.points as f64;
        let stages = n.log2();
        let flops = spec.sparse_flops();
        let compute = flops / (self.platform.peak_flops * SOTA_STREAM_EFF);
        // Stage-serial execution: each stage pays fill/drain overhead.
        let compute = compute * (1.0 + SOTA_STAGE_OVERHEAD * stages / 8.0);
        // Traffic: input + output once, intermediates spill to DDR when
        // the per-stage working set exceeds BRAM; FFT doubles planes.
        let planes = spec.kind.planes() as f64;
        let vec_bytes = n * 2.0 * planes;
        let ws = vec_bytes * (spec.vectors.min(64)) as f64
            + weight_bytes(spec.kind, spec.points);
        let io_bytes = spec.vectors as f64 * vec_bytes * 2.0;
        let spill = if ws > SOTA_BRAM_BYTES {
            // Re-stream intermediates per stage chain half.
            spec.vectors as f64 * vec_bytes * (stages / 8.0)
        } else {
            0.0
        };
        let weights = weight_bytes(spec.kind, spec.points)
            * (spec.vectors as f64 / 256.0).max(1.0) // weight re-fetch per tile
            ;
        let dram_bytes = io_bytes + spill + weights;
        let mem = dram_bytes / self.platform.bandwidth;
        let time = compute.max(mem);
        AccelKernelResult {
            name: spec.name.clone(),
            time_s: time,
            flops,
            dram_bytes,
            mac_utilization: (flops / self.platform.peak_flops) / time,
        }
    }
}

/// Butterfly weight bytes for an n-point kernel (fp16).
fn weight_bytes(kind: KernelKind, n: usize) -> f64 {
    let stages = (n as f64).log2();
    (n as f64 / 2.0) * stages * kind.weight_scalars_per_node() as f64 * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::platforms::sota_butterfly_accel;

    fn spec(kind: KernelKind, points: usize, vectors: usize) -> KernelSpec {
        KernelSpec {
            name: "t".into(),
            kind,
            points,
            vectors,
            d_in: points,
            d_out: points,
            seq: points,
        }
    }

    #[test]
    fn utilization_is_bounded() {
        let m = SotaButterflyModel::new(sota_butterfly_accel());
        for n in [128usize, 256, 1024] {
            let r = m.run(&spec(KernelKind::Fft, n, 1024));
            assert!(r.mac_utilization > 0.0 && r.mac_utilization <= SOTA_STREAM_EFF + 0.01);
        }
    }

    #[test]
    fn large_working_sets_spill() {
        // Past the BRAM budget the accelerator re-streams intermediates:
        // DRAM traffic exceeds pure I/O; below it, traffic ≈ I/O.
        let m = SotaButterflyModel::new(sota_butterfly_accel());
        let io = |n: usize, v: usize| (n * 2 * 2 * v * 2) as f64;
        let small = m.run(&spec(KernelKind::Fft, 128, 1024));
        assert!(small.dram_bytes < 1.2 * io(128, 1024), "{}", small.dram_bytes);
        let large = m.run(&spec(KernelKind::Fft, 16384, 1024));
        assert!(large.dram_bytes > 1.5 * io(16384, 1024), "{}", large.dram_bytes);
    }

    #[test]
    fn time_scales_superlinearly_past_bram() {
        let m = SotaButterflyModel::new(sota_butterfly_accel());
        let a = m.run(&spec(KernelKind::Bpmm, 512, 4096));
        let b = m.run(&spec(KernelKind::Bpmm, 8192, 4096));
        // 16x points → >16x time once spilling (flops grow ~21x here).
        assert!(b.time_s / a.time_s > 16.0);
    }
}
