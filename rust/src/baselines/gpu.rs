//! Analytical GPU execution model (Jetson Xavier NX / Nano).
//!
//! The paper's Fig. 2 profiling shows *why* butterfly sparsity
//! disappoints on GPUs: the per-stage strides 1, 2, 4, …, n/2 destroy
//! spatial locality once the stride crosses a cache line, and destroy
//! temporal locality once the strided working set overflows a level.
//! This model reproduces that mechanism:
//!
//! * execution time is a roofline over {compute, L2, DRAM} with a level
//!   traffic model: every access is served by L1; L1 misses flow to L2;
//!   L2 misses to DRAM;
//! * per-stage L1/L2 miss rates follow the stride/working-set rule
//!   below, averaged over the `log2 n` stages of a butterfly kernel;
//! * dense kernels (the `dense-*` rows of Fig. 15) get textbook tiled
//!   matmul locality and tensor-core throughput.
//!
//! Constants (cache bandwidths, efficiencies) are documented point
//! estimates for the Volta/Maxwell iGPUs; the figures depend on the
//! *relative* behaviour across kernels and scales, which the mechanism
//! reproduces rather than the constants.

use crate::workloads::platforms::Platform;
use crate::workloads::KernelSpec;

/// Cache-line size (bytes) on both Jetson platforms.
const LINE_BYTES: usize = 128;
/// fp16 element size used by all kernels.
const ELEM_BYTES: usize = 2;
/// Fraction of peak a well-tiled dense GEMM reaches on tensor cores.
const DENSE_TENSOR_EFF: f64 = 0.55;
/// Fraction of peak dense GEMM reaches on CUDA cores.
const DENSE_CUDA_EFF: f64 = 0.45;
/// Fraction of peak a strided butterfly kernel reaches on CUDA cores
/// when compute-bound (cuFFT-style shared-memory stages).
const BUTTERFLY_CUDA_EFF: f64 = 0.35;
/// Concurrent batch rows resident per SM batch tile (occupancy model).
const CONCURRENT_ROWS: usize = 128;

/// Result of one modelled GPU kernel execution.
#[derive(Debug, Clone)]
pub struct GpuKernelResult {
    pub name: String,
    pub time_s: f64,
    /// Hit rates (Fig. 2 bars).
    pub l1_hit: f64,
    pub l2_hit: f64,
    /// Accessing-requirement percentages: level traffic over level peak
    /// bandwidth for the kernel duration (Fig. 2 / Fig. 12 metric).
    pub l1_req: f64,
    pub l2_req: f64,
    /// DRAM bytes moved.
    pub dram_bytes: f64,
    /// FLOPs executed.
    pub flops: f64,
}

/// GPU model around a [`Platform`].
#[derive(Debug, Clone)]
pub struct GpuModel {
    pub platform: Platform,
    /// Aggregate L1 bandwidth (bytes/s).
    pub l1_bw: f64,
    /// L2 bandwidth (bytes/s).
    pub l2_bw: f64,
    /// Arch multiplier on butterfly issue efficiency (Maxwell penalty).
    pub butterfly_arch_eff: f64,
}

impl GpuModel {
    pub fn new(platform: Platform) -> Self {
        // Effective (not datasheet) bandwidths for half-precision strided
        // workloads: ~64 B/cycle/SM at L1 (NX: 6 SMs, Nano: 2 SM
        // partitions), ~128 B/cycle shared at L2.
        let sms = if platform.peak_flops > 1e12 { 6.0 } else { 2.0 };
        let l1_bw = sms * 64.0 * platform.freq_hz;
        let l2_bw = 128.0 * platform.freq_hz;
        // Architecture factor for gather-heavy butterfly kernels:
        // Maxwell (Nano) lacks Volta's unified L1/shared datapath and
        // full-rate fp16 shuffles — roughly half the achievable issue
        // efficiency of the NX on cuFFT-style stages.
        let butterfly_arch_eff = if platform.peak_flops > 1e12 { 1.0 } else { 0.5 };
        GpuModel { platform, l1_bw, l2_bw, butterfly_arch_eff }
    }

    /// Occupancy ramp: small kernels cannot fill the GPU (launch grain,
    /// tail effects) — efficiency approaches the asymptote only for
    /// multi-GFLOP launches.
    fn eff_ramp(flops: f64) -> f64 {
        flops / (flops + 5e8)
    }

    /// Dense GEMM kernel (the `dense-*` rows): rows × (d_in × d_out).
    pub fn dense_matmul(
        &self,
        name: &str,
        rows: usize,
        d_in: usize,
        d_out: usize,
        use_tensor: bool,
    ) -> GpuKernelResult {
        let flops = 2.0 * rows as f64 * d_in as f64 * d_out as f64;
        let peak = if use_tensor {
            self.platform.peak_flops_tensor.unwrap_or(self.platform.peak_flops)
                * DENSE_TENSOR_EFF
        } else {
            self.platform.peak_flops * DENSE_CUDA_EFF
        } * Self::eff_ramp(flops);
        // Tiled GEMM traffic: inputs + weights + outputs with good reuse.
        let bytes =
            ((rows * d_in + d_in * d_out + rows * d_out) * ELEM_BYTES) as f64 * 1.3;
        let (l1_hit, l2_hit) = (0.92, 0.75);
        self.finish(name, flops, peak, bytes, l1_hit, l2_hit)
    }

    /// Dense whole-attention kernel softmax(QKᵀ)V: batch heads folded in.
    pub fn dense_attention(
        &self,
        name: &str,
        batch: usize,
        seq: usize,
        hidden: usize,
        use_tensor: bool,
    ) -> GpuKernelResult {
        let flops = 2.0 * 2.0 * batch as f64 * seq as f64 * seq as f64 * hidden as f64;
        let peak = if use_tensor {
            self.platform.peak_flops_tensor.unwrap_or(self.platform.peak_flops)
                * DENSE_TENSOR_EFF
        } else {
            self.platform.peak_flops * DENSE_CUDA_EFF
        } * Self::eff_ramp(flops);
        // Softmax runs on CUDA cores at low efficiency (exp + reduce +
        // normalize over the score matrix) and is not overlappable.
        let softmax_flops = 10.0 * batch as f64 * seq as f64 * seq as f64;
        let softmax_time = softmax_flops / (self.platform.peak_flops * 0.25);
        // Score matrix materialization dominates traffic at large seq.
        let bytes = (batch * (2 * seq * hidden + seq * seq)) as f64
            * ELEM_BYTES as f64
            * 1.5;
        let mut r = self.finish(name, flops, peak, bytes, 0.88, 0.70);
        r.time_s += softmax_time;
        r
    }

    /// Butterfly kernel on CUDA cores (cuFFT-style stage loop).
    ///
    /// Mechanism (the Fig. 2 pathology): stages whose stride crosses the
    /// cache line lose spatial locality — each strided partner access
    /// pulls a fresh line of which only a few elements are used
    /// (`STRIDED_AMP` traffic amplification) — and lose temporal locality
    /// once the batch-concurrent working set overflows a level.  The
    /// shuffle-heavy stages also run at a much lower issue efficiency
    /// than contiguous ones.
    pub fn butterfly(&self, spec: &KernelSpec) -> GpuKernelResult {
        const CONTIG_EFF: f64 = BUTTERFLY_CUDA_EFF;
        const STRIDED_EFF: f64 = 0.10;
        const STRIDED_AMP: f64 = 4.0; // quarter-line utilization
        const OVERHEAD: f64 = 1.12; // launch + tail losses

        let n = spec.points;
        let stages = (n as f64).log2() as usize;
        let flops = spec.sparse_flops();
        let line_elems = LINE_BYTES / ELEM_BYTES;
        let l1 = self.platform.l1_bytes.unwrap_or(64 * 1024) as f64;
        let l2 = self.platform.l2_bytes.unwrap_or(256 * 1024) as f64;
        // Working set: vector span × batch rows concurrently resident.
        let ws = (n * ELEM_BYTES * CONCURRENT_ROWS.min(spec.vectors)) as f64;
        let per_stage_bytes = spec.sparse_bytes(ELEM_BYTES) / (stages as f64 + 2.0);

        let mut l1_traffic = 0.0; // line-granular bytes requested of L1
        let mut l2_traffic = 0.0;
        let mut dram_traffic = 0.0;
        let mut eff_acc = 0.0;
        let mut l1_hit_acc = 0.0;
        let mut l2_hit_acc = 0.0;
        for s in 0..stages + 2 {
            // +2: the load/store walks of the vector bracket the stages.
            let stride_elems = if s < stages { 1usize << s } else { 1 };
            let strided = stride_elems >= line_elems;
            let (l1_miss, amp, eff) = if !strided {
                (0.06, 1.0, CONTIG_EFF)
            } else if ws <= l1 {
                (0.12, 1.0, STRIDED_EFF * 2.0)
            } else {
                (0.55, STRIDED_AMP, STRIDED_EFF)
            };
            let l2_miss = if !strided {
                0.5
            } else if ws <= l2 {
                0.30
            } else {
                0.85
            };
            let req = per_stage_bytes * amp;
            l1_traffic += req;
            l2_traffic += req * l1_miss;
            dram_traffic += req * l1_miss * l2_miss;
            eff_acc += eff;
            l1_hit_acc += 1.0 - l1_miss;
            l2_hit_acc += 1.0 - l2_miss;
        }
        let k = (stages + 2) as f64;
        // Same occupancy ramp as the dense path: small butterfly
        // launches (short sequences / small batch) cannot fill the GPU.
        let eff = eff_acc / k * Self::eff_ramp(flops) * self.butterfly_arch_eff;
        let compute = flops / (self.platform.peak_flops * eff);
        let time = OVERHEAD
            * compute
                .max(dram_traffic / self.platform.bandwidth)
                .max(l2_traffic / self.l2_bw)
                .max(l1_traffic / self.l1_bw);
        GpuKernelResult {
            name: spec.name.clone(),
            time_s: time,
            l1_hit: l1_hit_acc / k,
            l2_hit: l2_hit_acc / k,
            l1_req: (l1_traffic / time) / self.l1_bw,
            l2_req: (l2_traffic / time) / self.l2_bw,
            dram_bytes: dram_traffic,
            flops,
        }
    }

    fn finish(
        &self,
        name: &str,
        flops: f64,
        peak: f64,
        req_bytes: f64,
        l1_hit: f64,
        l2_hit: f64,
    ) -> GpuKernelResult {
        let l2_bytes = req_bytes * (1.0 - l1_hit);
        let dram_bytes = l2_bytes * (1.0 - l2_hit);
        let time = (flops / peak)
            .max(dram_bytes / self.platform.bandwidth)
            .max(l2_bytes / self.l2_bw)
            .max(req_bytes / self.l1_bw);
        GpuKernelResult {
            name: name.to_string(),
            time_s: time,
            l1_hit,
            l2_hit,
            l1_req: (req_bytes / time) / self.l1_bw,
            l2_req: (l2_bytes / time) / self.l2_bw,
            dram_bytes,
            flops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::graph::KernelKind;
    use crate::workloads::platforms::{jetson_nano, jetson_xavier_nx};

    fn spec(kind: KernelKind, points: usize, vectors: usize, seq: usize) -> KernelSpec {
        KernelSpec {
            name: format!("{}-{}", kind.name(), points),
            kind,
            points,
            vectors,
            d_in: points,
            d_out: points,
            seq,
        }
    }

    #[test]
    fn hit_rates_degrade_with_scale() {
        // Fig. 2/12 mechanism: larger sequences → larger strided working
        // sets → worse hit rates and higher L2 requirement.
        let gpu = GpuModel::new(jetson_xavier_nx());
        let small = gpu.butterfly(&spec(KernelKind::Fft, 256, 1024, 256));
        let large = gpu.butterfly(&spec(KernelKind::Fft, 8192, 1024, 8192));
        assert!(large.l1_hit < small.l1_hit);
        assert!(large.l2_hit <= small.l2_hit);
        assert!(large.l2_req > small.l2_req);
    }

    #[test]
    fn l2_requirement_exceeds_l1_requirement() {
        // Paper: L1 req 20-54%, L2 req 40-71% — L2 is the pressured level.
        let gpu = GpuModel::new(jetson_xavier_nx());
        let r = gpu.butterfly(&spec(KernelKind::Fft, 4096, 16 * 1024, 4096));
        assert!(r.l2_req > r.l1_req, "l1 {} l2 {}", r.l1_req, r.l2_req);
        assert!(r.l2_req > 0.3 && r.l2_req <= 1.0, "l2 req {}", r.l2_req);
    }

    #[test]
    fn butterfly_does_not_speed_up_large_bert_on_gpu() {
        // Fig. 2 bottom: despite O(n log n) flops, the fft kernel fails
        // to beat the dense kernel at large scale on the GPU.
        let gpu = GpuModel::new(jetson_xavier_nx());
        let seq = 16 * 1024;
        let dense = gpu.dense_attention("dense", 1, seq, 1024, true);
        let bf_seq = gpu.butterfly(&spec(KernelKind::Fft, seq, 1024, seq));
        let bf_hid = gpu.butterfly(&spec(KernelKind::Fft, 1024, seq, seq));
        let sparse_total = bf_seq.time_s + bf_hid.time_s;
        // Butterfly wins at most modestly; flop ratio would predict >>10x.
        let flop_ratio = dense.flops / (bf_seq.flops + bf_hid.flops);
        let speedup = dense.time_s / sparse_total;
        assert!(
            speedup < flop_ratio / 4.0,
            "GPU should squander the sparsity: speedup {speedup:.2} vs flop ratio {flop_ratio:.2}"
        );
    }

    #[test]
    fn dense_tensor_beats_dense_cuda() {
        let gpu = GpuModel::new(jetson_xavier_nx());
        let t = gpu.dense_matmul("t", 4096, 1024, 1024, true);
        let c = gpu.dense_matmul("c", 4096, 1024, 1024, false);
        assert!(t.time_s < c.time_s);
    }

    #[test]
    fn nano_is_slower_than_nx() {
        let nx = GpuModel::new(jetson_xavier_nx());
        let nano = GpuModel::new(jetson_nano());
        let s = spec(KernelKind::Fft, 1024, 4096, 1024);
        assert!(nano.butterfly(&s).time_s > nx.butterfly(&s).time_s);
    }

    #[test]
    fn requirements_are_fractions() {
        let gpu = GpuModel::new(jetson_xavier_nx());
        for n in [256usize, 1024, 8192] {
            let r = gpu.butterfly(&spec(KernelKind::Bpmm, n, 2048, n));
            assert!((0.0..=1.0).contains(&r.l1_req), "{}", r.l1_req);
            assert!((0.0..=1.0).contains(&r.l2_req), "{}", r.l2_req);
            assert!((0.0..=1.0).contains(&r.l1_hit));
        }
    }
}
