//! Analytical models of the comparison platforms.
//!
//! * [`gpu`] — Jetson Xavier NX / Nano: roofline execution with a
//!   cache-hierarchy model that reproduces the butterfly's strided-access
//!   pathology (Fig. 2's hit-rate collapse and the dense-vs-sparse
//!   crossover of Fig. 15).
//! * [`accel`] — the SOTA butterfly FPGA accelerator [8] and the Table-IV
//!   ASIC baselines (SpAtten, DOTA; their end-to-end numbers are quoted
//!   from the literature, as the paper itself does).

pub mod accel;
pub mod gpu;
