//! The Table-IV end-to-end batch-streaming result type.
//!
//! "Input sequences are supplied in batch-256 and streamed in one-by-one
//! from DDR, which ensures the sufficient overlapping of DMA transfer and
//! PE array computation.  The average execution time of the sequence
//! batch is estimated as the latency result."  (§VI-H)
//!
//! The driver itself is [`super::Session::stream`]: every kernel of the
//! workload runs through the simulator (DMA overlap is inside the
//! engine, duplicate kernels hit the session's plan cache, independent
//! kernels fan out across threads), the kernel times are summed, and the
//! per-prediction latency, throughput, effective power and energy
//! efficiency are reported.  [`stream_workload`] remains as a
//! deprecated wrapper over a process-wide shared session.

use crate::workloads::KernelSpec;

use super::experiment::{ExperimentConfig, KernelResult};

/// End-to-end streaming result.
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// Per-kernel breakdown.
    pub kernels: Vec<KernelResult>,
    /// Total batch time (s).
    pub batch_time_s: f64,
    /// Batch size streamed.
    pub batch: usize,
    /// Per-prediction latency (ms) — the Table IV metric.
    pub latency_ms: f64,
    /// Predictions per second.
    pub throughput: f64,
    /// Time-weighted effective power (W).
    pub power_w: f64,
    /// Predictions per joule.
    pub energy_eff: f64,
}

/// Stream a batched workload through the design.
///
/// Errors on `batch == 0` (the per-prediction metrics divide by it).
#[deprecated(
    since = "0.2.0",
    note = "build a `coordinator::Session` and call `stream` instead — \
            sessions reuse lowered programs across kernels and runs"
)]
pub fn stream_workload(
    kernels: &[KernelSpec],
    batch: usize,
    cfg: &ExperimentConfig,
) -> anyhow::Result<StreamResult> {
    super::session::shared_session(cfg).stream(kernels, batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::coordinator::Session;
    use crate::workloads::find_suite;

    fn vanilla_kernels(batch: usize) -> Vec<KernelSpec> {
        find_suite("vanilla").unwrap().kernels_at(Some(batch))
    }

    fn table4_session() -> Session {
        Session::builder().arch(ArchConfig::table4()).build()
    }

    #[test]
    fn table4_workload_streams() {
        // Use a reduced batch for test speed; metrics are per-prediction.
        let r = table4_session().stream(&vanilla_kernels(16), 16).unwrap();
        assert_eq!(r.kernels.len(), 4);
        assert!(r.latency_ms > 0.0);
        assert!((r.throughput - 1000.0 / r.latency_ms).abs() < 1e-6);
        assert!(r.power_w > 0.5 && r.power_w < 6.0, "power {}", r.power_w);
        assert!(r.energy_eff > 0.0);
    }

    #[test]
    fn throughput_is_batch_invariant_in_steady_state() {
        let s = table4_session();
        let a = s.stream(&vanilla_kernels(8), 8).unwrap();
        let b = s.stream(&vanilla_kernels(32), 32).unwrap();
        let ratio = a.throughput / b.throughput;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn zero_batch_is_a_descriptive_error() {
        let err = table4_session()
            .stream(&vanilla_kernels(1), 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("batch"), "unexpected error: {err}");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_stream_wrapper_matches_session() {
        let cfg = ExperimentConfig { arch: ArchConfig::table4(), ..Default::default() };
        let legacy = stream_workload(&vanilla_kernels(8), 8, &cfg).unwrap();
        let modern = Session::from_config(&cfg).stream(&vanilla_kernels(8), 8).unwrap();
        assert_eq!(legacy.latency_ms, modern.latency_ms);
        assert_eq!(legacy.power_w, modern.power_w);
    }
}
