//! The Table-IV end-to-end batch-streaming driver.
//!
//! "Input sequences are supplied in batch-256 and streamed in one-by-one
//! from DDR, which ensures the sufficient overlapping of DMA transfer and
//! PE array computation.  The average execution time of the sequence
//! batch is estimated as the latency result."  (§VI-H)
//!
//! We run every kernel of the workload through the simulator (DMA overlap
//! is inside the engine), sum the kernel times, and report per-prediction
//! latency, throughput, effective power and energy efficiency.

use crate::workloads::KernelSpec;

use super::experiment::{run_kernel, ExperimentConfig, KernelResult};

/// End-to-end streaming result.
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// Per-kernel breakdown.
    pub kernels: Vec<KernelResult>,
    /// Total batch time (s).
    pub batch_time_s: f64,
    /// Batch size streamed.
    pub batch: usize,
    /// Per-prediction latency (ms) — the Table IV metric.
    pub latency_ms: f64,
    /// Predictions per second.
    pub throughput: f64,
    /// Time-weighted effective power (W).
    pub power_w: f64,
    /// Predictions per joule.
    pub energy_eff: f64,
}

/// Stream a batched workload through the design.
pub fn stream_workload(
    kernels: &[KernelSpec],
    batch: usize,
    cfg: &ExperimentConfig,
) -> anyhow::Result<StreamResult> {
    let mut results = Vec::with_capacity(kernels.len());
    for k in kernels {
        results.push(run_kernel(k, cfg)?);
    }
    let batch_time_s: f64 = results.iter().map(|r| r.time_s).sum();
    let energy_j: f64 = results.iter().map(|r| r.energy_j).sum();
    let power_w = if batch_time_s > 0.0 { energy_j / batch_time_s } else { 0.0 };
    let latency_s = batch_time_s / batch as f64;
    Ok(StreamResult {
        kernels: results,
        batch_time_s,
        batch,
        latency_ms: latency_s * 1e3,
        throughput: 1.0 / latency_s,
        power_w,
        energy_eff: (batch as f64) / energy_j,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::workloads::vanilla_kernels;

    #[test]
    fn table4_workload_streams() {
        let cfg = ExperimentConfig {
            arch: ArchConfig::table4(),
            ..Default::default()
        };
        // Use a reduced batch for test speed; metrics are per-prediction.
        let r = stream_workload(&vanilla_kernels(16), 16, &cfg).unwrap();
        assert_eq!(r.kernels.len(), 4);
        assert!(r.latency_ms > 0.0);
        assert!((r.throughput - 1000.0 / r.latency_ms).abs() < 1e-6);
        assert!(r.power_w > 0.5 && r.power_w < 6.0, "power {}", r.power_w);
        assert!(r.energy_eff > 0.0);
    }

    #[test]
    fn throughput_is_batch_invariant_in_steady_state() {
        let cfg = ExperimentConfig {
            arch: ArchConfig::table4(),
            ..Default::default()
        };
        let a = stream_workload(&vanilla_kernels(8), 8, &cfg).unwrap();
        let b = stream_workload(&vanilla_kernels(32), 32, &cfg).unwrap();
        let ratio = a.throughput / b.throughput;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }
}
