//! The Table-IV end-to-end batch-streaming result type.
//!
//! "Input sequences are supplied in batch-256 and streamed in one-by-one
//! from DDR, which ensures the sufficient overlapping of DMA transfer and
//! PE array computation.  The average execution time of the sequence
//! batch is estimated as the latency result."  (§VI-H)
//!
//! The driver is [`super::Session::stream`].  Two layers produce the
//! numbers:
//!
//! * **Simulated** — every kernel runs through the cycle-level
//!   simulator (per-iteration DMA gating, SPM ports, NoC contention;
//!   duplicate kernels hit the session's plan cache, independent
//!   kernels fan out across threads).  The per-kernel times, energies
//!   and traffic counters are simulation outputs.
//! * **Analytically overlapped** — the kernel *sequence* is then
//!   scheduled by [`super::pipeline`]: double-buffered DMA/compute
//!   overlap per kernel (prologue fill + steady-state
//!   `max(compute, dma)` + drain), inter-kernel pipelining of
//!   consecutive batch elements (floored by the per-array capacity
//!   bound — co-resident stages share the PEs and the DDR channel),
//!   and static batch sharding across `arrays` replicated dataflow
//!   arrays.  [`StreamResult`] reports
//!   both the serial reference ([`StreamResult::serial_time_s`], the
//!   plain sum of kernel times) and the overlapped makespan
//!   ([`StreamResult::overlapped_time_s`]); the per-prediction metrics
//!   (latency, throughput, power, energy efficiency) follow the
//!   session's configured mode.
//!
//! Configure via `Session::builder().overlap(..).arrays(..)` or per
//! call with [`super::Session::stream_with`]; on the CLI the knobs are
//! `bfdf run|stream --overlap {none,dma,pipeline} --arrays N`.  The
//! library default (`Overlap::None`, one array) reproduces the legacy
//! serial accounting bit-for-bit; the CLI defaults to the
//! paper-faithful `--overlap pipeline`.

use super::experiment::KernelResult;
use super::pipeline::Overlap;

/// End-to-end streaming result.
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// Per-kernel breakdown (simulated; serial reference numbers).
    pub kernels: Vec<KernelResult>,
    /// Batch size streamed.
    pub batch: usize,
    /// Effective batch makespan (s) under the configured overlap mode
    /// and array count (equals `serial_time_s` for `Overlap::None` on
    /// a single array; with more arrays even serial mode shards the
    /// batch).
    pub batch_time_s: f64,
    /// Serial reference: plain sum of the simulated kernel times (s).
    pub serial_time_s: f64,
    /// Overlapped makespan (s); always ≤ `serial_time_s`, and equal to
    /// `batch_time_s`.
    pub overlapped_time_s: f64,
    /// Achieved fraction of the shard's aggregate capacity bound
    /// (total compute vs total gating DMA), in (0, 1].
    pub pipeline_efficiency: f64,
    /// Replicated dataflow arrays the batch was sharded across.
    pub arrays: usize,
    /// Overlap mode the schedule was computed under.
    pub overlap: Overlap,
    /// Per-prediction latency (ms) — the Table IV metric.
    pub latency_ms: f64,
    /// Predictions per second.
    pub throughput: f64,
    /// Time-weighted effective power (W) over all arrays.
    pub power_w: f64,
    /// Total energy (J): active kernel energy plus idle-replica energy.
    pub energy_j: f64,
    /// Predictions per joule.
    pub energy_eff: f64,
}

impl StreamResult {
    /// Speedup of the overlapped schedule over the serial sum (≥ 1).
    pub fn speedup(&self) -> f64 {
        super::pipeline::speedup(self.serial_time_s, self.overlapped_time_s)
    }
}

/// Per-prediction metrics `(latency_ms, throughput, power_w,
/// energy_eff)` from a batch makespan and total energy, with every
/// division guarded: degenerate inputs (zero time or energy) yield 0.0
/// instead of `inf`/`NaN`.
pub(crate) fn per_prediction_metrics(
    batch: usize,
    batch_time_s: f64,
    energy_j: f64,
) -> (f64, f64, f64, f64) {
    let latency_s = batch_time_s / batch as f64;
    let latency_ms = latency_s * 1e3;
    let throughput = if latency_s > 0.0 { 1.0 / latency_s } else { 0.0 };
    let power_w = if batch_time_s > 0.0 { energy_j / batch_time_s } else { 0.0 };
    let energy_eff = if energy_j > 0.0 { batch as f64 / energy_j } else { 0.0 };
    (latency_ms, throughput, power_w, energy_eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::coordinator::pipeline::{Overlap, PipelineConfig};
    use crate::coordinator::Session;
    use crate::workloads::{find_suite, KernelSpec};

    fn vanilla_kernels(batch: usize) -> Vec<KernelSpec> {
        find_suite("vanilla").unwrap().kernels_at(Some(batch))
    }

    fn table4_session() -> Session {
        Session::builder().arch(ArchConfig::table4()).build()
    }

    #[test]
    fn table4_workload_streams() {
        // Use a reduced batch for test speed; metrics are per-prediction.
        let r = table4_session().stream(&vanilla_kernels(16), 16).unwrap();
        assert_eq!(r.kernels.len(), 4);
        assert!(r.latency_ms > 0.0);
        assert!((r.throughput - 1000.0 / r.latency_ms).abs() < 1e-6);
        assert!(r.power_w > 0.5 && r.power_w < 6.0, "power {}", r.power_w);
        assert!(r.energy_eff > 0.0);
        // The library default is the legacy serial accounting.
        assert_eq!(r.overlap, Overlap::None);
        assert_eq!(r.arrays, 1);
        assert_eq!(r.batch_time_s, r.serial_time_s);
        assert_eq!(r.batch_time_s, r.overlapped_time_s);
    }

    #[test]
    fn throughput_is_batch_invariant_in_steady_state() {
        // Per-prediction throughput must be nearly batch-independent
        // once the per-stage fills are amortized: time(B) ≈ F + B·s
        // with F ≪ B·s at these scales, so thr(8)/thr(32) sits just
        // below 1 and can exceed it only by iteration-rounding noise.
        let s = table4_session();
        let a = s.stream(&vanilla_kernels(8), 8).unwrap();
        let b = s.stream(&vanilla_kernels(32), 32).unwrap();
        let ratio = a.throughput / b.throughput;
        assert!((0.9..1.01).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn zero_batch_is_a_descriptive_error() {
        let err = table4_session()
            .stream(&vanilla_kernels(1), 0)
            .unwrap_err()
            .to_string();
        assert!(err.contains("batch"), "unexpected error: {err}");
    }

    #[test]
    fn degenerate_metrics_are_zero_not_nan() {
        // Regression: throughput and energy efficiency used to divide
        // by unguarded latency/energy; zero inputs must yield finite
        // zeros exactly like the power branch always did.
        let (latency_ms, throughput, power_w, energy_eff) =
            per_prediction_metrics(8, 0.0, 0.0);
        assert_eq!(latency_ms, 0.0);
        assert_eq!(throughput, 0.0);
        assert_eq!(power_w, 0.0);
        assert_eq!(energy_eff, 0.0);
        for v in [latency_ms, throughput, power_w, energy_eff] {
            assert!(v.is_finite());
        }
        // Positive inputs keep the exact legacy expressions.
        let (l, t, p, e) = per_prediction_metrics(4, 2.0, 8.0);
        assert_eq!(l, 500.0);
        assert_eq!(t, 2.0);
        assert_eq!(p, 4.0);
        assert_eq!(e, 0.5);
    }

    #[test]
    fn overlap_modes_order_on_a_real_workload() {
        let s = table4_session();
        let ks = vanilla_kernels(16);
        let t = |overlap, arrays| {
            s.stream_with(&ks, 16, PipelineConfig::new(overlap, arrays))
                .unwrap()
                .overlapped_time_s
        };
        let none = t(Overlap::None, 1);
        let dma = t(Overlap::Dma, 1);
        let pipe = t(Overlap::Pipeline, 1);
        assert!(dma <= none, "dma {dma} > none {none}");
        assert!(pipe <= dma, "pipeline {pipe} > dma {dma}");
        assert!(pipe > 0.0);
        // Sharding across arrays cuts the makespan further.
        let pipe4 = t(Overlap::Pipeline, 4);
        assert!(pipe4 < pipe, "4 arrays {pipe4} !< 1 array {pipe}");
    }

}
