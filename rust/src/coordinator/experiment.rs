//! Kernel-level experiment runner.
//!
//! A kernel's iteration population is usually far larger than what needs
//! cycle-accurate treatment (batch 128 × seq 64K ⇒ millions of DFG
//! iterations), and the block pipeline reaches a steady state within a
//! few tens of iterations.  So each stage DFG is simulated for a
//! *window* of iterations and extrapolated at the measured steady-state
//! iteration rate — the standard software-pipelining argument.  The
//! window default (48) is over 4× the deepest pipeline in the design;
//! `window_sensitivity` tests in `rust/tests/` verify the extrapolation.

use crate::arch::{ArchConfig, UnitKind};
use crate::dfg::stages::{plan_kernel, KernelPlan};
use crate::dfg::microcode::lower_stage_packed;

/// Packing target: keep at least this many butterfly nodes per PE per
/// layer so fixed block overheads stay amortized.
const TARGET_NODES_PER_PE: usize = 8;
use crate::energy;
use crate::sim::{simulate, SimOptions, SimStats};
use crate::workloads::KernelSpec;

/// Configuration for experiment runs.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub arch: ArchConfig,
    pub sim: SimOptions,
    /// Simulation window in DFG iterations per stage.
    pub window: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            arch: ArchConfig::full(),
            sim: SimOptions::default(),
            window: 48,
        }
    }
}

/// Aggregated result of one kernel on the dataflow design.
#[derive(Debug, Clone)]
pub struct KernelResult {
    pub name: String,
    /// Extrapolated total cycles.
    pub cycles: f64,
    /// Wall time at the configured clock.
    pub time_s: f64,
    /// Utilization per unit kind over the whole run (full array).
    pub util: [f64; 4],
    /// SPM accessing-requirement percentage (Fig. 12 metric): SPM scalar
    /// rate over aggregate SPM port capacity.
    pub spm_requirement: f64,
    /// NoC scalar traffic per cycle over link capacity (reuse indicator).
    pub noc_requirement: f64,
    /// Butterfly FLOPs executed.
    pub flops: f64,
    /// Achieved fraction of the array's peak FLOPS.
    pub flops_efficiency: f64,
    /// Effective power (W) and energy (J).
    pub power_w: f64,
    pub energy_j: f64,
    /// DDR bytes streamed.
    pub dma_bytes: f64,
    /// The underlying plan (stage structure).
    pub plan: KernelPlan,
}

/// Run a kernel with the default balanced division.
pub fn run_kernel(spec: &KernelSpec, cfg: &ExperimentConfig) -> anyhow::Result<KernelResult> {
    run_kernel_with(spec, cfg, None)
}

/// Run a kernel with an explicit stage division (the Fig. 14 sweep).
pub fn run_kernel_with(
    spec: &KernelSpec,
    cfg: &ExperimentConfig,
    division: Option<(usize, usize)>,
) -> anyhow::Result<KernelResult> {
    let arch = &cfg.arch;
    let plan = plan_kernel(spec.kind, spec.points, spec.vectors, arch, division)?;
    let w = arch.simd_width;

    let mut total_cycles = 0.0f64;
    let mut busy = [0.0f64; 4];
    let mut spm_scalars = 0.0f64;
    let mut noc_scalars = 0.0f64;
    let mut dma_bytes = 0.0f64;
    let mut ops_total = 0.0f64;

    for stage in &plan.stages {
        let instances = spec.vectors.saturating_mul(stage.sub_iters);
        // Instance packing: shallow stage DFGs (few nodes per PE) pack
        // several independent instances per iteration so block issue
        // overheads amortize (§V-A streaming).
        let base_npe = (stage.points / 2).div_ceil(arch.num_pes()).max(1);
        let pack = (TARGET_NODES_PER_PE / base_npe)
            .clamp(1, instances.div_ceil(w).max(1));
        let iters_total = instances.div_ceil(w * pack).max(1);
        let window = iters_total.min(cfg.window);
        let program = lower_stage_packed(stage, arch, window, pack);
        let stats = simulate(&program, arch, &cfg.sim);
        let scale = iters_total as f64 / window as f64;
        let stage_cycles = if iters_total > window {
            stats.cycles as f64
                + (iters_total - window) as f64 * stats.steady_cycles_per_iter()
        } else {
            stats.cycles as f64
        };
        total_cycles += stage_cycles;
        // Busy time is a *rate*: extrapolate by the cycle ratio (the
        // iteration ratio can drift ~1% from it and push utilization
        // fractionally above 1.0).
        let busy_scale = stage_cycles / stats.cycles.max(1) as f64;
        for k in 0..4 {
            busy[k] += stats.unit_busy[k] as f64 * busy_scale;
        }
        spm_scalars += stats.spm_scalars as f64 * scale;
        noc_scalars += stats.noc_scalars as f64 * scale;
        dma_bytes += stats.dma_bytes as f64 * scale;
        ops_total += program.total_ops() as f64 * scale;
    }

    let num_pes = arch.num_pes() as f64;
    let util = [
        busy[0] / (total_cycles * num_pes),
        busy[1] / (total_cycles * num_pes),
        busy[2] / (total_cycles * num_pes),
        busy[3] / (total_cycles * num_pes),
    ];
    // SPM accessing requirement (the Fig. 12 metric): fraction of the
    // compute's operand traffic that the SPM has to serve.  Each compute
    // slot touches ~2 operand scalars per lane; the multilayer DFG keeps
    // most of those inside PEs / on the NoC, so the SPM share stays low
    // (the paper reports ≤ 12.48%).
    let operand_scalars = 2.0 * ops_total * arch.simd_width as f64;
    let spm_requirement = spm_scalars / operand_scalars.max(1.0);
    let link_cap = (arch.num_pes() * 4) as f64
        * (arch.noc_link_bytes / arch.elem_bytes) as f64;
    let noc_requirement = (noc_scalars / total_cycles) / link_cap;

    let time_s = arch.cycles_to_seconds(1) * total_cycles;
    let flops = spec.sparse_flops();
    let flops_efficiency = flops / time_s / arch.peak_flops();

    // Build an aggregate stats view for the energy model.
    let agg = SimStats {
        cycles: total_cycles as u64,
        unit_busy: [
            busy[0] as u64,
            busy[1] as u64,
            busy[2] as u64,
            busy[3] as u64,
        ],
        ..Default::default()
    };
    let power_w = energy::effective_power_w(arch, &agg);
    let energy_j = power_w * time_s;

    Ok(KernelResult {
        name: spec.name.clone(),
        cycles: total_cycles,
        time_s,
        util,
        spm_requirement,
        noc_requirement,
        flops,
        flops_efficiency,
        power_w,
        energy_j,
        dma_bytes,
        plan,
    })
}

impl KernelResult {
    pub fn util_of(&self, kind: UnitKind) -> f64 {
        self.util[kind.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::graph::KernelKind;

    fn spec(kind: KernelKind, points: usize, vectors: usize) -> KernelSpec {
        KernelSpec {
            name: format!("{}-{}", kind.name(), points),
            kind,
            points,
            vectors,
            d_in: points,
            d_out: points,
            seq: points,
        }
    }

    #[test]
    fn basic_kernel_runs() {
        let cfg = ExperimentConfig::default();
        let r = run_kernel(&spec(KernelKind::Fft, 256, 4096), &cfg).unwrap();
        assert!(r.cycles > 0.0);
        assert!(r.time_s > 0.0);
        assert!(r.flops_efficiency > 0.0 && r.flops_efficiency <= 1.0);
        assert!(r.power_w > 1.0 && r.power_w < 10.0);
    }

    #[test]
    fn cal_utilization_above_064_at_scale() {
        // §VI-D headline: calUnit > 64% for all butterfly kernels (large
        // batch, steady state).
        let cfg = ExperimentConfig::default();
        for kind in [KernelKind::Fft, KernelKind::Bpmm] {
            let r = run_kernel(&spec(kind, 256, 64 * 1024), &cfg).unwrap();
            assert!(
                r.util_of(UnitKind::Cal) > 0.5,
                "{} cal util {:.3}",
                r.name,
                r.util_of(UnitKind::Cal)
            );
        }
    }

    #[test]
    fn spm_requirement_below_gpu_levels() {
        // Fig. 12: SPM accessing requirement below 12.48%... allow slack
        // but it must be far below the GPU's 40-70% L2 pressure.
        let cfg = ExperimentConfig::default();
        let r = run_kernel(&spec(KernelKind::Fft, 256, 64 * 1024), &cfg).unwrap();
        assert!(r.spm_requirement < 0.13, "spm req {:.3}", r.spm_requirement);
    }

    #[test]
    fn extrapolation_scales_linearly() {
        let cfg = ExperimentConfig::default();
        let small = run_kernel(&spec(KernelKind::Bpmm, 256, 16 * 1024), &cfg).unwrap();
        let large = run_kernel(&spec(KernelKind::Bpmm, 256, 64 * 1024), &cfg).unwrap();
        let ratio = large.cycles / small.cycles;
        assert!((ratio - 4.0).abs() < 0.4, "ratio {ratio}");
    }

    #[test]
    fn division_override_changes_plan() {
        let cfg = ExperimentConfig::default();
        let s = spec(KernelKind::Bpmm, 2048, 8192);
        let a = run_kernel_with(&s, &cfg, Some((32, 64))).unwrap();
        let b = run_kernel_with(&s, &cfg, Some((16, 128))).unwrap();
        assert_eq!(a.plan.stages[0].points, 32);
        assert_eq!(b.plan.stages[0].points, 16);
        assert_ne!(a.cycles, b.cycles);
    }
}
