//! Kernel-level experiment configuration and results.
//!
//! A kernel's iteration population is usually far larger than what needs
//! cycle-accurate treatment (batch 128 × seq 64K ⇒ millions of DFG
//! iterations), and the block pipeline reaches a steady state within a
//! few tens of iterations.  So each stage DFG is simulated for a
//! *window* of iterations and extrapolated at the measured steady-state
//! iteration rate — the standard software-pipelining argument.  The
//! window default (48) is over 4× the deepest pipeline in the design;
//! `window_sensitivity` tests in `rust/tests/` verify the extrapolation.
//!
//! The execution engine lives in [`super::session`]: a [`Session`]
//! plans, lowers and simulates kernels with plan caching, parallel
//! fan-out and a pool of reusable simulator workspaces
//! ([`crate::sim::SimWorkspace`]) so windowed re-simulation is
//! allocation-free at steady state.  This module keeps only the
//! configuration ([`ExperimentConfig`]) and result ([`KernelResult`])
//! types; all execution goes through a [`Session`](super::Session).

use crate::arch::{ArchConfig, UnitKind};
use crate::dfg::stages::KernelPlan;
use crate::sim::SimOptions;
use crate::workloads::KernelSpec;

/// Configuration for experiment runs.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub arch: ArchConfig,
    pub sim: SimOptions,
    /// Simulation window in DFG iterations per stage.
    pub window: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            arch: ArchConfig::full(),
            sim: SimOptions::default(),
            window: 48,
        }
    }
}

/// Aggregated result of one kernel on the dataflow design.
#[derive(Debug, Clone)]
pub struct KernelResult {
    pub name: String,
    /// Extrapolated total cycles.
    pub cycles: f64,
    /// Wall time at the configured clock.
    pub time_s: f64,
    /// Utilization per unit kind over the whole run (full array).
    pub util: [f64; 4],
    /// SPM accessing-requirement percentage (Fig. 12 metric): SPM scalar
    /// rate over aggregate SPM port capacity.
    pub spm_requirement: f64,
    /// NoC scalar traffic per cycle over link capacity (reuse indicator).
    pub noc_requirement: f64,
    /// Butterfly FLOPs executed.
    pub flops: f64,
    /// Achieved fraction of the array's peak FLOPS.
    pub flops_efficiency: f64,
    /// Effective power (W) and energy (J).
    pub power_w: f64,
    pub energy_j: f64,
    /// DDR bytes streamed (historical accounting: the window
    /// extrapolation scales the whole window traffic, weights
    /// included — the energy model is calibrated against this).
    pub dma_bytes: f64,
    /// DDR channel occupancy (s) of the *gating* stream — weights once
    /// per stage plus the extrapolated per-iteration input traffic — at
    /// the aggregate bandwidth.  Outputs drain on the writeback half of
    /// the channel budget and never gate compute (matching the
    /// simulator), so this is deliberately not `dma_bytes / bw` (see
    /// `dma_bytes`).  This is the streaming side of the coarse overlap
    /// model.
    pub dma_time_s: f64,
    /// Cold-start DMA prologue (s): per-stage fill (setup + weight
    /// preamble + first input chunk) summed over the plan's stages;
    /// charged once per stage regardless of the extrapolated iteration
    /// count.  Always ≤ `time_s`.
    pub fill_time_s: f64,
    /// The underlying plan (stage structure).
    pub plan: KernelPlan,
}

impl KernelResult {
    pub fn util_of(&self, kind: UnitKind) -> f64 {
        self.util[kind.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Session;
    use crate::dfg::graph::KernelKind;

    fn spec(kind: KernelKind, points: usize, vectors: usize) -> KernelSpec {
        KernelSpec {
            name: format!("{}-{}", kind.name(), points),
            kind,
            points,
            vectors,
            d_in: points,
            d_out: points,
            seq: points,
        }
    }

    fn session() -> Session {
        Session::builder().build()
    }

    #[test]
    fn basic_kernel_runs() {
        let r = session().run(&spec(KernelKind::Fft, 256, 4096)).unwrap();
        assert!(r.cycles > 0.0);
        assert!(r.time_s > 0.0);
        assert!(r.flops_efficiency > 0.0 && r.flops_efficiency <= 1.0);
        assert!(r.power_w > 1.0 && r.power_w < 10.0);
    }

    #[test]
    fn cal_utilization_above_064_at_scale() {
        // §VI-D headline: calUnit > 64% for all butterfly kernels (large
        // batch, steady state).
        let s = session();
        for kind in [KernelKind::Fft, KernelKind::Bpmm] {
            let r = s.run(&spec(kind, 256, 64 * 1024)).unwrap();
            assert!(
                r.util_of(UnitKind::Cal) > 0.5,
                "{} cal util {:.3}",
                r.name,
                r.util_of(UnitKind::Cal)
            );
        }
    }

    #[test]
    fn spm_requirement_below_gpu_levels() {
        // Fig. 12: SPM accessing requirement below 12.48%... allow slack
        // but it must be far below the GPU's 40-70% L2 pressure.
        let r = session().run(&spec(KernelKind::Fft, 256, 64 * 1024)).unwrap();
        assert!(r.spm_requirement < 0.13, "spm req {:.3}", r.spm_requirement);
    }

    #[test]
    fn extrapolation_scales_linearly() {
        let s = session();
        let small = s.run(&spec(KernelKind::Bpmm, 256, 16 * 1024)).unwrap();
        let large = s.run(&spec(KernelKind::Bpmm, 256, 64 * 1024)).unwrap();
        let ratio = large.cycles / small.cycles;
        assert!((ratio - 4.0).abs() < 0.4, "ratio {ratio}");
    }

    #[test]
    fn division_override_changes_plan() {
        let sess = session();
        let s = spec(KernelKind::Bpmm, 2048, 8192);
        let a = sess.run_with(&s, Some((32, 64))).unwrap();
        let b = sess.run_with(&s, Some((16, 128))).unwrap();
        assert_eq!(a.plan.stages[0].points, 32);
        assert_eq!(b.plan.stages[0].points, 16);
        assert_ne!(a.cycles, b.cycles);
    }

    #[test]
    fn from_config_matches_builder_defaults() {
        let cfg = ExperimentConfig::default();
        let s = spec(KernelKind::Fft, 512, 8192);
        let a = Session::from_config(&cfg).run(&s).unwrap();
        let b = Session::builder().build().run(&s).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.util, b.util);
    }
}
