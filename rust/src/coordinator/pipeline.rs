//! Coarse-grained streaming overlap: DMA double buffering, inter-kernel
//! pipelining, and batch sharding across replicated arrays.
//!
//! The paper's Table IV methodology (§VI-H) streams batch-256 sequences
//! from DDR "which ensures the sufficient overlapping of DMA transfer
//! and PE array computation".  The cycle-level simulator models that
//! overlap *inside* one kernel window (loads gate on per-iteration DMA
//! chunks), but the serial sum `Σ kernel time` that
//! [`super::Session::stream`] and [`super::Session::run_network`] used
//! to report charges every kernel its cold-start DMA prologue and lets
//! no two kernels ever share the substrate — systematically pessimistic
//! for a streamed batch.  This module closes that gap with an analytic
//! schedule layered **on top of** the per-kernel simulations:
//!
//! 1. **DMA/compute double buffering** ([`Overlap::Dma`]): every kernel
//!    splits into a cold-start *fill* (DMA setup + weight preamble +
//!    first input chunk — [`StageCost::fill_s`], measured by the
//!    simulator) and a steady *body*.  In a streamed schedule, kernel
//!    `k+1`'s fill prefetches under kernel `k`'s body, so only the first
//!    kernel pays its prologue; each later kernel occupies the array for
//!    `max(body, dma)` — compute or its DDR stream, whichever is longer
//!    — clamped by its serial time (the model never predicts overlap
//!    slower than the simulated serial execution).
//! 2. **Inter-kernel / inter-layer pipelining** ([`Overlap::Pipeline`]):
//!    the multilayer dataflow maps several stage DFGs onto the mesh at
//!    once, so consecutive batch elements occupy successive kernels
//!    (and, for a network, successive layers) concurrently.  The
//!    schedule is the classic linear pipeline — one fill, one pass of
//!    every stage for the first element, then one bottleneck-stage
//!    interval per further element — floored by the shard's aggregate
//!    capacity bound: co-resident stages still share one array's PEs
//!    and one DDR channel, so the makespan never undercuts
//!    `fill + max(Σ compute body, Σ gating DMA)`.
//! 3. **Array sharding** ([`PipelineConfig::arrays`]): the batch is
//!    statically partitioned over `A` replicated dataflow arrays
//!    (`ceil`/`floor` shards, no work stealing); the makespan is the
//!    most-loaded shard's, and replicas that finish early (or receive no
//!    work) are charged idle power ([`OverlapEstimate::idle_energy_j`]).
//!
//! Everything here is *analytic post-processing* of simulated
//! [`super::KernelResult`]s: per-kernel cycles, busy counters, DMA
//! traffic and fill come from the simulator; the overlap arithmetic is
//! deterministic and monotone (`pipeline ≤ dma ≤ none` by
//! construction), so `Overlap::None` with one array reproduces the
//! legacy serial numbers bit-for-bit.  Second-order effects the model
//! deliberately ignores: weight re-streaming into every replica array,
//! and the SPM footprint of co-resident stages.

use anyhow::Result;

use super::experiment::KernelResult;

/// Coarse-grained overlap mode of a streamed schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Overlap {
    /// Serial sum of kernel times — the legacy (v0.3) model, kept as
    /// the bit-exact reference (`--overlap none`).
    #[default]
    None,
    /// Double-buffered DMA/compute overlap per kernel; cold-start fills
    /// hide under the preceding kernel's steady state.
    Dma,
    /// [`Overlap::Dma`] plus inter-kernel/inter-layer pipelining of
    /// consecutive batch elements (the paper's streaming mode).
    Pipeline,
}

impl Overlap {
    pub fn name(self) -> &'static str {
        match self {
            Overlap::None => "none",
            Overlap::Dma => "dma",
            Overlap::Pipeline => "pipeline",
        }
    }

    /// Parse a CLI spelling (`none | dma | pipeline`).
    pub fn parse(s: &str) -> Result<Overlap> {
        match s {
            "none" => Ok(Overlap::None),
            "dma" => Ok(Overlap::Dma),
            "pipeline" => Ok(Overlap::Pipeline),
            other => anyhow::bail!("unknown overlap mode '{other}' (none | dma | pipeline)"),
        }
    }
}

/// Streaming-schedule configuration of a session: overlap mode plus the
/// number of replicated dataflow arrays the batch is sharded across.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PipelineConfig {
    pub overlap: Overlap,
    /// Replicated dataflow arrays (≥ 1); the batch is statically
    /// partitioned across them.
    pub arrays: usize,
}

impl Default for PipelineConfig {
    /// The library default preserves legacy semantics exactly: serial
    /// accounting on a single array.  The CLI defaults to
    /// `--overlap pipeline --arrays 1` (the paper-faithful mode).
    fn default() -> Self {
        PipelineConfig { overlap: Overlap::None, arrays: 1 }
    }
}

impl PipelineConfig {
    pub fn new(overlap: Overlap, arrays: usize) -> Self {
        PipelineConfig { overlap, arrays: arrays.max(1) }
    }
}

/// Cost decomposition of one pipeline stage (usually one kernel) for
/// the whole batch.
#[derive(Debug, Clone, Copy)]
pub struct StageCost {
    /// Simulated serial wall time of the stage (s) — includes the fill.
    pub serial_s: f64,
    /// Cold-start DMA prologue inside `serial_s` (s): setup + weight
    /// preamble + first input chunk, summed over the kernel's stage
    /// DFGs.  Batch-size independent.
    pub fill_s: f64,
    /// DDR channel occupancy of the stage's *gating* traffic (s):
    /// weights once plus the input stream; outputs drain on the
    /// writeback half of the channel budget (matching the simulator)
    /// and never gate.
    pub dma_s: f64,
}

impl StageCost {
    /// Stage cost of a simulated butterfly kernel.
    pub fn of_kernel(r: &KernelResult) -> StageCost {
        StageCost {
            serial_s: r.time_s,
            // The fill is measured inside the simulated makespan, so it
            // can never exceed it; clamp defensively anyway.
            fill_s: r.fill_time_s.min(r.time_s),
            dma_s: r.dma_time_s,
        }
    }

    /// Stage with no measured DMA split (dense roofline blocks): treated
    /// as pure serial occupancy.
    pub fn serial_only(time_s: f64) -> StageCost {
        StageCost { serial_s: time_s, fill_s: 0.0, dma_s: 0.0 }
    }
}

/// Analytic overlap estimate of one streamed schedule.
#[derive(Debug, Clone, Copy)]
pub struct OverlapEstimate {
    pub overlap: Overlap,
    pub arrays: usize,
    /// Serial reference: `Σ serial_s` over all stages (the legacy sum).
    pub serial_time_s: f64,
    /// Effective batch makespan under `(overlap, arrays)`; equals
    /// `serial_time_s` for `Overlap::None` on one array, and is
    /// `≤ serial_time_s` always.
    pub overlapped_time_s: f64,
    /// Achieved fraction of the shard's aggregate capacity bound (total
    /// compute body vs total gating DMA, whichever dominates) — in
    /// `(0, 1]`.
    pub pipeline_efficiency: f64,
    /// Idle-replica energy (J): arrays that finished early (or got no
    /// shard) burn idle power until the makespan.  Zero for one array.
    pub idle_energy_j: f64,
}

impl OverlapEstimate {
    /// Speedup of the overlapped schedule over the serial sum (≥ 1).
    pub fn speedup(&self) -> f64 {
        speedup(self.serial_time_s, self.overlapped_time_s)
    }
}

/// Speedup of an overlapped makespan over its serial reference (≥ 1;
/// degenerate zero makespans count as no speedup).  Shared by
/// [`OverlapEstimate`], `StreamResult` and `NetworkResult` so the
/// zero-guard policy cannot diverge between them.
pub(crate) fn speedup(serial_s: f64, overlapped_s: f64) -> f64 {
    if overlapped_s > 0.0 {
        serial_s / overlapped_s
    } else {
        1.0
    }
}

/// Steady occupancy of one stage at shard fraction `frac` under double
/// buffering: compute body or DDR stream, whichever is longer, clamped
/// by the (scaled) serial time.  Used by `shard_time` for the dma/
/// pipeline stage terms.  Note that `capacity_bound` intentionally does
/// NOT use this clamp: it sums raw bodies and raw gating streams, the
/// floor no single-array schedule can beat.
fn stage_occupancy(s: &StageCost, frac: f64) -> f64 {
    let body = (s.serial_s - s.fill_s).max(0.0) * frac;
    let ser = s.fill_s + body;
    ser.min(body.max(s.dma_s * frac))
}

/// Makespan of one array's shard of `b_shard` of the `batch` elements,
/// under `overlap`.  `frac = b_shard / batch` scales every
/// batch-proportional term; fills are charged per stage regardless.
fn shard_time(stages: &[StageCost], batch: usize, b_shard: usize, overlap: Overlap) -> f64 {
    if b_shard == 0 {
        return 0.0;
    }
    // Full shard ⇒ the serial reference must be reproduced exactly
    // (same floats, same summation order) in `Overlap::None`.
    if b_shard == batch && overlap == Overlap::None {
        return stages.iter().map(|s| s.serial_s).sum();
    }
    let frac = b_shard as f64 / batch as f64;
    // Scaled per-stage components: the fill is batch-independent, the
    // body (steady compute) and the DMA stream scale with elements.
    let serial: Vec<f64> =
        stages.iter().map(|s| s.fill_s + (s.serial_s - s.fill_s).max(0.0) * frac).collect();
    let t_none: f64 = serial.iter().sum();
    if overlap == Overlap::None {
        return t_none;
    }
    // Steady occupancy under double buffering: compute or DDR stream,
    // whichever is longer — clamped by the serial time (overlap never
    // makes a stage slower than its simulated serial execution).
    let ovl: Vec<f64> = stages.iter().map(|s| stage_occupancy(s, frac)).collect();
    // DMA mode: the first stage has no predecessor to hide its fill
    // under, so it is charged serially; every later stage runs at its
    // steady occupancy while its fill prefetches under the predecessor.
    let first_serial = serial.first().copied().unwrap_or(0.0);
    let rest_ovl: f64 = ovl.iter().skip(1).sum();
    let t_dma = (first_serial + rest_ovl).min(t_none);
    if overlap == Overlap::Dma {
        return t_dma;
    }
    // Pipeline mode: elements stream through the stages — one fill, one
    // pass of every stage for the first element, then one
    // bottleneck-stage interval per further element — but never below
    // the shard's aggregate capacity bound: co-resident stages still
    // share one array's PEs and one DDR channel, so the element-level
    // formula cannot undercut the total compute body or the total
    // gating DMA stream.  The final clamp by the DMA-mode time keeps
    // the mode ordering pipeline ≤ dma ≤ none exact even at batch 1
    // (where pipelining cannot help) and where the capacity bound's
    // DMA sum exceeds what the serial reference ever charged.
    let fill0 = stages.first().map(|s| s.fill_s).unwrap_or(0.0);
    let sum_ovl: f64 = ovl.iter().sum();
    let max_ovl = ovl.iter().copied().fold(0.0f64, f64::max);
    let b = b_shard as f64;
    let element_pipelined = fill0 + (sum_ovl + (b - 1.0) * max_ovl) / b;
    element_pipelined.max(capacity_bound(stages, batch, b_shard)).min(t_dma)
}

/// Aggregate capacity bound of one shard: whatever the schedule, a
/// single array must still execute every stage's compute body on its
/// PEs and stream every stage's gating DMA over its DDR channel, so no
/// overlap beats `fill + max(Σ body, Σ dma)`.  This is the
/// lower envelope `shard_time` converges to at large batch, and the
/// denominator-side reference for `pipeline_efficiency`.
fn capacity_bound(stages: &[StageCost], batch: usize, b_shard: usize) -> f64 {
    if b_shard == 0 {
        return 0.0;
    }
    let frac = b_shard as f64 / batch as f64;
    let fill0 = stages.first().map(|s| s.fill_s).unwrap_or(0.0);
    let body: f64 = stages.iter().map(|s| (s.serial_s - s.fill_s).max(0.0) * frac).sum();
    let dma: f64 = stages.iter().map(|s| s.dma_s * frac).sum();
    fill0 + body.max(dma)
}

/// Schedule a streamed batch over `cfg.arrays` replicated arrays under
/// `cfg.overlap`, from per-stage cost decompositions.
///
/// `idle_power_w` prices replicas that idle while the most-loaded shard
/// finishes (see [`crate::energy::idle_power_w`]).
pub fn schedule(
    stages: &[StageCost],
    batch: usize,
    cfg: PipelineConfig,
    idle_power_w: f64,
) -> OverlapEstimate {
    let arrays = cfg.arrays.max(1);
    let batch = batch.max(1);
    let serial_time_s: f64 = stages.iter().map(|s| s.serial_s).sum();
    // Static partitioner: `hi` arrays take `ceil(batch/arrays)` elements,
    // the rest take the floor (possibly zero when batch < arrays).
    let b_hi = batch.div_ceil(arrays);
    let b_lo = batch / arrays;
    let n_hi = if b_hi == b_lo { arrays } else { batch - b_lo * arrays };
    let n_lo = arrays - n_hi;
    let t_hi = shard_time(stages, batch, b_hi, cfg.overlap);
    let t_lo = shard_time(stages, batch, b_lo, cfg.overlap);
    // Shard times are monotone in shard size, so the makespan is the
    // most-loaded array's.  The final clamp makes `overlapped ≤ serial`
    // exact (not merely up-to-rounding: the scaled per-stage components
    // re-sum in a different float order than the serial reference).
    let overlapped_time_s = t_hi.max(t_lo).min(serial_time_s);
    let idle_energy_j = idle_power_w
        * ((overlapped_time_s - t_hi).max(0.0) * n_hi as f64
            + (overlapped_time_s - t_lo).max(0.0) * n_lo as f64);
    let bound = capacity_bound(stages, batch, b_hi.max(b_lo));
    let pipeline_efficiency = if overlapped_time_s > 0.0 && bound > 0.0 {
        (bound / overlapped_time_s).min(1.0)
    } else {
        1.0
    };
    OverlapEstimate {
        overlap: cfg.overlap,
        arrays,
        serial_time_s,
        overlapped_time_s,
        pipeline_efficiency,
        idle_energy_j,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stages() -> Vec<StageCost> {
        vec![
            StageCost { serial_s: 4.0e-3, fill_s: 0.2e-3, dma_s: 1.0e-3 },
            StageCost { serial_s: 2.0e-3, fill_s: 0.1e-3, dma_s: 2.5e-3 },
            StageCost { serial_s: 1.0e-3, fill_s: 0.1e-3, dma_s: 0.2e-3 },
        ]
    }

    #[test]
    fn none_single_array_is_the_exact_serial_sum() {
        let st = stages();
        let serial: f64 = st.iter().map(|s| s.serial_s).sum();
        let est = schedule(&st, 16, PipelineConfig::default(), 1.0);
        assert_eq!(est.overlapped_time_s, serial);
        assert_eq!(est.serial_time_s, serial);
        assert_eq!(est.idle_energy_j, 0.0);
        assert!(est.pipeline_efficiency > 0.0 && est.pipeline_efficiency <= 1.0);
    }

    #[test]
    fn mode_ordering_pipeline_dma_none() {
        let st = stages();
        for batch in [1usize, 2, 7, 64] {
            for arrays in [1usize, 2, 3] {
                let t = |o| {
                    schedule(&st, batch, PipelineConfig::new(o, arrays), 1.0).overlapped_time_s
                };
                let (n, d, p) = (t(Overlap::None), t(Overlap::Dma), t(Overlap::Pipeline));
                assert!(p <= d + 1e-15, "batch {batch} arrays {arrays}: {p} > {d}");
                assert!(d <= n + 1e-15, "batch {batch} arrays {arrays}: {d} > {n}");
                assert!(p > 0.0);
            }
        }
    }

    #[test]
    fn dma_bound_stage_never_beats_its_serial_time() {
        // A stage whose DDR stream dwarfs both compute and its serial
        // time must clamp at the serial time, not balloon past it.
        let st = vec![
            StageCost { serial_s: 1.0e-3, fill_s: 0.3e-3, dma_s: 5.0e-3 },
            StageCost { serial_s: 1.0e-3, fill_s: 0.3e-3, dma_s: 5.0e-3 },
        ];
        let serial: f64 = st.iter().map(|s| s.serial_s).sum();
        for o in [Overlap::Dma, Overlap::Pipeline] {
            let est = schedule(&st, 1, PipelineConfig::new(o, 1), 1.0);
            assert!(
                est.overlapped_time_s <= serial + 1e-15,
                "{o:?}: {} > {serial}",
                est.overlapped_time_s
            );
        }
    }

    #[test]
    fn sharding_splits_work_and_charges_idle_replicas() {
        let st = stages();
        let one = schedule(&st, 64, PipelineConfig::new(Overlap::Pipeline, 1), 2.0);
        let four = schedule(&st, 64, PipelineConfig::new(Overlap::Pipeline, 4), 2.0);
        assert!(four.overlapped_time_s < one.overlapped_time_s);
        // 64 / 4 splits evenly: no replica idles.
        assert_eq!(four.idle_energy_j, 0.0);
        // 64 / 3 does not: the floor shards idle at the end.
        let three = schedule(&st, 64, PipelineConfig::new(Overlap::Pipeline, 3), 2.0);
        assert!(three.idle_energy_j > 0.0);
        // More arrays than elements: surplus replicas idle for the whole
        // makespan.
        let surplus = schedule(&st, 2, PipelineConfig::new(Overlap::Pipeline, 4), 2.0);
        assert!(surplus.idle_energy_j > 0.0);
        assert!(surplus.overlapped_time_s > 0.0);
    }

    #[test]
    fn efficiency_in_unit_interval_and_speedup_at_least_one() {
        let st = stages();
        for batch in [1usize, 3, 256] {
            for arrays in [1usize, 2, 5] {
                for o in [Overlap::None, Overlap::Dma, Overlap::Pipeline] {
                    let est = schedule(&st, batch, PipelineConfig::new(o, arrays), 1.0);
                    assert!(
                        est.pipeline_efficiency > 0.0 && est.pipeline_efficiency <= 1.0,
                        "{o:?} b{batch} a{arrays}: eff {}",
                        est.pipeline_efficiency
                    );
                    assert!(
                        est.speedup() >= 1.0 - 1e-12,
                        "{o:?} b{batch} a{arrays}: speedup {}",
                        est.speedup()
                    );
                }
            }
        }
    }

    #[test]
    fn deep_pipeline_reaches_the_capacity_bound() {
        // At large batch the pipelined makespan converges to the
        // aggregate capacity bound (total compute body here, which
        // dominates the total gating DMA): efficiency → 1.
        let st = stages();
        let est = schedule(&st, 4096, PipelineConfig::new(Overlap::Pipeline, 1), 1.0);
        assert!(est.pipeline_efficiency > 0.95, "eff {}", est.pipeline_efficiency);
        // The makespan itself sits at fill + Σ body (6.6 ms) — not at
        // the physically impossible per-element bottleneck (≈ 3.8 ms),
        // which would let one array outrun its own PE budget.
        let body: f64 = st.iter().map(|s| s.serial_s - s.fill_s).sum();
        let fill0 = st[0].fill_s;
        assert!(
            est.overlapped_time_s >= fill0 + body - 1e-15,
            "makespan {} undercut the capacity bound {}",
            est.overlapped_time_s,
            fill0 + body
        );
    }

    #[test]
    fn overlap_parse_roundtrip() {
        for o in [Overlap::None, Overlap::Dma, Overlap::Pipeline] {
            assert_eq!(Overlap::parse(o.name()).unwrap(), o);
        }
        assert!(Overlap::parse("both").is_err());
    }
}
