//! Cross-session structural result store: stage-window measurements
//! keyed by *structure*, shared across [`super::session::Session`]
//! instances and optionally persisted for `--resume` sweeps.
//!
//! The per-session stage cache (PR 1) already deduplicates within one
//! session, but the sweep-shaped workloads of PRs 7–8 build *many*
//! sessions over the same architecture: the autotuner's session pool is
//! re-created per sweep invocation, `Strategy::Auto` probes rebuild the
//! same stage programs per session, and a resumed `--resume` run with a
//! slightly larger grid re-simulates every stage its journal does not
//! cover.  All of those are structural near-misses: the lowered program
//! of a stage window is fully determined by
//! `(kind, points, twiddle/ddr-weight flags, window, pack, mapping id)`
//! plus the architecture and simulator options — nothing session-local.
//!
//! [`StructuralStore`] memoizes exactly that function.  It sits *under*
//! the per-session stage cache: a session's stage-cache miss consults
//! the store before lowering, so a second session over the same
//! configuration pays zero lowerings.  Concurrent misses on one key
//! coalesce behind a per-key fill cell (the session plan-cache
//! pattern), which also keeps hit/miss counters deterministic under
//! parallel execution — load-bearing for CI's byte-identity smoke
//! gates.
//!
//! Persistence mirrors the autotune [`super::autotune::Journal`]: a
//! JSON-lines file whose first line is the header
//! `{"store":"bfdf-structural","version":1}` and whose every other line
//! is one measurement (the full key plus the complete [`SimStats`]).
//! Appends are flushed per entry; torn records from a crash — tail or
//! mid-file — are skipped, counted ([`StructuralStore::torn`]) and
//! warned about once per open, while a header naming a different format
//! or version fails loudly; entries from other configurations are
//! harmless (their signatures simply never match).  Persistence is
//! best-effort by design: an I/O error on append costs future reuse,
//! never correctness — the in-memory entry is still served.

use std::collections::HashMap;
use std::fmt;
use std::io::Write as _;
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::dfg::graph::KernelKind;
use crate::sim::SimStats;
use crate::util::json::{self, Json};

/// One simulated stage-window measurement (shared via `Arc` across the
/// kernels, sessions and sweeps that reuse it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageMeasure {
    /// Compute slots (per lane) of the lowered window program.
    pub ops: u64,
    pub stats: SimStats,
}

/// Full structural identity of one stage-window simulation.
///
/// `sig` is the `(architecture, simulator options)` signature — built
/// field-by-field via [`crate::sim::SimOptions::signature`], never
/// `{:?}` — and the remaining fields mirror the session's stage-cache
/// key: everything [`crate::dfg::microcode::lower_stage_mapped`] and
/// the simulator read.  Keys differing in *any* field (notably the
/// mapping id — two strategies may share a stage shape but map PEs
/// differently) must never share an entry; pinned by
/// `rust/tests/parallel_structural.rs`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StructuralKey {
    /// Arch + sim-options signature the measurement was taken under.
    pub sig: Arc<str>,
    pub kind: KernelKind,
    pub points: usize,
    pub twiddle_before: bool,
    pub weights_from_ddr: bool,
    /// Simulated window (DFG iterations).
    pub window: usize,
    /// Inflight pack factor of the lowered program.
    pub pack: usize,
    /// The strategy's PE-mapping id (`DataflowStrategy::mapping_id`).
    pub mapping: String,
}

/// A per-key fill cell: concurrent misses on one key coalesce behind
/// the cell's lock, so every distinct key is simulated exactly once and
/// counts exactly one miss no matter the thread interleaving.
type Cell = Arc<Mutex<Option<Arc<StageMeasure>>>>;

/// The shared structure-keyed measurement store.  All methods take
/// `&self`; one `Arc<StructuralStore>` can back any number of sessions
/// concurrently.
pub struct StructuralStore {
    entries: Mutex<HashMap<StructuralKey, Cell>>,
    sink: Option<Mutex<std::fs::File>>,
    loaded: usize,
    torn: usize,
}

/// Validate the first line of a JSON-lines checkpoint against its
/// expected header.
///
/// Returns `Ok(true)` when the line is this file kind's header (right
/// marker key, compatible version) and should be consumed, `Ok(false)`
/// when it is no header at all (a torn write, or a data line from a
/// headerless legacy file — the caller's record loop deals with it),
/// and a loud error when the file positively identifies as a different
/// format or version: silently skipping every record would masquerade
/// as an empty cache, and silently accepting them could replay numbers
/// a newer schema encodes differently.
pub(crate) fn check_jsonl_header(
    line: &str,
    path: &str,
    kind_key: &str,
    kind_val: &str,
    sibling_key: &str,
    version: f64,
) -> Result<bool> {
    let Ok(j) = json::parse(line) else { return Ok(false) };
    if let Some(other) = j.get(sibling_key).and_then(Json::as_str) {
        bail!(
            "'{path}' is a '{other}' {sibling_key} file, not a '{kind_val}' {kind_key} \
             — point --{kind_key} and --{sibling_key} at different paths"
        );
    }
    let Some(name) = j.get(kind_key).and_then(Json::as_str) else {
        return Ok(false);
    };
    ensure!(
        name == kind_val,
        "'{path}' is a '{name}' {kind_key} file, not '{kind_val}'"
    );
    let v = j.get("version").and_then(Json::as_f64).unwrap_or(f64::NAN);
    ensure!(
        v == version,
        "'{path}' has {kind_key} format version {v} but this build reads version \
         {version}; delete the file (or drop --resume) to regenerate it"
    );
    Ok(true)
}

impl fmt::Debug for StructuralStore {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("StructuralStore")
            .field("entries", &self.entries.lock().map(|m| m.len()).unwrap_or(0))
            .field("loaded", &self.loaded)
            .field("persistent", &self.sink.is_some())
            .finish()
    }
}

impl Default for StructuralStore {
    fn default() -> Self {
        Self::new()
    }
}

impl StructuralStore {
    /// In-memory store (no persistence).
    pub fn new() -> StructuralStore {
        StructuralStore { entries: Mutex::new(HashMap::new()), sink: None, loaded: 0, torn: 0 }
    }

    /// Open `path` for persistence.  With `resume`, previously recorded
    /// measurements are loaded and new ones appended; otherwise the
    /// file is truncated.  Loading is torn-write robust: any record a
    /// crashed run left unparseable — mid-file or tail — is skipped and
    /// counted ([`Self::torn`], one warning per open), while a header
    /// naming the wrong format or version fails loudly instead of
    /// masquerading as an empty cache.
    pub fn open(path: &str, resume: bool) -> Result<StructuralStore> {
        let mut entries = HashMap::new();
        let mut torn = 0usize;
        if resume {
            if let Ok(text) = std::fs::read_to_string(path) {
                let mut lines = text.lines().peekable();
                if let Some(&first) = lines.peek() {
                    if check_jsonl_header(first, path, "store", "bfdf-structural", "journal", 1.0)?
                    {
                        lines.next();
                    }
                }
                for line in lines {
                    let Ok(j) = json::parse(line) else {
                        torn += 1;
                        continue;
                    };
                    let Some((key, m)) = entry_from_json(&j) else {
                        torn += 1;
                        continue;
                    };
                    entries.insert(key, Arc::new(Mutex::new(Some(Arc::new(m)))) as Cell);
                }
                if torn > 0 {
                    eprintln!(
                        "warning: structural store '{path}': skipped {torn} torn or \
                         malformed record(s) left by a crashed run"
                    );
                }
            }
        }
        let loaded = entries.len();
        let mut file = if resume {
            std::fs::OpenOptions::new().create(true).append(true).open(path)
        } else {
            std::fs::File::create(path)
        }
        .with_context(|| format!("opening structural store '{path}'"))?;
        if !resume || file.metadata().map(|m| m.len() == 0).unwrap_or(false) {
            let header = json::obj(vec![
                ("store", json::s("bfdf-structural")),
                ("version", json::num(1.0)),
            ]);
            writeln!(file, "{}", header.render())
                .with_context(|| format!("writing structural store header to '{path}'"))?;
        }
        Ok(StructuralStore { entries, sink: Some(Mutex::new(file)), loaded, torn })
    }

    /// Entries loaded from disk at open time.
    pub fn loaded(&self) -> usize {
        self.loaded
    }

    /// Torn or malformed records skipped while loading at open time.
    pub fn torn(&self) -> usize {
        self.torn
    }

    /// Distinct measurements currently held.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look a measurement up without filling (tests, diagnostics).
    /// `None` for unknown keys *and* keys whose fill is still in
    /// flight on another thread.
    pub fn lookup(&self, key: &StructuralKey) -> Option<Arc<StageMeasure>> {
        let cell = self.entries.lock().unwrap().get(key)?.clone();
        let slot = cell.lock().unwrap();
        slot.clone()
    }

    /// Return the measurement for `key`, computing it with `fill` on a
    /// miss.  The boolean is `true` on a hit.  Concurrent callers on
    /// one key serialize on the key's cell (other keys proceed in
    /// parallel), so `fill` runs exactly once per distinct key and the
    /// hit/miss accounting is deterministic.
    pub fn get_or_fill(
        &self,
        key: &StructuralKey,
        fill: impl FnOnce() -> Arc<StageMeasure>,
    ) -> (Arc<StageMeasure>, bool) {
        let cell = {
            let mut map = self.entries.lock().unwrap();
            map.entry(key.clone()).or_default().clone()
        };
        let mut slot = cell.lock().unwrap();
        if let Some(m) = slot.as_ref() {
            return (m.clone(), true);
        }
        let m = fill();
        *slot = Some(m.clone());
        if let Some(sink) = &self.sink {
            // Best-effort append: an I/O failure only forfeits reuse in
            // a later --resume run, never this run's result.
            let line = entry_to_json(key, &m).render();
            let mut file = sink.lock().unwrap();
            let _ = writeln!(file, "{line}");
            let _ = file.flush();
        }
        (m, false)
    }
}

fn kind_from_name(name: &str) -> Option<KernelKind> {
    match name {
        "FFT" => Some(KernelKind::Fft),
        "BPMM" => Some(KernelKind::Bpmm),
        _ => None,
    }
}

/// Serialize one `(key, measure)` entry.  Every [`SimStats`] field is
/// carried — including the per-PE busy vectors and the per-iteration
/// completion times the windowed extrapolation reads — so a reloaded
/// measurement reproduces downstream metrics bit-for-bit (all fields
/// are integral and far below 2^53, so the JSON f64 codec is exact).
fn entry_to_json(key: &StructuralKey, m: &StageMeasure) -> Json {
    let st = &m.stats;
    json::obj(vec![
        ("sig", json::s(&key.sig)),
        ("kind", json::s(key.kind.name())),
        ("points", json::num(key.points as f64)),
        ("twiddle", Json::Bool(key.twiddle_before)),
        ("ddr_weights", Json::Bool(key.weights_from_ddr)),
        ("window", json::num(key.window as f64)),
        ("pack", json::num(key.pack as f64)),
        ("mapping", json::s(&key.mapping)),
        ("ops", json::num(m.ops as f64)),
        ("cycles", json::num(st.cycles as f64)),
        (
            "unit_busy",
            json::arr(st.unit_busy.iter().map(|&v| json::num(v as f64)).collect()),
        ),
        (
            "unit_busy_per_pe",
            json::arr(
                st.unit_busy_per_pe
                    .iter()
                    .map(|pe| json::arr(pe.iter().map(|&v| json::num(v as f64)).collect()))
                    .collect(),
            ),
        ),
        ("spm_scalars", json::num(st.spm_scalars as f64)),
        ("noc_scalars", json::num(st.noc_scalars as f64)),
        ("spm_port_busy", json::num(st.spm_port_busy as f64)),
        ("dma_bytes", json::num(st.dma_bytes as f64)),
        ("dma_weight_bytes", json::num(st.dma_weight_bytes as f64)),
        ("dma_in_bytes", json::num(st.dma_in_bytes as f64)),
        ("dma_fill_cycles", json::num(st.dma_fill_cycles as f64)),
        (
            "iter_done",
            json::arr(st.iter_done.iter().map(|&v| json::num(v as f64)).collect()),
        ),
        ("blocks_run", json::num(st.blocks_run as f64)),
        ("active_pes", json::num(st.active_pes as f64)),
    ])
}

fn entry_from_json(j: &Json) -> Option<(StructuralKey, StageMeasure)> {
    let u64_of = |field: &str| -> Option<u64> { Some(j.get(field)?.as_f64()? as u64) };
    let key = StructuralKey {
        sig: Arc::from(j.get("sig")?.as_str()?),
        kind: kind_from_name(j.get("kind")?.as_str()?)?,
        points: j.get("points")?.as_usize()?,
        twiddle_before: matches!(j.get("twiddle")?, Json::Bool(true)),
        weights_from_ddr: matches!(j.get("ddr_weights")?, Json::Bool(true)),
        window: j.get("window")?.as_usize()?,
        pack: j.get("pack")?.as_usize()?,
        mapping: j.get("mapping")?.as_str()?.to_string(),
    };
    let mut unit_busy = [0u64; 4];
    let ub = j.get("unit_busy")?.as_arr()?;
    if ub.len() != 4 {
        return None;
    }
    for (slot, v) in unit_busy.iter_mut().zip(ub) {
        *slot = v.as_f64()? as u64;
    }
    let mut unit_busy_per_pe = Vec::new();
    for pe in j.get("unit_busy_per_pe")?.as_arr()? {
        let row = pe.as_arr()?;
        if row.len() != 4 {
            return None;
        }
        let mut out = [0u64; 4];
        for (slot, v) in out.iter_mut().zip(row) {
            *slot = v.as_f64()? as u64;
        }
        unit_busy_per_pe.push(out);
    }
    let mut iter_done = Vec::new();
    for v in j.get("iter_done")?.as_arr()? {
        iter_done.push(v.as_f64()? as u64);
    }
    let stats = SimStats {
        cycles: u64_of("cycles")?,
        unit_busy,
        unit_busy_per_pe,
        spm_scalars: u64_of("spm_scalars")?,
        noc_scalars: u64_of("noc_scalars")?,
        spm_port_busy: u64_of("spm_port_busy")?,
        dma_bytes: u64_of("dma_bytes")?,
        dma_weight_bytes: u64_of("dma_weight_bytes")?,
        dma_in_bytes: u64_of("dma_in_bytes")?,
        dma_fill_cycles: u64_of("dma_fill_cycles")?,
        iter_done,
        blocks_run: u64_of("blocks_run")?,
        active_pes: j.get("active_pes")?.as_usize()?,
    };
    Some((key, StageMeasure { ops: u64_of("ops")?, stats }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(mapping: &str) -> StructuralKey {
        StructuralKey {
            sig: Arc::from("arch|nomlspm0|fifo0"),
            kind: KernelKind::Fft,
            points: 256,
            twiddle_before: false,
            weights_from_ddr: true,
            window: 48,
            pack: 2,
            mapping: mapping.to_string(),
        }
    }

    fn measure(cycles: u64) -> Arc<StageMeasure> {
        Arc::new(StageMeasure {
            ops: 7 * cycles,
            stats: SimStats {
                cycles,
                unit_busy: [1, 2, 3, 4],
                unit_busy_per_pe: vec![[1, 0, 0, 0], [0, 2, 3, 4]],
                spm_scalars: 10,
                noc_scalars: 11,
                spm_port_busy: 12,
                dma_bytes: 13,
                dma_weight_bytes: 5,
                dma_in_bytes: 8,
                dma_fill_cycles: 9,
                iter_done: vec![3, 6, 9, 12],
                blocks_run: 20,
                active_pes: 2,
            },
        })
    }

    #[test]
    fn fill_once_then_hit() {
        let store = StructuralStore::new();
        let mut fills = 0;
        let (a, hit) = store.get_or_fill(&key("round-robin"), || {
            fills += 1;
            measure(100)
        });
        assert!(!hit);
        let (b, hit) = store.get_or_fill(&key("round-robin"), || {
            fills += 1;
            measure(999)
        });
        assert!(hit);
        assert_eq!(fills, 1);
        assert_eq!(a, b);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn mapping_id_separates_entries() {
        // Two stages identical in everything but the mapping id must
        // not share an entry (the satellite collision-safety contract).
        let store = StructuralStore::new();
        let _ = store.get_or_fill(&key("round-robin"), || measure(100));
        assert!(store.lookup(&key("round-robin")).is_some());
        assert!(store.lookup(&key("column-major")).is_none());
        let (m, hit) = store.get_or_fill(&key("column-major"), || measure(200));
        assert!(!hit);
        assert_eq!(m.stats.cycles, 200);
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn entry_json_round_trips_every_stats_field() {
        let k = key("round-robin");
        let m = measure(12345);
        let j = entry_to_json(&k, &m);
        let parsed = json::parse(&j.render()).unwrap();
        let (k2, m2) = entry_from_json(&parsed).unwrap();
        assert_eq!(k, k2);
        assert_eq!(*m, m2);
    }

    #[test]
    fn persistence_round_trip_and_corrupt_tail() {
        let path = std::env::temp_dir()
            .join(format!("bfdf_structural_{}.jsonl", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        {
            let store = StructuralStore::open(&path, false).unwrap();
            let _ = store.get_or_fill(&key("round-robin"), || measure(42));
        }
        // Simulate a crash mid-append.
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"sig\":\"trunc").unwrap();
        }
        let store = StructuralStore::open(&path, true).unwrap();
        assert_eq!(store.loaded(), 1);
        assert_eq!(store.torn(), 1);
        let got = store.lookup(&key("round-robin")).unwrap();
        assert_eq!(*got, *measure(42));
        // Fresh open truncates.
        let store = StructuralStore::open(&path, false).unwrap();
        assert_eq!(store.loaded(), 0);
        assert_eq!(store.torn(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mid_file_torn_records_are_skipped_and_counted() {
        // A crash (or a partial filesystem sync) can tear a record in
        // the middle of the file, not just at the tail; the records
        // around it must still load.
        let path = std::env::temp_dir()
            .join(format!("bfdf_structural_torn_{}.jsonl", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let good_a = entry_to_json(&key("round-robin"), &measure(42)).render();
        let good_b = entry_to_json(&key("column-major"), &measure(77)).render();
        std::fs::write(
            &path,
            format!(
                "{}\n{}\n{{\"sig\":\"torn-mid\n{}\nnot json at all\n",
                r#"{"store":"bfdf-structural","version":1}"#,
                good_a, good_b
            ),
        )
        .unwrap();
        let store = StructuralStore::open(&path, true).unwrap();
        assert_eq!(store.loaded(), 2, "records around the tear must survive");
        assert_eq!(store.torn(), 2, "both the mid-file and the tail tear are counted");
        assert_eq!(store.lookup(&key("round-robin")).unwrap().stats.cycles, 42);
        assert_eq!(store.lookup(&key("column-major")).unwrap().stats.cycles, 77);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_header_fails_loudly() {
        let path = std::env::temp_dir()
            .join(format!("bfdf_structural_hdr_{}.jsonl", std::process::id()));
        let path = path.to_str().unwrap().to_string();

        // A future format version must not masquerade as an empty cache.
        std::fs::write(&path, "{\"store\":\"bfdf-structural\",\"version\":2}\n").unwrap();
        let err = StructuralStore::open(&path, true).unwrap_err().to_string();
        assert!(
            err.contains("version 2") && err.contains("version 1"),
            "unexpected error: {err}"
        );

        // An autotune journal is a different file kind, not torn data.
        std::fs::write(&path, "{\"journal\":\"bfdf-pareto\",\"version\":1}\n").unwrap();
        let err = StructuralStore::open(&path, true).unwrap_err().to_string();
        assert!(err.contains("bfdf-pareto"), "unexpected error: {err}");

        // Without --resume the file is truncated unread, so no error.
        let store = StructuralStore::open(&path, false).unwrap();
        assert_eq!(store.loaded(), 0);
        std::fs::remove_file(&path).ok();
    }
}
