//! Design-space autotuning: sweep the architecture, publish a Pareto
//! frontier (ROADMAP item 3).
//!
//! The paper evaluates one fixed design point; with the fast simulator
//! (PR 4) and the shared plan cache (PR 1) the experiment inverts: for
//! every workload class, which `{mesh, SIMD width, SPM capacity/ports,
//! DDR channels, inflight pack factor, replica arrays, dataflow
//! strategy}` combination is on the latency/energy/area frontier?
//! Three layers:
//!
//! 1. **Search space + pruning** — [`SearchSpace`] builds the grid over
//!    [`ArchConfig`] knobs (every candidate passes
//!    [`ArchConfig::validate`]).  Before any cycle-level simulation,
//!    two *provably sound* filters drop dominated points, and the
//!    dropped counts are reported — never silently capped:
//!    * *equal-shard*: for a batch of `B`, replicas `a1 < a2` with
//!      `ceil(B/a1) == ceil(B/a2)` run the identical per-shard
//!      schedule, so the larger design pays equal latency, at least as
//!      much energy (extra idle replicas) and strictly more area — it
//!      cannot reach the frontier.
//!    * *roofline*: analytic lower bounds on latency (dense roofline:
//!      `max(flops/peak, input bytes/DDR bw)`, scaled by the shard
//!      fraction, plus the exact analytic dense-block cost) and energy
//!      (idle power over the compute floor plus the FuncUnits dynamic
//!      floor, [`crate::energy::compute_energy_floor_j`]) are compared
//!      against the *measured* metrics of a few evaluated anchor
//!      points; a point whose bounds are already dominated by an
//!      anchor's actuals cannot be non-dominated.  Bounds carry a
//!      [`ROOFLINE_SLACK`] safety factor and prune-soundness is pinned
//!      by an exhaustive-grid test (`rust/tests/autotune.rs`).
//! 2. **Resumable parallel sweep driver** — [`sweep`] shards
//!    `(point, class)` evaluations across a `std::thread::scope` worker
//!    pool (the same pattern as `Session::run_many`, which each
//!    evaluation uses internally for its kernels).  Points that differ
//!    only in `arrays` — and all workload classes — share one
//!    [`Session`] per distinct architecture, so cross-point and
//!    cross-class plan-cache hits make the sweep affordable; the summed
//!    [`CacheStats`] are surfaced on [`AutotuneResult`].  Every
//!    completed evaluation is checkpointed to a JSON-lines [`Journal`]
//!    keyed by `(arch signature, arrays, model, batch, overlap)`;
//!    `--resume` replays completed entries instead of simulating.  The
//!    report is rebuilt in canonical enumeration order from either
//!    source — and the JSON float codec round-trips exactly — so a
//!    resumed run renders byte-identical to a fresh one.
//! 3. **Frontier + reporting** — per class, the non-dominated set over
//!    `(latency_s, energy_j, area_mm2)` (all minimized), where the
//!    paper's default design point lands, and the best point under a
//!    selectable [`Objective`].  Serialized via `Report::Pareto`
//!    (`BENCH_pareto.json`) and the `bfdf autotune` CLI tables.  The
//!    artifact deliberately excludes run-dependent fields (cache hits,
//!    journal hits) so fresh and resumed runs stay byte-identical;
//!    those live on the result struct and the text output.

use std::collections::HashMap;
use std::io::Write as _;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context};

use crate::arch::ArchConfig;
use crate::dfg::strategy::Strategy;
use crate::energy::{compute_energy_floor_j, design_area_mm2, idle_power_w};
use crate::sim::SimOptions;
use crate::util::json::{self, Json};
use crate::workloads::spec::{DenseCost, ModelSpec};
use crate::Result;

use super::network::eval_dense;
use super::pipeline::{Overlap, PipelineConfig};
use super::session::{CacheStats, Session};
use super::structural::StructuralStore;

/// Safety factor on roofline lower bounds.  The latency bound excludes
/// cold-start DMA fills (batch-independent, hidden by the pipeline
/// capacity bound) and the energy bound assumes unclamped peak-rate
/// utilization; the slack keeps both strictly below anything the
/// simulator can report even at the extrapolation's edges.  Smaller is
/// safer but prunes less.
pub const ROOFLINE_SLACK: f64 = 0.85;

// ---------------------------------------------------------------------------
// Search space
// ---------------------------------------------------------------------------

/// Grid of architecture knobs the autotuner sweeps.  Empty knob lists
/// are pinned to the base architecture's value at enumeration time, so
/// a space can perturb one axis at a time.
#[derive(Debug, Clone, Default)]
pub struct SearchSpace {
    /// PE mesh geometries `(rows, cols)`.
    pub mesh: Vec<(usize, usize)>,
    /// SIMD lanes per PE.
    pub simd: Vec<usize>,
    /// SPM capacity in KiB.
    pub spm_kib: Vec<usize>,
    /// SPM banks (= concurrently served ports).
    pub spm_banks: Vec<usize>,
    /// DDR channels (DMA bandwidth multiplier).
    pub ddr_channels: Vec<usize>,
    /// Iteration contexts resident per PE (the streaming pack factor).
    pub inflight: Vec<usize>,
    /// Replicated dataflow arrays the batch shards across.
    pub arrays: Vec<usize>,
    /// Dataflow strategies to sweep (empty = pin to [`Strategy::Paper`],
    /// keeping prior grids and journals byte-compatible).
    pub strategy: Vec<Strategy>,
}

impl SearchSpace {
    /// The built-in grid: 32 points spanning the paper's full and
    /// scaled designs on every axis the evaluation varies.
    pub fn default_grid() -> SearchSpace {
        SearchSpace {
            mesh: vec![(2, 2), (4, 4)],
            simd: vec![8, 32],
            spm_kib: vec![2048, 4096],
            spm_banks: vec![4],
            ddr_channels: vec![1, 2],
            inflight: vec![],
            arrays: vec![1, 2],
            strategy: vec![],
        }
    }

    /// Parse a space description:
    /// `mesh=2x2,4x4;simd=8,32;spm=2m,4m;ports=4;ddr=1,2;arrays=1,2`.
    /// SPM sizes take `k`/`m` suffixes (KiB without one); omitted knobs
    /// pin to the base architecture; `default` (or empty) is
    /// [`SearchSpace::default_grid`].
    pub fn parse(text: &str) -> Result<SearchSpace> {
        let text = text.trim();
        if text.is_empty() || text == "default" {
            return Ok(SearchSpace::default_grid());
        }
        let mut sp = SearchSpace::default();
        for term in text.split(';') {
            let term = term.trim();
            if term.is_empty() {
                continue;
            }
            let Some((knob, vals)) = term.split_once('=') else {
                bail!("search-space term '{term}' is not 'knob=v1,v2,...'");
            };
            let list = || -> Result<Vec<usize>> {
                vals.split(',').map(|t| parse_count(knob.trim(), t)).collect()
            };
            match knob.trim() {
                "mesh" => sp.mesh = vals.split(',').map(parse_mesh).collect::<Result<_>>()?,
                "simd" => sp.simd = list()?,
                "spm" => sp.spm_kib = vals.split(',').map(parse_kib).collect::<Result<_>>()?,
                "ports" | "banks" => sp.spm_banks = list()?,
                "ddr" => sp.ddr_channels = list()?,
                "inflight" | "pack" => sp.inflight = list()?,
                "arrays" => sp.arrays = list()?,
                "strategy" => {
                    sp.strategy =
                        vals.split(',').map(|t| Strategy::parse(t.trim())).collect::<Result<_>>()?
                }
                other => bail!(
                    "unknown search-space knob '{other}' \
                     (mesh | simd | spm | ports | ddr | inflight | arrays | strategy)"
                ),
            }
        }
        Ok(sp)
    }

    /// This space with empty knobs pinned to `base` and duplicate
    /// values removed (first occurrence wins) — the form [`sweep`]
    /// enumerates and [`SearchSpace::canonical`] renders.
    pub fn resolved(&self, base: &ArchConfig) -> SearchSpace {
        fn fill<T: PartialEq + Copy>(v: &[T], default: T) -> Vec<T> {
            let mut out: Vec<T> = Vec::new();
            for &x in v {
                if !out.contains(&x) {
                    out.push(x);
                }
            }
            if out.is_empty() {
                out.push(default);
            }
            out
        }
        SearchSpace {
            mesh: fill(&self.mesh, (base.mesh_rows, base.mesh_cols)),
            simd: fill(&self.simd, base.simd_width),
            spm_kib: fill(&self.spm_kib, base.spm_bytes / 1024),
            spm_banks: fill(&self.spm_banks, base.spm_banks),
            ddr_channels: fill(&self.ddr_channels, base.ddr_channels),
            inflight: fill(&self.inflight, base.inflight_iters),
            arrays: fill(&self.arrays, 1),
            strategy: fill(&self.strategy, Strategy::Paper),
        }
    }

    /// Canonical grammar string (of a resolved space) — stable across
    /// parse/render, stored in the report.  The `strategy` segment is
    /// rendered only when the axis departs from the pinned default
    /// (`[paper]`), so prior reports stay byte-identical.
    pub fn canonical(&self) -> String {
        let ints = |v: &[usize]| {
            v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
        };
        let mesh = self
            .mesh
            .iter()
            .map(|(r, c)| format!("{r}x{c}"))
            .collect::<Vec<_>>()
            .join(",");
        let spm = self
            .spm_kib
            .iter()
            .map(|&k| if k % 1024 == 0 { format!("{}m", k / 1024) } else { format!("{k}k") })
            .collect::<Vec<_>>()
            .join(",");
        let mut out = format!(
            "mesh={mesh};simd={};spm={spm};ports={};ddr={};inflight={};arrays={}",
            ints(&self.simd),
            ints(&self.spm_banks),
            ints(&self.ddr_channels),
            ints(&self.inflight),
            ints(&self.arrays),
        );
        if !self.strategy.is_empty() && self.strategy != [Strategy::Paper] {
            let names =
                self.strategy.iter().map(|s| s.name()).collect::<Vec<_>>().join(",");
            out.push_str(&format!(";strategy={names}"));
        }
        out
    }

    /// Grid size of the resolved space (before default-point injection).
    pub fn num_points(&self, base: &ArchConfig) -> usize {
        let sp = self.resolved(base);
        sp.mesh.len()
            * sp.simd.len()
            * sp.spm_kib.len()
            * sp.spm_banks.len()
            * sp.ddr_channels.len()
            * sp.inflight.len()
            * sp.arrays.len()
            * sp.strategy.len()
    }

    /// Enumerate the grid over `base` in fixed nested order
    /// (mesh → simd → spm → ports → ddr → inflight → arrays →
    /// strategy), validate every candidate, and inject the base design
    /// (`arrays = 1`, paper strategy) if the grid itself does not
    /// contain it — the frontier report always shows where the paper's
    /// default point lands.
    pub fn enumerate(&self, base: &ArchConfig) -> Result<Vec<DesignPoint>> {
        let sp = self.resolved(base);
        let base_sig = base.signature();
        let mut points = Vec::new();
        for &(rows, cols) in &sp.mesh {
            for &simd in &sp.simd {
                for &spm in &sp.spm_kib {
                    for &banks in &sp.spm_banks {
                        for &ddr in &sp.ddr_channels {
                            for &inflight in &sp.inflight {
                                let arch = ArchConfig {
                                    mesh_rows: rows,
                                    mesh_cols: cols,
                                    simd_width: simd,
                                    spm_bytes: spm * 1024,
                                    spm_banks: banks,
                                    ddr_channels: ddr,
                                    inflight_iters: inflight,
                                    ..base.clone()
                                };
                                arch.validate().with_context(|| {
                                    format!(
                                        "search-space point m{rows}x{cols}-s{simd}-spm{spm}k\
                                         -p{banks}-d{ddr}-i{inflight}"
                                    )
                                })?;
                                let is_base = arch.signature() == base_sig;
                                for &arrays in &sp.arrays {
                                    ensure!(arrays >= 1, "arrays must be >= 1 (got 0)");
                                    for &strategy in &sp.strategy {
                                        points.push(DesignPoint {
                                            id: point_id(&arch, arrays, strategy),
                                            arch: arch.clone(),
                                            arrays,
                                            strategy,
                                            is_default: is_base
                                                && arrays == 1
                                                && strategy == Strategy::Paper,
                                        });
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        if !points.iter().any(|p| p.is_default) {
            base.validate().context("base architecture")?;
            points.push(DesignPoint {
                id: point_id(base, 1, Strategy::Paper),
                arch: base.clone(),
                arrays: 1,
                strategy: Strategy::Paper,
                is_default: true,
            });
        }
        Ok(points)
    }
}

fn parse_count(knob: &str, tok: &str) -> Result<usize> {
    let v: usize = tok
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad {knob} value '{}' (expected an integer)", tok.trim()))?;
    ensure!(v >= 1, "{knob} values must be >= 1 (got {v})");
    Ok(v)
}

fn parse_mesh(tok: &str) -> Result<(usize, usize)> {
    let t = tok.trim();
    let parse = |s: &str| s.parse::<usize>().ok().filter(|&v| v >= 1);
    if let Some((r, c)) = t.split_once('x') {
        if let (Some(r), Some(c)) = (parse(r), parse(c)) {
            return Ok((r, c));
        }
    }
    bail!("bad mesh value '{t}' (expected RxC, e.g. 4x4)");
}

fn parse_kib(tok: &str) -> Result<usize> {
    let t = tok.trim().to_ascii_lowercase();
    let (digits, mult) = if let Some(p) = t.strip_suffix('m') {
        (p, 1024)
    } else if let Some(p) = t.strip_suffix('k') {
        (p, 1)
    } else {
        (t.as_str(), 1)
    };
    let v: usize = digits
        .parse()
        .map_err(|_| anyhow::anyhow!("bad spm size '{}' (KiB, or a k/m suffix)", tok.trim()))?;
    ensure!(v >= 1, "spm sizes must be >= 1 KiB (got {v})");
    Ok(v * mult)
}

fn point_id(arch: &ArchConfig, arrays: usize, strategy: Strategy) -> String {
    let mut id = format!(
        "m{}x{}-s{}-spm{}k-p{}-d{}-i{}-a{}",
        arch.mesh_rows,
        arch.mesh_cols,
        arch.simd_width,
        arch.spm_bytes / 1024,
        arch.spm_banks,
        arch.ddr_channels,
        arch.inflight_iters,
        arrays
    );
    // Paper points keep their historical ids; only alternatives are
    // suffixed (no collision: a non-paper point always carries one).
    if strategy != Strategy::Paper {
        id.push_str(&format!("-st{}", strategy.name()));
    }
    id
}

/// One candidate design: an architecture plus its replica count and
/// dataflow strategy.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    /// Stable knob-derived identifier, e.g. `m4x4-s32-spm4096k-p4-d2-i4-a1`
    /// (with an `-st<name>` suffix for non-paper strategies).
    pub id: String,
    pub arch: ArchConfig,
    pub arrays: usize,
    /// Dataflow strategy the point's sessions lower with.
    pub strategy: Strategy,
    /// Whether this is the paper's base design point (never pruned).
    pub is_default: bool,
}

// ---------------------------------------------------------------------------
// Workload classes, objectives, metrics
// ---------------------------------------------------------------------------

/// One workload class swept against every design point.
#[derive(Debug, Clone)]
pub struct WorkloadClass {
    /// Display name (suite name or spec string).
    pub name: String,
    pub model: ModelSpec,
    /// Lowering batch (resolved; never 0).
    pub batch: usize,
}

impl WorkloadClass {
    /// Resolve workload keys (suite names and/or spec strings) into
    /// classes, applying an optional batch override to all of them.
    pub fn resolve(keys: &[String], batch: Option<usize>) -> Result<Vec<WorkloadClass>> {
        ensure!(batch != Some(0), "autotune batch must be >= 1 (got 0)");
        keys.iter()
            .map(|key| {
                let model = crate::workloads::resolve_model(key)?;
                let batch = batch.unwrap_or_else(|| model.default_batch());
                Ok(WorkloadClass { name: key.clone(), model, batch })
            })
            .collect()
    }
}

/// Ranking objective for the per-class "best point" callout (the
/// frontier itself is always the full 3-axis non-dominated set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Latency,
    Energy,
    Area,
    Efficiency,
    /// Energy-delay product (`latency_s * energy_j`), the default.
    Edp,
}

impl Objective {
    pub fn name(self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Energy => "energy",
            Objective::Area => "area",
            Objective::Efficiency => "efficiency",
            Objective::Edp => "edp",
        }
    }

    pub fn parse(s: &str) -> Result<Objective> {
        match s {
            "latency" => Ok(Objective::Latency),
            "energy" => Ok(Objective::Energy),
            "area" => Ok(Objective::Area),
            "efficiency" => Ok(Objective::Efficiency),
            "edp" => Ok(Objective::Edp),
            other => bail!(
                "unknown objective '{other}' (latency | energy | area | efficiency | edp)"
            ),
        }
    }

    /// Scalar score, lower is better.
    pub fn score(self, m: &Metrics) -> f64 {
        match self {
            Objective::Latency => m.latency_s,
            Objective::Energy => m.energy_j,
            Objective::Area => m.area_mm2,
            Objective::Efficiency => -m.efficiency,
            Objective::Edp => m.latency_s * m.energy_j,
        }
    }
}

/// Measured (or journal-replayed) metrics of one `(point, class)`
/// evaluation.  Latency/energy/efficiency/throughput/power come from
/// the cycle-level [`Session::run_network_with`] schedule; area is the
/// analytic [`design_area_mm2`] times the replica count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Metrics {
    pub latency_s: f64,
    pub energy_j: f64,
    pub area_mm2: f64,
    pub efficiency: f64,
    pub throughput: f64,
    pub power_w: f64,
}

impl Metrics {
    fn to_json_pairs(self) -> Vec<(&'static str, Json)> {
        vec![
            ("latency_s", json::num(self.latency_s)),
            ("energy_j", json::num(self.energy_j)),
            ("area_mm2", json::num(self.area_mm2)),
            ("efficiency", json::num(self.efficiency)),
            ("throughput", json::num(self.throughput)),
            ("power_w", json::num(self.power_w)),
        ]
    }

    fn from_json(j: &Json) -> Option<Metrics> {
        Some(Metrics {
            latency_s: j.get("latency_s")?.as_f64()?,
            energy_j: j.get("energy_j")?.as_f64()?,
            area_mm2: j.get("area_mm2")?.as_f64()?,
            efficiency: j.get("efficiency")?.as_f64()?,
            throughput: j.get("throughput")?.as_f64()?,
            power_w: j.get("power_w")?.as_f64()?,
        })
    }
}

/// `a` Pareto-dominates `b` on (latency, energy, area): no worse on
/// every axis, strictly better on at least one.
pub fn dominates(a: &Metrics, b: &Metrics) -> bool {
    a.latency_s <= b.latency_s
        && a.energy_j <= b.energy_j
        && a.area_mm2 <= b.area_mm2
        && (a.latency_s < b.latency_s || a.energy_j < b.energy_j || a.area_mm2 < b.area_mm2)
}

// ---------------------------------------------------------------------------
// Roofline lower bounds
// ---------------------------------------------------------------------------

/// Batch-lowered analytic costs of one class, shared by every point's
/// bound computation.
struct ClassCosts {
    /// Total butterfly-kernel FLOPs at the class batch.
    flops: f64,
    /// Scalar elements every kernel must stream in at least once.
    input_elems: f64,
    /// Dense blocks, priced exactly per point via `eval_dense`.
    dense: Vec<DenseCost>,
}

fn class_costs(class: &WorkloadClass) -> ClassCosts {
    let mut flops = 0.0;
    let mut input_elems = 0.0;
    let mut dense = Vec::new();
    for block in class.model.lower(Some(class.batch)) {
        for k in &block.kernels {
            flops += k.sparse_flops();
            input_elems += (k.vectors as f64) * (k.points as f64);
        }
        if let Some(d) = block.dense {
            dense.push(d);
        }
    }
    ClassCosts { flops, input_elems, dense }
}

/// Analytic lower bounds on what any simulation of `point` over this
/// class can report.  Soundness argument per axis:
///
/// * latency — the pipeline capacity bound floors the per-shard
///   makespan at `max(Σ compute body, Σ gating DMA) × frac` plus dense
///   bodies; kernel bodies cannot beat `flops/peak` and gating DMA
///   cannot beat one input pass over the DDR interface (both slacked by
///   [`ROOFLINE_SLACK`]); dense bodies are priced by the exact same
///   `eval_dense` the evaluator uses.  `frac = ceil(B/arrays)/B` is the
///   widest shard every schedule must finish.
/// * energy — active kernel energy is at least idle power over the
///   compute floor plus the FuncUnits dynamic floor; dense energy is
///   exact; idle-replica energy only adds.
/// * area — exact (the same analytic model the evaluator reports).
fn lower_bounds(point: &DesignPoint, costs: &ClassCosts, batch: usize) -> Bounds {
    let arch = &point.arch;
    let frac = batch.div_ceil(point.arrays) as f64 / batch as f64;
    let mut dense_time = 0.0;
    let mut dense_energy = 0.0;
    for cost in &costs.dense {
        let d = eval_dense(arch, cost);
        dense_time += d.time_s;
        dense_energy += d.energy_j;
    }
    let compute_lb = ROOFLINE_SLACK * costs.flops / arch.peak_flops();
    let dma_lb = ROOFLINE_SLACK * costs.input_elems * arch.elem_bytes as f64 / arch.ddr_bw();
    Bounds {
        latency_s: (compute_lb + dense_time).max(dma_lb) * frac,
        energy_j: idle_power_w(arch) * compute_lb
            + ROOFLINE_SLACK * compute_energy_floor_j(arch, costs.flops)
            + dense_energy,
        area_mm2: design_area_mm2(arch) * point.arrays as f64,
    }
}

#[derive(Debug, Clone, Copy)]
struct Bounds {
    latency_s: f64,
    energy_j: f64,
    area_mm2: f64,
}

/// An evaluated anchor with actual metrics `a` proves a candidate with
/// lower bounds `lb` off the frontier when the actuals dominate even
/// the bounds (the candidate's real metrics can only be worse).
fn bounds_dominated(a: &Metrics, lb: &Bounds) -> bool {
    a.latency_s <= lb.latency_s
        && a.energy_j <= lb.energy_j
        && a.area_mm2 <= lb.area_mm2
        && (a.latency_s < lb.latency_s || a.energy_j < lb.energy_j || a.area_mm2 < lb.area_mm2)
}

// ---------------------------------------------------------------------------
// Journal (checkpoint/resume)
// ---------------------------------------------------------------------------

/// JSON-lines evaluation checkpoint.  Line 1 is the header
/// `{"journal":"bfdf-pareto","version":1}`; every other line is one
/// completed evaluation `{"key":..., latency_s, energy_j, area_mm2,
/// efficiency, throughput, power_w}`.  The journal is strictly a cache:
/// a resumed sweep looks up exactly the keys it was going to evaluate
/// and ignores everything else (stale entries from other grids are
/// harmless), appends are flushed per entry so a killed sweep loses at
/// most the evaluation in flight, and torn records from a crash — tail
/// or mid-file — are skipped, counted ([`Journal::torn`]) and warned
/// about once per open, while a header naming a different format or
/// version fails loudly instead of silently re-evaluating the grid.
pub struct Journal {
    entries: HashMap<String, Metrics>,
    sink: Option<Mutex<std::fs::File>>,
    loaded: usize,
    torn: usize,
}

impl Journal {
    /// Checkpoint-free journal (unit tests, throwaway sweeps).
    pub fn in_memory() -> Journal {
        Journal { entries: HashMap::new(), sink: None, loaded: 0, torn: 0 }
    }

    /// Open `path` for checkpointing.  With `resume`, completed entries
    /// are loaded and replayed; otherwise the file is truncated.
    pub fn open(path: &str, resume: bool) -> Result<Journal> {
        let mut entries = HashMap::new();
        let mut torn = 0usize;
        if resume {
            if let Ok(text) = std::fs::read_to_string(path) {
                let mut lines = text.lines().peekable();
                if let Some(&first) = lines.peek() {
                    if super::structural::check_jsonl_header(
                        first,
                        path,
                        "journal",
                        "bfdf-pareto",
                        "store",
                        1.0,
                    )? {
                        lines.next();
                    }
                }
                for line in lines {
                    let torn_record = (|| {
                        let j = json::parse(line).ok()?;
                        let key = j.get("key").and_then(Json::as_str)?;
                        let m = Metrics::from_json(&j)?;
                        entries.insert(key.to_string(), m);
                        Some(())
                    })()
                    .is_none();
                    if torn_record {
                        torn += 1;
                    }
                }
                if torn > 0 {
                    eprintln!(
                        "warning: journal '{path}': skipped {torn} torn or malformed \
                         record(s) left by a crashed run"
                    );
                }
            }
        }
        let loaded = entries.len();
        let mut file = if resume {
            std::fs::OpenOptions::new().create(true).append(true).open(path)
        } else {
            std::fs::File::create(path)
        }
        .with_context(|| format!("opening journal '{path}'"))?;
        if !resume || file.metadata().map(|m| m.len() == 0).unwrap_or(false) {
            let header = json::obj(vec![
                ("journal", json::s("bfdf-pareto")),
                ("version", json::num(1.0)),
            ]);
            writeln!(file, "{}", header.render())
                .with_context(|| format!("writing journal header to '{path}'"))?;
        }
        Ok(Journal { entries, sink: Some(Mutex::new(file)), loaded, torn })
    }

    /// Entries loaded from disk at open time.
    pub fn loaded(&self) -> usize {
        self.loaded
    }

    /// Torn or malformed records skipped while loading at open time.
    pub fn torn(&self) -> usize {
        self.torn
    }

    fn lookup(&self, key: &str) -> Option<Metrics> {
        self.entries.get(key).copied()
    }

    fn record(&self, key: &str, m: Metrics) -> Result<()> {
        if let Some(sink) = &self.sink {
            let mut pairs = vec![("key", json::s(key))];
            pairs.extend(m.to_json_pairs());
            let line = json::obj(pairs).render();
            let mut file = sink.lock().unwrap();
            writeln!(file, "{line}").context("appending to journal")?;
            file.flush().context("flushing journal")?;
        }
        Ok(())
    }
}

/// Journal key of one evaluation.  Replicates the session signature
/// (arch + simulator options + window + strategy) so a journal can
/// never replay an entry the current configuration would compute
/// differently; a format change simply misses and re-evaluates.  Paper
/// points keep the historical suffix-free key so old journals replay.
/// Simulator options embed via the explicit [`SimOptions::signature`]
/// (not `{:?}`), so renaming a field or changing the derive output
/// cannot silently alter — or accidentally preserve — the key.
fn eval_key(point: &DesignPoint, class: &WorkloadClass, cfg: &AutotuneConfig) -> String {
    let mut key = format!(
        "{}|{}|w{}|{}|a{}|{}|h{}|q{}|e{}|b{}",
        point.arch.signature(),
        SimOptions::default().signature(),
        cfg.window,
        cfg.overlap.name(),
        point.arrays,
        class.model.spec_string(),
        class.model.hidden(),
        class.model.seq(),
        class.model.heads(),
        class.batch
    );
    if point.strategy != Strategy::Paper {
        key.push_str(&format!("|st{}", point.strategy.name()));
    }
    key
}

// ---------------------------------------------------------------------------
// Sweep driver
// ---------------------------------------------------------------------------

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct AutotuneConfig {
    pub objective: Objective,
    /// Overlap mode every evaluation schedules with.
    pub overlap: Overlap,
    /// Simulation window (DFG iterations) of the per-arch sessions.
    pub window: usize,
    /// Batch override applied to every class (`None` = per-class default).
    pub batch: Option<usize>,
    /// Enable the shard/roofline pruner (reported, never silent).
    pub prune: bool,
    /// Structural result store every pool session shares (default: a
    /// fresh in-memory store per config).  Pass one opened with
    /// [`StructuralStore::open`] — or reuse one config across sweeps —
    /// and repeated sweeps over the same architectures pay only for
    /// genuinely novel stage structures (`bfdf autotune --store`).
    pub store: Arc<StructuralStore>,
    /// Worker threads of every pool session (0 = all available cores);
    /// kernels and stage windows shard across them.
    pub threads: usize,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        AutotuneConfig {
            objective: Objective::Edp,
            overlap: Overlap::Pipeline,
            window: 48,
            batch: None,
            prune: true,
            store: Arc::new(StructuralStore::new()),
            threads: 0,
        }
    }
}

/// One evaluated `(point, class)` pair.
#[derive(Debug, Clone, Copy)]
pub struct PointEval {
    /// Index into [`AutotuneResult::points`].
    pub point: usize,
    pub metrics: Metrics,
}

/// Sweep outcome for one workload class.
#[derive(Debug, Clone)]
pub struct ClassSweep {
    pub name: String,
    pub spec: String,
    pub batch: usize,
    /// Evaluated points in canonical enumeration order.
    pub evals: Vec<PointEval>,
    /// Indices into `evals` of the non-dominated set, latency-ascending.
    pub frontier: Vec<usize>,
    /// Index into `evals` of the paper's default design point.
    pub default_eval: usize,
    /// Index into `evals` of the best point under the objective.
    pub best_eval: usize,
    pub pruned_shard: usize,
    pub pruned_roofline: usize,
}

impl ClassSweep {
    /// Whether the default design point made the frontier.
    pub fn default_on_frontier(&self) -> bool {
        self.frontier.contains(&self.default_eval)
    }
}

/// Full autotune result.  `journal_hits` and `cache` are run-dependent
/// diagnostics — surfaced by the CLI text output and tests but excluded
/// from the JSON artifact, which must be byte-identical between fresh
/// and resumed runs.
#[derive(Debug, Clone)]
pub struct AutotuneResult {
    /// Base architecture signature the space perturbs.
    pub base_arch: String,
    /// Canonical resolved search-space grammar.
    pub space: String,
    pub objective: Objective,
    pub overlap: Overlap,
    pub window: usize,
    pub points: Vec<DesignPoint>,
    pub classes: Vec<ClassSweep>,
    /// Cycle-level evaluations performed or replayed.
    pub evaluated: usize,
    pub pruned_shard: usize,
    pub pruned_roofline: usize,
    /// Evaluations replayed from the journal this run.
    pub journal_hits: usize,
    /// Summed plan-cache statistics across every per-arch session.
    pub cache: CacheStats,
}

impl AutotuneResult {
    /// Total `(point, class)` grid size before pruning.
    pub fn units_total(&self) -> usize {
        self.points.len() * self.classes.len()
    }

    /// JSON form of the artifact (`Report::Pareto` delegates here).
    pub fn to_json(&self) -> Json {
        let classes = self
            .classes
            .iter()
            .map(|c| {
                let point_obj = |e: &PointEval| {
                    let p = &self.points[e.point];
                    let mut pairs = vec![
                        ("id", json::s(&p.id)),
                        ("mesh", json::s(&format!("{}x{}", p.arch.mesh_rows, p.arch.mesh_cols))),
                        ("simd", json::num(p.arch.simd_width as f64)),
                        ("spm_kib", json::num((p.arch.spm_bytes / 1024) as f64)),
                        ("spm_banks", json::num(p.arch.spm_banks as f64)),
                        ("ddr_channels", json::num(p.arch.ddr_channels as f64)),
                        ("inflight", json::num(p.arch.inflight_iters as f64)),
                        ("arrays", json::num(p.arrays as f64)),
                    ];
                    // Keep paper-only artifacts byte-identical to prior
                    // releases; the axis shows up only when swept.
                    if p.strategy != Strategy::Paper {
                        pairs.push(("strategy", json::s(p.strategy.name())));
                    }
                    pairs.extend(e.metrics.to_json_pairs());
                    json::obj(pairs)
                };
                let frontier = c.frontier.iter().map(|&i| point_obj(&c.evals[i])).collect();
                let default = {
                    let Json::Obj(mut m) = point_obj(&c.evals[c.default_eval]) else {
                        unreachable!("point_obj builds an object")
                    };
                    m.insert("on_frontier".to_string(), Json::Bool(c.default_on_frontier()));
                    Json::Obj(m)
                };
                json::obj(vec![
                    ("class", json::s(&c.name)),
                    ("spec", json::s(&c.spec)),
                    ("batch", json::num(c.batch as f64)),
                    ("evaluated", json::num(c.evals.len() as f64)),
                    ("pruned_shard", json::num(c.pruned_shard as f64)),
                    ("pruned_roofline", json::num(c.pruned_roofline as f64)),
                    ("frontier", json::arr(frontier)),
                    ("default_point", default),
                    ("best", point_obj(&c.evals[c.best_eval])),
                ])
            })
            .collect();
        json::obj(vec![
            ("report", json::s("pareto")),
            ("base_arch", json::s(&self.base_arch)),
            ("space", json::s(&self.space)),
            ("objective", json::s(self.objective.name())),
            ("overlap", json::s(self.overlap.name())),
            ("window", json::num(self.window as f64)),
            ("points_total", json::num(self.points.len() as f64)),
            ("evaluations_total", json::num(self.units_total() as f64)),
            ("evaluated", json::num(self.evaluated as f64)),
            ("pruned_shard", json::num(self.pruned_shard as f64)),
            ("pruned_roofline", json::num(self.pruned_roofline as f64)),
            ("classes", json::arr(classes)),
        ])
    }
}

/// Lazily-built per-`(architecture, strategy)` sessions shared by every
/// worker: all classes and every point that differs only in `arrays`
/// hit the same plan cache.  Strategy is part of the pool key — a
/// cross-strategy session share would be a correctness bug (the plan
/// cache keys on strategy, but `Session::strategy` is fixed at build).
struct SessionPool {
    window: usize,
    store: Arc<StructuralStore>,
    threads: usize,
    sessions: Mutex<HashMap<(String, Strategy), Arc<Session>>>,
}

impl SessionPool {
    fn new(window: usize, store: Arc<StructuralStore>, threads: usize) -> SessionPool {
        SessionPool { window, store, threads, sessions: Mutex::new(HashMap::new()) }
    }

    fn get(&self, arch: &ArchConfig, strategy: Strategy) -> Arc<Session> {
        let mut map = self.sessions.lock().unwrap();
        map.entry((arch.signature(), strategy))
            .or_insert_with(|| {
                Arc::new(
                    Session::builder()
                        .arch(arch.clone())
                        .window(self.window)
                        .strategy(strategy)
                        .structural_store(self.store.clone())
                        .threads(self.threads)
                        .build(),
                )
            })
            .clone()
    }

    fn cache_stats(&self) -> CacheStats {
        let map = self.sessions.lock().unwrap();
        let mut total = CacheStats::default();
        for session in map.values() {
            let s = session.cache_stats();
            total.plan_hits += s.plan_hits;
            total.plan_misses += s.plan_misses;
            total.stage_hits += s.stage_hits;
            total.stage_misses += s.stage_misses;
            total.structural_hits += s.structural_hits;
            total.structural_misses += s.structural_misses;
            total.lowerings += s.lowerings;
        }
        total
    }
}

fn eval_one(
    point: &DesignPoint,
    class: &WorkloadClass,
    cfg: &AutotuneConfig,
    pool: &SessionPool,
    journal: &Journal,
    journal_hits: &AtomicUsize,
) -> Result<Metrics> {
    let key = eval_key(point, class, cfg);
    if let Some(m) = journal.lookup(&key) {
        journal_hits.fetch_add(1, Ordering::Relaxed);
        return Ok(m);
    }
    let session = pool.get(&point.arch, point.strategy);
    let pipe = PipelineConfig::new(cfg.overlap, point.arrays);
    let r = session.run_network_with(&class.model, Some(class.batch), pipe)?;
    let m = Metrics {
        latency_s: r.batch_time_s,
        energy_j: r.energy_j,
        area_mm2: design_area_mm2(&point.arch) * point.arrays as f64,
        efficiency: r.energy_eff,
        throughput: r.throughput,
        power_w: r.power_w,
    };
    journal.record(&key, m)?;
    Ok(m)
}

/// Evaluate `(class, point)` units across a worker pool; results come
/// back in unit order regardless of completion order (the
/// `Session::run_many` pattern).  The outer pool is kept narrow because
/// every evaluation fans its kernels out across threads internally.
fn eval_units(
    units: &[(usize, usize)],
    points: &[DesignPoint],
    classes: &[WorkloadClass],
    cfg: &AutotuneConfig,
    pool: &SessionPool,
    journal: &Journal,
    journal_hits: &AtomicUsize,
) -> Result<Vec<Metrics>> {
    if units.is_empty() {
        return Ok(Vec::new());
    }
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 4)
        .min(units.len());
    let next = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, Result<Metrics>)>> = Mutex::new(Vec::with_capacity(units.len()));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= units.len() {
                    break;
                }
                let (ci, pi) = units[i];
                let r = eval_one(&points[pi], &classes[ci], cfg, pool, journal, journal_hits);
                done.lock().unwrap().push((i, r));
            });
        }
    });
    let mut slots: Vec<Option<Result<Metrics>>> = units.iter().map(|_| None).collect();
    for (i, r) in done.into_inner().unwrap() {
        slots[i] = Some(r);
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            let (ci, pi) = units[i];
            slot.expect("every unit was claimed by a worker").with_context(|| {
                format!("evaluating point '{}' on class '{}'", points[pi].id, classes[ci].name)
            })
        })
        .collect()
}

/// Indices into `evals` of the non-dominated set, sorted by
/// (latency, energy, point index) ascending.
fn pareto_frontier(evals: &[PointEval]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..evals.len())
        .filter(|&i| {
            !evals
                .iter()
                .enumerate()
                .any(|(j, other)| j != i && dominates(&other.metrics, &evals[i].metrics))
        })
        .collect();
    idx.sort_by(|&a, &b| {
        evals[a]
            .metrics
            .latency_s
            .total_cmp(&evals[b].metrics.latency_s)
            .then(evals[a].metrics.energy_j.total_cmp(&evals[b].metrics.energy_j))
            .then(evals[a].point.cmp(&evals[b].point))
    });
    idx
}

/// Run the full design-space sweep: enumerate, prune (reported),
/// evaluate in parallel through shared per-arch sessions with journal
/// checkpointing, and compute the per-class frontier.
pub fn sweep(
    space: &SearchSpace,
    base: &ArchConfig,
    classes: &[WorkloadClass],
    cfg: &AutotuneConfig,
    journal: &Journal,
) -> Result<AutotuneResult> {
    ensure!(!classes.is_empty(), "autotune needs at least one workload class");
    ensure!(cfg.window >= 1, "autotune window must be >= 1");
    base.validate().context("base architecture")?;
    let space = space.resolved(base);
    let points = space.enumerate(base)?;
    let default_pi = points
        .iter()
        .position(|p| p.is_default)
        .expect("enumerate always injects the default point");
    let costs: Vec<ClassCosts> = classes.iter().map(class_costs).collect();
    let (nc, np) = (classes.len(), points.len());

    // Layer 1a: equal-shard prune.  Among points sharing an architecture
    // AND a strategy (different strategies lower differently, so the
    // identical-schedule argument needs both), only the smallest replica
    // count per distinct shard width can be non-dominated (equal
    // latency, <= energy, strictly less area).
    let mut pruned_shard = vec![vec![false; np]; nc];
    if cfg.prune {
        let mut groups: HashMap<(String, Strategy), Vec<usize>> = HashMap::new();
        for (pi, p) in points.iter().enumerate() {
            groups.entry((p.arch.signature(), p.strategy)).or_default().push(pi);
        }
        for (ci, class) in classes.iter().enumerate() {
            for idxs in groups.values() {
                if idxs.len() < 2 {
                    continue;
                }
                let mut keep: HashMap<usize, usize> = HashMap::new();
                for &pi in idxs {
                    let shards = class.batch.div_ceil(points[pi].arrays);
                    keep.entry(shards)
                        .and_modify(|best| {
                            if points[pi].arrays < points[*best].arrays {
                                *best = pi;
                            }
                        })
                        .or_insert(pi);
                }
                for &pi in idxs {
                    let shards = class.batch.div_ceil(points[pi].arrays);
                    if keep[&shards] != pi && !points[pi].is_default {
                        pruned_shard[ci][pi] = true;
                    }
                }
            }
        }
    }

    let pool = SessionPool::new(cfg.window, cfg.store.clone(), cfg.threads);
    let journal_hits = AtomicUsize::new(0);
    let mut results: Vec<Vec<Option<Metrics>>> = vec![vec![None; np]; nc];

    // Layer 1b: roofline prune, anchored on measured points.  Anchors —
    // the default design plus the per-axis bound minimizers — are
    // evaluated first; any surviving point whose *bounds* they dominate
    // cannot be on the frontier and is skipped.
    let mut bounds: Vec<Vec<Option<Bounds>>> = vec![vec![None; np]; nc];
    let mut anchor_units: Vec<(usize, usize)> = Vec::new();
    if cfg.prune {
        for ci in 0..nc {
            let survivors: Vec<usize> =
                (0..np).filter(|&pi| !pruned_shard[ci][pi]).collect();
            for &pi in &survivors {
                bounds[ci][pi] = Some(lower_bounds(&points[pi], &costs[ci], classes[ci].batch));
            }
            let argmin = |key: fn(&Bounds) -> f64| -> usize {
                let mut best = survivors[0];
                for &pi in &survivors[1..] {
                    let (b, cur) = (bounds[ci][pi].unwrap(), bounds[ci][best].unwrap());
                    if key(&b).total_cmp(&key(&cur)) == std::cmp::Ordering::Less {
                        best = pi;
                    }
                }
                best
            };
            let mut set = vec![
                default_pi,
                argmin(|b| b.latency_s),
                argmin(|b| b.energy_j),
                argmin(|b| b.area_mm2),
            ];
            set.sort_unstable();
            set.dedup();
            anchor_units.extend(set.into_iter().map(|pi| (ci, pi)));
        }
    }
    let anchor_metrics =
        eval_units(&anchor_units, &points, classes, cfg, &pool, journal, &journal_hits)?;
    for (&(ci, pi), m) in anchor_units.iter().zip(anchor_metrics) {
        results[ci][pi] = Some(m);
    }

    let mut pruned_roofline = vec![vec![false; np]; nc];
    if cfg.prune {
        for ci in 0..nc {
            let anchors: Vec<usize> = anchor_units
                .iter()
                .filter(|&&(c, _)| c == ci)
                .map(|&(_, pi)| pi)
                .collect();
            for pi in 0..np {
                if pruned_shard[ci][pi] || results[ci][pi].is_some() || points[pi].is_default {
                    continue;
                }
                let lb = bounds[ci][pi].expect("bounds computed for every survivor");
                if anchors
                    .iter()
                    .any(|&a| bounds_dominated(results[ci][a].as_ref().unwrap(), &lb))
                {
                    pruned_roofline[ci][pi] = true;
                }
            }
        }
    }

    // Layer 2: evaluate everything that survived, in fixed order.
    let rest: Vec<(usize, usize)> = (0..nc)
        .flat_map(|ci| (0..np).map(move |pi| (ci, pi)))
        .filter(|&(ci, pi)| {
            !pruned_shard[ci][pi] && !pruned_roofline[ci][pi] && results[ci][pi].is_none()
        })
        .collect();
    let rest_metrics = eval_units(&rest, &points, classes, cfg, &pool, journal, &journal_hits)?;
    for (&(ci, pi), m) in rest.iter().zip(rest_metrics) {
        results[ci][pi] = Some(m);
    }

    // Layer 3: per-class frontier + report assembly, in canonical order.
    let mut sweeps = Vec::with_capacity(nc);
    let (mut evaluated, mut tot_shard, mut tot_roofline) = (0, 0, 0);
    for (ci, class) in classes.iter().enumerate() {
        let evals: Vec<PointEval> = (0..np)
            .filter_map(|pi| results[ci][pi].map(|metrics| PointEval { point: pi, metrics }))
            .collect();
        let frontier = pareto_frontier(&evals);
        let default_eval = evals
            .iter()
            .position(|e| e.point == default_pi)
            .expect("the default point is always evaluated");
        let mut best_eval = 0;
        for i in 1..evals.len() {
            let (a, b) = (
                cfg.objective.score(&evals[i].metrics),
                cfg.objective.score(&evals[best_eval].metrics),
            );
            if a.total_cmp(&b) == std::cmp::Ordering::Less {
                best_eval = i;
            }
        }
        let pruned_shard_n = (0..np).filter(|&pi| pruned_shard[ci][pi]).count();
        let pruned_roofline_n = (0..np).filter(|&pi| pruned_roofline[ci][pi]).count();
        evaluated += evals.len();
        tot_shard += pruned_shard_n;
        tot_roofline += pruned_roofline_n;
        sweeps.push(ClassSweep {
            name: class.name.clone(),
            spec: class.model.spec_string(),
            batch: class.batch,
            evals,
            frontier,
            default_eval,
            best_eval,
            pruned_shard: pruned_shard_n,
            pruned_roofline: pruned_roofline_n,
        });
    }

    Ok(AutotuneResult {
        base_arch: base.signature(),
        space: space.canonical(),
        objective: cfg.objective,
        overlap: cfg.overlap,
        window: cfg.window,
        points,
        classes: sweeps,
        evaluated,
        pruned_shard: tot_shard,
        pruned_roofline: tot_roofline,
        journal_hits: journal_hits.load(Ordering::Relaxed),
        cache: pool.cache_stats(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(latency_s: f64, energy_j: f64, area_mm2: f64) -> Metrics {
        Metrics {
            latency_s,
            energy_j,
            area_mm2,
            efficiency: 1.0,
            throughput: 1.0,
            power_w: 1.0,
        }
    }

    #[test]
    fn parse_default_and_round_trip() {
        let d = SearchSpace::parse("default").unwrap();
        assert_eq!(d.mesh, vec![(2, 2), (4, 4)]);
        let base = ArchConfig::scaled_128();
        let canon = d.resolved(&base).canonical();
        assert_eq!(
            canon,
            "mesh=2x2,4x4;simd=8,32;spm=2m,4m;ports=4;ddr=1,2;inflight=4;arrays=1,2"
        );
        // parse(canonical) == resolved space, point for point.
        let again = SearchSpace::parse(&canon).unwrap().resolved(&base);
        assert_eq!(again.canonical(), canon);
        assert_eq!(d.num_points(&base), 32);
    }

    #[test]
    fn parse_sizes_and_errors() {
        let sp = SearchSpace::parse("spm=512k,2m,4096").unwrap();
        assert_eq!(sp.spm_kib, vec![512, 2048, 4096]);
        assert!(SearchSpace::parse("mesh=4").unwrap_err().to_string().contains("bad mesh"));
        assert_eq!(
            SearchSpace::parse("warp=4").unwrap_err().to_string(),
            "unknown search-space knob 'warp' \
             (mesh | simd | spm | ports | ddr | inflight | arrays | strategy)"
        );
        assert!(SearchSpace::parse("simd=0").is_err());
        assert!(SearchSpace::parse("simd").unwrap_err().to_string().contains("not 'knob="));
    }

    #[test]
    fn enumerate_pins_omitted_knobs_and_injects_default() {
        let base = ArchConfig::scaled_128();
        // A grid that does not contain the base design.
        let sp = SearchSpace::parse("mesh=2x2;arrays=2").unwrap();
        let points = sp.enumerate(&base).unwrap();
        assert_eq!(points.len(), 2); // 1 grid point + injected default
        assert!(!points[0].is_default);
        assert_eq!(points[0].arch.simd_width, base.simd_width); // pinned
        let def = &points[1];
        assert!(def.is_default && def.arrays == 1);
        assert_eq!(def.arch.signature(), base.signature());
        // A grid that does contain it marks in place instead.
        let sp = SearchSpace::parse("arrays=1,2").unwrap();
        let points = sp.enumerate(&base).unwrap();
        assert_eq!(points.len(), 2);
        assert!(points[0].is_default && !points[1].is_default);
    }

    #[test]
    fn enumerate_rejects_invalid_candidates() {
        let base = ArchConfig { spm_banks: 0, ..ArchConfig::full() };
        let err = SearchSpace::parse("simd=8").unwrap().enumerate(&base).unwrap_err();
        assert!(format!("{err:#}").contains("SPM must expose at least one bank/port"));
    }

    #[test]
    fn frontier_drops_dominated_points() {
        let evals = vec![
            PointEval { point: 0, metrics: m(1.0, 1.0, 1.0) },
            PointEval { point: 1, metrics: m(2.0, 2.0, 2.0) }, // dominated
            PointEval { point: 2, metrics: m(0.5, 3.0, 1.0) }, // trade-off
            PointEval { point: 3, metrics: m(1.0, 1.0, 1.0) }, // tie: kept
        ];
        assert_eq!(pareto_frontier(&evals), vec![2, 0, 3]);
        assert!(dominates(&m(1.0, 1.0, 1.0), &m(1.0, 1.0, 2.0)));
        assert!(!dominates(&m(1.0, 1.0, 1.0), &m(1.0, 1.0, 1.0)));
    }

    #[test]
    fn objective_scores() {
        let a = m(2.0, 3.0, 5.0);
        assert_eq!(Objective::parse("edp").unwrap().score(&a), 6.0);
        assert_eq!(Objective::Latency.score(&a), 2.0);
        assert_eq!(Objective::Efficiency.score(&a), -1.0);
        assert_eq!(
            Objective::parse("speed").unwrap_err().to_string(),
            "unknown objective 'speed' (latency | energy | area | efficiency | edp)"
        );
    }

    #[test]
    fn bounds_dominated_needs_strictness() {
        let lb = Bounds { latency_s: 1.0, energy_j: 1.0, area_mm2: 1.0 };
        assert!(bounds_dominated(&m(1.0, 1.0, 0.5), &lb));
        assert!(!bounds_dominated(&m(1.0, 1.0, 1.0), &lb));
        assert!(!bounds_dominated(&m(0.5, 1.5, 0.5), &lb));
    }

    #[test]
    fn journal_round_trips_metrics_exactly() {
        let path = std::env::temp_dir().join(format!(
            "bfdf_autotune_journal_{}.jsonl",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let written = Metrics {
            latency_s: 1.0 / 3.0,
            energy_j: 2.718281828459045,
            area_mm2: 15.76,
            efficiency: 1e-7 / 3.0,
            throughput: 123456.789,
            power_w: 3.94,
        };
        {
            let j = Journal::open(&path, false).unwrap();
            j.record("k1", written).unwrap();
        }
        let j = Journal::open(&path, true).unwrap();
        assert_eq!(j.loaded(), 1);
        assert_eq!(j.lookup("k1"), Some(written)); // bit-exact round trip
        assert_eq!(j.lookup("k2"), None);
        // Fresh open truncates.
        let j = Journal::open(&path, false).unwrap();
        assert_eq!(j.loaded(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn journal_load_skips_corrupt_tail() {
        let path = std::env::temp_dir().join(format!(
            "bfdf_autotune_corrupt_{}.jsonl",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        {
            let j = Journal::open(&path, false).unwrap();
            j.record("good", m(1.0, 2.0, 3.0)).unwrap();
        }
        // Simulate a crash mid-append.
        {
            use std::io::Write;
            let mut f =
                std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"key\":\"trunc").unwrap();
        }
        let j = Journal::open(&path, true).unwrap();
        assert_eq!(j.loaded(), 1);
        assert!(j.lookup("good").is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn eval_key_separates_configs() {
        let class = &WorkloadClass::resolve(&["fabnet-128".into()], Some(2)).unwrap()[0];
        let cfg = AutotuneConfig::default();
        let p1 = DesignPoint {
            id: "a".into(),
            arch: ArchConfig::full(),
            arrays: 1,
            strategy: Strategy::Paper,
            is_default: false,
        };
        let p2 = DesignPoint { arrays: 2, ..p1.clone() };
        let p3 = DesignPoint { arch: ArchConfig::scaled_128(), ..p1.clone() };
        let k1 = eval_key(&p1, class, &cfg);
        assert_ne!(k1, eval_key(&p2, class, &cfg));
        assert_ne!(k1, eval_key(&p3, class, &cfg));
        let other = &WorkloadClass::resolve(&["fabnet-128".into()], Some(4)).unwrap()[0];
        assert_ne!(k1, eval_key(&p1, other, &cfg));
        let cfg2 = AutotuneConfig { overlap: Overlap::None, ..cfg.clone() };
        assert_ne!(k1, eval_key(&p1, class, &cfg2));
        // A different strategy on the same arch is a different journal
        // cell; the paper point keeps the historical suffix-free key.
        let p4 = DesignPoint { strategy: Strategy::SpmAdaptive, ..p1.clone() };
        let p5 = DesignPoint { strategy: Strategy::Auto, ..p1.clone() };
        let k4 = eval_key(&p4, class, &cfg);
        let k5 = eval_key(&p5, class, &cfg);
        assert_ne!(k1, k4);
        assert_ne!(k1, k5);
        assert_ne!(k4, k5);
        assert!(!k1.contains("|st"));
    }

    #[test]
    fn strategy_axis_enumerates_and_suffixes_ids() {
        let base = ArchConfig::scaled_128();
        let sp = SearchSpace::parse("strategy=paper,spm-adaptive,auto").unwrap();
        assert_eq!(sp.num_points(&base), 3);
        let points = sp.enumerate(&base).unwrap();
        assert_eq!(points.len(), 3);
        // The paper point is the default and keeps the suffix-free id.
        assert!(points[0].is_default && points[0].strategy == Strategy::Paper);
        assert!(!points[0].id.contains("-st"));
        assert!(points[1].id.ends_with("-stspm-adaptive"));
        assert!(points[2].id.ends_with("-stauto"));
        assert!(!points[1].is_default && !points[2].is_default);
        // Rendered canonical grammar round-trips the axis.
        let canon = sp.resolved(&base).canonical();
        assert!(canon.ends_with(";strategy=paper,spm-adaptive,auto"), "{canon}");
        let again = SearchSpace::parse(&canon).unwrap().resolved(&base);
        assert_eq!(again.canonical(), canon);
        // An omitted axis pins to paper and stays out of the grammar.
        let plain = SearchSpace::parse("arrays=1").unwrap().resolved(&base);
        assert_eq!(plain.strategy, vec![Strategy::Paper]);
        assert!(!plain.canonical().contains("strategy"), "{}", plain.canonical());
    }

    #[test]
    fn workload_class_resolve_rejects_zero_batch() {
        let err = WorkloadClass::resolve(&["vanilla".into()], Some(0)).unwrap_err();
        assert_eq!(err.to_string(), "autotune batch must be >= 1 (got 0)");
    }
}
