//! Experiment orchestration: kernel spec → stage plan → windowed
//! simulation → extrapolated metrics; plus the Table-IV batch-streaming
//! driver and aggregate helpers used by every figure bench.

pub mod experiment;
pub mod streaming;

pub use experiment::{run_kernel, run_kernel_with, ExperimentConfig, KernelResult};
pub use streaming::{stream_workload, StreamResult};
