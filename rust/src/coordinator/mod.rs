//! Experiment orchestration: kernel spec → stage plan → windowed
//! simulation → extrapolated metrics.
//!
//! The public surface is the [`Session`] API ([`session`]): a
//! builder-configured, long-lived session that owns a plan cache (so
//! repeated stage DFGs lower and simulate once), fans independent
//! kernels across threads ([`Session::run_many`]), streams batched
//! workloads ([`Session::stream`], the Table-IV driver), and executes
//! whole hybrid networks ([`Session::run_network`], producing per-layer
//! [`NetworkResult`] metrics from a declarative
//! [`crate::workloads::spec::ModelSpec`]).  Streamed schedules are
//! post-processed by the coarse-grained overlap model ([`pipeline`]):
//! DMA/compute double buffering per kernel, inter-kernel/inter-layer
//! pipelining of consecutive batch elements, and batch sharding across
//! replicated arrays (`Session::builder().overlap(..).arrays(..)`).
//! Results serialize through [`Report`] ([`report`]) for benches and
//! CI.  On top of the batch-level schedule sits the serving layer
//! ([`serve`]): deterministic Poisson/trace traffic over mixed request
//! classes, a dynamic batcher (max-batch/max-wait), and a
//! discrete-event loop across replica arrays producing SLO percentiles
//! ([`Session::serve`], `Report::Serving`, `bfdf serve-sim`).  The
//! serving loop also degrades gracefully under failures: seeded or
//! scripted replica up/down schedules ([`ReplicaFaults`]), capped
//! exponential-backoff retries for batches killed in flight,
//! per-request deadlines, and SLO-aware admission ([`Admission`]) —
//! all default-off, so fault-free runs stay byte-identical.  The
//! design-space autotuner ([`autotune`]) closes the loop: a
//! [`SearchSpace`] over `ArchConfig` knobs, sound shard/roofline
//! pruning, a resumable journal-checkpointed parallel sweep through
//! shared per-arch sessions, and per-class latency/energy/area Pareto
//! frontiers (`Report::Pareto`, `bfdf autotune`).  Underneath every
//! session's plan cache sits the cross-session [`StructuralStore`]
//! ([`structural`]): stage-window measurements keyed by structure
//! (kind, points, flags, window, pack, mapping id, arch+sim signature),
//! shared across the autotuner's session pool and optionally persisted
//! next to the journal so `--resume` sweeps pay only for genuinely
//! novel stages.
//!
//! *How* a kernel is lowered — division, mapping, packing — is the
//! session's [`crate::dfg::strategy::DataflowStrategy`]
//! (`Session::builder().strategy(..)`, default the paper's recipe;
//! `Strategy::Auto` simulates the registered strategies per kernel
//! shape and memoizes the winner).

pub mod autotune;
pub mod experiment;
pub mod network;
pub mod pipeline;
pub mod report;
pub mod serve;
pub mod session;
pub mod streaming;
pub mod structural;

pub use autotune::{
    AutotuneConfig, AutotuneResult, ClassSweep, DesignPoint, Journal, Metrics, Objective,
    PointEval, SearchSpace, WorkloadClass,
};
pub use experiment::{ExperimentConfig, KernelResult};
pub use network::{BlockResult, DenseResult, LayerResult, NetworkResult};
pub use pipeline::{Overlap, OverlapEstimate, PipelineConfig, StageCost};
pub use report::{Report, SweepRow};
pub use serve::{
    Admission, Arrival, ClassServeStats, ReplicaEvent, ReplicaFaults, ServeConfig, ServeResult,
    Traffic,
};
pub use session::{CacheStats, Session, SessionBuilder};
pub use streaming::StreamResult;
pub use structural::{StageMeasure, StructuralKey, StructuralStore};
