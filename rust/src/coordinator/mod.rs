//! Experiment orchestration: kernel spec → stage plan → windowed
//! simulation → extrapolated metrics.
//!
//! The public surface is the [`Session`] API ([`session`]): a
//! builder-configured, long-lived session that owns a plan cache (so
//! repeated stage DFGs lower and simulate once), fans independent
//! kernels across threads ([`Session::run_many`]), and streams batched
//! workloads ([`Session::stream`], the Table-IV driver).  Results
//! serialize through [`Report`] ([`report`]) for benches and CI.
//!
//! The historical one-shot free functions ([`run_kernel`],
//! [`run_kernel_with`], [`stream_workload`]) are deprecated wrappers
//! that build a throwaway session per call.

pub mod experiment;
pub mod report;
pub mod session;
pub mod streaming;

pub use experiment::{ExperimentConfig, KernelResult};
pub use report::{Report, SweepRow};
pub use session::{CacheStats, Session, SessionBuilder};
pub use streaming::StreamResult;

#[allow(deprecated)]
pub use experiment::{run_kernel, run_kernel_with};
#[allow(deprecated)]
pub use streaming::stream_workload;
