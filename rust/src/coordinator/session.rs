//! Long-lived orchestration sessions: plan caching and parallel kernel
//! execution.
//!
//! The paper's headline results come from streaming *many* kernels
//! through one reconfigurable substrate (§V-A, Table IV): the vanilla
//! transformer's two FFN BPMM layers lower to the *same* stage DFGs, and
//! FABNet repeats its block at every depth.  A [`Session`] owns the
//! architecture/simulation configuration plus a plan cache so that
//! repeated stage DFGs are planned, lowered and simulated exactly once
//! per session, and independent kernels fan out across threads via
//! [`Session::run_many`] with deterministic, input-ordered results.
//! Simulations run inside pooled [`SimWorkspace`] scratch arenas, so a
//! session's many `simulate` invocations (windows, sweeps, cache
//! misses across a batch) recycle the event calendar and per-unit
//! state instead of reallocating them per call.
//!
//! ```no_run
//! use butterfly_dataflow::coordinator::Session;
//! use butterfly_dataflow::workloads;
//!
//! let session = Session::builder().build();
//! let suite = workloads::find_suite("vanilla").unwrap();
//! let report = session.stream(&suite.kernels_at(Some(16)), 16).unwrap();
//! assert!(session.cache_stats().stage_hits > 0); // FFN-L1 == FFN-L2
//!
//! // Whole hybrid networks run end-to-end with per-layer metrics:
//! let net = workloads::NetworkBuilder::from_spec(
//!     "hybrid", "att:fft2d,ffn:bpmm*x4;att:bpmm,ffn:bpmm*x2").unwrap()
//!     .hidden(512).seq(256).batch(8)
//!     .build().unwrap();
//! let result = session.run_network(&net, None).unwrap();
//! assert_eq!(result.layers.len(), 2);
//! # let _ = report;
//! ```
//!
//! The one-shot free functions (`run_kernel`, `run_kernel_with`,
//! `stream_workload`) survive as `#[deprecated]` wrappers routed
//! through a process-wide pool of shared sessions (one per
//! configuration signature), so even legacy call sites reuse plan
//! caches across calls.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use crate::arch::ArchConfig;
use crate::dfg::graph::KernelKind;
use crate::dfg::microcode::lower_stage_packed;
use crate::dfg::stages::{plan_kernel, KernelPlan, StageDfg};
use crate::energy;
use crate::sim::{simulate_in, SimOptions, SimStats, SimWorkspace};
use crate::workloads::spec::ModelSpec;
use crate::workloads::KernelSpec;

use super::experiment::{ExperimentConfig, KernelResult};
use super::network::{self, NetworkResult};
use super::pipeline::{self, Overlap, PipelineConfig, StageCost};
use super::streaming::{self, StreamResult};

/// Packing target: keep at least this many butterfly nodes per PE per
/// layer so fixed block overheads stay amortized (§V-A streaming).
const TARGET_NODES_PER_PE: usize = 8;

/// The per-stage simulation schedule [`Session`] applies: shallow stage
/// DFGs (few nodes per PE) pack several independent instances per
/// iteration so block issue overheads amortize (§V-A streaming), the
/// total iteration count covers `vectors × sub_iters` instances, and
/// the simulated window is capped at `window_cap` (extrapolated beyond
/// it).  Returns `(iters_total, window, pack)`.
///
/// This is the single source of truth — `Session::execute` calls it per
/// stage, and the golden suite (`rust/tests/sim_golden.rs`) calls it to
/// diff exactly the programs the coordinator simulates.
pub fn stage_schedule(
    stage: &StageDfg,
    vectors: usize,
    arch: &ArchConfig,
    window_cap: usize,
) -> (usize, usize, usize) {
    let w = arch.simd_width;
    let instances = vectors.saturating_mul(stage.sub_iters);
    let base_npe = (stage.points / 2).div_ceil(arch.num_pes()).max(1);
    let pack =
        (TARGET_NODES_PER_PE / base_npe).clamp(1, instances.div_ceil(w).max(1));
    let iters_total = instances.div_ceil(w * pack).max(1);
    let window = iters_total.min(window_cap.max(1));
    (iters_total, window, pack)
}

/// Builder for [`Session`].
///
/// Defaults mirror the historical `ExperimentConfig::default()`: the
/// full 512-MAC architecture, default simulator options, a 48-iteration
/// window, automatic (balanced) stage division and plan caching on.
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    arch: ArchConfig,
    sim: SimOptions,
    window: usize,
    division: Option<(usize, usize)>,
    caching: bool,
    pipeline: PipelineConfig,
}

impl SessionBuilder {
    pub fn new() -> Self {
        SessionBuilder {
            arch: ArchConfig::full(),
            sim: SimOptions::default(),
            window: 48,
            division: None,
            caching: true,
            pipeline: PipelineConfig::default(),
        }
    }

    /// Architecture preset the session simulates.
    pub fn arch(mut self, arch: ArchConfig) -> Self {
        self.arch = arch;
        self
    }

    /// Simulator options (ablation switches).
    pub fn sim(mut self, sim: SimOptions) -> Self {
        self.sim = sim;
        self
    }

    /// Simulation window in DFG iterations per stage.
    pub fn window(mut self, window: usize) -> Self {
        self.window = window.max(1);
        self
    }

    /// Default stage division applied by [`Session::run`]
    /// (`None` = balanced; per-call override via [`Session::run_with`]).
    pub fn division(mut self, division: Option<(usize, usize)>) -> Self {
        self.division = division;
        self
    }

    /// Enable/disable the plan cache (on by default; the uncached mode
    /// exists for cache-equivalence tests and memory-constrained runs).
    pub fn plan_caching(mut self, on: bool) -> Self {
        self.caching = on;
        self
    }

    /// Number of replicated dataflow arrays streamed workloads shard
    /// across (default 1).  See [`crate::coordinator::pipeline`].
    pub fn arrays(mut self, n: usize) -> Self {
        self.pipeline.arrays = n.max(1);
        self
    }

    /// Coarse-grained overlap mode for [`Session::stream`] /
    /// [`Session::run_network`] (default [`Overlap::None`], the
    /// bit-exact legacy serial accounting; the CLI defaults to
    /// [`Overlap::Pipeline`]).
    pub fn overlap(mut self, overlap: Overlap) -> Self {
        self.pipeline.overlap = overlap;
        self
    }

    /// Set the full streaming-schedule configuration at once.
    pub fn pipeline(mut self, cfg: PipelineConfig) -> Self {
        self.pipeline = PipelineConfig::new(cfg.overlap, cfg.arrays);
        self
    }

    /// Start from an existing [`ExperimentConfig`].
    pub fn config(mut self, cfg: &ExperimentConfig) -> Self {
        self.arch = cfg.arch.clone();
        self.sim = cfg.sim.clone();
        self.window = cfg.window.max(1);
        self
    }

    pub fn build(self) -> Session {
        let arch_sig = format!("{}|{:?}|w{}", self.arch.signature(), self.sim, self.window);
        Session {
            cfg: ExperimentConfig { arch: self.arch, sim: self.sim, window: self.window },
            division: self.division,
            caching: self.caching,
            pipeline: self.pipeline,
            cache: PlanCache {
                arch_sig,
                plans: Mutex::new(HashMap::new()),
                stages: Mutex::new(HashMap::new()),
            },
            counters: Counters::default(),
            workspaces: Mutex::new(Vec::new()),
        }
    }
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// Key of a cached kernel plan: the stage decomposition depends only on
/// the kernel kind, the transform length, the (optional) explicit
/// division and the architecture — never on the vector count, which is
/// re-attached per kernel.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PlanKey {
    kind: KernelKind,
    points: usize,
    division: Option<(usize, usize)>,
}

/// Key of a cached stage measurement.  [`lower_stage_packed`] reads the
/// stage's `{kind, points, twiddle_before, weights_from_ddr}` plus the
/// window and pack factors; the architecture and simulator options are
/// session-constant (pinned by [`PlanCache::arch_sig`]), so together
/// these fields fully determine the lowered program and its simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct StageKey {
    kind: KernelKind,
    points: usize,
    twiddle_before: bool,
    weights_from_ddr: bool,
    window: usize,
    pack: usize,
}

/// One simulated stage measurement (shared across kernels via `Arc`).
#[derive(Debug)]
struct StageMeasure {
    /// Compute slots (per lane) of the lowered window program.
    ops: u64,
    stats: SimStats,
}

/// A per-key fill cell: concurrent misses on one key coalesce behind
/// the cell's lock, so every distinct key is computed exactly once even
/// under [`Session::run_many`] parallelism.
type Cell<T> = Arc<Mutex<Option<T>>>;

type PlanCell = Cell<Arc<Vec<StageDfg>>>;
type StageCell = Cell<Arc<StageMeasure>>;

/// The session's memo of planned divisions and simulated stage windows.
#[derive(Debug)]
struct PlanCache {
    /// Signature of the (arch, sim options, window) tuple every entry was
    /// produced under; a session never mixes configurations.
    arch_sig: String,
    plans: Mutex<HashMap<PlanKey, PlanCell>>,
    stages: Mutex<HashMap<StageKey, StageCell>>,
}

#[derive(Debug, Default)]
struct Counters {
    plan_hits: AtomicU64,
    plan_misses: AtomicU64,
    stage_hits: AtomicU64,
    stage_misses: AtomicU64,
    lowerings: AtomicU64,
}

/// Snapshot of a session's cache activity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Kernel plans served from / inserted into the cache.
    pub plan_hits: u64,
    pub plan_misses: u64,
    /// Stage-window simulations served from / inserted into the cache.
    pub stage_hits: u64,
    pub stage_misses: u64,
    /// Total `lower_stage_packed` invocations (equals `stage_misses`
    /// when caching is on; counts every stage when off).
    pub lowerings: u64,
}

/// A long-lived orchestration session.
///
/// Construct with [`Session::builder`]; all run methods take `&self`
/// and are thread-safe, so one session can serve concurrent callers.
#[derive(Debug)]
pub struct Session {
    cfg: ExperimentConfig,
    division: Option<(usize, usize)>,
    caching: bool,
    pipeline: PipelineConfig,
    cache: PlanCache,
    counters: Counters,
    /// Pool of simulator scratch arenas: each lowering/simulation
    /// checks one out (or starts a fresh one under `run_many`
    /// parallelism) and returns it, so re-simulation across windows,
    /// batches and sweeps reuses the event calendar, ready queues and
    /// dependency counters instead of reallocating them per call.
    workspaces: Mutex<Vec<SimWorkspace>>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// One-shot session equivalent to the deprecated free functions.
    pub fn from_config(cfg: &ExperimentConfig) -> Session {
        Session::builder().config(cfg).build()
    }

    /// The session's experiment configuration.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The architecture this session simulates.
    pub fn arch(&self) -> &ArchConfig {
        &self.cfg.arch
    }

    /// Signature of the configuration all cache entries were produced
    /// under (part of every cache key, by construction).
    pub fn arch_signature(&self) -> &str {
        &self.cache.arch_sig
    }

    /// Snapshot of the cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats {
            plan_hits: self.counters.plan_hits.load(Ordering::Relaxed),
            plan_misses: self.counters.plan_misses.load(Ordering::Relaxed),
            stage_hits: self.counters.stage_hits.load(Ordering::Relaxed),
            stage_misses: self.counters.stage_misses.load(Ordering::Relaxed),
            lowerings: self.counters.lowerings.load(Ordering::Relaxed),
        }
    }

    /// Run one kernel with the session's default division.
    pub fn run(&self, spec: &KernelSpec) -> Result<KernelResult> {
        self.run_with(spec, self.division)
    }

    /// Run one kernel with an explicit stage division (the Fig. 14
    /// sweep path); `None` picks the balanced division.
    pub fn run_with(
        &self,
        spec: &KernelSpec,
        division: Option<(usize, usize)>,
    ) -> Result<KernelResult> {
        let plan = self.plan_for(spec, division)?;
        self.execute(spec, &plan)
    }

    /// Run independent kernels across std threads and return results in
    /// input order.  Results are bitwise-identical to sequential
    /// [`Session::run`] calls: the simulator is deterministic and the
    /// per-kernel arithmetic never depends on execution order.
    pub fn run_many(&self, specs: &[KernelSpec]) -> Result<Vec<KernelResult>> {
        if specs.len() <= 1 {
            return specs.iter().map(|s| self.run(s)).collect();
        }
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .min(specs.len());
        let next = AtomicUsize::new(0);
        let done: Mutex<Vec<(usize, Result<KernelResult>)>> =
            Mutex::new(Vec::with_capacity(specs.len()));
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= specs.len() {
                        break;
                    }
                    let r = self.run(&specs[i]);
                    done.lock().unwrap().push((i, r));
                });
            }
        });
        let mut slots: Vec<Option<Result<KernelResult>>> =
            specs.iter().map(|_| None).collect();
        for (i, r) in done.into_inner().unwrap() {
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|r| r.expect("every index was claimed by a worker"))
            .collect()
    }

    /// The session's streaming-schedule configuration (overlap mode and
    /// replicated array count).
    pub fn pipeline_config(&self) -> PipelineConfig {
        self.pipeline
    }

    /// Stream a batched workload under the session's overlap
    /// configuration: run every kernel (in parallel), schedule the
    /// kernel sequence ([`crate::coordinator::pipeline`]) and report the
    /// Table-IV per-prediction metrics.  With the default configuration
    /// (`Overlap::None`, one array) the effective time is the legacy
    /// serial sum, bit-for-bit.
    pub fn stream(&self, kernels: &[KernelSpec], batch: usize) -> Result<StreamResult> {
        self.stream_with(kernels, batch, self.pipeline)
    }

    /// [`Session::stream`] with an explicit per-call overlap/arrays
    /// configuration (the session default is untouched).
    pub fn stream_with(
        &self,
        kernels: &[KernelSpec],
        batch: usize,
        cfg: PipelineConfig,
    ) -> Result<StreamResult> {
        anyhow::ensure!(
            batch > 0,
            "stream batch must be >= 1 (got 0): per-prediction latency divides by it"
        );
        anyhow::ensure!(!kernels.is_empty(), "stream workload has no kernels");
        let results = self.run_many(kernels)?;
        let stages: Vec<StageCost> = results.iter().map(StageCost::of_kernel).collect();
        let est =
            pipeline::schedule(&stages, batch, cfg, energy::idle_power_w(&self.cfg.arch));
        let active_energy_j: f64 = results.iter().map(|r| r.energy_j).sum();
        let energy_j = active_energy_j + est.idle_energy_j;
        let batch_time_s = est.overlapped_time_s;
        let (latency_ms, throughput, power_w, energy_eff) =
            streaming::per_prediction_metrics(batch, batch_time_s, energy_j);
        Ok(StreamResult {
            kernels: results,
            batch,
            batch_time_s,
            serial_time_s: est.serial_time_s,
            overlapped_time_s: est.overlapped_time_s,
            pipeline_efficiency: est.pipeline_efficiency,
            arrays: est.arrays,
            overlap: est.overlap,
            latency_ms,
            throughput,
            power_w,
            energy_j,
            energy_eff,
        })
    }

    /// Execute a whole hybrid network end-to-end: lower the
    /// [`ModelSpec`] at `batch` (`None` = the model's default), fan the
    /// butterfly kernels of all layers across threads (repeated blocks
    /// hit the plan cache, so each distinct stage lowers once per
    /// session no matter the depth), price dense blocks with the
    /// roofline model, and roll everything up into per-layer and total
    /// metrics ([`NetworkResult`]).
    pub fn run_network(
        &self,
        model: &ModelSpec,
        batch: Option<usize>,
    ) -> Result<NetworkResult> {
        self.run_network_with(model, batch, self.pipeline)
    }

    /// [`Session::run_network`] with an explicit per-call
    /// overlap/arrays configuration (the session default is untouched).
    pub fn run_network_with(
        &self,
        model: &ModelSpec,
        batch: Option<usize>,
        cfg: PipelineConfig,
    ) -> Result<NetworkResult> {
        anyhow::ensure!(
            batch != Some(0),
            "network batch must be >= 1 (got 0): per-prediction latency divides by it"
        );
        let batch = batch.unwrap_or(model.default_batch());
        let lowered = model.lower(Some(batch));
        let flat: Vec<KernelSpec> = lowered
            .iter()
            .flat_map(|b| b.kernels.iter().cloned())
            .collect();
        let results = self.run_many(&flat)?;
        let mut results = results.into_iter();
        let mut blocks = Vec::with_capacity(lowered.len());
        for lb in &lowered {
            let kernels: Vec<KernelResult> = lb
                .kernels
                .iter()
                .map(|_| results.next().expect("run_many returns one result per spec"))
                .collect();
            let dense = lb
                .dense
                .as_ref()
                .map(|cost| network::eval_dense(&self.cfg.arch, cost));
            blocks.push(network::BlockResult::new(
                lb.layer,
                lb.label.clone(),
                kernels,
                dense,
            ));
        }
        Ok(network::assemble(
            model.name().to_string(),
            model.spec_string(),
            batch,
            blocks,
            cfg,
            energy::idle_power_w(&self.cfg.arch),
        ))
    }

    /// Plan (or recall) the stage decomposition of one kernel.
    fn plan_for(
        &self,
        spec: &KernelSpec,
        division: Option<(usize, usize)>,
    ) -> Result<KernelPlan> {
        if !self.caching {
            return plan_kernel(spec.kind, spec.points, spec.vectors, &self.cfg.arch, division);
        }
        let key = PlanKey { kind: spec.kind, points: spec.points, division };
        let cell = {
            let mut map = self.cache.plans.lock().unwrap();
            map.entry(key).or_default().clone()
        };
        // Holding the cell (not the map) while planning: concurrent
        // misses on the same key wait for the first filler, other keys
        // proceed in parallel.
        let mut slot = cell.lock().unwrap();
        if let Some(stages) = slot.as_ref() {
            self.counters.plan_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(KernelPlan {
                kind: spec.kind,
                n: spec.points,
                stages: stages.as_ref().clone(),
                vectors: spec.vectors,
            });
        }
        self.counters.plan_misses.fetch_add(1, Ordering::Relaxed);
        let plan =
            plan_kernel(spec.kind, spec.points, spec.vectors, &self.cfg.arch, division)?;
        *slot = Some(Arc::new(plan.stages.clone()));
        Ok(plan)
    }

    /// Lower + simulate (or recall) one stage window.  Each distinct
    /// [`StageKey`] is lowered exactly once per session, including under
    /// [`Session::run_many`] parallelism (the per-key cell coalesces
    /// concurrent misses).
    fn measure_stage(&self, stage: &StageDfg, window: usize, pack: usize) -> Arc<StageMeasure> {
        let lower = || {
            self.counters.lowerings.fetch_add(1, Ordering::Relaxed);
            let program = lower_stage_packed(stage, &self.cfg.arch, window, pack);
            // Check a scratch arena out of the pool (falling back to a
            // fresh one when all are in flight under run_many), run,
            // and return it warm for the next stage.
            let mut ws =
                self.workspaces.lock().unwrap().pop().unwrap_or_else(SimWorkspace::new);
            let stats = simulate_in(&mut ws, &program, &self.cfg.arch, &self.cfg.sim);
            self.workspaces.lock().unwrap().push(ws);
            Arc::new(StageMeasure { ops: program.total_ops(), stats })
        };
        if !self.caching {
            return lower();
        }
        let key = StageKey {
            kind: stage.kind,
            points: stage.points,
            twiddle_before: stage.twiddle_before,
            weights_from_ddr: stage.weights_from_ddr,
            window,
            pack,
        };
        let cell = {
            let mut map = self.cache.stages.lock().unwrap();
            map.entry(key).or_default().clone()
        };
        let mut slot = cell.lock().unwrap();
        if let Some(m) = slot.as_ref() {
            self.counters.stage_hits.fetch_add(1, Ordering::Relaxed);
            return m.clone();
        }
        self.counters.stage_misses.fetch_add(1, Ordering::Relaxed);
        let m = lower();
        *slot = Some(m.clone());
        m
    }

    /// The windowed-extrapolation experiment loop (see module docs in
    /// [`super::experiment`] for the software-pipelining argument).
    fn execute(&self, spec: &KernelSpec, plan: &KernelPlan) -> Result<KernelResult> {
        let arch = &self.cfg.arch;

        let mut total_cycles = 0.0f64;
        let mut busy = [0.0f64; 4];
        let mut spm_scalars = 0.0f64;
        let mut noc_scalars = 0.0f64;
        let mut dma_bytes = 0.0f64;
        let mut dma_stream_bytes = 0.0f64;
        let mut fill_cycles = 0.0f64;
        let mut ops_total = 0.0f64;

        for stage in &plan.stages {
            let (iters_total, window, pack) =
                stage_schedule(stage, spec.vectors, arch, self.cfg.window);
            let m = self.measure_stage(stage, window, pack);
            let stats = &m.stats;
            let scale = iters_total as f64 / window as f64;
            let stage_cycles = if iters_total > window {
                stats.cycles as f64
                    + (iters_total - window) as f64 * stats.steady_cycles_per_iter()
            } else {
                stats.cycles as f64
            };
            total_cycles += stage_cycles;
            // Busy time is a *rate*: extrapolate by the cycle ratio (the
            // iteration ratio can drift ~1% from it and push utilization
            // fractionally above 1.0).
            let busy_scale = stage_cycles / stats.cycles.max(1) as f64;
            for k in 0..4 {
                busy[k] += stats.unit_busy[k] as f64 * busy_scale;
            }
            spm_scalars += stats.spm_scalars as f64 * scale;
            noc_scalars += stats.noc_scalars as f64 * scale;
            dma_bytes += stats.dma_bytes as f64 * scale;
            // Gating DMA stream for the overlap model: weights stream
            // once per stage (never scaled by the extrapolation ratio),
            // inputs once per iteration; outputs drain on the writeback
            // half of the channel budget and never gate, matching the
            // simulator.  (`dma_bytes` above keeps the historical
            // all-scaled in+out+weights accounting because the energy
            // model's router activity is calibrated against it.)
            dma_stream_bytes +=
                stats.dma_weight_bytes as f64 + stats.dma_in_bytes as f64 * scale;
            fill_cycles += stats.dma_fill_cycles as f64;
            ops_total += m.ops as f64 * scale;
        }

        let num_pes = arch.num_pes() as f64;
        let util = [
            busy[0] / (total_cycles * num_pes),
            busy[1] / (total_cycles * num_pes),
            busy[2] / (total_cycles * num_pes),
            busy[3] / (total_cycles * num_pes),
        ];
        // SPM accessing requirement (the Fig. 12 metric): fraction of the
        // compute's operand traffic that the SPM has to serve.  Each
        // compute slot touches ~2 operand scalars per lane; the
        // multilayer DFG keeps most of those inside PEs / on the NoC, so
        // the SPM share stays low (the paper reports <= 12.48%).
        let operand_scalars = 2.0 * ops_total * arch.simd_width as f64;
        let spm_requirement = spm_scalars / operand_scalars.max(1.0);
        let link_cap = (arch.num_pes() * 4) as f64
            * (arch.noc_link_bytes / arch.elem_bytes) as f64;
        let noc_requirement = (noc_scalars / total_cycles) / link_cap;

        let time_s = arch.cycles_to_seconds(1) * total_cycles;
        let flops = spec.sparse_flops();
        let flops_efficiency = flops / time_s / arch.peak_flops();

        // Aggregate stats view for the energy model, carrying the
        // extrapolated SPM/NoC/DMA activity alongside cycles and busy
        // time so the effective-power estimate sees the whole run.
        let agg = SimStats {
            cycles: total_cycles as u64,
            unit_busy: [
                busy[0] as u64,
                busy[1] as u64,
                busy[2] as u64,
                busy[3] as u64,
            ],
            spm_scalars: spm_scalars as u64,
            noc_scalars: noc_scalars as u64,
            dma_bytes: dma_bytes as u64,
            ..Default::default()
        };
        let power_w = energy::effective_power_w(arch, &agg);
        let energy_j = power_w * time_s;
        let cycle_s = arch.cycles_to_seconds(1);

        Ok(KernelResult {
            name: spec.name.clone(),
            cycles: total_cycles,
            time_s,
            util,
            spm_requirement,
            noc_requirement,
            flops,
            flops_efficiency,
            power_w,
            energy_j,
            dma_bytes,
            dma_time_s: dma_stream_bytes / arch.ddr_bw(),
            fill_time_s: (cycle_s * fill_cycles).min(time_s),
            plan: plan.clone(),
        })
    }
}

/// Process-wide session pool backing the deprecated one-shot wrappers
/// (`run_kernel`, `run_kernel_with`, `stream_workload`): one lazily
/// initialized [`Session`] per distinct configuration signature, so
/// legacy call sites share plan caches across calls instead of building
/// and discarding a fresh session — and cache — every time.
pub(crate) fn shared_session(cfg: &ExperimentConfig) -> Arc<Session> {
    static POOL: OnceLock<Mutex<HashMap<String, Arc<Session>>>> = OnceLock::new();
    let pool = POOL.get_or_init(|| Mutex::new(HashMap::new()));
    // Building a session is cheap (empty caches); the signature it
    // derives is the pool key, so key and configuration can never
    // disagree.  On a pool hit the fresh instance is simply dropped.
    let fresh = Session::from_config(cfg);
    let key = fresh.arch_signature().to_string();
    pool.lock()
        .unwrap()
        .entry(key)
        .or_insert_with(|| Arc::new(fresh))
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::graph::KernelKind;

    fn spec(kind: KernelKind, points: usize, vectors: usize) -> KernelSpec {
        KernelSpec {
            name: format!("{}-{}", kind.name(), points),
            kind,
            points,
            vectors,
            d_in: points,
            d_out: points,
            seq: points,
        }
    }

    #[test]
    fn session_runs_and_caches() {
        let session = Session::builder().build();
        let s = spec(KernelKind::Fft, 1024, 8 * 1024);
        let a = session.run(&s).unwrap();
        let b = session.run(&s).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.power_w, b.power_w);
        let stats = session.cache_stats();
        assert!(stats.plan_hits >= 1, "{stats:?}");
        assert!(stats.stage_hits >= 1, "{stats:?}");
    }

    #[test]
    fn uncached_session_matches_cached() {
        let cached = Session::builder().build();
        let raw = Session::builder().plan_caching(false).build();
        let s = spec(KernelKind::Bpmm, 2048, 16 * 1024);
        let a = cached.run(&s).unwrap();
        let _ = cached.run(&s).unwrap(); // populate + hit
        let b = raw.run(&s).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(raw.cache_stats().stage_hits, 0);
        assert_eq!(raw.cache_stats().plan_hits, 0);
        assert!(raw.cache_stats().lowerings > 0);
    }

    #[test]
    fn division_override_bypasses_default() {
        let session = Session::builder().division(Some((32, 64))).build();
        let s = spec(KernelKind::Bpmm, 2048, 8192);
        let a = session.run(&s).unwrap();
        let b = session.run_with(&s, Some((16, 128))).unwrap();
        assert_eq!(a.plan.stages[0].points, 32);
        assert_eq!(b.plan.stages[0].points, 16);
        assert_ne!(a.cycles, b.cycles);
    }

    #[test]
    fn shared_session_pool_reuses_per_config() {
        let cfg = ExperimentConfig::default();
        let a = shared_session(&cfg);
        let b = shared_session(&cfg);
        assert!(Arc::ptr_eq(&a, &b), "same config must share one session");
        let other = ExperimentConfig { window: 96, ..Default::default() };
        let c = shared_session(&other);
        assert!(
            !Arc::ptr_eq(&a, &c),
            "distinct configs must get distinct sessions"
        );
    }

    #[test]
    fn stream_rejects_degenerate_inputs() {
        let session = Session::builder().build();
        let ks = vec![spec(KernelKind::Fft, 256, 1024)];
        assert!(session.stream(&ks, 0).is_err());
        assert!(session.stream(&[], 8).is_err());
        assert!(session.stream(&ks, 8).is_ok());
    }
}
