//! Long-lived orchestration sessions: plan caching and parallel kernel
//! execution.
//!
//! The paper's headline results come from streaming *many* kernels
//! through one reconfigurable substrate (§V-A, Table IV): the vanilla
//! transformer's two FFN BPMM layers lower to the *same* stage DFGs, and
//! FABNet repeats its block at every depth.  A [`Session`] owns the
//! architecture/simulation configuration plus a plan cache so that
//! repeated stage DFGs are planned, lowered and simulated exactly once
//! per session, and independent kernels fan out across threads via
//! [`Session::run_many`] with deterministic, input-ordered results.
//! Within one kernel, the independent stage-window simulations shard
//! across the same worker pool (`Session::builder().threads(..)`, all
//! cores by default) and merge in stage order, so parallel results stay
//! bitwise-identical to serial ones.  Simulations run inside pooled
//! [`SimWorkspace`] scratch arenas — bounded at the thread count — so a
//! session's many `simulate` invocations (windows, sweeps, cache
//! misses across a batch) recycle the event calendar and per-unit
//! state instead of reallocating them per call.  Underneath the
//! per-session cache sits a cross-session
//! [`StructuralStore`](super::structural::StructuralStore)
//! (`Session::builder().structural_store(..)`): stage-cache misses
//! consult it before lowering, so sessions over the same configuration
//! — autotuner pools, resumed sweeps — reuse each other's stage-window
//! measurements, optionally persisted to disk.
//!
//! ```no_run
//! use butterfly_dataflow::coordinator::Session;
//! use butterfly_dataflow::workloads;
//!
//! let session = Session::builder().build();
//! let suite = workloads::find_suite("vanilla").unwrap();
//! let report = session.stream(&suite.kernels_at(Some(16)), 16).unwrap();
//! assert!(session.cache_stats().stage_hits > 0); // FFN-L1 == FFN-L2
//!
//! // Whole hybrid networks run end-to-end with per-layer metrics:
//! let net = workloads::NetworkBuilder::from_spec(
//!     "hybrid", "att:fft2d,ffn:bpmm*x4;att:bpmm,ffn:bpmm*x2").unwrap()
//!     .hidden(512).seq(256).batch(8)
//!     .build().unwrap();
//! let result = session.run_network(&net, None).unwrap();
//! assert_eq!(result.layers.len(), 2);
//! # let _ = report;
//! ```
//!
//! How a kernel is divided, mapped and scheduled is delegated to a
//! [`DataflowStrategy`] (default: the paper's recipe).  A session built
//! with [`Strategy::Auto`] simulates every registered strategy through
//! the plan cache the first time it meets a (kind, points, vectors,
//! division) block and memoizes the winner, so repeated blocks pay the
//! probe cost once.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::arch::ArchConfig;
use crate::dfg::graph::KernelKind;
use crate::dfg::microcode::lower_stage_mapped;
use crate::dfg::stages::{KernelPlan, StageDfg};
use crate::dfg::strategy::{self, DataflowStrategy, Strategy};
use crate::energy;
use crate::sim::{simulate_in, SimOptions, SimStats, SimWorkspace};
use crate::workloads::spec::ModelSpec;
use crate::workloads::KernelSpec;

use super::experiment::{ExperimentConfig, KernelResult};
use super::network::{self, NetworkResult};
use super::pipeline::{self, Overlap, PipelineConfig, StageCost};
use super::streaming::{self, StreamResult};
use super::structural::{StageMeasure, StructuralKey, StructuralStore};

/// The per-stage simulation schedule of the *paper* strategy: the
/// canonical implementation lives in
/// [`crate::dfg::strategy::paper_schedule`] (the [`DataflowStrategy`]
/// trait's default); this wrapper survives because the golden suite
/// (`rust/tests/sim_golden.rs`) calls it to diff exactly the programs
/// the default-strategy coordinator simulates.
pub fn stage_schedule(
    stage: &StageDfg,
    vectors: usize,
    arch: &ArchConfig,
    window_cap: usize,
) -> (usize, usize, usize) {
    strategy::paper_schedule(stage, vectors, arch, window_cap)
}

/// Builder for [`Session`].
///
/// Defaults mirror the historical `ExperimentConfig::default()`: the
/// full 512-MAC architecture, default simulator options, a 48-iteration
/// window, automatic (balanced) stage division and plan caching on.
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    arch: ArchConfig,
    sim: SimOptions,
    window: usize,
    division: Option<(usize, usize)>,
    caching: bool,
    pipeline: PipelineConfig,
    strategy: Strategy,
    threads: usize,
    structural: Option<Arc<StructuralStore>>,
}

impl SessionBuilder {
    pub fn new() -> Self {
        SessionBuilder {
            arch: ArchConfig::full(),
            sim: SimOptions::default(),
            window: 48,
            division: None,
            caching: true,
            pipeline: PipelineConfig::default(),
            strategy: Strategy::Paper,
            threads: 0,
            structural: None,
        }
    }

    /// Architecture preset the session simulates.
    pub fn arch(mut self, arch: ArchConfig) -> Self {
        self.arch = arch;
        self
    }

    /// Simulator options (ablation switches).
    pub fn sim(mut self, sim: SimOptions) -> Self {
        self.sim = sim;
        self
    }

    /// Inject a hardware fault model: lowering remaps butterfly nodes
    /// around dead PEs and the simulator prices degraded NoC links and
    /// downed DDR channels.  The model is validated against the
    /// session's architecture on the first `run` — a mismatch is a
    /// structured error, never a panic.
    pub fn faults(mut self, faults: crate::arch::FaultModel) -> Self {
        self.sim.faults = Some(Arc::new(faults));
        self
    }

    /// Simulation window in DFG iterations per stage.
    pub fn window(mut self, window: usize) -> Self {
        self.window = window.max(1);
        self
    }

    /// Default stage division applied by [`Session::run`]
    /// (`None` = balanced; per-call override via [`Session::run_with`]).
    pub fn division(mut self, division: Option<(usize, usize)>) -> Self {
        self.division = division;
        self
    }

    /// Enable/disable the plan cache (on by default; the uncached mode
    /// exists for cache-equivalence tests and memory-constrained runs).
    pub fn plan_caching(mut self, on: bool) -> Self {
        self.caching = on;
        self
    }

    /// Number of replicated dataflow arrays streamed workloads shard
    /// across (default 1).  See [`crate::coordinator::pipeline`].
    pub fn arrays(mut self, n: usize) -> Self {
        self.pipeline.arrays = n.max(1);
        self
    }

    /// Coarse-grained overlap mode for [`Session::stream`] /
    /// [`Session::run_network`] (default [`Overlap::None`], the
    /// bit-exact legacy serial accounting; the CLI defaults to
    /// [`Overlap::Pipeline`]).
    pub fn overlap(mut self, overlap: Overlap) -> Self {
        self.pipeline.overlap = overlap;
        self
    }

    /// Set the full streaming-schedule configuration at once.
    pub fn pipeline(mut self, cfg: PipelineConfig) -> Self {
        self.pipeline = PipelineConfig::new(cfg.overlap, cfg.arrays);
        self
    }

    /// Dataflow strategy the session lowers with (default
    /// [`Strategy::Paper`], the bit-exact pre-refactor recipe;
    /// [`Strategy::Auto`] simulates every registered strategy per kernel
    /// shape through the plan cache and memoizes the fastest).
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Worker threads for kernel fan-out ([`Session::run_many`]) and
    /// intra-kernel stage-window sharding (0 = all available cores, the
    /// default).  `threads(1)` is the fully serial mode; any thread
    /// count produces bitwise-identical results (results merge in
    /// deterministic input order and every stage simulation is
    /// order-independent).  The count also caps the [`SimWorkspace`]
    /// pool, so memory stays bounded under sustained fan-out.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Share a cross-session [`StructuralStore`]: stage-cache misses
    /// consult it before lowering, so sessions over the same
    /// `(arch, sim options)` configuration — autotuner pool sessions,
    /// resumed sweeps, serving replicas — reuse each other's
    /// stage-window measurements.  Without this call the session owns a
    /// private store (hits then come only from uncached re-entry, i.e.
    /// never — the per-session stage cache sits above it).
    pub fn structural_store(mut self, store: Arc<StructuralStore>) -> Self {
        self.structural = Some(store);
        self
    }

    /// Start from an existing [`ExperimentConfig`].
    pub fn config(mut self, cfg: &ExperimentConfig) -> Self {
        self.arch = cfg.arch.clone();
        self.sim = cfg.sim.clone();
        self.window = cfg.window.max(1);
        self
    }

    pub fn build(self) -> Session {
        // Field-by-field `SimOptions::signature()` (never `{:?}`): a new
        // simulator option must extend the signature or fail to compile,
        // so it can never silently alias cache keys.
        let structural_sig: Arc<str> =
            format!("{}|{}", self.arch.signature(), self.sim.signature()).into();
        let arch_sig = format!("{structural_sig}|w{}", self.window);
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        Session {
            cfg: ExperimentConfig { arch: self.arch, sim: self.sim, window: self.window },
            division: self.division,
            caching: self.caching,
            pipeline: self.pipeline,
            strategy: self.strategy,
            threads,
            cache: PlanCache {
                arch_sig,
                plans: Mutex::new(HashMap::new()),
                stages: Mutex::new(HashMap::new()),
            },
            structural: self.structural.unwrap_or_default(),
            structural_sig,
            auto_winners: Mutex::new(HashMap::new()),
            counters: Counters::default(),
            workspaces: Mutex::new(Vec::new()),
        }
    }
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// Key of a cached kernel plan: the stage decomposition depends only on
/// the kernel kind, the transform length, the (optional) explicit
/// division, the *strategy* that planned it and the architecture —
/// never on the vector count, which is re-attached per kernel.  The
/// strategy id is load-bearing: under [`Strategy::Auto`] one session
/// probes several strategies for the same kernel shape, and a cache hit
/// across strategies would silently replay the wrong division.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PlanKey {
    kind: KernelKind,
    points: usize,
    division: Option<(usize, usize)>,
    strategy: &'static str,
}

/// Key of a cached stage measurement.  [`lower_stage_mapped`] reads the
/// stage's `{kind, points, twiddle_before, weights_from_ddr}` plus the
/// window and pack factors and the strategy's mapping; the architecture
/// and simulator options are session-constant (pinned by
/// [`PlanCache::arch_sig`]), so together these fields fully determine
/// the lowered program and its simulation.  Keying on the *mapping id*
/// rather than the strategy name is deliberate: strategies that differ
/// only in division or packing still share structurally identical stage
/// measurements (an `Auto` probe is then nearly free on overlap).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct StageKey {
    kind: KernelKind,
    points: usize,
    twiddle_before: bool,
    weights_from_ddr: bool,
    window: usize,
    pack: usize,
    mapping: &'static str,
}

/// Memo key of an [`Strategy::Auto`] winner: the probe result holds for
/// every kernel with the same shape (kind, points, vectors, explicit
/// division) under this session's fixed architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct AutoKey {
    kind: KernelKind,
    points: usize,
    vectors: usize,
    division: Option<(usize, usize)>,
}

/// A per-key fill cell: concurrent misses on one key coalesce behind
/// the cell's lock, so every distinct key is computed exactly once even
/// under [`Session::run_many`] parallelism.
type Cell<T> = Arc<Mutex<Option<T>>>;

type PlanCell = Cell<Arc<Vec<StageDfg>>>;
type StageCell = Cell<Arc<StageMeasure>>;

/// The session's memo of planned divisions and simulated stage windows.
#[derive(Debug)]
struct PlanCache {
    /// Signature of the (arch, sim options, window) tuple every entry was
    /// produced under; a session never mixes configurations.
    arch_sig: String,
    plans: Mutex<HashMap<PlanKey, PlanCell>>,
    stages: Mutex<HashMap<StageKey, StageCell>>,
}

#[derive(Debug, Default)]
struct Counters {
    plan_hits: AtomicU64,
    plan_misses: AtomicU64,
    stage_hits: AtomicU64,
    stage_misses: AtomicU64,
    structural_hits: AtomicU64,
    structural_misses: AtomicU64,
    lowerings: AtomicU64,
}

/// Snapshot of a session's cache activity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Kernel plans served from / inserted into the cache.
    pub plan_hits: u64,
    pub plan_misses: u64,
    /// Stage-window simulations served from / inserted into the cache.
    pub stage_hits: u64,
    pub stage_misses: u64,
    /// Stage-cache misses served by the cross-session
    /// [`StructuralStore`] without lowering (> 0 only when sessions
    /// share a store or it was loaded from disk).
    pub structural_hits: u64,
    /// Stage-cache misses the structural store could not serve (each
    /// one lowered and simulated, then entered the store).
    pub structural_misses: u64,
    /// Total stage lowerings (equals `structural_misses`
    /// when caching is on; counts every stage when off).
    pub lowerings: u64,
}

/// A long-lived orchestration session.
///
/// Construct with [`Session::builder`]; all run methods take `&self`
/// and are thread-safe, so one session can serve concurrent callers.
#[derive(Debug)]
pub struct Session {
    cfg: ExperimentConfig,
    division: Option<(usize, usize)>,
    caching: bool,
    pipeline: PipelineConfig,
    strategy: Strategy,
    /// Resolved worker-thread count (>= 1) shared by the `run_many`
    /// kernel fan-out and the intra-kernel stage sharding; also the
    /// workspace-pool cap.
    threads: usize,
    cache: PlanCache,
    /// Cross-session structural result store (a private one unless the
    /// builder injected a shared/persistent store); consulted on every
    /// stage-cache miss when caching is on.
    structural: Arc<StructuralStore>,
    /// `(arch, sim options)` signature of structural keys — the
    /// window-free prefix of [`PlanCache::arch_sig`] (the window is a
    /// per-key structural field, not session identity).
    structural_sig: Arc<str>,
    /// [`Strategy::Auto`] winners per kernel shape (registry indices).
    auto_winners: Mutex<HashMap<AutoKey, usize>>,
    counters: Counters,
    /// Pool of simulator scratch arenas: each lowering/simulation
    /// checks one out (or starts a fresh one when all are in flight
    /// under fan-out) and returns it, so re-simulation across windows,
    /// batches and sweeps reuses the event calendar, ready queues and
    /// dependency counters instead of reallocating them per call.
    /// Bounded at `threads`: returns beyond the cap are dropped, so a
    /// burst of concurrent checkouts can never grow the pool past what
    /// steady-state parallelism uses.
    workspaces: Mutex<Vec<SimWorkspace>>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// Session with defaults taken from an [`ExperimentConfig`].
    pub fn from_config(cfg: &ExperimentConfig) -> Session {
        Session::builder().config(cfg).build()
    }

    /// The session's experiment configuration.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The architecture this session simulates.
    pub fn arch(&self) -> &ArchConfig {
        &self.cfg.arch
    }

    /// Signature of the configuration all cache entries were produced
    /// under (part of every cache key, by construction).
    pub fn arch_signature(&self) -> &str {
        &self.cache.arch_sig
    }

    /// The dataflow strategy this session lowers with.
    pub fn strategy(&self) -> Strategy {
        self.strategy
    }

    /// Resolved worker-thread count (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The structural result store backing this session (shared iff the
    /// builder injected one).
    pub fn structural_store(&self) -> &Arc<StructuralStore> {
        &self.structural
    }

    /// Current size of the pooled-workspace free list (bounded at
    /// [`Session::threads`]; exposed for the pool-cap regression test).
    pub fn workspace_pool_len(&self) -> usize {
        self.workspaces.lock().unwrap().len()
    }

    /// The [`Strategy::Auto`] picks made so far, as
    /// `((kind name, points, vectors), winning strategy name)` pairs
    /// sorted by shape — deterministic, so CLI lines and bench
    /// artifacts that print them reproduce byte-for-byte (empty unless
    /// the session runs `Auto`).
    pub fn auto_selections(&self) -> Vec<((&'static str, usize, usize), &'static str)> {
        let reg = strategy::registry();
        let mut picks: Vec<_> = self
            .auto_winners
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &i)| ((k.kind.name(), k.points, k.vectors), reg[i].name()))
            .collect();
        picks.sort_unstable();
        picks
    }

    /// Snapshot of the cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats {
            plan_hits: self.counters.plan_hits.load(Ordering::Relaxed),
            plan_misses: self.counters.plan_misses.load(Ordering::Relaxed),
            stage_hits: self.counters.stage_hits.load(Ordering::Relaxed),
            stage_misses: self.counters.stage_misses.load(Ordering::Relaxed),
            structural_hits: self.counters.structural_hits.load(Ordering::Relaxed),
            structural_misses: self.counters.structural_misses.load(Ordering::Relaxed),
            lowerings: self.counters.lowerings.load(Ordering::Relaxed),
        }
    }

    /// Run one kernel with the session's default division.
    pub fn run(&self, spec: &KernelSpec) -> Result<KernelResult> {
        self.run_with(spec, self.division)
    }

    /// Run one kernel with an explicit stage division (the Fig. 14
    /// sweep path); `None` lets the session's strategy choose.
    pub fn run_with(
        &self,
        spec: &KernelSpec,
        division: Option<(usize, usize)>,
    ) -> Result<KernelResult> {
        match self.strategy.implementation() {
            Some(strat) => self.run_strategy(spec, division, strat),
            None => self.run_auto(spec, division),
        }
    }

    /// Plan and execute one kernel under a specific concrete strategy.
    fn run_strategy(
        &self,
        spec: &KernelSpec,
        division: Option<(usize, usize)>,
        strat: &'static dyn DataflowStrategy,
    ) -> Result<KernelResult> {
        if let Some(f) = self.cfg.sim.faults.as_deref() {
            // Fail with a structured error — never a lowering panic —
            // before any work when the fault model does not fit this
            // architecture (wrong geometry, or nothing left to map onto).
            f.validate(&self.cfg.arch)?;
        }
        let plan = self.plan_for(spec, division, strat)?;
        self.execute(spec, &plan, strat)
    }

    /// [`Strategy::Auto`]: simulate every registered strategy for this
    /// kernel shape through the plan cache, return the fastest result
    /// and memoize the winner (ties resolve to the earliest registry
    /// entry, i.e. the paper default).  Probe runs fill the same cache
    /// cells the winner replays from, so the probes are pure overlap
    /// whenever the shape recurs.
    fn run_auto(
        &self,
        spec: &KernelSpec,
        division: Option<(usize, usize)>,
    ) -> Result<KernelResult> {
        let key = AutoKey {
            kind: spec.kind,
            points: spec.points,
            vectors: spec.vectors,
            division,
        };
        let memoized = self.auto_winners.lock().unwrap().get(&key).copied();
        if let Some(i) = memoized {
            return self.run_strategy(spec, division, strategy::registry()[i]);
        }
        let mut best: Option<(usize, KernelResult)> = None;
        for (i, strat) in strategy::registry().iter().enumerate() {
            let r = self.run_strategy(spec, division, *strat)?;
            let better = match &best {
                None => true,
                Some((_, b)) => r.time_s < b.time_s,
            };
            if better {
                best = Some((i, r));
            }
        }
        let (winner, result) = best.expect("strategy registry is never empty");
        self.auto_winners.lock().unwrap().insert(key, winner);
        Ok(result)
    }

    /// Run independent kernels across std threads and return results in
    /// input order.  Results are bitwise-identical to sequential
    /// [`Session::run`] calls: the simulator is deterministic and the
    /// per-kernel arithmetic never depends on execution order.
    pub fn run_many(&self, specs: &[KernelSpec]) -> Result<Vec<KernelResult>> {
        if specs.len() <= 1 || self.threads <= 1 {
            return specs.iter().map(|s| self.run(s)).collect();
        }
        let threads = self.threads.min(specs.len());
        let next = AtomicUsize::new(0);
        let done: Mutex<Vec<(usize, Result<KernelResult>)>> =
            Mutex::new(Vec::with_capacity(specs.len()));
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= specs.len() {
                        break;
                    }
                    let r = self.run(&specs[i]);
                    done.lock().unwrap().push((i, r));
                });
            }
        });
        let mut slots: Vec<Option<Result<KernelResult>>> =
            specs.iter().map(|_| None).collect();
        for (i, r) in done.into_inner().unwrap() {
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|r| r.expect("every index was claimed by a worker"))
            .collect()
    }

    /// The session's streaming-schedule configuration (overlap mode and
    /// replicated array count).
    pub fn pipeline_config(&self) -> PipelineConfig {
        self.pipeline
    }

    /// Stream a batched workload under the session's overlap
    /// configuration: run every kernel (in parallel), schedule the
    /// kernel sequence ([`crate::coordinator::pipeline`]) and report the
    /// Table-IV per-prediction metrics.  With the default configuration
    /// (`Overlap::None`, one array) the effective time is the legacy
    /// serial sum, bit-for-bit.
    pub fn stream(&self, kernels: &[KernelSpec], batch: usize) -> Result<StreamResult> {
        self.stream_with(kernels, batch, self.pipeline)
    }

    /// [`Session::stream`] with an explicit per-call overlap/arrays
    /// configuration (the session default is untouched).
    pub fn stream_with(
        &self,
        kernels: &[KernelSpec],
        batch: usize,
        cfg: PipelineConfig,
    ) -> Result<StreamResult> {
        anyhow::ensure!(
            batch > 0,
            "stream batch must be >= 1 (got 0): per-prediction latency divides by it"
        );
        anyhow::ensure!(!kernels.is_empty(), "stream workload has no kernels");
        let results = self.run_many(kernels)?;
        let stages: Vec<StageCost> = results.iter().map(StageCost::of_kernel).collect();
        let est =
            pipeline::schedule(&stages, batch, cfg, energy::idle_power_w(&self.cfg.arch));
        let active_energy_j: f64 = results.iter().map(|r| r.energy_j).sum();
        let energy_j = active_energy_j + est.idle_energy_j;
        let batch_time_s = est.overlapped_time_s;
        let (latency_ms, throughput, power_w, energy_eff) =
            streaming::per_prediction_metrics(batch, batch_time_s, energy_j);
        Ok(StreamResult {
            kernels: results,
            batch,
            batch_time_s,
            serial_time_s: est.serial_time_s,
            overlapped_time_s: est.overlapped_time_s,
            pipeline_efficiency: est.pipeline_efficiency,
            arrays: est.arrays,
            overlap: est.overlap,
            latency_ms,
            throughput,
            power_w,
            energy_j,
            energy_eff,
        })
    }

    /// Execute a whole hybrid network end-to-end: lower the
    /// [`ModelSpec`] at `batch` (`None` = the model's default), fan the
    /// butterfly kernels of all layers across threads (repeated blocks
    /// hit the plan cache, so each distinct stage lowers once per
    /// session no matter the depth), price dense blocks with the
    /// roofline model, and roll everything up into per-layer and total
    /// metrics ([`NetworkResult`]).
    pub fn run_network(
        &self,
        model: &ModelSpec,
        batch: Option<usize>,
    ) -> Result<NetworkResult> {
        self.run_network_with(model, batch, self.pipeline)
    }

    /// [`Session::run_network`] with an explicit per-call
    /// overlap/arrays configuration (the session default is untouched).
    pub fn run_network_with(
        &self,
        model: &ModelSpec,
        batch: Option<usize>,
        cfg: PipelineConfig,
    ) -> Result<NetworkResult> {
        anyhow::ensure!(
            batch != Some(0),
            "network batch must be >= 1 (got 0): per-prediction latency divides by it"
        );
        let batch = batch.unwrap_or(model.default_batch());
        let lowered = model.lower(Some(batch));
        let flat: Vec<KernelSpec> = lowered
            .iter()
            .flat_map(|b| b.kernels.iter().cloned())
            .collect();
        let results = self.run_many(&flat)?;
        let mut results = results.into_iter();
        let mut blocks = Vec::with_capacity(lowered.len());
        for lb in &lowered {
            let kernels: Vec<KernelResult> = lb
                .kernels
                .iter()
                .map(|_| results.next().expect("run_many returns one result per spec"))
                .collect();
            let dense = lb
                .dense
                .as_ref()
                .map(|cost| network::eval_dense(&self.cfg.arch, cost));
            blocks.push(network::BlockResult::new(
                lb.layer,
                lb.label.clone(),
                kernels,
                dense,
            ));
        }
        Ok(network::assemble(
            model.name().to_string(),
            model.spec_string(),
            batch,
            blocks,
            cfg,
            energy::idle_power_w(&self.cfg.arch),
        ))
    }

    /// Plan (or recall) the stage decomposition of one kernel under one
    /// concrete strategy.
    fn plan_for(
        &self,
        spec: &KernelSpec,
        division: Option<(usize, usize)>,
        strat: &'static dyn DataflowStrategy,
    ) -> Result<KernelPlan> {
        if !self.caching {
            return strat.plan(spec.kind, spec.points, spec.vectors, &self.cfg.arch, division);
        }
        let key = PlanKey {
            kind: spec.kind,
            points: spec.points,
            division,
            strategy: strat.name(),
        };
        let cell = {
            let mut map = self.cache.plans.lock().unwrap();
            map.entry(key).or_default().clone()
        };
        // Holding the cell (not the map) while planning: concurrent
        // misses on the same key wait for the first filler, other keys
        // proceed in parallel.
        let mut slot = cell.lock().unwrap();
        if let Some(stages) = slot.as_ref() {
            self.counters.plan_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(KernelPlan {
                kind: spec.kind,
                n: spec.points,
                stages: stages.as_ref().clone(),
                vectors: spec.vectors,
            });
        }
        self.counters.plan_misses.fetch_add(1, Ordering::Relaxed);
        let plan =
            strat.plan(spec.kind, spec.points, spec.vectors, &self.cfg.arch, division)?;
        *slot = Some(Arc::new(plan.stages.clone()));
        Ok(plan)
    }

    /// Lower + simulate (or recall) one stage window.  Each distinct
    /// [`StageKey`] is lowered exactly once per session, including under
    /// [`Session::run_many`] / stage-sharding parallelism (the per-key
    /// cell coalesces concurrent misses).  A stage-cache miss consults
    /// the cross-session [`StructuralStore`] before lowering, so
    /// sessions sharing a store (or loading one from disk) pay zero
    /// lowerings for structures any of them has already measured.
    fn measure_stage(
        &self,
        stage: &StageDfg,
        window: usize,
        pack: usize,
        strat: &'static dyn DataflowStrategy,
    ) -> Arc<StageMeasure> {
        let lower = || {
            self.counters.lowerings.fetch_add(1, Ordering::Relaxed);
            // Under a fault model, remap around dead PEs; `run_strategy`
            // validated the model against this arch before any lowering,
            // so the fallible path cannot fire here.  Healthy sessions
            // take the exact pre-fault call.
            let map = match self.cfg.sim.faults.as_deref() {
                Some(f) => strat
                    .fault_mapping(stage.points, &self.cfg.arch, f)
                    .expect("fault model validated against this arch before lowering"),
                None => strat.mapping(stage.points, &self.cfg.arch),
            };
            let program = lower_stage_mapped(stage, &self.cfg.arch, window, pack, &map);
            // Check a scratch arena out of the pool (falling back to a
            // fresh one when all are in flight under fan-out), run, and
            // return it warm for the next stage — unless the pool is
            // already at the thread-count cap, in which case the arena
            // is dropped (a transient burst must not grow the pool
            // permanently).
            let mut ws =
                self.workspaces.lock().unwrap().pop().unwrap_or_else(SimWorkspace::new);
            let stats = simulate_in(&mut ws, &program, &self.cfg.arch, &self.cfg.sim);
            let mut pool = self.workspaces.lock().unwrap();
            if pool.len() < self.threads {
                pool.push(ws);
            }
            drop(pool);
            Arc::new(StageMeasure { ops: program.total_ops(), stats })
        };
        if !self.caching {
            // Uncached mode is the cache-equivalence oracle: it must
            // re-lower every stage, so it bypasses the structural store
            // on both the read and the write side.
            return lower();
        }
        let key = StageKey {
            kind: stage.kind,
            points: stage.points,
            twiddle_before: stage.twiddle_before,
            weights_from_ddr: stage.weights_from_ddr,
            window,
            pack,
            mapping: strat.mapping_id(),
        };
        let cell = {
            let mut map = self.cache.stages.lock().unwrap();
            map.entry(key).or_default().clone()
        };
        let mut slot = cell.lock().unwrap();
        if let Some(m) = slot.as_ref() {
            self.counters.stage_hits.fetch_add(1, Ordering::Relaxed);
            return m.clone();
        }
        self.counters.stage_misses.fetch_add(1, Ordering::Relaxed);
        let skey = StructuralKey {
            sig: self.structural_sig.clone(),
            kind: stage.kind,
            points: stage.points,
            twiddle_before: stage.twiddle_before,
            weights_from_ddr: stage.weights_from_ddr,
            window,
            pack,
            mapping: strat.mapping_id().to_string(),
        };
        let (m, hit) = self.structural.get_or_fill(&skey, lower);
        let counter = if hit {
            &self.counters.structural_hits
        } else {
            &self.counters.structural_misses
        };
        counter.fetch_add(1, Ordering::Relaxed);
        *slot = Some(m.clone());
        m
    }

    /// Measure the stages of one kernel plan, sharding the independent
    /// stage-window simulations across the session's worker threads.
    /// Results come back in stage order regardless of completion order
    /// (the [`Session::run_many`] pattern), so the caller's rollup —
    /// and therefore every derived metric — is bitwise-identical to the
    /// serial loop.  `jobs[i]` is `(iters_total, window, pack)` for
    /// `stages[i]`, precomputed by the strategy's scheduler.
    fn measure_stages(
        &self,
        stages: &[StageDfg],
        jobs: &[(usize, usize, usize)],
        strat: &'static dyn DataflowStrategy,
    ) -> Vec<Arc<StageMeasure>> {
        if stages.len() <= 1 || self.threads <= 1 {
            return stages
                .iter()
                .zip(jobs)
                .map(|(stage, &(_, window, pack))| {
                    self.measure_stage(stage, window, pack, strat)
                })
                .collect();
        }
        let threads = self.threads.min(stages.len());
        let next = AtomicUsize::new(0);
        let done: Mutex<Vec<(usize, Arc<StageMeasure>)>> =
            Mutex::new(Vec::with_capacity(stages.len()));
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= stages.len() {
                        break;
                    }
                    let (_, window, pack) = jobs[i];
                    let m = self.measure_stage(&stages[i], window, pack, strat);
                    done.lock().unwrap().push((i, m));
                });
            }
        });
        let mut slots: Vec<Option<Arc<StageMeasure>>> =
            stages.iter().map(|_| None).collect();
        for (i, m) in done.into_inner().unwrap() {
            slots[i] = Some(m);
        }
        slots
            .into_iter()
            .map(|m| m.expect("every stage was claimed by a worker"))
            .collect()
    }

    /// The windowed-extrapolation experiment loop (see module docs in
    /// [`super::experiment`] for the software-pipelining argument).
    /// Stage windows are measured in parallel ([`Session::measure_stages`])
    /// and rolled up serially in stage order, so the f64 accumulation
    /// order — and with it every reported metric — matches the
    /// historical serial loop bit for bit.
    fn execute(
        &self,
        spec: &KernelSpec,
        plan: &KernelPlan,
        strat: &'static dyn DataflowStrategy,
    ) -> Result<KernelResult> {
        let arch = &self.cfg.arch;

        let jobs: Vec<(usize, usize, usize)> = plan
            .stages
            .iter()
            .map(|stage| strat.schedule(stage, spec.vectors, arch, self.cfg.window))
            .collect();
        let measures = self.measure_stages(&plan.stages, &jobs, strat);

        let mut total_cycles = 0.0f64;
        let mut busy = [0.0f64; 4];
        let mut spm_scalars = 0.0f64;
        let mut noc_scalars = 0.0f64;
        let mut dma_bytes = 0.0f64;
        let mut dma_stream_bytes = 0.0f64;
        let mut fill_cycles = 0.0f64;
        let mut ops_total = 0.0f64;

        for (&(iters_total, window, _pack), m) in jobs.iter().zip(&measures) {
            let stats = &m.stats;
            let scale = iters_total as f64 / window as f64;
            let stage_cycles = if iters_total > window {
                stats.cycles as f64
                    + (iters_total - window) as f64 * stats.steady_cycles_per_iter()
            } else {
                stats.cycles as f64
            };
            total_cycles += stage_cycles;
            // Busy time is a *rate*: extrapolate by the cycle ratio (the
            // iteration ratio can drift ~1% from it and push utilization
            // fractionally above 1.0).
            let busy_scale = stage_cycles / stats.cycles.max(1) as f64;
            for k in 0..4 {
                busy[k] += stats.unit_busy[k] as f64 * busy_scale;
            }
            spm_scalars += stats.spm_scalars as f64 * scale;
            noc_scalars += stats.noc_scalars as f64 * scale;
            dma_bytes += stats.dma_bytes as f64 * scale;
            // Gating DMA stream for the overlap model: weights stream
            // once per stage (never scaled by the extrapolation ratio),
            // inputs once per iteration; outputs drain on the writeback
            // half of the channel budget and never gate, matching the
            // simulator.  (`dma_bytes` above keeps the historical
            // all-scaled in+out+weights accounting because the energy
            // model's router activity is calibrated against it.)
            dma_stream_bytes +=
                stats.dma_weight_bytes as f64 + stats.dma_in_bytes as f64 * scale;
            fill_cycles += stats.dma_fill_cycles as f64;
            ops_total += m.ops as f64 * scale;
        }

        let num_pes = arch.num_pes() as f64;
        let util = [
            busy[0] / (total_cycles * num_pes),
            busy[1] / (total_cycles * num_pes),
            busy[2] / (total_cycles * num_pes),
            busy[3] / (total_cycles * num_pes),
        ];
        // SPM accessing requirement (the Fig. 12 metric): fraction of the
        // compute's operand traffic that the SPM has to serve.  Each
        // compute slot touches ~2 operand scalars per lane; the
        // multilayer DFG keeps most of those inside PEs / on the NoC, so
        // the SPM share stays low (the paper reports <= 12.48%).
        let operand_scalars = 2.0 * ops_total * arch.simd_width as f64;
        let spm_requirement = spm_scalars / operand_scalars.max(1.0);
        let link_cap = (arch.num_pes() * 4) as f64
            * (arch.noc_link_bytes / arch.elem_bytes) as f64;
        let noc_requirement = (noc_scalars / total_cycles) / link_cap;

        let time_s = arch.cycles_to_seconds(1) * total_cycles;
        let flops = spec.sparse_flops();
        let flops_efficiency = flops / time_s / arch.peak_flops();

        // Aggregate stats view for the energy model, carrying the
        // extrapolated SPM/NoC/DMA activity alongside cycles and busy
        // time so the effective-power estimate sees the whole run.
        let agg = SimStats {
            cycles: total_cycles as u64,
            unit_busy: [
                busy[0] as u64,
                busy[1] as u64,
                busy[2] as u64,
                busy[3] as u64,
            ],
            spm_scalars: spm_scalars as u64,
            noc_scalars: noc_scalars as u64,
            dma_bytes: dma_bytes as u64,
            ..Default::default()
        };
        let power_w = energy::effective_power_w(arch, &agg);
        let energy_j = power_w * time_s;
        let cycle_s = arch.cycles_to_seconds(1);

        Ok(KernelResult {
            name: spec.name.clone(),
            cycles: total_cycles,
            time_s,
            util,
            spm_requirement,
            noc_requirement,
            flops,
            flops_efficiency,
            power_w,
            energy_j,
            dma_bytes,
            dma_time_s: dma_stream_bytes / arch.ddr_bw(),
            fill_time_s: (cycle_s * fill_cycles).min(time_s),
            plan: plan.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::graph::KernelKind;

    fn spec(kind: KernelKind, points: usize, vectors: usize) -> KernelSpec {
        KernelSpec {
            name: format!("{}-{}", kind.name(), points),
            kind,
            points,
            vectors,
            d_in: points,
            d_out: points,
            seq: points,
        }
    }

    #[test]
    fn session_runs_and_caches() {
        let session = Session::builder().build();
        let s = spec(KernelKind::Fft, 1024, 8 * 1024);
        let a = session.run(&s).unwrap();
        let b = session.run(&s).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.power_w, b.power_w);
        let stats = session.cache_stats();
        assert!(stats.plan_hits >= 1, "{stats:?}");
        assert!(stats.stage_hits >= 1, "{stats:?}");
    }

    #[test]
    fn uncached_session_matches_cached() {
        let cached = Session::builder().build();
        let raw = Session::builder().plan_caching(false).build();
        let s = spec(KernelKind::Bpmm, 2048, 16 * 1024);
        let a = cached.run(&s).unwrap();
        let _ = cached.run(&s).unwrap(); // populate + hit
        let b = raw.run(&s).unwrap();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(raw.cache_stats().stage_hits, 0);
        assert_eq!(raw.cache_stats().plan_hits, 0);
        assert!(raw.cache_stats().lowerings > 0);
    }

    #[test]
    fn division_override_bypasses_default() {
        let session = Session::builder().division(Some((32, 64))).build();
        let s = spec(KernelKind::Bpmm, 2048, 8192);
        let a = session.run(&s).unwrap();
        let b = session.run_with(&s, Some((16, 128))).unwrap();
        assert_eq!(a.plan.stages[0].points, 32);
        assert_eq!(b.plan.stages[0].points, 16);
        assert_ne!(a.cycles, b.cycles);
    }

    #[test]
    fn strategies_never_share_plan_cache_cells() {
        // Same session, same kernel, two strategies: the plan cache must
        // key on the strategy id, so the second strategy's plan is a
        // *miss* (a cross-strategy hit would hand SpmAdaptive the paper
        // plan — a correctness bug, not a perf bug).
        let session = Session::builder().strategy(Strategy::Paper).build();
        let s = spec(KernelKind::Bpmm, 1024, 8192);
        let paper = session.run(&s).unwrap();
        let misses_after_paper = session.cache_stats().plan_misses;
        assert_eq!(misses_after_paper, 1);

        let adaptive = Session::builder().strategy(Strategy::SpmAdaptive).build();
        let alt = adaptive.run(&s).unwrap();
        assert_eq!(adaptive.cache_stats().plan_misses, 1);
        // Distinct strategies may legitimately produce distinct results;
        // what must never happen is the adaptive run *reusing* the paper
        // plan cell.  Probe via a mixed-strategy Auto session below.
        let _ = (paper, alt);

        let auto = Session::builder().strategy(Strategy::Auto).build();
        let first = auto.run(&s).unwrap();
        // Auto probed every registered strategy: one plan miss per
        // registry entry, never a shared cell.
        let n = strategy::registry().len();
        assert_eq!(auto.cache_stats().plan_misses, n as u64);
        // Re-running the same kernel reuses the memoized winner through
        // the cache the probes populated: no new plan misses.
        let second = auto.run(&s).unwrap();
        assert_eq!(auto.cache_stats().plan_misses, n as u64);
        assert!(auto.cache_stats().plan_hits >= 1);
        assert_eq!(first.cycles, second.cycles);
    }

    #[test]
    fn auto_never_picks_worse_than_paper() {
        let auto = Session::builder().strategy(Strategy::Auto).build();
        let paper = Session::builder().build();
        for (kind, points) in [
            (KernelKind::Fft, 256),
            (KernelKind::Fft, 1024),
            (KernelKind::Bpmm, 512),
            (KernelKind::Bpmm, 2048),
        ] {
            let s = spec(kind, points, 8192);
            let a = auto.run(&s).unwrap();
            let p = paper.run(&s).unwrap();
            assert!(
                a.time_s <= p.time_s,
                "auto picked a slower strategy for {}-{points}: {} > {}",
                kind.name(),
                a.time_s,
                p.time_s
            );
        }
        assert!(!auto.auto_selections().is_empty());
    }

    #[test]
    fn explicit_strategy_sessions_run_all_registered() {
        let s = spec(KernelKind::Fft, 512, 4096);
        for sel in Strategy::ALL {
            let session = Session::builder().strategy(sel).build();
            let r = session.run(&s).unwrap();
            assert!(r.cycles > 0.0, "{} produced zero cycles", sel.name());
            assert_eq!(session.strategy(), sel);
        }
    }

    #[test]
    fn stream_rejects_degenerate_inputs() {
        let session = Session::builder().build();
        let ks = vec![spec(KernelKind::Fft, 256, 1024)];
        assert!(session.stream(&ks, 0).is_err());
        assert!(session.stream(&[], 8).is_err());
        assert!(session.stream(&ks, 8).is_ok());
    }

    #[test]
    fn faulty_session_degrades_gracefully_and_deterministically() {
        use crate::arch::FaultModel;

        let s = spec(KernelKind::Fft, 1024, 4096);
        let healthy = Session::builder().build().run(&s).unwrap();

        let mut fm = FaultModel::for_arch(&ArchConfig::full());
        fm.kill_pe(5).unwrap();
        fm.degrade_link(9, 4).unwrap();
        let faulty = Session::builder().faults(fm.clone()).build();
        let a = faulty.run(&s).unwrap();
        let b = faulty.run(&s).unwrap();
        // Deterministic under a fixed fault set.
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_j, b.energy_j);
        // A dead PE halves the usable mesh (largest power-of-two live
        // subset): the kernel still completes, just slower.
        assert!(
            a.time_s > healthy.time_s,
            "faulty run should be slower: {} <= {}",
            a.time_s,
            healthy.time_s
        );

        // An all-healthy model must not perturb the healthy numbers.
        let noop = Session::builder()
            .faults(FaultModel::for_arch(&ArchConfig::full()))
            .build()
            .run(&s)
            .unwrap();
        assert_eq!(noop.cycles, healthy.cycles);
        assert_eq!(noop.energy_j, healthy.energy_j);
    }

    #[test]
    fn mismatched_fault_model_is_a_structured_error() {
        use crate::arch::FaultModel;

        // Built for the full mesh, run against the §VI-H scaled config
        // (one DDR channel): the geometry check must fire before any
        // lowering, as an error — not a remap panic.
        let mut fm = FaultModel::for_arch(&ArchConfig::full());
        fm.kill_pe(3).unwrap();
        let session = Session::builder().arch(ArchConfig::scaled_128()).faults(fm).build();
        let err = session.run(&spec(KernelKind::Fft, 256, 1024)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fault model was built for"), "unexpected error: {msg}");
    }
}
