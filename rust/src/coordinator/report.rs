//! Machine-readable experiment reports.
//!
//! Every CLI subcommand, bench and CI consumer used to scrape the text
//! tables; [`Report`] is the structured alternative, serialized through
//! [`crate::util::json`] (the offline vendor set has no serde).  Six
//! variants cover the coordinator's result shapes:
//!
//! * [`Report::Kernel`]  — one kernel simulation ([`KernelResult`]);
//! * [`Report::Stream`]  — a batched workload ([`StreamResult`]) plus
//!   the session's cache activity;
//! * [`Report::Network`] — a hybrid network run ([`NetworkResult`])
//!   with the per-layer / per-block breakdown;
//! * [`Report::Sweep`]   — a division sweep (the Fig. 14 scenario);
//! * [`Report::Serving`] — a serving-simulation load/latency curve
//!   ([`ServeResult`] points from `bfdf serve-sim`), with the shared
//!   session cache stats that make multi-tenant plan reuse observable;
//! * [`Report::Pareto`]  — a design-space autotune sweep
//!   ([`AutotuneResult`] from `bfdf autotune`): per-class
//!   latency/energy/area frontiers, the default design point's
//!   placement and the prune counts.  Unlike the other variants this
//!   one deliberately omits cache statistics: the artifact must be
//!   byte-identical between a fresh sweep and a journal-`--resume`d
//!   one, and cache activity is run-dependent (it lives on
//!   [`AutotuneResult`] and in the CLI text output instead).
//!
//! The JSON layout is stable: a top-level `"report"` discriminator plus
//! flat snake_case metric keys matching the `KernelResult`/
//! `StreamResult`/`NetworkResult` field names.

use crate::arch::UnitKind;
use crate::dfg::strategy::Strategy;
use crate::util::json::{arr, num, obj, s, Json};

use super::autotune::AutotuneResult;
use super::experiment::KernelResult;
use super::network::{BlockResult, LayerResult, NetworkResult};
use super::serve::ServeResult;
use super::session::CacheStats;
use super::streaming::StreamResult;

/// One row of a division sweep.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub division: (usize, usize),
    pub cycles: f64,
    /// Utilization per unit kind (Load/Flow/Cal/Store).
    pub util: [f64; 4],
}

/// A structured, serializable experiment report.
#[derive(Debug, Clone)]
pub enum Report {
    /// One kernel on the dataflow design.
    Kernel {
        /// Architecture signature the result was produced under.
        arch: String,
        result: KernelResult,
    },
    /// A batched workload streamed end-to-end.
    Stream {
        arch: String,
        /// Workload suite name (or an ad-hoc description).
        workload: String,
        /// Dataflow strategy the session lowered with.  Serialized only
        /// when it departs from [`Strategy::Paper`], so default-strategy
        /// artifacts stay byte-identical to prior releases.
        strategy: Strategy,
        cache: CacheStats,
        result: StreamResult,
    },
    /// A hybrid network executed end-to-end with per-layer metrics.
    Network {
        arch: String,
        /// Dataflow strategy (see [`Report::Stream::strategy`]).
        strategy: Strategy,
        cache: CacheStats,
        result: NetworkResult,
    },
    /// A stage-division sweep of one kernel.
    Sweep {
        arch: String,
        kernel: String,
        rows: Vec<SweepRow>,
    },
    /// A serving-simulation load/latency curve: one [`ServeResult`]
    /// per offered rate (a single rate is a one-point curve; trace
    /// runs are always one point).
    Serving {
        arch: String,
        /// Session cache totals after the whole sweep — nonzero hits
        /// are the multi-tenant plan-sharing evidence.
        cache: CacheStats,
        points: Vec<ServeResult>,
    },
    /// A design-space autotune sweep: per-workload-class Pareto
    /// frontiers over `(latency_s, energy_j, area_mm2)`.
    Pareto { result: AutotuneResult },
}

impl Report {
    /// Serialize to a JSON value.
    pub fn to_json(&self) -> Json {
        match self {
            Report::Kernel { arch, result } => obj(vec![
                ("report", s("kernel")),
                ("arch", s(arch)),
                ("result", kernel_json(result)),
            ]),
            Report::Stream { arch, workload, strategy, cache, result } => {
                let mut pairs = vec![
                    ("report", s("stream")),
                    ("arch", s(arch)),
                    ("workload", s(workload)),
                ];
                if *strategy != Strategy::Paper {
                    pairs.push(("strategy", s(strategy.name())));
                }
                pairs.push(("cache", cache_json(cache)));
                pairs.push(("result", stream_json(result)));
                obj(pairs)
            }
            Report::Network { arch, strategy, cache, result } => {
                let mut pairs = vec![("report", s("network")), ("arch", s(arch))];
                if *strategy != Strategy::Paper {
                    pairs.push(("strategy", s(strategy.name())));
                }
                pairs.push(("cache", cache_json(cache)));
                pairs.push(("result", network_json(result)));
                obj(pairs)
            }
            Report::Sweep { arch, kernel, rows } => obj(vec![
                ("report", s("sweep")),
                ("arch", s(arch)),
                ("kernel", s(kernel)),
                ("rows", arr(rows.iter().map(sweep_row_json).collect())),
            ]),
            Report::Serving { arch, cache, points } => obj(vec![
                ("report", s("serving")),
                ("arch", s(arch)),
                ("cache", cache_json(cache)),
                ("points", arr(points.iter().map(ServeResult::to_json).collect())),
            ]),
            Report::Pareto { result } => result.to_json(),
        }
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        self.to_json().render()
    }
}

/// JSON view of one [`KernelResult`].
pub fn kernel_json(r: &KernelResult) -> Json {
    obj(vec![
        ("name", s(&r.name)),
        ("cycles", num(r.cycles)),
        ("time_s", num(r.time_s)),
        (
            "stages",
            arr(r.plan.stages.iter().map(|st| num(st.points as f64)).collect()),
        ),
        ("util", util_json(&r.util)),
        ("spm_requirement", num(r.spm_requirement)),
        ("noc_requirement", num(r.noc_requirement)),
        ("flops", num(r.flops)),
        ("flops_efficiency", num(r.flops_efficiency)),
        ("power_w", num(r.power_w)),
        ("energy_j", num(r.energy_j)),
        ("dma_bytes", num(r.dma_bytes)),
        ("dma_time_s", num(r.dma_time_s)),
        ("fill_time_s", num(r.fill_time_s)),
    ])
}

/// JSON view of one [`StreamResult`].
pub fn stream_json(r: &StreamResult) -> Json {
    obj(vec![
        ("batch", num(r.batch as f64)),
        ("batch_time_s", num(r.batch_time_s)),
        ("serial_time_s", num(r.serial_time_s)),
        ("overlapped_time_s", num(r.overlapped_time_s)),
        ("pipeline_efficiency", num(r.pipeline_efficiency)),
        ("arrays", num(r.arrays as f64)),
        ("overlap", s(r.overlap.name())),
        ("latency_ms", num(r.latency_ms)),
        ("throughput", num(r.throughput)),
        ("power_w", num(r.power_w)),
        ("energy_j", num(r.energy_j)),
        ("energy_eff", num(r.energy_eff)),
        ("kernels", arr(r.kernels.iter().map(kernel_json).collect())),
    ])
}

/// JSON view of one [`NetworkResult`] (per-layer and total metrics).
pub fn network_json(r: &NetworkResult) -> Json {
    obj(vec![
        ("network", s(&r.network)),
        ("spec", s(&r.spec)),
        ("batch", num(r.batch as f64)),
        ("batch_time_s", num(r.batch_time_s)),
        ("serial_time_s", num(r.serial_time_s)),
        ("overlapped_time_s", num(r.overlapped_time_s)),
        ("pipeline_efficiency", num(r.pipeline_efficiency)),
        ("arrays", num(r.arrays as f64)),
        ("overlap", s(r.overlap.name())),
        ("latency_ms", num(r.latency_ms)),
        ("throughput", num(r.throughput)),
        ("power_w", num(r.power_w)),
        ("energy_j", num(r.energy_j)),
        ("energy_eff", num(r.energy_eff)),
        ("util", util_json(&r.util)),
        ("layers", arr(r.layers.iter().map(layer_json).collect())),
    ])
}

fn layer_json(l: &LayerResult) -> Json {
    obj(vec![
        ("layer", num(l.layer as f64)),
        ("time_s", num(l.time_s)),
        ("energy_j", num(l.energy_j)),
        ("util", util_json(&l.util)),
        ("blocks", arr(l.blocks.iter().map(block_json).collect())),
    ])
}

fn block_json(b: &BlockResult) -> Json {
    let mut fields = vec![
        ("label", s(&b.label)),
        ("time_s", num(b.time_s)),
        ("energy_j", num(b.energy_j)),
        ("util", util_json(&b.util)),
        ("kernels", arr(b.kernels.iter().map(kernel_json).collect())),
    ];
    if let Some(d) = &b.dense {
        fields.push((
            "dense",
            obj(vec![
                ("name", s(&d.name)),
                ("flops", num(d.flops)),
                ("time_s", num(d.time_s)),
                ("power_w", num(d.power_w)),
                ("energy_j", num(d.energy_j)),
            ]),
        ));
    }
    obj(fields)
}

/// JSON view of a session's [`CacheStats`].
pub fn cache_json(c: &CacheStats) -> Json {
    obj(vec![
        ("plan_hits", num(c.plan_hits as f64)),
        ("plan_misses", num(c.plan_misses as f64)),
        ("stage_hits", num(c.stage_hits as f64)),
        ("stage_misses", num(c.stage_misses as f64)),
        ("structural_hits", num(c.structural_hits as f64)),
        ("structural_misses", num(c.structural_misses as f64)),
        ("lowerings", num(c.lowerings as f64)),
    ])
}

fn util_json(util: &[f64; 4]) -> Json {
    obj(UnitKind::ALL
        .iter()
        .map(|k| (k.name(), num(util[k.index()])))
        .collect())
}

fn sweep_row_json(row: &SweepRow) -> Json {
    obj(vec![
        ("division", s(&format!("{}x{}", row.division.0, row.division.1))),
        ("cycles", num(row.cycles)),
        ("util", util_json(&row.util)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Session;
    use crate::dfg::graph::KernelKind;
    use crate::util::json;
    use crate::workloads::KernelSpec;

    fn small_spec() -> KernelSpec {
        KernelSpec {
            name: "FFT-256".into(),
            kind: KernelKind::Fft,
            points: 256,
            vectors: 2048,
            d_in: 256,
            d_out: 256,
            seq: 256,
        }
    }

    #[test]
    fn kernel_report_roundtrips_through_parser() {
        let session = Session::builder().build();
        let result = session.run(&small_spec()).unwrap();
        let report = Report::Kernel {
            arch: session.arch_signature().to_string(),
            result,
        };
        let text = report.render();
        let parsed = json::parse(&text).unwrap();
        assert_eq!(parsed.req_str("report").unwrap(), "kernel");
        let r = parsed.req("result").unwrap();
        assert_eq!(r.req_str("name").unwrap(), "FFT-256");
        assert!(r.req_f64("cycles").unwrap() > 0.0);
        assert!(r.get("util").unwrap().get("Cal").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn stream_report_carries_cache_and_kernels() {
        let session = Session::builder().build();
        let ks = vec![small_spec(), small_spec()];
        let result = session.stream(&ks, 4).unwrap();
        let report = Report::Stream {
            arch: session.arch_signature().to_string(),
            workload: "test".into(),
            strategy: session.strategy(),
            cache: session.cache_stats(),
            result,
        };
        let parsed = json::parse(&report.render()).unwrap();
        assert_eq!(parsed.req_str("report").unwrap(), "stream");
        // The default strategy stays out of the stable layout; a
        // non-default one is serialized by name.
        assert!(parsed.get("strategy").is_none());
        let Report::Stream { arch, workload, cache, result, .. } = report else {
            unreachable!()
        };
        let tagged = Report::Stream {
            arch,
            workload,
            strategy: Strategy::SpmAdaptive,
            cache,
            result,
        };
        let parsed2 = json::parse(&tagged.render()).unwrap();
        assert_eq!(parsed2.req_str("strategy").unwrap(), "spm-adaptive");
        let result = parsed.req("result").unwrap();
        let kernels = result.get("kernels").unwrap();
        assert_eq!(kernels.as_arr().unwrap().len(), 2);
        // The overlap-schedule fields are part of the stable layout.
        assert_eq!(result.req_str("overlap").unwrap(), "none");
        assert_eq!(result.req_f64("arrays").unwrap(), 1.0);
        assert!(result.req_f64("serial_time_s").unwrap() > 0.0);
        assert!(
            result.req_f64("overlapped_time_s").unwrap()
                <= result.req_f64("serial_time_s").unwrap()
        );
        assert!(result.req_f64("pipeline_efficiency").unwrap() > 0.0);
        // The duplicate spec must have hit the stage cache.
        assert!(parsed.req("cache").unwrap().req_f64("stage_hits").unwrap() >= 1.0);
    }

    #[test]
    fn network_report_carries_layer_breakdown() {
        use crate::workloads::spec::{AttnSparsity, FfnForm, ModelSpec};
        let model = ModelSpec::builder("mix")
            .hidden(256)
            .seq(128)
            .batch(2)
            .attention(AttnSparsity::Fft2d)
            .next_layer()
            .attention(AttnSparsity::Dense)
            .ffn(FfnForm::Bpmm, 2)
            .build()
            .unwrap();
        let session = Session::builder().build();
        let result = session.run_network(&model, None).unwrap();
        let report = Report::Network {
            arch: session.arch_signature().to_string(),
            strategy: session.strategy(),
            cache: session.cache_stats(),
            result,
        };
        let parsed = json::parse(&report.render()).unwrap();
        assert_eq!(parsed.req_str("report").unwrap(), "network");
        assert!(parsed.get("strategy").is_none());
        let r = parsed.req("result").unwrap();
        assert_eq!(r.req_str("spec").unwrap(), "att:fft2d;att:dense,ffn:bpmm*x2");
        assert!(r.req_f64("latency_ms").unwrap() > 0.0);
        let layers = r.req("layers").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(layers.len(), 2);
        let blocks = layers[1].req("blocks").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(blocks[0].req_str("label").unwrap(), "att:dense");
        assert!(blocks[0].req("dense").unwrap().req_f64("time_s").unwrap() > 0.0);
    }

    #[test]
    fn serving_report_round_trips() {
        use crate::coordinator::serve::{ServeConfig, Traffic};
        let session = Session::builder().build();
        let traffic =
            Traffic::poisson(&["att:bpmm".to_string()], 2000.0, 0.05, 11).unwrap();
        let point = session.serve(&traffic, &ServeConfig::default()).unwrap();
        let report = Report::Serving {
            arch: session.arch_signature().to_string(),
            cache: session.cache_stats(),
            points: vec![point],
        };
        let parsed = json::parse(&report.render()).unwrap();
        assert_eq!(parsed.req_str("report").unwrap(), "serving");
        let points = parsed.req("points").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(points.len(), 1);
        let p = &points[0];
        assert!(p.req_f64("latency_p99_ms").unwrap() > 0.0);
        assert!(p.req_f64("goodput_rps").unwrap() > 0.0);
        assert!(p.req_f64("capacity_rps").unwrap() > 0.0);
        assert_eq!(p.req_str("overlap").unwrap(), "pipeline");
        let classes = p.req("classes").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(classes.len(), 1);
        assert_eq!(classes[0].req_str("spec").unwrap(), "att:bpmm");
        // Repeated batches of one class must share plans in the cache.
        assert!(parsed.req("cache").unwrap().req_f64("stage_hits").unwrap() >= 1.0);
    }

    #[test]
    fn pareto_report_round_trips() {
        use crate::arch::ArchConfig;
        use crate::coordinator::autotune::{
            sweep, AutotuneConfig, Journal, SearchSpace, WorkloadClass,
        };
        let space = SearchSpace::parse("arrays=1,2").unwrap();
        let classes = WorkloadClass::resolve(&["fabnet-128".to_string()], Some(2)).unwrap();
        let cfg = AutotuneConfig { window: 16, ..AutotuneConfig::default() };
        let result = sweep(
            &space,
            &ArchConfig::scaled_128(),
            &classes,
            &cfg,
            &Journal::in_memory(),
        )
        .unwrap();
        let report = Report::Pareto { result };
        let parsed = json::parse(&report.render()).unwrap();
        assert_eq!(parsed.req_str("report").unwrap(), "pareto");
        assert_eq!(parsed.req_str("objective").unwrap(), "edp");
        assert!(parsed.req_f64("points_total").unwrap() >= 2.0);
        let classes = parsed.req("classes").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(classes.len(), 1);
        let c = &classes[0];
        assert_eq!(c.req_str("class").unwrap(), "fabnet-128");
        let frontier = c.req("frontier").unwrap().as_arr().unwrap().to_vec();
        assert!(!frontier.is_empty());
        assert!(frontier[0].req_f64("latency_s").unwrap() > 0.0);
        assert!(frontier[0].req_f64("area_mm2").unwrap() > 0.0);
        let def = c.req("default_point").unwrap();
        assert!(def.get("on_frontier").is_some());
        // Run-dependent diagnostics stay out of the artifact.
        assert!(parsed.get("cache").is_none());
    }

    #[test]
    fn sweep_report_rows() {
        let report = Report::Sweep {
            arch: "a".into(),
            kernel: "BPMM-2048".into(),
            rows: vec![SweepRow { division: (32, 64), cycles: 10.0, util: [0.1, 0.2, 0.8, 0.1] }],
        };
        let parsed = json::parse(&report.render()).unwrap();
        let rows = parsed.req("rows").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].req_str("division").unwrap(), "32x64");
    }
}
