//! Whole-network execution results for hybrid butterfly-sparsity
//! networks ([`crate::workloads::spec::ModelSpec`]).
//!
//! [`super::Session::run_network`] lowers a network, runs every
//! butterfly kernel through the simulator (reusing the session's plan
//! cache across repeated blocks and layers), prices dense blocks with a
//! first-order roofline, and rolls the per-block measurements up into
//! per-layer and network totals.  The layer/block structure mirrors the
//! lowering's provenance, so a report can attribute latency and energy
//! to the exact block that caused it.
//!
//! Dense blocks (the accuracy anchor of a hybrid network) are *not*
//! cycle-simulated: the dataflow compiler only targets butterfly
//! sparsity.  They are priced as
//! `max(flops / (peak_flops × 0.75), bytes / ddr_bw)` — a dense GEMM
//! mapped on the MAC array without butterfly reuse reaches a fraction
//! of peak and is otherwise DDR-bound — at the array's active power.
//! The estimate is deterministic and first-order; per-kernel
//! cycle-accurate numbers come only from butterfly kernels.

use crate::arch::ArchConfig;
use crate::energy;
use crate::workloads::spec::DenseCost;

use super::experiment::KernelResult;

/// Fraction of the array's peak MACs a dense GEMM sustains (no
/// butterfly locality; systolic-style streaming with edge effects).
const DENSE_ARRAY_EFF: f64 = 0.75;

/// Analytic result of one dense block (roofline-priced; see module
/// docs).
#[derive(Debug, Clone)]
pub struct DenseResult {
    pub name: String,
    /// Dense FLOPs executed.
    pub flops: f64,
    pub time_s: f64,
    pub power_w: f64,
    pub energy_j: f64,
}

/// Price a dense block on the array (roofline over peak MACs and DDR).
pub(crate) fn eval_dense(arch: &ArchConfig, cost: &DenseCost) -> DenseResult {
    let compute_s = cost.flops / (arch.peak_flops() * DENSE_ARRAY_EFF);
    let mem_s = cost.elems * arch.elem_bytes as f64 / arch.ddr_bw();
    let time_s = compute_s.max(mem_s);
    let power_w = energy::array_power_w(arch);
    DenseResult {
        name: cost.name.clone(),
        flops: cost.flops,
        time_s,
        power_w,
        energy_j: power_w * time_s,
    }
}

/// One executed block: simulated butterfly kernels and/or an analytic
/// dense estimate, with the originating layer and grammar label.
#[derive(Debug, Clone)]
pub struct BlockResult {
    /// 0-based layer index (lowering provenance).
    pub layer: usize,
    /// Grammar label of the block, e.g. `att:fft2d`.
    pub label: String,
    /// Cycle-simulated butterfly kernels (empty for dense blocks).
    pub kernels: Vec<KernelResult>,
    /// Roofline estimate (dense blocks only).
    pub dense: Option<DenseResult>,
    /// Block wall time (kernel times + dense estimate).
    pub time_s: f64,
    pub energy_j: f64,
    /// Cycle-weighted utilization per unit kind over the block's
    /// butterfly kernels (zeros for dense-only blocks).
    pub util: [f64; 4],
}

impl BlockResult {
    pub(crate) fn new(
        layer: usize,
        label: String,
        kernels: Vec<KernelResult>,
        dense: Option<DenseResult>,
    ) -> Self {
        let mut time_s: f64 = kernels.iter().map(|k| k.time_s).sum();
        let mut energy_j: f64 = kernels.iter().map(|k| k.energy_j).sum();
        if let Some(d) = &dense {
            time_s += d.time_s;
            energy_j += d.energy_j;
        }
        let util = weighted_util(kernels.iter());
        BlockResult { layer, label, kernels, dense, time_s, energy_j, util }
    }
}

/// Per-layer rollup.
#[derive(Debug, Clone)]
pub struct LayerResult {
    pub layer: usize,
    pub blocks: Vec<BlockResult>,
    pub time_s: f64,
    pub energy_j: f64,
    /// Cycle-weighted utilization per unit kind over the layer's
    /// butterfly kernels (zeros for all-dense layers).
    pub util: [f64; 4],
}

/// End-to-end network result: per-layer breakdown plus batch totals
/// (the Table-IV metric set generalized to arbitrary hybrids).
#[derive(Debug, Clone)]
pub struct NetworkResult {
    /// Network name (model name or suite name).
    pub network: String,
    /// Canonical spec-grammar string of the network.
    pub spec: String,
    /// Batch the network was lowered at.
    pub batch: usize,
    pub layers: Vec<LayerResult>,
    /// Total batch time (s).
    pub batch_time_s: f64,
    /// Per-prediction latency (ms).
    pub latency_ms: f64,
    /// Predictions per second.
    pub throughput: f64,
    /// Time-weighted effective power (W).
    pub power_w: f64,
    pub energy_j: f64,
    /// Predictions per joule.
    pub energy_eff: f64,
    /// Cycle-weighted utilization over all butterfly kernels.
    pub util: [f64; 4],
}

/// Cycle-weighted average utilization of a kernel set.
fn weighted_util<'a>(kernels: impl Iterator<Item = &'a KernelResult>) -> [f64; 4] {
    let mut acc = [0.0f64; 4];
    let mut cycles = 0.0f64;
    for k in kernels {
        for (a, u) in acc.iter_mut().zip(k.util.iter()) {
            *a += u * k.cycles;
        }
        cycles += k.cycles;
    }
    if cycles > 0.0 {
        for a in acc.iter_mut() {
            *a /= cycles;
        }
    }
    acc
}

/// Roll lowered-order block results up into layers and network totals.
/// Blocks must arrive in lowering order (grouped by ascending layer).
pub(crate) fn assemble(
    network: String,
    spec: String,
    batch: usize,
    blocks: Vec<BlockResult>,
) -> NetworkResult {
    let mut layers: Vec<LayerResult> = Vec::new();
    for b in blocks {
        if layers.last().map(|l| l.layer) != Some(b.layer) {
            layers.push(LayerResult {
                layer: b.layer,
                blocks: Vec::new(),
                time_s: 0.0,
                energy_j: 0.0,
                util: [0.0; 4],
            });
        }
        let l = layers.last_mut().expect("layer pushed above");
        l.time_s += b.time_s;
        l.energy_j += b.energy_j;
        l.blocks.push(b);
    }
    for l in &mut layers {
        l.util = weighted_util(l.blocks.iter().flat_map(|b| b.kernels.iter()));
    }
    let batch_time_s: f64 = layers.iter().map(|l| l.time_s).sum();
    let energy_j: f64 = layers.iter().map(|l| l.energy_j).sum();
    let util = weighted_util(
        layers
            .iter()
            .flat_map(|l| l.blocks.iter())
            .flat_map(|b| b.kernels.iter()),
    );
    let latency_s = batch_time_s / batch.max(1) as f64;
    NetworkResult {
        network,
        spec,
        batch,
        layers,
        batch_time_s,
        latency_ms: latency_s * 1e3,
        throughput: if latency_s > 0.0 { 1.0 / latency_s } else { 0.0 },
        power_w: if batch_time_s > 0.0 { energy_j / batch_time_s } else { 0.0 },
        energy_j,
        energy_eff: if energy_j > 0.0 { batch as f64 / energy_j } else { 0.0 },
        util,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Session;
    use crate::workloads::spec::{AttnSparsity, FfnForm, ModelSpec};

    fn mixed_model() -> ModelSpec {
        ModelSpec::builder("mixed")
            .hidden(256)
            .seq(128)
            .batch(2)
            .attention(AttnSparsity::Fft2d)
            .ffn(FfnForm::Bpmm, 2)
            .next_layer()
            .attention(AttnSparsity::Dense)
            .ffn(FfnForm::Bpmm, 2)
            .build()
            .unwrap()
    }

    #[test]
    fn network_totals_are_layer_sums() {
        let session = Session::builder().build();
        let r = session.run_network(&mixed_model(), None).unwrap();
        assert_eq!(r.layers.len(), 2);
        let t: f64 = r.layers.iter().map(|l| l.time_s).sum();
        let e: f64 = r.layers.iter().map(|l| l.energy_j).sum();
        assert!((r.batch_time_s - t).abs() < 1e-12);
        assert!((r.energy_j - e).abs() < 1e-12);
        assert!(r.latency_ms > 0.0 && r.throughput > 0.0);
        assert!(r.power_w > 0.0);
    }

    #[test]
    fn dense_blocks_cost_time_without_kernels() {
        let session = Session::builder().build();
        let r = session.run_network(&mixed_model(), None).unwrap();
        let dense_att = &r.layers[1].blocks[0];
        assert_eq!(dense_att.label, "att:dense");
        assert!(dense_att.kernels.is_empty());
        let d = dense_att.dense.as_ref().expect("dense estimate");
        assert!(d.time_s > 0.0 && d.energy_j > 0.0);
        assert!((dense_att.time_s - d.time_s).abs() < 1e-15);
    }

    #[test]
    fn repeated_layers_hit_the_plan_cache() {
        let session = Session::builder().build();
        let model = ModelSpec::builder("deep")
            .hidden(256)
            .seq(128)
            .batch(2)
            .attention(AttnSparsity::Fft2d)
            .ffn(FfnForm::Bpmm, 2)
            .repeat(4)
            .build()
            .unwrap();
        let r = session.run_network(&model, None).unwrap();
        let kernel_count: usize =
            r.layers.iter().flat_map(|l| &l.blocks).map(|b| b.kernels.len()).sum();
        assert_eq!(kernel_count, 16);
        let stats = session.cache_stats();
        assert!(
            stats.lowerings < kernel_count as u64,
            "repeated layers must reuse lowered programs: {stats:?}"
        );
    }

    #[test]
    fn run_network_rejects_zero_batch() {
        let session = Session::builder().build();
        let err = session
            .run_network(&mixed_model(), Some(0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("batch"), "unexpected error: {err}");
    }

    #[test]
    fn batch_override_scales_batch_time_not_latency() {
        let session = Session::builder().build();
        let a = session.run_network(&mixed_model(), Some(2)).unwrap();
        let b = session.run_network(&mixed_model(), Some(8)).unwrap();
        assert!(b.batch_time_s > a.batch_time_s);
        let ratio = a.latency_ms / b.latency_ms;
        assert!((0.5..2.0).contains(&ratio), "latency ratio {ratio}");
    }
}
