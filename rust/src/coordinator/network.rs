//! Whole-network execution results for hybrid butterfly-sparsity
//! networks ([`crate::workloads::spec::ModelSpec`]).
//!
//! [`super::Session::run_network`] lowers a network, runs every
//! butterfly kernel through the simulator (reusing the session's plan
//! cache across repeated blocks and layers), prices dense blocks with a
//! first-order roofline, and rolls the per-block measurements up into
//! per-layer and network totals.  The layer/block structure mirrors the
//! lowering's provenance, so a report can attribute latency and energy
//! to the exact block that caused it.
//!
//! Dense blocks (the accuracy anchor of a hybrid network) are *not*
//! cycle-simulated: the dataflow compiler only targets butterfly
//! sparsity.  They are priced as
//! `max(flops / (peak_flops × 0.75), bytes / ddr_bw)` — a dense GEMM
//! mapped on the MAC array without butterfly reuse reaches a fraction
//! of peak and is otherwise DDR-bound — at the array's active power.
//! The estimate is deterministic and first-order; per-kernel
//! cycle-accurate numbers come only from butterfly kernels.

use crate::arch::ArchConfig;
use crate::energy;
use crate::workloads::spec::DenseCost;

use super::experiment::KernelResult;
use super::pipeline::{self, Overlap, PipelineConfig, StageCost};
use super::streaming;

/// Fraction of the array's peak MACs a dense GEMM sustains (no
/// butterfly locality; systolic-style streaming with edge effects).
const DENSE_ARRAY_EFF: f64 = 0.75;

/// Analytic result of one dense block (roofline-priced; see module
/// docs).
#[derive(Debug, Clone)]
pub struct DenseResult {
    pub name: String,
    /// Dense FLOPs executed.
    pub flops: f64,
    pub time_s: f64,
    pub power_w: f64,
    pub energy_j: f64,
}

/// Price a dense block on the array (roofline over peak MACs and DDR).
pub(crate) fn eval_dense(arch: &ArchConfig, cost: &DenseCost) -> DenseResult {
    let compute_s = cost.flops / (arch.peak_flops() * DENSE_ARRAY_EFF);
    let mem_s = cost.elems * arch.elem_bytes as f64 / arch.ddr_bw();
    let time_s = compute_s.max(mem_s);
    let power_w = energy::array_power_w(arch);
    DenseResult {
        name: cost.name.clone(),
        flops: cost.flops,
        time_s,
        power_w,
        energy_j: power_w * time_s,
    }
}

/// One executed block: simulated butterfly kernels and/or an analytic
/// dense estimate, with the originating layer and grammar label.
#[derive(Debug, Clone)]
pub struct BlockResult {
    /// 0-based layer index (lowering provenance).
    pub layer: usize,
    /// Grammar label of the block, e.g. `att:fft2d`.
    pub label: String,
    /// Cycle-simulated butterfly kernels (empty for dense blocks).
    pub kernels: Vec<KernelResult>,
    /// Roofline estimate (dense blocks only).
    pub dense: Option<DenseResult>,
    /// Block wall time (kernel times + dense estimate).
    pub time_s: f64,
    pub energy_j: f64,
    /// Cycle-weighted utilization per unit kind over the block's
    /// butterfly kernels (zeros for dense-only blocks).
    pub util: [f64; 4],
}

impl BlockResult {
    pub(crate) fn new(
        layer: usize,
        label: String,
        kernels: Vec<KernelResult>,
        dense: Option<DenseResult>,
    ) -> Self {
        let mut time_s: f64 = kernels.iter().map(|k| k.time_s).sum();
        let mut energy_j: f64 = kernels.iter().map(|k| k.energy_j).sum();
        if let Some(d) = &dense {
            time_s += d.time_s;
            energy_j += d.energy_j;
        }
        let util = weighted_util(kernels.iter());
        BlockResult { layer, label, kernels, dense, time_s, energy_j, util }
    }
}

/// Per-layer rollup.
#[derive(Debug, Clone)]
pub struct LayerResult {
    pub layer: usize,
    pub blocks: Vec<BlockResult>,
    pub time_s: f64,
    pub energy_j: f64,
    /// Cycle-weighted utilization per unit kind over the layer's
    /// butterfly kernels (zeros for all-dense layers).
    pub util: [f64; 4],
}

/// End-to-end network result: per-layer breakdown plus batch totals
/// (the Table-IV metric set generalized to arbitrary hybrids).
#[derive(Debug, Clone)]
pub struct NetworkResult {
    /// Network name (model name or suite name).
    pub network: String,
    /// Canonical spec-grammar string of the network.
    pub spec: String,
    /// Batch the network was lowered at.
    pub batch: usize,
    pub layers: Vec<LayerResult>,
    /// Effective batch makespan (s) under the configured overlap mode
    /// and array count (equals `serial_time_s` for `Overlap::None` on
    /// a single array; with more arrays even serial mode shards the
    /// batch).
    pub batch_time_s: f64,
    /// Serial reference: sum of all layer times (s).
    pub serial_time_s: f64,
    /// Overlapped makespan (s); always ≤ `serial_time_s`, and equal to
    /// `batch_time_s`.
    pub overlapped_time_s: f64,
    /// Achieved fraction of the shard's aggregate capacity bound
    /// (total compute vs total gating DMA), in (0, 1].
    pub pipeline_efficiency: f64,
    /// Replicated dataflow arrays the batch was sharded across.
    pub arrays: usize,
    /// Overlap mode the schedule was computed under.
    pub overlap: Overlap,
    /// Per-prediction latency (ms).
    pub latency_ms: f64,
    /// Predictions per second.
    pub throughput: f64,
    /// Time-weighted effective power (W) over all arrays.
    pub power_w: f64,
    /// Total energy (J): active block energy plus idle-replica energy.
    pub energy_j: f64,
    /// Predictions per joule.
    pub energy_eff: f64,
    /// Cycle-weighted utilization over all butterfly kernels.
    pub util: [f64; 4],
}

impl NetworkResult {
    /// Speedup of the overlapped schedule over the serial sum (≥ 1).
    pub fn speedup(&self) -> f64 {
        pipeline::speedup(self.serial_time_s, self.overlapped_time_s)
    }
}

/// Cycle-weighted average utilization of a kernel set.
fn weighted_util<'a>(kernels: impl Iterator<Item = &'a KernelResult>) -> [f64; 4] {
    let mut acc = [0.0f64; 4];
    let mut cycles = 0.0f64;
    for k in kernels {
        for (a, u) in acc.iter_mut().zip(k.util.iter()) {
            *a += u * k.cycles;
        }
        cycles += k.cycles;
    }
    if cycles > 0.0 {
        for a in acc.iter_mut() {
            *a /= cycles;
        }
    }
    acc
}

/// Roll lowered-order block results up into layers and network totals,
/// then schedule the whole kernel/block sequence under `cfg` (see
/// [`super::pipeline`]): consecutive batch elements occupy successive
/// layers concurrently, and the batch shards across `cfg.arrays`
/// replicated arrays.  Blocks must arrive in lowering order (grouped by
/// ascending layer).
pub(crate) fn assemble(
    network: String,
    spec: String,
    batch: usize,
    blocks: Vec<BlockResult>,
    cfg: PipelineConfig,
    idle_power_w: f64,
) -> NetworkResult {
    let mut layers: Vec<LayerResult> = Vec::new();
    for b in blocks {
        if layers.last().map(|l| l.layer) != Some(b.layer) {
            layers.push(LayerResult {
                layer: b.layer,
                blocks: Vec::new(),
                time_s: 0.0,
                energy_j: 0.0,
                util: [0.0; 4],
            });
        }
        let l = layers.last_mut().expect("layer pushed above");
        l.time_s += b.time_s;
        l.energy_j += b.energy_j;
        l.blocks.push(b);
    }
    for l in &mut layers {
        l.util = weighted_util(l.blocks.iter().flat_map(|b| b.kernels.iter()));
    }
    let serial_time_s: f64 = layers.iter().map(|l| l.time_s).sum();
    let active_energy_j: f64 = layers.iter().map(|l| l.energy_j).sum();
    let util = weighted_util(
        layers
            .iter()
            .flat_map(|l| l.blocks.iter())
            .flat_map(|b| b.kernels.iter()),
    );
    // Pipeline stages in lowering order: every simulated butterfly
    // kernel is a stage with its measured DMA split; dense roofline
    // blocks are serial-only stages (no measured split to overlap).
    let stages: Vec<StageCost> = layers
        .iter()
        .flat_map(|l| l.blocks.iter())
        .flat_map(|b| {
            b.kernels
                .iter()
                .map(StageCost::of_kernel)
                .chain(b.dense.iter().map(|d| StageCost::serial_only(d.time_s)))
        })
        .collect();
    let est = pipeline::schedule(&stages, batch.max(1), cfg, idle_power_w);
    // Serial mode on an undivided batch is the legacy accounting: keep
    // the layer-grouped sum (same floats as v0.3) as the makespan.
    let full_shard = batch.max(1).div_ceil(cfg.arrays.max(1)) == batch.max(1);
    let legacy = cfg.overlap == Overlap::None && full_shard;
    // The estimate's serial reference sums per-kernel, ours per-layer;
    // clamp so `overlapped ≤ serial` holds exactly, not up-to-rounding.
    let batch_time_s =
        if legacy { serial_time_s } else { est.overlapped_time_s.min(serial_time_s) };
    let energy_j = active_energy_j + est.idle_energy_j;
    let (latency_ms, throughput, power_w, energy_eff) =
        streaming::per_prediction_metrics(batch.max(1), batch_time_s, energy_j);
    NetworkResult {
        network,
        spec,
        batch,
        layers,
        batch_time_s,
        serial_time_s,
        overlapped_time_s: batch_time_s,
        pipeline_efficiency: est.pipeline_efficiency,
        arrays: est.arrays,
        overlap: est.overlap,
        latency_ms,
        throughput,
        power_w,
        energy_j,
        energy_eff,
        util,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Session;
    use crate::workloads::spec::{AttnSparsity, FfnForm, ModelSpec};

    fn mixed_model() -> ModelSpec {
        ModelSpec::builder("mixed")
            .hidden(256)
            .seq(128)
            .batch(2)
            .attention(AttnSparsity::Fft2d)
            .ffn(FfnForm::Bpmm, 2)
            .next_layer()
            .attention(AttnSparsity::Dense)
            .ffn(FfnForm::Bpmm, 2)
            .build()
            .unwrap()
    }

    #[test]
    fn network_totals_are_layer_sums() {
        let session = Session::builder().build();
        let r = session.run_network(&mixed_model(), None).unwrap();
        assert_eq!(r.layers.len(), 2);
        let t: f64 = r.layers.iter().map(|l| l.time_s).sum();
        let e: f64 = r.layers.iter().map(|l| l.energy_j).sum();
        assert!((r.batch_time_s - t).abs() < 1e-12);
        assert!((r.energy_j - e).abs() < 1e-12);
        assert!(r.latency_ms > 0.0 && r.throughput > 0.0);
        assert!(r.power_w > 0.0);
    }

    #[test]
    fn dense_blocks_cost_time_without_kernels() {
        let session = Session::builder().build();
        let r = session.run_network(&mixed_model(), None).unwrap();
        let dense_att = &r.layers[1].blocks[0];
        assert_eq!(dense_att.label, "att:dense");
        assert!(dense_att.kernels.is_empty());
        let d = dense_att.dense.as_ref().expect("dense estimate");
        assert!(d.time_s > 0.0 && d.energy_j > 0.0);
        assert!((dense_att.time_s - d.time_s).abs() < 1e-15);
    }

    #[test]
    fn repeated_layers_hit_the_plan_cache() {
        let session = Session::builder().build();
        let model = ModelSpec::builder("deep")
            .hidden(256)
            .seq(128)
            .batch(2)
            .attention(AttnSparsity::Fft2d)
            .ffn(FfnForm::Bpmm, 2)
            .repeat(4)
            .build()
            .unwrap();
        let r = session.run_network(&model, None).unwrap();
        let kernel_count: usize =
            r.layers.iter().flat_map(|l| &l.blocks).map(|b| b.kernels.len()).sum();
        assert_eq!(kernel_count, 16);
        let stats = session.cache_stats();
        assert!(
            stats.lowerings < kernel_count as u64,
            "repeated layers must reuse lowered programs: {stats:?}"
        );
    }

    #[test]
    fn network_pipeline_never_exceeds_serial() {
        use crate::coordinator::pipeline::{Overlap, PipelineConfig};
        let session = Session::builder().build();
        let model = mixed_model();
        let legacy = session.run_network(&model, None).unwrap();
        assert_eq!(legacy.batch_time_s, legacy.serial_time_s);
        assert_eq!(legacy.overlap, Overlap::None);
        for (overlap, arrays) in
            [(Overlap::Dma, 1), (Overlap::Pipeline, 1), (Overlap::Pipeline, 4)]
        {
            let r = session
                .run_network_with(&model, None, PipelineConfig::new(overlap, arrays))
                .unwrap();
            assert!(
                r.overlapped_time_s <= r.serial_time_s,
                "{overlap:?}/{arrays}: {} > {}",
                r.overlapped_time_s,
                r.serial_time_s
            );
            assert!(r.pipeline_efficiency > 0.0 && r.pipeline_efficiency <= 1.0);
            assert!(r.speedup() >= 1.0);
            assert_eq!(r.arrays, arrays);
            // The per-layer simulated breakdown is mode-independent.
            assert_eq!(r.layers.len(), legacy.layers.len());
            assert_eq!(r.serial_time_s, legacy.serial_time_s);
        }
    }

    #[test]
    fn run_network_rejects_zero_batch() {
        let session = Session::builder().build();
        let err = session
            .run_network(&mixed_model(), Some(0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("batch"), "unexpected error: {err}");
    }

    #[test]
    fn batch_override_scales_batch_time_not_latency() {
        let session = Session::builder().build();
        let a = session.run_network(&mixed_model(), Some(2)).unwrap();
        let b = session.run_network(&mixed_model(), Some(8)).unwrap();
        assert!(b.batch_time_s > a.batch_time_s);
        let ratio = a.latency_ms / b.latency_ms;
        assert!((0.5..2.0).contains(&ratio), "latency ratio {ratio}");
    }
}
