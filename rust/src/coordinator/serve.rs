//! Serving-at-scale simulation: trace-driven traffic, dynamic batching,
//! and SLO percentiles under load.
//!
//! The paper's Table IV methodology streams one fixed batch through the
//! array and reports its average latency — a *throughput* experiment.
//! Serving "millions of users" is a different question: requests arrive
//! asynchronously, latency includes queueing, and capacity is
//! "requests/s/array at a fixed p99", not "batch time at batch 256".
//! This module layers a discrete-event serving loop over everything the
//! lower layers already measure:
//!
//! 1. **Traffic generator** ([`Traffic`]): deterministic Poisson
//!    arrivals (seeded [`Rng::exp`] inter-arrivals, so a fixed seed is
//!    bit-reproducible and rate sweeps are time-scaled copies of one
//!    arrival pattern) or an explicit JSON trace file, over *mixed*
//!    request classes — any registered suite name or spec-grammar
//!    string ([`crate::workloads::resolve_model`]), e.g. `bert-4k` next
//!    to `vit-256` next to `att:fft2d,ffn:bpmm*x2`.
//! 2. **Dynamic batcher**: queued requests of one class pack into a
//!    batch when the class reaches [`ServeConfig::max_batch`] or its
//!    oldest request has waited [`ServeConfig::max_wait_s`], whichever
//!    comes first (and a replica array is free).  Batch cost comes from
//!    the plan-cached [`Session::run_network_with`] pipeline schedule,
//!    so many concurrent classes share one session cache — the
//!    multi-tenant property that keeps per-request marginal simulation
//!    cost near zero ([`Session::cache_stats`] makes it observable).
//! 3. **Serving loop**: a deterministic discrete-event simulation over
//!    [`ServeConfig::arrays`] replica arrays (each executes one batch
//!    at a time) with a bounded admission queue
//!    ([`ServeConfig::queue_cap`]) — arrivals beyond it are rejected —
//!    tracking per-request queueing delay and end-to-end latency.
//! 4. **Report** ([`ServeResult`]): p50/p95/p99/mean latency, goodput
//!    (completed requests/s over the makespan), the analytic capacity
//!    bound goodput saturates at, utilization, queue-depth stats and a
//!    per-class breakdown — serialized as `Report::Serving` and plotted
//!    by `bfdf serve-sim` / `BENCH_serving.json`.
//!
//! The layering, bottom-up: the cycle-level simulator prices one kernel
//! window; the analytic overlap model ([`super::pipeline`]) prices one
//! *batch* (DMA double buffering + inter-kernel pipelining on one
//! array); this module prices a *workload of batches over time*.  All
//! three are deterministic, so a fixed traffic seed reproduces the
//! whole load/latency curve bit-for-bit.
//!
//! ```no_run
//! use butterfly_dataflow::coordinator::{Session, serve::{ServeConfig, Traffic}};
//!
//! let session = Session::builder().build();
//! let traffic = Traffic::poisson(
//!     &["vanilla".into(), "att:fft2d,ffn:bpmm*x2".into()], 500.0, 1.0, 42)?;
//! let result = session.serve(&traffic, &ServeConfig::default())?;
//! println!("p99 {:.2} ms at {:.0} req/s", result.latency_p99_ms, result.goodput_rps);
//! # anyhow::Ok(())
//! ```

use std::collections::{HashMap, VecDeque};

use anyhow::{ensure, Result};

use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workloads::{resolve_model, spec::ModelSpec};

use super::pipeline::{Overlap, PipelineConfig};
use super::session::Session;

/// Dynamic-batcher and serving-loop knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max requests packed into one batch (per class).
    pub max_batch: usize,
    /// Max time the oldest queued request of a class waits before a
    /// partial batch dispatches anyway (seconds).
    pub max_wait_s: f64,
    /// Replica dataflow arrays; each serves one batch at a time (this
    /// is concurrency *across* batches — per-batch sharding stays a
    /// [`super::pipeline`] concern and is not applied here).
    pub arrays: usize,
    /// Bounded admission queue (total across classes); arrivals beyond
    /// it are rejected.
    pub queue_cap: usize,
    /// Per-batch streaming overlap model (the paper-faithful default is
    /// [`Overlap::Pipeline`], matching the CLI).
    pub overlap: Overlap,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_wait_s: 2e-3,
            arrays: 1,
            queue_cap: 256,
            overlap: Overlap::Pipeline,
        }
    }
}

/// One request arrival: a time and an index into [`Traffic::classes`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub t_s: f64,
    pub class: usize,
}

/// A request stream over mixed request classes.
#[derive(Debug, Clone)]
pub struct Traffic {
    /// The distinct request classes (each one whole network to run per
    /// request), resolved via [`resolve_model`].
    pub classes: Vec<ModelSpec>,
    /// Arrivals sorted by time.
    pub arrivals: Vec<Arrival>,
    /// Arrival horizon (s): Poisson generation stops here; for traces,
    /// the last arrival time.  Denominator of the offered rate.
    pub duration_s: f64,
}

impl Traffic {
    /// Deterministic Poisson traffic: exponential inter-arrivals at
    /// `rate_rps` over `[0, duration_s)`, class drawn uniformly per
    /// arrival.  Exactly one `exp` draw plus one class draw per arrival
    /// (in that order), so two rates from the same seed produce
    /// time-scaled copies of one arrival/class sequence — rate sweeps
    /// compare the *same* workload under compression, which is what
    /// makes their latency curves monotone.
    pub fn poisson(keys: &[String], rate_rps: f64, duration_s: f64, seed: u64) -> Result<Traffic> {
        ensure!(!keys.is_empty(), "poisson traffic needs at least one workload class");
        ensure!(
            rate_rps > 0.0 && rate_rps.is_finite(),
            "arrival rate must be positive and finite (got {rate_rps})"
        );
        ensure!(
            duration_s > 0.0 && duration_s.is_finite(),
            "traffic duration must be positive and finite (got {duration_s})"
        );
        let classes: Vec<ModelSpec> =
            keys.iter().map(|k| resolve_model(k)).collect::<Result<_>>()?;
        let mut rng = Rng::new(seed);
        let mut arrivals = Vec::new();
        let mut t = 0.0f64;
        loop {
            t += rng.exp(rate_rps);
            // Class draw happens unconditionally so the per-arrival
            // draw count is rate-independent (see the scaling note).
            let class = rng.below(classes.len() as u64) as usize;
            if t >= duration_s {
                break;
            }
            arrivals.push(Arrival { t_s: t, class });
        }
        Ok(Traffic { classes, arrivals, duration_s })
    }

    /// Parse a JSON trace document (see the README "Serving simulation"
    /// section):
    ///
    /// ```json
    /// {"arrivals": [{"t": 0.000, "workload": "bert-4k"},
    ///               {"t": 0.0012, "workload": "att:fft2d,ffn:bpmm*x2"}]}
    /// ```
    ///
    /// `t` is the arrival time in seconds; `workload` is a suite name
    /// or spec string.  Arrivals may appear in any order (they are
    /// stably sorted by time); classes are numbered by first
    /// appearance.
    pub fn from_trace_str(text: &str) -> Result<Traffic> {
        let doc = json::parse(text)?;
        let items = doc
            .req("arrivals")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("trace \"arrivals\" must be an array"))?;
        ensure!(!items.is_empty(), "trace has no arrivals");
        let mut keys: Vec<String> = Vec::new();
        let mut arrivals = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            let t = item
                .req_f64("t")
                .map_err(|e| anyhow::anyhow!("trace arrival {i}: {e}"))?;
            ensure!(
                t.is_finite() && t >= 0.0,
                "trace arrival {i}: time must be finite and >= 0 (got {t})"
            );
            let w = item
                .req_str("workload")
                .map_err(|e| anyhow::anyhow!("trace arrival {i}: {e}"))?;
            let class = match keys.iter().position(|k| k == w) {
                Some(c) => c,
                None => {
                    keys.push(w.to_string());
                    keys.len() - 1
                }
            };
            arrivals.push(Arrival { t_s: t, class });
        }
        arrivals.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).expect("finite times"));
        let classes: Vec<ModelSpec> =
            keys.iter().map(|k| resolve_model(k)).collect::<Result<_>>()?;
        let duration_s = arrivals.last().map(|a| a.t_s).unwrap_or(0.0);
        Ok(Traffic { classes, arrivals, duration_s })
    }

    /// [`Traffic::from_trace_str`] over a file path.
    pub fn from_trace_file(path: &str) -> Result<Traffic> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read trace file '{path}': {e}"))?;
        Self::from_trace_str(&text)
    }
}

/// Per-class slice of a serving run.
#[derive(Debug, Clone)]
pub struct ClassServeStats {
    /// Class name (suite name, or the spec string itself).
    pub name: String,
    /// Canonical spec-grammar string of the class network.
    pub spec: String,
    pub offered: u64,
    pub rejected: u64,
    pub completed: u64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
}

/// Result of one serving simulation (one point of a load/latency
/// curve).
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// Offered load: arrivals / duration (req/s).
    pub offered_rate_rps: f64,
    /// Arrival horizon of the traffic (s).
    pub duration_s: f64,
    pub offered: u64,
    pub admitted: u64,
    /// Arrivals bounced off the full admission queue.
    pub rejected: u64,
    pub completed: u64,
    /// Last event time: queue drain may extend past `duration_s`.
    pub makespan_s: f64,
    /// Completed requests/s over the makespan — saturates at
    /// `capacity_rps` under overload.
    pub goodput_rps: f64,
    /// Analytic ceiling: `arrays × max_batch / (mix-weighted service
    /// time of a full batch)`.
    pub capacity_rps: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_mean_ms: f64,
    pub latency_max_ms: f64,
    pub queue_delay_mean_ms: f64,
    pub queue_delay_p99_ms: f64,
    /// Event-sampled queue depth (total across classes).
    pub queue_depth_mean: f64,
    pub queue_depth_max: usize,
    /// Batches dispatched and their mean size.
    pub batches: u64,
    pub mean_batch: f64,
    /// Mean busy fraction across the replica arrays over the makespan.
    pub utilization: f64,
    /// Active service energy of all dispatched batches (J).
    pub energy_j: f64,
    pub energy_per_req_j: f64,
    pub arrays: usize,
    pub max_batch: usize,
    pub max_wait_s: f64,
    pub queue_cap: usize,
    pub overlap: Overlap,
    pub classes: Vec<ClassServeStats>,
}

impl ServeResult {
    /// JSON view (one point of `Report::Serving`).
    pub fn to_json(&self) -> Json {
        use crate::util::json::{arr, num, obj, s};
        obj(vec![
            ("offered_rate_rps", num(self.offered_rate_rps)),
            ("duration_s", num(self.duration_s)),
            ("offered", num(self.offered as f64)),
            ("admitted", num(self.admitted as f64)),
            ("rejected", num(self.rejected as f64)),
            ("completed", num(self.completed as f64)),
            ("makespan_s", num(self.makespan_s)),
            ("goodput_rps", num(self.goodput_rps)),
            ("capacity_rps", num(self.capacity_rps)),
            ("latency_p50_ms", num(self.latency_p50_ms)),
            ("latency_p95_ms", num(self.latency_p95_ms)),
            ("latency_p99_ms", num(self.latency_p99_ms)),
            ("latency_mean_ms", num(self.latency_mean_ms)),
            ("latency_max_ms", num(self.latency_max_ms)),
            ("queue_delay_mean_ms", num(self.queue_delay_mean_ms)),
            ("queue_delay_p99_ms", num(self.queue_delay_p99_ms)),
            ("queue_depth_mean", num(self.queue_depth_mean)),
            ("queue_depth_max", num(self.queue_depth_max as f64)),
            ("batches", num(self.batches as f64)),
            ("mean_batch", num(self.mean_batch)),
            ("utilization", num(self.utilization)),
            ("energy_j", num(self.energy_j)),
            ("energy_per_req_j", num(self.energy_per_req_j)),
            ("arrays", num(self.arrays as f64)),
            ("max_batch", num(self.max_batch as f64)),
            ("max_wait_ms", num(self.max_wait_s * 1e3)),
            ("queue_cap", num(self.queue_cap as f64)),
            ("overlap", s(self.overlap.name())),
            (
                "classes",
                arr(self
                    .classes
                    .iter()
                    .map(|c| {
                        obj(vec![
                            ("name", s(&c.name)),
                            ("spec", s(&c.spec)),
                            ("offered", num(c.offered as f64)),
                            ("rejected", num(c.rejected as f64)),
                            ("completed", num(c.completed as f64)),
                            ("latency_p50_ms", num(c.latency_p50_ms)),
                            ("latency_p99_ms", num(c.latency_p99_ms)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Raw counters and samples the event loop produces (assembled into a
/// [`ServeResult`] by [`simulate`]).
struct LoopStats {
    offered: u64,
    admitted: u64,
    rejected: u64,
    completed: u64,
    batches: u64,
    batch_elems: u64,
    latency_ms: Summary,
    queue_delay_ms: Summary,
    depth: Summary,
    depth_max: usize,
    busy_s: Vec<f64>,
    free_at: Vec<f64>,
    energy_j: f64,
    last_event_s: f64,
    class_offered: Vec<u64>,
    class_rejected: Vec<u64>,
    class_completed: Vec<u64>,
    class_latency_ms: Vec<Summary>,
}

impl LoopStats {
    fn new(nclasses: usize, arrays: usize) -> Self {
        LoopStats {
            offered: 0,
            admitted: 0,
            rejected: 0,
            completed: 0,
            batches: 0,
            batch_elems: 0,
            latency_ms: Summary::new(),
            queue_delay_ms: Summary::new(),
            depth: Summary::new(),
            depth_max: 0,
            busy_s: vec![0.0; arrays],
            free_at: vec![0.0; arrays],
            energy_j: 0.0,
            last_event_s: 0.0,
            class_offered: vec![0; nclasses],
            class_rejected: vec![0; nclasses],
            class_completed: vec![0; nclasses],
            class_latency_ms: vec![Summary::new(); nclasses],
        }
    }

    fn sample_depth(&mut self, queued: usize) {
        self.depth.push(queued as f64);
        self.depth_max = self.depth_max.max(queued);
    }
}

/// The deterministic discrete-event loop.  `service(class, batch)`
/// returns the batch's `(service_seconds, energy_joules)`; in
/// production it is the memoized pipeline schedule, in unit tests a
/// synthetic closure.  Event order is total and deterministic: at each
/// step the earliest of (next arrival, earliest eligible dispatch)
/// fires, arrivals winning ties so a request arriving exactly at a
/// dispatch instant still joins the batch.
fn run_loop(
    arrivals: &[Arrival],
    nclasses: usize,
    cfg: &ServeConfig,
    service: &mut dyn FnMut(usize, usize) -> Result<(f64, f64)>,
) -> Result<LoopStats> {
    let mut st = LoopStats::new(nclasses, cfg.arrays);
    let mut queues: Vec<VecDeque<f64>> = vec![VecDeque::new(); nclasses];
    let mut queued = 0usize;
    let mut i = 0usize;
    let mut now = 0.0f64;
    loop {
        let t_arr = arrivals.get(i).map(|a| a.t_s);
        // Earliest-free replica (lowest index on ties).
        let (srv, t_free) = st
            .free_at
            .iter()
            .copied()
            .enumerate()
            .fold((0usize, f64::INFINITY), |acc, (j, t)| if t < acc.1 { (j, t) } else { acc });
        // Earliest eligible dispatch across nonempty classes: a class
        // is ready when full (max_batch queued) or its head request has
        // waited max_wait; either way a replica must be free.  Ties go
        // to the earliest head arrival (closest to starvation), then
        // the lowest class index — a total, deterministic order.
        let mut best: Option<(f64, f64, usize)> = None;
        for (c, q) in queues.iter().enumerate() {
            if let Some(&head) = q.front() {
                let trigger =
                    if q.len() >= cfg.max_batch { now } else { head + cfg.max_wait_s };
                let cand = (t_free.max(trigger).max(now), head, c);
                best = Some(match best {
                    Some(b) if (b.0, b.1, b.2) <= (cand.0, cand.1, cand.2) => b,
                    _ => cand,
                });
            }
        }
        // Decide the next event: the earlier of (next arrival, chosen
        // dispatch), arrivals winning exact ties so a request arriving
        // at a dispatch instant still joins the batch.
        enum Next {
            Done,
            Arrival(f64),
            Dispatch(f64, usize),
        }
        let next = match (t_arr, best) {
            (None, None) => Next::Done,
            (Some(ta), None) => Next::Arrival(ta),
            (None, Some((td, _, c))) => Next::Dispatch(td, c),
            (Some(ta), Some((td, _, c))) => {
                if ta <= td {
                    Next::Arrival(ta)
                } else {
                    Next::Dispatch(td, c)
                }
            }
        };
        match next {
            Next::Done => break,
            Next::Arrival(ta) => {
                now = now.max(ta);
                let a = arrivals[i];
                i += 1;
                st.offered += 1;
                st.class_offered[a.class] += 1;
                if queued >= cfg.queue_cap {
                    st.rejected += 1;
                    st.class_rejected[a.class] += 1;
                } else {
                    queues[a.class].push_back(a.t_s);
                    queued += 1;
                    st.admitted += 1;
                }
                st.sample_depth(queued);
                st.last_event_s = st.last_event_s.max(now);
            }
            Next::Dispatch(td, c) => {
                now = now.max(td);
                let b = queues[c].len().min(cfg.max_batch);
                let (svc_s, energy_j) = service(c, b)?;
                let done = now + svc_s;
                st.free_at[srv] = done;
                st.busy_s[srv] += svc_s;
                st.energy_j += energy_j;
                st.batches += 1;
                st.batch_elems += b as u64;
                for _ in 0..b {
                    let arr_t = queues[c].pop_front().expect("batch size <= queue len");
                    queued -= 1;
                    st.queue_delay_ms.push((now - arr_t) * 1e3);
                    let lat_ms = (done - arr_t) * 1e3;
                    st.latency_ms.push(lat_ms);
                    st.class_latency_ms[c].push(lat_ms);
                }
                st.completed += b as u64;
                st.class_completed[c] += b as u64;
                st.sample_depth(queued);
                st.last_event_s = st.last_event_s.max(done);
            }
        }
    }
    Ok(st)
}

/// Run the serving simulation: batch costs come from the session's
/// plan-cached pipeline schedule (`run_network_with` on one array under
/// [`ServeConfig::overlap`]), memoized per `(class, batch-size)` so the
/// event loop pays for each distinct shape once.
pub fn simulate(session: &Session, traffic: &Traffic, cfg: &ServeConfig) -> Result<ServeResult> {
    ensure!(cfg.max_batch >= 1, "serve max_batch must be >= 1");
    ensure!(cfg.arrays >= 1, "serve arrays must be >= 1");
    ensure!(cfg.queue_cap >= 1, "serve queue_cap must be >= 1");
    ensure!(
        cfg.max_wait_s >= 0.0 && cfg.max_wait_s.is_finite(),
        "serve max_wait must be finite and >= 0 (got {})",
        cfg.max_wait_s
    );
    ensure!(!traffic.classes.is_empty(), "traffic has no request classes");
    for a in &traffic.arrivals {
        ensure!(
            a.class < traffic.classes.len(),
            "arrival references class {} but only {} classes exist",
            a.class,
            traffic.classes.len()
        );
    }
    let pipe = PipelineConfig::new(cfg.overlap, 1);
    let mut memo: HashMap<(usize, usize), (f64, f64)> = HashMap::new();
    let mut service = |c: usize, b: usize| -> Result<(f64, f64)> {
        if let Some(&hit) = memo.get(&(c, b)) {
            return Ok(hit);
        }
        let r = session.run_network_with(&traffic.classes[c], Some(b), pipe)?;
        let v = (r.batch_time_s, r.energy_j);
        memo.insert((c, b), v);
        Ok(v)
    };
    let st = run_loop(&traffic.arrivals, traffic.classes.len(), cfg, &mut service)?;

    // Capacity bound: one replica serving full batches of the offered
    // mix sustains max_batch / (mix-weighted full-batch service time)
    // requests/s.  This is what goodput saturates at under overload.
    let mut weighted_svc = 0.0f64;
    if st.offered > 0 {
        for c in 0..traffic.classes.len() {
            if st.class_offered[c] > 0 {
                let (svc, _) = service(c, cfg.max_batch)?;
                weighted_svc += st.class_offered[c] as f64 / st.offered as f64 * svc;
            }
        }
    }
    let capacity_rps = if weighted_svc > 0.0 {
        cfg.arrays as f64 * cfg.max_batch as f64 / weighted_svc
    } else {
        0.0
    };

    let makespan_s = st.last_event_s;
    let lat = st.latency_ms.percentiles(&[50.0, 95.0, 99.0]);
    let served = !st.latency_ms.is_empty();
    let classes = traffic
        .classes
        .iter()
        .enumerate()
        .map(|(c, m)| {
            let p = st.class_latency_ms[c].percentiles(&[50.0, 99.0]);
            let has = !st.class_latency_ms[c].is_empty();
            ClassServeStats {
                name: m.name().to_string(),
                spec: m.spec_string(),
                offered: st.class_offered[c],
                rejected: st.class_rejected[c],
                completed: st.class_completed[c],
                latency_p50_ms: if has { p[0] } else { 0.0 },
                latency_p99_ms: if has { p[1] } else { 0.0 },
            }
        })
        .collect();
    Ok(ServeResult {
        offered_rate_rps: if traffic.duration_s > 0.0 {
            st.offered as f64 / traffic.duration_s
        } else {
            0.0
        },
        duration_s: traffic.duration_s,
        offered: st.offered,
        admitted: st.admitted,
        rejected: st.rejected,
        completed: st.completed,
        makespan_s,
        goodput_rps: if makespan_s > 0.0 { st.completed as f64 / makespan_s } else { 0.0 },
        capacity_rps,
        latency_p50_ms: if served { lat[0] } else { 0.0 },
        latency_p95_ms: if served { lat[1] } else { 0.0 },
        latency_p99_ms: if served { lat[2] } else { 0.0 },
        latency_mean_ms: if served { st.latency_ms.mean() } else { 0.0 },
        latency_max_ms: if served { st.latency_ms.max() } else { 0.0 },
        queue_delay_mean_ms: if served { st.queue_delay_ms.mean() } else { 0.0 },
        queue_delay_p99_ms: if served { st.queue_delay_ms.percentile(99.0) } else { 0.0 },
        queue_depth_mean: if st.depth.is_empty() { 0.0 } else { st.depth.mean() },
        queue_depth_max: st.depth_max,
        batches: st.batches,
        mean_batch: if st.batches > 0 {
            st.batch_elems as f64 / st.batches as f64
        } else {
            0.0
        },
        utilization: if makespan_s > 0.0 {
            st.busy_s.iter().sum::<f64>() / (cfg.arrays as f64 * makespan_s)
        } else {
            0.0
        },
        energy_j: st.energy_j,
        energy_per_req_j: if st.completed > 0 {
            st.energy_j / st.completed as f64
        } else {
            0.0
        },
        arrays: cfg.arrays,
        max_batch: cfg.max_batch,
        max_wait_s: cfg.max_wait_s,
        queue_cap: cfg.queue_cap,
        overlap: cfg.overlap,
        classes,
    })
}

impl Session {
    /// Run the discrete-event serving simulation on this session (see
    /// [`simulate`]): traffic arrives, the dynamic batcher packs
    /// it, replica arrays execute plan-cached pipeline schedules, and
    /// the result is the SLO view — latency percentiles, goodput and
    /// utilization under load.
    pub fn serve(&self, traffic: &Traffic, cfg: &ServeConfig) -> Result<ServeResult> {
        simulate(self, traffic, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, max_wait_s: f64, arrays: usize, queue_cap: usize) -> ServeConfig {
        ServeConfig { max_batch, max_wait_s, arrays, queue_cap, overlap: Overlap::Pipeline }
    }

    fn arrivals(ts: &[(f64, usize)]) -> Vec<Arrival> {
        ts.iter().map(|&(t_s, class)| Arrival { t_s, class }).collect()
    }

    /// Constant 10 ms service regardless of class/batch; 1 J per batch.
    fn flat_service() -> impl FnMut(usize, usize) -> Result<(f64, f64)> {
        |_c, _b| Ok((0.010, 1.0))
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let a = arrivals(&[(0.0, 0), (0.0, 0), (0.0, 0), (0.0, 0)]);
        let st = run_loop(&a, 1, &cfg(4, 1.0, 1, 64), &mut flat_service()).unwrap();
        assert_eq!(st.batches, 1);
        assert_eq!(st.completed, 4);
        // No queueing: dispatched the instant the batch filled.
        assert_eq!(st.queue_delay_ms.max(), 0.0);
        assert_eq!(st.latency_ms.max(), 10.0);
        assert_eq!(st.last_event_s, 0.010);
    }

    #[test]
    fn max_wait_flushes_partial_batches() {
        // One lonely request must not wait for a batch that never
        // fills: it dispatches after max_wait.
        let a = arrivals(&[(0.0, 0)]);
        let st = run_loop(&a, 1, &cfg(8, 0.005, 1, 64), &mut flat_service()).unwrap();
        assert_eq!(st.batches, 1);
        assert_eq!(st.batch_elems, 1);
        assert!((st.queue_delay_ms.max() - 5.0).abs() < 1e-9);
        assert!((st.latency_ms.max() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn bounded_queue_rejects_overflow() {
        let a = arrivals(&[(0.0, 0), (0.0, 0), (0.0, 0), (0.0, 0), (0.0, 0)]);
        let st = run_loop(&a, 1, &cfg(2, 1.0, 1, 2), &mut flat_service()).unwrap();
        assert_eq!(st.offered, 5);
        assert_eq!(st.admitted, 2);
        assert_eq!(st.rejected, 3);
        assert_eq!(st.completed, 2);
        assert_eq!(st.class_rejected[0], 3);
        assert_eq!(st.depth_max, 2);
    }

    #[test]
    fn classes_batch_separately_and_fifo_by_head_age() {
        // Class 1 arrives first; both time out; the single replica must
        // serve class 1 first (earliest head), then class 0.
        let a = arrivals(&[(0.0, 1), (0.001, 0)]);
        let st = run_loop(&a, 2, &cfg(4, 0.010, 1, 64), &mut flat_service()).unwrap();
        assert_eq!(st.batches, 2, "classes never share a batch");
        assert_eq!(st.class_completed, vec![1, 1]);
        // Class 1: waits its full max_wait (dispatch 0.010, done 0.020).
        assert!((st.class_latency_ms[1].max() - 20.0).abs() < 1e-9);
        // Class 0: its deadline (0.011) coincides with the replica
        // freeing at 0.020 -> dispatched then, done 0.030.
        assert!((st.class_latency_ms[0].max() - (0.030 - 0.001) * 1e3).abs() < 1e-9);
    }

    #[test]
    fn replicas_serve_batches_concurrently() {
        let a = arrivals(&[(0.0, 0), (0.0, 0)]);
        let one = run_loop(&a, 1, &cfg(1, 0.0, 1, 64), &mut flat_service()).unwrap();
        let two = run_loop(&a, 1, &cfg(1, 0.0, 2, 64), &mut flat_service()).unwrap();
        assert_eq!(one.batches, 2);
        assert_eq!(two.batches, 2);
        assert!((one.last_event_s - 0.020).abs() < 1e-12, "serial replicas");
        assert!((two.last_event_s - 0.010).abs() < 1e-12, "parallel replicas");
        assert_eq!(two.busy_s, vec![0.010, 0.010]);
    }

    #[test]
    fn compressed_arrivals_never_lower_tail_latency() {
        // The rate-sweep property at loop level: the same arrival
        // pattern compressed in time (higher offered rate) cannot
        // reduce the latency percentiles.
        let base: Vec<(f64, usize)> = (0..64).map(|i| (i as f64 * 0.004, 0)).collect();
        let mut last_p99 = 0.0f64;
        for compress in [1.0, 2.0, 8.0] {
            let a: Vec<Arrival> = base
                .iter()
                .map(|&(t, c)| Arrival { t_s: t / compress, class: c })
                .collect();
            let st = run_loop(&a, 1, &cfg(4, 0.002, 1, 32), &mut flat_service()).unwrap();
            let p99 = st.latency_ms.percentile(99.0);
            assert!(
                p99 >= last_p99 - 1e-9,
                "compression {compress}: p99 {p99} < previous {last_p99}"
            );
            last_p99 = p99;
        }
        assert!(last_p99 > 10.0, "overload must show queueing beyond pure service");
    }

    #[test]
    fn poisson_traffic_is_seed_deterministic_and_rate_scaled() {
        let keys = vec!["att:bpmm".to_string()];
        let a = Traffic::poisson(&keys, 100.0, 0.5, 9).unwrap();
        let b = Traffic::poisson(&keys, 100.0, 0.5, 9).unwrap();
        assert_eq!(a.arrivals, b.arrivals);
        assert!(!a.arrivals.is_empty());
        assert!(a.arrivals.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        // Doubled rate = halved times, element for element (common
        // prefix; the faster stream has at least as many arrivals).
        let fast = Traffic::poisson(&keys, 200.0, 0.5, 9).unwrap();
        assert!(fast.arrivals.len() >= a.arrivals.len());
        for (s, f) in a.arrivals.iter().zip(&fast.arrivals) {
            assert!((s.t_s - 2.0 * f.t_s).abs() < 1e-12);
            assert_eq!(s.class, f.class);
        }
    }

    #[test]
    fn poisson_traffic_mixes_classes() {
        let keys = vec!["att:bpmm".to_string(), "att:fft2d".to_string()];
        let t = Traffic::poisson(&keys, 2000.0, 0.5, 3).unwrap();
        assert_eq!(t.classes.len(), 2);
        let ones = t.arrivals.iter().filter(|a| a.class == 1).count();
        assert!(ones > 0 && ones < t.arrivals.len(), "both classes must appear");
    }

    #[test]
    fn trace_parses_sorts_and_dedups_classes() {
        let text = r#"{"arrivals": [
            {"t": 0.002, "workload": "att:bpmm"},
            {"t": 0.000, "workload": "vanilla"},
            {"t": 0.001, "workload": "att:bpmm"}
        ]}"#;
        let t = Traffic::from_trace_str(text).unwrap();
        assert_eq!(t.classes.len(), 2);
        assert_eq!(t.arrivals.len(), 3);
        assert!(t.arrivals.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        assert_eq!(t.arrivals[0].class, 1, "vanilla arrived first after sorting");
        assert!((t.duration_s - 0.002).abs() < 1e-15);
        assert!(Traffic::from_trace_str(r#"{"arrivals": []}"#).is_err());
        assert!(Traffic::from_trace_str(r#"{"arrivals": [{"t": -1.0, "workload": "x"}]}"#)
            .is_err());
    }

    #[test]
    fn degenerate_config_is_rejected() {
        let session = Session::builder().build();
        let traffic =
            Traffic::poisson(&["att:bpmm".to_string()], 100.0, 0.05, 1).unwrap();
        for bad in [
            ServeConfig { max_batch: 0, ..ServeConfig::default() },
            ServeConfig { arrays: 0, ..ServeConfig::default() },
            ServeConfig { queue_cap: 0, ..ServeConfig::default() },
            ServeConfig { max_wait_s: f64::NAN, ..ServeConfig::default() },
        ] {
            assert!(session.serve(&traffic, &bad).is_err(), "{bad:?}");
        }
    }
}
