//! Serving-at-scale simulation: trace-driven traffic, dynamic batching,
//! and SLO percentiles under load.
//!
//! The paper's Table IV methodology streams one fixed batch through the
//! array and reports its average latency — a *throughput* experiment.
//! Serving "millions of users" is a different question: requests arrive
//! asynchronously, latency includes queueing, and capacity is
//! "requests/s/array at a fixed p99", not "batch time at batch 256".
//! This module layers a discrete-event serving loop over everything the
//! lower layers already measure:
//!
//! 1. **Traffic generator** ([`Traffic`]): deterministic Poisson
//!    arrivals (seeded [`Rng::exp`] inter-arrivals, so a fixed seed is
//!    bit-reproducible and rate sweeps are time-scaled copies of one
//!    arrival pattern) or an explicit JSON trace file, over *mixed*
//!    request classes — any registered suite name or spec-grammar
//!    string ([`crate::workloads::resolve_model`]), e.g. `bert-4k` next
//!    to `vit-256` next to `att:fft2d,ffn:bpmm*x2`.
//! 2. **Dynamic batcher**: queued requests of one class pack into a
//!    batch when the class reaches [`ServeConfig::max_batch`] or its
//!    oldest request has waited [`ServeConfig::max_wait_s`], whichever
//!    comes first (and a replica array is free).  Batch cost comes from
//!    the plan-cached [`Session::run_network_with`] pipeline schedule,
//!    so many concurrent classes share one session cache — the
//!    multi-tenant property that keeps per-request marginal simulation
//!    cost near zero ([`Session::cache_stats`] makes it observable).
//! 3. **Serving loop**: a deterministic discrete-event simulation over
//!    [`ServeConfig::arrays`] replica arrays (each executes one batch
//!    at a time) with a bounded admission queue
//!    ([`ServeConfig::queue_cap`]) — arrivals beyond it are rejected —
//!    tracking per-request queueing delay and end-to-end latency.
//! 4. **Report** ([`ServeResult`]): p50/p95/p99/mean latency, goodput
//!    (completed requests/s over the makespan), the analytic capacity
//!    bound goodput saturates at, utilization, queue-depth stats and a
//!    per-class breakdown — serialized as `Report::Serving` and plotted
//!    by `bfdf serve-sim` / `BENCH_serving.json`.
//!
//! The layering, bottom-up: the cycle-level simulator prices one kernel
//! window; the analytic overlap model ([`super::pipeline`]) prices one
//! *batch* (DMA double buffering + inter-kernel pipelining on one
//! array); this module prices a *workload of batches over time*.  All
//! three are deterministic, so a fixed traffic seed reproduces the
//! whole load/latency curve bit-for-bit.
//!
//! **Fault tolerance** (all default-off): replica arrays can fail and
//! recover on a seeded MTBF/MTTR process or a scripted trace
//! ([`ReplicaFaults`]); batches in flight on a failed replica are lost
//! and their requests retried with capped exponential backoff up to
//! [`ServeConfig::max_retries`]; requests can carry a deadline
//! ([`ServeConfig::deadline_s`], stale queued work cancels at batch
//! formation); and admission is pluggable ([`Admission`]) — FIFO
//! tail-drop or SLO-aware shedding of the request least likely to meet
//! its deadline.  With none of these configured the event loop runs
//! the original fault-free path *verbatim*, keeping every pre-fault
//! artifact byte-identical.
//!
//! ```no_run
//! use butterfly_dataflow::coordinator::{Session, serve::{ServeConfig, Traffic}};
//!
//! let session = Session::builder().build();
//! let traffic = Traffic::poisson(
//!     &["vanilla".into(), "att:fft2d,ffn:bpmm*x2".into()], 500.0, 1.0, 42)?;
//! let result = session.serve(&traffic, &ServeConfig::default())?;
//! println!("p99 {:.2} ms at {:.0} req/s", result.latency_p99_ms, result.goodput_rps);
//! # anyhow::Ok(())
//! ```

use std::collections::{HashMap, VecDeque};

use anyhow::{ensure, Result};

use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workloads::{resolve_model, spec::ModelSpec};

use super::pipeline::{Overlap, PipelineConfig};
use super::session::Session;

/// Admission policy for arrivals that find the queue full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Blind tail-drop: the newcomer bounces off the full queue.
    Fifo,
    /// Shed the queued-or-arriving request *least likely to meet its
    /// deadline* (estimated dispatch delay from queue position plus the
    /// memoized full-batch service time of its class), admitting the
    /// newcomer if some queued request is more doomed.  Requires
    /// [`ServeConfig::deadline_s`]; without a deadline there is no
    /// slack to rank by and the policy degrades to [`Admission::Fifo`].
    SloAware,
}

impl Admission {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fifo" => Ok(Admission::Fifo),
            "slo" | "slo-aware" => Ok(Admission::SloAware),
            other => {
                anyhow::bail!("unknown admission policy '{other}' (policies: fifo, slo-aware)")
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Admission::Fifo => "fifo",
            Admission::SloAware => "slo-aware",
        }
    }
}

/// One replica up/down transition at an absolute time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaEvent {
    pub t_s: f64,
    /// Replica array index (`< ServeConfig::arrays`).
    pub replica: usize,
    /// `false` = the replica fails at `t_s`; `true` = it recovers.
    pub up: bool,
}

/// Replica failure/recovery source: a seeded stochastic process or an
/// explicit scripted trace (mirroring the traffic-trace JSON).
#[derive(Debug, Clone, PartialEq)]
pub enum ReplicaFaults {
    /// Each replica alternates exponential up-times (mean `mtbf_s`) and
    /// repair times (mean `mttr_s`) from its own seeded stream, so a
    /// fixed seed reproduces the whole failure schedule bit-for-bit.
    Process { mtbf_s: f64, mttr_s: f64, seed: u64 },
    /// Scripted transitions (any order; stably sorted by time).
    Trace(Vec<ReplicaEvent>),
}

impl ReplicaFaults {
    /// Parse a JSON fault-trace document (see the README "Fault
    /// tolerance" section):
    ///
    /// ```json
    /// {"events": [{"t": 0.050, "replica": 0, "up": false},
    ///             {"t": 0.120, "replica": 0, "up": true}]}
    /// ```
    ///
    /// `t` is the transition time in seconds; `replica` indexes the
    /// replica arrays; `up: false` fails the replica, `up: true`
    /// recovers it.
    pub fn from_trace_str(text: &str) -> Result<Self> {
        let doc = json::parse(text)?;
        let items = doc
            .req("events")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("fault trace \"events\" must be an array"))?;
        ensure!(!items.is_empty(), "fault trace has no events");
        let mut events = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            let t = item
                .req_f64("t")
                .map_err(|e| anyhow::anyhow!("fault event {i}: {e}"))?;
            ensure!(
                t.is_finite() && t >= 0.0,
                "fault event {i}: time must be finite and >= 0 (got {t})"
            );
            let replica = item
                .req("replica")
                .and_then(|j| {
                    j.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("JSON key 'replica' is not a number"))
                })
                .map_err(|e| anyhow::anyhow!("fault event {i}: {e}"))?;
            let up = item
                .req("up")
                .and_then(|j| {
                    j.as_bool()
                        .ok_or_else(|| anyhow::anyhow!("JSON key 'up' is not a boolean"))
                })
                .map_err(|e| anyhow::anyhow!("fault event {i}: {e}"))?;
            events.push(ReplicaEvent { t_s: t, replica, up });
        }
        Ok(ReplicaFaults::Trace(events))
    }

    /// [`ReplicaFaults::from_trace_str`] over a file path.
    pub fn from_trace_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read fault trace file '{path}': {e}"))?;
        Self::from_trace_str(&text)
    }
}

/// Expand a fault source into a sorted transition list for the event
/// loop, validated against the replica count.
fn expand_fault_events(
    faults: &ReplicaFaults,
    arrays: usize,
    duration_s: f64,
) -> Result<Vec<ReplicaEvent>> {
    let mut events = match faults {
        ReplicaFaults::Trace(evs) => {
            for e in evs {
                ensure!(
                    e.t_s.is_finite() && e.t_s >= 0.0,
                    "fault event time must be finite and >= 0 (got {})",
                    e.t_s
                );
                ensure!(
                    e.replica < arrays,
                    "fault trace references replica {} but the run has {} replica arrays",
                    e.replica,
                    arrays
                );
            }
            evs.clone()
        }
        ReplicaFaults::Process { mtbf_s, mttr_s, seed } => {
            ensure!(
                *mtbf_s > 0.0 && mtbf_s.is_finite(),
                "replica MTBF must be positive and finite (got {mtbf_s})"
            );
            ensure!(
                *mttr_s > 0.0 && mttr_s.is_finite(),
                "replica MTTR must be positive and finite (got {mttr_s})"
            );
            // Generate past the arrival horizon so the drain phase still
            // sees recoveries; events beyond the makespan are inert.
            let horizon = duration_s * 4.0 + 1.0;
            let mut evs = Vec::new();
            for r in 0..arrays {
                // One independent stream per replica (seed mixed with
                // the replica index) so adding a replica never perturbs
                // the failure schedule of the others.
                let mut rng =
                    Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(r as u64 + 1));
                let mut t = 0.0f64;
                loop {
                    t += rng.exp(1.0 / mtbf_s);
                    if t >= horizon {
                        break;
                    }
                    evs.push(ReplicaEvent { t_s: t, replica: r, up: false });
                    t += rng.exp(1.0 / mttr_s);
                    if t >= horizon {
                        break;
                    }
                    evs.push(ReplicaEvent { t_s: t, replica: r, up: true });
                }
            }
            evs
        }
    };
    events.sort_by(|a, b| {
        a.t_s
            .partial_cmp(&b.t_s)
            .expect("finite fault times")
            .then(a.replica.cmp(&b.replica))
    });
    Ok(events)
}

/// Dynamic-batcher and serving-loop knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max requests packed into one batch (per class).
    pub max_batch: usize,
    /// Max time the oldest queued request of a class waits before a
    /// partial batch dispatches anyway (seconds).
    pub max_wait_s: f64,
    /// Replica dataflow arrays; each serves one batch at a time (this
    /// is concurrency *across* batches — per-batch sharding stays a
    /// [`super::pipeline`] concern and is not applied here).
    pub arrays: usize,
    /// Bounded admission queue (total across classes); arrivals beyond
    /// it are rejected.
    pub queue_cap: usize,
    /// Per-batch streaming overlap model (the paper-faithful default is
    /// [`Overlap::Pipeline`], matching the CLI).
    pub overlap: Overlap,
    /// Policy for arrivals that find the queue full.
    pub admission: Admission,
    /// End-to-end deadline per request (s): requests still queued past
    /// it are cancelled (`timed_out`) at the next dispatch instead of
    /// wasting a batch slot.  `None` disables deadlines.
    pub deadline_s: Option<f64>,
    /// Replica failure/recovery schedule; `None` (the default) keeps
    /// every replica up and the event loop on the exact pre-fault path.
    pub faults: Option<ReplicaFaults>,
    /// Service attempts per request before it counts as `lost` (a
    /// request killed mid-batch by a replica failure re-enqueues with
    /// capped exponential backoff up to this many times).
    pub max_retries: u32,
    /// Base retry backoff (s); attempt `n` waits `2^(n-1)` times this,
    /// capped at 64x.
    pub retry_backoff_s: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_wait_s: 2e-3,
            arrays: 1,
            queue_cap: 256,
            overlap: Overlap::Pipeline,
            admission: Admission::Fifo,
            deadline_s: None,
            faults: None,
            max_retries: 3,
            retry_backoff_s: 5e-3,
        }
    }
}

/// One request arrival: a time and an index into [`Traffic::classes`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub t_s: f64,
    pub class: usize,
}

/// A request stream over mixed request classes.
#[derive(Debug, Clone)]
pub struct Traffic {
    /// The distinct request classes (each one whole network to run per
    /// request), resolved via [`resolve_model`].
    pub classes: Vec<ModelSpec>,
    /// Arrivals sorted by time.
    pub arrivals: Vec<Arrival>,
    /// Arrival horizon (s): Poisson generation stops here; for traces,
    /// the last arrival time.  Denominator of the offered rate.
    pub duration_s: f64,
}

impl Traffic {
    /// Deterministic Poisson traffic: exponential inter-arrivals at
    /// `rate_rps` over `[0, duration_s)`, class drawn uniformly per
    /// arrival.  Exactly one `exp` draw plus one class draw per arrival
    /// (in that order), so two rates from the same seed produce
    /// time-scaled copies of one arrival/class sequence — rate sweeps
    /// compare the *same* workload under compression, which is what
    /// makes their latency curves monotone.
    pub fn poisson(keys: &[String], rate_rps: f64, duration_s: f64, seed: u64) -> Result<Traffic> {
        ensure!(!keys.is_empty(), "poisson traffic needs at least one workload class");
        ensure!(
            rate_rps > 0.0 && rate_rps.is_finite(),
            "arrival rate must be positive and finite (got {rate_rps})"
        );
        ensure!(
            duration_s > 0.0 && duration_s.is_finite(),
            "traffic duration must be positive and finite (got {duration_s})"
        );
        let classes: Vec<ModelSpec> =
            keys.iter().map(|k| resolve_model(k)).collect::<Result<_>>()?;
        let mut rng = Rng::new(seed);
        let mut arrivals = Vec::new();
        let mut t = 0.0f64;
        loop {
            t += rng.exp(rate_rps);
            // Class draw happens unconditionally so the per-arrival
            // draw count is rate-independent (see the scaling note).
            let class = rng.below(classes.len() as u64) as usize;
            if t >= duration_s {
                break;
            }
            arrivals.push(Arrival { t_s: t, class });
        }
        Ok(Traffic { classes, arrivals, duration_s })
    }

    /// Parse a JSON trace document (see the README "Serving simulation"
    /// section):
    ///
    /// ```json
    /// {"arrivals": [{"t": 0.000, "workload": "bert-4k"},
    ///               {"t": 0.0012, "workload": "att:fft2d,ffn:bpmm*x2"}]}
    /// ```
    ///
    /// `t` is the arrival time in seconds; `workload` is a suite name
    /// or spec string.  Arrivals may appear in any order (they are
    /// stably sorted by time); classes are numbered by first
    /// appearance.
    pub fn from_trace_str(text: &str) -> Result<Traffic> {
        let doc = json::parse(text)?;
        let items = doc
            .req("arrivals")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("trace \"arrivals\" must be an array"))?;
        ensure!(!items.is_empty(), "trace has no arrivals");
        let mut keys: Vec<String> = Vec::new();
        let mut arrivals = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            let t = item
                .req_f64("t")
                .map_err(|e| anyhow::anyhow!("trace arrival {i}: {e}"))?;
            ensure!(
                t.is_finite() && t >= 0.0,
                "trace arrival {i}: time must be finite and >= 0 (got {t})"
            );
            let w = item
                .req_str("workload")
                .map_err(|e| anyhow::anyhow!("trace arrival {i}: {e}"))?;
            let class = match keys.iter().position(|k| k == w) {
                Some(c) => c,
                None => {
                    keys.push(w.to_string());
                    keys.len() - 1
                }
            };
            arrivals.push(Arrival { t_s: t, class });
        }
        arrivals.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).expect("finite times"));
        let classes: Vec<ModelSpec> =
            keys.iter().map(|k| resolve_model(k)).collect::<Result<_>>()?;
        let duration_s = arrivals.last().map(|a| a.t_s).unwrap_or(0.0);
        Ok(Traffic { classes, arrivals, duration_s })
    }

    /// [`Traffic::from_trace_str`] over a file path.
    pub fn from_trace_file(path: &str) -> Result<Traffic> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read trace file '{path}': {e}"))?;
        Self::from_trace_str(&text)
    }
}

/// Per-class slice of a serving run.
#[derive(Debug, Clone)]
pub struct ClassServeStats {
    /// Class name (suite name, or the spec string itself).
    pub name: String,
    /// Canonical spec-grammar string of the class network.
    pub spec: String,
    pub offered: u64,
    pub rejected: u64,
    pub completed: u64,
    /// Cancelled in queue past their deadline.
    pub timed_out: u64,
    /// Dropped by [`Admission::SloAware`] load shedding.
    pub shed: u64,
    /// Admitted but never completed: killed by replica failures past
    /// the retry budget, or stranded when no replica ever recovered.
    pub lost: u64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
}

/// Result of one serving simulation (one point of a load/latency
/// curve).
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// Offered load: arrivals / duration (req/s).
    pub offered_rate_rps: f64,
    /// Arrival horizon of the traffic (s).
    pub duration_s: f64,
    pub offered: u64,
    pub admitted: u64,
    /// Arrivals bounced off the full admission queue.
    pub rejected: u64,
    pub completed: u64,
    /// Last event time: queue drain may extend past `duration_s`.
    pub makespan_s: f64,
    /// Completed requests/s over the makespan — saturates at
    /// `capacity_rps` under overload.
    pub goodput_rps: f64,
    /// Analytic ceiling: `arrays × max_batch / (mix-weighted service
    /// time of a full batch)`.
    pub capacity_rps: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_mean_ms: f64,
    pub latency_max_ms: f64,
    pub queue_delay_mean_ms: f64,
    pub queue_delay_p99_ms: f64,
    /// Event-sampled queue depth (total across classes).
    pub queue_depth_mean: f64,
    pub queue_depth_max: usize,
    /// Batches dispatched and their mean size.
    pub batches: u64,
    pub mean_batch: f64,
    /// Mean busy fraction across the replica arrays over the makespan.
    pub utilization: f64,
    /// Active service energy of all dispatched batches (J).
    pub energy_j: f64,
    pub energy_per_req_j: f64,
    pub arrays: usize,
    pub max_batch: usize,
    pub max_wait_s: f64,
    pub queue_cap: usize,
    pub overlap: Overlap,
    /// Admission policy the run used.
    pub admission: Admission,
    /// Per-request deadline, if any.
    pub deadline_s: Option<f64>,
    /// Whether a replica fault schedule was configured (distinct from
    /// "any fault fired": a quiet schedule still flips the loop onto
    /// the robustness path and is reported as such).
    pub faults_configured: bool,
    /// Requests cancelled in queue past their deadline.
    pub timed_out: u64,
    /// Requests dropped by SLO-aware load shedding.
    pub shed: u64,
    /// Requests admitted but never completed (replica failures).
    pub lost: u64,
    /// Re-enqueues after a replica failure killed an in-flight batch.
    pub retries: u64,
    /// Up replica-seconds / (arrays x makespan); 1.0 without faults.
    pub availability: f64,
    /// `capacity_rps` scaled by availability: the ceiling goodput can
    /// actually reach given the replica-seconds that existed.
    pub degraded_capacity_rps: f64,
    pub classes: Vec<ClassServeStats>,
}

impl ServeResult {
    /// True when any robustness feature was *configured* (faults, a
    /// non-FIFO admission policy, or deadlines).  Gates serialization
    /// of the robustness block on configuration — not outcomes — so a
    /// fault-free run stays byte-identical to the pre-fault format.
    pub fn robustness_on(&self) -> bool {
        self.faults_configured || self.admission != Admission::Fifo || self.deadline_s.is_some()
    }

    /// JSON view (one point of `Report::Serving`).
    pub fn to_json(&self) -> Json {
        use crate::util::json::{arr, num, obj, s};
        let robust = self.robustness_on();
        let mut pairs = vec![
            ("offered_rate_rps", num(self.offered_rate_rps)),
            ("duration_s", num(self.duration_s)),
            ("offered", num(self.offered as f64)),
            ("admitted", num(self.admitted as f64)),
            ("rejected", num(self.rejected as f64)),
            ("completed", num(self.completed as f64)),
            ("makespan_s", num(self.makespan_s)),
            ("goodput_rps", num(self.goodput_rps)),
            ("capacity_rps", num(self.capacity_rps)),
            ("latency_p50_ms", num(self.latency_p50_ms)),
            ("latency_p95_ms", num(self.latency_p95_ms)),
            ("latency_p99_ms", num(self.latency_p99_ms)),
            ("latency_mean_ms", num(self.latency_mean_ms)),
            ("latency_max_ms", num(self.latency_max_ms)),
            ("queue_delay_mean_ms", num(self.queue_delay_mean_ms)),
            ("queue_delay_p99_ms", num(self.queue_delay_p99_ms)),
            ("queue_depth_mean", num(self.queue_depth_mean)),
            ("queue_depth_max", num(self.queue_depth_max as f64)),
            ("batches", num(self.batches as f64)),
            ("mean_batch", num(self.mean_batch)),
            ("utilization", num(self.utilization)),
            ("energy_j", num(self.energy_j)),
            ("energy_per_req_j", num(self.energy_per_req_j)),
            ("arrays", num(self.arrays as f64)),
            ("max_batch", num(self.max_batch as f64)),
            ("max_wait_ms", num(self.max_wait_s * 1e3)),
            ("queue_cap", num(self.queue_cap as f64)),
            ("overlap", s(self.overlap.name())),
        ];
        if robust {
            pairs.push(("admission", s(self.admission.name())));
            if let Some(dl) = self.deadline_s {
                pairs.push(("deadline_ms", num(dl * 1e3)));
            }
            pairs.push(("timed_out", num(self.timed_out as f64)));
            pairs.push(("shed", num(self.shed as f64)));
            pairs.push(("lost", num(self.lost as f64)));
            pairs.push(("retries", num(self.retries as f64)));
            pairs.push(("availability", num(self.availability)));
            pairs.push(("degraded_capacity_rps", num(self.degraded_capacity_rps)));
        }
        pairs.push((
            "classes",
            arr(self
                .classes
                .iter()
                .map(|c| {
                    let mut fields = vec![
                        ("name", s(&c.name)),
                        ("spec", s(&c.spec)),
                        ("offered", num(c.offered as f64)),
                        ("rejected", num(c.rejected as f64)),
                        ("completed", num(c.completed as f64)),
                    ];
                    if robust {
                        fields.push(("timed_out", num(c.timed_out as f64)));
                        fields.push(("shed", num(c.shed as f64)));
                        fields.push(("lost", num(c.lost as f64)));
                    }
                    fields.push(("latency_p50_ms", num(c.latency_p50_ms)));
                    fields.push(("latency_p99_ms", num(c.latency_p99_ms)));
                    obj(fields)
                })
                .collect()),
        ));
        obj(pairs)
    }
}

/// Raw counters and samples the event loop produces (assembled into a
/// [`ServeResult`] by [`simulate`]).
struct LoopStats {
    offered: u64,
    admitted: u64,
    rejected: u64,
    completed: u64,
    batches: u64,
    batch_elems: u64,
    latency_ms: Summary,
    queue_delay_ms: Summary,
    depth: Summary,
    depth_max: usize,
    busy_s: Vec<f64>,
    free_at: Vec<f64>,
    energy_j: f64,
    last_event_s: f64,
    class_offered: Vec<u64>,
    class_rejected: Vec<u64>,
    class_completed: Vec<u64>,
    class_latency_ms: Vec<Summary>,
    // Robustness counters: all zero on the fault-free loop.
    timed_out: u64,
    shed: u64,
    lost: u64,
    retries: u64,
    class_timed_out: Vec<u64>,
    class_shed: Vec<u64>,
    class_lost: Vec<u64>,
    /// Up replica-seconds accumulated by the faulty loop (unused — and
    /// zero — on the fault-free loop, where availability is 1.0).
    up_s: f64,
}

impl LoopStats {
    fn new(nclasses: usize, arrays: usize) -> Self {
        LoopStats {
            offered: 0,
            admitted: 0,
            rejected: 0,
            completed: 0,
            batches: 0,
            batch_elems: 0,
            latency_ms: Summary::new(),
            queue_delay_ms: Summary::new(),
            depth: Summary::new(),
            depth_max: 0,
            busy_s: vec![0.0; arrays],
            free_at: vec![0.0; arrays],
            energy_j: 0.0,
            last_event_s: 0.0,
            class_offered: vec![0; nclasses],
            class_rejected: vec![0; nclasses],
            class_completed: vec![0; nclasses],
            class_latency_ms: vec![Summary::new(); nclasses],
            timed_out: 0,
            shed: 0,
            lost: 0,
            retries: 0,
            class_timed_out: vec![0; nclasses],
            class_shed: vec![0; nclasses],
            class_lost: vec![0; nclasses],
            up_s: 0.0,
        }
    }

    fn sample_depth(&mut self, queued: usize) {
        self.depth.push(queued as f64);
        self.depth_max = self.depth_max.max(queued);
    }

    /// Every offered request must reach exactly one terminal state —
    /// completed, rejected, shed, timed out, or lost.  Both event loops
    /// check this per class before returning (debug builds), so any
    /// accounting leak fails the test suite instead of skewing goodput.
    fn assert_conservation(&self) {
        for c in 0..self.class_offered.len() {
            debug_assert_eq!(
                self.class_offered[c],
                self.class_completed[c]
                    + self.class_rejected[c]
                    + self.class_shed[c]
                    + self.class_timed_out[c]
                    + self.class_lost[c],
                "class {c} request accounting leak"
            );
        }
        debug_assert_eq!(
            self.offered,
            self.completed + self.rejected + self.shed + self.timed_out + self.lost,
            "total request accounting leak"
        );
    }
}

/// The deterministic discrete-event loop.  `service(class, batch)`
/// returns the batch's `(service_seconds, energy_joules)`; in
/// production it is the memoized pipeline schedule, in unit tests a
/// synthetic closure.  Event order is total and deterministic: at each
/// step the earliest of (next arrival, earliest eligible dispatch)
/// fires, arrivals winning ties so a request arriving exactly at a
/// dispatch instant still joins the batch.
fn run_loop(
    arrivals: &[Arrival],
    nclasses: usize,
    cfg: &ServeConfig,
    service: &mut dyn FnMut(usize, usize) -> Result<(f64, f64)>,
) -> Result<LoopStats> {
    let mut st = LoopStats::new(nclasses, cfg.arrays);
    let mut queues: Vec<VecDeque<f64>> = vec![VecDeque::new(); nclasses];
    let mut queued = 0usize;
    let mut i = 0usize;
    let mut now = 0.0f64;
    loop {
        let t_arr = arrivals.get(i).map(|a| a.t_s);
        // Earliest-free replica (lowest index on ties).
        let (srv, t_free) = st
            .free_at
            .iter()
            .copied()
            .enumerate()
            .fold((0usize, f64::INFINITY), |acc, (j, t)| if t < acc.1 { (j, t) } else { acc });
        // Earliest eligible dispatch across nonempty classes: a class
        // is ready when full (max_batch queued) or its head request has
        // waited max_wait; either way a replica must be free.  Ties go
        // to the earliest head arrival (closest to starvation), then
        // the lowest class index — a total, deterministic order.
        let mut best: Option<(f64, f64, usize)> = None;
        for (c, q) in queues.iter().enumerate() {
            if let Some(&head) = q.front() {
                let trigger =
                    if q.len() >= cfg.max_batch { now } else { head + cfg.max_wait_s };
                let cand = (t_free.max(trigger).max(now), head, c);
                best = Some(match best {
                    Some(b) if (b.0, b.1, b.2) <= (cand.0, cand.1, cand.2) => b,
                    _ => cand,
                });
            }
        }
        // Decide the next event: the earlier of (next arrival, chosen
        // dispatch), arrivals winning exact ties so a request arriving
        // at a dispatch instant still joins the batch.
        enum Next {
            Done,
            Arrival(f64),
            Dispatch(f64, usize),
        }
        let next = match (t_arr, best) {
            (None, None) => Next::Done,
            (Some(ta), None) => Next::Arrival(ta),
            (None, Some((td, _, c))) => Next::Dispatch(td, c),
            (Some(ta), Some((td, _, c))) => {
                if ta <= td {
                    Next::Arrival(ta)
                } else {
                    Next::Dispatch(td, c)
                }
            }
        };
        match next {
            Next::Done => break,
            Next::Arrival(ta) => {
                now = now.max(ta);
                let a = arrivals[i];
                i += 1;
                st.offered += 1;
                st.class_offered[a.class] += 1;
                if queued >= cfg.queue_cap {
                    st.rejected += 1;
                    st.class_rejected[a.class] += 1;
                } else {
                    queues[a.class].push_back(a.t_s);
                    queued += 1;
                    st.admitted += 1;
                }
                st.sample_depth(queued);
                st.last_event_s = st.last_event_s.max(now);
            }
            Next::Dispatch(td, c) => {
                now = now.max(td);
                let b = queues[c].len().min(cfg.max_batch);
                let (svc_s, energy_j) = service(c, b)?;
                let done = now + svc_s;
                st.free_at[srv] = done;
                st.busy_s[srv] += svc_s;
                st.energy_j += energy_j;
                st.batches += 1;
                st.batch_elems += b as u64;
                for _ in 0..b {
                    let arr_t = queues[c].pop_front().expect("batch size <= queue len");
                    queued -= 1;
                    st.queue_delay_ms.push((now - arr_t) * 1e3);
                    let lat_ms = (done - arr_t) * 1e3;
                    st.latency_ms.push(lat_ms);
                    st.class_latency_ms[c].push(lat_ms);
                }
                st.completed += b as u64;
                st.class_completed[c] += b as u64;
                st.sample_depth(queued);
                st.last_event_s = st.last_event_s.max(done);
            }
        }
    }
    st.assert_conservation();
    Ok(st)
}

/// One queued request on the robustness path: the original arrival
/// time (latency and deadlines always measure from it) plus how many
/// service attempts replica failures have already killed.
#[derive(Debug, Clone, Copy)]
struct Req {
    arrive: f64,
    retries: u32,
}

/// A batch executing on one replica (the robustness loop needs
/// completion as an explicit event, because a failure can kill it
/// first).
struct InFlight {
    class: usize,
    start: f64,
    done: f64,
    svc_s: f64,
    energy_j: f64,
    reqs: Vec<Req>,
}

/// The robustness event loop: the same deterministic discrete-event
/// skeleton as [`run_loop`], extended with replica up/down transitions,
/// in-flight batch loss with capped-exponential-backoff retries,
/// per-request deadlines (lazy cancellation at batch formation) and
/// pluggable admission.  It runs *only* when a robustness feature is
/// configured — the fault-free path stays on [`run_loop`] verbatim,
/// which is what keeps pre-fault artifacts byte-identical (f64
/// accumulation order and all).
///
/// Event priority at equal times: completions, then fault transitions,
/// then arrivals (originals before retries), then dispatches — so a
/// batch finishing exactly when its replica dies still completes, and
/// a request arriving at a dispatch instant still joins the batch.
fn run_loop_faulty(
    arrivals: &[Arrival],
    nclasses: usize,
    cfg: &ServeConfig,
    fault_events: &[ReplicaEvent],
    service: &mut dyn FnMut(usize, usize) -> Result<(f64, f64)>,
) -> Result<LoopStats> {
    /// Retry delay doubles per attempt, capped at `2^6 = 64x` the base
    /// backoff — enough spread to clear a repair window without ever
    /// overflowing the shift.
    const BACKOFF_CAP_DOUBLINGS: u32 = 6;

    let mut st = LoopStats::new(nclasses, cfg.arrays);
    let mut queues: Vec<VecDeque<Req>> = vec![VecDeque::new(); nclasses];
    let mut queued = 0usize;
    let mut inflight: Vec<Option<InFlight>> = (0..cfg.arrays).map(|_| None).collect();
    let mut up = vec![true; cfg.arrays];
    let mut last_change = vec![0.0f64; cfg.arrays];
    // Pending retries: (ready time, enqueue seq, class, request); the
    // seq keeps the pop order total when ready times tie.
    let mut retryq: Vec<(f64, u64, usize, Req)> = Vec::new();
    let mut retry_seq = 0u64;
    // Memoized full-batch service time per class (SLO-aware slack).
    let mut svc_full: Vec<Option<f64>> = vec![None; nclasses];

    let mut i = 0usize; // next arrival
    let mut fi = 0usize; // next fault transition
    let mut now = 0.0f64;

    #[derive(Clone, Copy)]
    enum Ev {
        Complete(usize),
        Fault,
        Arrive,
        Retry(usize),
        Dispatch(usize, usize),
    }

    loop {
        let pending = i < arrivals.len()
            || !retryq.is_empty()
            || queued > 0
            || inflight.iter().any(Option::is_some);
        if !pending {
            break;
        }

        // Candidate events, pushed in tie-break priority order; the
        // strict `<` scan below keeps the earliest-pushed on ties.
        let mut cands: Vec<(f64, Ev)> = Vec::with_capacity(5);
        let mut done_next: Option<(usize, f64)> = None;
        for (r, fl) in inflight.iter().enumerate() {
            if let Some(fl) = fl {
                if done_next.map_or(true, |(_, t)| fl.done < t) {
                    done_next = Some((r, fl.done));
                }
            }
        }
        if let Some((r, t)) = done_next {
            cands.push((t, Ev::Complete(r)));
        }
        if fi < fault_events.len() {
            cands.push((fault_events[fi].t_s, Ev::Fault));
        }
        if i < arrivals.len() {
            cands.push((arrivals[i].t_s, Ev::Arrive));
        }
        let mut retry_next: Option<(usize, f64, u64)> = None;
        for (k, &(t, seq, _, _)) in retryq.iter().enumerate() {
            if retry_next.map_or(true, |(_, bt, bs)| (t, seq) < (bt, bs)) {
                retry_next = Some((k, t, seq));
            }
        }
        if let Some((k, t, _)) = retry_next {
            cands.push((t, Ev::Retry(k)));
        }
        // Earliest-free *up* replica (lowest index on ties), then the
        // earliest eligible dispatch, exactly as the fault-free loop.
        // With every replica down there is no dispatch candidate; the
        // clock advances on fault transitions instead.
        let mut free: Option<(usize, f64)> = None;
        for r in 0..cfg.arrays {
            if up[r] && free.map_or(true, |(_, bt)| st.free_at[r] < bt) {
                free = Some((r, st.free_at[r]));
            }
        }
        if let Some((srv, t_free)) = free {
            let mut best: Option<(f64, f64, usize)> = None;
            for (c, q) in queues.iter().enumerate() {
                if let Some(head) = q.front() {
                    let trigger =
                        if q.len() >= cfg.max_batch { now } else { head.arrive + cfg.max_wait_s };
                    let cand = (t_free.max(trigger).max(now), head.arrive, c);
                    best = Some(match best {
                        Some(b) if (b.0, b.1, b.2) <= (cand.0, cand.1, cand.2) => b,
                        _ => cand,
                    });
                }
            }
            if let Some((td, _, c)) = best {
                cands.push((td, Ev::Dispatch(srv, c)));
            }
        }

        let mut sel: Option<(f64, Ev)> = None;
        for &(t, ev) in &cands {
            if sel.map_or(true, |(bt, _)| t < bt) {
                sel = Some((t, ev));
            }
        }
        let Some((te, ev)) = sel else {
            // Nothing can advance the clock: queued work is stranded
            // with every replica down and no recovery left.  Drain —
            // with a deadline the requests would expire; without one
            // they are simply lost.
            for c in 0..nclasses {
                while queues[c].pop_front().is_some() {
                    queued -= 1;
                    if cfg.deadline_s.is_some() {
                        st.timed_out += 1;
                        st.class_timed_out[c] += 1;
                    } else {
                        st.lost += 1;
                        st.class_lost[c] += 1;
                    }
                }
            }
            for &(_, _, c, _) in &retryq {
                if cfg.deadline_s.is_some() {
                    st.timed_out += 1;
                    st.class_timed_out[c] += 1;
                } else {
                    st.lost += 1;
                    st.class_lost[c] += 1;
                }
            }
            retryq.clear();
            st.sample_depth(queued);
            st.last_event_s = st.last_event_s.max(now);
            break;
        };

        match ev {
            Ev::Complete(r) => {
                now = now.max(te);
                let fl = inflight[r].take().expect("completion fired for an in-flight batch");
                st.busy_s[r] += fl.svc_s;
                st.energy_j += fl.energy_j;
                for req in &fl.reqs {
                    let lat_ms = (fl.done - req.arrive) * 1e3;
                    st.latency_ms.push(lat_ms);
                    st.class_latency_ms[fl.class].push(lat_ms);
                }
                st.completed += fl.reqs.len() as u64;
                st.class_completed[fl.class] += fl.reqs.len() as u64;
                st.last_event_s = st.last_event_s.max(fl.done);
            }
            Ev::Fault => {
                now = now.max(te);
                let e = fault_events[fi];
                fi += 1;
                if up[e.replica] == e.up {
                    // Not a transition (e.g. a second `down` for an
                    // already-down replica): ignore, so a busy
                    // replica's `free_at` is never clobbered.
                } else if e.up {
                    up[e.replica] = true;
                    last_change[e.replica] = e.t_s;
                    st.free_at[e.replica] = e.t_s;
                } else {
                    up[e.replica] = false;
                    st.up_s += e.t_s - last_change[e.replica];
                    last_change[e.replica] = e.t_s;
                    if let Some(fl) = inflight[e.replica].take() {
                        // The batch dies with its replica: bill the
                        // partial service, re-enqueue what still has
                        // retry budget, drop the rest.
                        let class = fl.class;
                        let served = e.t_s - fl.start;
                        st.busy_s[e.replica] += served;
                        if fl.svc_s > 0.0 {
                            st.energy_j += fl.energy_j * (served / fl.svc_s);
                        }
                        for req in fl.reqs {
                            if req.retries >= cfg.max_retries {
                                st.lost += 1;
                                st.class_lost[class] += 1;
                            } else {
                                let n = req.retries + 1;
                                let delay = cfg.retry_backoff_s
                                    * (1u64 << (n - 1).min(BACKOFF_CAP_DOUBLINGS)) as f64;
                                st.retries += 1;
                                retryq.push((
                                    e.t_s + delay,
                                    retry_seq,
                                    class,
                                    Req { arrive: req.arrive, retries: n },
                                ));
                                retry_seq += 1;
                            }
                        }
                        st.last_event_s = st.last_event_s.max(e.t_s);
                    }
                }
            }
            Ev::Arrive | Ev::Retry(_) => {
                now = now.max(te);
                let (class, req, fresh) = match ev {
                    Ev::Arrive => {
                        let a = arrivals[i];
                        i += 1;
                        st.offered += 1;
                        st.class_offered[a.class] += 1;
                        (a.class, Req { arrive: a.t_s, retries: 0 }, true)
                    }
                    Ev::Retry(k) => {
                        let (_, _, c, r) = retryq.remove(k);
                        (c, r, false)
                    }
                    _ => unreachable!("arm only matches arrivals and retries"),
                };
                if queued < cfg.queue_cap {
                    queues[class].push_back(req);
                    queued += 1;
                    if fresh {
                        st.admitted += 1;
                    }
                } else {
                    match (cfg.admission, cfg.deadline_s) {
                        (Admission::SloAware, Some(dl)) => {
                            // Shed whoever is least likely to meet the
                            // deadline.  Slack of a request at queue
                            // position `pos` of class `c`: deadline
                            // minus its estimated completion (earliest
                            // free replica, whole batches ahead of it
                            // spread over the arrays, plus its own
                            // full-batch service time).
                            let t_free = (0..cfg.arrays)
                                .filter(|&r| up[r])
                                .map(|r| st.free_at[r])
                                .fold(f64::INFINITY, f64::min)
                                .max(now);
                            let mut slack_of =
                                |c: usize, pos: usize, arrive: f64| -> Result<f64> {
                                    let svc = match svc_full[c] {
                                        Some(v) => v,
                                        None => {
                                            let (v, _) = service(c, cfg.max_batch)?;
                                            svc_full[c] = Some(v);
                                            v
                                        }
                                    };
                                    let start = t_free
                                        + (pos / cfg.max_batch) as f64 * svc
                                            / cfg.arrays as f64;
                                    Ok(arrive + dl - (start + svc))
                                };
                            let mut worst: Option<(f64, usize, usize)> = None;
                            for (c, q) in queues.iter().enumerate() {
                                for (pos, r) in q.iter().enumerate() {
                                    let sl = slack_of(c, pos, r.arrive)?;
                                    if worst.map_or(true, |(w, ..)| sl < w) {
                                        worst = Some((sl, c, pos));
                                    }
                                }
                            }
                            // The newcomer competes too; on ties it
                            // loses, so a uniform-slack queue degrades
                            // to exactly FIFO tail-drop.
                            let sl_new = slack_of(class, queues[class].len(), req.arrive)?;
                            match worst {
                                Some((w, c, pos)) if w < sl_new => {
                                    queues[c].remove(pos).expect("victim position indexed");
                                    st.shed += 1;
                                    st.class_shed[c] += 1;
                                    queues[class].push_back(req);
                                    if fresh {
                                        st.admitted += 1;
                                    }
                                }
                                _ => {
                                    st.shed += 1;
                                    st.class_shed[class] += 1;
                                }
                            }
                        }
                        _ => {
                            // FIFO tail-drop — and SLO-aware without a
                            // deadline, which has no slack to rank by.
                            // A bounced retry was admitted once already
                            // and now has nowhere to go: that is a loss
                            // to the failure, not a rejection.
                            if fresh {
                                st.rejected += 1;
                                st.class_rejected[class] += 1;
                            } else {
                                st.lost += 1;
                                st.class_lost[class] += 1;
                            }
                        }
                    }
                }
                st.sample_depth(queued);
                st.last_event_s = st.last_event_s.max(now);
            }
            Ev::Dispatch(srv, c) => {
                now = now.max(te);
                // Form the batch, lazily cancelling requests whose
                // deadline already passed (retries put old arrivals
                // behind younger ones, so expiry is checked per popped
                // request, not just at the head).
                let mut batch: Vec<Req> = Vec::new();
                while batch.len() < cfg.max_batch {
                    let Some(req) = queues[c].pop_front() else { break };
                    queued -= 1;
                    match cfg.deadline_s {
                        Some(dl) if now > req.arrive + dl => {
                            st.timed_out += 1;
                            st.class_timed_out[c] += 1;
                        }
                        _ => batch.push(req),
                    }
                }
                if !batch.is_empty() {
                    let b = batch.len();
                    let (svc_s, energy_j) = service(c, b)?;
                    let done = now + svc_s;
                    st.free_at[srv] = done;
                    st.batches += 1;
                    st.batch_elems += b as u64;
                    for req in &batch {
                        st.queue_delay_ms.push((now - req.arrive) * 1e3);
                    }
                    inflight[srv] = Some(InFlight {
                        class: c,
                        start: now,
                        done,
                        svc_s,
                        energy_j,
                        reqs: batch,
                    });
                }
                st.sample_depth(queued);
                st.last_event_s = st.last_event_s.max(now);
            }
        }
    }

    // Close the availability ledger at the makespan: replicas still up
    // have been up since their last transition.
    let makespan = st.last_event_s;
    for r in 0..cfg.arrays {
        if up[r] && last_change[r] < makespan {
            st.up_s += makespan - last_change[r];
        }
    }
    st.assert_conservation();
    Ok(st)
}

/// Run the serving simulation: batch costs come from the session's
/// plan-cached pipeline schedule (`run_network_with` on one array under
/// [`ServeConfig::overlap`]), memoized per `(class, batch-size)` so the
/// event loop pays for each distinct shape once.
pub fn simulate(session: &Session, traffic: &Traffic, cfg: &ServeConfig) -> Result<ServeResult> {
    ensure!(cfg.max_batch >= 1, "serve max_batch must be >= 1");
    ensure!(cfg.arrays >= 1, "serve arrays must be >= 1");
    ensure!(cfg.queue_cap >= 1, "serve queue_cap must be >= 1");
    ensure!(
        cfg.max_wait_s >= 0.0 && cfg.max_wait_s.is_finite(),
        "serve max_wait must be finite and >= 0 (got {})",
        cfg.max_wait_s
    );
    if let Some(dl) = cfg.deadline_s {
        ensure!(
            dl > 0.0 && dl.is_finite(),
            "serve deadline must be positive and finite (got {dl})"
        );
    }
    ensure!(
        cfg.retry_backoff_s >= 0.0 && cfg.retry_backoff_s.is_finite(),
        "serve retry backoff must be finite and >= 0 (got {})",
        cfg.retry_backoff_s
    );
    ensure!(!traffic.classes.is_empty(), "traffic has no request classes");
    for a in &traffic.arrivals {
        ensure!(
            a.class < traffic.classes.len(),
            "arrival references class {} but only {} classes exist",
            a.class,
            traffic.classes.len()
        );
    }
    let pipe = PipelineConfig::new(cfg.overlap, 1);
    let mut memo: HashMap<(usize, usize), (f64, f64)> = HashMap::new();
    let mut service = |c: usize, b: usize| -> Result<(f64, f64)> {
        if let Some(&hit) = memo.get(&(c, b)) {
            return Ok(hit);
        }
        let r = session.run_network_with(&traffic.classes[c], Some(b), pipe)?;
        let v = (r.batch_time_s, r.energy_j);
        memo.insert((c, b), v);
        Ok(v)
    };
    // The fault-free configuration takes the original loop *verbatim*
    // (not the robustness loop with no faults): its f64 accumulation
    // order is part of the byte-reproducibility contract.
    let robust =
        cfg.faults.is_some() || cfg.admission != Admission::Fifo || cfg.deadline_s.is_some();
    let st = if robust {
        let fault_events = match &cfg.faults {
            Some(f) => expand_fault_events(f, cfg.arrays, traffic.duration_s)?,
            None => Vec::new(),
        };
        run_loop_faulty(
            &traffic.arrivals,
            traffic.classes.len(),
            cfg,
            &fault_events,
            &mut service,
        )?
    } else {
        run_loop(&traffic.arrivals, traffic.classes.len(), cfg, &mut service)?
    };

    // Capacity bound: one replica serving full batches of the offered
    // mix sustains max_batch / (mix-weighted full-batch service time)
    // requests/s.  This is what goodput saturates at under overload.
    let mut weighted_svc = 0.0f64;
    if st.offered > 0 {
        for c in 0..traffic.classes.len() {
            if st.class_offered[c] > 0 {
                let (svc, _) = service(c, cfg.max_batch)?;
                weighted_svc += st.class_offered[c] as f64 / st.offered as f64 * svc;
            }
        }
    }
    let capacity_rps = if weighted_svc > 0.0 {
        cfg.arrays as f64 * cfg.max_batch as f64 / weighted_svc
    } else {
        0.0
    };

    let makespan_s = st.last_event_s;
    // Availability: up replica-seconds over the replica-seconds that
    // the makespan spans.  Without a fault schedule every replica is up
    // the whole run by construction.
    let availability = if cfg.faults.is_some() && makespan_s > 0.0 {
        (st.up_s / (cfg.arrays as f64 * makespan_s)).clamp(0.0, 1.0)
    } else {
        1.0
    };
    let lat = st.latency_ms.percentiles(&[50.0, 95.0, 99.0]);
    let served = !st.latency_ms.is_empty();
    let classes = traffic
        .classes
        .iter()
        .enumerate()
        .map(|(c, m)| {
            let p = st.class_latency_ms[c].percentiles(&[50.0, 99.0]);
            let has = !st.class_latency_ms[c].is_empty();
            ClassServeStats {
                name: m.name().to_string(),
                spec: m.spec_string(),
                offered: st.class_offered[c],
                rejected: st.class_rejected[c],
                completed: st.class_completed[c],
                timed_out: st.class_timed_out[c],
                shed: st.class_shed[c],
                lost: st.class_lost[c],
                latency_p50_ms: if has { p[0] } else { 0.0 },
                latency_p99_ms: if has { p[1] } else { 0.0 },
            }
        })
        .collect();
    Ok(ServeResult {
        offered_rate_rps: if traffic.duration_s > 0.0 {
            st.offered as f64 / traffic.duration_s
        } else {
            0.0
        },
        duration_s: traffic.duration_s,
        offered: st.offered,
        admitted: st.admitted,
        rejected: st.rejected,
        completed: st.completed,
        makespan_s,
        goodput_rps: if makespan_s > 0.0 { st.completed as f64 / makespan_s } else { 0.0 },
        capacity_rps,
        latency_p50_ms: if served { lat[0] } else { 0.0 },
        latency_p95_ms: if served { lat[1] } else { 0.0 },
        latency_p99_ms: if served { lat[2] } else { 0.0 },
        latency_mean_ms: if served { st.latency_ms.mean() } else { 0.0 },
        latency_max_ms: if served { st.latency_ms.max() } else { 0.0 },
        queue_delay_mean_ms: if served { st.queue_delay_ms.mean() } else { 0.0 },
        queue_delay_p99_ms: if served { st.queue_delay_ms.percentile(99.0) } else { 0.0 },
        queue_depth_mean: if st.depth.is_empty() { 0.0 } else { st.depth.mean() },
        queue_depth_max: st.depth_max,
        batches: st.batches,
        mean_batch: if st.batches > 0 {
            st.batch_elems as f64 / st.batches as f64
        } else {
            0.0
        },
        utilization: if makespan_s > 0.0 {
            st.busy_s.iter().sum::<f64>() / (cfg.arrays as f64 * makespan_s)
        } else {
            0.0
        },
        energy_j: st.energy_j,
        energy_per_req_j: if st.completed > 0 {
            st.energy_j / st.completed as f64
        } else {
            0.0
        },
        arrays: cfg.arrays,
        max_batch: cfg.max_batch,
        max_wait_s: cfg.max_wait_s,
        queue_cap: cfg.queue_cap,
        overlap: cfg.overlap,
        admission: cfg.admission,
        deadline_s: cfg.deadline_s,
        faults_configured: cfg.faults.is_some(),
        timed_out: st.timed_out,
        shed: st.shed,
        lost: st.lost,
        retries: st.retries,
        availability,
        degraded_capacity_rps: capacity_rps * availability,
        classes,
    })
}

impl Session {
    /// Run the discrete-event serving simulation on this session (see
    /// [`simulate`]): traffic arrives, the dynamic batcher packs
    /// it, replica arrays execute plan-cached pipeline schedules, and
    /// the result is the SLO view — latency percentiles, goodput and
    /// utilization under load.
    pub fn serve(&self, traffic: &Traffic, cfg: &ServeConfig) -> Result<ServeResult> {
        simulate(self, traffic, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, max_wait_s: f64, arrays: usize, queue_cap: usize) -> ServeConfig {
        ServeConfig {
            max_batch,
            max_wait_s,
            arrays,
            queue_cap,
            overlap: Overlap::Pipeline,
            ..ServeConfig::default()
        }
    }

    fn arrivals(ts: &[(f64, usize)]) -> Vec<Arrival> {
        ts.iter().map(|&(t_s, class)| Arrival { t_s, class }).collect()
    }

    /// Constant 10 ms service regardless of class/batch; 1 J per batch.
    fn flat_service() -> impl FnMut(usize, usize) -> Result<(f64, f64)> {
        |_c, _b| Ok((0.010, 1.0))
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let a = arrivals(&[(0.0, 0), (0.0, 0), (0.0, 0), (0.0, 0)]);
        let st = run_loop(&a, 1, &cfg(4, 1.0, 1, 64), &mut flat_service()).unwrap();
        assert_eq!(st.batches, 1);
        assert_eq!(st.completed, 4);
        // No queueing: dispatched the instant the batch filled.
        assert_eq!(st.queue_delay_ms.max(), 0.0);
        assert_eq!(st.latency_ms.max(), 10.0);
        assert_eq!(st.last_event_s, 0.010);
    }

    #[test]
    fn max_wait_flushes_partial_batches() {
        // One lonely request must not wait for a batch that never
        // fills: it dispatches after max_wait.
        let a = arrivals(&[(0.0, 0)]);
        let st = run_loop(&a, 1, &cfg(8, 0.005, 1, 64), &mut flat_service()).unwrap();
        assert_eq!(st.batches, 1);
        assert_eq!(st.batch_elems, 1);
        assert!((st.queue_delay_ms.max() - 5.0).abs() < 1e-9);
        assert!((st.latency_ms.max() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn bounded_queue_rejects_overflow() {
        let a = arrivals(&[(0.0, 0), (0.0, 0), (0.0, 0), (0.0, 0), (0.0, 0)]);
        let st = run_loop(&a, 1, &cfg(2, 1.0, 1, 2), &mut flat_service()).unwrap();
        assert_eq!(st.offered, 5);
        assert_eq!(st.admitted, 2);
        assert_eq!(st.rejected, 3);
        assert_eq!(st.completed, 2);
        assert_eq!(st.class_rejected[0], 3);
        assert_eq!(st.depth_max, 2);
    }

    #[test]
    fn classes_batch_separately_and_fifo_by_head_age() {
        // Class 1 arrives first; both time out; the single replica must
        // serve class 1 first (earliest head), then class 0.
        let a = arrivals(&[(0.0, 1), (0.001, 0)]);
        let st = run_loop(&a, 2, &cfg(4, 0.010, 1, 64), &mut flat_service()).unwrap();
        assert_eq!(st.batches, 2, "classes never share a batch");
        assert_eq!(st.class_completed, vec![1, 1]);
        // Class 1: waits its full max_wait (dispatch 0.010, done 0.020).
        assert!((st.class_latency_ms[1].max() - 20.0).abs() < 1e-9);
        // Class 0: its deadline (0.011) coincides with the replica
        // freeing at 0.020 -> dispatched then, done 0.030.
        assert!((st.class_latency_ms[0].max() - (0.030 - 0.001) * 1e3).abs() < 1e-9);
    }

    #[test]
    fn replicas_serve_batches_concurrently() {
        let a = arrivals(&[(0.0, 0), (0.0, 0)]);
        let one = run_loop(&a, 1, &cfg(1, 0.0, 1, 64), &mut flat_service()).unwrap();
        let two = run_loop(&a, 1, &cfg(1, 0.0, 2, 64), &mut flat_service()).unwrap();
        assert_eq!(one.batches, 2);
        assert_eq!(two.batches, 2);
        assert!((one.last_event_s - 0.020).abs() < 1e-12, "serial replicas");
        assert!((two.last_event_s - 0.010).abs() < 1e-12, "parallel replicas");
        assert_eq!(two.busy_s, vec![0.010, 0.010]);
    }

    #[test]
    fn compressed_arrivals_never_lower_tail_latency() {
        // The rate-sweep property at loop level: the same arrival
        // pattern compressed in time (higher offered rate) cannot
        // reduce the latency percentiles.
        let base: Vec<(f64, usize)> = (0..64).map(|i| (i as f64 * 0.004, 0)).collect();
        let mut last_p99 = 0.0f64;
        for compress in [1.0, 2.0, 8.0] {
            let a: Vec<Arrival> = base
                .iter()
                .map(|&(t, c)| Arrival { t_s: t / compress, class: c })
                .collect();
            let st = run_loop(&a, 1, &cfg(4, 0.002, 1, 32), &mut flat_service()).unwrap();
            let p99 = st.latency_ms.percentile(99.0);
            assert!(
                p99 >= last_p99 - 1e-9,
                "compression {compress}: p99 {p99} < previous {last_p99}"
            );
            last_p99 = p99;
        }
        assert!(last_p99 > 10.0, "overload must show queueing beyond pure service");
    }

    #[test]
    fn poisson_traffic_is_seed_deterministic_and_rate_scaled() {
        let keys = vec!["att:bpmm".to_string()];
        let a = Traffic::poisson(&keys, 100.0, 0.5, 9).unwrap();
        let b = Traffic::poisson(&keys, 100.0, 0.5, 9).unwrap();
        assert_eq!(a.arrivals, b.arrivals);
        assert!(!a.arrivals.is_empty());
        assert!(a.arrivals.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        // Doubled rate = halved times, element for element (common
        // prefix; the faster stream has at least as many arrivals).
        let fast = Traffic::poisson(&keys, 200.0, 0.5, 9).unwrap();
        assert!(fast.arrivals.len() >= a.arrivals.len());
        for (s, f) in a.arrivals.iter().zip(&fast.arrivals) {
            assert!((s.t_s - 2.0 * f.t_s).abs() < 1e-12);
            assert_eq!(s.class, f.class);
        }
    }

    #[test]
    fn poisson_traffic_mixes_classes() {
        let keys = vec!["att:bpmm".to_string(), "att:fft2d".to_string()];
        let t = Traffic::poisson(&keys, 2000.0, 0.5, 3).unwrap();
        assert_eq!(t.classes.len(), 2);
        let ones = t.arrivals.iter().filter(|a| a.class == 1).count();
        assert!(ones > 0 && ones < t.arrivals.len(), "both classes must appear");
    }

    #[test]
    fn trace_parses_sorts_and_dedups_classes() {
        let text = r#"{"arrivals": [
            {"t": 0.002, "workload": "att:bpmm"},
            {"t": 0.000, "workload": "vanilla"},
            {"t": 0.001, "workload": "att:bpmm"}
        ]}"#;
        let t = Traffic::from_trace_str(text).unwrap();
        assert_eq!(t.classes.len(), 2);
        assert_eq!(t.arrivals.len(), 3);
        assert!(t.arrivals.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        assert_eq!(t.arrivals[0].class, 1, "vanilla arrived first after sorting");
        assert!((t.duration_s - 0.002).abs() < 1e-15);
        assert!(Traffic::from_trace_str(r#"{"arrivals": []}"#).is_err());
        assert!(Traffic::from_trace_str(r#"{"arrivals": [{"t": -1.0, "workload": "x"}]}"#)
            .is_err());
    }

    #[test]
    fn degenerate_config_is_rejected() {
        let session = Session::builder().build();
        let traffic =
            Traffic::poisson(&["att:bpmm".to_string()], 100.0, 0.05, 1).unwrap();
        for bad in [
            ServeConfig { max_batch: 0, ..ServeConfig::default() },
            ServeConfig { arrays: 0, ..ServeConfig::default() },
            ServeConfig { queue_cap: 0, ..ServeConfig::default() },
            ServeConfig { max_wait_s: f64::NAN, ..ServeConfig::default() },
            ServeConfig { deadline_s: Some(0.0), ..ServeConfig::default() },
            ServeConfig { deadline_s: Some(f64::NAN), ..ServeConfig::default() },
            ServeConfig { retry_backoff_s: -1.0, ..ServeConfig::default() },
            ServeConfig {
                faults: Some(ReplicaFaults::Process { mtbf_s: 0.0, mttr_s: 0.01, seed: 1 }),
                ..ServeConfig::default()
            },
            ServeConfig {
                faults: Some(ReplicaFaults::Trace(vec![ReplicaEvent {
                    t_s: 0.0,
                    replica: 9,
                    up: false,
                }])),
                ..ServeConfig::default()
            },
        ] {
            assert!(session.serve(&traffic, &bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn deadline_cancels_stale_queued_requests() {
        // Three requests at t=0, one replica, 10 ms service, 12 ms
        // deadline: the first two dispatch in time (the second finishes
        // late — deadlines cancel queued work, they don't abort running
        // batches), the third is still queued at 20 ms and cancels.
        let a = arrivals(&[(0.0, 0), (0.0, 0), (0.0, 0)]);
        let c = ServeConfig { deadline_s: Some(0.012), ..cfg(1, 1.0, 1, 64) };
        let st = run_loop_faulty(&a, 1, &c, &[], &mut flat_service()).unwrap();
        assert_eq!(st.completed, 2);
        assert_eq!(st.timed_out, 1);
        assert_eq!(st.class_timed_out[0], 1);
        assert_eq!(st.batches, 2);
        assert!((st.latency_ms.max() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn replica_failure_kills_the_batch_and_the_retry_succeeds() {
        let a = arrivals(&[(0.0, 0)]);
        let ev = [
            ReplicaEvent { t_s: 0.005, replica: 0, up: false },
            ReplicaEvent { t_s: 0.05, replica: 0, up: true },
        ];
        let c = cfg(1, 0.0, 1, 64);
        let st = run_loop_faulty(&a, 1, &c, &ev, &mut flat_service()).unwrap();
        // Dispatched at 0, killed at 5 ms, retried (5 ms backoff),
        // stuck until the replica recovers at 50 ms, done at 60 ms.
        assert_eq!(st.completed, 1);
        assert_eq!(st.retries, 1);
        assert_eq!(st.lost, 0);
        assert!((st.latency_ms.max() - 60.0).abs() < 1e-9);
        // Up 0..5 ms and 50..60 ms of a 60 ms makespan.
        assert!((st.up_s - 0.015).abs() < 1e-12, "up_s {}", st.up_s);
    }

    #[test]
    fn permanently_dead_replicas_lose_requests_without_hanging() {
        let a = arrivals(&[(0.0, 0), (0.001, 0)]);
        let ev = [ReplicaEvent { t_s: 0.005, replica: 0, up: false }];
        let st = run_loop_faulty(&a, 1, &cfg(2, 0.0, 1, 64), &ev, &mut flat_service()).unwrap();
        // The in-flight request retries once, then both strand in the
        // queue with no recovery in the schedule: drained as lost.
        assert_eq!(st.completed, 0);
        assert_eq!(st.retries, 1);
        assert_eq!(st.lost, 2);
        assert_eq!(st.offered, 2);
        assert_eq!(st.class_lost[0], 2);
    }

    #[test]
    fn robustness_loop_agrees_with_simple_loop_when_nothing_fires() {
        // Same scenario through both loops: no faults, a deadline far
        // beyond any latency.  Counters and latencies must agree (the
        // byte-identity contract for default configs is stronger — the
        // simple loop runs verbatim — but the semantics must match too).
        let a = arrivals(&[(0.0, 0), (0.0, 0), (0.003, 0), (0.009, 0)]);
        let c = cfg(2, 0.002, 1, 8);
        let simple = run_loop(&a, 1, &c, &mut flat_service()).unwrap();
        let dl = ServeConfig { deadline_s: Some(10.0), ..c };
        let robust = run_loop_faulty(&a, 1, &dl, &[], &mut flat_service()).unwrap();
        assert_eq!(simple.completed, robust.completed);
        assert_eq!(simple.batches, robust.batches);
        assert_eq!(simple.batch_elems, robust.batch_elems);
        assert_eq!(simple.latency_ms.max(), robust.latency_ms.max());
        assert_eq!(simple.queue_delay_ms.max(), robust.queue_delay_ms.max());
    }

    #[test]
    fn slo_aware_beats_fifo_under_mixed_class_overload() {
        // One replica, queue of 2.  Two slow requests (30 ms) arrive
        // first, then four fast ones (1 ms); 40 ms deadline.  FIFO
        // tail-drops the fast arrivals and serves a doomed slow request
        // late; SLO-aware sheds the queued slow request (least slack)
        // and completes the fast ones inside their deadline.
        let mut service = |c: usize, _b: usize| -> Result<(f64, f64)> {
            Ok(if c == 0 { (0.001, 1.0) } else { (0.030, 1.0) })
        };
        let a = arrivals(&[
            (0.0, 1),
            (0.0, 1),
            (0.001, 0),
            (0.001, 0),
            (0.001, 0),
            (0.001, 0),
        ]);
        let base = cfg(1, 1.0, 1, 2);
        let fifo = ServeConfig { deadline_s: Some(0.040), ..base.clone() };
        let slo = ServeConfig {
            admission: Admission::SloAware,
            deadline_s: Some(0.040),
            ..base
        };
        let f = run_loop_faulty(&a, 2, &fifo, &[], &mut service).unwrap();
        let s = run_loop_faulty(&a, 2, &slo, &[], &mut service).unwrap();

        assert_eq!(f.completed, 2);
        assert_eq!(f.rejected, 3);
        assert_eq!(f.timed_out, 1);
        assert!(f.latency_ms.max() > 40.0, "FIFO completes a request past its deadline");

        assert_eq!(s.completed, 3);
        assert_eq!(s.shed, 3);
        assert_eq!(s.class_shed[1], 1, "the doomed slow request is shed");
        assert_eq!(s.class_shed[0], 2, "excess fast arrivals shed on their own slack");
        assert_eq!(s.timed_out, 0);
        assert!(s.latency_ms.max() <= 40.0, "every SLO-aware completion meets the deadline");
        assert!(s.completed > f.completed, "strictly more deadline-met goodput");
    }

    #[test]
    fn fault_process_is_seeded_and_per_replica_independent() {
        let p = ReplicaFaults::Process { mtbf_s: 0.05, mttr_s: 0.01, seed: 7 };
        let a = expand_fault_events(&p, 3, 1.0).unwrap();
        let b = expand_fault_events(&p, 3, 1.0).unwrap();
        assert_eq!(a, b, "same seed, same schedule");
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s), "sorted by time");
        // Replica 0's stream is independent of the replica count.
        let solo = expand_fault_events(&p, 1, 1.0).unwrap();
        let r0: Vec<ReplicaEvent> = a.iter().filter(|e| e.replica == 0).copied().collect();
        assert_eq!(solo, r0);
        let other = ReplicaFaults::Process { mtbf_s: 0.05, mttr_s: 0.01, seed: 8 };
        assert_ne!(a, expand_fault_events(&other, 3, 1.0).unwrap());
        // Validation: out-of-range trace replica, degenerate MTBF.
        let bad = ReplicaFaults::Trace(vec![ReplicaEvent { t_s: 0.0, replica: 5, up: false }]);
        let err = expand_fault_events(&bad, 2, 1.0).unwrap_err().to_string();
        assert!(err.contains("references replica 5"), "{err}");
        let degenerate = ReplicaFaults::Process { mtbf_s: 0.0, mttr_s: 0.01, seed: 1 };
        assert!(expand_fault_events(&degenerate, 1, 1.0).is_err());
    }

    #[test]
    fn fault_trace_parses_and_rejects_garbage() {
        let text = r#"{"events": [
            {"t": 0.05, "replica": 0, "up": false},
            {"t": 0.12, "replica": 0, "up": true}
        ]}"#;
        match ReplicaFaults::from_trace_str(text).unwrap() {
            ReplicaFaults::Trace(ev) => {
                assert_eq!(ev.len(), 2);
                assert!(!ev[0].up && ev[1].up);
            }
            other => panic!("expected a trace, got {other:?}"),
        }
        assert!(ReplicaFaults::from_trace_str(r#"{"events": []}"#).is_err());
        assert!(ReplicaFaults::from_trace_str(
            r#"{"events": [{"t": -1.0, "replica": 0, "up": true}]}"#
        )
        .is_err());
        assert!(
            ReplicaFaults::from_trace_str(r#"{"events": [{"t": 1.0, "replica": 0}]}"#).is_err()
        );
        assert_eq!(Admission::parse("slo-aware").unwrap(), Admission::SloAware);
        assert_eq!(Admission::parse("fifo").unwrap(), Admission::Fifo);
        assert!(Admission::parse("nope").is_err());
    }
}
