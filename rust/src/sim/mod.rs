//! Deterministic cycle-level discrete-event simulator of the dataflow
//! substrate.
//!
//! The simulated machine is the paper's Fig. 6/8 design: a mesh of PEs,
//! each with four *decoupled* function units {Load, Flow, Cal, Store} fed
//! by a coarse-grained block scheduler (smallest `{layer, iter}` priority
//! string first), a shared multi-bank SPM with a fixed number of SIMD16
//! ports, a mesh NoC with per-link occupancy and XY routing, and a DMA
//! engine streaming iteration data from DDR.
//!
//! [`engine`] runs one lowered [`crate::dfg::Program`] — rewritten for
//! throughput around an indexed event calendar, per-unit pending-wake
//! flags, precomputed NoC routes and a reusable [`SimWorkspace`] (see
//! the engine module docs for the design); [`reference`] is the
//! pre-rewrite engine frozen verbatim as the bit-exactness oracle
//! (golden tests diff the two, the perf bench baselines against it).
//! [`result`] is the collected statistics.  Multi-stage plans, windowed
//! extrapolation and figure-level metrics live in [`crate::coordinator`].

pub mod engine;
pub mod reference;
pub mod result;

pub use engine::{simulate, simulate_in, SimOptions, SimWorkspace};
pub use result::SimStats;
