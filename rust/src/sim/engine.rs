//! The discrete-event core.
//!
//! Entities and their contention model:
//!
//! * **Function units** (4 per PE): serve one block at a time; among
//!   ready blocks the controlUnit picks the smallest `{layer, iter}`
//!   priority string (Fig. 8).  Every block pays the fixed
//!   `block_issue_overhead` (arbitration + context fetch).
//! * **SPM ports**: `banks/2` SIMD16 ports shared by all PEs' Load/Store
//!   units; a block occupies the earliest-free port for the duration of
//!   its transfer.  The multi-line design makes row- and column-access
//!   equal cost (the ablation flag `no_multiline_spm` serializes
//!   column-gather reads to model its absence).
//! * **NoC links**: directed mesh links with XY routing; a FLOW reserves
//!   every link on its path for the serialized transfer duration, then
//!   pays per-hop latency before the payload is visible downstream.
//! * **DMA**: iteration `i`'s LOAD blocks gate on the DMA having
//!   delivered chunks `0..=i` (plus a one-time weight stream), at the
//!   aggregate DDR bandwidth.
//!
//! Everything is deterministic: ties break on block id.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::arch::{ArchConfig, UnitKind};
use crate::dfg::{Block, Program};

use super::result::SimStats;

/// Simulation knobs (ablations + windowing live in the coordinator).
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Model a conventional single-line SPM: column-gather accesses
    /// serialize to one scalar per cycle (§V-C ablation).
    pub no_multiline_spm: bool,
    /// Disable the coarse-grained priority scheduler: FIFO block issue
    /// (ablation for the Fig. 8 design point).
    pub fifo_scheduling: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { no_multiline_spm: false, fifo_scheduling: false }
    }
}

/// Priority key: the paper's `{Layer_idx, Iter_idx}` bit string; FIFO
/// mode degrades to insertion order.
type Prio = (u16, u32, u32);

struct UnitState {
    free_at: u64,
    ready: BinaryHeap<Reverse<(Prio, u32)>>, // ((layer, iter, seq), block)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// A block's service finished on its unit (unit becomes free).
    UnitFree { pe: u16, unit: u8 },
    /// A block's outputs are visible (dependents may fire).
    BlockDone { block: u32 },
    /// The DMA delivered an input chunk this block was gated on.
    DmaArrive { block: u32 },
}

/// Whether a block gates on DMA delivery: input-bearing layer-0 loads
/// wait for their iteration's chunk.  Single source of truth for the
/// dependency count, the `DmaArrive` event seeding and the
/// `dma_fill_cycles` statistic — these three must never disagree.
fn dma_gated(b: &Block) -> bool {
    b.unit == UnitKind::Load && b.layer == 0 && b.scalars_wide > 0
}

/// Run a program to completion and collect statistics.
pub fn simulate(program: &Program, arch: &ArchConfig, opts: &SimOptions) -> SimStats {
    let blocks = &program.blocks;
    let num_pes = arch.num_pes();
    let w = arch.simd_width as u64;
    let entry = arch.spm_entry_width as u64;

    // Dependents (CSR layout — one flat array, no per-block Vecs) +
    // remaining-dep counts.
    let mut remaining: Vec<u32> = vec![0; blocks.len()];
    let mut dep_start: Vec<u32> = vec![0; blocks.len() + 1];
    for b in blocks.iter() {
        for d in &b.deps {
            dep_start[d.0 as usize + 1] += 1;
        }
    }
    for i in 0..blocks.len() {
        dep_start[i + 1] += dep_start[i];
    }
    let mut dep_flat: Vec<u32> = vec![0; dep_start[blocks.len()] as usize];
    let mut cursor: Vec<u32> = dep_start[..blocks.len()].to_vec();
    for (i, b) in blocks.iter().enumerate() {
        remaining[i] = b.deps.len() as u32;
        for d in &b.deps {
            let c = &mut cursor[d.0 as usize];
            dep_flat[*c as usize] = i as u32;
            *c += 1;
        }
        // Input-bearing layer-0 loads carry an extra virtual dependency
        // on the DMA delivery of their iteration's chunk (resolved by a
        // DmaArrive event) — the unit itself never stalls on DMA.
        if dma_gated(b) {
            remaining[i] += 1;
        }
    }
    let dependents = |block: usize| -> &[u32] {
        &dep_flat[dep_start[block] as usize..dep_start[block + 1] as usize]
    };

    // Units.
    let mut units: Vec<UnitState> = (0..num_pes * 4)
        .map(|_| UnitState { free_at: 0, ready: BinaryHeap::new() })
        .collect();
    let unit_idx = |pe: u16, unit: UnitKind| pe as usize * 4 + unit.index();

    // SPM ports: one SIMD16 port per bank for row-wise access; the
    // multi-line interleave makes column access equal cost (§V-C).
    let num_ports = arch.spm_banks.max(1);
    let mut port_free: Vec<u64> = vec![0; num_ports];

    // NoC links: directed, 4 per PE (N, E, S, W neighbours).
    let mut link_free: Vec<u64> = vec![0; num_pes * 4];

    // DMA schedule: weight preamble then per-iteration in+out chunks.
    let bpc = arch.ddr_bytes_per_cycle();
    let weight_cycles = (program.meta.weight_dma_bytes as f64 / bpc).ceil() as u64;
    let chunk_in = program.meta.dma_in_bytes_per_iter as f64;
    let chunk_out = program.meta.dma_out_bytes_per_iter as f64;
    // Inputs prefetch ahead of compute (double buffering); outputs drain
    // on the writeback half of the channel budget and never gate loads.
    let _ = chunk_out;
    let dma_ready = |iter: u32| -> u64 {
        arch.dma_setup + weight_cycles + (((iter as f64 + 1.0) * chunk_in) / bpc).ceil() as u64
    };

    // Any layer-0 input load gates on DMA delivery; if at least one
    // exists, the makespan includes the cold-start fill `dma_ready(0)`
    // (setup + weight preamble + first chunk), which the coordinator's
    // streaming overlap model can hide under a preceding kernel.
    let gated_loads = blocks.iter().any(dma_gated);
    let mut stats = SimStats {
        unit_busy_per_pe: vec![[0u64; 4]; num_pes],
        active_pes: program.meta.active_pes,
        dma_bytes: program.meta.weight_dma_bytes
            + program.meta.iters as u64
                * (program.meta.dma_in_bytes_per_iter
                    + program.meta.dma_out_bytes_per_iter),
        dma_weight_bytes: program.meta.weight_dma_bytes,
        dma_in_bytes: program.meta.iters as u64 * program.meta.dma_in_bytes_per_iter,
        dma_fill_cycles: if gated_loads { dma_ready(0) } else { 0 },
        ..Default::default()
    };
    let mut iter_done: Vec<u64> = vec![0; program.meta.iters];

    // Event queue: (time, seq, event).
    let mut seq: u64 = 0;
    let mut events: BinaryHeap<Reverse<(u64, u64, Event)>> = BinaryHeap::new();
    let push_event = |events: &mut BinaryHeap<Reverse<(u64, u64, Event)>>,
                          seq: &mut u64,
                          t: u64,
                          e: Event| {
        *seq += 1;
        events.push(Reverse((t, *seq, e)));
    };

    // Seed ready sets.
    let mut fifo_seq: u32 = 0;
    let mut make_prio = |b: &Block, opts: &SimOptions| -> Prio {
        if opts.fifo_scheduling {
            fifo_seq += 1;
            (0, fifo_seq, 0)
        } else {
            (b.layer, b.iter, 0)
        }
    };
    for (i, b) in blocks.iter().enumerate() {
        if remaining[i] == 0 {
            let p = make_prio(b, opts);
            units[unit_idx(b.pe, b.unit)].ready.push(Reverse((p, i as u32)));
        }
        if dma_gated(b) {
            push_event(
                &mut events,
                &mut seq,
                dma_ready(b.iter),
                Event::DmaArrive { block: i as u32 },
            );
        }
    }
    for pe in 0..num_pes as u16 {
        for unit in 0..4u8 {
            push_event(&mut events, &mut seq, 0, Event::UnitFree { pe, unit });
        }
    }

    let mut now: u64 = 0;
    while let Some(Reverse((t, _, ev))) = events.pop() {
        now = now.max(t);
        match ev {
            Event::BlockDone { block } => {
                for &dep in dependents(block as usize) {
                    remaining[dep as usize] -= 1;
                    if remaining[dep as usize] == 0 {
                        let b = &blocks[dep as usize];
                        let p = make_prio(b, opts);
                        let ui = unit_idx(b.pe, b.unit);
                        units[ui].ready.push(Reverse((p, dep)));
                        if units[ui].free_at <= t {
                            push_event(
                                &mut events,
                                &mut seq,
                                t,
                                Event::UnitFree { pe: b.pe, unit: b.unit.index() as u8 },
                            );
                        }
                    }
                }
                let b = &blocks[block as usize];
                if b.completes_iter {
                    let d = &mut iter_done[b.iter as usize];
                    *d = (*d).max(t);
                }
            }
            Event::DmaArrive { block } => {
                remaining[block as usize] -= 1;
                if remaining[block as usize] == 0 {
                    let b = &blocks[block as usize];
                    let p = make_prio(b, opts);
                    let ui = unit_idx(b.pe, b.unit);
                    units[ui].ready.push(Reverse((p, block)));
                    if units[ui].free_at <= t {
                        push_event(
                            &mut events,
                            &mut seq,
                            t,
                            Event::UnitFree { pe: b.pe, unit: b.unit.index() as u8 },
                        );
                    }
                }
            }
            Event::UnitFree { pe, unit } => {
                let ui = pe as usize * 4 + unit as usize;
                if units[ui].free_at > t {
                    continue; // stale wake-up; a real free event will come
                }
                let Some(Reverse((_, bid))) = units[ui].ready.pop() else {
                    continue;
                };
                let b = &blocks[bid as usize];
                let mut start = t.max(units[ui].free_at);
                let mut done_at; // when outputs are visible
                let service_end; // when the unit frees
                match b.unit {
                    UnitKind::Cal => {
                        let dur = arch.block_issue_overhead + b.ops;
                        service_end = start + dur;
                        done_at = service_end;
                    }
                    UnitKind::Load | UnitKind::Store => {
                        // (DMA gating is a DmaArrive dependency, resolved
                        // before the block ever becomes ready.)
                        // Acquire the earliest-free SPM port.
                        let (pi, pf) = port_free
                            .iter()
                            .enumerate()
                            .min_by_key(|(i, f)| (**f, *i))
                            .map(|(i, f)| (i, *f))
                            .unwrap();
                        start = start.max(pf);
                        let wide = b.scalars_wide * w;
                        let wide_cycles = if opts.no_multiline_spm && b.layer > 0 {
                            // Column-gather without the multi-line design:
                            // one scalar per cycle.
                            wide
                        } else {
                            wide.div_ceil(entry)
                        };
                        let bcast_cycles = b.scalars_bcast.div_ceil(entry);
                        let dur = arch.block_issue_overhead
                            + arch.spm_latency
                            + wide_cycles
                            + bcast_cycles;
                        port_free[pi] = start + dur;
                        stats.spm_port_busy += dur;
                        stats.spm_scalars += wide + b.scalars_bcast;
                        service_end = start + dur;
                        done_at = service_end;
                    }
                    UnitKind::Flow => {
                        // Reserve the XY path; serialized transfer then
                        // per-hop latency to visibility.
                        let bytes = b.scalars_wide * w * arch.elem_bytes as u64;
                        let xfer = bytes.div_ceil(arch.noc_link_bytes as u64).max(1);
                        let dest = b.dest_pe.unwrap_or(b.pe) as usize;
                        let path = xy_path(b.pe as usize, dest, arch);
                        let mut s = start;
                        for &l in &path {
                            s = s.max(link_free[l]);
                        }
                        for &l in &path {
                            link_free[l] = s + xfer;
                        }
                        let dur = arch.block_issue_overhead + (s - start) + xfer;
                        stats.noc_scalars += b.scalars_wide * w;
                        service_end = start + dur;
                        done_at =
                            service_end + b.noc_hops as u64 * arch.noc_hop_latency;
                    }
                }
                if done_at < service_end {
                    done_at = service_end;
                }
                let busy = service_end - start;
                stats.unit_busy[b.unit.index()] += busy;
                stats.unit_busy_per_pe[b.pe as usize][b.unit.index()] += busy;
                stats.blocks_run += 1;
                units[ui].free_at = service_end;
                push_event(&mut events, &mut seq, service_end, Event::UnitFree { pe, unit });
                push_event(&mut events, &mut seq, done_at, Event::BlockDone { block: bid });
            }
        }
    }

    stats.cycles = now;
    stats.iter_done = iter_done;
    stats
}

/// Directed link ids along the XY route from `src` to `dst`.
/// Link encoding: `pe * 4 + dir` with dir 0=E, 1=W, 2=S, 3=N, owned by the
/// *upstream* PE.
fn xy_path(src: usize, dst: usize, arch: &ArchConfig) -> Vec<usize> {
    let cols = arch.mesh_cols;
    let (mut r, mut c) = (src / cols, src % cols);
    let (dr, dc) = (dst / cols, dst % cols);
    let mut path = Vec::new();
    while c != dc {
        let pe = r * cols + c;
        if dc > c {
            path.push(pe * 4);
            c += 1;
        } else {
            path.push(pe * 4 + 1);
            c -= 1;
        }
    }
    while r != dr {
        let pe = r * cols + c;
        if dr > r {
            path.push(pe * 4 + 2);
            r += 1;
        } else {
            path.push(pe * 4 + 3);
            r -= 1;
        }
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::graph::KernelKind;
    use crate::dfg::microcode::lower_stage;
    use crate::dfg::stages::StageDfg;

    fn stage(kind: KernelKind, points: usize) -> StageDfg {
        StageDfg { kind, points, sub_iters: 1, twiddle_before: false, weights_from_ddr: false }
    }

    fn run(kind: KernelKind, points: usize, iters: usize) -> SimStats {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(kind, points), &arch, iters);
        p.validate().unwrap();
        simulate(&p, &arch, &SimOptions::default())
    }

    #[test]
    fn completes_and_is_deterministic() {
        let a = run(KernelKind::Bpmm, 256, 4);
        let b = run(KernelKind::Bpmm, 256, 4);
        assert!(a.cycles > 0);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.unit_busy, b.unit_busy);
        assert_eq!(a.blocks_run, b.blocks_run);
    }

    #[test]
    fn all_blocks_execute() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Fft, 128), &arch, 3);
        let s = simulate(&p, &arch, &SimOptions::default());
        assert_eq!(s.blocks_run as usize, p.blocks.len());
    }

    #[test]
    fn iteration_completions_monotone() {
        let s = run(KernelKind::Bpmm, 256, 8);
        for w in s.iter_done.windows(2) {
            assert!(w[0] <= w[1], "{:?}", s.iter_done);
        }
        assert!(*s.iter_done.last().unwrap() <= s.cycles);
    }

    #[test]
    fn pipelining_beats_serial_execution() {
        // 8 iterations pipelined must be much cheaper than 8x one
        // iteration (the coarse-grained streaming claim of §V-A).
        let one = run(KernelKind::Fft, 256, 1).cycles;
        let eight = run(KernelKind::Fft, 256, 8).cycles;
        assert!(
            (eight as f64) < 0.7 * (8 * one) as f64,
            "no pipelining: 1 iter {one}, 8 iters {eight}"
        );
    }

    #[test]
    fn cal_dominates_for_large_fft() {
        // §VI-D: Cal utilization over 89% for FFT at large scales;
        // Load under 6%.  Check the ordering (not the exact numbers) in
        // a long steady window.
        let s = run(KernelKind::Fft, 256, 32);
        let cal = s.unit_busy[UnitKind::Cal.index()] as f64;
        let load = s.unit_busy[UnitKind::Load.index()] as f64;
        let flow = s.unit_busy[UnitKind::Flow.index()] as f64;
        assert!(cal > flow, "cal {cal} flow {flow}");
        assert!(cal > 3.0 * load, "cal {cal} load {load}");
    }

    #[test]
    fn fft_flows_more_than_bpmm() {
        // §VI-D: FFT needs twice the Flow traffic of BPMM.
        let f = run(KernelKind::Fft, 256, 16);
        let b = run(KernelKind::Bpmm, 256, 16);
        assert!(f.noc_scalars == 2 * b.noc_scalars);
    }

    #[test]
    fn fifo_scheduling_is_comparable_but_not_better_at_steady_state() {
        // The {layer, iter} priority scheduler must track the
        // dependency-driven FIFO baseline closely (FIFO arrival order is
        // itself near-optimal for a layered DAG); the paper's argument is
        // that the *cheap* priority rule suffices — verify it stays
        // within 3% and does not collapse.
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Fft, 256), &arch, 32);
        let pri = simulate(&p, &arch, &SimOptions::default());
        let fifo = simulate(
            &p,
            &arch,
            &SimOptions { fifo_scheduling: true, ..Default::default() },
        );
        // Measured: the layer-major rule trails dependency-order FIFO by
        // ~6% here because postponing STOREs delays buffer recycling —
        // recorded as an ablation in EXPERIMENTS.md.  Guard the band.
        assert!(
            (pri.cycles as f64) <= fifo.cycles as f64 * 1.10,
            "priority {} vs fifo {}",
            pri.cycles,
            fifo.cycles
        );
    }

    #[test]
    fn single_line_spm_is_slower() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Bpmm, 512), &arch, 8);
        let multi = simulate(&p, &arch, &SimOptions::default());
        let single = simulate(
            &p,
            &arch,
            &SimOptions { no_multiline_spm: true, ..Default::default() },
        );
        assert!(single.cycles >= multi.cycles);
    }

    #[test]
    fn xy_path_lengths_match_manhattan() {
        let arch = ArchConfig::full();
        for src in 0..16 {
            for dst in 0..16 {
                let path = xy_path(src, dst, &arch);
                assert_eq!(path.len(), arch.hop_distance(src, dst));
            }
        }
    }

    #[test]
    fn utilization_bounds() {
        let s = run(KernelKind::Fft, 256, 16);
        for k in crate::arch::UnitKind::ALL {
            let u = s.utilization(k, 16);
            assert!((0.0..=1.0).contains(&u), "{k:?} {u}");
        }
    }
}
