//! The discrete-event core — throughput-oriented rewrite.
//!
//! Entities and their contention model (unchanged from the paper's
//! Fig. 6/8 machine):
//!
//! * **Function units** (4 per PE): serve one block at a time; among
//!   ready blocks the controlUnit picks the smallest `{layer, iter}`
//!   priority string (Fig. 8).  Every block pays the fixed
//!   `block_issue_overhead` (arbitration + context fetch).
//! * **SPM ports**: `banks/2` SIMD16 ports shared by all PEs' Load/Store
//!   units; a block occupies the earliest-free port for the duration of
//!   its transfer (the `no_multiline_spm` ablation serializes
//!   column-gather reads).
//! * **NoC links**: directed mesh links with XY routing; a FLOW reserves
//!   every link on its path for the serialized transfer duration, then
//!   pays per-hop latency before the payload is visible downstream.
//! * **DMA**: iteration `i`'s LOAD blocks gate on the DMA having
//!   delivered chunks `0..=i` (plus a one-time weight stream), at the
//!   aggregate DDR bandwidth.
//!
//! Everything is deterministic: ties break on block id.
//!
//! # Data structures (the rewrite)
//!
//! The hot loop is built for throughput while staying **bit-exact**
//! with the pre-rewrite engine ([`super::reference`], enforced by
//! `rust/tests/sim_golden.rs`):
//!
//! * **Indexed event calendar** ([`EventWheel`]): a bucketed time wheel
//!   (`WHEEL_SLOTS` one-cycle buckets) with a sorted overflow tier for
//!   events beyond the horizon.  Push and pop are O(1) amortized, and
//!   same-cycle events drain in exact insertion order — the property
//!   that makes shared-resource (port/link) acquisition order, and
//!   therefore every statistic, identical to the old global
//!   `BinaryHeap<(time, seq, event)>`.
//! * **Pending-wake flags**: one boolean per function unit replaces the
//!   speculative `UnitFree` wake-up flood.  A unit has at most one live
//!   wake event queued at any moment (pushed when it goes busy, or when
//!   the first block becomes ready while it sits idle), so each block
//!   costs a bounded number of calendar operations and the stale-event
//!   `continue` path is gone entirely.
//! * **SPM port min-heap**: the earliest-free port is popped from a
//!   `(free_at, port)` heap instead of an O(ports) scan, preserving the
//!   earliest-free/lowest-index tie-break (the heap always holds
//!   exactly one entry per port).
//! * **Precomputed NoC routes**: XY paths live in the per-geometry
//!   [`crate::arch::RouteTable`] and are copied into per-block CSR
//!   slices at lowering ([`crate::dfg::ExecLayout`]), killing the
//!   per-FLOW `Vec` allocation of the old `xy_path` walk (kept below
//!   only as the executable route specification for tests).
//! * **Structure-of-arrays walk**: the loop reads the flat
//!   [`crate::dfg::ExecLayout`] arrays (unit, priorities, scalars,
//!   dependents CSR) built once at lowering — no per-call dependency
//!   CSR construction, no `&blocks[i]` field chasing, and `{layer,
//!   iter}` priorities pre-packed into one `u64` (FIFO mode still
//!   assigns its insertion-order priorities at ready time, preserving
//!   the ablation's semantics).
//! * **Reusable scratch arena** ([`SimWorkspace`]): all transient state
//!   (dependency counters, ready queues, calendar buckets, link/port
//!   occupancy) lives in a workspace that [`simulate_in`] recycles
//!   across calls, so windowed/batched re-simulation in
//!   [`crate::coordinator::Session`] stops paying a dozen allocations
//!   per invocation.  [`simulate`] remains the one-shot convenience
//!   wrapper.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::arch::ArchConfig;
use crate::dfg::{ExecLayout, Program};

use super::result::SimStats;

/// Simulation knobs (ablations + windowing live in the coordinator).
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Model a conventional single-line SPM: column-gather accesses
    /// serialize to one scalar per cycle (§V-C ablation).
    pub no_multiline_spm: bool,
    /// Disable the coarse-grained priority scheduler: FIFO block issue
    /// (ablation for the Fig. 8 design point).
    pub fifo_scheduling: bool,
    /// Injected hardware faults ([`crate::arch::FaultModel`]): degraded
    /// NoC links serialize scaled transfers and downed DDR channels
    /// shrink the delivery bandwidth.  `None` (the default) is the
    /// perfect machine — that path is code-identical to the pre-fault
    /// engine, so every healthy number stays bit-for-bit reproducible.
    pub faults: Option<std::sync::Arc<crate::arch::FaultModel>>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { no_multiline_spm: false, fifo_scheduling: false, faults: None }
    }
}

impl SimOptions {
    /// Stable field-by-field cache-key signature.
    ///
    /// Cache keys (the session's `arch_sig`, the autotune journal key,
    /// the structural result store) must change whenever any option
    /// that affects simulation changes — and `{:?}` formatting cannot
    /// guarantee that: a newly added field with a `Debug` impl that
    /// elides defaults (or a derive-format change across compiler
    /// versions) would silently alias keys across configurations.  The
    /// exhaustive destructuring below makes the compiler the guard:
    /// adding a field to `SimOptions` refuses to build until it is
    /// spliced into the signature here.
    ///
    /// The fault segment appears only when a model is present, so every
    /// pre-fault cache key (persisted structural stores, autotune
    /// journals) keeps its exact historical spelling.
    pub fn signature(&self) -> String {
        let SimOptions { no_multiline_spm, fifo_scheduling, faults } = self;
        let mut sig =
            format!("nomlspm{}|fifo{}", *no_multiline_spm as u8, *fifo_scheduling as u8);
        if let Some(f) = faults {
            sig.push('|');
            sig.push_str(&f.signature());
        }
        sig
    }
}

/// Unit-kind indices as stored in [`ExecLayout::unit`]
/// (`UnitKind::index()` values; asserted equivalent in tests).
const U_LOAD: u8 = 0;
const U_FLOW: u8 = 1;
const U_CAL: u8 = 2;
const U_STORE: u8 = 3;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// A unit may issue its next ready block (its previous service
    /// finished, or work arrived while it was idle).
    UnitFree { slot: u32 },
    /// A block's outputs are visible (dependents may fire).
    BlockDone { block: u32 },
    /// The DMA delivered an input chunk this block was gated on.
    DmaArrive { block: u32 },
}

/// Calendar bucket count (one cycle per bucket).  Power of two; events
/// further than this ahead of the cursor wait in the sorted overflow
/// tier and migrate as the horizon advances.
const WHEEL_SLOTS: usize = 1024;
const WHEEL_MASK: usize = WHEEL_SLOTS - 1;

/// Bucketed time wheel with a sorted overflow tier.
///
/// Invariants (the bit-exactness load-bearing ones):
///
/// * events are pushed at times `>= cursor` (the simulation is causal);
/// * every resident bucket event has time in `[cursor, cursor + W)`, so
///   a bucket holds exactly one time value at a time;
/// * the overflow tier holds only events at `>= cursor + W`, kept
///   sorted by `(time, seq)`; [`EventWheel::advance`] migrates entries
///   as the horizon moves — always *before* any processing at the new
///   cursor, so same-cycle ordering stays global insertion order even
///   across the two tiers.
#[derive(Debug, Default)]
struct EventWheel {
    buckets: Vec<Vec<Event>>,
    /// Read index into the current bucket.
    head: usize,
    /// Current time.
    cursor: u64,
    /// Unconsumed events resident in buckets.
    pending: usize,
    /// Events beyond the horizon: `(time, seq, event)` min-heap.
    overflow: BinaryHeap<Reverse<(u64, u64, Event)>>,
    /// Insertion counter for overflow ordering.
    seq: u64,
}

impl EventWheel {
    fn reset(&mut self) {
        if self.buckets.len() != WHEEL_SLOTS {
            self.buckets = (0..WHEEL_SLOTS).map(|_| Vec::new()).collect();
        } else {
            for b in &mut self.buckets {
                b.clear();
            }
        }
        self.head = 0;
        self.cursor = 0;
        self.pending = 0;
        self.overflow.clear();
        self.seq = 0;
    }

    #[inline]
    fn push(&mut self, t: u64, ev: Event) {
        debug_assert!(t >= self.cursor, "event pushed into the past");
        if t < self.cursor + WHEEL_SLOTS as u64 {
            self.buckets[t as usize & WHEEL_MASK].push(ev);
            self.pending += 1;
        } else {
            self.seq += 1;
            self.overflow.push(Reverse((t, self.seq, ev)));
        }
    }

    #[inline]
    fn pop(&mut self) -> Option<(u64, Event)> {
        loop {
            let b = self.cursor as usize & WHEEL_MASK;
            if self.head < self.buckets[b].len() {
                let ev = self.buckets[b][self.head];
                self.head += 1;
                self.pending -= 1;
                return Some((self.cursor, ev));
            }
            self.buckets[b].clear();
            self.head = 0;
            if self.pending > 0 {
                // All resident events are within the horizon; scan to
                // the next occupied cycle.
                let limit = self.cursor + WHEEL_SLOTS as u64;
                let mut t = self.cursor + 1;
                while t < limit && self.buckets[t as usize & WHEEL_MASK].is_empty() {
                    t += 1;
                }
                assert!(t < limit, "event wheel lost {} pending events", self.pending);
                self.advance(t);
            } else if let Some(&Reverse((t, _, _))) = self.overflow.peek() {
                self.advance(t);
            } else {
                return None;
            }
        }
    }

    /// Move the cursor and migrate overflow events inside the new
    /// horizon.  Must be the only way the cursor changes.
    fn advance(&mut self, to: u64) {
        self.cursor = to;
        let horizon = to + WHEEL_SLOTS as u64;
        while let Some(&Reverse((t, _, _))) = self.overflow.peek() {
            if t >= horizon {
                break;
            }
            let Reverse((t, _, ev)) = self.overflow.pop().unwrap();
            self.buckets[t as usize & WHEEL_MASK].push(ev);
            self.pending += 1;
        }
    }
}

/// Reusable scratch arena for [`simulate_in`]: every per-run transient
/// (dependency counters, per-unit ready queues and wake flags, SPM-port
/// and NoC-link occupancy, the event calendar) keeps its allocation
/// across calls.  One workspace serves one simulation at a time; the
/// coordinator's [`crate::coordinator::Session`] keeps a pool so
/// parallel `run_many` workers each reuse their own.
#[derive(Debug, Default)]
pub struct SimWorkspace {
    remaining: Vec<u32>,
    /// Per-unit ready queues: min-heap on (packed priority, block id).
    ready: Vec<BinaryHeap<Reverse<(u64, u32)>>>,
    /// Per-unit "a live UnitFree event is queued" flag.
    wake_pending: Vec<bool>,
    /// SPM ports: exactly one `(free_at, port)` entry per port.
    port_heap: BinaryHeap<Reverse<(u64, u32)>>,
    link_free: Vec<u64>,
    wheel: EventWheel,
}

impl SimWorkspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Run a program to completion and collect statistics (one-shot
/// wrapper over [`simulate_in`] with a throwaway workspace).
pub fn simulate(program: &Program, arch: &ArchConfig, opts: &SimOptions) -> SimStats {
    let mut ws = SimWorkspace::new();
    simulate_in(&mut ws, program, arch, opts)
}

/// Scheduler priority of a block at the moment it becomes ready: the
/// precomputed packed `{layer, iter}` string, or — under the FIFO
/// ablation — the next insertion-order ticket (assigned at ready time,
/// exactly like the reference engine's lazy `make_prio`).
#[inline]
fn next_prio(fifo: bool, fifo_seq: &mut u64, static_prio: u64) -> u64 {
    if fifo {
        *fifo_seq += 1;
        *fifo_seq
    } else {
        static_prio
    }
}

/// Mark a block ready on its unit's queue and wake the unit if no live
/// wake event is already scheduled (at most one per unit, ever).
#[inline]
fn enqueue_ready(
    ready: &mut [BinaryHeap<Reverse<(u64, u32)>>],
    wake_pending: &mut [bool],
    wheel: &mut EventWheel,
    prio: u64,
    slot: usize,
    block: u32,
    t: u64,
) {
    ready[slot].push(Reverse((prio, block)));
    if !wake_pending[slot] {
        wake_pending[slot] = true;
        wheel.push(t, Event::UnitFree { slot: slot as u32 });
    }
}

/// Run a program to completion inside a reusable workspace.
///
/// Results are independent of the workspace's history: every scratch
/// structure is reset (but not reallocated) before the run.
pub fn simulate_in(
    ws: &mut SimWorkspace,
    program: &Program,
    arch: &ArchConfig,
    opts: &SimOptions,
) -> SimStats {
    let exec: &ExecLayout = &program.exec;
    let nb = exec.len();
    let num_pes = arch.num_pes();
    let num_units = num_pes * 4;
    let w = arch.simd_width as u64;
    let entry = arch.spm_entry_width as u64;
    let num_ports = arch.spm_banks.max(1);

    // --- Reset the arena (allocation-free once warm). ---
    ws.remaining.clear();
    ws.remaining.extend_from_slice(&exec.n_deps);
    if ws.ready.len() < num_units {
        ws.ready.resize_with(num_units, BinaryHeap::new);
    }
    for q in &mut ws.ready[..num_units] {
        q.clear();
    }
    ws.wake_pending.clear();
    ws.wake_pending.resize(num_units, false);
    ws.port_heap.clear();
    for p in 0..num_ports {
        ws.port_heap.push(Reverse((0u64, p as u32)));
    }
    ws.link_free.clear();
    ws.link_free.resize(num_pes * 4, 0);
    ws.wheel.reset();

    // --- DMA schedule: weight preamble then per-iteration chunks. ---
    // A downed DDR channel shrinks the aggregate delivery bandwidth by
    // the surviving fraction; the healthy path never touches the scale
    // factor (bit-exactness of every fault-free number).
    let faults = opts.faults.as_deref();
    let bpc = match faults {
        Some(f) if f.ddr_down() > 0 => arch.ddr_bytes_per_cycle() * f.ddr_scale(),
        _ => arch.ddr_bytes_per_cycle(),
    };
    let weight_cycles = (program.meta.weight_dma_bytes as f64 / bpc).ceil() as u64;
    let chunk_in = program.meta.dma_in_bytes_per_iter as f64;
    // Inputs prefetch ahead of compute (double buffering).  Output
    // drains (`meta.dma_out_bytes_per_iter`) never gate loads: they are
    // charged to the writeback half of the channel budget — counted in
    // `SimStats::dma_bytes` below and priced by the coordinator
    // (`KernelResult::dma_time_s` deliberately excludes them), so they
    // deliberately do not appear in this delivery schedule.
    let dma_ready = |iter: u32| -> u64 {
        arch.dma_setup + weight_cycles + (((iter as f64 + 1.0) * chunk_in) / bpc).ceil() as u64
    };

    // Any layer-0 input load gates on DMA delivery; if at least one
    // exists, the makespan includes the cold-start fill `dma_ready(0)`
    // (setup + weight preamble + first chunk), which the coordinator's
    // streaming overlap model can hide under a preceding kernel.
    let mut stats = SimStats {
        unit_busy_per_pe: vec![[0u64; 4]; num_pes],
        active_pes: program.meta.active_pes,
        dma_bytes: program.meta.weight_dma_bytes
            + program.meta.iters as u64
                * (program.meta.dma_in_bytes_per_iter
                    + program.meta.dma_out_bytes_per_iter),
        dma_weight_bytes: program.meta.weight_dma_bytes,
        dma_in_bytes: program.meta.iters as u64 * program.meta.dma_in_bytes_per_iter,
        dma_fill_cycles: if exec.any_dma_gated { dma_ready(0) } else { 0 },
        ..Default::default()
    };
    let mut iter_done: Vec<u64> = vec![0; program.meta.iters];

    // FIFO-ablation priorities are assigned in ready order (matching
    // the reference engine's lazy `make_prio`), not block order.
    let fifo = opts.fifo_scheduling;
    let mut fifo_seq: u64 = 0;

    // --- Seed: initially-ready blocks and the DMA delivery calendar. ---
    for i in 0..nb {
        if exec.n_deps[i] == 0 {
            let prio = next_prio(fifo, &mut fifo_seq, exec.prio[i]);
            ws.ready[exec.unit_slot[i] as usize].push(Reverse((prio, i as u32)));
        }
        if exec.flags[i] & ExecLayout::FLAG_DMA_GATED != 0 {
            ws.wheel.push(dma_ready(exec.iter[i]), Event::DmaArrive { block: i as u32 });
        }
    }
    for slot in 0..num_units {
        ws.wake_pending[slot] = true;
        ws.wheel.push(0, Event::UnitFree { slot: slot as u32 });
    }

    // --- Event loop. ---
    let mut now: u64 = 0;
    while let Some((t, ev)) = ws.wheel.pop() {
        now = t; // calendar pops are time-monotone
        match ev {
            Event::BlockDone { block } => {
                let b = block as usize;
                let ds = exec.dep_start[b] as usize;
                let de = exec.dep_start[b + 1] as usize;
                for &dep in &exec.dep_flat[ds..de] {
                    let d = dep as usize;
                    ws.remaining[d] -= 1;
                    if ws.remaining[d] == 0 {
                        let prio = next_prio(fifo, &mut fifo_seq, exec.prio[d]);
                        enqueue_ready(
                            &mut ws.ready,
                            &mut ws.wake_pending,
                            &mut ws.wheel,
                            prio,
                            exec.unit_slot[d] as usize,
                            dep,
                            t,
                        );
                    }
                }
                if exec.flags[b] & ExecLayout::FLAG_COMPLETES_ITER != 0 {
                    let d = &mut iter_done[exec.iter[b] as usize];
                    *d = (*d).max(t);
                }
            }
            Event::DmaArrive { block } => {
                let b = block as usize;
                ws.remaining[b] -= 1;
                if ws.remaining[b] == 0 {
                    let prio = next_prio(fifo, &mut fifo_seq, exec.prio[b]);
                    enqueue_ready(
                        &mut ws.ready,
                        &mut ws.wake_pending,
                        &mut ws.wheel,
                        prio,
                        exec.unit_slot[b] as usize,
                        block,
                        t,
                    );
                }
            }
            Event::UnitFree { slot } => {
                let slot = slot as usize;
                ws.wake_pending[slot] = false;
                let Some(Reverse((_, bid))) = ws.ready[slot].pop() else {
                    continue;
                };
                let b = bid as usize;
                // Every queued UnitFree is live (the pending-wake flag
                // guarantees it), so service starts at the event time.
                let mut start = t;
                let mut done_at; // when outputs are visible
                let service_end; // when the unit frees
                let uidx = exec.unit[b];
                match uidx {
                    U_CAL => {
                        let dur = arch.block_issue_overhead + exec.ops[b];
                        service_end = start + dur;
                        done_at = service_end;
                    }
                    U_LOAD | U_STORE => {
                        // (DMA gating is a DmaArrive dependency, resolved
                        // before the block ever becomes ready.)
                        // Acquire the earliest-free SPM port (lowest
                        // index on ties) from the port heap.
                        let Reverse((pf, pi)) = ws.port_heap.pop().unwrap();
                        start = start.max(pf);
                        let wide = exec.scalars_wide[b] * w;
                        let wide_cycles = if opts.no_multiline_spm
                            && exec.flags[b] & ExecLayout::FLAG_COL_ACCESS != 0
                        {
                            // Column-gather without the multi-line design:
                            // one scalar per cycle.
                            wide
                        } else {
                            wide.div_ceil(entry)
                        };
                        let bcast_cycles = exec.scalars_bcast[b].div_ceil(entry);
                        let dur = arch.block_issue_overhead
                            + arch.spm_latency
                            + wide_cycles
                            + bcast_cycles;
                        ws.port_heap.push(Reverse((start + dur, pi)));
                        stats.spm_port_busy += dur;
                        stats.spm_scalars += wide + exec.scalars_bcast[b];
                        service_end = start + dur;
                        done_at = service_end;
                    }
                    U_FLOW => {
                        // Reserve the precomputed XY route; serialized
                        // transfer then per-hop latency to visibility.
                        let bytes = exec.scalars_wide[b] * w * arch.elem_bytes as u64;
                        let xfer = bytes.div_ceil(arch.noc_link_bytes as u64).max(1);
                        let rs = exec.route_start[b] as usize;
                        let re = exec.route_start[b + 1] as usize;
                        let route = &exec.route_flat[rs..re];
                        let mut s = start;
                        for &l in route {
                            s = s.max(ws.link_free[l as usize]);
                        }
                        let (tail, hop_lat) = match faults {
                            None => {
                                for &l in route {
                                    ws.link_free[l as usize] = s + xfer;
                                }
                                (xfer, exec.noc_hops[b] as u64 * arch.noc_hop_latency)
                            }
                            Some(f) => {
                                // Degraded links serialize a scaled
                                // transfer: the path frees when its
                                // slowest link drains, and each hop's
                                // latency scales with its multiplier.
                                let mut worst = xfer;
                                let mut lat = 0;
                                for &l in route {
                                    let x = xfer * f.link_multiplier(l as usize);
                                    ws.link_free[l as usize] = s + x;
                                    worst = worst.max(x);
                                    lat += arch.noc_hop_latency
                                        * f.link_multiplier(l as usize);
                                }
                                (worst, lat)
                            }
                        };
                        let dur = arch.block_issue_overhead + (s - start) + tail;
                        stats.noc_scalars += exec.scalars_wide[b] * w;
                        service_end = start + dur;
                        done_at = service_end + hop_lat;
                    }
                    _ => unreachable!("unit kind index out of range"),
                }
                if done_at < service_end {
                    done_at = service_end;
                }
                let busy = service_end - start;
                stats.unit_busy[uidx as usize] += busy;
                stats.unit_busy_per_pe[exec.pe[b] as usize][uidx as usize] += busy;
                stats.blocks_run += 1;
                ws.wake_pending[slot] = true;
                ws.wheel.push(service_end, Event::UnitFree { slot: slot as u32 });
                ws.wheel.push(done_at, Event::BlockDone { block: bid });
            }
        }
    }

    stats.cycles = now;
    stats.iter_done = iter_done;
    stats
}

/// Directed link ids along the XY route from `src` to `dst` — the
/// executable route *specification*.  The hot loop reads the
/// [`crate::arch::RouteTable`]-derived CSR slices instead; tests assert
/// the two stay equivalent over the full mesh.
/// Link encoding: `pe * 4 + dir` with dir 0=E, 1=W, 2=S, 3=N, owned by the
/// *upstream* PE.
#[cfg(test)]
fn xy_path(src: usize, dst: usize, arch: &ArchConfig) -> Vec<usize> {
    let cols = arch.mesh_cols;
    let (mut r, mut c) = (src / cols, src % cols);
    let (dr, dc) = (dst / cols, dst % cols);
    let mut path = Vec::new();
    while c != dc {
        let pe = r * cols + c;
        if dc > c {
            path.push(pe * 4);
            c += 1;
        } else {
            path.push(pe * 4 + 1);
            c -= 1;
        }
    }
    while r != dr {
        let pe = r * cols + c;
        if dr > r {
            path.push(pe * 4 + 2);
            r += 1;
        } else {
            path.push(pe * 4 + 3);
            r -= 1;
        }
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{RouteTable, UnitKind};
    use crate::dfg::graph::KernelKind;
    use crate::dfg::microcode::lower_stage;
    use crate::dfg::stages::StageDfg;

    fn stage(kind: KernelKind, points: usize) -> StageDfg {
        StageDfg { kind, points, sub_iters: 1, twiddle_before: false, weights_from_ddr: false }
    }

    fn run(kind: KernelKind, points: usize, iters: usize) -> SimStats {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(kind, points), &arch, iters);
        p.validate().unwrap();
        simulate(&p, &arch, &SimOptions::default())
    }

    #[test]
    fn unit_kind_constants_match_index() {
        assert_eq!(U_LOAD as usize, UnitKind::Load.index());
        assert_eq!(U_FLOW as usize, UnitKind::Flow.index());
        assert_eq!(U_CAL as usize, UnitKind::Cal.index());
        assert_eq!(U_STORE as usize, UnitKind::Store.index());
    }

    #[test]
    fn completes_and_is_deterministic() {
        let a = run(KernelKind::Bpmm, 256, 4);
        let b = run(KernelKind::Bpmm, 256, 4);
        assert!(a.cycles > 0);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.unit_busy, b.unit_busy);
        assert_eq!(a.blocks_run, b.blocks_run);
    }

    #[test]
    fn workspace_reuse_is_bit_exact() {
        // One workspace across heterogeneous programs must produce the
        // same stats as fresh one-shot runs, in any order.
        let arch = ArchConfig::full();
        let progs = [
            lower_stage(&stage(KernelKind::Fft, 256), &arch, 8),
            lower_stage(&stage(KernelKind::Bpmm, 64), &arch, 3),
            lower_stage(&stage(KernelKind::Fft, 256), &arch, 8),
        ];
        let mut ws = SimWorkspace::new();
        let opts = SimOptions::default();
        for p in &progs {
            let reused = simulate_in(&mut ws, p, &arch, &opts);
            let fresh = simulate(p, &arch, &opts);
            assert_eq!(reused, fresh);
        }
    }

    #[test]
    fn all_blocks_execute() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Fft, 128), &arch, 3);
        let s = simulate(&p, &arch, &SimOptions::default());
        assert_eq!(s.blocks_run as usize, p.blocks.len());
    }

    #[test]
    fn iteration_completions_monotone() {
        let s = run(KernelKind::Bpmm, 256, 8);
        for w in s.iter_done.windows(2) {
            assert!(w[0] <= w[1], "{:?}", s.iter_done);
        }
        assert!(*s.iter_done.last().unwrap() <= s.cycles);
    }

    #[test]
    fn pipelining_beats_serial_execution() {
        // 8 iterations pipelined must be much cheaper than 8x one
        // iteration (the coarse-grained streaming claim of §V-A).
        let one = run(KernelKind::Fft, 256, 1).cycles;
        let eight = run(KernelKind::Fft, 256, 8).cycles;
        assert!(
            (eight as f64) < 0.7 * (8 * one) as f64,
            "no pipelining: 1 iter {one}, 8 iters {eight}"
        );
    }

    #[test]
    fn cal_dominates_for_large_fft() {
        // §VI-D: Cal utilization over 89% for FFT at large scales;
        // Load under 6%.  Check the ordering (not the exact numbers) in
        // a long steady window.
        let s = run(KernelKind::Fft, 256, 32);
        let cal = s.unit_busy[UnitKind::Cal.index()] as f64;
        let load = s.unit_busy[UnitKind::Load.index()] as f64;
        let flow = s.unit_busy[UnitKind::Flow.index()] as f64;
        assert!(cal > flow, "cal {cal} flow {flow}");
        assert!(cal > 3.0 * load, "cal {cal} load {load}");
    }

    #[test]
    fn fft_flows_more_than_bpmm() {
        // §VI-D: FFT needs twice the Flow traffic of BPMM.
        let f = run(KernelKind::Fft, 256, 16);
        let b = run(KernelKind::Bpmm, 256, 16);
        assert!(f.noc_scalars == 2 * b.noc_scalars);
    }

    #[test]
    fn fifo_scheduling_is_comparable_but_not_better_at_steady_state() {
        // The {layer, iter} priority scheduler must track the
        // dependency-driven FIFO baseline closely (FIFO arrival order is
        // itself near-optimal for a layered DAG); the paper's argument is
        // that the *cheap* priority rule suffices — verify it stays
        // within 3% and does not collapse.
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Fft, 256), &arch, 32);
        let pri = simulate(&p, &arch, &SimOptions::default());
        let fifo = simulate(
            &p,
            &arch,
            &SimOptions { fifo_scheduling: true, ..Default::default() },
        );
        // Measured: the layer-major rule trails dependency-order FIFO by
        // ~6% here because postponing STOREs delays buffer recycling —
        // recorded as an ablation in EXPERIMENTS.md.  Guard the band.
        assert!(
            (pri.cycles as f64) <= fifo.cycles as f64 * 1.10,
            "priority {} vs fifo {}",
            pri.cycles,
            fifo.cycles
        );
    }

    #[test]
    fn single_line_spm_is_slower() {
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Bpmm, 512), &arch, 8);
        let multi = simulate(&p, &arch, &SimOptions::default());
        let single = simulate(
            &p,
            &arch,
            &SimOptions { no_multiline_spm: true, ..Default::default() },
        );
        assert!(single.cycles >= multi.cycles);
    }

    #[test]
    fn xy_path_lengths_match_manhattan() {
        let arch = ArchConfig::full();
        for src in 0..arch.num_pes() {
            for dst in 0..arch.num_pes() {
                let path = xy_path(src, dst, &arch);
                assert_eq!(path.len(), arch.hop_distance(src, dst));
            }
        }
    }

    #[test]
    fn route_table_matches_legacy_xy_path() {
        // The precomputed per-geometry table the engine consumes must
        // reproduce the legacy walk link-for-link over the full mesh —
        // including a non-square geometry.
        for arch in [
            ArchConfig::full(),
            ArchConfig { mesh_rows: 2, mesh_cols: 8, ..ArchConfig::full() },
        ] {
            let table = RouteTable::for_arch(&arch);
            assert_eq!(table.num_pes(), arch.num_pes());
            for src in 0..arch.num_pes() {
                for dst in 0..arch.num_pes() {
                    let legacy: Vec<u32> =
                        xy_path(src, dst, &arch).iter().map(|&l| l as u32).collect();
                    assert_eq!(
                        table.route(src, dst),
                        &legacy[..],
                        "route {src}->{dst} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn utilization_bounds() {
        let arch = ArchConfig::full();
        let s = run(KernelKind::Fft, 256, 16);
        for k in crate::arch::UnitKind::ALL {
            let u = s.utilization(k, arch.num_pes());
            assert!((0.0..=1.0).contains(&u), "{k:?} {u}");
        }
    }

    #[test]
    fn event_wheel_orders_across_overflow() {
        // Events pushed beyond the horizon must drain in (time,
        // insertion) order once the cursor reaches them, interleaved
        // correctly with direct bucket pushes at the same cycle.
        let mut wh = EventWheel::default();
        wh.reset();
        let far = WHEEL_SLOTS as u64 * 3 + 17;
        wh.push(far, Event::BlockDone { block: 1 }); // overflow
        wh.push(0, Event::UnitFree { slot: 0 }); // bucket
        wh.push(far + 1, Event::BlockDone { block: 2 }); // overflow
        let (t0, e0) = wh.pop().unwrap();
        assert_eq!((t0, e0), (0, Event::UnitFree { slot: 0 }));
        // While at cursor 0, same-time far events land after migrated
        // overflow entries only if pushed after the horizon crossed —
        // push one at `far` now (still beyond horizon => overflow, with
        // a later seq than block 1).
        wh.push(far, Event::BlockDone { block: 3 });
        let order: Vec<_> = std::iter::from_fn(|| wh.pop()).collect();
        assert_eq!(
            order,
            vec![
                (far, Event::BlockDone { block: 1 }),
                (far, Event::BlockDone { block: 3 }),
                (far + 1, Event::BlockDone { block: 2 }),
            ]
        );
    }

    #[test]
    fn sim_options_signature_is_explicit_and_field_sensitive() {
        // Pinned: the signature is a hand-built field list, never a
        // `{:?}` dump (which could silently alias cache keys — the
        // satellite fix this test guards).
        assert_eq!(SimOptions::default().signature(), "nomlspm0|fifo0");
        let spm = SimOptions { no_multiline_spm: true, ..Default::default() };
        let fifo = SimOptions { fifo_scheduling: true, ..Default::default() };
        assert_eq!(spm.signature(), "nomlspm1|fifo0");
        assert_eq!(fifo.signature(), "nomlspm0|fifo1");
        assert_ne!(spm.signature(), fifo.signature());
        assert!(!SimOptions::default().signature().contains("SimOptions"));
        // Faults extend the signature only when present: every
        // pre-fault cache key keeps its historical spelling.
        let mut fm = crate::arch::FaultModel::for_arch(&ArchConfig::full());
        fm.kill_pe(2).unwrap();
        let faulty =
            SimOptions { faults: Some(std::sync::Arc::new(fm)), ..Default::default() };
        assert_eq!(faulty.signature(), "nomlspm0|fifo0|fault[pes16|dead=2|links=|ddr0]");
    }

    #[test]
    fn degraded_links_and_ddr_slow_the_run_monotonically() {
        // A ladder of worsening fault sets must never speed the machine
        // up — and a healthy FaultModel must be priced exactly like no
        // model at all (the graceful-degradation acceptance criterion at
        // the engine level).
        use crate::arch::FaultModel;
        use std::sync::Arc;
        let arch = ArchConfig::full();
        let p = lower_stage(&stage(KernelKind::Fft, 256), &arch, 8);
        let base = simulate(&p, &arch, &SimOptions::default());
        let healthy = SimOptions {
            faults: Some(Arc::new(FaultModel::for_arch(&arch))),
            ..Default::default()
        };
        assert_eq!(simulate(&p, &arch, &healthy), base, "healthy model is a no-op");
        let mut prev = base.cycles;
        for mult in [2u32, 8, 32] {
            let mut fm = FaultModel::for_arch(&arch);
            for l in 0..arch.num_pes() * 4 {
                fm.degrade_link(l, mult).unwrap();
            }
            let opts = SimOptions { faults: Some(Arc::new(fm)), ..Default::default() };
            let s = simulate(&p, &arch, &opts);
            assert!(s.cycles >= prev, "mult {mult}: {} < {prev}", s.cycles);
            prev = s.cycles;
        }
        assert!(prev > base.cycles, "fully degraded NoC must cost cycles");
        // Downing one of full()'s two DDR channels stretches the
        // delivery schedule.
        let mut fm = FaultModel::for_arch(&arch);
        fm.down_ddr(1).unwrap();
        let opts = SimOptions { faults: Some(Arc::new(fm)), ..Default::default() };
        let s = simulate(&p, &arch, &opts);
        assert!(s.dma_fill_cycles > base.dma_fill_cycles);
        assert!(s.cycles >= base.cycles);
    }

    #[test]
    fn matches_reference_engine_smoke() {
        // Full-matrix equality lives in rust/tests/sim_golden.rs; keep
        // one in-crate guard so `cargo test --lib` alone catches drift.
        let arch = ArchConfig::full();
        for (kind, points, iters) in
            [(KernelKind::Fft, 128, 6), (KernelKind::Bpmm, 512, 3)]
        {
            let p = lower_stage(&stage(kind, points), &arch, iters);
            for opts in [
                SimOptions::default(),
                SimOptions { fifo_scheduling: true, ..Default::default() },
                SimOptions { no_multiline_spm: true, ..Default::default() },
            ] {
                let new = simulate(&p, &arch, &opts);
                let old = crate::sim::reference::simulate(&p, &arch, &opts);
                assert_eq!(new, old, "{kind:?}-{points} x{iters} {opts:?}");
            }
        }
    }
}
