//! The pre-rewrite discrete-event core, frozen verbatim as the
//! bit-exactness oracle.
//!
//! [`super::engine`] rewrote the simulator's data structures for
//! throughput (indexed event calendar, pending-wake flags, precomputed
//! NoC routes, structure-of-arrays program walk, reusable workspace)
//! under a *bit-exact* contract: every [`SimStats`] field must match
//! this implementation on every program.  This module is that contract
//! made executable — `rust/tests/sim_golden.rs` runs both engines over
//! a fixture matrix and all registered workload suites and asserts
//! exact equality, and `benches/perf_simulator.rs` measures both so the
//! speedup is recorded against the true pre-rewrite baseline in the
//! same run.
//!
//! Except for reading [`SimOptions`] from the engine module (the knobs
//! are shared) and borrowing dependent-CSR naming, the body below is
//! the seed engine unchanged — including its per-call CSR construction,
//! speculative `UnitFree` wake-ups, O(ports) port scans and per-FLOW
//! route allocation, which are exactly the costs the rewrite removed.
//! Do not "improve" this file; its value is being frozen.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::arch::{ArchConfig, UnitKind};
use crate::dfg::{Block, Program};

use super::engine::SimOptions;
use super::result::SimStats;

/// Priority key: the paper's `{Layer_idx, Iter_idx}` bit string; FIFO
/// mode degrades to insertion order.
type Prio = (u16, u32, u32);

struct UnitState {
    free_at: u64,
    ready: BinaryHeap<Reverse<(Prio, u32)>>, // ((layer, iter, seq), block)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// A block's service finished on its unit (unit becomes free).
    UnitFree { pe: u16, unit: u8 },
    /// A block's outputs are visible (dependents may fire).
    BlockDone { block: u32 },
    /// The DMA delivered an input chunk this block was gated on.
    DmaArrive { block: u32 },
}

/// Whether a block gates on DMA delivery: input-bearing layer-0 loads
/// wait for their iteration's chunk.
fn dma_gated(b: &Block) -> bool {
    b.unit == UnitKind::Load && b.layer == 0 && b.scalars_wide > 0
}

/// Run a program to completion and collect statistics — the pre-rewrite
/// engine, kept only as the golden/benchmark baseline.
pub fn simulate(program: &Program, arch: &ArchConfig, opts: &SimOptions) -> SimStats {
    let blocks = &program.blocks;
    let num_pes = arch.num_pes();
    let w = arch.simd_width as u64;
    let entry = arch.spm_entry_width as u64;

    // Dependents (CSR layout — one flat array, no per-block Vecs) +
    // remaining-dep counts.
    let mut remaining: Vec<u32> = vec![0; blocks.len()];
    let mut dep_start: Vec<u32> = vec![0; blocks.len() + 1];
    for b in blocks.iter() {
        for d in &b.deps {
            dep_start[d.0 as usize + 1] += 1;
        }
    }
    for i in 0..blocks.len() {
        dep_start[i + 1] += dep_start[i];
    }
    let mut dep_flat: Vec<u32> = vec![0; dep_start[blocks.len()] as usize];
    let mut cursor: Vec<u32> = dep_start[..blocks.len()].to_vec();
    for (i, b) in blocks.iter().enumerate() {
        remaining[i] = b.deps.len() as u32;
        for d in &b.deps {
            let c = &mut cursor[d.0 as usize];
            dep_flat[*c as usize] = i as u32;
            *c += 1;
        }
        // Input-bearing layer-0 loads carry an extra virtual dependency
        // on the DMA delivery of their iteration's chunk (resolved by a
        // DmaArrive event) — the unit itself never stalls on DMA.
        if dma_gated(b) {
            remaining[i] += 1;
        }
    }
    let dependents = |block: usize| -> &[u32] {
        &dep_flat[dep_start[block] as usize..dep_start[block + 1] as usize]
    };

    // Units.
    let mut units: Vec<UnitState> = (0..num_pes * 4)
        .map(|_| UnitState { free_at: 0, ready: BinaryHeap::new() })
        .collect();
    let unit_idx = |pe: u16, unit: UnitKind| pe as usize * 4 + unit.index();

    // SPM ports: one SIMD16 port per bank for row-wise access; the
    // multi-line interleave makes column access equal cost (§V-C).
    let num_ports = arch.spm_banks.max(1);
    let mut port_free: Vec<u64> = vec![0; num_ports];

    // NoC links: directed, 4 per PE (N, E, S, W neighbours).
    let mut link_free: Vec<u64> = vec![0; num_pes * 4];

    // DMA schedule: weight preamble then per-iteration in+out chunks.
    let bpc = arch.ddr_bytes_per_cycle();
    let weight_cycles = (program.meta.weight_dma_bytes as f64 / bpc).ceil() as u64;
    let chunk_in = program.meta.dma_in_bytes_per_iter as f64;
    let chunk_out = program.meta.dma_out_bytes_per_iter as f64;
    // Inputs prefetch ahead of compute (double buffering); outputs drain
    // on the writeback half of the channel budget and never gate loads.
    let _ = chunk_out;
    let dma_ready = |iter: u32| -> u64 {
        arch.dma_setup + weight_cycles + (((iter as f64 + 1.0) * chunk_in) / bpc).ceil() as u64
    };

    // Any layer-0 input load gates on DMA delivery; if at least one
    // exists, the makespan includes the cold-start fill `dma_ready(0)`
    // (setup + weight preamble + first chunk), which the coordinator's
    // streaming overlap model can hide under a preceding kernel.
    let gated_loads = blocks.iter().any(dma_gated);
    let mut stats = SimStats {
        unit_busy_per_pe: vec![[0u64; 4]; num_pes],
        active_pes: program.meta.active_pes,
        dma_bytes: program.meta.weight_dma_bytes
            + program.meta.iters as u64
                * (program.meta.dma_in_bytes_per_iter
                    + program.meta.dma_out_bytes_per_iter),
        dma_weight_bytes: program.meta.weight_dma_bytes,
        dma_in_bytes: program.meta.iters as u64 * program.meta.dma_in_bytes_per_iter,
        dma_fill_cycles: if gated_loads { dma_ready(0) } else { 0 },
        ..Default::default()
    };
    let mut iter_done: Vec<u64> = vec![0; program.meta.iters];

    // Event queue: (time, seq, event).
    let mut seq: u64 = 0;
    let mut events: BinaryHeap<Reverse<(u64, u64, Event)>> = BinaryHeap::new();
    let push_event = |events: &mut BinaryHeap<Reverse<(u64, u64, Event)>>,
                          seq: &mut u64,
                          t: u64,
                          e: Event| {
        *seq += 1;
        events.push(Reverse((t, *seq, e)));
    };

    // Seed ready sets.
    let mut fifo_seq: u32 = 0;
    let mut make_prio = |b: &Block, opts: &SimOptions| -> Prio {
        if opts.fifo_scheduling {
            fifo_seq += 1;
            (0, fifo_seq, 0)
        } else {
            (b.layer, b.iter, 0)
        }
    };
    for (i, b) in blocks.iter().enumerate() {
        if remaining[i] == 0 {
            let p = make_prio(b, opts);
            units[unit_idx(b.pe, b.unit)].ready.push(Reverse((p, i as u32)));
        }
        if dma_gated(b) {
            push_event(
                &mut events,
                &mut seq,
                dma_ready(b.iter),
                Event::DmaArrive { block: i as u32 },
            );
        }
    }
    for pe in 0..num_pes as u16 {
        for unit in 0..4u8 {
            push_event(&mut events, &mut seq, 0, Event::UnitFree { pe, unit });
        }
    }

    let mut now: u64 = 0;
    while let Some(Reverse((t, _, ev))) = events.pop() {
        now = now.max(t);
        match ev {
            Event::BlockDone { block } => {
                for &dep in dependents(block as usize) {
                    remaining[dep as usize] -= 1;
                    if remaining[dep as usize] == 0 {
                        let b = &blocks[dep as usize];
                        let p = make_prio(b, opts);
                        let ui = unit_idx(b.pe, b.unit);
                        units[ui].ready.push(Reverse((p, dep)));
                        if units[ui].free_at <= t {
                            push_event(
                                &mut events,
                                &mut seq,
                                t,
                                Event::UnitFree { pe: b.pe, unit: b.unit.index() as u8 },
                            );
                        }
                    }
                }
                let b = &blocks[block as usize];
                if b.completes_iter {
                    let d = &mut iter_done[b.iter as usize];
                    *d = (*d).max(t);
                }
            }
            Event::DmaArrive { block } => {
                remaining[block as usize] -= 1;
                if remaining[block as usize] == 0 {
                    let b = &blocks[block as usize];
                    let p = make_prio(b, opts);
                    let ui = unit_idx(b.pe, b.unit);
                    units[ui].ready.push(Reverse((p, block)));
                    if units[ui].free_at <= t {
                        push_event(
                            &mut events,
                            &mut seq,
                            t,
                            Event::UnitFree { pe: b.pe, unit: b.unit.index() as u8 },
                        );
                    }
                }
            }
            Event::UnitFree { pe, unit } => {
                let ui = pe as usize * 4 + unit as usize;
                if units[ui].free_at > t {
                    continue; // stale wake-up; a real free event will come
                }
                let Some(Reverse((_, bid))) = units[ui].ready.pop() else {
                    continue;
                };
                let b = &blocks[bid as usize];
                let mut start = t.max(units[ui].free_at);
                let mut done_at; // when outputs are visible
                let service_end; // when the unit frees
                match b.unit {
                    UnitKind::Cal => {
                        let dur = arch.block_issue_overhead + b.ops;
                        service_end = start + dur;
                        done_at = service_end;
                    }
                    UnitKind::Load | UnitKind::Store => {
                        // (DMA gating is a DmaArrive dependency, resolved
                        // before the block ever becomes ready.)
                        // Acquire the earliest-free SPM port.
                        let (pi, pf) = port_free
                            .iter()
                            .enumerate()
                            .min_by_key(|(i, f)| (**f, *i))
                            .map(|(i, f)| (i, *f))
                            .unwrap();
                        start = start.max(pf);
                        let wide = b.scalars_wide * w;
                        let wide_cycles = if opts.no_multiline_spm && b.layer > 0 {
                            // Column-gather without the multi-line design:
                            // one scalar per cycle.
                            wide
                        } else {
                            wide.div_ceil(entry)
                        };
                        let bcast_cycles = b.scalars_bcast.div_ceil(entry);
                        let dur = arch.block_issue_overhead
                            + arch.spm_latency
                            + wide_cycles
                            + bcast_cycles;
                        port_free[pi] = start + dur;
                        stats.spm_port_busy += dur;
                        stats.spm_scalars += wide + b.scalars_bcast;
                        service_end = start + dur;
                        done_at = service_end;
                    }
                    UnitKind::Flow => {
                        // Reserve the XY path; serialized transfer then
                        // per-hop latency to visibility.
                        let bytes = b.scalars_wide * w * arch.elem_bytes as u64;
                        let xfer = bytes.div_ceil(arch.noc_link_bytes as u64).max(1);
                        let dest = b.dest_pe.unwrap_or(b.pe) as usize;
                        let path = xy_path(b.pe as usize, dest, arch);
                        let mut s = start;
                        for &l in &path {
                            s = s.max(link_free[l]);
                        }
                        for &l in &path {
                            link_free[l] = s + xfer;
                        }
                        let dur = arch.block_issue_overhead + (s - start) + xfer;
                        stats.noc_scalars += b.scalars_wide * w;
                        service_end = start + dur;
                        done_at =
                            service_end + b.noc_hops as u64 * arch.noc_hop_latency;
                    }
                }
                if done_at < service_end {
                    done_at = service_end;
                }
                let busy = service_end - start;
                stats.unit_busy[b.unit.index()] += busy;
                stats.unit_busy_per_pe[b.pe as usize][b.unit.index()] += busy;
                stats.blocks_run += 1;
                units[ui].free_at = service_end;
                push_event(&mut events, &mut seq, service_end, Event::UnitFree { pe, unit });
                push_event(&mut events, &mut seq, done_at, Event::BlockDone { block: bid });
            }
        }
    }

    stats.cycles = now;
    stats.iter_done = iter_done;
    stats
}

/// Directed link ids along the XY route from `src` to `dst`.
/// Link encoding: `pe * 4 + dir` with dir 0=E, 1=W, 2=S, 3=N, owned by the
/// *upstream* PE.
fn xy_path(src: usize, dst: usize, arch: &ArchConfig) -> Vec<usize> {
    let cols = arch.mesh_cols;
    let (mut r, mut c) = (src / cols, src % cols);
    let (dr, dc) = (dst / cols, dst % cols);
    let mut path = Vec::new();
    while c != dc {
        let pe = r * cols + c;
        if dc > c {
            path.push(pe * 4);
            c += 1;
        } else {
            path.push(pe * 4 + 1);
            c -= 1;
        }
    }
    while r != dr {
        let pe = r * cols + c;
        if dr > r {
            path.push(pe * 4 + 2);
            r += 1;
        } else {
            path.push(pe * 4 + 3);
            r -= 1;
        }
    }
    path
}
