//! Simulation statistics.

use crate::arch::UnitKind;

/// Statistics of one simulated program (one stage DFG × window iters).
///
/// Every field is integral and the simulator is deterministic, so two
/// runs of equivalent engines over the same program must compare
/// *exactly* equal — `PartialEq`/`Eq` here is the bit-exactness
/// contract the golden suite (`rust/tests/sim_golden.rs`) checks the
/// rewritten engine against [`crate::sim::reference`] with.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Total simulated cycles (makespan).
    pub cycles: u64,
    /// Busy cycles per unit kind, summed over PEs.
    pub unit_busy: [u64; 4],
    /// Busy cycles per unit kind *per PE* (pe-major).
    pub unit_busy_per_pe: Vec<[u64; 4]>,
    /// Scalars served by the SPM (lane-scaled + broadcast).
    pub spm_scalars: u64,
    /// Scalars moved over the NoC (lane-scaled).
    pub noc_scalars: u64,
    /// Cycles SPM ports were busy (for port-utilization metrics).
    pub spm_port_busy: u64,
    /// Bytes streamed by DMA (in + out + weights).
    pub dma_bytes: u64,
    /// The one-time weight-preamble portion of `dma_bytes`: streamed
    /// once per stage execution, not per iteration, so window
    /// extrapolation must not scale it (the remainder of `dma_bytes`
    /// is per-iteration input/output traffic and does scale).
    pub dma_weight_bytes: u64,
    /// Per-iteration *input* bytes over the whole window (`iters ×
    /// in_bytes_per_iter`): together with the weight preamble this is
    /// the gating DMA stream — the engine charges outputs to the
    /// writeback half of the channel budget, where they never gate
    /// compute.
    pub dma_in_bytes: u64,
    /// Cold-start DMA prologue (cycles): setup + weight preamble + the
    /// first per-iteration input chunk — the part of the makespan that
    /// elapses before any DMA-gated load can fire.  Zero when no load
    /// gates on DMA.  The coordinator's overlap model hides this fill
    /// under the preceding kernel's steady state when streaming
    /// (see `coordinator::pipeline`).
    pub dma_fill_cycles: u64,
    /// Completion time of each DFG iteration (cycles).
    pub iter_done: Vec<u64>,
    /// Blocks executed.
    pub blocks_run: u64,
    /// PEs that hosted work.
    pub active_pes: usize,
}

impl SimStats {
    /// Utilization of a unit kind over *active* PEs (the paper reports
    /// per-design utilization; idle PEs of a shallow DFG count against
    /// it via `active_pes` vs the full array in the caller).
    pub fn utilization(&self, kind: UnitKind, num_pes: usize) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.unit_busy[kind.index()] as f64 / (self.cycles as f64 * num_pes as f64)
    }

    /// Steady-state cycles per iteration, measured over the second half
    /// of the window (used for extrapolation beyond the window).
    pub fn steady_cycles_per_iter(&self) -> f64 {
        let n = self.iter_done.len();
        if n < 2 {
            return self.cycles as f64;
        }
        let half = n / 2;
        let span = self.iter_done[n - 1].saturating_sub(self.iter_done[half - 1]);
        let iters = (n - half) as f64;
        if span == 0 {
            // Fully parallel window: fall back to makespan/iters.
            self.cycles as f64 / n as f64
        } else {
            span as f64 / iters
        }
    }
}
