//! Tentpole contracts of the parallel, incremental simulator: stage
//! sharding must be bitwise-invisible (any thread count reproduces the
//! serial results exactly, suite by suite and strategy by strategy),
//! and the cross-session [`StructuralStore`] must hand later sessions
//! the earlier sessions' measurements — without ever conflating keys
//! that differ in architecture, simulator options or PE mapping.

use std::sync::Arc;

use butterfly_dataflow::arch::ArchConfig;
use butterfly_dataflow::coordinator::{Session, StructuralStore};
use butterfly_dataflow::dfg::strategy::Strategy;
use butterfly_dataflow::sim::SimOptions;
use butterfly_dataflow::workloads;

/// Small window + batch keep the all-suites sweeps cheap in debug mode;
/// the contracts under test are thread-count and store invariance, not
/// absolute numbers.
const WINDOW: usize = 8;
const BATCH: usize = 1;

fn builder(strategy: Strategy, threads: usize) -> Session {
    Session::builder()
        .arch(ArchConfig::scaled_128())
        .window(WINDOW)
        .strategy(strategy)
        .threads(threads)
        .build()
}

fn assert_streams_equal(name: &str, a: &Session, b: &Session) {
    let suite = workloads::find_suite(name).unwrap();
    let kernels = suite.kernels_at(Some(BATCH));
    let ra = a.stream(&kernels, BATCH).unwrap();
    let rb = b.stream(&kernels, BATCH).unwrap();
    assert_eq!(ra.kernels.len(), rb.kernels.len());
    for (ka, kb) in ra.kernels.iter().zip(&rb.kernels) {
        assert_eq!(ka.name, kb.name, "{name}: kernel order diverged");
        assert_eq!(ka.cycles, kb.cycles, "{name}/{}", ka.name);
        assert_eq!(ka.time_s, kb.time_s, "{name}/{}", ka.name);
        assert_eq!(ka.util, kb.util, "{name}/{}", ka.name);
        assert_eq!(ka.power_w, kb.power_w, "{name}/{}", ka.name);
        assert_eq!(ka.energy_j, kb.energy_j, "{name}/{}", ka.name);
        assert_eq!(ka.spm_requirement, kb.spm_requirement, "{name}/{}", ka.name);
        assert_eq!(ka.noc_requirement, kb.noc_requirement, "{name}/{}", ka.name);
        assert_eq!(ka.dma_bytes, kb.dma_bytes, "{name}/{}", ka.name);
        assert_eq!(ka.dma_time_s, kb.dma_time_s, "{name}/{}", ka.name);
        assert_eq!(ka.fill_time_s, kb.fill_time_s, "{name}/{}", ka.name);
    }
    assert_eq!(ra.latency_ms, rb.latency_ms, "{name}");
    assert_eq!(ra.batch_time_s, rb.batch_time_s, "{name}");
    assert_eq!(ra.energy_j, rb.energy_j, "{name}");
    assert_eq!(ra.power_w, rb.power_w, "{name}");
}

#[test]
fn parallel_matches_serial_bitwise_on_every_suite_and_strategy() {
    // The headline tentpole contract: an 8-thread session (kernel
    // fan-out *and* intra-kernel stage sharding both active) streams
    // every registered suite bitwise-identically to a 1-thread session,
    // under both concrete strategies — including the per-key cache
    // counters, which the fill cells keep deterministic under any
    // interleaving.
    for strategy in [Strategy::Paper, Strategy::SpmAdaptive] {
        let serial = builder(strategy, 1);
        let parallel = builder(strategy, 8);
        assert_eq!(serial.threads(), 1);
        assert_eq!(parallel.threads(), 8);
        for name in workloads::suite_names() {
            assert_streams_equal(name, &serial, &parallel);
        }
        assert_eq!(
            serial.cache_stats(),
            parallel.cache_stats(),
            "{}: cache counters depend on thread count",
            strategy.name()
        );
    }
}

#[test]
fn shared_store_replays_across_sessions() {
    // Two sessions over the same configuration sharing one store: the
    // second must not lower anything — every stage-cache miss is served
    // structurally — and must reproduce the first's results bitwise.
    let store = Arc::new(StructuralStore::new());
    let first = Session::builder()
        .arch(ArchConfig::scaled_128())
        .window(WINDOW)
        .structural_store(store.clone())
        .build();
    let second = Session::builder()
        .arch(ArchConfig::scaled_128())
        .window(WINDOW)
        .structural_store(store.clone())
        .threads(4)
        .build();
    let suite = workloads::find_suite("vanilla").unwrap();
    let kernels = suite.kernels_at(Some(2));
    let ra = first.stream(&kernels, 2).unwrap();
    let s1 = first.cache_stats();
    assert!(s1.lowerings > 0, "first session must simulate: {s1:?}");
    assert_eq!(s1.structural_misses, s1.lowerings, "{s1:?}");
    assert_eq!(s1.structural_hits, 0, "{s1:?}");
    assert_eq!(store.len() as u64, s1.structural_misses);

    let rb = second.stream(&kernels, 2).unwrap();
    let s2 = second.cache_stats();
    assert_eq!(s2.lowerings, 0, "second session re-lowered: {s2:?}");
    assert_eq!(s2.structural_hits, s2.stage_misses, "{s2:?}");
    assert_eq!(s2.structural_misses, 0, "{s2:?}");
    assert_eq!(ra.latency_ms, rb.latency_ms);
    assert_eq!(ra.energy_j, rb.energy_j);
    for (ka, kb) in ra.kernels.iter().zip(&rb.kernels) {
        assert_eq!(ka.cycles, kb.cycles, "{}", ka.name);
        assert_eq!(ka.power_w, kb.power_w, "{}", ka.name);
    }
}

#[test]
fn store_keys_separate_arch_and_sim_options() {
    // A shared store must never serve a measurement taken under a
    // different architecture or different simulator options: the
    // signature is part of every key.
    let store = Arc::new(StructuralStore::new());
    let a = Session::builder()
        .arch(ArchConfig::scaled_128())
        .window(WINDOW)
        .structural_store(store.clone())
        .build();
    let suite = workloads::find_suite("fabnet-128").unwrap();
    let kernels = suite.kernels_at(Some(2));
    a.stream(&kernels, 2).unwrap();
    assert!(a.cache_stats().structural_misses > 0);

    let other_arch = Session::builder()
        .arch(ArchConfig::full())
        .window(WINDOW)
        .structural_store(store.clone())
        .build();
    other_arch.stream(&kernels, 2).unwrap();
    let s = other_arch.cache_stats();
    assert_eq!(s.structural_hits, 0, "cross-arch store hit: {s:?}");
    assert_eq!(s.lowerings, s.structural_misses, "{s:?}");

    let other_sim = Session::builder()
        .arch(ArchConfig::scaled_128())
        .sim(SimOptions { fifo_scheduling: true, ..SimOptions::default() })
        .window(WINDOW)
        .structural_store(store.clone())
        .build();
    other_sim.stream(&kernels, 2).unwrap();
    let s = other_sim.cache_stats();
    assert_eq!(s.structural_hits, 0, "cross-sim-options store hit: {s:?}");
    assert_eq!(s.lowerings, s.structural_misses, "{s:?}");
}

#[test]
fn persisted_store_resumes_with_zero_lowerings() {
    // Write-through persistence: a fresh process (modeled by reopening
    // the file with resume) must replay every measurement and reproduce
    // the run bitwise with zero lowerings.
    let path = std::env::temp_dir()
        .join(format!("bfdf_structural_it_{}.jsonl", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    let suite = workloads::find_suite("vit-256").unwrap();
    let kernels = suite.kernels_at(Some(2));

    let store = Arc::new(StructuralStore::open(&path, false).unwrap());
    let first = Session::builder()
        .arch(ArchConfig::scaled_128())
        .window(WINDOW)
        .structural_store(store)
        .build();
    let ra = first.stream(&kernels, 2).unwrap();
    let written = first.cache_stats().structural_misses;
    assert!(written > 0);

    let reloaded = Arc::new(StructuralStore::open(&path, true).unwrap());
    assert_eq!(reloaded.loaded() as u64, written, "store did not persist every entry");
    let second = Session::builder()
        .arch(ArchConfig::scaled_128())
        .window(WINDOW)
        .structural_store(reloaded)
        .threads(4)
        .build();
    let rb = second.stream(&kernels, 2).unwrap();
    let s2 = second.cache_stats();
    assert_eq!(s2.lowerings, 0, "resumed run re-simulated: {s2:?}");
    assert_eq!(ra.latency_ms, rb.latency_ms);
    assert_eq!(ra.energy_j, rb.energy_j);
    std::fs::remove_file(&path).ok();
}

#[test]
fn arch_signature_is_built_from_explicit_signatures() {
    // The session signature must be composed of the arch and
    // field-by-field SimOptions signatures plus the window — never the
    // `{:?}` of SimOptions, whose derive output would silently absorb
    // field renames (and leak type names into cache keys).
    let arch = ArchConfig::scaled_128();
    let session = Session::builder().arch(arch.clone()).window(48).build();
    assert_eq!(
        session.arch_signature(),
        format!("{}|{}|w48", arch.signature(), SimOptions::default().signature())
    );
    assert!(!session.arch_signature().contains("SimOptions"));
}
