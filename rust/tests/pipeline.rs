//! Streaming-overlap invariants (coordinator::pipeline): serial-mode
//! bit-compatibility, overlap/array monotonicity, and the Table-IV
//! acceptance bound `overlapped_time_s <= serial_time_s` over every
//! registered suite.

use butterfly_dataflow::arch::ArchConfig;
use butterfly_dataflow::coordinator::{Overlap, PipelineConfig, Session};
use butterfly_dataflow::workloads::{self, find_suite};

fn table4_session() -> Session {
    Session::builder().arch(ArchConfig::table4()).build()
}

#[test]
fn serial_mode_single_array_is_bitwise_legacy() {
    // `--overlap none --arrays 1` must reproduce the legacy serial
    // accounting exactly: same kernel sum, same latency, same energy.
    let session = table4_session();
    let kernels = find_suite("vanilla").unwrap().kernels_at(Some(8));
    let default = session.stream(&kernels, 8).unwrap();
    let explicit = session
        .stream_with(&kernels, 8, PipelineConfig::new(Overlap::None, 1))
        .unwrap();
    let serial_sum: f64 = default.kernels.iter().map(|k| k.time_s).sum();
    assert_eq!(default.batch_time_s, serial_sum);
    assert_eq!(default.batch_time_s, explicit.batch_time_s);
    assert_eq!(default.latency_ms, explicit.latency_ms);
    assert_eq!(default.throughput, explicit.throughput);
    assert_eq!(default.power_w, explicit.power_w);
    assert_eq!(default.energy_j, explicit.energy_j);
    assert_eq!(default.energy_eff, explicit.energy_eff);
    // No phantom idle-replica energy on a single array.
    let active: f64 = default.kernels.iter().map(|k| k.energy_j).sum();
    assert_eq!(default.energy_j, active);
}

#[test]
fn every_suite_overlaps_at_or_below_serial() {
    // The acceptance bound, over the whole registry at each suite's
    // default batch: pipeline mode never exceeds the serial reference,
    // and its efficiency stays in (0, 1].
    let session = table4_session();
    for suite in workloads::SUITES {
        let batch = suite.default_batch;
        let kernels = suite.kernels_at(Some(batch));
        let r = session
            .stream_with(&kernels, batch, PipelineConfig::new(Overlap::Pipeline, 1))
            .unwrap();
        assert!(
            r.overlapped_time_s <= r.serial_time_s,
            "{}: overlapped {} > serial {}",
            suite.name,
            r.overlapped_time_s,
            r.serial_time_s
        );
        assert!(r.overlapped_time_s > 0.0, "{}: zero makespan", suite.name);
        assert!(
            r.pipeline_efficiency > 0.0 && r.pipeline_efficiency <= 1.0,
            "{}: efficiency {}",
            suite.name,
            r.pipeline_efficiency
        );
        assert!(r.speedup() >= 1.0, "{}: speedup {}", suite.name, r.speedup());
    }
}

#[test]
fn overlap_modes_are_monotone() {
    let session = table4_session();
    let kernels = find_suite("fabnet-256").unwrap().kernels_at(Some(32));
    let t = |overlap| {
        session
            .stream_with(&kernels, 32, PipelineConfig::new(overlap, 1))
            .unwrap()
            .overlapped_time_s
    };
    let none = t(Overlap::None);
    let dma = t(Overlap::Dma);
    let pipe = t(Overlap::Pipeline);
    assert!(dma <= none, "dma {dma} > none {none}");
    assert!(pipe <= dma, "pipeline {pipe} > dma {dma}");
    // At this depth (4 kernels, batch 32) real pipelining must actually
    // help, not just not hurt.
    assert!(pipe < none, "pipeline did not improve on serial at all");
}

#[test]
fn array_sharding_scales_throughput_and_charges_idle_power() {
    let session = table4_session();
    let kernels = find_suite("vanilla").unwrap().kernels_at(Some(32));
    let run = |arrays| {
        session
            .stream_with(&kernels, 32, PipelineConfig::new(Overlap::Pipeline, arrays))
            .unwrap()
    };
    let one = run(1);
    let four = run(4);
    assert!(four.batch_time_s < one.batch_time_s);
    assert!(four.throughput > one.throughput);
    assert_eq!(four.arrays, 4);
    // Same simulated work: the active energy is identical, only the
    // idle-replica term may differ (32/4 splits evenly, so none here).
    let active: f64 = one.kernels.iter().map(|k| k.energy_j).sum();
    assert!(four.energy_j >= active);
    // An uneven split must charge idle replicas.
    let three = session
        .stream_with(&kernels, 32, PipelineConfig::new(Overlap::Pipeline, 3))
        .unwrap();
    assert!(three.energy_j > active, "idle replicas not charged");
}

#[test]
fn network_pipeline_matches_stream_invariants() {
    // The same schedule drives run_network: legacy equality in serial
    // mode, the overlap bound in pipeline mode.
    let session = Session::builder().build();
    let model = find_suite("fabnet-128").unwrap().model();
    let legacy = session.run_network(&model, Some(16)).unwrap();
    assert_eq!(legacy.batch_time_s, legacy.serial_time_s);
    let piped = session
        .run_network_with(&model, Some(16), PipelineConfig::new(Overlap::Pipeline, 2))
        .unwrap();
    assert!(piped.overlapped_time_s <= piped.serial_time_s);
    assert!(piped.pipeline_efficiency > 0.0 && piped.pipeline_efficiency <= 1.0);
    assert_eq!(piped.serial_time_s, legacy.serial_time_s);
    assert!(piped.latency_ms < legacy.latency_ms);
}

#[test]
fn kernel_results_carry_a_sane_dma_split() {
    // The overlap model is fed by the per-kernel split: the fill must
    // sit inside the simulated makespan, and the DDR occupancy must be
    // positive for kernels that stream from DDR.
    let session = table4_session();
    let kernels = find_suite("vit-256").unwrap().kernels_at(Some(4));
    let r = session.stream(&kernels, 4).unwrap();
    for k in &r.kernels {
        assert!(k.fill_time_s >= 0.0, "{}: negative fill", k.name);
        assert!(k.fill_time_s <= k.time_s, "{}: fill exceeds makespan", k.name);
        assert!(k.dma_time_s > 0.0, "{}: no DDR stream", k.name);
        assert!(k.dma_time_s.is_finite() && k.fill_time_s.is_finite());
    }
}

#[test]
fn builder_defaults_flow_into_results() {
    let kernels = find_suite("fabnet-128").unwrap().kernels_at(Some(8));
    let session = Session::builder()
        .arch(ArchConfig::table4())
        .overlap(Overlap::Pipeline)
        .arrays(2)
        .build();
    assert_eq!(session.pipeline_config(), PipelineConfig::new(Overlap::Pipeline, 2));
    let r = session.stream(&kernels, 8).unwrap();
    assert_eq!(r.overlap, Overlap::Pipeline);
    assert_eq!(r.arrays, 2);
    assert!(r.overlapped_time_s <= r.serial_time_s);
}
