//! Integration tests for the serving layer (`coordinator::serve`):
//! the ISSUE-6 acceptance criteria — monotone p99 across a rate sweep,
//! goodput saturating at the capacity bound, bit-reproducible reports
//! under a fixed seed, observable multi-tenant cache sharing, and
//! trace-driven runs — plus the ISSUE-10 robustness criteria: graceful
//! degradation under replica faults, SLO-aware admission beating FIFO
//! on deadline-met goodput, and edge cases (zero arrivals, zero
//! max-wait, a trace downing every replica) that must terminate
//! cleanly.

use butterfly_dataflow::coordinator::{
    Admission, Overlap, PipelineConfig, ReplicaEvent, ReplicaFaults, Report, ServeConfig,
    Session, Traffic,
};
use butterfly_dataflow::util::json;
use butterfly_dataflow::workloads::resolve_model;

/// A spec-grammar request class (also exercises the suite-or-spec
/// fallback `serve-sim` uses).
const CLASS: &str = "att:fft2d,ffn:bpmm*x2";

fn cfg(max_batch: usize, arrays: usize) -> ServeConfig {
    ServeConfig {
        max_batch,
        max_wait_s: 1e-3,
        arrays,
        queue_cap: 64,
        overlap: Overlap::Pipeline,
        ..ServeConfig::default()
    }
}

/// Full-batch service time of the test class: the basis for choosing
/// sweep rates relative to capacity, so the load-curve assertions hold
/// regardless of the architecture's absolute speed.
fn full_batch_svc_s(session: &Session, max_batch: usize) -> f64 {
    let model = resolve_model(CLASS).unwrap();
    let r = session
        .run_network_with(&model, Some(max_batch), PipelineConfig::new(Overlap::Pipeline, 1))
        .unwrap();
    assert!(r.batch_time_s > 0.0);
    r.batch_time_s
}

#[test]
fn p99_is_monotone_across_rate_sweep_and_goodput_saturates() {
    let session = Session::builder().build();
    let c = cfg(4, 1);
    let svc = full_batch_svc_s(&session, c.max_batch);
    let capacity = c.max_batch as f64 / svc;
    // Same seed at every rate: Rng::exp consumes one uniform per
    // sample, so the arrival patterns are time-scaled copies of each
    // other and the latency curve is monotone by construction.
    let mut last_p99 = 0.0f64;
    let mut results = Vec::new();
    for mult in [0.2, 1.0, 4.0] {
        let rate = mult * capacity;
        // Fixed arrival *count* per point (duration ~ 1/rate) so every
        // point serves the same scaled request sequence.
        let traffic = Traffic::poisson(&[CLASS.to_string()], rate, 160.0 / rate, 77).unwrap();
        let r = session.serve(&traffic, &c).unwrap();
        assert!(r.completed > 0, "rate {rate}: nothing completed");
        assert!(
            r.latency_p99_ms >= last_p99 - 1e-9,
            "p99 regressed under higher load: {} < {last_p99}",
            r.latency_p99_ms
        );
        assert!(r.latency_p50_ms <= r.latency_p95_ms);
        assert!(r.latency_p95_ms <= r.latency_p99_ms);
        assert!(r.latency_p99_ms <= r.latency_max_ms + 1e-12);
        last_p99 = r.latency_p99_ms;
        results.push(r);
    }
    // Light load: everything admitted, goodput well below capacity.
    let light = &results[0];
    assert_eq!(light.rejected, 0, "light load must not reject");
    assert!(light.goodput_rps < 0.9 * light.capacity_rps);
    // 4x overload: the bounded queue rejects, the servers run full
    // batches continuously, and goodput saturates at the capacity
    // bound (never exceeding it).
    let over = results.last().unwrap();
    assert!(over.rejected > 0, "4x overload must overflow the bounded queue");
    assert!(
        over.goodput_rps <= over.capacity_rps * 1.02,
        "goodput {} exceeds capacity {}",
        over.goodput_rps,
        over.capacity_rps
    );
    assert!(
        over.goodput_rps >= 0.7 * over.capacity_rps,
        "goodput {} did not saturate toward capacity {}",
        over.goodput_rps,
        over.capacity_rps
    );
    // Single class: the reported capacity bound is exactly
    // arrays * max_batch / svc(max_batch).
    assert!((over.capacity_rps - capacity).abs() <= 1e-9 * capacity);
    assert!(over.utilization > light.utilization);
}

#[test]
fn fixed_seed_reproduces_identical_report_json() {
    // Two runs from scratch (fresh sessions, fresh traffic) must render
    // byte-identical Report::Serving JSON — the property CI's
    // serve-smoke job checks end-to-end through the CLI.
    let run = || {
        let session = Session::builder().build();
        let keys = vec!["vit-256".to_string(), CLASS.to_string()];
        let mut points = Vec::new();
        for rate in [400.0, 1600.0] {
            let traffic = Traffic::poisson(&keys, rate, 0.1, 42).unwrap();
            points.push(session.serve(&traffic, &ServeConfig::default()).unwrap());
        }
        Report::Serving {
            arch: session.arch_signature().to_string(),
            cache: session.cache_stats(),
            points,
        }
        .render()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "fixed seed must reproduce the serving report bit-for-bit");
    // And the rendered document is valid, discriminated JSON.
    let parsed = json::parse(&a).unwrap();
    assert_eq!(parsed.req_str("report").unwrap(), "serving");
    assert_eq!(parsed.req("points").unwrap().as_arr().unwrap().len(), 2);
}

#[test]
fn repeated_specs_share_the_plan_cache_and_report_it() {
    let session = Session::builder().build();
    // Two request classes with the *same* spec: the second tenant must
    // ride the first tenant's cached plans.
    let traffic =
        Traffic::poisson(&[CLASS.to_string(), CLASS.to_string()], 2000.0, 0.05, 5).unwrap();
    let point = session.serve(&traffic, &ServeConfig::default()).unwrap();
    let stats = session.cache_stats();
    assert!(
        stats.stage_hits > 0,
        "repeated specs must hit the stage cache: {stats:?}"
    );
    assert!(stats.plan_hits > 0, "repeated specs must hit the plan cache: {stats:?}");
    // The sharing is visible in the serialized report (satellite:
    // cache stats in Report JSON).
    let report = Report::Serving {
        arch: session.arch_signature().to_string(),
        cache: stats,
        points: vec![point],
    };
    let parsed = json::parse(&report.render()).unwrap();
    let cache = parsed.req("cache").unwrap();
    assert!(cache.req_f64("stage_hits").unwrap() > 0.0);
    assert!(cache.req_f64("plan_hits").unwrap() > 0.0);
    assert!(cache.req_f64("lowerings").unwrap() > 0.0);
}

#[test]
fn trace_driven_run_works_end_to_end() {
    // Mixed suite-name and spec-string workloads in one trace,
    // deliberately out of time order.
    let trace = r#"{"arrivals": [
        {"t": 0.0010, "workload": "att:bpmm"},
        {"t": 0.0000, "workload": "vit-256"},
        {"t": 0.0005, "workload": "att:bpmm"},
        {"t": 0.0020, "workload": "att:bpmm"}
    ]}"#;
    let traffic = Traffic::from_trace_str(trace).unwrap();
    assert_eq!(traffic.classes.len(), 2);
    assert!((traffic.duration_s - 0.002).abs() < 1e-15);
    let session = Session::builder().build();
    let r = session.serve(&traffic, &ServeConfig::default()).unwrap();
    assert_eq!(r.offered, 4);
    assert_eq!(r.rejected, 0);
    assert_eq!(r.completed, 4);
    assert!(r.makespan_s >= traffic.duration_s);
    assert!(r.latency_p99_ms > 0.0);
    // Classes are numbered by first appearance in the trace document.
    assert_eq!(r.classes[0].name, "att:bpmm");
    assert_eq!(r.classes[0].completed, 3);
    assert_eq!(r.classes[1].name, "vit-256");
    assert_eq!(r.classes[1].completed, 1);
}

#[test]
fn degradation_is_graceful_and_monotone_under_nested_fault_traces() {
    // A ladder of *nested* downtime windows over the same traffic: each
    // rung strictly contains the previous rung's downtime, so goodput
    // must not increase and p99 must not decrease — and nothing may
    // panic or hang.  (Requests carry no deadline and every replica
    // recovers, so all admitted work eventually completes: degradation
    // shows up purely as a longer makespan and fatter tail.)
    let session = Session::builder().build();
    let svc = full_batch_svc_s(&session, 4);
    let rate = 6.0 / svc; // ~1.5x the two-array capacity of batch-4 service
    let traffic = Traffic::poisson(&[CLASS.to_string()], rate, 80.0 / rate, 21).unwrap();
    let horizon = traffic.duration_s;
    let ladders: Vec<Vec<ReplicaEvent>> = vec![
        vec![],
        vec![
            ReplicaEvent { t_s: 0.2 * horizon, replica: 1, up: false },
            ReplicaEvent { t_s: 0.4 * horizon, replica: 1, up: true },
        ],
        vec![
            ReplicaEvent { t_s: 0.2 * horizon, replica: 1, up: false },
            ReplicaEvent { t_s: 0.8 * horizon, replica: 1, up: true },
        ],
        vec![
            ReplicaEvent { t_s: 0.2 * horizon, replica: 1, up: false },
            ReplicaEvent { t_s: 0.8 * horizon, replica: 1, up: true },
            ReplicaEvent { t_s: 0.3 * horizon, replica: 0, up: false },
            ReplicaEvent { t_s: 0.7 * horizon, replica: 0, up: true },
        ],
    ];
    let mut last_goodput = f64::INFINITY;
    let mut last_p99 = 0.0f64;
    let mut last_avail = f64::INFINITY;
    for (i, events) in ladders.iter().enumerate() {
        let c = ServeConfig {
            max_batch: 4,
            arrays: 2,
            queue_cap: 256,
            faults: if events.is_empty() {
                // Rung 0 still runs the robustness loop (empty trace is
                // rejected by the parser but fine programmatically? no:
                // use a far-future fault so the schedule is configured
                // yet inert inside the horizon).
                Some(ReplicaFaults::Trace(vec![ReplicaEvent {
                    t_s: horizon * 100.0,
                    replica: 0,
                    up: false,
                }]))
            } else {
                Some(ReplicaFaults::Trace(events.clone()))
            },
            ..cfg(4, 2)
        };
        let r = session.serve(&traffic, &c).unwrap();
        assert_eq!(
            r.offered,
            r.completed + r.rejected + r.shed + r.timed_out + r.lost,
            "rung {i}: accounting leak"
        );
        assert!(r.completed > 0, "rung {i}: nothing completed");
        assert!(
            r.goodput_rps <= last_goodput + 1e-9,
            "rung {i}: goodput rose under more downtime: {} > {}",
            r.goodput_rps,
            last_goodput
        );
        assert!(
            r.latency_p99_ms >= last_p99 - 1e-9,
            "rung {i}: p99 improved under more downtime: {} < {}",
            r.latency_p99_ms,
            last_p99
        );
        assert!(
            r.availability <= last_avail + 1e-12,
            "rung {i}: availability rose with more downtime"
        );
        assert!(r.availability > 0.0 && r.availability <= 1.0);
        assert!(r.degraded_capacity_rps <= r.capacity_rps + 1e-9);
        last_goodput = r.goodput_rps;
        last_p99 = r.latency_p99_ms;
        last_avail = r.availability;
    }
}

#[test]
fn slo_aware_admission_beats_fifo_on_deadline_goodput() {
    // Deterministic mixed-class overload with real kernel costs: two
    // slow-class requests arrive first, four fast ones right behind,
    // one replica, queue of two, max_batch 1.  The deadline is chosen
    // between the classes' measured service times so FIFO tail-drop
    // serves a doomed slow request late and times the fast ones out,
    // while SLO-aware sheds the doomed request and completes the fast
    // ones in time.
    let session = Session::builder().build();
    let fast_key = "att:bpmm".to_string();
    let slow_key = "bert-4k".to_string();
    let pipe = PipelineConfig::new(Overlap::Pipeline, 1);
    let svc_of = |key: &str| {
        session
            .run_network_with(&resolve_model(key).unwrap(), Some(1), pipe)
            .unwrap()
            .batch_time_s
    };
    let (svc_fast, svc_slow) = (svc_of(&fast_key), svc_of(&slow_key));
    // The shedding walkthrough below needs the doomed slow request's
    // slack to sit strictly under every fast newcomer's, which holds
    // whenever svc_fast < svc_slow / 3.  A whole BERT network against a
    // single attention bpmm clears that with a wide margin.
    assert!(
        svc_fast < 0.3 * svc_slow,
        "test classes must differ in cost: fast {svc_fast} vs slow {svc_slow}"
    );
    let t1 = svc_fast * 0.01;
    let deadline = 1.5 * svc_slow + 0.5 * svc_fast;

    let trace = format!(
        concat!(
            "{{\"arrivals\": [",
            "{{\"t\": 0.0, \"workload\": \"{slow}\"}},",
            "{{\"t\": 0.0, \"workload\": \"{slow}\"}},",
            "{{\"t\": {t1}, \"workload\": \"{fast}\"}},",
            "{{\"t\": {t1}, \"workload\": \"{fast}\"}},",
            "{{\"t\": {t1}, \"workload\": \"{fast}\"}},",
            "{{\"t\": {t1}, \"workload\": \"{fast}\"}}",
            "]}}"
        ),
        slow = slow_key,
        fast = fast_key,
        t1 = t1,
    );
    let traffic = Traffic::from_trace_str(&trace).unwrap();

    let base = ServeConfig {
        max_batch: 1,
        max_wait_s: 1.0,
        arrays: 1,
        queue_cap: 2,
        deadline_s: Some(deadline),
        ..ServeConfig::default()
    };
    let fifo = session.serve(&traffic, &base).unwrap();
    let slo = session
        .serve(&traffic, &ServeConfig { admission: Admission::SloAware, ..base })
        .unwrap();

    assert_eq!(fifo.completed, 2);
    assert_eq!(fifo.rejected, 3);
    assert_eq!(fifo.timed_out, 1);
    assert!(
        fifo.latency_max_ms > deadline * 1e3,
        "FIFO completes the second slow request past its deadline"
    );

    assert_eq!(slo.completed, 3, "SLO-aware completes strictly more");
    assert_eq!(slo.shed, 3);
    assert_eq!(slo.timed_out, 0);
    assert_eq!(slo.rejected, 0);
    assert!(slo.completed > fifo.completed);
    // Per-class: the one shed slow request, two shed fast stragglers.
    let slow_class = slo.classes.iter().find(|c| c.name == slow_key).unwrap();
    let fast_class = slo.classes.iter().find(|c| c.name == fast_key).unwrap();
    assert_eq!(slow_class.shed, 1);
    assert_eq!(fast_class.shed, 2);
}

#[test]
fn seeded_replica_faults_reproduce_identical_reports() {
    // The whole robustness path — seeded fault process, retries,
    // deadlines, SLO-aware shedding — must stay byte-reproducible.
    let run = || {
        let session = Session::builder().build();
        let traffic =
            Traffic::poisson(&[CLASS.to_string(), "att:bpmm".to_string()], 3000.0, 0.05, 11)
                .unwrap();
        let c = ServeConfig {
            arrays: 2,
            admission: Admission::SloAware,
            deadline_s: Some(0.05),
            faults: Some(ReplicaFaults::Process { mtbf_s: 0.01, mttr_s: 0.004, seed: 5 }),
            ..cfg(4, 2)
        };
        let r = session.serve(&traffic, &c).unwrap();
        Report::Serving {
            arch: session.arch_signature().to_string(),
            cache: session.cache_stats(),
            points: vec![r],
        }
        .render()
    };
    let a = run();
    assert_eq!(a, run(), "same fault seed must reproduce the report bit-for-bit");
    // The robustness block is serialized (configured => reported).
    let parsed = json::parse(&a).unwrap();
    let point = &parsed.req("points").unwrap().as_arr().unwrap()[0];
    assert_eq!(point.req_str("admission").unwrap(), "slo-aware");
    assert!(point.req_f64("availability").unwrap() <= 1.0);
    assert!(point.req_f64("degraded_capacity_rps").unwrap() > 0.0);
    // And a default-config run serializes *no* robustness block.
    let session = Session::builder().build();
    let traffic = Traffic::poisson(&[CLASS.to_string()], 500.0, 0.05, 11).unwrap();
    let plain = session.serve(&traffic, &ServeConfig::default()).unwrap();
    let doc = plain.to_json().render();
    assert!(!doc.contains("\"admission\""), "fault-free JSON gained robustness fields");
    assert!(!doc.contains("\"availability\""));
}

#[test]
fn serving_edge_cases_terminate_cleanly() {
    let session = Session::builder().build();

    // Zero arrivals (constructed directly: the generators reject empty
    // streams, the serving loop must still handle one).
    let empty = Traffic {
        classes: vec![resolve_model(CLASS).unwrap()],
        arrivals: vec![],
        duration_s: 0.0,
    };
    let r = session.serve(&empty, &ServeConfig::default()).unwrap();
    assert_eq!((r.offered, r.completed, r.rejected), (0, 0, 0));
    assert_eq!(r.latency_p99_ms, 0.0);
    // ... and with the robustness loop engaged.
    let c = ServeConfig {
        deadline_s: Some(0.01),
        faults: Some(ReplicaFaults::Process { mtbf_s: 0.01, mttr_s: 0.001, seed: 3 }),
        ..ServeConfig::default()
    };
    let r = session.serve(&empty, &c).unwrap();
    assert_eq!(r.offered, 0);
    assert_eq!(r.availability, 1.0, "no makespan, nothing was unavailable");

    // max_wait_s = 0: every partial batch dispatches immediately.
    let traffic = Traffic::poisson(&[CLASS.to_string()], 800.0, 0.02, 9).unwrap();
    let zero_wait = ServeConfig { max_wait_s: 0.0, ..ServeConfig::default() };
    let r = session.serve(&traffic, &zero_wait).unwrap();
    assert_eq!(r.offered, r.completed + r.rejected);

    // A trace that downs every replica at t=0 and never recovers:
    // zero goodput, zero availability (to fp tolerance), no hang.
    let all_down = ServeConfig {
        arrays: 2,
        faults: Some(ReplicaFaults::Trace(vec![
            ReplicaEvent { t_s: 0.0, replica: 0, up: false },
            ReplicaEvent { t_s: 0.0, replica: 1, up: false },
        ])),
        ..cfg(4, 2)
    };
    let r = session.serve(&traffic, &all_down).unwrap();
    assert_eq!(r.completed, 0);
    assert_eq!(r.offered, r.rejected + r.lost);
    assert!(r.availability <= 1e-9, "availability {} with every replica down", r.availability);
    assert_eq!(r.goodput_rps, 0.0);
}

#[test]
fn replica_arrays_scale_serving_capacity() {
    let session = Session::builder().build();
    let one = cfg(4, 1);
    let four = cfg(4, 4);
    let svc = full_batch_svc_s(&session, 4);
    let rate = 8.0 / svc; // 2x one-array capacity
    let traffic = Traffic::poisson(&[CLASS.to_string()], rate, 120.0 / rate, 13).unwrap();
    let r1 = session.serve(&traffic, &one).unwrap();
    let r4 = session.serve(&traffic, &four).unwrap();
    assert!((r4.capacity_rps - 4.0 * r1.capacity_rps).abs() <= 1e-9 * r4.capacity_rps);
    // What overloads one array is comfortable for four: less queueing,
    // lower tail latency, higher goodput.
    assert!(r4.latency_p99_ms <= r1.latency_p99_ms + 1e-9);
    assert!(r4.goodput_rps >= r1.goodput_rps * (1.0 - 1e-9));
    assert!(r4.rejected <= r1.rejected);
}
