//! Integration tests for the serving layer (`coordinator::serve`):
//! the ISSUE-6 acceptance criteria — monotone p99 across a rate sweep,
//! goodput saturating at the capacity bound, bit-reproducible reports
//! under a fixed seed, observable multi-tenant cache sharing, and
//! trace-driven runs.

use butterfly_dataflow::coordinator::{
    Overlap, PipelineConfig, Report, ServeConfig, Session, Traffic,
};
use butterfly_dataflow::util::json;
use butterfly_dataflow::workloads::resolve_model;

/// A spec-grammar request class (also exercises the suite-or-spec
/// fallback `serve-sim` uses).
const CLASS: &str = "att:fft2d,ffn:bpmm*x2";

fn cfg(max_batch: usize, arrays: usize) -> ServeConfig {
    ServeConfig {
        max_batch,
        max_wait_s: 1e-3,
        arrays,
        queue_cap: 64,
        overlap: Overlap::Pipeline,
    }
}

/// Full-batch service time of the test class: the basis for choosing
/// sweep rates relative to capacity, so the load-curve assertions hold
/// regardless of the architecture's absolute speed.
fn full_batch_svc_s(session: &Session, max_batch: usize) -> f64 {
    let model = resolve_model(CLASS).unwrap();
    let r = session
        .run_network_with(&model, Some(max_batch), PipelineConfig::new(Overlap::Pipeline, 1))
        .unwrap();
    assert!(r.batch_time_s > 0.0);
    r.batch_time_s
}

#[test]
fn p99_is_monotone_across_rate_sweep_and_goodput_saturates() {
    let session = Session::builder().build();
    let c = cfg(4, 1);
    let svc = full_batch_svc_s(&session, c.max_batch);
    let capacity = c.max_batch as f64 / svc;
    // Same seed at every rate: Rng::exp consumes one uniform per
    // sample, so the arrival patterns are time-scaled copies of each
    // other and the latency curve is monotone by construction.
    let mut last_p99 = 0.0f64;
    let mut results = Vec::new();
    for mult in [0.2, 1.0, 4.0] {
        let rate = mult * capacity;
        // Fixed arrival *count* per point (duration ~ 1/rate) so every
        // point serves the same scaled request sequence.
        let traffic = Traffic::poisson(&[CLASS.to_string()], rate, 160.0 / rate, 77).unwrap();
        let r = session.serve(&traffic, &c).unwrap();
        assert!(r.completed > 0, "rate {rate}: nothing completed");
        assert!(
            r.latency_p99_ms >= last_p99 - 1e-9,
            "p99 regressed under higher load: {} < {last_p99}",
            r.latency_p99_ms
        );
        assert!(r.latency_p50_ms <= r.latency_p95_ms);
        assert!(r.latency_p95_ms <= r.latency_p99_ms);
        assert!(r.latency_p99_ms <= r.latency_max_ms + 1e-12);
        last_p99 = r.latency_p99_ms;
        results.push(r);
    }
    // Light load: everything admitted, goodput well below capacity.
    let light = &results[0];
    assert_eq!(light.rejected, 0, "light load must not reject");
    assert!(light.goodput_rps < 0.9 * light.capacity_rps);
    // 4x overload: the bounded queue rejects, the servers run full
    // batches continuously, and goodput saturates at the capacity
    // bound (never exceeding it).
    let over = results.last().unwrap();
    assert!(over.rejected > 0, "4x overload must overflow the bounded queue");
    assert!(
        over.goodput_rps <= over.capacity_rps * 1.02,
        "goodput {} exceeds capacity {}",
        over.goodput_rps,
        over.capacity_rps
    );
    assert!(
        over.goodput_rps >= 0.7 * over.capacity_rps,
        "goodput {} did not saturate toward capacity {}",
        over.goodput_rps,
        over.capacity_rps
    );
    // Single class: the reported capacity bound is exactly
    // arrays * max_batch / svc(max_batch).
    assert!((over.capacity_rps - capacity).abs() <= 1e-9 * capacity);
    assert!(over.utilization > light.utilization);
}

#[test]
fn fixed_seed_reproduces_identical_report_json() {
    // Two runs from scratch (fresh sessions, fresh traffic) must render
    // byte-identical Report::Serving JSON — the property CI's
    // serve-smoke job checks end-to-end through the CLI.
    let run = || {
        let session = Session::builder().build();
        let keys = vec!["vit-256".to_string(), CLASS.to_string()];
        let mut points = Vec::new();
        for rate in [400.0, 1600.0] {
            let traffic = Traffic::poisson(&keys, rate, 0.1, 42).unwrap();
            points.push(session.serve(&traffic, &ServeConfig::default()).unwrap());
        }
        Report::Serving {
            arch: session.arch_signature().to_string(),
            cache: session.cache_stats(),
            points,
        }
        .render()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "fixed seed must reproduce the serving report bit-for-bit");
    // And the rendered document is valid, discriminated JSON.
    let parsed = json::parse(&a).unwrap();
    assert_eq!(parsed.req_str("report").unwrap(), "serving");
    assert_eq!(parsed.req("points").unwrap().as_arr().unwrap().len(), 2);
}

#[test]
fn repeated_specs_share_the_plan_cache_and_report_it() {
    let session = Session::builder().build();
    // Two request classes with the *same* spec: the second tenant must
    // ride the first tenant's cached plans.
    let traffic =
        Traffic::poisson(&[CLASS.to_string(), CLASS.to_string()], 2000.0, 0.05, 5).unwrap();
    let point = session.serve(&traffic, &ServeConfig::default()).unwrap();
    let stats = session.cache_stats();
    assert!(
        stats.stage_hits > 0,
        "repeated specs must hit the stage cache: {stats:?}"
    );
    assert!(stats.plan_hits > 0, "repeated specs must hit the plan cache: {stats:?}");
    // The sharing is visible in the serialized report (satellite:
    // cache stats in Report JSON).
    let report = Report::Serving {
        arch: session.arch_signature().to_string(),
        cache: stats,
        points: vec![point],
    };
    let parsed = json::parse(&report.render()).unwrap();
    let cache = parsed.req("cache").unwrap();
    assert!(cache.req_f64("stage_hits").unwrap() > 0.0);
    assert!(cache.req_f64("plan_hits").unwrap() > 0.0);
    assert!(cache.req_f64("lowerings").unwrap() > 0.0);
}

#[test]
fn trace_driven_run_works_end_to_end() {
    // Mixed suite-name and spec-string workloads in one trace,
    // deliberately out of time order.
    let trace = r#"{"arrivals": [
        {"t": 0.0010, "workload": "att:bpmm"},
        {"t": 0.0000, "workload": "vit-256"},
        {"t": 0.0005, "workload": "att:bpmm"},
        {"t": 0.0020, "workload": "att:bpmm"}
    ]}"#;
    let traffic = Traffic::from_trace_str(trace).unwrap();
    assert_eq!(traffic.classes.len(), 2);
    assert!((traffic.duration_s - 0.002).abs() < 1e-15);
    let session = Session::builder().build();
    let r = session.serve(&traffic, &ServeConfig::default()).unwrap();
    assert_eq!(r.offered, 4);
    assert_eq!(r.rejected, 0);
    assert_eq!(r.completed, 4);
    assert!(r.makespan_s >= traffic.duration_s);
    assert!(r.latency_p99_ms > 0.0);
    // Classes are numbered by first appearance in the trace document.
    assert_eq!(r.classes[0].name, "att:bpmm");
    assert_eq!(r.classes[0].completed, 3);
    assert_eq!(r.classes[1].name, "vit-256");
    assert_eq!(r.classes[1].completed, 1);
}

#[test]
fn replica_arrays_scale_serving_capacity() {
    let session = Session::builder().build();
    let one = cfg(4, 1);
    let four = cfg(4, 4);
    let svc = full_batch_svc_s(&session, 4);
    let rate = 8.0 / svc; // 2x one-array capacity
    let traffic = Traffic::poisson(&[CLASS.to_string()], rate, 120.0 / rate, 13).unwrap();
    let r1 = session.serve(&traffic, &one).unwrap();
    let r4 = session.serve(&traffic, &four).unwrap();
    assert!((r4.capacity_rps - 4.0 * r1.capacity_rps).abs() <= 1e-9 * r4.capacity_rps);
    // What overloads one array is comfortable for four: less queueing,
    // lower tail latency, higher goodput.
    assert!(r4.latency_p99_ms <= r1.latency_p99_ms + 1e-9);
    assert!(r4.goodput_rps >= r1.goodput_rps * (1.0 - 1e-9));
    assert!(r4.rejected <= r1.rejected);
}
