//! Cross-module integration tests: DFG compiler → simulator →
//! coordinator metrics, plus windowed-extrapolation validity and
//! headline-claim guards.  (Runtime/PJRT integration lives in
//! `artifact_runtime.rs` and is gated on `artifacts/` existing.)

use butterfly_dataflow::arch::{ArchConfig, UnitKind};
use butterfly_dataflow::coordinator::Session;
use butterfly_dataflow::dfg::graph::KernelKind;
use butterfly_dataflow::dfg::microcode::lower_stage;
use butterfly_dataflow::dfg::stages::{plan_kernel, StageDfg};
use butterfly_dataflow::sim::{simulate, SimOptions};
use butterfly_dataflow::util::prop::check;
use butterfly_dataflow::workloads::{find_suite, KernelSpec};

fn vanilla_kernels(batch: usize) -> Vec<KernelSpec> {
    find_suite("vanilla").unwrap().kernels_at(Some(batch))
}

fn fabnet_kernels(batch: usize, seq: usize) -> Vec<KernelSpec> {
    let name = format!("fabnet-{}", butterfly_dataflow::workloads::scale_name(seq));
    find_suite(&name).unwrap().kernels_at(Some(batch))
}

fn spec(kind: KernelKind, points: usize, vectors: usize) -> KernelSpec {
    KernelSpec {
        name: format!("{}-{}", kind.name(), points),
        kind,
        points,
        vectors,
        d_in: points,
        d_out: points,
        seq: points,
    }
}

#[test]
fn window_sensitivity_of_extrapolation() {
    // The windowed steady-state extrapolation must agree across window
    // sizes within a few percent — otherwise the Fig. 13-17 numbers
    // would be artifacts of the window choice.
    let s = spec(KernelKind::Fft, 256, 512 * 1024);
    let base = Session::builder().window(32).build().run(&s).unwrap();
    for window in [48, 96, 192] {
        let r = Session::builder().window(window).build().run(&s).unwrap();
        let ratio = r.cycles / base.cycles;
        assert!(
            (0.92..1.08).contains(&ratio),
            "window {window}: cycles ratio {ratio}"
        );
    }
}

#[test]
fn whole_plan_cycles_scale_with_points() {
    // n log n work at fixed vector count: 4x points ≈ >4x cycles.
    let sess = Session::builder().build();
    let a = sess.run(&spec(KernelKind::Bpmm, 128, 64 * 1024)).unwrap();
    let b = sess.run(&spec(KernelKind::Bpmm, 512, 64 * 1024)).unwrap();
    let ratio = b.cycles / a.cycles;
    assert!(ratio > 3.0 && ratio < 9.0, "ratio {ratio}");
}

#[test]
fn fft_512_dip_and_recovery() {
    // FFT above the 256-point cap pays the staged division; utilization
    // recovers at larger scales (deeper sub-DFGs).  Guards the Fig. 13
    // curve shape.
    let sess = Session::builder().build();
    let u = |points: usize| {
        sess.run(&spec(KernelKind::Fft, points, (1 << 26) / points))
            .unwrap()
            .util_of(UnitKind::Cal)
    };
    let u256 = u(256);
    let u512 = u(512);
    let u8k = u(8192);
    assert!(u256 > u512, "no dip at the cap boundary: {u256} vs {u512}");
    assert!(u8k > u512, "no recovery at scale: {u8k} vs {u512}");
    assert!(u8k > 0.85, "large-scale FFT must exceed 85%: {u8k}");
}

#[test]
fn headline_cal_utilization_band() {
    // §VI-D: Cal > 64% for all butterfly kernels at steady batch.
    let sess = Session::builder().build();
    for kind in [KernelKind::Fft, KernelKind::Bpmm] {
        for points in [256usize, 2048, 8192] {
            let r = sess.run(&spec(kind, points, (1 << 26) / points)).unwrap();
            assert!(
                r.util_of(UnitKind::Cal) > 0.55,
                "{}-{points}: cal {:.3}",
                kind.name(),
                r.util_of(UnitKind::Cal)
            );
            assert!(
                r.spm_requirement < 0.1248,
                "{}-{points}: spm req {:.3} exceeds the paper bound",
                kind.name(),
                r.spm_requirement
            );
        }
    }
}

#[test]
fn ablation_multiline_spm_required_for_staged_kernels() {
    // §V-C: without the multi-line SPM the column-gather stage of the
    // Fig. 9 division serializes — must cost measurably more.
    let s = spec(KernelKind::Bpmm, 4096, 64 * 1024);
    let multi = Session::builder().build().run(&s).unwrap();
    let single = Session::builder()
        .sim(SimOptions { no_multiline_spm: true, ..Default::default() })
        .build()
        .run(&s)
        .unwrap();
    assert!(
        single.cycles > 1.5 * multi.cycles,
        "single-line {} vs multi-line {}",
        single.cycles,
        multi.cycles
    );
}

#[test]
fn division_sweep_prefers_balance_fft() {
    // Fig. 14: balanced FFT divisions beat strongly-unbalanced ones.
    let sess = Session::builder().build();
    let s = spec(KernelKind::Fft, 4096, 16 * 1024);
    let balanced = sess.run_with(&s, Some((64, 64))).unwrap();
    let skewed = sess.run_with(&s, Some((16, 256))).unwrap();
    assert!(
        balanced.util_of(UnitKind::Cal) > skewed.util_of(UnitKind::Cal),
        "balanced {:.3} vs skewed {:.3}",
        balanced.util_of(UnitKind::Cal),
        skewed.util_of(UnitKind::Cal)
    );
}

#[test]
fn table4_configuration_lands_near_paper() {
    // Our side of Table IV: latency near 2 ms, power near 3.94 W band.
    let sess = Session::builder().arch(ArchConfig::table4()).build();
    let r = sess.stream(&vanilla_kernels(64), 64).unwrap();
    assert!(
        (0.5..6.0).contains(&r.latency_ms),
        "latency {} ms out of band",
        r.latency_ms
    );
    assert!((2.0..5.0).contains(&r.power_w), "power {} W", r.power_w);
    // The SOTA comparison must remain a win but not absurd.
    let sota_latency = 2.4;
    let ratio = sota_latency / r.latency_ms;
    assert!((0.8..3.0).contains(&ratio), "vs SOTA ratio {ratio}");
}

#[test]
fn fabnet_512_fits_spm() {
    // §VI-H: FABNet-512's working set just fills the 4 MB SPM — no
    // stage of its kernels should stream weights from DDR.
    let arch = ArchConfig::scaled_128();
    for k in fabnet_kernels(1, 512) {
        let plan = plan_kernel(k.kind, k.points, k.vectors, &arch, None).unwrap();
        assert!(
            plan.stages.iter().all(|s| !s.weights_from_ddr),
            "{} unexpectedly streams weights",
            k.name
        );
    }
}

#[test]
fn simulator_conserves_work_under_scheduling_ablations() {
    // FIFO vs priority scheduling changes time, never the work done.
    let arch = ArchConfig::full();
    let stage = StageDfg {
        kind: KernelKind::Fft,
        points: 128,
        sub_iters: 1,
        twiddle_before: false,
        weights_from_ddr: false,
    };
    let p = lower_stage(&stage, &arch, 16);
    let a = simulate(&p, &arch, &SimOptions::default());
    let b = simulate(
        &p,
        &arch,
        &SimOptions { fifo_scheduling: true, ..Default::default() },
    );
    assert_eq!(a.blocks_run, b.blocks_run);
    assert_eq!(a.spm_scalars, b.spm_scalars);
    assert_eq!(a.noc_scalars, b.noc_scalars);
}

#[test]
fn prop_any_plan_simulates_and_accounts() {
    // Randomized end-to-end property: any power-of-two kernel plan
    // simulates to completion with conserved block counts and bounded
    // utilizations.
    let sess = Session::builder().window(16).build();
    check("plan-simulates", 25, |rng| {
        let points = rng.pow2(16, 4096);
        let kind = if rng.chance(0.5) { KernelKind::Fft } else { KernelKind::Bpmm };
        let vectors = rng.range(64, 4096);
        let r = sess.run(&spec(kind, points, vectors)).unwrap();
        assert!(r.cycles > 0.0);
        assert!(r.flops_efficiency > 0.0 && r.flops_efficiency <= 1.0);
        for k in UnitKind::ALL {
            let u = r.util_of(k);
            assert!((0.0..=1.0).contains(&u), "{k:?}: {u}");
        }
    });
}
