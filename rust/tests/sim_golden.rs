//! Golden-stats gate for the simulator rewrite.
//!
//! The engine rewrite (`sim::engine`: event calendar, pending-wake
//! flags, precomputed routes, SoA walk, reusable workspace) promised
//! **bit-exact** [`SimStats`] against the pre-rewrite engine, which is
//! frozen verbatim as `sim::reference`.  Rather than pinning numbers
//! that silently rot when lowering legitimately changes, the goldens
//! are *executable*: every case runs both engines over the identical
//! `Program` and asserts exact equality of every field — cycles,
//! per-unit and per-PE busy time, SPM/NoC/DMA counters, iteration
//! completion times, block counts.
//!
//! Coverage: the fixture matrix {Fft, Bpmm} × {64, 256, 512 points} ×
//! {1, 8, 48 iterations} × {pack 1, 4} under all simulator-option
//! combinations, plus every stage program of every registered workload
//! suite (windowed like the coordinator runs them).  The cache
//! determinism and `parallel == serial` tests in `session.rs` continue
//! to guard the coordinator layer above.

use std::collections::HashSet;

use butterfly_dataflow::arch::ArchConfig;
use butterfly_dataflow::coordinator::session::stage_schedule;
use butterfly_dataflow::dfg::graph::KernelKind;
use butterfly_dataflow::dfg::mapping::Mapping;
use butterfly_dataflow::dfg::microcode::{lower_stage_mapped, lower_stage_packed};
use butterfly_dataflow::dfg::slicing::SlicePlan;
use butterfly_dataflow::dfg::stages::{plan_kernel, StageDfg};
use butterfly_dataflow::dfg::strategy::{DataflowStrategy, PAPER};
use butterfly_dataflow::sim::{self, simulate, simulate_in, SimOptions, SimWorkspace};
use butterfly_dataflow::workloads::SUITES;

fn opt_combos() -> [SimOptions; 4] {
    [
        SimOptions::default(),
        SimOptions { fifo_scheduling: true, ..Default::default() },
        SimOptions { no_multiline_spm: true, ..Default::default() },
        SimOptions { fifo_scheduling: true, no_multiline_spm: true, ..Default::default() },
    ]
}

fn assert_engines_agree(
    stage: &StageDfg,
    arch: &ArchConfig,
    iters: usize,
    pack: usize,
    opts: &SimOptions,
    label: &str,
) {
    let program = lower_stage_packed(stage, arch, iters, pack);
    program.validate().unwrap();
    let golden = sim::reference::simulate(&program, arch, opts);
    let rewritten = simulate(&program, arch, opts);
    assert_eq!(rewritten, golden, "engines diverged on {label} ({opts:?})");
    // The statistics must be internally coherent too: every block ran.
    assert_eq!(rewritten.blocks_run as usize, program.blocks.len(), "{label}");
}

#[test]
fn golden_matrix_is_bit_exact() {
    let arch = ArchConfig::full();
    for kind in [KernelKind::Fft, KernelKind::Bpmm] {
        for points in [64usize, 256, 512] {
            for iters in [1usize, 8, 48] {
                for pack in [1usize, 4] {
                    let stage = StageDfg {
                        kind,
                        points,
                        sub_iters: 1,
                        twiddle_before: false,
                        weights_from_ddr: false,
                    };
                    for opts in opt_combos() {
                        assert_engines_agree(
                            &stage,
                            &arch,
                            iters,
                            pack,
                            &opts,
                            &format!("{}-{points} x{iters} pack{pack}", kind.name()),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn golden_stage_variants_are_bit_exact() {
    // Twiddle layers and DDR-streamed weights exercise the WLOAD and
    // DMA-gating paths the plain matrix misses.
    let arch = ArchConfig::full();
    for (twiddle, ddr) in [(true, false), (false, true), (true, true)] {
        for kind in [KernelKind::Fft, KernelKind::Bpmm] {
            let stage = StageDfg {
                kind,
                points: 256,
                sub_iters: 1,
                twiddle_before: twiddle,
                weights_from_ddr: ddr,
            };
            for opts in opt_combos() {
                assert_engines_agree(
                    &stage,
                    &arch,
                    8,
                    1,
                    &opts,
                    &format!("{} twiddle={twiddle} ddr={ddr}", kind.name()),
                );
            }
        }
    }
}

#[test]
fn golden_scaled_arch_is_bit_exact() {
    // The §VI-H fair-comparison configuration (SIMD8, one DDR channel)
    // changes lane scaling and the DMA schedule.
    let arch = ArchConfig::scaled_128();
    for kind in [KernelKind::Fft, KernelKind::Bpmm] {
        let stage = StageDfg {
            kind,
            points: 256,
            sub_iters: 1,
            twiddle_before: false,
            weights_from_ddr: false,
        };
        assert_engines_agree(
            &stage,
            &arch,
            12,
            2,
            &SimOptions::default(),
            &format!("scaled128 {}", kind.name()),
        );
    }
}

#[test]
fn golden_all_suites_are_bit_exact() {
    // Every stage program the registered suites actually simulate
    // (same plan, same packing policy as the coordinator; window capped
    // for test runtime — equality is program-for-program, so the cap
    // does not weaken the check).
    let arch = ArchConfig::full();
    let mut seen: HashSet<(String, usize, bool, bool, usize, usize)> = HashSet::new();
    let mut programs = 0usize;
    for suite in SUITES {
        for spec in suite.default_kernels() {
            let plan = plan_kernel(spec.kind, spec.points, spec.vectors, &arch, None)
                .unwrap_or_else(|e| panic!("plan {} failed: {e}", spec.name));
            for stage in &plan.stages {
                // The coordinator's own per-stage schedule (window
                // capped at 16 instead of the session default 48 for
                // test runtime — program shape is unaffected).
                let (_, window, pack) = stage_schedule(stage, spec.vectors, &arch, 16);
                let key = (
                    format!("{:?}", stage.kind),
                    stage.points,
                    stage.twiddle_before,
                    stage.weights_from_ddr,
                    window,
                    pack,
                );
                if !seen.insert(key) {
                    continue; // identical stage program already diffed
                }
                programs += 1;
                assert_engines_agree(
                    stage,
                    &arch,
                    window,
                    pack,
                    &SimOptions::default(),
                    &format!("suite {} kernel {} stage {}pt", suite.name, spec.name, stage.points),
                );
            }
        }
    }
    assert!(programs >= 10, "suite sweep degenerated to {programs} programs");
}

#[test]
fn golden_paper_strategy_matches_prerefactor_lowering() {
    // The DataflowStrategy refactor moved the three lowering decisions
    // (division plan, PE mapping, BPMM slicing) plus the stage schedule
    // behind a trait; PaperStrategy must be the pre-refactor behavior
    // verbatim.  Sweep every registered suite's kernels and assert, per
    // decision, structural equality against the direct free-function
    // path — and bit-exact SimStats for the lowered stage programs.
    let arch = ArchConfig::full();
    let opts = SimOptions::default();
    let mut seen: HashSet<(String, usize, bool, bool, usize, usize)> = HashSet::new();
    let mut programs = 0usize;
    for suite in SUITES {
        for spec in suite.default_kernels() {
            let direct = plan_kernel(spec.kind, spec.points, spec.vectors, &arch, None)
                .unwrap_or_else(|e| panic!("plan {} failed: {e}", spec.name));
            let via = PAPER
                .plan(spec.kind, spec.points, spec.vectors, &arch, None)
                .unwrap_or_else(|e| panic!("strategy plan {} failed: {e}", spec.name));
            assert_eq!(via, direct, "{}: division plan diverged", spec.name);
            assert_eq!(
                PAPER.slice(spec.d_in, spec.d_out).unwrap(),
                SlicePlan::new(spec.d_in, spec.d_out).unwrap(),
                "{}: slice plan diverged",
                spec.name
            );
            for stage in &via.stages {
                let want = stage_schedule(stage, spec.vectors, &arch, 16);
                let got = PAPER.schedule(stage, spec.vectors, &arch, 16);
                assert_eq!(got, want, "{}: stage schedule diverged", spec.name);
                let map = PAPER.mapping(stage.points, &arch);
                assert_eq!(
                    map,
                    Mapping::for_points(stage.points, &arch),
                    "{}: mapping diverged",
                    spec.name
                );
                let (_, window, pack) = want;
                let key = (
                    format!("{:?}", stage.kind),
                    stage.points,
                    stage.twiddle_before,
                    stage.weights_from_ddr,
                    window,
                    pack,
                );
                if !seen.insert(key) {
                    continue;
                }
                programs += 1;
                let strategic = lower_stage_mapped(stage, &arch, window, pack, &map);
                let legacy = lower_stage_packed(stage, &arch, window, pack);
                strategic.validate().unwrap();
                assert_eq!(
                    simulate(&strategic, &arch, &opts),
                    simulate(&legacy, &arch, &opts),
                    "{}: lowered program stats diverged at {}pt",
                    spec.name,
                    stage.points
                );
            }
        }
    }
    assert!(programs >= 10, "strategy sweep degenerated to {programs} programs");
}

#[test]
fn golden_workspace_reuse_matches_reference() {
    // One workspace threaded through the whole matrix (the session
    // pool's usage pattern) must not leak state between runs.
    let arch = ArchConfig::full();
    let mut ws = SimWorkspace::new();
    let opts = SimOptions::default();
    for kind in [KernelKind::Fft, KernelKind::Bpmm] {
        for (points, iters, pack) in [(64, 48, 4), (256, 8, 1), (512, 1, 4)] {
            let stage = StageDfg {
                kind,
                points,
                sub_iters: 1,
                twiddle_before: false,
                weights_from_ddr: false,
            };
            let program = lower_stage_packed(&stage, &arch, iters, pack);
            let reused = simulate_in(&mut ws, &program, &arch, &opts);
            let golden = sim::reference::simulate(&program, &arch, &opts);
            assert_eq!(reused, golden, "{kind:?}-{points} x{iters} pack{pack}");
        }
    }
}
