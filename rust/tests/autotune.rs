//! Integration tests for the design-space autotuner
//! (`coordinator::autotune`): the ISSUE-7 acceptance criteria — the
//! pruner skips provably-dominated work without ever discarding a
//! frontier point, resumed sweeps render byte-identical reports while
//! simulating nothing, every frontier metric matches an individually
//! run session, and multi-suite sweeps share one plan cache.

use butterfly_dataflow::arch::ArchConfig;
use butterfly_dataflow::coordinator::{
    autotune, AutotuneConfig, AutotuneResult, Journal, Metrics, Overlap, PipelineConfig, Report,
    SearchSpace, Session, WorkloadClass,
};
use butterfly_dataflow::energy::design_area_mm2;
use butterfly_dataflow::util::json;

fn classes(keys: &[&str], batch: Option<usize>) -> Vec<WorkloadClass> {
    let keys: Vec<String> = keys.iter().map(|k| k.to_string()).collect();
    WorkloadClass::resolve(&keys, batch).unwrap()
}

fn cfg(window: usize, prune: bool) -> AutotuneConfig {
    AutotuneConfig { window, prune, ..AutotuneConfig::default() }
}

/// The frontier of one single-class result as `(point id, metrics)` in
/// frontier order — comparable across runs that evaluated different
/// subsets of the same grid.
fn frontier_ids(r: &AutotuneResult) -> Vec<(String, Metrics)> {
    let c = &r.classes[0];
    c.frontier
        .iter()
        .map(|&fi| {
            let e = &c.evals[fi];
            (r.points[e.point].id.clone(), e.metrics)
        })
        .collect()
}

#[test]
fn equal_shard_replicas_are_pruned_not_simulated() {
    // bert-1k defaults to batch 1: ceil(1/1) == ceil(1/2), so the
    // arrays=2 replica point runs the identical per-shard schedule on
    // strictly more silicon and must be pruned without simulation.
    let space = SearchSpace::parse("arrays=1,2").unwrap();
    let base = ArchConfig::scaled_128();
    let cls = classes(&["bert-1k"], None);
    let r = autotune::sweep(&space, &base, &cls, &cfg(12, true), &Journal::in_memory()).unwrap();
    assert_eq!(r.points.len(), 2);
    assert_eq!(r.pruned_shard, 1, "arrays=2 at batch 1 must be shard-pruned");
    assert_eq!(r.evaluated, 1);
    assert_eq!(r.evaluated + r.pruned_shard + r.pruned_roofline, r.units_total());
    let c = &r.classes[0];
    assert_eq!(c.evals.len(), 1);
    let p = &r.points[c.evals[0].point];
    assert!(p.is_default && p.arrays == 1, "only the default design survives: {p:?}");
    assert!(c.default_on_frontier());
}

#[test]
fn pruner_never_discards_a_fully_simulated_frontier_point() {
    // Exhaustive small grid, swept twice: pruned and brute-force.  The
    // prune-soundness property is that both agree on the frontier,
    // point for point and bit for bit.
    let space = SearchSpace::parse("mesh=2x2,4x4;simd=8,32;arrays=1,2").unwrap();
    let base = ArchConfig::scaled_128();
    let cls = classes(&["fabnet-128"], Some(1));
    let on = autotune::sweep(&space, &base, &cls, &cfg(12, true), &Journal::in_memory()).unwrap();
    let off = autotune::sweep(&space, &base, &cls, &cfg(12, false), &Journal::in_memory()).unwrap();
    assert!(on.pruned_shard + on.pruned_roofline > 0, "grid must exercise the pruner: {on:?}");
    assert_eq!(off.pruned_shard + off.pruned_roofline, 0);
    assert_eq!(off.evaluated, off.units_total());
    assert_eq!(frontier_ids(&on), frontier_ids(&off), "pruning changed the frontier");
    // Every brute-force frontier point was actually simulated (never
    // pruned) in the pruned run.
    let evaluated: Vec<&str> =
        on.classes[0].evals.iter().map(|e| on.points[e.point].id.as_str()).collect();
    for (id, _) in frontier_ids(&off) {
        assert!(evaluated.contains(&id.as_str()), "frontier point {id} was pruned");
    }
}

#[test]
fn resumed_sweep_reproduces_the_report_byte_for_byte() {
    let path = std::env::temp_dir()
        .join(format!("bfdf_autotune_resume_{}.jsonl", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);
    let space = SearchSpace::parse("simd=8,32").unwrap();
    let base = ArchConfig::scaled_128();
    let cls = classes(&["fabnet-128"], Some(2));
    let c = cfg(12, true);
    let run = |resume: bool| {
        let journal = Journal::open(&path, resume).unwrap();
        let result = autotune::sweep(&space, &base, &cls, &c, &journal).unwrap();
        (Report::Pareto { result: result.clone() }.render(), result)
    };
    let (a, fresh) = run(false);
    assert_eq!(fresh.journal_hits, 0);
    assert!(fresh.evaluated > 0 && fresh.cache.lowerings > 0);
    let (b, resumed) = run(true);
    assert_eq!(a, b, "resumed report must be byte-identical to the fresh run");
    assert_eq!(resumed.journal_hits, resumed.evaluated, "resume must replay every evaluation");
    assert_eq!(resumed.cache.lowerings, 0, "a fully-journaled resume simulates nothing");
    // The artifact is valid discriminated JSON and excludes the
    // run-dependent cache/journal diagnostics (they differ between the
    // two runs above, which is exactly why they cannot be in it).
    let parsed = json::parse(&a).unwrap();
    assert_eq!(parsed.req_str("report").unwrap(), "pareto");
    assert!(parsed.get("cache").is_none());
    assert!(parsed.get("journal_hits").is_none());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn journal_skips_torn_records_and_rejects_foreign_headers() {
    let path = std::env::temp_dir()
        .join(format!("bfdf_autotune_torn_{}.jsonl", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    let good = "{\"key\":\"k1\",\"latency_s\":1.0,\"energy_j\":2.0,\"area_mm2\":3.0,\
                \"efficiency\":4.0,\"throughput\":5.0,\"power_w\":6.0}";

    // Mid-file tear between two good records: both survive, counted.
    std::fs::write(
        &path,
        format!(
            "{}\n{}\n{{\"key\":\"torn\n{}\n",
            "{\"journal\":\"bfdf-pareto\",\"version\":1}",
            good,
            good.replace("k1", "k2"),
        ),
    )
    .unwrap();
    let j = Journal::open(&path, true).unwrap();
    assert_eq!(j.loaded(), 2, "records around the tear must survive");
    assert_eq!(j.torn(), 1);

    // A future format version fails loudly instead of re-evaluating
    // the whole grid behind the user's back.
    std::fs::write(&path, "{\"journal\":\"bfdf-pareto\",\"version\":2}\n").unwrap();
    let err = Journal::open(&path, true).unwrap_err().to_string();
    assert!(err.contains("version 2") && err.contains("version 1"), "unexpected error: {err}");

    // Pointing --journal at a structural store is a user error, not an
    // empty journal.
    std::fs::write(&path, "{\"store\":\"bfdf-structural\",\"version\":1}\n").unwrap();
    let err = Journal::open(&path, true).unwrap_err().to_string();
    assert!(err.contains("bfdf-structural"), "unexpected error: {err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn frontier_metrics_match_individually_run_sessions() {
    // Acceptance: every frontier point's stats must be reproducible by
    // a fresh single-point Session run — the sweep adds sharding,
    // journaling and pruning around the evaluations, never arithmetic.
    let space = SearchSpace::parse("mesh=2x2;simd=8,32;arrays=1,2").unwrap();
    let base = ArchConfig::scaled_128();
    let cls = classes(&["fabnet-128"], Some(2));
    let r = autotune::sweep(&space, &base, &cls, &cfg(12, true), &Journal::in_memory()).unwrap();
    let c = &r.classes[0];
    assert!(!c.frontier.is_empty());
    for &fi in &c.frontier {
        let e = &c.evals[fi];
        let p = &r.points[e.point];
        let session = Session::builder().arch(p.arch.clone()).window(12).build();
        let pipe = PipelineConfig::new(Overlap::Pipeline, p.arrays);
        let nr = session.run_network_with(&cls[0].model, Some(2), pipe).unwrap();
        assert_eq!(e.metrics.latency_s, nr.batch_time_s, "{}", p.id);
        assert_eq!(e.metrics.energy_j, nr.energy_j, "{}", p.id);
        assert_eq!(e.metrics.efficiency, nr.energy_eff, "{}", p.id);
        assert_eq!(e.metrics.throughput, nr.throughput, "{}", p.id);
        assert_eq!(e.metrics.power_w, nr.power_w, "{}", p.id);
        assert_eq!(e.metrics.area_mm2, design_area_mm2(&p.arch) * p.arrays as f64, "{}", p.id);
    }
}

#[test]
fn multi_suite_sweep_shares_one_plan_cache_across_classes() {
    // fabnet-128 and fabnet-256 run the same hidden-256 FFT/BPMM
    // kernels (plan keys ignore the vector count), so the second class
    // must ride the first class's cached plans within one sweep.
    let space = SearchSpace::parse("arrays=1").unwrap();
    let base = ArchConfig::scaled_128();
    let cls = classes(&["fabnet-128", "fabnet-256"], Some(2));
    let r = autotune::sweep(&space, &base, &cls, &cfg(12, true), &Journal::in_memory()).unwrap();
    assert_eq!(r.points.len(), 1);
    assert_eq!(r.evaluated, 2);
    assert!(r.cache.plan_hits > 0, "cross-class sweep must hit the plan cache: {:?}", r.cache);
    assert!(r.cache.stage_hits > 0, "cross-class sweep must hit the stage cache: {:?}", r.cache);
}

#[test]
fn shared_structural_store_makes_repeat_sweeps_free() {
    // The autotuner's session pool is rebuilt per sweep() call; with one
    // AutotuneConfig (and thus one shared StructuralStore) reused across
    // calls, the second sweep must lower nothing, serve every stage
    // structurally, and render a byte-identical Pareto report.
    let space = SearchSpace::parse("mesh=2x2;simd=8,32").unwrap();
    let base = ArchConfig::scaled_128();
    let cls = classes(&["fabnet-128"], Some(2));
    let c = cfg(12, true);
    let first = autotune::sweep(&space, &base, &cls, &c, &Journal::in_memory()).unwrap();
    assert!(first.cache.lowerings > 0);
    assert_eq!(first.cache.structural_misses, first.cache.lowerings, "{:?}", first.cache);
    assert!(!c.store.is_empty(), "sweep left the shared store empty");

    let second = autotune::sweep(&space, &base, &cls, &c, &Journal::in_memory()).unwrap();
    assert_eq!(second.cache.lowerings, 0, "shared store was bypassed: {:?}", second.cache);
    assert_eq!(second.cache.structural_hits, second.cache.stage_misses, "{:?}", second.cache);
    assert_eq!(
        Report::Pareto { result: first }.render(),
        Report::Pareto { result: second }.render(),
        "store reuse changed the frontier"
    );
}

#[test]
fn default_grid_pruner_skips_work_and_reports_it() {
    // Acceptance: on the default grid the pruner must skip at least one
    // evaluation, and the accounting must cover the whole grid — no
    // silent caps.
    let space = SearchSpace::parse("default").unwrap();
    let base = ArchConfig::scaled_128();
    let cls = classes(&["bert-1k"], None);
    let r = autotune::sweep(&space, &base, &cls, &cfg(8, true), &Journal::in_memory()).unwrap();
    assert!(r.pruned_shard >= 1, "default grid must shard-prune at batch 1: {r:?}");
    assert_eq!(r.evaluated + r.pruned_shard + r.pruned_roofline, r.units_total());
    assert!(r.evaluated < r.units_total());
    let c = &r.classes[0];
    assert!(!c.frontier.is_empty());
    assert!(c.evals.iter().any(|e| r.points[e.point].is_default));
}
