//! ModelSpec API tests: the golden guarantee that every registry
//! suite's declarative definition lowers to the exact seed kernel
//! enumeration, a randomized property over valid hybrid schedules
//! (sparse wins + grammar round-trip), and end-to-end hybrid execution
//! through `Session::run_network`.

use butterfly_dataflow::coordinator::Session;
use butterfly_dataflow::dfg::KernelKind;
use butterfly_dataflow::util::prop::check;
use butterfly_dataflow::util::rng::Rng;
use butterfly_dataflow::workloads::spec::{
    AttnSparsity, Block, FfnForm, ModelSpec, NetworkBuilder, parse_spec_layers,
};
use butterfly_dataflow::workloads::{self, scale_name, KernelSpec, ModelFamily, SUITES};

/// The seed repo's hand-written kernel enumerations, frozen here as the
/// golden reference the `ModelSpec` lowering must reproduce
/// field-for-field.  (They lived in `workloads` as deprecated free
/// functions until 0.6.0; the fixtures below are their final resting
/// place.)
fn seed_enumeration(suite: &workloads::WorkloadSuite, batch: usize) -> Vec<KernelSpec> {
    let spec = |name: String, kind, points, vectors, d_in, d_out, seq| KernelSpec {
        name,
        kind,
        points,
        vectors,
        d_in,
        d_out,
        seq,
    };
    let seq = suite.seq;
    match suite.family {
        // ViT (Fig. 15a shapes, power-of-two 512 hidden): three folded
        // qkv projections, expand/contract FFN pair, 2D-FFT AT-all.
        ModelFamily::Vit => {
            let h = 512;
            vec![
                spec("VIT-AT-to_qkv".into(), KernelKind::Bpmm, h, 3 * batch * seq, h, h, seq),
                spec("VIT-FFN-L1".into(), KernelKind::Bpmm, h, 4 * batch * seq, h, 4 * h, seq),
                spec("VIT-FFN-L2".into(), KernelKind::Bpmm, h, 4 * batch * seq, 4 * h, h, seq),
                spec("VIT-AT-all-hidden".into(), KernelKind::Fft, h, batch * seq, h, h, seq),
                spec("VIT-AT-all-seq".into(), KernelKind::Fft, seq, batch * h, seq, seq, seq),
            ]
        }
        // BERT at the §VI-F sequence scales, 1K hidden, expand-only FFN.
        ModelFamily::Bert => {
            let h = 1024;
            let sc = scale_name(seq);
            vec![
                spec(
                    format!("BERT-AT-to_qkv-{sc}"),
                    KernelKind::Bpmm,
                    h,
                    3 * batch * seq,
                    h,
                    h,
                    seq,
                ),
                spec(
                    format!("BERT-FFN-L1-{sc}"),
                    KernelKind::Bpmm,
                    h,
                    4 * batch * seq,
                    h,
                    4 * h,
                    seq,
                ),
                spec(
                    format!("BERT-AT-all-hidden-{sc}"),
                    KernelKind::Fft,
                    h,
                    batch * seq,
                    h,
                    h,
                    seq,
                ),
                spec(
                    format!("BERT-AT-all-seq-{sc}"),
                    KernelKind::Fft,
                    seq,
                    batch * h,
                    seq,
                    seq,
                    seq,
                ),
            ]
        }
        // FABNet-Base block (Fig. 17): 2D-FFT attention + 2x FFN pair.
        ModelFamily::FabNet => {
            let h = 256;
            vec![
                spec(
                    format!("FABNet-{seq}-ATT-hidden"),
                    KernelKind::Fft,
                    h,
                    batch * seq,
                    h,
                    h,
                    seq,
                ),
                spec(
                    format!("FABNet-{seq}-ATT-seq"),
                    KernelKind::Fft,
                    seq,
                    batch * h,
                    seq,
                    seq,
                    seq,
                ),
                spec(
                    format!("FABNet-{seq}-FFN-L1"),
                    KernelKind::Bpmm,
                    h,
                    2 * batch * seq,
                    h,
                    2 * h,
                    seq,
                ),
                spec(
                    format!("FABNet-{seq}-FFN-L2"),
                    KernelKind::Bpmm,
                    h,
                    2 * batch * seq,
                    2 * h,
                    h,
                    seq,
                ),
            ]
        }
        // Table-IV one-layer vanilla transformer: 1K hidden.
        ModelFamily::Vanilla => {
            let h = 1024;
            vec![
                spec("Vanilla-ATT-hidden".into(), KernelKind::Fft, h, batch * seq, h, h, seq),
                spec("Vanilla-ATT-seq".into(), KernelKind::Fft, seq, batch * h, seq, seq, seq),
                spec("Vanilla-FFN-L1".into(), KernelKind::Bpmm, h, 2 * batch * seq, h, 2 * h, seq),
                spec("Vanilla-FFN-L2".into(), KernelKind::Bpmm, h, 2 * batch * seq, 2 * h, h, seq),
            ]
        }
    }
}

#[test]
fn golden_suite_lowering_matches_seed_enumerations() {
    // Acceptance gate: all 10 registered suites are ModelSpec-backed and
    // lower to kernel lists identical to the seed enumerations — name,
    // kind, points, vectors, d_in, d_out and seq — at the default batch
    // and at an override.
    for suite in SUITES {
        for batch in [suite.default_batch, 3] {
            let golden = seed_enumeration(suite, batch);
            let lowered = suite.kernels_at(Some(batch));
            assert_eq!(
                lowered.len(),
                golden.len(),
                "{}: kernel count diverged at batch {batch}",
                suite.name
            );
            for (got, want) in lowered.iter().zip(&golden) {
                assert_eq!(got, want, "{}: kernel diverged at batch {batch}", suite.name);
            }
        }
    }
}

#[test]
fn golden_default_batch_matches_seed_default() {
    for suite in SUITES {
        assert_eq!(
            suite.default_kernels(),
            seed_enumeration(suite, suite.default_batch),
            "{}: default-batch lowering diverged",
            suite.name
        );
    }
}

/// Generate a random valid hybrid network.
fn random_network(rng: &mut Rng) -> ModelSpec {
    // Floors chosen to keep every generated network valid: fft2d needs
    // hidden/seq >= 32 (validation would reject smaller).
    let hidden = rng.pow2(32, 1024);
    let seq = rng.pow2(32, 4096);
    let heads = rng.pow2(1, 8).min(hidden);
    let depth = rng.range(1, 4);
    let mut b = NetworkBuilder::new("prop-net")
        .hidden(hidden)
        .seq(seq)
        .heads(heads)
        .batch(rng.range(1, 16));
    for layer in 0..depth {
        if layer > 0 {
            b = b.next_layer();
        }
        let blocks = rng.range(1, 4);
        for _ in 0..blocks {
            b = if rng.chance(0.5) {
                let sparsity = match rng.below(3) {
                    0 => AttnSparsity::Dense,
                    1 => AttnSparsity::Bpmm,
                    _ => AttnSparsity::Fft2d,
                };
                b.attention(sparsity)
            } else {
                let form = if rng.chance(0.7) { FfnForm::Bpmm } else { FfnForm::Dense };
                let expand = rng.pow2(1, 8);
                if rng.chance(0.8) {
                    b.ffn(form, expand)
                } else {
                    b.ffn_expand_only(form, expand)
                }
            };
        }
    }
    b.build().expect("generated network must validate")
}

#[test]
fn prop_valid_hybrids_save_flops_and_round_trip() {
    // Every valid hybrid schedule satisfies sparse_flops < dense_flops
    // for its sparse layers, and its canonical spec string round-trips
    // through the grammar (parse -> format -> parse).
    check("hybrid-schedules", 100, |rng| {
        let net = random_network(rng);
        for k in net.kernels(Some(rng.range(1, 8))) {
            assert!(
                k.sparse_flops() < k.dense_flops(),
                "{}: sparse {} !< dense {}",
                k.name,
                k.sparse_flops(),
                k.dense_flops()
            );
        }
        let rendered = net.spec_string();
        let reparsed = parse_spec_layers(&rendered).expect("canonical spec must parse");
        assert_eq!(
            &reparsed,
            net.layers(),
            "grammar round-trip diverged for '{rendered}'"
        );
        let rerendered = workloads::spec::format_spec_layers(&reparsed);
        assert_eq!(rendered, rerendered, "format is not a fixed point");
    });
}

#[test]
fn prop_lowering_provenance_covers_every_block() {
    check("lowering-provenance", 40, |rng| {
        let net = random_network(rng);
        let lowered = net.lower(None);
        let blocks_total: usize = net.layers().iter().map(Vec::len).sum();
        assert_eq!(lowered.len(), blocks_total);
        let mut last_layer = 0;
        for lb in &lowered {
            assert!(lb.layer >= last_layer, "layers must be emitted in order");
            last_layer = lb.layer;
            // Every block carries either kernels or a dense estimate.
            assert!(
                !lb.kernels.is_empty() || lb.dense.is_some(),
                "block {} lowered to nothing",
                lb.label
            );
        }
        assert_eq!(last_layer, net.depth() - 1, "every layer must be lowered");
    });
}

#[test]
fn hybrid_network_mixing_sparsities_runs_end_to_end() {
    // Acceptance gate: a network mixing two attention sparsities in one
    // run produces per-layer and total metrics.
    let net = NetworkBuilder::from_spec(
        "mixed",
        "att:fft2d,ffn:bpmm*x4;att:bpmm,ffn:bpmm*x2",
    )
    .unwrap()
    .hidden(256)
    .seq(128)
    .batch(4)
    .build()
    .unwrap();
    let session = Session::builder().build();
    let r = session.run_network(&net, None).unwrap();
    assert_eq!(r.layers.len(), 2);
    assert_eq!(r.layers[0].blocks[0].label, "att:fft2d");
    assert_eq!(r.layers[1].blocks[0].label, "att:bpmm");
    assert!(r.layers.iter().all(|l| l.time_s > 0.0 && l.energy_j > 0.0));
    let t: f64 = r.layers.iter().map(|l| l.time_s).sum();
    assert!((r.batch_time_s - t).abs() < 1e-12, "totals must sum the layers");
    assert!(r.latency_ms > 0.0 && r.throughput > 0.0 && r.energy_eff > 0.0);
}

#[test]
fn suite_models_and_direct_builders_agree() {
    // Composing the vanilla structure by hand must lower to the same
    // shapes (modulo kernel names) as the registry model.
    let by_hand = ModelSpec::builder("vanilla-by-hand")
        .hidden(1024)
        .seq(1024)
        .batch(256)
        .attention(AttnSparsity::Fft2d)
        .ffn(FfnForm::Bpmm, 2)
        .build()
        .unwrap();
    let registry = workloads::find_suite("vanilla").unwrap().model();
    let a = by_hand.kernels(Some(8));
    let b = registry.kernels(Some(8));
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!((x.kind, x.points, x.vectors, x.d_in, x.d_out, x.seq),
                   (y.kind, y.points, y.vectors, y.d_in, y.d_out, y.seq));
    }
}

#[test]
fn expand_only_block_matches_bert_ffn_slice() {
    let net = ModelSpec::builder("slice")
        .hidden(1024)
        .seq(4096)
        .block(Block::Ffn { form: FfnForm::Bpmm, expand: 4, contract: false })
        .build()
        .unwrap();
    let ks = net.kernels(Some(1));
    assert_eq!(ks.len(), 1);
    assert_eq!(ks[0].vectors, 4 * 4096);
    assert_eq!(ks[0].d_out, 4 * 1024);
}
