//! Integration tests for the pluggable dataflow-strategy layer: the
//! structural invariants every registered [`DataflowStrategy`] must
//! satisfy across the kernel grid, the `Strategy::Auto` guarantee that
//! simulate-and-pick never loses to the paper recipe on any registered
//! suite, the plan-cache population contract of Auto's probes, and the
//! autotuner's `strategy=` search-space axis end-to-end.

use butterfly_dataflow::arch::ArchConfig;
use butterfly_dataflow::coordinator::{
    autotune, AutotuneConfig, Journal, Overlap, SearchSpace, Session, WorkloadClass,
};
use butterfly_dataflow::dfg::graph::KernelKind;
use butterfly_dataflow::dfg::strategy::{registry, Strategy};
use butterfly_dataflow::workloads::{find_suite, SUITES};

#[test]
fn every_strategy_plans_exact_depth_and_node_count() {
    // Whatever division a strategy picks, the lowered plan must still
    // compute the full butterfly: total depth exactly log2(n), stage
    // points multiplying back to n, and the per-vector node count
    // conserved at (n/2)·log2(n) — across both kinds and every
    // power-of-two size up to 64K points.
    let arch = ArchConfig::full();
    for strat in registry() {
        for kind in [KernelKind::Fft, KernelKind::Bpmm] {
            for exp in 1..=16usize {
                let n = 1usize << exp;
                let plan = strat
                    .plan(kind, n, 64, &arch, None)
                    .unwrap_or_else(|e| panic!("{} {kind:?} {n}: {e}", strat.name()));
                assert_eq!(
                    plan.total_depth(),
                    exp,
                    "{} {kind:?} {n}: depth not log2(n)",
                    strat.name()
                );
                let product: usize = plan.stages.iter().map(|s| s.points).product();
                assert_eq!(product, n, "{} {kind:?} {n}: stage points", strat.name());
                assert_eq!(
                    plan.nodes_per_vector(),
                    n / 2 * exp,
                    "{} {kind:?} {n}: node count not conserved",
                    strat.name()
                );
            }
        }
    }
}

#[test]
fn auto_never_regresses_any_registered_suite() {
    // Strategy::Auto probes every registry entry per kernel shape and
    // keeps the fastest, with ties resolved to paper — so per kernel,
    // and therefore per serial suite total, it can never be slower than
    // the paper recipe.
    for suite in SUITES {
        let kernels = suite.kernels_at(Some(2));
        let paper = Session::builder().window(12).strategy(Strategy::Paper).build();
        let auto = Session::builder().window(12).strategy(Strategy::Auto).build();
        let p = paper.run_many(&kernels).unwrap();
        let a = auto.run_many(&kernels).unwrap();
        for (pk, ak) in p.iter().zip(&a) {
            assert!(
                ak.time_s <= pk.time_s,
                "{}: auto {} s > paper {} s",
                pk.name,
                ak.time_s,
                pk.time_s
            );
        }
        let pt: f64 = p.iter().map(|k| k.time_s).sum();
        let at: f64 = a.iter().map(|k| k.time_s).sum();
        assert!(at <= pt, "{}: auto total {at} > paper total {pt}", suite.name);
    }
}

#[test]
fn auto_probes_populate_the_cache_the_winner_reuses() {
    // Auto's probe runs land in the same plan cache the winner is
    // served from: a second identical run must add zero misses, and the
    // memoized winner must reproduce the first run bit-for-bit.
    let auto = Session::builder().strategy(Strategy::Auto).build();
    let kernels = find_suite("fabnet-128").unwrap().kernels_at(Some(2));
    let r1 = auto.run_many(&kernels).unwrap();
    let s1 = auto.cache_stats();
    assert!(s1.plan_misses > 0);
    let r2 = auto.run_many(&kernels).unwrap();
    let s2 = auto.cache_stats();
    assert_eq!(s1.plan_misses, s2.plan_misses, "second run must miss nothing");
    assert!(s2.plan_hits > s1.plan_hits, "second run must ride the cache");
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.cycles, b.cycles, "{}", a.name);
        assert_eq!(a.time_s, b.time_s, "{}", a.name);
    }
    let picks = auto.auto_selections();
    assert!(!picks.is_empty(), "auto must record its selections");
}

#[test]
fn autotune_strategy_axis_auto_point_never_loses_to_paper() {
    // End-to-end through the autotuner: a strategy=paper,auto axis
    // yields two points per arch, the auto point carries the id suffix,
    // and under serial accounting its latency is bounded by paper's.
    let space = SearchSpace::parse("strategy=paper,auto").unwrap();
    let base = ArchConfig::scaled_128();
    let classes = WorkloadClass::resolve(&["fabnet-128".into()], Some(2)).unwrap();
    let cfg = AutotuneConfig {
        window: 12,
        overlap: Overlap::None,
        prune: false,
        ..AutotuneConfig::default()
    };
    let r = autotune::sweep(&space, &base, &classes, &cfg, &Journal::in_memory()).unwrap();
    assert_eq!(r.points.len(), 2);
    let c = &r.classes[0];
    assert_eq!(c.evals.len(), 2, "prune disabled: both points evaluated");
    let find = |want: Strategy| {
        c.evals
            .iter()
            .find(|e| r.points[e.point].strategy == want)
            .unwrap_or_else(|| panic!("no {} point", want.name()))
    };
    let paper = find(Strategy::Paper);
    let auto = find(Strategy::Auto);
    assert!(r.points[paper.point].is_default);
    assert!(r.points[auto.point].id.ends_with("-stauto"));
    assert!(
        auto.metrics.latency_s <= paper.metrics.latency_s,
        "auto {} s > paper {} s",
        auto.metrics.latency_s,
        paper.metrics.latency_s
    );
}
