//! Runtime/PJRT integration: load the AOT artifacts and check the
//! numerics against both the Python goldens and the Rust-side numeric
//! models.  Skipped (with a message) when `artifacts/` has not been
//! built — run `make artifacts` first.

use std::path::Path;

use butterfly_dataflow::model::attention::{fnet_mixing, Mat};
use butterfly_dataflow::runtime::{tensor::read_f32_tensor, Runtime, Tensor};
use butterfly_dataflow::util::rng::Rng;

fn artifacts_dir() -> Option<&'static str> {
    if Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn all_artifacts_validate_against_goldens() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let names = rt.artifact_names();
    assert!(names.len() >= 4, "expected at least 4 artifacts: {names:?}");
    let dirp = rt.dir.clone();
    for name in names {
        let model = rt.load(&name).unwrap();
        let err = model.validate_golden(&dirp).unwrap();
        assert!(err < 1e-2, "{name}: rel err {err}");
    }
}

#[test]
fn fft_artifact_matches_rust_fft_oracle() {
    // The PJRT-executed Pallas FFT must agree with the independent
    // Rust Cooley-Tukey implementation on fresh random inputs — the
    // strongest cross-language, cross-layer consistency check.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let model = rt.load("fft_b64_n256").unwrap();
    let (b, n) = (64usize, 256usize);
    let mut rng = Rng::new(99);
    let x = Tensor::new(vec![b, n], rng.normal_vec(b * n)).unwrap();
    let y = model.run(&x).unwrap();
    for row in 0..b {
        let spec = butterfly_dataflow::model::fft::fft_real(&x.data[row * n..(row + 1) * n]);
        for k in 0..n {
            let got = y.data[row * n + k] as f64;
            let want = spec[k].re;
            assert!(
                (got - want).abs() < 1e-2 * (1.0 + want.abs()),
                "row {row} bin {k}: {got} vs {want}"
            );
        }
    }
}

#[test]
fn fnet_block_artifact_runs_fresh_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let model = rt.load("fnet_block_b4_s256_h256").unwrap();
    let shape = model.meta.input_shape.clone();
    let n: usize = shape.iter().product();
    let mut rng = Rng::new(3);
    let x = Tensor::new(shape.clone(), rng.normal_vec(n)).unwrap();
    let y = model.run(&x).unwrap();
    assert_eq!(y.shape, model.meta.output_shape);
    assert!(y.data.iter().all(|v| v.is_finite()));
    // Determinism of the compiled executable.
    let y2 = model.run(&x).unwrap();
    assert_eq!(y.data, y2.data);
}

#[test]
fn golden_inputs_are_readable_and_shaped() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(dir).unwrap();
    for name in rt.artifact_names() {
        let meta = rt.meta(&name).unwrap().clone();
        let input =
            read_f32_tensor(&rt.dir.join(format!("{name}.in.f32t"))).unwrap();
        assert_eq!(input.shape, meta.input_shape, "{name}");
        let out = read_f32_tensor(&rt.dir.join(format!("{name}.out.f32t"))).unwrap();
        assert_eq!(out.shape, meta.output_shape, "{name}");
        // Manifest checksums match the golden file.
        assert!(
            (out.l2() - meta.output_l2).abs() / meta.output_l2.max(1e-9) < 1e-4,
            "{name}: l2 {} vs manifest {}",
            out.l2(),
            meta.output_l2
        );
    }
}

#[test]
fn rust_fnet_mixing_sanity_against_model() {
    // Pure Rust-side consistency (no artifacts needed, but grouped here
    // as part of the numerics chain): fnet mixing DC term.
    let mut rng = Rng::new(1);
    let x = Mat::from_vec(16, 32, rng.normal_vec(16 * 32));
    let y = fnet_mixing(&x);
    let sum: f32 = x.data.iter().sum();
    assert!((y.at(0, 0) - sum).abs() < 1e-2 * (1.0 + sum.abs()));
}

#[test]
fn runtime_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    let model = rt.load("bpmm_b64_n256").unwrap();
    let bad = Tensor::zeros(vec![2, 2]);
    assert!(model.run(&bad).is_err());
}

#[test]
fn runtime_open_fails_cleanly_without_manifest() {
    let err = match Runtime::open("/nonexistent-artifacts-dir") {
        Ok(_) => panic!("open should fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}
