//! Session-API tests: plan-cache determinism, parallel-vs-serial
//! equivalence, and the streaming cache-reuse guarantee (the vanilla
//! workload's duplicate FFN kernels must lower once).

use butterfly_dataflow::arch::ArchConfig;
use butterfly_dataflow::coordinator::Session;
use butterfly_dataflow::dfg::graph::KernelKind;
use butterfly_dataflow::workloads::{find_suite, KernelSpec};

fn vanilla_kernels(batch: usize) -> Vec<KernelSpec> {
    find_suite("vanilla").unwrap().kernels_at(Some(batch))
}

fn vit_kernels(batch: usize) -> Vec<KernelSpec> {
    find_suite("vit-256").unwrap().kernels_at(Some(batch))
}

fn spec(kind: KernelKind, points: usize, vectors: usize) -> KernelSpec {
    KernelSpec {
        name: format!("{}-{}", kind.name(), points),
        kind,
        points,
        vectors,
        d_in: points,
        d_out: points,
        seq: points,
    }
}

#[test]
fn plan_cache_is_deterministic() {
    // Same spec twice through one session: identical metrics and a
    // recorded cache hit; a fresh session must agree bitwise.
    let session = Session::builder().build();
    let s = spec(KernelKind::Fft, 1024, 16 * 1024);
    let first = session.run(&s).unwrap();
    let second = session.run(&s).unwrap();
    assert_eq!(first.cycles, second.cycles);
    assert_eq!(first.time_s, second.time_s);
    assert_eq!(first.util, second.util);
    assert_eq!(first.power_w, second.power_w);
    assert_eq!(first.energy_j, second.energy_j);
    let stats = session.cache_stats();
    assert!(stats.plan_hits >= 1, "no plan hit recorded: {stats:?}");
    assert!(stats.stage_hits >= 1, "no stage hit recorded: {stats:?}");

    let fresh = Session::builder().build().run(&s).unwrap();
    assert_eq!(first.cycles, fresh.cycles);
    assert_eq!(first.energy_j, fresh.energy_j);
}

#[test]
fn run_many_matches_serial_in_input_order() {
    // Parallel fan-out must return bitwise-identical results to
    // sequential runs, in input order.
    let mut specs = vanilla_kernels(2);
    specs.extend(vit_kernels(2));
    let parallel = Session::builder().build().run_many(&specs).unwrap();
    let serial_session = Session::builder().build();
    let serial: Vec<_> = specs
        .iter()
        .map(|s| serial_session.run(s).unwrap())
        .collect();
    assert_eq!(parallel.len(), specs.len());
    for ((p, s), want) in parallel.iter().zip(&serial).zip(&specs) {
        assert_eq!(p.name, want.name, "input order not preserved");
        assert_eq!(p.name, s.name);
        assert_eq!(p.cycles, s.cycles, "{}", p.name);
        assert_eq!(p.time_s, s.time_s, "{}", p.name);
        assert_eq!(p.util, s.util, "{}", p.name);
        assert_eq!(p.power_w, s.power_w, "{}", p.name);
        assert_eq!(p.energy_j, s.energy_j, "{}", p.name);
        assert_eq!(p.spm_requirement, s.spm_requirement, "{}", p.name);
    }
}

#[test]
fn vanilla_stream_reuses_lowered_programs() {
    // Acceptance gate: the vanilla transformer carries duplicate
    // kernels (ATT-hidden == ATT-seq at 1K/1K, FFN-L1 == FFN-L2), so a
    // cached stream must invoke the stage lowering fewer times than it
    // runs kernels — with latency identical to the uncached path.
    let batch = 4;
    let cached = Session::builder().arch(ArchConfig::table4()).build();
    let r = cached.stream(&vanilla_kernels(batch), batch).unwrap();
    let stats = cached.cache_stats();
    let kernels_run = r.kernels.len();
    assert_eq!(kernels_run, 4);
    assert!(
        stats.lowerings < kernels_run as u64,
        "expected fewer lowerings than kernels: {stats:?}"
    );
    assert!(stats.stage_hits >= 1, "no stage cache hit: {stats:?}");
    assert!(stats.plan_hits >= 1, "no plan cache hit: {stats:?}");

    let uncached = Session::builder()
        .arch(ArchConfig::table4())
        .plan_caching(false)
        .build();
    let r2 = uncached.stream(&vanilla_kernels(batch), batch).unwrap();
    assert_eq!(
        r.latency_ms, r2.latency_ms,
        "caching changed the simulated latency"
    );
    assert_eq!(r.power_w, r2.power_w);
    let raw = uncached.cache_stats();
    assert!(raw.lowerings >= kernels_run as u64, "{raw:?}");
}

#[test]
fn run_many_propagates_planning_errors() {
    let session = Session::builder().build();
    let mut specs = vanilla_kernels(1);
    specs.push(spec(KernelKind::Fft, 100, 64)); // not a power of two
    let err = session.run_many(&specs).unwrap_err().to_string();
    assert!(err.contains("power of two"), "unexpected error: {err}");
}

#[test]
fn workspace_pool_is_bounded_at_thread_count() {
    // The pooled scratch arenas must never outgrow the worker count:
    // a burst of concurrent checkouts (kernel fan-out x stage sharding)
    // may allocate extras, but returns beyond the cap are dropped.
    let session = Session::builder().threads(3).build();
    assert_eq!(session.threads(), 3);
    let mut specs = vanilla_kernels(2);
    specs.extend(vit_kernels(2));
    specs.extend(vanilla_kernels(4));
    session.run_many(&specs).unwrap();
    let len = session.workspace_pool_len();
    assert!(len <= 3, "workspace pool grew past the thread count: {len}");

    // Serial sessions keep at most one warm arena.
    let serial = Session::builder().threads(1).build();
    serial.run_many(&vanilla_kernels(2)).unwrap();
    assert!(serial.workspace_pool_len() <= 1);
}

#[test]
fn sessions_with_different_windows_do_not_share_results() {
    // The window is part of the stage cache key; different windows may
    // measure slightly different steady states but must both run.
    let s = spec(KernelKind::Bpmm, 2048, 32 * 1024);
    let a = Session::builder().window(32).build().run(&s).unwrap();
    let b = Session::builder().window(96).build().run(&s).unwrap();
    let ratio = a.cycles / b.cycles;
    assert!((0.9..1.1).contains(&ratio), "window drift too large: {ratio}");
}
