"""AOT export: lower the L2 models to HLO *text* artifacts for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Each artifact bakes its parameters in as constants (deterministic seeds),
so the Rust side supplies only the activation tensor.  Alongside every
``<name>.hlo.txt`` we write ``<name>.meta.json`` (shape/dtype/expected
checksum) that `rust/src/runtime` uses to validate I/O, plus a golden
input/output pair ``<name>.golden.npyf32`` for bit-exact runtime tests.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile does).
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import butterfly as bf
from .kernels import fft as kfft
from .kernels.ref import random_bpmm_factors


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    CRITICAL: print with ``print_large_constants=True``.  The default
    printer elides big constants as ``constant({...})`` and the xla
    0.5.1 text parser silently materializes those as zeros — the model
    weights would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New-jax metadata attributes (source_end_line, ...) choke the 0.5.1
    # text parser; layouts/metadata are irrelevant to the interchange.
    opts.print_metadata = False
    opts.print_backend_config = False
    return comp.get_hlo_module().to_string(opts)


def write_f32_tensor(path: str, arr: np.ndarray) -> None:
    """Tiny self-describing binary: ndim, dims..., f32 data (little-endian).

    The Rust loader is ``runtime::tensor::read_f32_tensor``.
    """
    arr = np.ascontiguousarray(arr, dtype=np.float32)
    with open(path, "wb") as f:
        f.write(struct.pack("<I", arr.ndim))
        for d in arr.shape:
            f.write(struct.pack("<I", d))
        f.write(arr.tobytes())


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: list[dict] = []
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name: str, fn, example_input: np.ndarray) -> None:
        """Lower fn(x)->(y,) at x's shape, dump HLO text + golden pair."""
        x = jnp.asarray(example_input, dtype=jnp.float32)
        wrapped = lambda t: (fn(t),)
        lowered = jax.jit(wrapped).lower(
            jax.ShapeDtypeStruct(x.shape, jnp.float32))
        text = to_hlo_text(lowered)
        if "{...}" in text:
            raise RuntimeError(
                f"artifact {name}: HLO text contains elided constants "
                "('{...}') — the rust loader would read zeros")
        hlo_path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(text)
        y = np.asarray(jax.jit(wrapped)(x)[0])
        write_f32_tensor(os.path.join(self.out_dir, f"{name}.in.f32t"),
                         np.asarray(x))
        write_f32_tensor(os.path.join(self.out_dir, f"{name}.out.f32t"), y)
        meta = {
            "name": name,
            "input_shape": list(x.shape),
            "output_shape": list(y.shape),
            "dtype": "f32",
            "hlo_bytes": len(text),
            "output_mean": float(y.mean()),
            "output_l2": float(np.sqrt((y.astype(np.float64) ** 2).sum())),
        }
        with open(os.path.join(self.out_dir, f"{name}.meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        self.manifest.append(meta)
        print(f"  {name}: in{tuple(x.shape)} -> out{tuple(y.shape)}, "
              f"hlo {len(text)/1024:.0f} KiB", flush=True)

    def finish(self) -> None:
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)


def build_all(out_dir: str, quick: bool = False) -> None:
    ex = Exporter(out_dir)
    rng = np.random.default_rng(42)

    # 1. Raw BPMM kernel, paper's single-DFG scale (n=256, batch 64).
    factors_256 = random_bpmm_factors(256, seed=3)
    ex.export("bpmm_b64_n256",
              lambda x: bf.bpmm(x, factors_256),
              rng.normal(size=(64, 256)).astype(np.float32))

    # 2. Raw FFT kernel (returns re-plane; im validated in pytest).
    ex.export("fft_b64_n256",
              lambda x: kfft.fft_real(x)[0],
              rng.normal(size=(64, 256)).astype(np.float32))

    # 3. FABNet-style encoder block, seq 256 / hidden 256.
    p_fnet = M.FnetBlockParams.init(256, ffn_mult=4, seed=7)
    ex.export("fnet_block_b4_s256_h256",
              lambda x: M.fnet_block(x, p_fnet),
              rng.normal(size=(4, 256, 256)).astype(np.float32) * 0.1)

    # 4. Butterfly softmax-attention block (AT-to_qkv BPMM), seq 128 / d 256.
    p_attn = M.ButterflyAttentionParams.init(256, heads=4, seed=11)
    ex.export("bfattn_b2_s128_h256",
              lambda x: M.butterfly_attention(x, p_attn),
              rng.normal(size=(2, 128, 256)).astype(np.float32) * 0.1)

    if not quick:
        # 5. Table-IV one-layer vanilla transformer, 1K seq / 1K hidden.
        p_van = M.VanillaButterflyParams.init(1024, seed=13)
        ex.export("vanilla_b1_s1024_h1024",
                  lambda x: M.vanilla_butterfly_layer(x, p_van),
                  rng.normal(size=(1, 1024, 1024)).astype(np.float32) * 0.1)

        # 6. Staged (Fig. 9) BPMM at n=2048 (division 64x32 auto).
        staged = M.make_staged_bpmm_factors(2048, seed=17)
        ex.export("bpmm_staged_b16_n2048",
                  lambda x: M.bpmm_staged(x, staged),
                  rng.normal(size=(16, 2048)).astype(np.float32))

    ex.finish()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="skip the large artifacts (CI smoke)")
    args = ap.parse_args()
    print(f"AOT-lowering artifacts to {args.out_dir}", flush=True)
    build_all(args.out_dir, quick=args.quick)
    print("done")


if __name__ == "__main__":
    main()
