"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from . import butterfly, fft, ref  # noqa: F401
