"""Pure-jnp reference oracles for the butterfly kernels.

Everything in this module is *deliberately naive*: dense matrices, explicit
permutations, textbook Cooley-Tukey.  The Pallas kernels in
``butterfly.py`` / ``fft.py`` and the Rust model in ``rust/src/model/``
are validated against these functions.

Conventions
-----------
* A *butterfly stage* ``s`` (0-based) pairs element ``i`` with ``i + 2**s``
  within blocks of ``2**(s+1)``.  Pair ``p`` of stage ``s`` is
  ``(blk, off)`` with ``i = blk * 2**(s+1) + off``, ``j = i + 2**s`` and
  the flat pair index ``p = blk * 2**s + off``.
* BPMM stage weights have shape ``(n//2, 4)`` per stage: for pair ``p``
  the 2x2 dense block ``[[w0, w1], [w2, w3]]`` maps
  ``(x_i, x_j) -> (w0*x_i + w1*x_j, w2*x_i + w3*x_j)``.
* A full BPMM factor set has shape ``(log2(n), n//2, 4)`` and is applied
  stage 0 first (stride 1) up to stage log2(n)-1 (stride n/2), matching
  the paper's Fig. 4 left-to-right product B_n ... B_2.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def log2_int(n: int) -> int:
    """log2 for exact powers of two, raising otherwise."""
    l = int(n).bit_length() - 1
    if n <= 0 or (1 << l) != n:
        raise ValueError(f"{n} is not a positive power of two")
    return l


# ---------------------------------------------------------------------------
# Butterfly stage / BPMM
# ---------------------------------------------------------------------------

def stage_pair_indices(n: int, stage: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (i, j) index arrays of the n//2 pairs of a butterfly stage."""
    stride = 1 << stage
    blocks = n // (2 * stride)
    blk = np.repeat(np.arange(blocks), stride)
    off = np.tile(np.arange(stride), blocks)
    i = blk * 2 * stride + off
    return i, i + stride


def stage_dense_matrix(n: int, stage: int, w: np.ndarray) -> np.ndarray:
    """Materialize one butterfly stage as a dense (n, n) matrix.

    ``w`` has shape (n//2, 4).  Row/col convention: y = B @ x.
    """
    w = np.asarray(w)
    assert w.shape == (n // 2, 4), w.shape
    i, j = stage_pair_indices(n, stage)
    m = np.zeros((n, n), dtype=w.dtype)
    m[i, i] = w[:, 0]
    m[i, j] = w[:, 1]
    m[j, i] = w[:, 2]
    m[j, j] = w[:, 3]
    return m


def bpmm_dense_matrix(n: int, factors: np.ndarray) -> np.ndarray:
    """Product of all stages as a dense matrix (stage log2(n)-1 leftmost)."""
    stages = log2_int(n)
    assert factors.shape == (stages, n // 2, 4), factors.shape
    m = np.eye(n, dtype=factors.dtype)
    for s in range(stages):
        m = stage_dense_matrix(n, s, factors[s]) @ m
    return m


def bpmm_stage_ref(x: jnp.ndarray, w: jnp.ndarray, stage: int) -> jnp.ndarray:
    """Apply one butterfly stage to x of shape (..., n) (real or complex)."""
    n = x.shape[-1]
    stride = 1 << stage
    blocks = n // (2 * stride)
    xr = x.reshape(x.shape[:-1] + (blocks, 2, stride))
    wr = w.reshape(blocks, stride, 4)
    top, bot = xr[..., 0, :], xr[..., 1, :]
    y_top = wr[..., 0] * top + wr[..., 1] * bot
    y_bot = wr[..., 2] * top + wr[..., 3] * bot
    y = jnp.stack([y_top, y_bot], axis=-2)
    return y.reshape(x.shape)


def bpmm_ref(x: jnp.ndarray, factors: jnp.ndarray) -> jnp.ndarray:
    """Apply the full BPMM (all log2(n) stages) to x of shape (..., n)."""
    stages = factors.shape[0]
    for s in range(stages):
        x = bpmm_stage_ref(x, factors[s], s)
    return x


def random_bpmm_factors(n: int, seed: int = 0,
                        dtype=jnp.float32) -> jnp.ndarray:
    """Random butterfly factor set, biased towards identity so the full
    product stays well-conditioned at any log2(n) depth."""
    stages = log2_int(n)
    rng = np.random.default_rng(seed)
    w = rng.normal(0.0, 0.5, size=(stages, n // 2, 4)) + \
        0.5 * np.tile(np.array([1.0, 0.0, 0.0, 1.0]), (stages, n // 2, 1))
    return jnp.asarray(w, dtype=dtype)


# ---------------------------------------------------------------------------
# FFT via butterfly stages (decimation in time)
# ---------------------------------------------------------------------------

def bit_reversal_permutation(n: int) -> np.ndarray:
    """Index array ``perm`` with perm[k] = bit-reverse(k, log2 n)."""
    bits = log2_int(n)
    perm = np.zeros(n, dtype=np.int64)
    for k in range(n):
        r = 0
        for b in range(bits):
            if k & (1 << b):
                r |= 1 << (bits - 1 - b)
        perm[k] = r
    return perm


def fft_twiddles(n: int) -> np.ndarray:
    """Per-stage complex twiddles, shape (log2 n, n//2) complex128.

    Stage ``s`` pair (blk, off) uses w = exp(-2 pi i * off / 2**(s+1))
    (DIT radix-2 after bit-reversal input permutation).
    """
    stages = log2_int(n)
    tw = np.zeros((stages, n // 2), dtype=np.complex128)
    for s in range(stages):
        stride = 1 << s
        blocks = n // (2 * stride)
        w = np.exp(-2j * np.pi * np.arange(stride) / (2 * stride))
        tw[s] = np.tile(w, blocks)
    return tw


def fft_stage_factors(n: int) -> np.ndarray:
    """FFT stages expressed as *complex* BPMM factors, shape (log2 n, n//2, 4).

    Pair map: (t, b) -> (t + w*b, t - w*b), i.e. block [[1, w], [1, -w]].
    """
    tw = fft_twiddles(n)
    stages, half = tw.shape
    f = np.zeros((stages, half, 4), dtype=np.complex128)
    f[:, :, 0] = 1.0
    f[:, :, 1] = tw
    f[:, :, 2] = 1.0
    f[:, :, 3] = -tw
    return f


def fft_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Reference DFT over the last axis (jnp.fft)."""
    return jnp.fft.fft(x, axis=-1)


def fft_butterfly_ref(x: jnp.ndarray) -> jnp.ndarray:
    """DIT radix-2 FFT built from butterfly stages (complex, last axis)."""
    n = x.shape[-1]
    perm = jnp.asarray(bit_reversal_permutation(n))
    x = jnp.take(x, perm, axis=-1).astype(jnp.complex128)
    factors = jnp.asarray(fft_stage_factors(n))
    for s in range(factors.shape[0]):
        x = bpmm_stage_ref(x, factors[s], s)
    return x


def fft2d_ref(x: jnp.ndarray) -> jnp.ndarray:
    """2D DFT over the last two axes (sequence, hidden) — FNet mixing."""
    return jnp.fft.fft2(x, axes=(-2, -1))


# ---------------------------------------------------------------------------
# Attention references
# ---------------------------------------------------------------------------

def softmax_attention_ref(q: jnp.ndarray, k: jnp.ndarray,
                          v: jnp.ndarray) -> jnp.ndarray:
    """Dense softmax(QK^T/sqrt(d))V over (..., seq, dim)."""
    d = q.shape[-1]
    scores = jnp.einsum("...sd,...td->...st", q, k) / jnp.sqrt(
        jnp.asarray(d, dtype=q.dtype))
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("...st,...td->...sd", probs, v)


def fnet_mixing_ref(x: jnp.ndarray) -> jnp.ndarray:
    """FNet token mixing: Re(FFT2(x)) over (seq, hidden)."""
    return jnp.real(fft2d_ref(x)).astype(x.dtype)


def dense_linear_ref(x: jnp.ndarray, w: jnp.ndarray,
                     b=None) -> jnp.ndarray:
    y = x @ w
    if b is not None:
        y = y + b
    return y


def butterfly_linear_ref(x: jnp.ndarray, factor_sets, d_in: int,
                         d_out: int) -> jnp.ndarray:
    """BPMM linear layer with Fig.10 slicing for unequal hidden sizes.

    ``factor_sets`` is a list of factor arrays, each (log2 m, m//2, 4) where
    m = min(d_in, d_out):
      * d_in > d_out: slice x into d_in/d_out pieces, BPMM each, sum.
      * d_in < d_out: BPMM x with d_out/d_in factor sets, concatenate.
      * equal: single factor set.
    """
    if d_in == d_out:
        return bpmm_ref(x, factor_sets[0])
    if d_in > d_out:
        k = d_in // d_out
        assert k * d_out == d_in and len(factor_sets) == k
        pieces = jnp.split(x, k, axis=-1)
        return sum(bpmm_ref(p, f) for p, f in zip(pieces, factor_sets))
    k = d_out // d_in
    assert k * d_in == d_out and len(factor_sets) == k
    return jnp.concatenate([bpmm_ref(x, f) for f in factor_sets], axis=-1)


def layer_norm_ref(x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)
