"""L1 Pallas kernel: radix-2 DIT FFT as butterfly dataflow.

Complex values are carried as separate real/imaginary planes (Pallas has no
complex refs); each butterfly stage is the complex specialization of the
BPMM 2x2 block: ``(t, b) -> (t + w*b, t - w*b)``.  The paper's observation
that FFT needs twice the Flow traffic of BPMM (real+imag swap, §VI-D) shows
up here as the doubled plane state.

Same VMEM-residency contract as butterfly.py: one tile = all stages, HBM is
touched once per element per direction.  The bit-reversal input permutation
is done with a static gather before the stage loop — inside the kernel, so
the permuted layout never exists in HBM (the paper's P_N matrices are folded
into SPM addressing the same way).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import bit_reversal_permutation, fft_twiddles, log2_int

# Paper: max single-DFG FFT scale on the PE array (complex halves storage).
MAX_FFT_POINTS = 256
DEFAULT_BLOCK_B = 16


def _bit_reverse_rows(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Bit-reversal permutation of the last axis as reshape/transpose.

    ``y[..., k] = x[..., bitrev(k)]``: split the axis into ``bits`` binary
    axes and reverse their order.  Pure layout ops — no gather constants,
    which Pallas kernels may not capture.  This is also exactly how the
    paper folds the P_N permutation matrices into SPM addressing instead
    of materializing them.
    """
    b = x.shape[0]
    y = x.reshape((b,) + (2,) * bits)
    y = y.transpose((0,) + tuple(range(bits, 0, -1)))
    return y.reshape(b, -1)


def _fft_kernel(xr_ref, xi_ref, twr_ref, twi_ref, or_ref, oi_ref,
                *, n: int, stages: int, inverse: bool):
    xr = _bit_reverse_rows(xr_ref[...], stages)
    xi = _bit_reverse_rows(xi_ref[...], stages)
    b = xr.shape[0]
    for s in range(stages):
        stride = 1 << s
        blocks = n // (2 * stride)
        wr = twr_ref[s].reshape(blocks, stride)
        wi = twi_ref[s].reshape(blocks, stride)
        if inverse:
            wi = -wi
        tr = xr.reshape(b, blocks, 2, stride)
        ti = xi.reshape(b, blocks, 2, stride)
        top_r, bot_r = tr[:, :, 0, :], tr[:, :, 1, :]
        top_i, bot_i = ti[:, :, 0, :], ti[:, :, 1, :]
        # w * bot (complex multiply on planes)
        wb_r = wr * bot_r - wi * bot_i
        wb_i = wr * bot_i + wi * bot_r
        y_top_r, y_top_i = top_r + wb_r, top_i + wb_i
        y_bot_r, y_bot_i = top_r - wb_r, top_i - wb_i
        xr = jnp.stack([y_top_r, y_bot_r], axis=2).reshape(b, n)
        xi = jnp.stack([y_top_i, y_bot_i], axis=2).reshape(b, n)
    if inverse:
        xr = xr / n
        xi = xi / n
    or_ref[...] = xr
    oi_ref[...] = xi


@functools.partial(jax.jit, static_argnames=("block_b", "inverse"))
def fft(xr: jnp.ndarray, xi: jnp.ndarray, block_b: int = DEFAULT_BLOCK_B,
        inverse: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched 1D FFT over the last axis; planes (batch, n) -> (re, im)."""
    batch, n = xr.shape
    assert xi.shape == (batch, n)
    stages = log2_int(n)
    tw = fft_twiddles(n)
    twr = jnp.asarray(tw.real, dtype=xr.dtype)
    twi = jnp.asarray(tw.imag, dtype=xr.dtype)
    if batch % block_b != 0:
        pad = block_b - batch % block_b
        z = jnp.zeros((pad, n), xr.dtype)
        xr = jnp.concatenate([xr, z], axis=0)
        xi = jnp.concatenate([xi, z], axis=0)
    grid = (xr.shape[0] // block_b,)
    spec_x = pl.BlockSpec((block_b, n), lambda i: (i, 0))
    spec_tw = pl.BlockSpec((stages, n // 2), lambda i: (0, 0))
    out_r, out_i = pl.pallas_call(
        functools.partial(_fft_kernel, n=n, stages=stages, inverse=inverse),
        grid=grid,
        in_specs=[spec_x, spec_x, spec_tw, spec_tw],
        out_specs=[spec_x, spec_x],
        out_shape=[jax.ShapeDtypeStruct(xr.shape, xr.dtype)] * 2,
        interpret=True,
    )(xr, xi, twr, twi)
    return out_r[:batch], out_i[:batch]


def fft_real(x: jnp.ndarray, block_b: int = DEFAULT_BLOCK_B):
    """FFT of a real batch (batch, n) -> (re, im) planes."""
    return fft(x, jnp.zeros_like(x), block_b=block_b)


def fft2d(x: jnp.ndarray, block_b: int = DEFAULT_BLOCK_B):
    """2D FFT over (seq, hidden) of a real input (..., seq, hidden).

    FNet mixing: fft over hidden, then over sequence.  Returns (re, im).
    Leading axes are flattened into the batch (paper: batch x head
    dimensions pour iterations into the DFG pipeline).
    """
    lead = x.shape[:-2]
    seq, hid = x.shape[-2:]
    flat = x.reshape((-1, hid))
    hr, hi = fft_real(flat, block_b=block_b)
    hr = hr.reshape(lead + (seq, hid))
    hi = hi.reshape(lead + (seq, hid))
    # FFT along sequence: transpose seq<->hidden, batch the rest.
    hr_t = jnp.swapaxes(hr, -1, -2).reshape((-1, seq))
    hi_t = jnp.swapaxes(hi, -1, -2).reshape((-1, seq))
    sr, si = fft(hr_t, hi_t, block_b=block_b)
    sr = jnp.swapaxes(sr.reshape(lead + (hid, seq)), -1, -2)
    si = jnp.swapaxes(si.reshape(lead + (hid, seq)), -1, -2)
    return sr, si


def fnet_mixing(x: jnp.ndarray, block_b: int = DEFAULT_BLOCK_B) -> jnp.ndarray:
    """FNet token mixing Re(FFT2(x)) built on the Pallas FFT kernel."""
    sr, _ = fft2d(x, block_b=block_b)
    return sr.astype(x.dtype)
