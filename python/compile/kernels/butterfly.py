"""L1 Pallas kernel: BPMM — butterfly-pattern matrix multiply.

The paper's hot-spot is the chain of ``log2(n)`` butterfly stages applied to
a batch of vectors (Fig. 4 / Fig. 5b).  The TPU adaptation of the
"multilayer DFG stays resident in SPM" idea (DESIGN.md §Hardware-Adaptation)
is: one ``pallas_call`` invocation owns a ``(block_b, n)`` tile in VMEM and
runs **all stages** on it before writing back — HBM sees each element twice
(one load, one store) regardless of the stage count, exactly like the
paper's SPM-resident multilayer execution avoids per-stage shuffles.

The batch dimension maps onto the vector lanes (the paper's SIMD-lane
batching of §V-C); the stage loop is unrolled at trace time since
``log2(n)`` is static.

``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; correctness is the contract here, TPU timing is estimated
analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import log2_int

# Maximum single-DFG scale the paper maps on the PE array (BPMM, real).
MAX_BPMM_POINTS = 512
# Default batch tile: matches the SIMD16 entry width of the paper's SPM.
DEFAULT_BLOCK_B = 16


def _bpmm_kernel(x_ref, w_ref, o_ref, *, stages: int):
    """All butterfly stages over one (block_b, n) tile, VMEM-resident."""
    x = x_ref[...]
    b, n = x.shape
    for s in range(stages):
        stride = 1 << s
        blocks = n // (2 * stride)
        xr = x.reshape(b, blocks, 2, stride)
        # Stage weights: (n//2, 4) laid out as (blocks, stride, 4).
        w = w_ref[s].reshape(blocks, stride, 4)
        top, bot = xr[:, :, 0, :], xr[:, :, 1, :]
        y_top = w[:, :, 0] * top + w[:, :, 1] * bot
        y_bot = w[:, :, 2] * top + w[:, :, 3] * bot
        x = jnp.stack([y_top, y_bot], axis=2).reshape(b, n)
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("block_b",))
def bpmm(x: jnp.ndarray, factors: jnp.ndarray,
         block_b: int = DEFAULT_BLOCK_B) -> jnp.ndarray:
    """Apply a full BPMM factor set to ``x`` of shape (batch, n).

    ``factors``: (log2 n, n//2, 4) real stage weights (see ref.py).
    Batch is tiled by ``block_b``; n stays whole inside a tile (n <= 512
    per the paper's single-DFG limit — larger n goes through the
    multi-stage division in model.py).
    """
    batch, n = x.shape
    stages = log2_int(n)
    assert factors.shape == (stages, n // 2, 4), factors.shape
    if batch % block_b != 0:
        # Pad the batch to a tile multiple; cheaper than a ragged grid.
        pad = block_b - batch % block_b
        x = jnp.concatenate([x, jnp.zeros((pad, n), x.dtype)], axis=0)
    grid = (x.shape[0] // block_b,)
    out = pl.pallas_call(
        functools.partial(_bpmm_kernel, stages=stages),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            # Full factor stack resident per tile (the paper pre-stores
            # stage weights in each PE before streaming iterations).
            pl.BlockSpec((stages, n // 2, 4), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, factors)
    return out[:batch]


def _bpmm_grouped_kernel(x_ref, w_ref, o_ref, *, stages: int):
    """Like _bpmm_kernel but with a per-group factor set (leading dim 1)."""
    x = x_ref[0]
    b, n = x.shape
    for s in range(stages):
        stride = 1 << s
        blocks = n // (2 * stride)
        xr = x.reshape(b, blocks, 2, stride)
        w = w_ref[0, s].reshape(blocks, stride, 4)
        top, bot = xr[:, :, 0, :], xr[:, :, 1, :]
        y_top = w[:, :, 0] * top + w[:, :, 1] * bot
        y_bot = w[:, :, 2] * top + w[:, :, 3] * bot
        x = jnp.stack([y_top, y_bot], axis=2).reshape(b, n)
    o_ref[0] = x


@functools.partial(jax.jit, static_argnames=("block_b",))
def bpmm_grouped(x: jnp.ndarray, factors: jnp.ndarray,
                 block_b: int = DEFAULT_BLOCK_B) -> jnp.ndarray:
    """Grouped BPMM: x (groups, batch, n), factors (groups, log2 n, n//2, 4).

    Group g's batch rows all go through factor set g.  This is the
    single-launch form of the Fig. 9 column/row stages, where each column
    (row) of the reshaped matrix carries its own butterfly weights —
    the Monarch block-diagonal structure.
    """
    groups, batch, n = x.shape
    stages = log2_int(n)
    assert factors.shape == (groups, stages, n // 2, 4), factors.shape
    if batch % block_b != 0:
        pad = block_b - batch % block_b
        x = jnp.concatenate(
            [x, jnp.zeros((groups, pad, n), x.dtype)], axis=1)
    bt = x.shape[1] // block_b
    out = pl.pallas_call(
        functools.partial(_bpmm_grouped_kernel, stages=stages),
        grid=(groups, bt),
        in_specs=[
            pl.BlockSpec((1, block_b, n), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, stages, n // 2, 4), lambda g, i: (g, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, n), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, factors)
    return out[:, :batch, :]


def bpmm_single_stage(x: jnp.ndarray, w: jnp.ndarray, stage: int,
                      block_b: int = DEFAULT_BLOCK_B) -> jnp.ndarray:
    """One butterfly stage as its own kernel (used by the stage-division
    path where a synchronization barrier separates stages)."""
    batch, n = x.shape
    stages_total = log2_int(n)
    assert 0 <= stage < stages_total
    return bpmm(x, _single_stage_factors(w, n, stage), block_b=block_b)


def _single_stage_factors(w: jnp.ndarray, n: int, stage: int) -> jnp.ndarray:
    """Embed one stage's weights into an identity factor stack."""
    stages = log2_int(n)
    ident = jnp.tile(jnp.asarray([1.0, 0.0, 0.0, 1.0], dtype=w.dtype),
                     (stages, n // 2, 1))
    return ident.at[stage].set(w)
